#!/usr/bin/env bash
# Tier-1 verify: the fast, single-process test suite (see ROADMAP.md).
# The `slow` marker excludes the multi-device subprocess tests
# (tests/test_distributed.py); run plain `pytest` for the full gate.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -q -m "not slow" "$@"
