"""Diff two BENCH_*.json artifacts and fail on throughput regressions.

Rows are matched by their IDENTITY fields — every key that is not a
measurement or derived statistic (``*_ms``, ``*_mbps``, ``*_speedup``,
``*_share``, ``*_steps``, ``*_vs_*``) — so a row compares only against the
same benchmark kind, geometry, backend and knob settings, and a PR that
legitimately changes a derived value (e.g. the traceback walk length) still
gates its throughput against the baseline row. On each matched row, every decoded-bits/s field
(``*_mbps``) in the new file must be at least ``(1 - threshold)`` × the old
value; latency fields are reported but not gated (they overlap the mbps
signal and double-gating doubles the noise).

Exit status: 0 = no regression (including "no matching rows" — geometry
changes are not regressions), 1 = at least one gated field regressed
beyond the threshold, 2 = usage/IO error.

CI usage (the bench-smoke job runs the smoke sweep on the PR head AND on
the merge-base of the same runner, so the comparison is same-machine):

    python tools/bench_compare.py BENCH_base.json BENCH_head.json \
        [--threshold 0.15] [--min-matches 1]
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

MEASUREMENT_SUFFIXES = ("_ms", "_mbps", "_speedup", "_share", "_steps")


def _is_measurement(key: str) -> bool:
    return key.endswith(MEASUREMENT_SUFFIXES) or "_vs_" in key


def row_identity(row: dict) -> tuple:
    """Hashable identity of a row: its non-measurement fields, sorted."""
    return tuple(sorted((k, v) for k, v in row.items() if not _is_measurement(k)))


def load_rows(path: str) -> list[dict]:
    doc = json.loads(Path(path).read_text())
    rows = doc.get("rows", doc if isinstance(doc, list) else [])
    if not isinstance(rows, list):
        raise ValueError(f"{path}: no 'rows' list found")
    return rows


def compare(
    old_rows: list[dict], new_rows: list[dict], *, threshold: float
) -> tuple[list[str], int]:
    """Returns (regression messages, number of matched gated fields)."""
    old_by_id = {row_identity(r): r for r in old_rows}
    regressions: list[str] = []
    matched = 0
    for new in new_rows:
        old = old_by_id.get(row_identity(new))
        if old is None:
            continue
        label = ",".join(
            f"{k}={v}" for k, v in sorted(new.items()) if not _is_measurement(k)
        )
        for key, new_val in new.items():
            if key not in old:
                continue
            old_val = old[key]
            if not isinstance(old_val, (int, float)) or old_val <= 0:
                continue
            if key.endswith("_ms"):
                # latency is REPORTED next to the gated throughput (so a
                # serve_latency tail blow-up is visible in the job log) but
                # never gated: it overlaps the mbps signal and double-gating
                # doubles the noise
                ratio = float(new_val) / float(old_val)
                print(f"info        {label}: {key} {old_val} → {new_val} ({ratio:.2f}×)")
                continue
            if not key.endswith("_mbps"):
                continue
            matched += 1
            ratio = float(new_val) / float(old_val)
            line = f"{label}: {key} {old_val} → {new_val} ({ratio:.2f}×)"
            if ratio < 1.0 - threshold:
                regressions.append(line)
                print(f"REGRESSION  {line}")
            else:
                print(f"ok          {line}")
    return regressions, matched


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("old", help="baseline BENCH_*.json")
    ap.add_argument("new", help="candidate BENCH_*.json")
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.15,
        help="maximum tolerated fractional drop in any *_mbps field (default 0.15)",
    )
    ap.add_argument(
        "--min-matches",
        type=int,
        default=0,
        help="fail unless at least this many gated fields matched (guards "
        "against a silently vacuous comparison; default 0 = allow none)",
    )
    args = ap.parse_args(argv)

    try:
        old_rows = load_rows(args.old)
        new_rows = load_rows(args.new)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    regressions, matched = compare(old_rows, new_rows, threshold=args.threshold)
    print(
        f"# {matched} gated field(s) compared across "
        f"{len(new_rows)} candidate row(s); threshold {args.threshold:.0%}"
    )
    if matched < args.min_matches:
        print(
            f"error: only {matched} matched field(s) < --min-matches "
            f"{args.min_matches} (identity fields drifted?)",
            file=sys.stderr,
        )
        return 2
    if regressions:
        print(
            f"FAIL: {len(regressions)} field(s) regressed beyond "
            f"{args.threshold:.0%}",
            file=sys.stderr,
        )
        return 1
    print("PASS: no throughput regression")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
