#!/usr/bin/env bash
# Property-based verify: the hypothesis equivalence suite at CI depth.
# Runs tests/test_property.py with a raised example count and (when the
# real hypothesis package is installed) derandomized, fixed-seed draws —
# the conftest shim is deterministic by construction. Override the count:
#   PROPERTY_MAX_EXAMPLES=100 tools/run_property.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export PROPERTY_MAX_EXAMPLES="${PROPERTY_MAX_EXAMPLES:-25}"
exec python -m pytest -q tests/test_property.py "$@"
