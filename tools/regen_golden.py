"""Regenerate the golden known-answer vectors in tests/golden/.

    PYTHONPATH=src python tools/regen_golden.py [--check]

One ``.npz`` per registered CodeSpec, each holding a fixed-seed noisy
transmission (the *symbols themselves* are stored, so the test suite never
re-derives them through the encoder/channel — cross-version JAX/XLA drift in
either shows up as a golden mismatch, not a silently moved reference):

  ``payload``      (n_bits,) uint8   — the transmitted payload bits
  ``y``            float32           — received soft symbols ((n, R) full-rate,
                                       or (n,) punctured wire format)
  ``bits_f32``     (n_bits,) uint8   — expected decode, metric_mode="f32"
                                       (bit-exact for "i16" too, by contract)
  ``bits_i8``      (n_bits,) uint8   — expected decode, metric_mode="i8"
  ``meta``         json string       — geometry + generator provenance

Decodes are generated with the ``ref`` backend; the backend-parity suite
holds ``pallas``/``fused`` equal to ``ref``, so ``tests/test_golden.py``
replays every spec × backend × metric mode against these arrays.

``--check`` regenerates in memory and fails (exit 1) on any mismatch with
the committed files — the regeneration workflow is: edit decoder → run
``--check`` → if the change is *intended* to move decode results, rerun
without ``--check`` and commit the new vectors with an explanation.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.channel import transmit
from repro.core.codespec import available_code_specs, get_code_spec
from repro.core.encoder import encode_jax, terminate
from repro.core.engine import DecoderEngine
from repro.core.pbvd import PBVDConfig

GOLDEN_DIR = Path(__file__).resolve().parent.parent / "tests" / "golden"

# Fixed golden geometry: D/L exercise the framing (several blocks per
# stream, depth ≈ 6K for the largest registered K), q=8 symbols.
GEOMETRY = dict(D=48, L=28, q=8)
N_BITS = 160
EBN0_DB = 4.5
SEED = 20260729  # never change without regenerating every vector


def spec_filename(name: str) -> str:
    return name.replace("/", "_") + ".npz"


def generate(name: str) -> dict:
    spec = get_code_spec(name)
    rng = np.random.default_rng(SEED)
    payload = rng.integers(0, 2, N_BITS)
    coded = encode_jax(jnp.asarray(terminate(payload, spec.code)), spec.code)
    tx = spec.puncture_stream(coded) if spec.is_punctured else coded
    y = np.asarray(transmit(jax.random.PRNGKey(SEED), tx, EBN0_DB, spec.rate))

    out = dict(
        payload=payload.astype(np.uint8),
        y=y.astype(np.float32),
        meta=json.dumps(
            dict(spec=name, seed=SEED, ebn0_db=EBN0_DB, n_bits=N_BITS, **GEOMETRY)
        ),
    )
    for mode in ("f32", "i8"):
        cfg = PBVDConfig(spec=spec, backend="ref", metric_mode=mode, **GEOMETRY)
        bits = np.asarray(DecoderEngine(cfg).decode(jnp.asarray(y), N_BITS))
        out[f"bits_{mode}"] = bits.astype(np.uint8)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--check",
        action="store_true",
        help="verify committed vectors instead of rewriting them",
    )
    args = ap.parse_args(argv)

    GOLDEN_DIR.mkdir(parents=True, exist_ok=True)
    bad = []
    for name in available_code_specs():
        fresh = generate(name)
        path = GOLDEN_DIR / spec_filename(name)
        if args.check:
            if not path.exists():
                bad.append(f"{name}: {path.name} missing")
                continue
            with np.load(path, allow_pickle=False) as old:
                for key, val in fresh.items():
                    if key == "meta":
                        continue
                    if not np.array_equal(old[key], val):
                        bad.append(f"{name}: {key} drifted")
            print(f"[golden] {name}: ok")
        else:
            np.savez_compressed(path, **fresh)
            ber = float(np.mean(fresh["bits_f32"] != fresh["payload"]))
            print(f"[golden] wrote {path.name} (f32 BER {ber:.3f})")
    if bad:
        print("[golden] MISMATCH:\n  " + "\n  ".join(bad), file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
