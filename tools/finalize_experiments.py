"""Patches EXPERIMENTS.md with the generated roofline table and perf tables."""

import io
import re
import subprocess
import sys
from contextlib import redirect_stdout
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))


def main():
    from repro.launch import perf_report
    from repro.launch.roofline import load_all, to_markdown

    exp = (ROOT / "EXPERIMENTS.md").read_text()

    table = to_markdown(load_all(ROOT / "reports" / "dryrun"))
    exp = exp.replace("<!-- ROOFLINE_TABLE -->", table)

    # capture perf_report sections
    buf = io.StringIO()
    with redirect_stdout(buf):
        perf_report.main()
    sections = buf.getvalue()
    parts = re.split(r"^### ", sections, flags=re.M)
    cells = {}
    for part in parts:
        if part.startswith("Cell 1"):
            cells["PERF_CELL1"] = "### " + part.strip()
        elif part.startswith("Cell 2"):
            cells["PERF_CELL2"] = "### " + part.strip()
        elif part.startswith("Cell 3"):
            cells["PERF_CELL3"] = "### " + part.strip()
        elif part.startswith("Extra"):
            cells["PERF_CELL2"] = cells.get("PERF_CELL2", "") + "\n\n### " + part.strip()

    for marker, content in cells.items():
        # strip the duplicate header line (the narrative already has one)
        body = "\n".join(content.splitlines()[1:]).strip()
        exp = exp.replace(f"<!-- {marker} -->", body)

    (ROOT / "EXPERIMENTS.md").write_text(exp)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
