"""End-to-end driver (deliverable b): train a ~100M-param LM for a few
hundred steps through the full production stack — data pipeline with
prefetch, pjit train step, sharded AdamW, async checkpoints, failure
recovery.

    PYTHONPATH=src python examples/train_tiny_lm.py --steps 300

(On this CPU container a 100M model at seq 128 runs ~1 step/s; pass
--preset tiny for a quick smoke.)
"""

import argparse

from repro.configs.base import get_config
from repro.launch.mesh import make_local_mesh
from repro.launch.train import TrainLoop, preset_config
from repro._unused.train.optimizer import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--preset", default="100m", choices=["tiny", "100m"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_tiny_lm")
    args = ap.parse_args()

    cfg = preset_config(args.arch, args.preset)
    from repro._unused.models import lm as _lm
    import jax

    n_params = sum(
        int(__import__("numpy").prod(l.shape))
        for l in jax.tree.leaves(
            jax.eval_shape(lambda k: _lm.init_params(k, cfg), jax.random.PRNGKey(0))
        )
    )
    print(f"training {cfg.name}: {n_params/1e6:.1f}M params, "
          f"batch {args.global_batch} × seq {args.seq_len}, {args.steps} steps")

    loop = TrainLoop(
        cfg,
        AdamWConfig(lr=1e-3, total_steps=args.steps, warmup_steps=max(args.steps // 20, 5)),
        make_local_mesh(),
        ckpt_dir=args.ckpt_dir,
        global_batch=args.global_batch,
        seq_len=args.seq_len,
        ckpt_every=100,
    )
    try:
        log = loop.run(args.steps)
        print(f"loss: {log[0]['loss']} → {log[-1]['loss']}")
        assert log[-1]["loss"] < log[0]["loss"], "loss did not decrease"
    finally:
        loop.pipeline.close()


if __name__ == "__main__":
    main()
