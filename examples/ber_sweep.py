"""BER sweep (paper Fig. 4): traceback depth L vs error rate, at any rate of
the punctured code family.

    PYTHONPATH=src python examples/ber_sweep.py [--bits 32768] [--code ccsds-3/4]
"""

import argparse

import jax

from repro.core.ber import simulate_ber, uncoded_ber
from repro.core.codespec import available_code_specs, get_code_spec
from repro.core.pbvd import PBVDConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bits", type=int, default=1 << 15)
    ap.add_argument("--code", default="ccsds", choices=available_code_specs())
    ap.add_argument("--depths", type=int, nargs="+", default=[14, 28, 42])
    ap.add_argument("--ebn0", type=float, nargs="+", default=[2.0, 3.0, 4.0])
    args = ap.parse_args()

    spec = get_code_spec(args.code)
    key = jax.random.PRNGKey(0)
    print(f"code {spec.name}: K={spec.code.K}, rate={spec.rate:.3f}")
    print(f"{'Eb/N0':>6} {'uncoded':>10} " + " ".join(f"L={L:>8}" for L in args.depths))
    for ebn0 in args.ebn0:
        row = [f"{ebn0:6.1f}", f"{uncoded_ber(ebn0):10.2e}"]
        for L in args.depths:
            key, k = jax.random.split(key)
            cfg = PBVDConfig(spec=spec, D=512, L=L, q=8, backend="ref")
            ber = simulate_ber(k, ebn0, cfg, n_bits=args.bits)
            row.append(f"{ber:10.2e}")
        print(" ".join(row))
    print("\npaper's conclusion: L = 42 ≈ 6K reaches near-ML performance; "
          "shallower L floors early (and punctured rates need deeper L still).")


if __name__ == "__main__":
    main()
