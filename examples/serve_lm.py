"""Batched serving demo: KV-cache decode through the serving stack,
including a sliding-window model (rolling cache) and an SSM (state cache).

    PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro._unused.models import lm
from repro._unused.serve.serve_step import make_decode_step


def serve(arch: str, n_new: int = 48, batch: int = 4):
    cfg = get_config(arch).reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (batch, 8)), jnp.int32)
    s_max = prompt.shape[1] + n_new
    cache = lm.init_cache(cfg, batch, s_max)
    step = jax.jit(make_decode_step(cfg, s_max))

    # prefill (token-by-token for simplicity; prefill_32k lowers the batched path)
    tok = prompt[:, :1]
    for t in range(prompt.shape[1]):
        nxt, cache = step(params, prompt[:, t : t + 1], cache, jnp.int32(t))
    t0 = time.perf_counter()
    out = []
    tok = nxt[:, None]
    for t in range(n_new):
        out.append(tok)
        nxt, cache = step(params, tok, cache, jnp.int32(prompt.shape[1] + t))
        tok = nxt[:, None]
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    toks = jnp.concatenate(out, axis=1)
    print(f"{arch:22s} generated {batch}×{n_new} tokens in {dt*1e3:.0f} ms "
          f"({batch*n_new/dt:.0f} tok/s) — cache kinds: "
          + ("KV ring" if cfg.sliding_window else "state" if cfg.family == "ssm" else "KV"))
    return toks


def main():
    for arch in ("starcoder2-3b", "rwkv6-3b", "mixtral-8x22b"):
        serve(arch)


if __name__ == "__main__":
    main()
