"""Quickstart: decode a noisy CCSDS (2,1,7) stream with the DecoderEngine.

    PYTHONPATH=src python examples/quickstart.py

Walks the full paper pipeline: encode → BPSK+AWGN → 8-bit quantize (packed
H2D format) → parallel-block framing → backend-dispatched decode → BER check,
then re-decodes the same stream chunk-by-chunk through a streaming session
and at a punctured rate — both one-liners on the same engine API.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.channel import transmit
from repro.core.codespec import get_code_spec
from repro.core.encoder import encode_jax, terminate
from repro.core.engine import DecoderEngine
from repro.core.pbvd import PBVDConfig
from repro.core.quantize import pack_words, quantize_soft, u1_bytes


def main():
    spec = get_code_spec("ccsds")
    code = spec.code
    n_bits = 100_000
    ebn0_db = 4.0
    print(f"CCSDS (2,1,7): K={code.K}, R=1/{code.R}, {code.n_states} states, "
          f"{code.n_groups} butterfly groups (paper Table II)")

    # --- transmit ------------------------------------------------------------------
    rng = np.random.default_rng(0)
    payload = rng.integers(0, 2, n_bits)
    bits = terminate(payload, code)
    coded = encode_jax(jnp.asarray(bits), code)
    y = transmit(jax.random.PRNGKey(1), coded, ebn0_db, spec.rate)
    print(f"transmitted {n_bits} bits at Eb/N0 = {ebn0_db} dB")

    # --- the paper's packed H2D format ------------------------------------------------
    yq = quantize_soft(y, q=8)
    packed = pack_words(yq.reshape(-1), q=8)
    print(f"8-bit packed input: {packed.size * 4} bytes "
          f"(U1 = {u1_bytes(code.R, 8)} B/symbol vs {u1_bytes(code.R, None)} float32)")

    # --- one-shot decode through the engine -----------------------------------------
    engine = DecoderEngine(PBVDConfig(spec=spec, D=512, L=42, q=8, backend="ref"))
    t0 = time.perf_counter()
    decoded = engine.decode(y, n_bits)
    decoded.block_until_ready()
    dt = time.perf_counter() - t0
    n_blocks = -(-n_bits // engine.cfg.D)
    ber = float(jnp.mean(decoded != jnp.asarray(payload)))
    print(f"decoded {n_blocks} parallel blocks (D={engine.cfg.D}, L={engine.cfg.L}) "
          f"in {dt*1e3:.1f} ms → {n_bits/dt/1e6:.2f} Mbps (CPU)")
    print(f"BER = {ber:.2e}  ({int(ber*n_bits)} errors)")
    assert ber < 1e-3

    # --- the same stream, chunk-by-chunk through a streaming session -----------------
    sess = engine.session()
    ya = np.asarray(y)
    chunks = np.array_split(ya, 20)
    outs = [sess.decode(c) for c in chunks]
    outs.append(sess.finish(n_bits))
    streamed = np.concatenate(outs)
    print(f"streaming session: {len(chunks)} chunks → "
          f"bit-exact to one-shot: {np.array_equal(streamed, np.asarray(decoded))}")

    # --- punctured rate 3/4 from the same mother code --------------------------------
    spec34 = get_code_spec("ccsds-3/4")
    tx = spec34.puncture_stream(coded)
    y34 = transmit(jax.random.PRNGKey(2), tx, ebn0_db + 1.5, spec34.rate)
    eng34 = DecoderEngine(PBVDConfig(spec=spec34, D=512, L=42, q=8, backend="ref"))
    dec34 = eng34.decode(y34, n_bits)
    ber34 = float(jnp.mean(dec34 != jnp.asarray(payload)))
    print(f"punctured rate {spec34.rate:.2f}: {tx.shape[0]} symbols "
          f"(vs {coded.shape[0]*code.R} unpunctured), BER = {ber34:.2e} at "
          f"Eb/N0 = {ebn0_db + 1.5} dB")


if __name__ == "__main__":
    main()
