"""Quickstart: decode a noisy CCSDS (2,1,7) stream with the PBVD decoder.

    PYTHONPATH=src python examples/quickstart.py

Walks the full paper pipeline: encode → BPSK+AWGN → 8-bit quantize (packed
H2D format) → parallel-block framing → two-phase decode → BER check.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.channel import transmit
from repro.core.encoder import encode_jax, terminate
from repro.core.pbvd import PBVDConfig, decode_stream
from repro.core.quantize import pack_words, quantize_soft, u1_bytes
from repro.core.trellis import CCSDS_27


def main():
    code = CCSDS_27
    n_bits = 100_000
    ebn0_db = 4.0
    print(f"CCSDS (2,1,7): K={code.K}, R=1/{code.R}, {code.n_states} states, "
          f"{code.n_groups} butterfly groups (paper Table II)")

    # --- transmit ------------------------------------------------------------------
    rng = np.random.default_rng(0)
    payload = rng.integers(0, 2, n_bits)
    bits = terminate(payload, code)
    coded = encode_jax(jnp.asarray(bits), code)
    y = transmit(jax.random.PRNGKey(1), coded, ebn0_db, code.rate)
    print(f"transmitted {n_bits} bits at Eb/N0 = {ebn0_db} dB")

    # --- the paper's packed H2D format ------------------------------------------------
    yq = quantize_soft(y, q=8)
    packed = pack_words(yq.reshape(-1), q=8)
    print(f"8-bit packed input: {packed.size * 4} bytes "
          f"(U1 = {u1_bytes(code.R, 8)} B/symbol vs {u1_bytes(code.R, None)} float32)")

    # --- decode -------------------------------------------------------------------------
    cfg = PBVDConfig(D=512, L=42, q=8, backend="ref")
    t0 = time.perf_counter()
    decoded = decode_stream(y, n_bits, cfg)
    decoded.block_until_ready()
    dt = time.perf_counter() - t0
    n_blocks = -(-n_bits // cfg.D)
    ber = float(jnp.mean(decoded != jnp.asarray(payload)))
    print(f"decoded {n_blocks} parallel blocks (D={cfg.D}, L={cfg.L}) "
          f"in {dt*1e3:.1f} ms → {n_bits/dt/1e6:.2f} Mbps (CPU)")
    print(f"BER = {ber:.2e}  ({int(ber*n_bits)} errors)")
    assert ber < 1e-3


if __name__ == "__main__":
    main()
