"""Pallas TPU kernel for the PBVD forward ACS phase (paper kernel K1).

TPU mapping (see DESIGN.md §2):

* parallel blocks live on the **lane axis** (tiles of ``LANE_TILE = 128``);
  the trellis states live on sublanes — ``PM`` is a ``(N, 128)`` VMEM-resident
  matrix per program instance (for the CCSDS code: 64×128×4 B = 32 KiB).
* the stage loop is tiled by the second grid dimension; ``PM`` persists in a
  VMEM scratch across stage-chunks (grid iterates stage-chunks innermost) and
  is re-zeroed at chunk 0 — this is the TPU analogue of the GPU kernel
  keeping PM in shared memory for the whole block.
* the paper's group-based BM reduction: only ``2^R`` branch metrics are
  computed per stage (R multiply-adds each); they are expanded to the four
  per-butterfly metric rows (α/β/γ/θ) with **static one-hot combinations**
  — no gathers, no warp shuffles.
* the butterfly read ``PM[2j], PM[2j+1]`` is a free sublane reshape
  ``(N, T) → (N/2, 2, T)``; the write-back is a concat of the top/bottom
  halves. No shared-memory banking concerns exist on TPU.
* survivor decisions are bit-packed on the fly to ``ceil(N/32)`` int32 words
  per stage (weighted sublane reduction), giving the paper's
  ``SP[T][words][blocks]`` layout with fully coalesced (lane-contiguous)
  stores — and 32× less HBM traffic than byte-per-state.

The same kernel body runs the float32 path and the exact int32 path (for
q-bit quantized symbols): integer PM accumulation never overflows within a
block (headroom 2^31 / (R·2^q) stages).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.trellis import ConvCode

__all__ = ["acs_forward_pallas", "LANE_TILE", "DEFAULT_STAGE_CHUNK"]

LANE_TILE = 128
DEFAULT_STAGE_CHUNK = 64


def _acs_kernel(
    y_ref,  # (SC, R, TILE) soft symbols for this stage chunk
    signs_ref,  # (4, nb, R) per-butterfly codeword signs [α, γ, β, θ] rows
    sp_ref,  # (SC, W, TILE) int32 out: packed survivor words
    pm_out_ref,  # (N, TILE) out: final path metrics (last chunk's write wins)
    pm_ref,  # scratch (N, TILE) acc_dtype: path metrics, persists across chunks
    *,
    code: ConvCode,
    stage_chunk: int,
    acc_dtype,
):
    nb = code.n_butterflies
    tile = pm_ref.shape[-1]

    @pl.when(pl.program_id(1) == 0)
    def _init():
        pm_ref[...] = jnp.zeros_like(pm_ref)

    def stage_body(s, pm):
        # ---- group-reduced branch metrics -------------------------------------
        # The 2^R-entry BM table composed with the static α/β/γ/θ lookup is a
        # rank-R linear map; we apply it directly as R multiply-adds per row:
        #   bm_row[j] = Σ_r signs[row, j, r] * y[r]
        y_s = y_ref[pl.ds(s, 1)][0]  # (R, TILE)
        y_s = y_s.astype(acc_dtype)
        bm_rows = []
        for row in range(4):  # α (top/even), γ (top/odd), β (bot/even), θ (bot/odd)
            acc = jnp.zeros((nb, tile), dtype=acc_dtype)
            for r in range(code.R):
                acc = acc + signs_ref[row, :, r][:, None] * y_s[r][None, :]
            bm_rows.append(acc)
        bm_te, bm_to, bm_be, bm_bo = bm_rows

        # ---- butterfly ACS: reshape replaces the GPU shared-memory shuffle ---
        pairs = pm.reshape(nb, 2, tile)
        pm_even, pm_odd = pairs[:, 0], pairs[:, 1]

        m_te = pm_even + bm_te
        m_to = pm_odd + bm_to
        dec_top = (m_to < m_te).astype(jnp.int32)
        pm_top = jnp.minimum(m_te, m_to)

        m_be = pm_even + bm_be
        m_bo = pm_odd + bm_bo
        dec_bot = (m_bo < m_be).astype(jnp.int32)
        pm_bot = jnp.minimum(m_be, m_bo)

        new_pm = jnp.concatenate([pm_top, pm_bot], axis=0)  # (N, TILE)

        # ---- bit-pack survivor decisions to int32 words ----------------------
        dec = jnp.concatenate([dec_top, dec_bot], axis=0)  # (N, TILE)
        n = dec.shape[0]
        pad = (-n) % 32
        if pad:
            dec = jnp.concatenate([dec, jnp.zeros((pad, tile), jnp.int32)], axis=0)
        n_words = dec.shape[0] // 32
        d = dec.reshape(n_words, 32, tile)
        weights = (jnp.int32(1) << jnp.arange(32, dtype=jnp.int32))[None, :, None]
        words = (d * weights).sum(axis=1, dtype=jnp.int32)  # (W, TILE)
        sp_ref[pl.ds(s, 1)] = words[None]
        return new_pm

    pm = pm_ref[...]
    pm = jax.lax.fori_loop(0, stage_chunk, stage_body, pm, unroll=False)
    pm_ref[...] = pm
    pm_out_ref[...] = pm


@functools.partial(
    jax.jit, static_argnames=("code", "stage_chunk", "interpret")
)
def acs_forward_pallas(
    y: jnp.ndarray,
    code: ConvCode,
    *,
    stage_chunk: int = DEFAULT_STAGE_CHUNK,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Forward ACS over parallel blocks. y: (T, R, B) → (sp (T, W, B), pm (N, B)).

    T must be a multiple of ``stage_chunk`` and B a multiple of 128 (the ops
    wrapper pads). Float32 and integer (int8/int16/int32) inputs supported;
    integer inputs run the exact int32-PM path.
    """
    T, R, B = y.shape
    if R != code.R:
        raise ValueError(f"symbol rank {R} != code R {code.R}")
    if T % stage_chunk:
        raise ValueError(f"T={T} not a multiple of stage_chunk={stage_chunk}")
    if B % LANE_TILE:
        raise ValueError(f"B={B} not a multiple of {LANE_TILE}")
    integer = jnp.issubdtype(y.dtype, jnp.integer)
    acc_dtype = jnp.int32 if integer else jnp.float32
    y = y.astype(acc_dtype)

    N = code.n_states
    W = (N + 31) // 32
    n_bt = B // LANE_TILE
    n_sc = T // stage_chunk
    nb = code.n_butterflies

    # per-butterfly codeword sign tables, rows [α, γ, β, θ] (see kernel body)
    cw = code.butterfly_codewords  # (nb, 4) as [α, β, γ, θ]
    signs_np = code.codeword_signs[cw[:, [0, 2, 1, 3]]]  # (nb, 4, R) → reorder
    signs_arr = jnp.asarray(np.transpose(signs_np, (1, 0, 2)), dtype=acc_dtype)

    kernel = functools.partial(
        _acs_kernel, code=code, stage_chunk=stage_chunk, acc_dtype=acc_dtype
    )
    sp, pm = pl.pallas_call(
        kernel,
        grid=(n_bt, n_sc),
        in_specs=[
            pl.BlockSpec((stage_chunk, R, LANE_TILE), lambda bt, sc: (sc, 0, bt)),
            pl.BlockSpec((4, nb, R), lambda bt, sc: (0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((stage_chunk, W, LANE_TILE), lambda bt, sc: (sc, 0, bt)),
            # PM written out on every chunk; only the last chunk's value is
            # meaningful (same block for all sc → last write wins).
            pl.BlockSpec((N, LANE_TILE), lambda bt, sc: (0, bt)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, W, B), jnp.int32),
            jax.ShapeDtypeStruct((N, B), acc_dtype),
        ],
        scratch_shapes=[pltpu.VMEM((N, LANE_TILE), acc_dtype)],
        interpret=interpret,
    )(y, signs_arr)
    return sp, pm
