"""Pallas TPU kernel for the PBVD forward ACS phase (paper kernel K1).

TPU mapping (see DESIGN.md §2):

* parallel blocks live on the **lane axis** (tiles of ``LANE_TILE = 128``);
  the trellis states live on sublanes — ``PM`` is a ``(N, 128)`` VMEM-resident
  matrix per program instance (for the CCSDS code: 64×128×4 B = 32 KiB).
* the stage loop is tiled by the second grid dimension; ``PM`` persists in a
  VMEM scratch across stage-chunks (grid iterates stage-chunks innermost) and
  is re-zeroed at chunk 0 — this is the TPU analogue of the GPU kernel
  keeping PM in shared memory for the whole block.
* **symmetry-folded branch metrics**: the correlation metric is antipodal in
  the label (BM(~c) = -BM(c)), so only ``2^(R-1)`` folded metrics exist per
  stage — half the paper's ``2^R`` group metrics. The folded rows are built
  with static add/sub chains (the ±1 signs are trace-time constants — zero
  multiplies), and the four per-butterfly metric rows (α/γ/β/θ) are expanded
  with **static sign selects**: each butterfly's row is ``±`` one of the
  folded entries, negated in-register. No gathers, no warp shuffles.
* the butterfly read ``PM[2j], PM[2j+1]`` is a free sublane reshape
  ``(N, T) → (N/2, 2, T)``; the write-back is a concat of the top/bottom
  halves. No shared-memory banking concerns exist on TPU.
* survivor decisions are bit-packed on the fly to ``ceil(N/32)`` int32 words
  per stage (weighted sublane reduction), giving the paper's
  ``SP[T][words][blocks]`` layout with fully coalesced (lane-contiguous)
  stores — and 32× less HBM traffic than byte-per-state.

The same kernel body runs the float32 path and the exact integer path.
``metric_mode`` selects the path-metric pipeline semantics (see
``repro.kernels.registry.METRIC_MODES``): ``"f32"`` accumulates unbounded
(int32 for integer symbols), ``"i16"``/``"i8"`` add the amortized
min-subtract normalization (every ``norm_interval(code, mode)`` stages,
counted in GLOBAL stage indices so stage-chunking cannot move the
normalization points) whose saturation budget bounds every metric within
int16/int8 range. The TPU VPU computes on 32-bit lanes either way, so
the kernel keeps int32 registers — the narrow dtypes are a *storage/traffic*
contract (symbols arrive int8 over HBM; the pure-XLA ``ref`` backend stores
PM natively narrow) and the normalized values here are bit-identical to the
narrow-dtype arithmetic because they never leave the narrow range.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quantize import metric_mode_qmax, norm_interval
from repro.core.trellis import ConvCode
from .ref import _acc_dtype_for

__all__ = [
    "acs_forward_pallas",
    "folded_matrix_bm_rows",
    "matrix_step",
    "LANE_TILE",
    "DEFAULT_STAGE_CHUNK",
]

LANE_TILE = 128
DEFAULT_STAGE_CHUNK = 64


def folded_bm_rows(y_s, code: ConvCode, acc_dtype):
    """(R, TILE) stage symbols → 2·2^(R-1) rows [+folded, -folded], (1, TILE) each.

    Static add/sub chains over the fold representatives' ±1 signs (trace-time
    constants — no multiplies, no table input); the negated set is the
    in-register sign application the expansion selects from.
    """
    fsv = code.folded_codeword_signs  # (2^(R-1), R) static ±1
    pos, neg = [], []
    for k in range(code.n_folded):
        acc = None
        for r in range(code.R):
            term = y_s[r] if fsv[k, r] > 0 else -y_s[r]
            acc = term if acc is None else acc + term
        row = acc.astype(acc_dtype)[None, :]
        pos.append(row)
        neg.append(-row)
    return pos, neg


def expand_run_rows(pos, neg, idx, sgn, tile: int):
    """Expand static (index, sign) tables over ±folded rows to a metric row.

    ``pos``/``neg`` are lists of (1, TILE) folded rows and their negations;
    ``idx``/``sgn`` are STATIC int arrays (trace-time constants). The
    expansion is a run-length concat of broadcast ±folded rows — no captured
    constants, no gathers — and exactly equals the gather-based form.
    """
    runs: list[tuple[tuple[int, int], int]] = []
    for i, s in zip(idx.tolist(), sgn.tolist()):
        if runs and runs[-1][0] == (i, s):
            runs[-1] = (runs[-1][0], runs[-1][1] + 1)
        else:
            runs.append(((i, s), 1))
    parts = [
        jnp.broadcast_to(pos[k] if s > 0 else neg[k], (cnt, tile))
        for (k, s), cnt in runs
    ]
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)


def butterfly_bm_row(pos, neg, code: ConvCode, key: str, tile: int, acc_dtype):
    """Expand the folded rows to a (n_butterflies, TILE) per-butterfly row.

    ``key`` ∈ {te, to, be, bo} names the α/γ/β/θ codeword column. Each
    butterfly's metric is ± one folded entry; the (index, sign) tables are
    static, so the expansion is a static run-length concat of broadcast
    ±folded rows (no captured constants, no gathers) — cheaper than the
    4·nb·R multiply-adds of the unfolded form and exactly equal to it.
    """
    tabs = code.folded_acs_tables
    return expand_run_rows(
        pos, neg, tabs["fold_cw_" + key], tabs["fold_sgn_" + key], tile
    )


def folded_radix4_bm_rows(y0, y1, code: ConvCode, acc_dtype):
    """Stage-pair symbols → 2^(2R-1) combined folded rows [+, −], (1, TILE) each.

    The combined two-stage label stays antipodal (BM2(~cc) = −BM2(cc)), so
    one static add/sub chain per fold representative covers all 2^(2R)
    combined metrics — the PR 3 fold composed over the stage pair.
    """
    fsv = code.folded_radix4_codeword_signs  # (2^(2R-1), 2R) static ±1
    R = code.R
    pos, neg = [], []
    for k in range(code.n_folded4):
        acc = None
        for r in range(2 * R):
            y_r = y0[r] if r < R else y1[r - R]
            term = y_r if fsv[k, r] > 0 else -y_r
            acc = term if acc is None else acc + term
        row = acc.astype(acc_dtype)[None, :]
        pos.append(row)
        neg.append(-row)
    return pos, neg


def folded_matrix_bm_rows(ys, code: ConvCode, k: int, acc_dtype):
    """k stage symbol rows → 2^(kR-1) combined folded rows [+, −], (1, TILE) each.

    The k-stage combined label stays antipodal (BM_k(~cc) = −BM_k(cc)), so
    one static add/sub chain per fold representative covers all 2^(kR)
    combined metrics — the PR 3 fold composed over the k-stage window
    (radix-4's two-stage fold generalized). ``ys`` is a list of k (R, TILE)
    stage rows, stage t first.
    """
    fsv = code.folded_matrix_codeword_signs(k)  # (2^(kR-1), kR) static ±1
    R = code.R
    pos, neg = [], []
    for m in range(code.n_folded_matrix(k)):
        acc = None
        for r in range(k * R):
            y_r = ys[r // R][r % R]
            term = y_r if fsv[m, r] > 0 else -y_r
            acc = term if acc is None else acc + term
        row = acc.astype(acc_dtype)[None, :]
        pos.append(row)
        neg.append(-row)
    return pos, neg


def matrix_step(pm, ys, code: ConvCode, acc_dtype, tile: int, k: int, e=None):
    """One k-stage (min,+) matrix ACS step on (N, TILE) operands.

    Mirrors :func:`repro.kernels.ref._matrix_step` (integer accumulators
    only — the wrappers lower float to the staged butterfly): the k-stage
    transition metrics A[c, j, u] are assembled from the 2^(kR-1) folded
    combined rows, then ceil-log2(2^k) suffix-min tournament rounds reduce
    the 2^k candidates per target while emitting the k STANDARD radix-2
    survivor bit-planes (round i's decisions, laid out over the canonical
    covering c < 2^(i+1) — exact because later-round terms are common
    additive offsets under integer min).

    Two assembly modes:

    * ``e=None`` — static (index, sign) run-length expansion over the ±folded
      rows per (c, j) (the VPU form; no gathers, like the butterfly path).
    * ``e`` given — the (2^k·N, 2^(kR-1)) signed one-hot expansion operand:
      ONE dense matmul ``E @ folded`` produces every transition metric — the
      MXU-shaped form. Exact: one ±1 per row, and |BM_k| ≤ kR·qmax ≪ 2^24 is
      below f32's integer-exact range, so the f32 accumulate round-trips to
      int losslessly.

    Returns (new_pm, planes): time-(t+k) metrics plus k (N, TILE) decision
    planes, stage t first.
    """
    N = code.n_states
    U = N >> k
    nk = 1 << k
    pos, neg = folded_matrix_bm_rows(ys, code, k, acc_dtype)
    if e is not None:
        folded = jnp.concatenate(pos, axis=0).astype(jnp.float32)
        a = jnp.dot(e, folded, preferred_element_type=jnp.float32)
        a = a.astype(acc_dtype).reshape(nk, nk, U, tile)

        def bm(c, j):
            return a[c, j]

    else:
        tabs = code.matrix_acs_tables(k)

        def bm(c, j):
            return expand_run_rows(
                pos, neg, tabs["fold_idx"][c, j], tabs["fold_sgn"][c, j], tile
            )

    pmk = pm.reshape(U, nk, tile)
    levels = {c: [pmk[:, j] + bm(c, j) for j in range(nk)] for c in range(nk)}
    planes = []
    for i in range(k):
        n_c = 1 << (i + 1)
        parts, nxt = [], {}
        for c in range(nk):
            cur = levels[c]
            d = [
                (cur[2 * h + 1] < cur[2 * h]).astype(jnp.int32)
                for h in range(len(cur) // 2)
            ]
            nxt[c] = [
                jnp.minimum(cur[2 * h], cur[2 * h + 1]) for h in range(len(cur) // 2)
            ]
            if c < n_c:
                parts.append(
                    d[0]
                    if len(d) == 1
                    else jnp.stack(d, axis=1).reshape(len(d) * U, tile)
                )
        levels = nxt
        planes.append(parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0))
    new_pm = jnp.concatenate([levels[c][0] for c in range(nk)], axis=0)
    return new_pm, planes


def _pack_plane(dec, tile: int):
    """(N, TILE) {0,1} decisions → (ceil(N/32), TILE) int32 packed words."""
    pad = (-dec.shape[0]) % 32
    if pad:
        dec = jnp.concatenate([dec, jnp.zeros((pad, tile), jnp.int32)], axis=0)
    d = dec.reshape(-1, 32, tile)
    weights = (jnp.int32(1) << jnp.arange(32, dtype=jnp.int32))[None, :, None]
    return (d * weights).sum(axis=1, dtype=jnp.int32)


def radix4_stage_pair(pm, y0, y1, code: ConvCode, acc_dtype, tile: int, combine: bool = False):
    """One stage-fused radix-4 ACS step on (N, TILE) operands.

    Mirrors :func:`repro.kernels.ref._radix4_step` with the Pallas row
    idiom: the metric tables are expanded by static run-length concats of
    ±folded rows (no gathers). The default (staged) form shares the first
    tournament round between the two target groups with the same stage-t
    input bit and fixes the add order to the two-stage accumulation — the
    identical op sequence as two radix-2 stages (bit-exact even in IEEE
    float), fused into one step body: one symbol fetch, one normalization
    round and one survivor-emission round per two decoded bits.

    ``combine=True`` (integer accumulators only) adds the combined
    2^(2R-1)-folded two-stage metric once per candidate instead — exact by
    integer associativity, one fewer dependent add round at the cost of N
    extra compare/selects (the measured alternative; see DESIGN.md §10).

    Returns (new_pm, dec1, dec2): the time-(t+2) metrics plus the two
    STANDARD radix-2 survivor bit-planes of stages t and t+1.
    """
    N = code.n_states
    Q = N // 4
    tabs = code.radix4_acs_tables
    pm4 = pm.reshape(Q, 4, tile)
    if combine and jnp.issubdtype(acc_dtype, jnp.integer):
        pos2, neg2 = folded_radix4_bm_rows(y0, y1, code, acc_dtype)
        d1, l1 = {}, {}
        for k in range(4):
            cand = [
                pm4[:, j]
                + expand_run_rows(
                    pos2, neg2, tabs["fold_cc_idx"][k, j], tabs["fold_cc_sgn"][k, j], tile
                )
                for j in range(4)
            ]
            for bm_bit in (0, 1):
                even, odd = cand[2 * bm_bit], cand[2 * bm_bit + 1]
                d1[k, bm_bit] = (odd < even).astype(jnp.int32)
                l1[k, bm_bit] = jnp.minimum(even, odd)
    else:
        pos_a, neg_a = folded_bm_rows(y0, code, acc_dtype)
        pos_b, neg_b = folded_bm_rows(y1, code, acc_dtype)
        mu, d1v = {}, {}
        for x1 in range(2):
            a = [
                pm4[:, j]
                + expand_run_rows(
                    pos_a, neg_a, tabs["fold_c1_idx"][x1, j], tabs["fold_c1_sgn"][x1, j], tile
                )
                for j in range(4)
            ]
            for bm_bit in (0, 1):
                even, odd = a[2 * bm_bit], a[2 * bm_bit + 1]
                d1v[x1, bm_bit] = (odd < even).astype(jnp.int32)
                mu[x1, bm_bit] = jnp.minimum(even, odd)
        d1, l1 = {}, {}
        for k in range(4):
            for bm_bit in (0, 1):
                d1[k, bm_bit] = d1v[k & 1, bm_bit]
                l1[k, bm_bit] = mu[k & 1, bm_bit] + expand_run_rows(
                    pos_b, neg_b, tabs["fold_c2_idx"][k, bm_bit], tabs["fold_c2_sgn"][k, bm_bit], tile
                )
    outs, d2 = [], []
    for k in range(4):
        d2.append((l1[k, 1] < l1[k, 0]).astype(jnp.int32))
        outs.append(jnp.minimum(l1[k, 0], l1[k, 1]))
    new_pm = jnp.concatenate(outs, axis=0)
    # stage-t plane from groups k=0/1 (intermediates [0, N/2)/[N/2, N));
    # the interleave is a free sublane reshape, like the butterfly read
    dec1 = jnp.concatenate(
        [
            jnp.stack([d1[0, 0], d1[0, 1]], axis=1).reshape(N // 2, tile),
            jnp.stack([d1[1, 0], d1[1, 1]], axis=1).reshape(N // 2, tile),
        ],
        axis=0,
    )
    dec2 = jnp.concatenate(d2, axis=0)
    return new_pm, dec1, dec2


def radix2_stage(pm, y_s, code: ConvCode, acc_dtype, tile: int):
    """One radix-2 butterfly stage on (N, TILE) operands → (new_pm, dec).

    Symmetry-folded branch metrics: 2^(R-1) folded rows once per stage
    (static add/sub chains), then the four α/γ/β/θ rows by in-register sign
    selects; the butterfly read is a free sublane reshape (the TPU analogue
    of the GPU shared-memory shuffle).
    """
    nb = code.n_butterflies
    pos, neg = folded_bm_rows(y_s, code, acc_dtype)
    bm_te = butterfly_bm_row(pos, neg, code, "te", tile, acc_dtype)
    bm_to = butterfly_bm_row(pos, neg, code, "to", tile, acc_dtype)
    bm_be = butterfly_bm_row(pos, neg, code, "be", tile, acc_dtype)
    bm_bo = butterfly_bm_row(pos, neg, code, "bo", tile, acc_dtype)

    pairs = pm.reshape(nb, 2, tile)
    pm_even, pm_odd = pairs[:, 0], pairs[:, 1]

    m_te = pm_even + bm_te
    m_to = pm_odd + bm_to
    dec_top = (m_to < m_te).astype(jnp.int32)
    pm_top = jnp.minimum(m_te, m_to)

    m_be = pm_even + bm_be
    m_bo = pm_odd + bm_bo
    dec_bot = (m_bo < m_be).astype(jnp.int32)
    pm_bot = jnp.minimum(m_be, m_bo)

    new_pm = jnp.concatenate([pm_top, pm_bot], axis=0)  # (N, TILE)
    dec = jnp.concatenate([dec_top, dec_bot], axis=0)  # (N, TILE)
    return new_pm, dec


def _min_subtract(pm):
    return pm - jnp.min(pm, axis=0, keepdims=True)


def _acs_kernel(
    y_ref,  # (SC, R, TILE) soft symbols for this stage chunk
    sp_ref,  # (SC, W, TILE) int32 out: packed survivor words
    pm_out_ref,  # (N, TILE) out: final path metrics (last chunk's write wins)
    pm_ref,  # scratch (N, TILE) acc_dtype: path metrics, persists across chunks
    *,
    code: ConvCode,
    stage_chunk: int,
    acc_dtype,
    norm_every: int,
    radix: int,
):
    tile = pm_ref.shape[-1]
    # global stage base of this chunk — hoisted out of the stage loop
    # (program_id is only available at kernel top level)
    chunk_base = pl.program_id(1) * stage_chunk

    @pl.when(pl.program_id(1) == 0)
    def _init():
        pm_ref[...] = jnp.zeros_like(pm_ref)

    def maybe_norm(pm, step_idx):
        if not norm_every:
            return pm
        # amortized min-subtract (i16/i8 saturation contract); cadence counts
        # GLOBAL steps so chunking can't change the normalization points
        return jax.lax.cond(
            step_idx % norm_every == norm_every - 1, _min_subtract, lambda p: p, pm
        )

    if radix == 2:

        def stage_body(s, pm):
            y_s = y_ref[pl.ds(s, 1)][0].astype(acc_dtype)  # (R, TILE)
            new_pm, dec = radix2_stage(pm, y_s, code, acc_dtype, tile)
            new_pm = maybe_norm(new_pm, chunk_base + s)
            sp_ref[pl.ds(s, 1)] = _pack_plane(dec, tile)[None]
            return new_pm

        n_steps = stage_chunk
    else:
        # radix 4: two trellis stages per step; the wrapper guarantees an
        # even stage_chunk, so pairs never straddle a chunk boundary
        step_base = chunk_base // 2

        def stage_body(s, pm):
            y0 = y_ref[pl.ds(2 * s, 1)][0].astype(acc_dtype)
            y1 = y_ref[pl.ds(2 * s + 1, 1)][0].astype(acc_dtype)
            new_pm, dec1, dec2 = radix4_stage_pair(pm, y0, y1, code, acc_dtype, tile)
            new_pm = maybe_norm(new_pm, step_base + s)
            words = jnp.stack([_pack_plane(dec1, tile), _pack_plane(dec2, tile)])
            sp_ref[pl.ds(2 * s, 2)] = words  # two radix-2 bit-planes per step
            return new_pm

        n_steps = stage_chunk // 2

    pm = pm_ref[...]
    pm = jax.lax.fori_loop(0, n_steps, stage_body, pm, unroll=False)
    pm_ref[...] = pm
    pm_out_ref[...] = pm


def _acs_matrix_kernel(
    y_ref,  # (SC, R, TILE) soft symbols for this stage chunk
    e_ref,  # (2^k·N, 2^(kR-1)) f32 expansion operand (whole array, all chunks)
    sp_ref,  # (SC, W, TILE) int32 out: packed survivor words
    pm_out_ref,  # (N, TILE) out: final path metrics (last chunk's write wins)
    pm_ref,  # scratch (N, TILE) acc_dtype: path metrics, persists across chunks
    *,
    code: ConvCode,
    stage_chunk: int,
    acc_dtype,
    norm_every: int,
    k: int,
):
    """Matrix-ACS chunk body: ``stage_chunk // k`` tropical matmul steps.

    The wrapper guarantees ``stage_chunk % k == 0``, so k-stage steps never
    straddle a chunk boundary; each step emits its k standard radix-2
    survivor planes contiguously (one lane-coalesced store). The expansion
    operand E rides in as a real kernel input with a constant index map — it
    is the matmul's left operand, resident for every grid instance.
    """
    tile = pm_ref.shape[-1]
    chunk_base = pl.program_id(1) * stage_chunk
    step_base = chunk_base // k

    @pl.when(pl.program_id(1) == 0)
    def _init():
        pm_ref[...] = jnp.zeros_like(pm_ref)

    e = e_ref[...]

    def maybe_norm(pm, step_idx):
        if not norm_every:
            return pm
        # cadence counts GLOBAL k-stage steps (matching the ref scan), so
        # chunking can't move the normalization points
        return jax.lax.cond(
            step_idx % norm_every == norm_every - 1, _min_subtract, lambda p: p, pm
        )

    def step_body(s, pm):
        ys = y_ref[pl.ds(k * s, k)].astype(acc_dtype)  # (k, R, TILE)
        new_pm, planes = matrix_step(
            pm, [ys[i] for i in range(k)], code, acc_dtype, tile, k, e=e
        )
        new_pm = maybe_norm(new_pm, step_base + s)
        sp_ref[pl.ds(k * s, k)] = jnp.stack([_pack_plane(d, tile) for d in planes])
        return new_pm

    pm = pm_ref[...]
    pm = jax.lax.fori_loop(0, stage_chunk // k, step_body, pm, unroll=False)
    pm_ref[...] = pm
    pm_out_ref[...] = pm


@functools.partial(
    jax.jit,
    static_argnames=(
        "code", "stage_chunk", "interpret", "metric_mode", "radix", "impl", "k"
    ),
)
def acs_forward_pallas(
    y: jnp.ndarray,
    code: ConvCode,
    *,
    stage_chunk: int = DEFAULT_STAGE_CHUNK,
    interpret: bool = False,
    metric_mode: str = "f32",
    radix: int = 2,
    impl: str = "butterfly",
    k: int = 2,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Forward ACS over parallel blocks. y: (T, R, B) → (sp (T, W, B), pm (N, B)).

    T must be a multiple of ``stage_chunk`` and B a multiple of 128 (the ops
    wrapper pads). Float32 and integer (int8/int16/int32) inputs supported;
    integer inputs run the exact integer path. ``metric_mode`` "i16"/"i8"
    adds the amortized min-subtract normalization (int32 VPU registers; the
    values stay bit-identical to narrow-dtype arithmetic by the saturation
    budget — see ``repro.kernels.registry.METRIC_MODES``).
    ``radix=4`` runs the stage-fused two-stage ACS (stage_chunk must be
    even): half the serial chain, two radix-2 survivor bit-planes per step —
    ``sp`` is bit-identical to the radix-2 history.
    ``impl="matrix"`` runs the k-stage (min,+) tropical-matmul ACS
    (stage_chunk must be a k-multiple): the transition matrix is assembled
    as ONE dense MXU matmul against the signed one-hot expansion operand,
    and each step emits k standard radix-2 bit-planes — ``sp`` stays
    bit-identical. Float symbols lower to the staged butterfly (the flat
    k-stage contraction is not IEEE-associative; integers are exact).
    """
    T, R, B = y.shape
    if R != code.R:
        raise ValueError(f"symbol rank {R} != code R {code.R}")
    if T % stage_chunk:
        raise ValueError(f"T={T} not a multiple of stage_chunk={stage_chunk}")
    if B % LANE_TILE:
        raise ValueError(f"B={B} not a multiple of {LANE_TILE}")
    if impl not in ("butterfly", "matrix"):
        raise ValueError(f"impl must be 'butterfly' or 'matrix', got {impl!r}")
    if radix not in (2, 4):
        raise ValueError(f"radix must be 2 or 4, got {radix}")
    if impl == "matrix":
        code.validate_matrix_k(k)
    else:
        if radix == 4 and stage_chunk % 2:
            raise ValueError(f"radix-4 needs an even stage_chunk, got {stage_chunk}")
        if radix == 4 and code.n_states < 4:
            raise ValueError(f"radix-4 ACS needs K >= 3 (got K={code.K})")
    # semantic dtype check (raises for float symbols with i16/i8); registers
    # stay 32-bit wide on the VPU
    semantic = _acc_dtype_for(y.dtype, metric_mode)
    acc_dtype = jnp.float32 if semantic == jnp.float32 else jnp.int32
    if impl == "matrix" and acc_dtype == jnp.float32:
        # IEEE float + is not associative: the flat k-stage contraction would
        # drift from the staged butterfly. Lower to the butterfly radix-2
        # body — the identical op sequence, so still bit-exact to "matrix"
        # semantics (which only promise butterfly-equal decisions).
        impl, radix = "butterfly", 2
    if impl == "matrix":
        if stage_chunk % k:
            raise ValueError(
                f"matrix ACS needs stage_chunk divisible by k={k}, got {stage_chunk}"
            )
        norm_every = norm_interval(code, metric_mode, stages_per_step=k)
    else:
        norm_every = norm_interval(code, metric_mode, radix)
    y = y.astype(acc_dtype)
    if norm_every:
        # saturate out-of-budget pre-quantized symbols (see acs_forward_ref)
        qm = metric_mode_qmax(code, metric_mode)
        y = jnp.clip(y, -qm, qm)

    N = code.n_states
    W = (N + 31) // 32
    n_bt = B // LANE_TILE
    n_sc = T // stage_chunk

    if impl == "matrix":
        kernel = functools.partial(
            _acs_matrix_kernel,
            code=code,
            stage_chunk=stage_chunk,
            acc_dtype=acc_dtype,
            norm_every=norm_every,
            k=k,
        )
        # the expansion matrix is a REAL kernel operand (no captured
        # constants): whole-array block, constant index map — every grid
        # instance sees the same resident E
        e = jnp.asarray(code.matrix_expansion(k), jnp.float32)
        in_specs = [
            pl.BlockSpec((stage_chunk, R, LANE_TILE), lambda bt, sc: (sc, 0, bt)),
            pl.BlockSpec(e.shape, lambda bt, sc: (0, 0)),
        ]
        operands = (y, e)
    else:
        kernel = functools.partial(
            _acs_kernel,
            code=code,
            stage_chunk=stage_chunk,
            acc_dtype=acc_dtype,
            norm_every=norm_every,
            radix=radix,
        )
        in_specs = [
            pl.BlockSpec((stage_chunk, R, LANE_TILE), lambda bt, sc: (sc, 0, bt)),
        ]
        operands = (y,)
    sp, pm = pl.pallas_call(
        kernel,
        grid=(n_bt, n_sc),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((stage_chunk, W, LANE_TILE), lambda bt, sc: (sc, 0, bt)),
            # PM written out on every chunk; only the last chunk's value is
            # meaningful (same block for all sc → last write wins).
            pl.BlockSpec((N, LANE_TILE), lambda bt, sc: (0, bt)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, W, B), jnp.int32),
            jax.ShapeDtypeStruct((N, B), acc_dtype),
        ],
        scratch_shapes=[pltpu.VMEM((N, LANE_TILE), acc_dtype)],
        interpret=interpret,
    )(*operands)
    return sp, pm
