"""jit'd public wrappers around the PBVD Pallas kernels.

Handles the shape plumbing the kernels require (lane padding to 128, stage
padding to the stage-chunk — end-padding with zero symbols is BM-neutral and
keeps the state-0 walk stable, see tests), the traceback start-state policy,
and the paper's packed-I/O transforms.

On CPU (this container) the kernels run in interpret mode; on TPU they
compile natively. ``backend="ref"`` selects the pure-jnp oracle (which is
also the fast path on CPU and the one XLA fuses well — used by the
benchmarks).
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.trellis import ConvCode
from . import ref as _ref
from .acs import LANE_TILE, DEFAULT_STAGE_CHUNK, acs_forward_pallas
from .traceback import traceback_pallas

__all__ = ["pbvd_decode_blocks", "default_interpret"]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_axis(x: jnp.ndarray, axis: int, multiple: int) -> jnp.ndarray:
    n = x.shape[axis]
    pad = (-n) % multiple
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=(
        "code",
        "decode_start",
        "n_decode",
        "start_policy",
        "backend",
        "stage_chunk",
        "interpret",
    ),
)
def pbvd_decode_blocks(
    y_blocks: jnp.ndarray,
    code: ConvCode,
    *,
    decode_start: int,
    n_decode: int,
    start_policy: Literal["zero", "argmin"] = "zero",
    backend: Literal["pallas", "ref", "fused"] = "pallas",
    stage_chunk: int = DEFAULT_STAGE_CHUNK,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Decode framed parallel blocks.

    y_blocks: (T, R, B) soft symbols (float32, or int8/int16 for the exact
        quantized path), framed [trunc M | decode D | traceback L].
    Returns (n_decode, B) int32 decoded bits.
    """
    if interpret is None:
        interpret = default_interpret()
    T, R, B = y_blocks.shape

    if backend == "fused":
        # single-kernel path (ACS + in-VMEM traceback, bit-packed output) —
        # see kernels/fused.py; unpacked here for API compatibility.
        from repro.core.quantize import unpack_bits
        from .fused import pbvd_fused_pallas

        nd = -(-n_decode // 32) * 32  # kernel emits 32-bit words
        y = _pad_axis(y_blocks, 2, LANE_TILE)
        packed = pbvd_fused_pallas(
            y, code, decode_start=decode_start, n_decode=nd, interpret=interpret
        )
        shifts = jnp.arange(32, dtype=jnp.int32)
        bits = ((packed[:, None, :] >> shifts[None, :, None]) & 1).reshape(-1, y.shape[2])
        return bits[:n_decode, :B].astype(jnp.int32)

    if backend == "ref":
        sp, pm = _ref.acs_forward_ref(y_blocks, code)
        if start_policy == "argmin":
            start = jnp.argmin(pm, axis=0).astype(jnp.int32)
        else:
            start = jnp.zeros((B,), jnp.int32)
        return _ref.traceback_ref(sp, code, decode_start, n_decode, start)

    # ---- pallas path: pad lanes and stages --------------------------------------
    y = _pad_axis(y_blocks, 2, LANE_TILE)  # lane padding
    y = _pad_axis(y, 0, stage_chunk)  # stage padding (end; BM-neutral zeros)
    Bp = y.shape[2]

    sp, pm = acs_forward_pallas(y, code, stage_chunk=stage_chunk, interpret=interpret)
    if start_policy == "argmin":
        start = jnp.argmin(pm, axis=0).astype(jnp.int32)
    else:
        start = jnp.zeros((Bp,), jnp.int32)
    bits = traceback_pallas(
        sp,
        start,
        code,
        decode_start=decode_start,
        n_decode=n_decode,
        interpret=interpret,
    )
    return bits[:, :B]
