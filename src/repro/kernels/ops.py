"""jit'd public wrappers around the PBVD kernels, backend-dispatched.

The three decode backends (``ref`` pure-jnp oracle, ``pallas`` two-kernel
K1/K2 path, ``fused`` single-kernel ACS+traceback) register themselves here
via the :mod:`repro.kernels.registry` decorator, each receiving the common
``FramedBlocks``/``ConvCode`` contract. ``pbvd_decode_blocks`` is the
dispatcher the engine calls; it validates the backend/start-policy pair
eagerly (a ``ValueError`` before any tracing) and contains no per-backend
branches.

Each backend adapter owns its shape plumbing (lane padding to 128, stage
padding to the stage-chunk — end-padding with zero symbols is BM-neutral and
keeps the state-0 walk stable, see tests), the traceback start-state policy,
and the paper's packed-I/O transforms. The lane axis may be a flattened
frames × blocks packing (``FramedBlocks.frame_counts``); backends return
exactly ``blocks.n_real_blocks`` lanes, trimming any pad lanes themselves.

On CPU (this container) the Pallas kernels run in interpret mode; on TPU they
compile natively. ``backend="ref"`` selects the pure-jnp oracle (which is
also the fast path on CPU and the one XLA fuses well — used by the
benchmarks).
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.quantize import norm_interval
from repro.core.trellis import ConvCode
from . import ref as _ref
from .acs import LANE_TILE, DEFAULT_STAGE_CHUNK, acs_forward_pallas
from .registry import (
    ACS_IMPL,
    ACS_RADIX,
    METRIC_MODES,
    TB_MODES,
    FramedBlocks,
    available_backends,
    backend_acs_impl,
    backend_acs_radix,
    backend_metric_modes,
    backend_preferred_tb_mode,
    backend_start_policies,
    backend_tb_chunk_sensitive,
    backend_tb_modes,
    get_backend,
    knob_error,
    register_backend,
    resolve_tb_mode,
)
from .traceback import DEFAULT_TB_CHUNK, traceback_pallas, traceback_prefix_pallas

__all__ = [
    "pbvd_decode_blocks",
    "check_mesh_launch",
    "default_interpret",
    "FramedBlocks",
    "METRIC_MODES",
    "TB_MODES",
    "ACS_RADIX",
    "ACS_IMPL",
    "DEFAULT_TB_CHUNK",
    "DEFAULT_ACS_K",
    "register_backend",
    "get_backend",
    "available_backends",
    "backend_start_policies",
    "backend_metric_modes",
    "backend_tb_modes",
    "backend_tb_chunk_sensitive",
    "backend_acs_radix",
    "backend_acs_impl",
    "backend_preferred_tb_mode",
    "resolve_tb_mode",
    "knob_error",
]

# Default matrix-ACS fusion depth; also what ``acs_k`` normalizes to when
# ``acs_impl="butterfly"`` leaves it inert (cache-key hygiene, like tb_chunk
# under serial traceback).
DEFAULT_ACS_K = 2


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


#: Lane-axis dispatch strategies for a mesh-bound engine (DESIGN.md §12):
#: ``"constraint"`` places the packed lanes with a NamedSharding and lets
#: pjit partition the (collective-free) launch; ``"shard_map"`` wraps the
#: launch in a per-shard :func:`repro.sharding.smap.shard_map` call, each
#: shard decoding only its local lanes (pad-lane trimming then happens once,
#: globally, after the shards are stitched — per-shard output shapes must be
#: uniform, so the trim cannot live inside the mapped body).
SHARD_DISPATCH = ("constraint", "shard_map")


def check_mesh_launch(mesh, block_axes, backend: str, *, dispatch: str = "constraint") -> int:
    """Eagerly validate a mesh × backend decode combination; return n_shards.

    Every failure here is a clear pre-trace ``ValueError`` (or ``KeyError``
    for an unknown backend) instead of a downstream pjit/shard_map shape
    error: unknown dispatch mode, empty/duplicate ``block_axes``, axes the
    mesh does not have, and a backend name the registry does not know.
    Called by ``DecoderEngine`` at construction, so a bad mesh binding fails
    when the engine is built — never inside a batched launch mid-stream.
    """
    get_backend(backend)  # KeyError names the unknown backend
    if dispatch not in SHARD_DISPATCH:
        raise ValueError(
            f"unknown shard dispatch {dispatch!r}; supported: {SHARD_DISPATCH}"
        )
    axes = tuple(block_axes)
    if not axes:
        raise ValueError("block_axes must name at least one mesh axis")
    if len(set(axes)) != len(axes):
        raise ValueError(f"block_axes {axes} repeats a mesh axis")
    missing = [a for a in axes if a not in mesh.axis_names]
    if missing:
        raise ValueError(
            f"block_axes {missing} not in mesh axes {tuple(mesh.axis_names)}"
        )
    n_shards = 1
    for a in axes:
        n_shards *= int(mesh.shape[a])
    if n_shards < 1:
        raise ValueError(f"mesh shards the lane axis {n_shards} ways: empty mesh?")
    return n_shards


def _pad_axis(x: jnp.ndarray, axis: int, multiple: int) -> jnp.ndarray:
    n = x.shape[axis]
    pad = (-n) % multiple
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------
@register_backend(
    "ref",
    metric_modes=("f32", "i16", "i8"),
    tb_modes=("serial", "prefix"),
    tb_chunk_sensitive=False,  # full-depth associative scan — no chunks
    preferred_tb_mode="serial",  # BENCH_pr.json: prefix 0.14-0.39× serial here
    acs_radix=(2, 4),
    acs_impl=("butterfly", "matrix"),
)
def _decode_ref(
    blocks: FramedBlocks,
    code: ConvCode,
    *,
    start_policy: str = "zero",
    stage_chunk: int = DEFAULT_STAGE_CHUNK,
    interpret: bool = False,
    metric_mode: str = "f32",
    tb_mode: str = "serial",
    tb_chunk: int = DEFAULT_TB_CHUNK,
    acs_radix: int = 2,
    acs_impl: str = "butterfly",
    acs_k: int = DEFAULT_ACS_K,
) -> jnp.ndarray:
    """Pure-jnp oracle path (also the XLA-fused fast path on CPU).

    ``tb_mode="prefix"`` uses the ``lax.associative_scan`` state-map
    composition (log-depth, exact); ``tb_chunk`` is a kernel-layout knob and
    is ignored here — the scan composes at full depth either way, and the
    decoded bits are identical for every chunking.
    """
    B = blocks.y.shape[2]
    sp, pm = _ref.acs_forward_ref(
        blocks.y, code, metric_mode=metric_mode, radix=acs_radix,
        impl=acs_impl, matrix_k=acs_k,
    )
    if start_policy == "argmin":
        start = jnp.argmin(pm, axis=0).astype(jnp.int32)
    else:
        start = jnp.zeros((B,), jnp.int32)
    tb = _ref.traceback_prefix_ref if tb_mode == "prefix" else _ref.traceback_ref
    bits = tb(sp, code, blocks.decode_start, blocks.n_decode, start)
    return bits[:, : blocks.n_real_blocks]


@register_backend(
    "pallas",
    metric_modes=("f32", "i16", "i8"),
    tb_modes=("serial", "prefix"),
    # measured-fastest on the committed bench (BENCH_pr.json, acs_radix_sweep
    # / traceback_sweep): the interpret lowering pays ~4× for the prefix
    # composition phases. Flip to "prefix" once a real-TPU bench lands —
    # the declaration IS the auto-resolution, one line per backend.
    preferred_tb_mode="serial",
    acs_radix=(2, 4),
    acs_impl=("butterfly", "matrix"),
)
def _decode_pallas(
    blocks: FramedBlocks,
    code: ConvCode,
    *,
    start_policy: str = "zero",
    stage_chunk: int = DEFAULT_STAGE_CHUNK,
    interpret: bool = False,
    metric_mode: str = "f32",
    tb_mode: str = "serial",
    tb_chunk: int = DEFAULT_TB_CHUNK,
    acs_radix: int = 2,
    acs_impl: str = "butterfly",
    acs_k: int = DEFAULT_ACS_K,
) -> jnp.ndarray:
    """Two-kernel path (paper K1 ACS + K2 traceback, serial or prefix)."""
    T = blocks.y.shape[0]
    if acs_impl == "matrix":
        # the matrix kernel consumes whole k-stage steps per chunk: round
        # the chunk down to a k-multiple (64 → 63 for k=3); stage padding
        # below then keeps T a chunk multiple as before
        stage_chunk = max(acs_k, stage_chunk - stage_chunk % acs_k)
    y = _pad_axis(blocks.y, 2, LANE_TILE)  # lane padding
    y = _pad_axis(y, 0, stage_chunk)  # stage padding (end; BM-neutral zeros)
    Bp = y.shape[2]

    sp, pm = acs_forward_pallas(
        y,
        code,
        stage_chunk=stage_chunk,
        interpret=interpret,
        metric_mode=metric_mode,
        radix=acs_radix,
        impl=acs_impl,
        k=acs_k,
    )
    if start_policy == "argmin":
        # argmin over the padded-final metrics: the zero-BM pad stages only
        # min-merge paths, so the padded walk recovers a true argmin state at
        # stage T and the full padded survivor history must be walked.
        start = jnp.argmin(pm, axis=0).astype(jnp.int32)
    else:
        # state-0 start is defined at the true final stage T: walking the
        # zero-symbol pad stages from state 0 would land on an arbitrary
        # state at T, so drop the pad-stage survivors before the traceback.
        sp = sp[:T]
        start = jnp.zeros((Bp,), jnp.int32)
    if tb_mode == "prefix":
        bits = traceback_prefix_pallas(
            sp,
            start,
            code,
            decode_start=blocks.decode_start,
            n_decode=blocks.n_decode,
            tb_chunk=tb_chunk,
            interpret=interpret,
        )
    else:
        bits = traceback_pallas(
            sp,
            start,
            code,
            decode_start=blocks.decode_start,
            n_decode=blocks.n_decode,
            interpret=interpret,
        )
    return bits[:, : blocks.n_real_blocks]


@register_backend(
    "fused",
    start_policies=("zero",),
    metric_modes=("f32", "i16", "i8"),
    tb_modes=("serial", "prefix"),
    preferred_tb_mode="serial",  # measured-fastest on the committed bench
    # (see the pallas registration note; same TPU re-measure applies here)
    acs_radix=(2, 4),
    acs_impl=("butterfly", "matrix"),
)
def _decode_fused(
    blocks: FramedBlocks,
    code: ConvCode,
    *,
    start_policy: str = "zero",
    stage_chunk: int = DEFAULT_STAGE_CHUNK,
    interpret: bool = False,
    metric_mode: str = "f32",
    tb_mode: str = "serial",
    tb_chunk: int = DEFAULT_TB_CHUNK,
    acs_radix: int = 2,
    acs_impl: str = "butterfly",
    acs_k: int = DEFAULT_ACS_K,
) -> jnp.ndarray:
    """Single-kernel path (ACS + in-VMEM traceback, bit-packed output) —
    see kernels/fused.py; unpacked here for API compatibility."""
    from .fused import pbvd_fused_pallas

    if start_policy != "zero":
        # direct backend callers bypass the dispatcher's eager check; fail
        # loudly rather than silently decoding from state 0
        raise ValueError(
            "fused backend tracebacks from state 0 (start_policies=('zero',))"
        )
    nd = -(-blocks.n_decode // 32) * 32  # kernel emits 32-bit words
    y = _pad_axis(blocks.y, 2, LANE_TILE)
    packed = pbvd_fused_pallas(
        y,
        code,
        decode_start=blocks.decode_start,
        n_decode=nd,
        interpret=interpret,
        metric_mode=metric_mode,
        tb_mode=tb_mode,
        tb_chunk=tb_chunk,
        acs_radix=acs_radix,
        acs_impl=acs_impl,
        acs_k=acs_k,
    )
    # unpack only what is kept: trim pad lanes BEFORE the 32× shift-expand
    # and expand the ragged last word to just its live rows, so the
    # intermediate is (n_decode, n_real) instead of (n_words·32, B_padded)
    packed = packed[:, : blocks.n_real_blocks]
    n_full = blocks.n_decode // 32
    rem = blocks.n_decode - n_full * 32
    shifts = jnp.arange(32, dtype=jnp.int32)
    parts = []
    if n_full:
        full = (packed[:n_full, None, :] >> shifts[None, :, None]) & 1
        parts.append(full.reshape(n_full * 32, -1))
    if rem:
        tail = (packed[n_full, None, :] >> shifts[:rem, None]) & 1
        parts.append(tail)
    bits = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)
    return bits.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Dispatcher
# ---------------------------------------------------------------------------
@functools.partial(
    jax.jit,
    static_argnames=(
        "code",
        "decode_start",
        "n_decode",
        "start_policy",
        "backend",
        "stage_chunk",
        "interpret",
        "n_real",
        "metric_mode",
        "tb_mode",
        "tb_chunk",
        "acs_radix",
        "acs_impl",
        "acs_k",
    ),
)
def _decode_blocks_jit(
    y_blocks: jnp.ndarray,
    code: ConvCode,
    *,
    decode_start: int,
    n_decode: int,
    start_policy: str,
    backend: str,
    stage_chunk: int,
    interpret: bool,
    n_real: int | None,
    metric_mode: str,
    tb_mode: str,
    tb_chunk: int,
    acs_radix: int,
    acs_impl: str,
    acs_k: int,
) -> jnp.ndarray:
    fn = get_backend(backend)
    return fn(
        FramedBlocks(
            y_blocks,
            decode_start,
            n_decode,
            (n_real,) if n_real is not None else None,
        ),
        code,
        start_policy=start_policy,
        stage_chunk=stage_chunk,
        interpret=interpret,
        metric_mode=metric_mode,
        tb_mode=tb_mode,
        tb_chunk=tb_chunk,
        acs_radix=acs_radix,
        acs_impl=acs_impl,
        acs_k=acs_k,
    )


def pbvd_decode_blocks(
    y_blocks: jnp.ndarray,
    code: ConvCode,
    *,
    decode_start: int,
    n_decode: int,
    start_policy: Literal["zero", "argmin"] = "zero",
    backend: str = "pallas",
    stage_chunk: int = DEFAULT_STAGE_CHUNK,
    interpret: bool | None = None,
    frame_counts: tuple[int, ...] | None = None,
    metric_mode: Literal["f32", "i16", "i8"] = "f32",
    tb_mode: Literal["serial", "prefix", "auto"] = "serial",
    tb_chunk: int = DEFAULT_TB_CHUNK,
    acs_radix: int = 2,
    acs_impl: Literal["butterfly", "matrix"] = "butterfly",
    acs_k: int = DEFAULT_ACS_K,
) -> jnp.ndarray:
    """Decode framed parallel blocks via the named backend.

    y_blocks: (T, R, B) soft symbols (float32, or int8/int16 for the exact
        quantized path), framed [trunc M | decode D | traceback L]. The lane
        axis may pack several frames (``frame_counts``, see
        :class:`FramedBlocks`); trailing lanes beyond the real blocks are
        padding.
    ``metric_mode`` selects the path-metric pipeline (:data:`METRIC_MODES`):
        "f32" accumulates unbounded; "i16"/"i8" run the narrow normalized
        pipeline and require pre-quantized integer symbols within the
        saturation budget (the engine quantizes accordingly).
    ``tb_mode`` selects the traceback algorithm (:data:`TB_MODES`): "serial"
        is the paper's stage walk, "prefix" the chunked parallel-prefix
        survivor-map composition (bit-exact; ``tb_chunk`` sizes the chunks
        and is ignored by "serial"), and "auto" resolves — eagerly, before
        the cache key — to the backend's declared measured-fastest mode.
    ``acs_radix`` selects the forward-ACS step (:data:`ACS_RADIX`): 2 is the
        paper's butterfly, 4 the stage-fused two-stage step (bit-exact; odd
        T runs one trailing radix-2 step).
    ``acs_impl`` selects the forward-pass formulation (:data:`ACS_IMPL`):
        "butterfly" is the compare-select trellis at ``acs_radix``,
        "matrix" the k-stage (min,+) tropical-matmul path with fusion depth
        ``acs_k`` (bit-exact; T mod k trailing stages run radix-2). Each
        impl's inert knob (``acs_k`` under butterfly, ``acs_radix`` under
        matrix) is normalized out of the jit cache key.
    Returns (n_decode, n_real_blocks) int32 decoded bits.

    Backend, start-policy, metric-mode, tb-mode, acs-radix and acs-impl are
    validated *before* jit: an unknown backend raises ``KeyError``; an
    unsupported start policy, metric mode, tb mode, radix or impl —
    including a narrow metric mode whose saturation budget cannot absorb
    the radix-4 double-stage (or matrix k-stage) accumulation for this
    code, and an ``acs_k`` outside the structural bounds — raises
    ``ValueError`` eagerly via :func:`repro.kernels.registry.knob_error`'s
    uniform shape (never a trace-time error from inside the kernel
    adapter).

    Only the TOTAL real-lane count enters the jit cache key: lanes are
    mutually independent and per-frame unpacking happens host-side, so the
    per-frame split is collapsed to ``sum(frame_counts)`` at this boundary —
    a pool whose sessions contribute varying block counts reuses one
    compiled launch per padded shape instead of retracing per composition.
    """
    if interpret is None:
        interpret = default_interpret()
    supported = backend_start_policies(backend)  # KeyError for unknown backend
    if start_policy not in supported:
        raise knob_error(backend, "start_policy", start_policy, supported)
    supported_modes = backend_metric_modes(backend)
    if metric_mode not in supported_modes:
        raise knob_error(backend, "metric_mode", metric_mode, supported_modes)
    tb_mode = resolve_tb_mode(backend, tb_mode)  # "auto" → declared fastest
    supported_tb = backend_tb_modes(backend)
    if tb_mode not in supported_tb:
        raise knob_error(backend, "tb_mode", tb_mode, supported_tb)
    if tb_chunk < 1:
        raise ValueError(f"tb_chunk must be >= 1, got {tb_chunk}")
    supported_impl = backend_acs_impl(backend)
    if acs_impl not in supported_impl:
        raise knob_error(backend, "acs_impl", acs_impl, supported_impl)
    if acs_impl == "matrix":
        # structural bounds on the fusion depth, then the narrow-mode budget
        # for k unnormalized stages per step — both eager, pre-jit
        code.validate_matrix_k(acs_k)
        norm_interval(code, metric_mode, stages_per_step=acs_k)
        # the butterfly radix is inert under the matrix impl: normalize it
        # out of the jit cache key (and skip its K>=3 check — a K=2 code
        # can run matrix k=1 regardless of the radix default)
        acs_radix = 2
    else:
        supported_radix = backend_acs_radix(backend)
        if acs_radix not in supported_radix:
            raise knob_error(backend, "acs_radix", acs_radix, supported_radix)
        if acs_radix == 4 and code.n_states < 4:
            raise ValueError(f"acs_radix=4 needs K >= 3 (got K={code.K})")
        # narrow modes: the re-derived normalization cadence must exist at
        # this radix — norm_interval raises a clear ValueError here, pre-jit,
        # when the budget cannot absorb the fused double-stage accumulation
        norm_interval(code, metric_mode, acs_radix)
        acs_k = DEFAULT_ACS_K  # inert under butterfly: one cache key
    if tb_mode == "serial" or not backend_tb_chunk_sensitive(backend):
        # the launch ignores tb_chunk (serial walk, or a chunk-free prefix
        # implementation): normalize it out of the jit cache key so callers
        # sweeping tb_chunk don't recompile identical launches
        tb_chunk = DEFAULT_TB_CHUNK
    return _decode_blocks_jit(
        y_blocks,
        code,
        decode_start=decode_start,
        n_decode=n_decode,
        start_policy=start_policy,
        backend=backend,
        stage_chunk=stage_chunk,
        interpret=interpret,
        n_real=sum(frame_counts) if frame_counts is not None else None,
        metric_mode=metric_mode,
        tb_mode=tb_mode,
        tb_chunk=tb_chunk,
        acs_radix=acs_radix,
        acs_impl=acs_impl,
        acs_k=acs_k,
    )
