"""jit'd public wrappers around the PBVD kernels, backend-dispatched.

The three decode backends (``ref`` pure-jnp oracle, ``pallas`` two-kernel
K1/K2 path, ``fused`` single-kernel ACS+traceback) register themselves here
via the :mod:`repro.kernels.registry` decorator, each receiving the common
``FramedBlocks``/``ConvCode`` contract. ``pbvd_decode_blocks`` is the jit'd
dispatcher the engine calls; it contains no per-backend branches.

Each backend adapter owns its shape plumbing (lane padding to 128, stage
padding to the stage-chunk — end-padding with zero symbols is BM-neutral and
keeps the state-0 walk stable, see tests), the traceback start-state policy,
and the paper's packed-I/O transforms.

On CPU (this container) the Pallas kernels run in interpret mode; on TPU they
compile natively. ``backend="ref"`` selects the pure-jnp oracle (which is
also the fast path on CPU and the one XLA fuses well — used by the
benchmarks).
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.trellis import ConvCode
from . import ref as _ref
from .acs import LANE_TILE, DEFAULT_STAGE_CHUNK, acs_forward_pallas
from .registry import FramedBlocks, available_backends, get_backend, register_backend
from .traceback import traceback_pallas

__all__ = [
    "pbvd_decode_blocks",
    "default_interpret",
    "FramedBlocks",
    "register_backend",
    "get_backend",
    "available_backends",
]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_axis(x: jnp.ndarray, axis: int, multiple: int) -> jnp.ndarray:
    n = x.shape[axis]
    pad = (-n) % multiple
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------
@register_backend("ref")
def _decode_ref(
    blocks: FramedBlocks,
    code: ConvCode,
    *,
    start_policy: str = "zero",
    stage_chunk: int = DEFAULT_STAGE_CHUNK,
    interpret: bool = False,
) -> jnp.ndarray:
    """Pure-jnp oracle path (also the XLA-fused fast path on CPU)."""
    B = blocks.y.shape[2]
    sp, pm = _ref.acs_forward_ref(blocks.y, code)
    if start_policy == "argmin":
        start = jnp.argmin(pm, axis=0).astype(jnp.int32)
    else:
        start = jnp.zeros((B,), jnp.int32)
    return _ref.traceback_ref(sp, code, blocks.decode_start, blocks.n_decode, start)


@register_backend("pallas")
def _decode_pallas(
    blocks: FramedBlocks,
    code: ConvCode,
    *,
    start_policy: str = "zero",
    stage_chunk: int = DEFAULT_STAGE_CHUNK,
    interpret: bool = False,
) -> jnp.ndarray:
    """Two-kernel path (paper K1 ACS + K2 traceback)."""
    T, _, B = blocks.y.shape
    y = _pad_axis(blocks.y, 2, LANE_TILE)  # lane padding
    y = _pad_axis(y, 0, stage_chunk)  # stage padding (end; BM-neutral zeros)
    Bp = y.shape[2]

    sp, pm = acs_forward_pallas(y, code, stage_chunk=stage_chunk, interpret=interpret)
    if start_policy == "argmin":
        # argmin over the padded-final metrics: the zero-BM pad stages only
        # min-merge paths, so the padded walk recovers a true argmin state at
        # stage T and the full padded survivor history must be walked.
        start = jnp.argmin(pm, axis=0).astype(jnp.int32)
    else:
        # state-0 start is defined at the true final stage T: walking the
        # zero-symbol pad stages from state 0 would land on an arbitrary
        # state at T, so drop the pad-stage survivors before the traceback.
        sp = sp[:T]
        start = jnp.zeros((Bp,), jnp.int32)
    bits = traceback_pallas(
        sp,
        start,
        code,
        decode_start=blocks.decode_start,
        n_decode=blocks.n_decode,
        interpret=interpret,
    )
    return bits[:, :B]


@register_backend("fused")
def _decode_fused(
    blocks: FramedBlocks,
    code: ConvCode,
    *,
    start_policy: str = "zero",
    stage_chunk: int = DEFAULT_STAGE_CHUNK,
    interpret: bool = False,
) -> jnp.ndarray:
    """Single-kernel path (ACS + in-VMEM traceback, bit-packed output) —
    see kernels/fused.py; unpacked here for API compatibility."""
    from .fused import pbvd_fused_pallas

    if start_policy != "zero":
        raise NotImplementedError(
            "fused backend tracebacks from state 0; use start_policy='zero'"
        )
    B = blocks.y.shape[2]
    nd = -(-blocks.n_decode // 32) * 32  # kernel emits 32-bit words
    y = _pad_axis(blocks.y, 2, LANE_TILE)
    packed = pbvd_fused_pallas(
        y, code, decode_start=blocks.decode_start, n_decode=nd, interpret=interpret
    )
    shifts = jnp.arange(32, dtype=jnp.int32)
    bits = ((packed[:, None, :] >> shifts[None, :, None]) & 1).reshape(-1, y.shape[2])
    return bits[: blocks.n_decode, :B].astype(jnp.int32)


# ---------------------------------------------------------------------------
# Dispatcher
# ---------------------------------------------------------------------------
@functools.partial(
    jax.jit,
    static_argnames=(
        "code",
        "decode_start",
        "n_decode",
        "start_policy",
        "backend",
        "stage_chunk",
        "interpret",
    ),
)
def pbvd_decode_blocks(
    y_blocks: jnp.ndarray,
    code: ConvCode,
    *,
    decode_start: int,
    n_decode: int,
    start_policy: Literal["zero", "argmin"] = "zero",
    backend: str = "pallas",
    stage_chunk: int = DEFAULT_STAGE_CHUNK,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Decode framed parallel blocks via the named backend.

    y_blocks: (T, R, B) soft symbols (float32, or int8/int16 for the exact
        quantized path), framed [trunc M | decode D | traceback L].
    Returns (n_decode, B) int32 decoded bits.
    """
    if interpret is None:
        interpret = default_interpret()
    fn = get_backend(backend)
    return fn(
        FramedBlocks(y_blocks, decode_start, n_decode),
        code,
        start_policy=start_policy,
        stage_chunk=stage_chunk,
        interpret=interpret,
    )
