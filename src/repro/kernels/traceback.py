"""Pallas TPU kernels for the PBVD traceback/decode phase (paper kernel K2).

Two kernels share this module (selected by the ``tb_mode`` backend knob,
see DESIGN.md §9):

**Serial** (``tb_mode="serial"``, the paper's K2): the traceback is
embarrassingly parallel in blocks but strictly serial in stages. On the GPU
the paper assigns one *thread* per block; on TPU we assign one *lane* per
block: the walked state is a ``(1, 128)`` int32 vector, the stage loop is a
``fori_loop`` of ``T - decode_start`` steps (stages below ``decode_start``
emit nothing and are never walked), and each step does

  * a W-way select to fetch the survivor word of the current state
    (W = ceil(N/32) = 2 for the CCSDS code — cheaper than any gather),
  * a per-lane variable bit-shift to extract the decision bit,
  * the state walk ``state' = 2·(state mod N/2) + bit``,
  * emits the decoded bit (the state's MSB) for stages inside the decode
    region.

**Parallel-prefix** (``tb_mode="prefix"``): the serial chain is broken with
chunked survivor-map composition. Each stage's packed survivor words define
a predecessor map ``f_s: state → prev_state`` over the N states; maps
compose associatively, so for chunks of ``C = tb_chunk`` stages the kernel

  1. composes each chunk's C maps into one N-entry chunk map, vectorized
     over **chunks × states on the sublane axis** (the data-dependent
     "gather" ``h ← f_s[h]`` is the same W-way word select + variable shift
     as the serial walk, just on (n_chunks, N, 128) operands — no gathers);
  2. walks the ceil(T/C) composed maps serially from the start state (a
     one-hot sublane reduction per step) to recover every chunk's entry
     state — the ONLY remaining serial chain, T/C steps instead of T;
  3. re-expands all chunks' decoded bits in parallel given their entry
     states (C steps on (n_chunks, 128) operands).

Chunks wholly below ``decode_start`` are never composed, walked or
expanded; chunks above the decode region (the traceback-only tail) are
composed and walked but not expanded. T is padded *below* stage 0 to a
chunk multiple — the walk never depends on stages below the emitted region,
so zero pad words are inert.

Decoded bits are written stage-major ``(T, TILE)`` (serial) or chunk-major
``(nc, C, TILE)`` (prefix; reshaped/sliced by the wrapper) and bit-packed
by the ops wrapper (the paper's U₂ = 1/8 D2H compression).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.trellis import ConvCode
from .acs import LANE_TILE

__all__ = [
    "traceback_pallas",
    "traceback_prefix_pallas",
    "DEFAULT_TB_CHUNK",
    "prefix_chunk_geometry",
]

DEFAULT_TB_CHUNK = 64


def _traceback_kernel(
    sp_ref,  # (T, W, TILE) int32 packed survivor words
    start_ref,  # (1, TILE) int32 traceback start state per block
    bits_ref,  # (D, TILE) int32 out: decoded bits, forward order
    *,
    code: ConvCode,
    n_stages: int,
    decode_start: int,
    n_decode: int,
):
    W = sp_ref.shape[1]
    tile = sp_ref.shape[-1]
    v = code.v
    half = code.n_states // 2

    def step(i, state):
        s = n_stages - 1 - i  # walk stages T-1 .. decode_start
        sp_t = sp_ref[pl.ds(s, 1)][0]  # (W, TILE)
        word_idx = state >> 5
        word = sp_t[0][None, :] if W == 1 else jnp.zeros((1, tile), jnp.int32)
        if W > 1:
            for wi in range(W):
                word = jnp.where(word_idx == wi, sp_t[wi][None, :], word)
        bit = (word >> (state & 31)) & 1
        out_bit = state >> (v - 1)  # MSB = input bit of transition s

        # store decoded bit if s < decode_start + n_decode (the early-exit
        # loop bound already guarantees s >= decode_start)
        in_region = s < decode_start + n_decode
        offset = jnp.clip(s - decode_start, 0, n_decode - 1)

        @pl.when(in_region)
        def _emit():
            bits_ref[pl.ds(offset, 1)] = out_bit.astype(jnp.int32)

        return 2 * (state % half) + bit

    state0 = start_ref[...]  # (1, TILE)
    # stages below decode_start emit nothing and feed nothing: stop the walk
    # at decode_start (saves the M truncation stages, ~8% at Table III)
    jax.lax.fori_loop(0, n_stages - decode_start, step, state0, unroll=False)


@functools.partial(
    jax.jit, static_argnames=("code", "decode_start", "n_decode", "interpret")
)
def traceback_pallas(
    sp: jnp.ndarray,
    start_state: jnp.ndarray,
    code: ConvCode,
    *,
    decode_start: int,
    n_decode: int,
    interpret: bool = False,
) -> jnp.ndarray:
    """Serial traceback/decode. sp: (T, W, B); start_state: (B,) → bits (D, B)."""
    T, W, B = sp.shape
    if B % LANE_TILE:
        raise ValueError(f"B={B} not a multiple of {LANE_TILE}")
    n_bt = B // LANE_TILE
    kernel = functools.partial(
        _traceback_kernel,
        code=code,
        n_stages=T,
        decode_start=decode_start,
        n_decode=n_decode,
    )
    bits = pl.pallas_call(
        kernel,
        grid=(n_bt,),
        in_specs=[
            pl.BlockSpec((T, W, LANE_TILE), lambda bt: (0, 0, bt)),
            pl.BlockSpec((1, LANE_TILE), lambda bt: (0, bt)),
        ],
        out_specs=pl.BlockSpec((n_decode, LANE_TILE), lambda bt: (0, bt)),
        out_shape=jax.ShapeDtypeStruct((n_decode, B), jnp.int32),
        interpret=interpret,
    )(sp, start_state.reshape(1, B).astype(jnp.int32))
    return bits


# ---------------------------------------------------------------------------
# Parallel-prefix traceback
# ---------------------------------------------------------------------------
def prefix_chunk_geometry(T: int, decode_start: int, n_decode: int, tb_chunk: int):
    """Static chunk geometry of the prefix traceback.

    Returns ``(C, P, n_chunks, c_lo, c_hi)``: the clamped chunk size, the
    below-stage-0 padding that makes ``T + P`` a chunk multiple, the total
    chunk count, and the first/last chunk index touching the decode region
    (after padding). Chunks ``< c_lo`` are skipped entirely; chunks
    ``> c_hi`` are composed/walked but never expanded.
    """
    if tb_chunk < 1:
        raise ValueError(f"tb_chunk must be >= 1, got {tb_chunk}")
    if not 0 <= decode_start <= T - n_decode:
        raise ValueError(
            f"decode region [{decode_start}, {decode_start + n_decode}) "
            f"outside [0, {T})"
        )
    C = min(tb_chunk, T)
    P = (-T) % C
    n_chunks = (T + P) // C
    ds = decode_start + P
    c_lo = ds // C
    c_hi = (ds + n_decode - 1) // C
    return C, P, n_chunks, c_lo, c_hi


def _prefix_traceback_phases(
    spr_ref,  # (n_chunks, C, W, TILE) packed survivor words (chunk-major view)
    start,  # (1, TILE) int32 start state at time T
    emit_bit,  # callback(row k, out_bit (nc_e, 1, TILE)) — write decoded bits
    maps_ref,  # VMEM scratch (n_act, N, TILE) int32 composed chunk maps
    entry_ref,  # VMEM scratch (nc_e, TILE) int32 chunk entry states
    *,
    code: ConvCode,
    C: int,
    n_chunks: int,
    c_lo: int,
    c_hi: int,
):
    """The three prefix phases, shared by the standalone and fused kernels.

    Phase A composes each active chunk's C stage maps into one N-entry map
    (vectorized over chunks × states); phase B serially walks the
    ``n_chunks - c_lo`` composed maps from ``start`` recording each
    expansion chunk's entry state; phase C re-walks the expansion chunks in
    parallel, emitting one decoded-bit row per step via ``emit_bit``.
    """
    N = code.n_states
    half = N // 2
    v = code.v
    W = spr_ref.shape[2]
    tile = spr_ref.shape[-1]
    n_act = n_chunks - c_lo
    nc_e = c_hi - c_lo + 1

    # ---- phase A: compose chunk maps, parallel across chunks × states ----
    maps_ref[...] = jax.lax.broadcasted_iota(jnp.int32, (n_act, N, tile), 1)

    def compose_body(k, _):
        row = C - 1 - k  # stages are applied top-down within each chunk
        sp_k = spr_ref[pl.ds(c_lo, n_act), pl.ds(row, 1)][:, 0]  # (n_act, W, TILE)
        h = maps_ref[...]  # (n_act, N, TILE)
        word_idx = h >> 5
        sel = jnp.broadcast_to(sp_k[:, 0][:, None, :], (n_act, N, tile))
        for wi in range(1, W):
            sel = jnp.where(word_idx == wi, sp_k[:, wi][:, None, :], sel)
        bit = (sel >> (h & 31)) & 1
        maps_ref[...] = 2 * (h % half) + bit
        return 0

    jax.lax.fori_loop(0, C, compose_body, 0, unroll=False)

    # ---- phase B: the ONLY serial chain — ceil(T/C) steps over chunk maps ----
    iota_n = jax.lax.broadcasted_iota(jnp.int32, (N, tile), 0)

    def walk_body(j, state):
        c = n_act - 1 - j  # local chunk index (0 = chunk c_lo), walked top-down

        @pl.when(c < nc_e)
        def _record():  # entry state = walk state at the top of chunk c
            entry_ref[pl.ds(c, 1)] = state

        g = maps_ref[pl.ds(c, 1)][0]  # (N, TILE)
        onehot = iota_n == state  # (N, TILE); state broadcasts from (1, TILE)
        return jnp.sum(jnp.where(onehot, g, 0), axis=0, keepdims=True)

    jax.lax.fori_loop(0, n_act, walk_body, start, unroll=False)

    # ---- phase C: re-expand decoded bits, parallel across chunks ----
    def expand_body(k, state):  # state: (nc_e, TILE)
        row = C - 1 - k
        sp_k = spr_ref[pl.ds(c_lo, nc_e), pl.ds(row, 1)][:, 0]  # (nc_e, W, TILE)
        word_idx = state >> 5
        sel = sp_k[:, 0]
        for wi in range(1, W):
            sel = jnp.where(word_idx == wi, sp_k[:, wi], sel)
        bit = (sel >> (state & 31)) & 1
        emit_bit(row, (state >> (v - 1))[:, None, :])
        return 2 * (state % half) + bit

    jax.lax.fori_loop(0, C, expand_body, entry_ref[...], unroll=False)


def _traceback_prefix_kernel(
    spr_ref,  # (n_chunks, C, W, TILE) int32 packed survivor words
    start_ref,  # (1, TILE) int32 traceback start state per block
    bits_ref,  # (nc_e, C, TILE) int32 out: decoded bits, chunk-major
    maps_ref,  # VMEM scratch (n_act, N, TILE) int32
    entry_ref,  # VMEM scratch (nc_e, TILE) int32
    *,
    code: ConvCode,
    C: int,
    n_chunks: int,
    c_lo: int,
    c_hi: int,
):
    def emit(row, out_bit):
        bits_ref[:, pl.ds(row, 1)] = out_bit

    _prefix_traceback_phases(
        spr_ref,
        start_ref[...],
        emit,
        maps_ref,
        entry_ref,
        code=code,
        C=C,
        n_chunks=n_chunks,
        c_lo=c_lo,
        c_hi=c_hi,
    )


@functools.partial(
    jax.jit,
    static_argnames=("code", "decode_start", "n_decode", "tb_chunk", "interpret"),
)
def traceback_prefix_pallas(
    sp: jnp.ndarray,
    start_state: jnp.ndarray,
    code: ConvCode,
    *,
    decode_start: int,
    n_decode: int,
    tb_chunk: int = DEFAULT_TB_CHUNK,
    interpret: bool = False,
) -> jnp.ndarray:
    """Parallel-prefix traceback. sp: (T, W, B); start: (B,) → bits (D, B).

    Bit-exact to :func:`traceback_pallas` for every chunk size (including
    non-divisors of T and ``tb_chunk >= T``); the serial dependency drops
    from T steps to ceil(T/tb_chunk). VMEM cost: the composed-map scratch is
    ``(ceil(T/C) - c_lo)·N·128·4`` bytes per lane tile — ~320 KB at Table III
    geometry with C=64 (see DESIGN.md §9 for the cost model).
    """
    T, W, B = sp.shape
    if B % LANE_TILE:
        raise ValueError(f"B={B} not a multiple of {LANE_TILE}")
    C, P, n_chunks, c_lo, c_hi = prefix_chunk_geometry(
        T, decode_start, n_decode, tb_chunk
    )
    if P:  # pad BELOW stage 0: the walk never consumes stages under the
        # emitted region, so zero pad words are inert (top stage stays T-1)
        sp = jnp.pad(sp, ((P, 0), (0, 0), (0, 0)))
    spr = sp.reshape(n_chunks, C, W, B)
    n_act = n_chunks - c_lo
    nc_e = c_hi - c_lo + 1
    N = code.n_states
    n_bt = B // LANE_TILE

    kernel = functools.partial(
        _traceback_prefix_kernel,
        code=code,
        C=C,
        n_chunks=n_chunks,
        c_lo=c_lo,
        c_hi=c_hi,
    )
    bits = pl.pallas_call(
        kernel,
        grid=(n_bt,),
        in_specs=[
            pl.BlockSpec((n_chunks, C, W, LANE_TILE), lambda bt: (0, 0, 0, bt)),
            pl.BlockSpec((1, LANE_TILE), lambda bt: (0, bt)),
        ],
        out_specs=pl.BlockSpec((nc_e, C, LANE_TILE), lambda bt: (0, 0, bt)),
        out_shape=jax.ShapeDtypeStruct((nc_e, C, B), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((n_act, N, LANE_TILE), jnp.int32),
            pltpu.VMEM((nc_e, LANE_TILE), jnp.int32),
        ],
        interpret=interpret,
    )(spr, start_state.reshape(1, B).astype(jnp.int32))
    # chunk-major (nc_e, C, B) → stage-major rows of the decode region
    ds_local = (decode_start + P) - c_lo * C
    flat = bits.reshape(nc_e * C, B)
    return jax.lax.slice_in_dim(flat, ds_local, ds_local + n_decode, axis=0)
