"""Pallas TPU kernel for the PBVD traceback/decode phase (paper kernel K2).

The traceback is inherently serial in stages but embarrassingly parallel in
blocks. On the GPU the paper assigns one *thread* per block; on TPU we assign
one *lane* per block: the walked state is a ``(1, 128)`` int32 vector, the
stage loop is a ``fori_loop``, and each step does

  * a W-way select to fetch the survivor word of the current state
    (W = ceil(N/32) = 2 for the CCSDS code — cheaper than any gather),
  * a per-lane variable bit-shift to extract the decision bit,
  * the state walk ``state' = 2·(state mod N/2) + bit``,
  * emits the decoded bit (the state's MSB) for stages inside the decode
    region.

Decoded bits are written stage-major ``(T, TILE)`` and bit-packed by the ops
wrapper (the paper's U₂ = 1/8 D2H compression).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.trellis import ConvCode
from .acs import LANE_TILE

__all__ = ["traceback_pallas"]


def _traceback_kernel(
    sp_ref,  # (T, W, TILE) int32 packed survivor words
    start_ref,  # (1, TILE) int32 traceback start state per block
    bits_ref,  # (D, TILE) int32 out: decoded bits, forward order
    *,
    code: ConvCode,
    n_stages: int,
    decode_start: int,
    n_decode: int,
):
    W = sp_ref.shape[1]
    tile = sp_ref.shape[-1]
    v = code.v
    half = code.n_states // 2

    def step(i, state):
        s = n_stages - 1 - i  # walk stages T-1 .. 0
        sp_t = sp_ref[pl.ds(s, 1)][0]  # (W, TILE)
        word_idx = state >> 5
        word = sp_t[0][None, :] if W == 1 else jnp.zeros((1, tile), jnp.int32)
        if W > 1:
            for wi in range(W):
                word = jnp.where(word_idx == wi, sp_t[wi][None, :], word)
        bit = (word >> (state & 31)) & 1
        out_bit = state >> (v - 1)  # MSB = input bit of transition s

        # store decoded bit if s ∈ [decode_start, decode_start + n_decode)
        in_region = jnp.logical_and(s >= decode_start, s < decode_start + n_decode)
        offset = jnp.clip(s - decode_start, 0, n_decode - 1)

        @pl.when(in_region)
        def _emit():
            bits_ref[pl.ds(offset, 1)] = out_bit.astype(jnp.int32)

        return 2 * (state % half) + bit

    state0 = start_ref[...]  # (1, TILE)
    jax.lax.fori_loop(0, n_stages, step, state0, unroll=False)


@functools.partial(
    jax.jit, static_argnames=("code", "decode_start", "n_decode", "interpret")
)
def traceback_pallas(
    sp: jnp.ndarray,
    start_state: jnp.ndarray,
    code: ConvCode,
    *,
    decode_start: int,
    n_decode: int,
    interpret: bool = False,
) -> jnp.ndarray:
    """Traceback/decode. sp: (T, W, B); start_state: (B,) int32 → bits (D, B)."""
    T, W, B = sp.shape
    if B % LANE_TILE:
        raise ValueError(f"B={B} not a multiple of {LANE_TILE}")
    n_bt = B // LANE_TILE
    kernel = functools.partial(
        _traceback_kernel,
        code=code,
        n_stages=T,
        decode_start=decode_start,
        n_decode=n_decode,
    )
    bits = pl.pallas_call(
        kernel,
        grid=(n_bt,),
        in_specs=[
            pl.BlockSpec((T, W, LANE_TILE), lambda bt: (0, 0, bt)),
            pl.BlockSpec((1, LANE_TILE), lambda bt: (0, bt)),
        ],
        out_specs=pl.BlockSpec((n_decode, LANE_TILE), lambda bt: (0, bt)),
        out_shape=jax.ShapeDtypeStruct((n_decode, B), jnp.int32),
        interpret=interpret,
    )(sp, start_state.reshape(1, B).astype(jnp.int32))
    return bits
