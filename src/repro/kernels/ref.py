"""Pure-jnp oracles for the PBVD kernels.

Three levels of reference, each used to validate the next:

1. ``viterbi_classic_np`` — textbook full-sequence Viterbi (numpy, per-state
   loops). Ground truth for everything.
2. ``acs_forward_ref`` / ``traceback_ref`` — vectorized jnp implementations of
   the paper's two phases (K1/K2) with the group-based BM reduction and
   bit-packed survivor words. These mirror the Pallas kernels' math exactly
   (same packing layout, same tie-breaking) and serve as their allclose
   oracles.
3. ``pbvd_decode_ref`` — the block decoder composed of (2).

Conventions (see DESIGN.md §5):
  state ``d``; transition with input x: next = (x << (v-1)) | (d >> 1)
  butterfly j: sources 2j (even), 2j+1 (odd); targets j (x=0), j+N/2 (x=1)
  BM(c) = Σ_r y_r (2 c_r - 1)  — minimized (y: received soft symbols,
  BPSK map 0 → +1). Ties select the EVEN predecessor.
  Survivor word layout: SP[stage, word, block] int32, bit (state % 32) of
  word (state // 32) = 1 iff the ODD predecessor was selected for ``state``.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantize import metric_mode_qmax, norm_interval
from repro.core.trellis import ConvCode

__all__ = [
    "viterbi_classic_np",
    "branch_metric_table",
    "folded_branch_metric_table",
    "expand_folded_bm",
    "folded_radix4_bm_table",
    "expand_folded_radix4_bm",
    "folded_matrix_bm_table",
    "expand_folded_matrix_bm",
    "acs_forward_ref",
    "traceback_ref",
    "traceback_prefix_ref",
    "stage_maps_ref",
    "pbvd_decode_ref",
]


# ---------------------------------------------------------------------------
# Level 1: textbook Viterbi (numpy, slow, ground truth)
# ---------------------------------------------------------------------------
def viterbi_classic_np(
    y: np.ndarray, code: ConvCode, init_state: int | None = 0, final_state: int | None = 0
) -> np.ndarray:
    """Full-sequence ML Viterbi. y: (T, R) soft symbols. Returns (T,) bits.

    init_state/final_state None → unknown (uniform PM / argmin pick).
    """
    T = y.shape[0]
    N = code.n_states
    INF = 1e18
    pm = np.full(N, INF)
    pm[init_state if init_state is not None else slice(None)] = 0.0
    if init_state is None:
        pm[:] = 0.0
    signs = code.codeword_signs  # (2^R, R)
    # per-state transition tables
    states = np.arange(N)
    decisions = np.zeros((T, N), dtype=np.int8)
    for t in range(T):
        bm = signs @ y[t]  # (2^R,)
        new_pm = np.full(N, INF)
        dec = np.zeros(N, dtype=np.int8)
        for j in range(N // 2):
            for tgt, cw_even, cw_odd in (
                (j, code.butterfly_codewords[j, 0], code.butterfly_codewords[j, 2]),
                (j + N // 2, code.butterfly_codewords[j, 1], code.butterfly_codewords[j, 3]),
            ):
                m_even = pm[2 * j] + bm[cw_even]
                m_odd = pm[2 * j + 1] + bm[cw_odd]
                if m_odd < m_even:
                    new_pm[tgt] = m_odd
                    dec[tgt] = 1
                else:
                    new_pm[tgt] = m_even
                    dec[tgt] = 0
        pm = new_pm
        decisions[t] = dec
    state = int(np.argmin(pm)) if final_state is None else int(final_state)
    bits = np.zeros(T, dtype=np.int64)
    for t in range(T - 1, -1, -1):
        bits[t] = state >> (code.v - 1)  # MSB = input bit of transition t
        b = decisions[t, state]
        state = 2 * (state % (N // 2)) + b
    return bits


# ---------------------------------------------------------------------------
# Level 2: vectorized jnp K1/K2 references (the Pallas oracles)
# ---------------------------------------------------------------------------
def branch_metric_table(y: jnp.ndarray, code: ConvCode) -> jnp.ndarray:
    """Full BM table for all 2^R codewords. y: (..., R) → (..., 2^R).

    This is the paper's group reduction: 2^R metrics per stage, not 2^K.
    Kept as the unfolded reference — the decode paths compute the
    symmetry-folded half table (:func:`folded_branch_metric_table`) and
    expand it with signs.
    """
    signs = jnp.asarray(code.codeword_signs)  # (2^R, R)
    return jnp.einsum("...r,cr->...c", y, signs)


def folded_branch_metric_table(y: jnp.ndarray, code: ConvCode) -> jnp.ndarray:
    """Symmetry-folded BM table. y: (..., R) → (..., 2^(R-1)).

    The correlation metric is antipodal in the label (BM(~c) = -BM(c)), so
    only the 2^(R-1) fold representatives (labels with MSB 0) need computing;
    every other label is a sign flip (:func:`expand_folded_bm`). The rows are
    built as static add/sub chains — no multiplies, and bit-exact to the full
    table's rows because IEEE negation/rounding are sign-symmetric.
    """
    rows = []
    svals = code.folded_codeword_signs  # (2^(R-1), R) static ±1
    for k in range(code.n_folded):
        acc = None
        for r in range(code.R):
            term = y[..., r] if svals[k, r] > 0 else -y[..., r]
            acc = term if acc is None else acc + term
        rows.append(acc)
    return jnp.stack(rows, axis=-1)


def expand_folded_bm(bm_folded: jnp.ndarray, code: ConvCode) -> jnp.ndarray:
    """(..., 2^(R-1)) folded table → (..., 2^R) full table via in-register signs."""
    gathered = bm_folded[..., code.fold_index]  # static gather
    neg = jnp.asarray(code.fold_sign < 0)
    return jnp.where(neg, -gathered, gathered)


def folded_radix4_bm_table(y2: jnp.ndarray, code: ConvCode) -> jnp.ndarray:
    """Combined two-stage folded BM table. y2: (..., 2R) → (..., 2^(2R-1)).

    ``y2`` is the stage pair ``[y_t; y_{t+1}]`` concatenated channel-last.
    The combined label stays antipodal (BM2(~cc) = −BM2(cc)), so only the
    2^(2R-1) fold representatives need computing — static add/sub chains
    over :attr:`ConvCode.folded_radix4_codeword_signs`, no multiplies.
    """
    rows = []
    svals = code.folded_radix4_codeword_signs  # (2^(2R-1), 2R) static ±1
    for k in range(code.n_folded4):
        acc = None
        for r in range(2 * code.R):
            term = y2[..., r] if svals[k, r] > 0 else -y2[..., r]
            acc = term if acc is None else acc + term
        rows.append(acc)
    return jnp.stack(rows, axis=-1)


def expand_folded_radix4_bm(bm2_folded: jnp.ndarray, code: ConvCode) -> jnp.ndarray:
    """(..., 2^(2R-1)) combined folded table → (..., 2^(2R)) full table."""
    gathered = bm2_folded[..., code.fold_index4]  # static gather
    neg = jnp.asarray(code.fold_sign4 < 0)
    return jnp.where(neg, -gathered, gathered)


def folded_matrix_bm_table(yk: jnp.ndarray, code: ConvCode, k: int) -> jnp.ndarray:
    """Combined k-stage folded BM table. yk: (..., kR) → (..., 2^(kR-1)).

    ``yk`` is the stage window ``[y_t; ...; y_{t+k-1}]`` concatenated
    channel-last. The combined label stays antipodal (BMk(~cc) = −BMk(cc)),
    so only the 2^(kR-1) fold representatives need computing — static
    add/sub chains over :meth:`ConvCode.folded_matrix_codeword_signs`.
    These are the distinct finite values of the k-stage (min,+) transition
    matrix, up to sign.
    """
    rows = []
    svals = code.folded_matrix_codeword_signs(k)  # (2^(kR-1), kR) static ±1
    for f in range(code.n_folded_matrix(k)):
        acc = None
        for r in range(k * code.R):
            term = yk[..., r] if svals[f, r] > 0 else -yk[..., r]
            acc = term if acc is None else acc + term
        rows.append(acc)
    return jnp.stack(rows, axis=-1)


def expand_folded_matrix_bm(bmk_folded: jnp.ndarray, code: ConvCode, k: int) -> jnp.ndarray:
    """(..., 2^(kR-1)) combined folded table → (..., 2^(kR)) full table."""
    gathered = bmk_folded[..., code.fold_index_matrix(k)]  # static gather
    neg = jnp.asarray(code.fold_sign_matrix(k) < 0)
    return jnp.where(neg, -gathered, gathered)


def _pack_decisions(dec_bits: jnp.ndarray) -> jnp.ndarray:
    """dec_bits: (N, B) {0,1} → (ceil(N/32), B) int32, bit (n%32) of word n//32."""
    n, b = dec_bits.shape
    pad = (-n) % 32
    if pad:
        dec_bits = jnp.concatenate([dec_bits, jnp.zeros((pad, b), dec_bits.dtype)], 0)
    n_words = dec_bits.shape[0] // 32
    d = dec_bits.astype(jnp.int32).reshape(n_words, 32, b)
    weights = (jnp.int32(1) << jnp.arange(32, dtype=jnp.int32))[None, :, None]
    return (d * weights).sum(axis=1, dtype=jnp.int32)


def _acc_dtype_for(y_dtype, metric_mode: str):
    """Accumulator/storage dtype of the path metrics for a metric mode."""
    integer = jnp.issubdtype(y_dtype, jnp.integer)
    if metric_mode == "f32":
        return jnp.int32 if integer else jnp.float32
    if metric_mode not in ("i16", "i8"):
        raise ValueError(f"unknown metric_mode {metric_mode!r}")
    if not integer:
        raise ValueError(
            f"metric_mode={metric_mode!r} needs pre-quantized integer symbols "
            f"(got {y_dtype}); the engine quantizes within the saturation "
            f"budget (see repro.kernels.registry.METRIC_MODES)"
        )
    return jnp.int16 if metric_mode == "i16" else jnp.int8


def _radix2_stage(pm: jnp.ndarray, bm: jnp.ndarray, code: ConvCode):
    """One radix-2 butterfly stage. pm (N, B) + bm table (2^R, B) →
    (new_pm (N, B), dec (N, B) int32 odd-predecessor decisions)."""
    nb = code.n_butterflies
    tabs = code.acs_tables
    pairs = pm.reshape(nb, 2, pm.shape[-1])
    pm_even, pm_odd = pairs[:, 0], pairs[:, 1]
    # top targets j: even pred uses α, odd pred uses γ
    m_te = pm_even + bm[jnp.asarray(tabs["cw_top_even"])]
    m_to = pm_odd + bm[jnp.asarray(tabs["cw_top_odd"])]
    dec_top = (m_to < m_te).astype(jnp.int32)
    pm_top = jnp.minimum(m_te, m_to)
    # bottom targets j+N/2: even pred uses β, odd pred uses θ
    m_be = pm_even + bm[jnp.asarray(tabs["cw_bot_even"])]
    m_bo = pm_odd + bm[jnp.asarray(tabs["cw_bot_odd"])]
    dec_bot = (m_bo < m_be).astype(jnp.int32)
    pm_bot = jnp.minimum(m_be, m_bo)
    new_pm = jnp.concatenate([pm_top, pm_bot], axis=0)
    return new_pm, jnp.concatenate([dec_top, dec_bot], axis=0)


def _interleave_sublanes(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(Q, B) pairs → (2Q, B): row 2q from ``a``, row 2q+1 from ``b``."""
    q, lanes = a.shape
    return jnp.stack([a, b], axis=1).reshape(2 * q, lanes)


def _radix4_step(
    pm: jnp.ndarray,
    y0: jnp.ndarray,
    y1: jnp.ndarray,
    code: ConvCode,
    acc_dtype,
    combine: bool = False,
):
    """One stage-fused radix-4 ACS step (two trellis stages).

    pm (N, B) at time t; y0/y1 (R, B) symbols of stages t, t+1 (already in
    ``acc_dtype``). Returns (new_pm (N, B) at time t+2, dec1, dec2) where
    dec1/dec2 are the STANDARD radix-2 survivor bit-planes of stages t and
    t+1 — the fused step emits exactly what two radix-2 steps would, so the
    traceback (serial or prefix) and the packed SP layout are untouched.

    The 4-way compare-select per target runs as a tournament whose first
    round is SHARED between the two target groups with the same stage-t
    input bit — exactly the sharing the radix-2 trellis does — so the
    default (staged) form is the identical op sequence as two radix-2
    stages, with the add order fixed to the two-stage accumulation
    (bit-exact even in IEEE float).

    ``combine=True`` (integer accumulators only) instead adds the combined
    2^(2R-1)-folded two-stage metric once per candidate — exact because
    integer addition is associative and, within a fixed intermediate, the
    stage-(t+1) term is a common offset to both compared candidates. It
    trades the shared first round for one fewer dependent add round
    (4 adds + 3 compare/select rounds vs 6 adds + 4); measured slower under
    XLA CPU SIMD (the extra N compare/selects dominate), kept as the
    selectable reference for architectures where dependency depth wins.
    """
    if not combine or not jnp.issubdtype(acc_dtype, jnp.integer):
        # staged-shared: literally the two radix-2 half-steps, fused in one
        # step body (one normalization/emission round per two stages)
        bm_a = expand_folded_bm(folded_branch_metric_table(y0.T, code), code).T
        bm_b = expand_folded_bm(folded_branch_metric_table(y1.T, code), code).T
        pm1, dec1 = _radix2_stage(pm, bm_a, code)
        new_pm, dec2 = _radix2_stage(pm1, bm_b, code)
        return new_pm, dec1, dec2
    N = code.n_states
    Q = N // 4
    tabs = code.radix4_acs_tables
    pm4 = pm.reshape(Q, 4, pm.shape[-1])
    # combined folded metric set: 2^(2R-1) distinct two-stage metrics
    y2 = jnp.concatenate([y0, y1], axis=0)  # (2R, B)
    bm2 = expand_folded_radix4_bm(folded_radix4_bm_table(y2.T, code), code).T
    d1, l1 = {}, {}
    for k in range(4):
        cand = [pm4[:, j] + bm2[jnp.asarray(tabs["cc"][k, j])] for j in range(4)]
        for bm_bit in (0, 1):
            even, odd = cand[2 * bm_bit], cand[2 * bm_bit + 1]
            d1[k, bm_bit] = (odd < even).astype(jnp.int32)
            l1[k, bm_bit] = jnp.minimum(even, odd)
    outs, d2 = [], []
    for k in range(4):
        d2.append((l1[k, 1] < l1[k, 0]).astype(jnp.int32))
        outs.append(jnp.minimum(l1[k, 0], l1[k, 1]))
    new_pm = jnp.concatenate(outs, axis=0)
    # stage-t bit-plane: groups k=0/1 cover intermediates [0, N/2)/[N/2, N)
    # (groups 2/3 would duplicate them); stage-(t+1) plane is in group order
    dec1 = jnp.concatenate(
        [_interleave_sublanes(d1[0, 0], d1[0, 1]), _interleave_sublanes(d1[1, 0], d1[1, 1])],
        axis=0,
    )
    dec2 = jnp.concatenate(d2, axis=0)
    return new_pm, dec1, dec2


def _matrix_step(pm: jnp.ndarray, ys: jnp.ndarray, code: ConvCode, acc_dtype, k: int):
    """One k-stage (min,+) matrix ACS step (integer accumulators only).

    pm (N, B) at time t; ys (k, R, B) symbols of stages t..t+k-1 (already in
    ``acc_dtype``). Returns (new_pm (N, B) at time t+k, [dec_0 .. dec_{k-1}])
    where dec_i is the STANDARD radix-2 survivor bit-plane of stage t+i —
    the collapsed step emits exactly what k radix-2 steps would, so the
    traceback (serial or prefix) and the packed SP layout are untouched.

    The forward update is the tropical matrix-vector product
    ``new_pm[n'] = min_j pm[pred(n', j)] + A[n', j]`` with A assembled from
    the 2^(kR-1) folded combined metrics (one add per candidate instead of
    k dependent adds). The min over the 2^k predecessors runs as a
    suffix-min tournament from j's LSB; round i's compare bits ARE the
    stage-(t+i) decisions of every intermediate state, read off the
    canonical covering c < 2^(i+1) (groups with equal low input bits share
    intermediates). Exactness relies on integer addition being associative
    and on later-stage label terms being a COMMON offset to both compared
    candidates within a fixed (n', high bits of j) — so each round
    reproduces the staged butterfly's comparison verbatim, strict ``<``
    tie-breaks (even predecessor wins) included. IEEE float addition is not
    associative, so float accumulators never reach here: the caller lowers
    the float matrix path to the staged radix-2 sequence instead.
    """
    if not jnp.issubdtype(acc_dtype, jnp.integer):
        raise ValueError("_matrix_step is integer-exact only; float lowers to radix-2")
    N = code.n_states
    B = pm.shape[-1]
    U = N >> k
    nk = 1 << k
    tabs = code.matrix_acs_tables(k)
    yk = ys.reshape(k * code.R, B)  # stage-major channel stack [y_t; ...]
    bmk = expand_folded_matrix_bm(folded_matrix_bm_table(yk.T, code, k), code, k).T
    pmk = pm.reshape(U, nk, B)  # pmk[u, j] = pm[pred] = pm[2^k·u + j]
    levels = {
        c: [pmk[:, j] + bmk[jnp.asarray(tabs["cc"][c, j])] for j in range(nk)]
        for c in range(nk)
    }
    planes = []
    for i in range(k):
        n_c = 1 << (i + 1)  # canonical target groups covering all intermediates
        parts, nxt = [], {}
        for c in range(nk):
            cur = levels[c]
            d = [(cur[2 * h + 1] < cur[2 * h]).astype(jnp.int32) for h in range(len(cur) // 2)]
            m = [jnp.minimum(cur[2 * h], cur[2 * h + 1]) for h in range(len(cur) // 2)]
            nxt[c] = m
            if c < n_c:
                # intermediate state at t+i+1: c·N/2^(i+1) + u·2^(k-1-i) + h
                parts.append(d[0] if len(d) == 1 else jnp.stack(d, axis=1).reshape(len(d) * U, B))
        levels = nxt
        planes.append(jnp.concatenate(parts, axis=0))
    new_pm = jnp.concatenate([levels[c][0] for c in range(nk)], axis=0)
    return new_pm, planes


@partial(
    jax.jit,
    static_argnames=("code", "metric_mode", "fold", "radix", "r4_combine", "impl", "matrix_k"),
)
def acs_forward_ref(
    y: jnp.ndarray,
    code: ConvCode,
    metric_mode: str = "f32",
    fold: bool = True,
    radix: int = 2,
    r4_combine: bool = False,
    impl: str = "butterfly",
    matrix_k: int = 2,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Forward ACS over a batch of parallel blocks (paper K1).

    y: (T, R, B) soft symbols (float32 or int-like; int inputs accumulate
       exactly — int32 for ``metric_mode="f32"``, int16/int8 with min-subtract
       normalization every ``norm_interval(code, mode, radix)`` ACS steps for
       ``"i16"``/``"i8"``, never saturating within the registry's documented
       budget).
    ``fold=True`` (the hot path) computes only the 2^(R-1) symmetry-folded
    branch metrics per stage and expands them with in-register signs;
    ``fold=False`` keeps the full 2^R table (benchmark/parity reference,
    radix 2 only).
    ``radix=4`` collapses each pair of trellis stages into one stage-fused
    4-way compare-select step (ceil(T/2) steps; odd T runs one trailing
    radix-2 step), emitting the same two radix-2 survivor bit-planes per
    step — the returned ``sp`` is bit-identical to the radix-2 history.
    ``r4_combine=True`` (integer accumulators only) selects the combined
    2^(2R-1)-folded metric formulation of the fused step (see
    :func:`_radix4_step`; exact, kept as the measured alternative).
    ``impl="matrix"`` runs the forward pass as ceil(T/matrix_k) k-stage
    (min,+) matrix steps (:func:`_matrix_step`; trailing T mod k stages run
    radix-2) — integer accumulators take the flat tropical contraction,
    float accumulators lower to the staged radix-2 sequence (IEEE float
    addition is not associative, so re-associating the per-stage sums could
    not be bit-exact; the staged form is, by construction). Either way the
    emitted survivor history is bit-identical to the butterfly path.
    Returns (sp, pm_final):
      sp: (T, ceil(N/32), B) int32 bit-packed survivor decisions
      pm_final: (N, B) final path metrics (normalized for i16/i8; under
      radix 4 or matrix the narrow-mode normalization points differ from
      radix 2 by a per-lane uniform shift only — decisions and argmin are
      invariant).
    """
    T, R, B = y.shape
    N = code.n_states
    if impl not in ("butterfly", "matrix"):
        raise ValueError(f"impl must be 'butterfly' or 'matrix', got {impl!r}")
    if radix not in (2, 4):
        raise ValueError(f"radix must be 2 or 4, got {radix}")
    if radix == 4 and not fold:
        raise ValueError("the unfolded (fold=False) reference exists only for radix 2")
    if impl == "butterfly" and radix == 4 and N < 4:
        raise ValueError(f"radix-4 ACS needs K >= 3 (got K={code.K})")

    acc_dtype = _acc_dtype_for(y.dtype, metric_mode)
    if impl == "matrix":
        code.validate_matrix_k(matrix_k)
        if not jnp.issubdtype(acc_dtype, jnp.integer):
            # float matrix path lowers to the staged radix-2 butterfly (see
            # the docstring); decisions, sp and pm are identical
            impl, radix = "butterfly", 2

    if impl == "matrix":
        norm_every = norm_interval(code, metric_mode, stages_per_step=matrix_k)
    else:
        norm_every = norm_interval(code, metric_mode, radix)  # 0 → never (f32)
    if norm_every:
        # saturate out-of-budget pre-quantized symbols on ingestion: the
        # no-saturation guarantee assumes |y| ≤ metric_mode_qmax, and symbol
        # values are tracers (uncheckable eagerly) — clipping makes the
        # contract self-enforcing (identity for engine-quantized inputs,
        # graceful degradation instead of PM wrap for everything else)
        qm = metric_mode_qmax(code, metric_mode)
        y = jnp.clip(y, -qm, qm)

    def norm_cond(pm, step_idx):
        # amortized min-subtract: decisions are invariant to the uniform
        # per-lane shift, so only the saturation budget fixes the cadence
        return jax.lax.cond(
            step_idx % norm_every == norm_every - 1,
            lambda p: p - jnp.min(p, axis=0, keepdims=True),
            lambda p: p,
            pm,
        )

    pm0 = jnp.zeros((N, B), dtype=acc_dtype)

    if impl == "matrix":
        # ---- k-stage (min,+) matrix steps + trailing radix-2 stages ----
        k = matrix_k
        W = -(-N // 32)
        Tk = T // k
        y_steps = y[: k * Tk].reshape(Tk, k, R, B)

        def stepk(pm, xs):
            y_step, r = xs
            new_pm, planes = _matrix_step(pm, y_step.astype(acc_dtype), code, acc_dtype, k)
            if norm_every:
                new_pm = norm_cond(new_pm, r)
            return new_pm, jnp.stack([_pack_decisions(d) for d in planes])

        pm_final, spk = jax.lax.scan(stepk, pm0, (y_steps, jnp.arange(Tk, dtype=jnp.int32)))
        sp = spk.reshape(k * Tk, W, B)
        for t in range(k * Tk, T):
            # trailing radix-2 stages (T mod k); narrow modes normalize here
            # unconditionally — a uniform shift, decision- and argmin-
            # invariant, that keeps the gap within the k-stage budget
            y_t = y[t].astype(acc_dtype)
            bm = expand_folded_bm(folded_branch_metric_table(y_t.T, code), code).T
            pm_final, dec = _radix2_stage(pm_final, bm, code)
            if norm_every:
                pm_final = pm_final - jnp.min(pm_final, axis=0, keepdims=True)
            sp = jnp.concatenate([sp, _pack_decisions(dec)[None]], axis=0)
        return sp, pm_final

    if radix == 2:
        signs = jnp.asarray(code.codeword_signs, dtype=acc_dtype)  # (2^R, R)

        def step(pm, xs):
            y_t, t = xs
            # y_t: (R, B) → bm table (2^R, B)
            y_t = y_t.astype(acc_dtype)
            if fold:
                # folded half table, sign-expanded — bit-exact to the full
                # table (IEEE negation is sign-symmetric); channel-last helpers
                bm = expand_folded_bm(folded_branch_metric_table(y_t.T, code), code).T
            else:
                bm = signs @ y_t
            new_pm, dec = _radix2_stage(pm, bm, code)
            if norm_every:
                new_pm = norm_cond(new_pm, t)
            return new_pm, _pack_decisions(dec)

        pm_final, sp = jax.lax.scan(step, pm0, (y, jnp.arange(T, dtype=jnp.int32)))
        return sp, pm_final

    # ---- radix 4: ceil(T/2) fused steps + optional trailing radix-2 step ----
    T2 = T // 2
    y_pairs = y[: 2 * T2].reshape(T2, 2, R, B)

    def step4(pm, xs):
        y_pair, r = xs
        y0 = y_pair[0].astype(acc_dtype)
        y1 = y_pair[1].astype(acc_dtype)
        new_pm, dec1, dec2 = _radix4_step(pm, y0, y1, code, acc_dtype, r4_combine)
        if norm_every:
            new_pm = norm_cond(new_pm, r)
        sp2 = jnp.stack([_pack_decisions(dec1), _pack_decisions(dec2)])
        return new_pm, sp2  # (2, W, B) — two stages per step

    pm_final, sp2 = jax.lax.scan(step4, pm0, (y_pairs, jnp.arange(T2, dtype=jnp.int32)))
    sp = sp2.reshape(2 * T2, -1, B)
    if T % 2:
        # trailing radix-2 step (odd T); narrow modes normalize here
        # unconditionally — a uniform shift, decision- and argmin-invariant,
        # that keeps the inter-normalization gap within the radix-4 budget
        y_last = y[T - 1].astype(acc_dtype)
        bm = expand_folded_bm(folded_branch_metric_table(y_last.T, code), code).T
        pm_final, dec = _radix2_stage(pm_final, bm, code)
        if norm_every:
            pm_final = pm_final - jnp.min(pm_final, axis=0, keepdims=True)
        sp = jnp.concatenate([sp, _pack_decisions(dec)[None]], axis=0)
    return sp, pm_final


@partial(jax.jit, static_argnames=("code", "decode_start", "n_decode"))
def traceback_ref(
    sp: jnp.ndarray,
    code: ConvCode,
    decode_start: int,
    n_decode: int,
    start_state: jnp.ndarray | int = 0,
) -> jnp.ndarray:
    """Traceback + decode (paper K2).

    sp: (T, W, B) packed survivor words from acs_forward_ref, laid out as
        [truncation M | decode D | traceback L | optional pad]. The walk
        starts at stage T (state ``start_state``) and emits bits for stages
        [decode_start, decode_start + n_decode) — with the paper's framing
        (M = L) ``decode_start = L``.
    Returns (D, B) decoded bits (int32), in forward order.
    """
    T, W, B = sp.shape
    N = code.n_states
    v = code.v
    D = n_decode

    def step(state, sp_t):
        # sp_t: (W, B). decision bit for `state` = bit (state%32) of word state//32
        word_idx = state >> 5  # (B,)
        # gather per-lane word: W is tiny (ceil(N/32)); select via comparisons
        word = jnp.zeros_like(state)
        for wi in range(W):
            word = jnp.where(word_idx == wi, sp_t[wi], word)
        bit = (word >> (state & 31)) & 1
        out_bit = state >> (v - 1)  # MSB = input bit of this transition
        prev_state = 2 * (state % (N // 2)) + bit
        return prev_state, out_bit

    state0 = jnp.broadcast_to(jnp.asarray(start_state, jnp.int32), (B,))
    # walk stages T-1 .. 0 (we only need down to decode_start, but walking to
    # 0 is harmless and keeps shapes static; earlier bits are discarded)
    _, bits_rev = jax.lax.scan(step, state0, sp[::-1])
    bits = bits_rev[::-1]  # (T, B), bits[t] = decoded input bit of stage t
    return jax.lax.dynamic_slice_in_dim(bits, decode_start, D, axis=0)


def stage_maps_ref(sp: jnp.ndarray, code: ConvCode) -> jnp.ndarray:
    """Per-stage predecessor maps from packed survivor words.

    sp: (T, W, B) → f: (T, N, B) int32 with ``f[t, n]`` the state the
    traceback walk moves to when it sits in state ``n`` after stage ``t``
    (i.e. at "time" t+1): ``f_t(n) = 2·(n mod N/2) + sp_bit_t(n)``. The
    word/bit extraction uses only STATIC indices (``n`` ranges over all
    states), so no data-dependent gather exists here — the gathers live in
    the map *composition*, which the TPU kernels replace with sublane
    selects (DESIGN.md §9).
    """
    N = code.n_states
    states = jnp.arange(N, dtype=jnp.int32)
    words = sp[:, states >> 5, :]  # (T, N, B) static gather
    bits = (words >> (states & 31)[None, :, None]) & 1
    return 2 * (states % (N // 2))[None, :, None] + bits


@partial(jax.jit, static_argnames=("code", "decode_start", "n_decode"))
def traceback_prefix_ref(
    sp: jnp.ndarray,
    code: ConvCode,
    decode_start: int,
    n_decode: int,
    start_state: jnp.ndarray | int = 0,
) -> jnp.ndarray:
    """Parallel-prefix traceback: O(log T) composition depth, zero serial walk.

    Bit-exact to :func:`traceback_ref` for any survivor history: each stage's
    predecessor map is an N-entry int vector, map composition
    ``(g ∘ f)[n] = g[f[n]]`` is associative, and ``lax.associative_scan``
    over the stage-reversed maps yields, for every prefix length i, the
    composed map ``f_{T-1-i} ∘ … ∘ f_{T-1}`` — i.e. the walk state at time
    ``T-1-i`` as a function of the start state. Applying every prefix to
    ``start_state`` recovers the full state trajectory at once; the decoded
    bit of stage t is the MSB of the state at time t+1 (exactly the serial
    walk's emit rule). This is the jnp oracle for the chunked Pallas prefix
    kernels (which trade the log-depth scan for a T/C-step walk to stay
    gather-free — see kernels/traceback.py).
    """
    T, W, B = sp.shape
    v = code.v

    f = stage_maps_ref(sp, code)  # (T, N, B)
    fr = f[::-1]  # fr[i] = f_{T-1-i}

    def compose(a, b):
        # "b after a": a is the composition of later (higher) stages
        return jnp.take_along_axis(b, a, axis=1)

    prefixes = jax.lax.associative_scan(compose, fr, axis=0)  # (T, N, B)
    start = jnp.broadcast_to(jnp.asarray(start_state, jnp.int32), (B,))
    idx = jnp.broadcast_to(start[None, None, :], (T, 1, B))
    walked = jnp.take_along_axis(prefixes, idx, axis=1)[:, 0, :]  # (T, B)
    # states at times [T, T-1, …, 1]; bits[t] = MSB(state at time t+1)
    states_desc = jnp.concatenate([start[None, :], walked[: T - 1]], axis=0)
    bits = (states_desc >> (v - 1))[::-1]  # (T, B), forward stage order
    return jax.lax.dynamic_slice_in_dim(bits, decode_start, n_decode, axis=0)


def pbvd_decode_ref(
    y_blocks: jnp.ndarray,
    code: ConvCode,
    n_decode: int,
    n_traceback: int,
    start_state: int = 0,
    metric_mode: str = "f32",
) -> jnp.ndarray:
    """Decode framed parallel blocks: y_blocks (T, R, B) → (D, B) bits."""
    sp, _ = acs_forward_ref(y_blocks, code, metric_mode=metric_mode)
    return traceback_ref(sp, code, n_traceback, n_decode, start_state)
