"""Pure-jnp oracles for the PBVD kernels.

Three levels of reference, each used to validate the next:

1. ``viterbi_classic_np`` — textbook full-sequence Viterbi (numpy, per-state
   loops). Ground truth for everything.
2. ``acs_forward_ref`` / ``traceback_ref`` — vectorized jnp implementations of
   the paper's two phases (K1/K2) with the group-based BM reduction and
   bit-packed survivor words. These mirror the Pallas kernels' math exactly
   (same packing layout, same tie-breaking) and serve as their allclose
   oracles.
3. ``pbvd_decode_ref`` — the block decoder composed of (2).

Conventions (see DESIGN.md §5):
  state ``d``; transition with input x: next = (x << (v-1)) | (d >> 1)
  butterfly j: sources 2j (even), 2j+1 (odd); targets j (x=0), j+N/2 (x=1)
  BM(c) = Σ_r y_r (2 c_r - 1)  — minimized (y: received soft symbols,
  BPSK map 0 → +1). Ties select the EVEN predecessor.
  Survivor word layout: SP[stage, word, block] int32, bit (state % 32) of
  word (state // 32) = 1 iff the ODD predecessor was selected for ``state``.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.trellis import ConvCode

__all__ = [
    "viterbi_classic_np",
    "branch_metric_table",
    "acs_forward_ref",
    "traceback_ref",
    "pbvd_decode_ref",
]


# ---------------------------------------------------------------------------
# Level 1: textbook Viterbi (numpy, slow, ground truth)
# ---------------------------------------------------------------------------
def viterbi_classic_np(
    y: np.ndarray, code: ConvCode, init_state: int | None = 0, final_state: int | None = 0
) -> np.ndarray:
    """Full-sequence ML Viterbi. y: (T, R) soft symbols. Returns (T,) bits.

    init_state/final_state None → unknown (uniform PM / argmin pick).
    """
    T = y.shape[0]
    N = code.n_states
    INF = 1e18
    pm = np.full(N, INF)
    pm[init_state if init_state is not None else slice(None)] = 0.0
    if init_state is None:
        pm[:] = 0.0
    signs = code.codeword_signs  # (2^R, R)
    # per-state transition tables
    states = np.arange(N)
    decisions = np.zeros((T, N), dtype=np.int8)
    for t in range(T):
        bm = signs @ y[t]  # (2^R,)
        new_pm = np.full(N, INF)
        dec = np.zeros(N, dtype=np.int8)
        for j in range(N // 2):
            for tgt, cw_even, cw_odd in (
                (j, code.butterfly_codewords[j, 0], code.butterfly_codewords[j, 2]),
                (j + N // 2, code.butterfly_codewords[j, 1], code.butterfly_codewords[j, 3]),
            ):
                m_even = pm[2 * j] + bm[cw_even]
                m_odd = pm[2 * j + 1] + bm[cw_odd]
                if m_odd < m_even:
                    new_pm[tgt] = m_odd
                    dec[tgt] = 1
                else:
                    new_pm[tgt] = m_even
                    dec[tgt] = 0
        pm = new_pm
        decisions[t] = dec
    state = int(np.argmin(pm)) if final_state is None else int(final_state)
    bits = np.zeros(T, dtype=np.int64)
    for t in range(T - 1, -1, -1):
        bits[t] = state >> (code.v - 1)  # MSB = input bit of transition t
        b = decisions[t, state]
        state = 2 * (state % (N // 2)) + b
    return bits


# ---------------------------------------------------------------------------
# Level 2: vectorized jnp K1/K2 references (the Pallas oracles)
# ---------------------------------------------------------------------------
def branch_metric_table(y: jnp.ndarray, code: ConvCode) -> jnp.ndarray:
    """BM table for all 2^R codewords. y: (..., R) → (..., 2^R).

    This is the paper's group reduction: 2^R metrics per stage, not 2^K.
    """
    signs = jnp.asarray(code.codeword_signs)  # (2^R, R)
    return jnp.einsum("...r,cr->...c", y, signs)


def _pack_decisions(dec_bits: jnp.ndarray) -> jnp.ndarray:
    """dec_bits: (N, B) {0,1} → (ceil(N/32), B) int32, bit (n%32) of word n//32."""
    n, b = dec_bits.shape
    pad = (-n) % 32
    if pad:
        dec_bits = jnp.concatenate([dec_bits, jnp.zeros((pad, b), dec_bits.dtype)], 0)
    n_words = dec_bits.shape[0] // 32
    d = dec_bits.astype(jnp.int32).reshape(n_words, 32, b)
    weights = (jnp.int32(1) << jnp.arange(32, dtype=jnp.int32))[None, :, None]
    return (d * weights).sum(axis=1, dtype=jnp.int32)


@partial(jax.jit, static_argnames=("code",))
def acs_forward_ref(y: jnp.ndarray, code: ConvCode) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Forward ACS over a batch of parallel blocks (paper K1).

    y: (T, R, B) soft symbols (float32 or int-like; int inputs accumulate in
       int32 — exact integer path used by the quantized decoder).
    Returns (sp, pm_final):
      sp: (T, ceil(N/32), B) int32 bit-packed survivor decisions
      pm_final: (N, B) final path metrics.
    """
    T, R, B = y.shape
    N = code.n_states
    nb = N // 2
    tabs = code.acs_tables
    cw_te = jnp.asarray(tabs["cw_top_even"])  # α
    cw_to = jnp.asarray(tabs["cw_top_odd"])  # γ
    cw_be = jnp.asarray(tabs["cw_bot_even"])  # β
    cw_bo = jnp.asarray(tabs["cw_bot_odd"])  # θ

    integer = jnp.issubdtype(y.dtype, jnp.integer)
    acc_dtype = jnp.int32 if integer else jnp.float32
    signs = jnp.asarray(code.codeword_signs, dtype=acc_dtype)  # (2^R, R)

    def step(pm, y_t):
        # y_t: (R, B) → bm table (2^R, B)
        bm = signs @ y_t.astype(acc_dtype)
        pairs = pm.reshape(nb, 2, B)
        pm_even, pm_odd = pairs[:, 0], pairs[:, 1]
        # top targets j: even pred uses α, odd pred uses γ
        m_te = pm_even + bm[cw_te]
        m_to = pm_odd + bm[cw_to]
        dec_top = (m_to < m_te).astype(jnp.int32)
        pm_top = jnp.minimum(m_te, m_to)
        # bottom targets j+N/2: even pred uses β, odd pred uses θ
        m_be = pm_even + bm[cw_be]
        m_bo = pm_odd + bm[cw_bo]
        dec_bot = (m_bo < m_be).astype(jnp.int32)
        pm_bot = jnp.minimum(m_be, m_bo)
        new_pm = jnp.concatenate([pm_top, pm_bot], axis=0)
        sp_words = _pack_decisions(jnp.concatenate([dec_top, dec_bot], axis=0))
        return new_pm, sp_words

    pm0 = jnp.zeros((N, B), dtype=acc_dtype)
    pm_final, sp = jax.lax.scan(step, pm0, y)
    return sp, pm_final


@partial(jax.jit, static_argnames=("code", "decode_start", "n_decode"))
def traceback_ref(
    sp: jnp.ndarray,
    code: ConvCode,
    decode_start: int,
    n_decode: int,
    start_state: jnp.ndarray | int = 0,
) -> jnp.ndarray:
    """Traceback + decode (paper K2).

    sp: (T, W, B) packed survivor words from acs_forward_ref, laid out as
        [truncation M | decode D | traceback L | optional pad]. The walk
        starts at stage T (state ``start_state``) and emits bits for stages
        [decode_start, decode_start + n_decode) — with the paper's framing
        (M = L) ``decode_start = L``.
    Returns (D, B) decoded bits (int32), in forward order.
    """
    T, W, B = sp.shape
    N = code.n_states
    v = code.v
    D = n_decode

    def step(state, sp_t):
        # sp_t: (W, B). decision bit for `state` = bit (state%32) of word state//32
        word_idx = state >> 5  # (B,)
        # gather per-lane word: W is tiny (ceil(N/32)); select via comparisons
        word = jnp.zeros_like(state)
        for wi in range(W):
            word = jnp.where(word_idx == wi, sp_t[wi], word)
        bit = (word >> (state & 31)) & 1
        out_bit = state >> (v - 1)  # MSB = input bit of this transition
        prev_state = 2 * (state % (N // 2)) + bit
        return prev_state, out_bit

    state0 = jnp.broadcast_to(jnp.asarray(start_state, jnp.int32), (B,))
    # walk stages T-1 .. 0 (we only need down to decode_start, but walking to
    # 0 is harmless and keeps shapes static; earlier bits are discarded)
    _, bits_rev = jax.lax.scan(step, state0, sp[::-1])
    bits = bits_rev[::-1]  # (T, B), bits[t] = decoded input bit of stage t
    return jax.lax.dynamic_slice_in_dim(bits, decode_start, D, axis=0)


def pbvd_decode_ref(
    y_blocks: jnp.ndarray,
    code: ConvCode,
    n_decode: int,
    n_traceback: int,
    start_state: int = 0,
) -> jnp.ndarray:
    """Decode framed parallel blocks: y_blocks (T, R, B) → (D, B) bits."""
    sp, _ = acs_forward_ref(y_blocks, code)
    return traceback_ref(sp, code, n_traceback, n_decode, start_state)
