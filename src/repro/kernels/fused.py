"""Fused single-kernel PBVD: forward ACS + in-VMEM traceback (beyond-paper).

The paper's two-kernel split exists because a GPU CTA cannot hold the
survivor-path history of a parallel block in shared memory (D+2L = 596
stages × 8 B × 32 blocks/warp ≈ 150 KB > SMEM), so SP must round-trip
through global memory between K1 and K2 — that SP traffic (8 B per stage
per block ≈ 9.3 B per decoded bit) dominates the decoder's memory roofline.

On TPU the VMEM budget is two orders of magnitude larger: a 128-lane block
tile needs only `T×2×4×128 ≈ 610 KB` for the full bit-packed SP history.
This kernel therefore keeps SP in VMEM scratch, runs the traceback in the
same kernel invocation, and emits bit-packed decoded words — HBM traffic
per decoded bit drops from ≈ 11.6 B (int8 symbols + SP out + SP in + bits)
to ≈ (1+2L/D)·R·1 B in + 1/8 B out ≈ 2.5 B:  a ~4.6× memory-roofline win
that the GPU architecture structurally cannot reach.

``tb_mode`` selects the phase-2 traceback: ``"serial"`` walks one stage per
step (stopping at ``decode_start`` — earlier stages emit nothing);
``"prefix"`` runs the chunked survivor-map composition of
:mod:`repro.kernels.traceback` directly from the VMEM SP scratch (the
composed-map and decoded-bit scratches also live in VMEM), keeping the
~2.5 B/bit HBM roofline while cutting the serial chain from T steps to
ceil(T/tb_chunk) — see DESIGN.md §9.

Validated bit-exactly against the two-kernel path and the jnp oracle
(`tests/test_fused_kernel.py`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.trellis import ConvCode
from .acs import (
    LANE_TILE,
    _min_subtract,
    _pack_plane,
    butterfly_bm_row,
    folded_bm_rows,
    matrix_step,
    radix2_stage,
    radix4_stage_pair,
)
from repro.core.quantize import metric_mode_qmax, norm_interval
from .ref import _acc_dtype_for
from .traceback import DEFAULT_TB_CHUNK, _prefix_traceback_phases, prefix_chunk_geometry

__all__ = ["pbvd_fused_pallas", "DEFAULT_SYM_CHUNK"]

# Stages per double-buffered symbol tile (radix-4 path): the HBM read of the
# next tile overlaps the current tile's ACS compute. Even (radix-4 pairs
# never straddle a tile) and big enough to amortize the DMA issue cost; the
# 2× scratch is 2·64·R·TILE symbol bytes — see DESIGN.md §10 for the model.
DEFAULT_SYM_CHUNK = 64


def _acs_phase(
    y_ref,
    pm_ref,
    sp_write,
    *,
    code: ConvCode,
    n_stages: int,
    acc_dtype,
    norm_every: int,
):
    """Phase 1 (radix 2): forward ACS from VMEM-resident symbols; survivor
    words handed to ``sp_write(s, words)``."""
    tile = pm_ref.shape[-1]

    pm_ref[...] = jnp.zeros_like(pm_ref)

    def acs_body(s, pm):
        y_s = y_ref[pl.ds(s, 1)][0].astype(acc_dtype)  # (R, TILE)
        new_pm, dec = radix2_stage(pm, y_s, code, acc_dtype, tile)
        if norm_every:  # amortized min-subtract (i16/i8 saturation contract)
            new_pm = jax.lax.cond(
                s % norm_every == norm_every - 1, _min_subtract, lambda p: p, new_pm
            )
        sp_write(s, _pack_plane(dec, tile))  # (W, TILE)
        return new_pm

    pm = jax.lax.fori_loop(0, n_stages, acs_body, pm_ref[...], unroll=False)
    pm_ref[...] = pm


def _acs_phase_r4_dbuf(
    y_hbm,  # (T_pad, R, B) symbols, HBM/ANY — in their ORIGINAL dtype
    bt,  # lane-tile index of this program instance
    pm_ref,  # VMEM scratch (N, TILE)
    sp_write,  # per-stage survivor-word writer (odd trailing stage)
    sp_write_pair,  # per-step writer: (flat stage, words1, words2)
    sym_ref,  # VMEM scratch (2, SYM, R, TILE), y dtype — the double buffer
    sem_ref,  # DMA semaphores (2,)
    *,
    code: ConvCode,
    n_stages: int,
    acc_dtype,
    norm_every: int,
    clip_qmax: int | None,
    sym_chunk: int,
):
    """Phase 1 (radix 4): stage-fused ACS with a double-buffered symbol pipeline.

    Symbols stay in HBM in their quantized dtype; while the radix-4
    butterflies of tile c compute, the DMA engine prefetches tile c+1 into
    the other half of the double buffer, so the HBM read of ``ys`` overlaps
    ACS compute instead of serializing with it (and the HBM traffic stays at
    the narrow symbol width — the cast to 32-bit VPU registers happens after
    the VMEM load). The wrapper pads T to a ``sym_chunk`` multiple so every
    DMA has static shape; the compute loops stop at the true ``n_stages``.
    """
    tile = pm_ref.shape[-1]
    T = n_stages
    n_chunks = -(-T // sym_chunk)

    def dma(c, slot):
        return pltpu.make_async_copy(
            y_hbm.at[pl.ds(c * sym_chunk, sym_chunk), :, pl.ds(bt * tile, tile)],
            sym_ref.at[slot],
            sem_ref.at[slot],
        )

    pm_ref[...] = jnp.zeros_like(pm_ref)
    pm = pm_ref[...]
    dma(0, 0).start()
    for c in range(n_chunks):  # static chunk count: python-level pipeline
        slot = c % 2
        if c + 1 < n_chunks:
            dma(c + 1, (c + 1) % 2).start()  # prefetch overlaps this chunk
        dma(c, slot).wait()
        lo = c * sym_chunk
        hi = min(lo + sym_chunk, T)
        step_base = lo // 2  # sym_chunk is even: pairs never straddle tiles

        def load(row, n_rows, slot=slot):
            # widen (and clip, narrow modes — see acs_forward_ref; in-kernel
            # because the HBM copy keeps the wire dtype) at the VMEM read
            y_t = sym_ref[slot, pl.ds(row, n_rows)].astype(acc_dtype)
            if clip_qmax is not None:
                y_t = jnp.clip(y_t, -clip_qmax, clip_qmax)
            return y_t

        def pair_body(s, pm, step_base=step_base):
            y_pair = load(2 * s, 2)  # (2, R, TILE)
            new_pm, dec1, dec2 = radix4_stage_pair(
                pm, y_pair[0], y_pair[1], code, acc_dtype, tile
            )
            if norm_every:  # cadence counts GLOBAL fused steps
                new_pm = jax.lax.cond(
                    (step_base + s) % norm_every == norm_every - 1,
                    _min_subtract,
                    lambda p: p,
                    new_pm,
                )
            sp_write_pair(
                lo + 2 * s, _pack_plane(dec1, tile), _pack_plane(dec2, tile)
            )
            return new_pm

        pm = jax.lax.fori_loop(0, (hi - lo) // 2, pair_body, pm, unroll=False)
        if (hi - lo) % 2:
            # trailing radix-2 step (odd T, last tile only); narrow modes
            # min-subtract unconditionally — uniform shift, budget-safe
            pm, dec = radix2_stage(pm, load(hi - 1 - lo, 1)[0], code, acc_dtype, tile)
            if norm_every:
                pm = _min_subtract(pm)
            sp_write(hi - 1, _pack_plane(dec, tile))
    pm_ref[...] = pm


def _acs_phase_mat_dbuf(
    y_hbm,  # (T_pad, R, B) symbols, HBM/ANY — in their ORIGINAL dtype
    bt,  # lane-tile index of this program instance
    pm_ref,  # VMEM scratch (N, TILE)
    sp_write,  # per-stage survivor-word writer (trailing T mod k stages)
    sp_write_multi,  # per-step writer: (flat stage, [k packed planes])
    sym_ref,  # VMEM scratch (2, SYM, R, TILE), y dtype — the double buffer
    sem_ref,  # DMA semaphores (2,)
    *,
    code: ConvCode,
    n_stages: int,
    acc_dtype,
    norm_every: int,
    clip_qmax: int | None,
    sym_chunk: int,
    k: int,
):
    """Phase 1 (matrix): k-stage tropical-matmul ACS on the double-buffered
    symbol pipeline of :func:`_acs_phase_r4_dbuf` — the DMA prefetch of
    symbol tile c+1 overlaps tile c's matrix steps. The wrapper rounds
    ``sym_chunk`` to a k-multiple, so steps never straddle tiles and the
    T mod k trailing stages (radix-2, unconditional min-subtract in narrow
    modes — a uniform budget-safe shift) fall in the last tile only,
    matching the ref scan's step/trailing split exactly.
    """
    tile = pm_ref.shape[-1]
    T = n_stages
    n_chunks = -(-T // sym_chunk)

    def dma(c, slot):
        return pltpu.make_async_copy(
            y_hbm.at[pl.ds(c * sym_chunk, sym_chunk), :, pl.ds(bt * tile, tile)],
            sym_ref.at[slot],
            sem_ref.at[slot],
        )

    pm_ref[...] = jnp.zeros_like(pm_ref)
    pm = pm_ref[...]
    dma(0, 0).start()
    for c in range(n_chunks):  # static chunk count: python-level pipeline
        slot = c % 2
        if c + 1 < n_chunks:
            dma(c + 1, (c + 1) % 2).start()  # prefetch overlaps this chunk
        dma(c, slot).wait()
        lo = c * sym_chunk
        hi = min(lo + sym_chunk, T)
        step_base = lo // k  # sym_chunk is a k-multiple

        def load(row, n_rows, slot=slot):
            # widen (and clip, narrow modes) at the VMEM read, as in the
            # radix-4 pipeline — the HBM copy keeps the wire dtype
            y_t = sym_ref[slot, pl.ds(row, n_rows)].astype(acc_dtype)
            if clip_qmax is not None:
                y_t = jnp.clip(y_t, -clip_qmax, clip_qmax)
            return y_t

        def step_body(s, pm, step_base=step_base, lo=lo):
            ys = load(k * s, k)  # (k, R, TILE)
            new_pm, planes = matrix_step(
                pm, [ys[i] for i in range(k)], code, acc_dtype, tile, k
            )
            if norm_every:  # cadence counts GLOBAL k-stage steps
                new_pm = jax.lax.cond(
                    (step_base + s) % norm_every == norm_every - 1,
                    _min_subtract,
                    lambda p: p,
                    new_pm,
                )
            sp_write_multi(lo + k * s, [_pack_plane(d, tile) for d in planes])
            return new_pm

        pm = jax.lax.fori_loop(0, (hi - lo) // k, step_body, pm, unroll=False)
        for t in range(hi - lo - (hi - lo) % k, hi - lo):
            pm, dec = radix2_stage(pm, load(t, 1)[0], code, acc_dtype, tile)
            if norm_every:
                pm = _min_subtract(pm)
            sp_write(lo + t, _pack_plane(dec, tile))
    pm_ref[...] = pm


def _run_acs_phase(
    y_ref,
    pm_ref,
    sp_write,
    sp_write_multi,
    extra_scratch,
    *,
    code: ConvCode,
    n_stages: int,
    acc_dtype,
    norm_every: int,
    radix: int,
    impl: str,
    k: int,
    clip_qmax: int | None,
    sym_chunk: int,
):
    """Dispatch phase 1: VMEM-resident radix-2, or a double-buffered fused
    path (stage-fused radix-4 butterflies, or k-stage matrix steps)."""
    if impl == "matrix":
        sym_ref, sem_ref = extra_scratch
        _acs_phase_mat_dbuf(
            y_ref,
            pl.program_id(0),
            pm_ref,
            sp_write,
            sp_write_multi,
            sym_ref,
            sem_ref,
            code=code,
            n_stages=n_stages,
            acc_dtype=acc_dtype,
            norm_every=norm_every,
            clip_qmax=clip_qmax,
            sym_chunk=sym_chunk,
            k=k,
        )
    elif radix == 2:
        _acs_phase(
            y_ref,
            pm_ref,
            sp_write,
            code=code,
            n_stages=n_stages,
            acc_dtype=acc_dtype,
            norm_every=norm_every,
        )
    else:
        sym_ref, sem_ref = extra_scratch

        def sp_write_pair(s, words1, words2):
            sp_write_multi(s, [words1, words2])

        _acs_phase_r4_dbuf(
            y_ref,
            pl.program_id(0),
            pm_ref,
            sp_write,
            sp_write_pair,
            sym_ref,
            sem_ref,
            code=code,
            n_stages=n_stages,
            acc_dtype=acc_dtype,
            norm_every=norm_every,
            clip_qmax=clip_qmax,
            sym_chunk=sym_chunk,
        )


def _fused_kernel(
    y_ref,  # (T, R, TILE) symbols in VMEM (radix 2) or (T_pad, R, B) in ANY (radix 4)
    start_ref,  # (1, TILE) int32 traceback start state
    bits_ref,  # (n_words, TILE) int32 out: bit-packed decoded bits
    sp_ref,  # VMEM scratch (T, W, TILE) int32 survivor words
    pm_ref,  # VMEM scratch (N, TILE) acc path metrics
    *extra_scratch,  # radix 4: (sym double buffer, DMA semaphores)
    code: ConvCode,
    n_stages: int,
    decode_start: int,
    n_decode: int,
    acc_dtype,
    norm_every: int,
    radix: int,
    impl: str,
    k: int,
    clip_qmax: int | None,
    sym_chunk: int,
):
    tile = pm_ref.shape[-1]
    v = code.v
    half = code.n_states // 2
    W = sp_ref.shape[1]

    # ---- phase 1: forward ACS, SP stays in VMEM ---------------------------------
    def sp_write(s, words):
        sp_ref[pl.ds(s, 1)] = words[None]

    def sp_write_multi(s, words):
        # stage-major scratch: all of a fused step's bit-planes land in one
        # contiguous store
        sp_ref[pl.ds(s, len(words))] = jnp.stack(words)

    _run_acs_phase(
        y_ref,
        pm_ref,
        sp_write,
        sp_write_multi,
        extra_scratch,
        code=code,
        n_stages=n_stages,
        acc_dtype=acc_dtype,
        norm_every=norm_every,
        radix=radix,
        impl=impl,
        k=k,
        clip_qmax=clip_qmax,
        sym_chunk=sym_chunk,
    )

    # ---- phase 2: serial traceback from VMEM, emit packed bits -------------------
    def tb_body(i, carry):
        state, word = carry
        s = n_stages - 1 - i  # walk stages T-1 .. decode_start (early exit)
        sp_t = sp_ref[pl.ds(s, 1)][0]  # (W, TILE)
        word_idx = state >> 5
        sel = sp_t[0][None, :]
        if W > 1:
            for wi in range(1, W):
                sel = jnp.where(word_idx == wi, sp_t[wi][None, :], sel)
        bit = (sel >> (state & 31)) & 1
        out_bit = state >> (v - 1)

        b = s - decode_start  # decoded-bit index (valid when 0 ≤ b < n_decode)
        in_region = jnp.logical_and(b >= 0, b < n_decode)
        word = jnp.where(in_region, word | (out_bit << (b & 31)), word)

        # flush the packed word when its lowest bit arrives
        @pl.when(jnp.logical_and(in_region, (b & 31) == 0))
        def _flush():
            bits_ref[pl.ds(b >> 5, 1)] = word

        word = jnp.where(jnp.logical_and(in_region, (b & 31) == 0), jnp.zeros_like(word), word)
        return 2 * (state % half) + bit, word

    state0 = start_ref[...]
    # stages below decode_start feed nothing the emitted words depend on:
    # the last flush fires at s = decode_start (b = 0)
    jax.lax.fori_loop(
        0,
        n_stages - decode_start,
        tb_body,
        (state0, jnp.zeros((1, tile), jnp.int32)),
        unroll=False,
    )


def _fused_prefix_kernel(
    y_ref,  # (T, R, TILE) symbols in VMEM (radix 2) or (T_pad, R, B) in ANY (radix 4)
    start_ref,  # (1, TILE) int32 traceback start state
    bits_ref,  # (n_words, TILE) int32 out: bit-packed decoded bits
    sp_ref,  # VMEM scratch (n_chunks, C, W, TILE) int32 survivor words
    pm_ref,  # VMEM scratch (N, TILE) acc path metrics
    maps_ref,  # VMEM scratch (n_act, N, TILE) int32 composed chunk maps
    entry_ref,  # VMEM scratch (nc_e, TILE) int32 chunk entry states
    tbbits_ref,  # VMEM scratch (nc_e, C, TILE) int32 unpacked decoded bits
    *extra_scratch,  # radix 4: (sym double buffer, DMA semaphores)
    code: ConvCode,
    n_stages: int,
    decode_start: int,
    n_decode: int,
    acc_dtype,
    norm_every: int,
    radix: int,
    impl: str,
    k: int,
    clip_qmax: int | None,
    sym_chunk: int,
    C: int,
    P: int,
    n_chunks: int,
    c_lo: int,
    c_hi: int,
):
    tile = pm_ref.shape[-1]

    # ---- phase 1: forward ACS into the chunk-major SP scratch -------------------
    if P:  # pad rows below stage 0 (chunk 0) are inert zero words
        sp_ref[0:1, 0:P] = jnp.zeros_like(sp_ref[0:1, 0:P])

    def sp_write(s, words):
        flat = s + P
        sp_ref[pl.ds(flat // C, 1), pl.ds(flat % C, 1)] = words[None, None]

    def sp_write_multi(s, words):
        # chunk-major scratch: a fused step may straddle a traceback-chunk
        # boundary (C not a step multiple), so the planes store individually
        for i, w in enumerate(words):
            sp_write(s + i, w)

    _run_acs_phase(
        y_ref,
        pm_ref,
        sp_write,
        sp_write_multi,
        extra_scratch,
        code=code,
        n_stages=n_stages,
        acc_dtype=acc_dtype,
        norm_every=norm_every,
        radix=radix,
        impl=impl,
        k=k,
        clip_qmax=clip_qmax,
        sym_chunk=sym_chunk,
    )

    # ---- phase 2: chunked map composition + short walk + expansion --------------
    def emit(row, out_bit):
        tbbits_ref[:, pl.ds(row, 1)] = out_bit

    _prefix_traceback_phases(
        sp_ref,
        start_ref[...],
        emit,
        maps_ref,
        entry_ref,
        code=code,
        C=C,
        n_chunks=n_chunks,
        c_lo=c_lo,
        c_hi=c_hi,
    )

    # ---- phase 3: pack the decode region to output words --------------------------
    # same vectorized pack idiom as the ACS phase: flatten the chunk-major
    # bit scratch, slice the decode window (static bounds), zero-pad bits
    # that overhang T (they don't exist; serial mode leaves them 0 too) and
    # reduce 32 sublanes per word
    ds_local = (decode_start + P) - c_lo * C
    n_window = min(n_decode, n_stages - decode_start)  # bits that exist
    n_words = bits_ref.shape[0]
    flat = tbbits_ref[...].reshape(-1, tile)[ds_local : ds_local + n_window]
    pad = n_words * 32 - n_window
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad, tile), jnp.int32)], axis=0)
    weights = (jnp.int32(1) << jnp.arange(32, dtype=jnp.int32))[None, :, None]
    bits_ref[...] = (flat.reshape(n_words, 32, tile) * weights).sum(
        axis=1, dtype=jnp.int32
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "code",
        "decode_start",
        "n_decode",
        "interpret",
        "metric_mode",
        "tb_mode",
        "tb_chunk",
        "acs_radix",
        "acs_impl",
        "acs_k",
        "sym_chunk",
    ),
)
def pbvd_fused_pallas(
    y: jnp.ndarray,
    code: ConvCode,
    *,
    decode_start: int,
    n_decode: int,
    start_state: jnp.ndarray | None = None,
    interpret: bool = False,
    metric_mode: str = "f32",
    tb_mode: str = "serial",
    tb_chunk: int = DEFAULT_TB_CHUNK,
    acs_radix: int = 2,
    acs_impl: str = "butterfly",
    acs_k: int = 2,
    sym_chunk: int = DEFAULT_SYM_CHUNK,
) -> jnp.ndarray:
    """One-kernel PBVD decode. y (T, R, B) → packed bits (n_decode/32, B) int32.

    n_decode must be a multiple of 32 (bit-packed output words).
    ``metric_mode`` "i16"/"i8" adds the amortized min-subtract normalization
    (int32 VPU registers — see ``repro.kernels.registry.METRIC_MODES``).
    ``tb_mode="prefix"`` runs the chunked parallel-prefix traceback from the
    VMEM survivor scratch (bit-exact to serial for any ``tb_chunk``).
    ``acs_radix=4`` halves the forward serial chain with stage-fused radix-4
    steps AND moves the symbol read to a double-buffered HBM→VMEM pipeline:
    the symbols stay in their wire dtype in HBM and the next ``sym_chunk``
    stages prefetch while the current ones compute (odd T runs one trailing
    radix-2 step; decoded bits stay bit-identical to radix 2).
    ``acs_impl="matrix"`` runs the k-stage (min,+) tropical-matmul ACS on
    the same double-buffered pipeline (``sym_chunk`` rounds down to a
    k-multiple; T mod k trailing stages run radix-2; float symbols lower to
    the staged butterfly — see ``acs_forward_pallas``). Decoded bits stay
    bit-identical for every impl/radix/k.
    """
    T, R, B = y.shape
    if n_decode % 32:
        raise ValueError("n_decode must be a multiple of 32")
    if B % LANE_TILE:
        raise ValueError(f"B={B} not a multiple of {LANE_TILE}")
    if tb_mode not in ("serial", "prefix"):
        raise ValueError(f"unknown tb_mode {tb_mode!r}")
    if acs_impl not in ("butterfly", "matrix"):
        raise ValueError(f"acs_impl must be 'butterfly' or 'matrix', got {acs_impl!r}")
    if acs_radix not in (2, 4):
        raise ValueError(f"acs_radix must be 2 or 4, got {acs_radix}")
    if acs_impl == "matrix":
        code.validate_matrix_k(acs_k)
    else:
        if acs_radix == 4 and sym_chunk % 2:
            raise ValueError(f"sym_chunk must be even, got {sym_chunk}")
        if acs_radix == 4 and code.n_states < 4:
            raise ValueError(f"radix-4 ACS needs K >= 3 (got K={code.K})")
    semantic = _acc_dtype_for(y.dtype, metric_mode)
    acc_dtype = jnp.float32 if semantic == jnp.float32 else jnp.int32
    if acs_impl == "matrix" and acc_dtype == jnp.float32:
        # float lowering, as in acs_forward_pallas: the flat k-stage
        # contraction is not IEEE-associative — run the butterfly body
        acs_impl, acs_radix = "butterfly", 2
    if acs_impl == "matrix":
        # steps must not straddle symbol tiles: round the double-buffer
        # chunk down to a k-multiple (64 → 63 for k=3)
        sym_chunk = max(acs_k, sym_chunk - sym_chunk % acs_k)
        norm_every = norm_interval(code, metric_mode, stages_per_step=acs_k)
    else:
        norm_every = norm_interval(code, metric_mode, acs_radix)
    clip_qmax = metric_mode_qmax(code, metric_mode) if norm_every else None
    dbuf = acs_impl == "matrix" or acs_radix == 4
    if not dbuf:
        # symbols ride the pallas pipeline into VMEM, widened to the
        # register dtype up front
        y = y.astype(acc_dtype)
        if clip_qmax is not None:
            # saturate out-of-budget pre-quantized symbols (see acs_forward_ref)
            y = jnp.clip(y, -clip_qmax, clip_qmax)
        y_spec = pl.BlockSpec((T, R, LANE_TILE), lambda bt: (0, 0, bt))
    else:
        # symbols stay in HBM in their WIRE dtype (the kernel widens/clips
        # after the VMEM load); pad T so every double-buffer DMA is
        # statically shaped — the pad stages are never computed
        pad = (-T) % sym_chunk
        if pad:
            y = jnp.pad(y, ((0, pad), (0, 0), (0, 0)))
        y_spec = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)

    N = code.n_states
    W = (N + 31) // 32
    n_bt = B // LANE_TILE
    n_words = n_decode // 32

    if start_state is None:
        start_state = jnp.zeros((B,), jnp.int32)

    common = dict(
        code=code,
        n_stages=T,
        decode_start=decode_start,
        n_decode=n_decode,
        acc_dtype=acc_dtype,
        norm_every=norm_every,
        radix=acs_radix,
        impl=acs_impl,
        k=acs_k,
        clip_qmax=clip_qmax,
        sym_chunk=sym_chunk,
    )
    if tb_mode == "serial":
        kernel = functools.partial(_fused_kernel, **common)
        scratch = [
            pltpu.VMEM((T, W, LANE_TILE), jnp.int32),
            pltpu.VMEM((N, LANE_TILE), acc_dtype),
        ]
    else:
        # geometry over the bits that exist: the packed width n_decode may
        # overhang T at ragged D (top word bits stay 0, as in serial mode)
        n_window = min(n_decode, T - decode_start)
        C, P, n_chunks, c_lo, c_hi = prefix_chunk_geometry(
            T, decode_start, n_window, tb_chunk
        )
        kernel = functools.partial(
            _fused_prefix_kernel,
            **common,
            C=C,
            P=P,
            n_chunks=n_chunks,
            c_lo=c_lo,
            c_hi=c_hi,
        )
        scratch = [
            pltpu.VMEM((n_chunks, C, W, LANE_TILE), jnp.int32),
            pltpu.VMEM((N, LANE_TILE), acc_dtype),
            pltpu.VMEM((n_chunks - c_lo, N, LANE_TILE), jnp.int32),
            pltpu.VMEM((c_hi - c_lo + 1, LANE_TILE), jnp.int32),
            pltpu.VMEM((c_hi - c_lo + 1, C, LANE_TILE), jnp.int32),
        ]
    if dbuf:
        scratch = scratch + [
            pltpu.VMEM((2, sym_chunk, R, LANE_TILE), y.dtype),  # double buffer
            pltpu.SemaphoreType.DMA((2,)),
        ]
    packed = pl.pallas_call(
        kernel,
        grid=(n_bt,),
        in_specs=[
            y_spec,
            pl.BlockSpec((1, LANE_TILE), lambda bt: (0, bt)),
        ],
        out_specs=pl.BlockSpec((n_words, LANE_TILE), lambda bt: (0, bt)),
        out_shape=jax.ShapeDtypeStruct((n_words, B), jnp.int32),
        scratch_shapes=scratch,
        interpret=interpret,
    )(y, start_state.reshape(1, B).astype(jnp.int32))
    return packed
