"""Fused single-kernel PBVD: forward ACS + in-VMEM traceback (beyond-paper).

The paper's two-kernel split exists because a GPU CTA cannot hold the
survivor-path history of a parallel block in shared memory (D+2L = 596
stages × 8 B × 32 blocks/warp ≈ 150 KB > SMEM), so SP must round-trip
through global memory between K1 and K2 — that SP traffic (8 B per stage
per block ≈ 9.3 B per decoded bit) dominates the decoder's memory roofline.

On TPU the VMEM budget is two orders of magnitude larger: a 128-lane block
tile needs only `T×2×4×128 ≈ 610 KB` for the full bit-packed SP history.
This kernel therefore keeps SP in VMEM scratch, runs the traceback in the
same kernel invocation, and emits bit-packed decoded words — HBM traffic
per decoded bit drops from ≈ 11.6 B (int8 symbols + SP out + SP in + bits)
to ≈ (1+2L/D)·R·1 B in + 1/8 B out ≈ 2.5 B:  a ~4.6× memory-roofline win
that the GPU architecture structurally cannot reach.

Validated bit-exactly against the two-kernel path and the jnp oracle
(`tests/test_fused_kernel.py`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.trellis import ConvCode
from .acs import LANE_TILE

__all__ = ["pbvd_fused_pallas"]


def _fused_kernel(
    y_ref,  # (T, R, TILE) symbols
    signs_ref,  # (4, nb, R) codeword signs [α, γ, β, θ]
    start_ref,  # (1, TILE) int32 traceback start state
    bits_ref,  # (n_words, TILE) int32 out: bit-packed decoded bits
    sp_ref,  # VMEM scratch (T, W, TILE) int32 survivor words
    pm_ref,  # VMEM scratch (N, TILE) acc path metrics
    *,
    code: ConvCode,
    n_stages: int,
    decode_start: int,
    n_decode: int,
    acc_dtype,
):
    nb = code.n_butterflies
    tile = pm_ref.shape[-1]
    v = code.v
    half = code.n_states // 2
    W = sp_ref.shape[1]

    pm_ref[...] = jnp.zeros_like(pm_ref)

    # ---- phase 1: forward ACS, SP stays in VMEM ---------------------------------
    def acs_body(s, pm):
        y_s = y_ref[pl.ds(s, 1)][0].astype(acc_dtype)  # (R, TILE)
        bm_rows = []
        for row in range(4):
            acc = jnp.zeros((nb, tile), dtype=acc_dtype)
            for r in range(code.R):
                acc = acc + signs_ref[row, :, r][:, None] * y_s[r][None, :]
            bm_rows.append(acc)
        bm_te, bm_to, bm_be, bm_bo = bm_rows

        pairs = pm.reshape(nb, 2, tile)
        pm_even, pm_odd = pairs[:, 0], pairs[:, 1]
        m_te, m_to = pm_even + bm_te, pm_odd + bm_to
        dec_top = (m_to < m_te).astype(jnp.int32)
        pm_top = jnp.minimum(m_te, m_to)
        m_be, m_bo = pm_even + bm_be, pm_odd + bm_bo
        dec_bot = (m_bo < m_be).astype(jnp.int32)
        pm_bot = jnp.minimum(m_be, m_bo)
        new_pm = jnp.concatenate([pm_top, pm_bot], axis=0)

        dec = jnp.concatenate([dec_top, dec_bot], axis=0)
        pad = (-dec.shape[0]) % 32
        if pad:
            dec = jnp.concatenate([dec, jnp.zeros((pad, tile), jnp.int32)], axis=0)
        d = dec.reshape(-1, 32, tile)
        weights = (jnp.int32(1) << jnp.arange(32, dtype=jnp.int32))[None, :, None]
        sp_ref[pl.ds(s, 1)] = (d * weights).sum(axis=1, dtype=jnp.int32)[None]
        return new_pm

    pm = jax.lax.fori_loop(0, n_stages, acs_body, pm_ref[...], unroll=False)
    pm_ref[...] = pm

    # ---- phase 2: traceback from VMEM, emit packed bits ---------------------------
    def tb_body(i, carry):
        state, word = carry
        s = n_stages - 1 - i
        sp_t = sp_ref[pl.ds(s, 1)][0]  # (W, TILE)
        word_idx = state >> 5
        sel = sp_t[0][None, :]
        if W > 1:
            for wi in range(1, W):
                sel = jnp.where(word_idx == wi, sp_t[wi][None, :], sel)
        bit = (sel >> (state & 31)) & 1
        out_bit = state >> (v - 1)

        b = s - decode_start  # decoded-bit index (valid when 0 ≤ b < n_decode)
        in_region = jnp.logical_and(b >= 0, b < n_decode)
        word = jnp.where(in_region, word | (out_bit << (b & 31)), word)

        # flush the packed word when its lowest bit arrives
        @pl.when(jnp.logical_and(in_region, (b & 31) == 0))
        def _flush():
            bits_ref[pl.ds(b >> 5, 1)] = word

        word = jnp.where(jnp.logical_and(in_region, (b & 31) == 0), jnp.zeros_like(word), word)
        return 2 * (state % half) + bit, word

    state0 = start_ref[...]
    jax.lax.fori_loop(
        0, n_stages, tb_body, (state0, jnp.zeros((1, tile), jnp.int32)), unroll=False
    )


@functools.partial(
    jax.jit, static_argnames=("code", "decode_start", "n_decode", "interpret")
)
def pbvd_fused_pallas(
    y: jnp.ndarray,
    code: ConvCode,
    *,
    decode_start: int,
    n_decode: int,
    start_state: jnp.ndarray | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """One-kernel PBVD decode. y (T, R, B) → packed bits (n_decode/32, B) int32.

    n_decode must be a multiple of 32 (bit-packed output words).
    """
    T, R, B = y.shape
    if n_decode % 32:
        raise ValueError("n_decode must be a multiple of 32")
    if B % LANE_TILE:
        raise ValueError(f"B={B} not a multiple of {LANE_TILE}")
    integer = jnp.issubdtype(y.dtype, jnp.integer)
    acc_dtype = jnp.int32 if integer else jnp.float32
    y = y.astype(acc_dtype)

    N = code.n_states
    W = (N + 31) // 32
    nb = code.n_butterflies
    n_bt = B // LANE_TILE
    n_words = n_decode // 32

    cw = code.butterfly_codewords
    signs_np = code.codeword_signs[cw[:, [0, 2, 1, 3]]]
    signs_arr = jnp.asarray(np.transpose(signs_np, (1, 0, 2)), dtype=acc_dtype)
    if start_state is None:
        start_state = jnp.zeros((B,), jnp.int32)

    kernel = functools.partial(
        _fused_kernel,
        code=code,
        n_stages=T,
        decode_start=decode_start,
        n_decode=n_decode,
        acc_dtype=acc_dtype,
    )
    packed = pl.pallas_call(
        kernel,
        grid=(n_bt,),
        in_specs=[
            pl.BlockSpec((T, R, LANE_TILE), lambda bt: (0, 0, bt)),
            pl.BlockSpec((4, nb, R), lambda bt: (0, 0, 0)),
            pl.BlockSpec((1, LANE_TILE), lambda bt: (0, bt)),
        ],
        out_specs=pl.BlockSpec((n_words, LANE_TILE), lambda bt: (0, bt)),
        out_shape=jax.ShapeDtypeStruct((n_words, B), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((T, W, LANE_TILE), jnp.int32),
            pltpu.VMEM((N, LANE_TILE), acc_dtype),
        ],
        interpret=interpret,
    )(y, signs_arr, start_state.reshape(1, B).astype(jnp.int32))
    return packed
