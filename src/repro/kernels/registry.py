"""Backend registry for the PBVD decode kernels.

Every backend is a function with the common contract

    backend(blocks: FramedBlocks, code: ConvCode, *,
            start_policy, stage_chunk, interpret, metric_mode,
            tb_mode, tb_chunk)
        -> (n_decode, B_real) int32 bits

registered under a name via ``@register_backend("name")``. The engine (and
the legacy ``pbvd_decode_blocks`` wrapper) dispatch through :func:`get_backend`
— adding a backend is one decorated function, not another ``if`` branch in
the decode path (DESIGN.md §1).

Contract details (DESIGN.md §3):

* The lane axis of ``FramedBlocks.y`` may be a flattened **frames × blocks**
  packing: the blocks of several independent streams ride one launch,
  concatenated along the lane dimension, with ``frame_counts`` recording how
  many real blocks each frame contributed. Every backend must return exactly
  ``blocks.n_real_blocks`` lanes — trailing pad lanes (power-of-two shape
  budget, lane-tile rounding, shard padding) are the backend's to trim.
* Backends declare which traceback start policies they implement via
  ``register_backend(name, start_policies=...)``; the dispatcher validates
  the policy *before* entering jit so unsupported combinations fail with an
  eager ``ValueError`` instead of a trace-time error.
* Backends likewise declare the **metric modes** they implement
  (``register_backend(name, metric_modes=...)``); the mode semantics are the
  :data:`METRIC_MODES` contract below, validated eagerly the same way.
* Backends declare the **traceback modes** they implement
  (``register_backend(name, tb_modes=...)``); the mode semantics are the
  :data:`TB_MODES` contract below (serial stage walk vs chunked
  parallel-prefix survivor-map composition), validated eagerly the same way.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Protocol

__all__ = [
    "FramedBlocks",
    "DecodeBackend",
    "METRIC_MODES",
    "TB_MODES",
    "ACS_RADIX",
    "ACS_IMPL",
    "register_backend",
    "get_backend",
    "available_backends",
    "backend_start_policies",
    "backend_metric_modes",
    "backend_tb_modes",
    "backend_tb_chunk_sensitive",
    "backend_acs_radix",
    "backend_acs_impl",
    "backend_preferred_tb_mode",
    "resolve_tb_mode",
    "knob_error",
]


# ---------------------------------------------------------------------------
# The quantized-metric contract (DESIGN.md §8)
# ---------------------------------------------------------------------------
# ``metric_mode`` fixes the *semantics* of the path-metric pipeline — symbol
# width, normalization cadence, and saturation budget. Storage width is a
# backend implementation detail: the pure-XLA ``ref`` backend stores PM in
# the narrow dtype (CPU SIMD lanes are 2–4× wider at int16/int8), while the
# Pallas kernels keep 32-bit VPU registers (TPU lanes are 32-bit; the narrow
# win there is HBM symbol traffic, already int8) — bit-identical either way,
# because the budget keeps every value inside the narrow range.
#
# Saturation budget (see ``repro.core.quantize.pm_spread_bound``): with
# min-subtract normalization every k stages and symbols bounded by
# |y| ≤ qmax, every path metric ever formed obeys
# |PM| ≤ (2·v + k)·R·qmax. A mode is well-defined for a code/quantizer pair
# iff that bound fits ``pm_dtype`` — the engine picks the widest symbol
# quantizer that satisfies it at k=1 (``repro.core.quantize.max_symbol_bits``)
# and the kernels spend the remaining headroom on the normalization cadence
# (``repro.core.quantize.norm_interval``; identical k in every backend), so
# the narrow paths can NEVER saturate, regardless of stream length
# (10k-stage adversarial streams are driven against this in
# tests/test_kernels.py).
METRIC_MODES: dict[str, dict[str, Any]] = {
    "f32": dict(
        pm_dtype="float32/int32",
        symbols="float32, or any pre-quantized int (exact int32 accumulation)",
        normalization="none (unbounded accumulation)",
        saturation_budget="int32 headroom: 2^31 / (R·2^q) stages per block",
    ),
    "i16": dict(
        pm_dtype="int16",
        symbols="int8 (q ≤ 8; widest q with the k=1 budget ≤ 32767)",
        normalization="min-subtract every norm_interval(code, 'i16') stages "
        "(per lane; ~100+ for the registered codes)",
        saturation_budget="(2·v+k)·R·qmax ≤ 32767 — hard-decision bit-exact "
        "to f32 on the same symbols",
    ),
    "i8": dict(
        pm_dtype="int8",
        symbols="coarse int (widest q with the k=1 budget ≤ 127; q=3 for "
        "the registered codes)",
        normalization="min-subtract every norm_interval(code, 'i8') stages "
        "(per lane; ~8-9 for the registered codes)",
        saturation_budget="(2·v+k)·R·qmax ≤ 127 — exact vs f32 on the same "
        "coarse symbols; vs q=8 the difference is the quantizer's (≈0.2–0.3 dB "
        "at 3-bit soft decisions)",
    ),
}


# ---------------------------------------------------------------------------
# The traceback-mode contract (DESIGN.md §9)
# ---------------------------------------------------------------------------
# ``tb_mode`` fixes the *algorithm* of the K2 traceback phase; both modes are
# bit-exact for every survivor history (composition of exact predecessor maps
# commutes with the walk), so the choice is purely a latency/VMEM trade:
#
# * ``"serial"`` — the paper's walk: one W-way word select + variable shift
#   per stage, ``T - decode_start`` strictly serial steps on (1, lanes)
#   operands. Minimal memory, maximal dependency chain.
# * ``"prefix"`` — chunked survivor-map composition: each chunk of
#   ``tb_chunk`` stages is composed into one N-entry state map (parallel
#   across chunks × states on the sublane axis, same select idiom), the
#   composed maps are walked in ceil(T/tb_chunk) serial steps, and all
#   chunks' bits re-expand in parallel. ``tb_chunk`` bounds the composed-map
#   scratch: (ceil(T/C) - c_lo)·N·lanes·4 B per lane tile (see DESIGN.md §9
#   for the VMEM cost model and the chunk-size sweet spot).
#
# ``tb_chunk`` is a jit static — changing the chunk size recompiles a
# chunk-sensitive prefix launch, it never re-frames. Where the launch
# ignores it (``tb_mode="serial"``, or a backend registered with
# ``tb_chunk_sensitive=False`` such as ``ref``'s full-depth scan) the
# dispatcher normalizes it out of the cache key.
TB_MODES: dict[str, dict[str, Any]] = {
    "serial": dict(
        serial_steps="T - decode_start (early exit below the decode region)",
        scratch="none beyond the survivor history",
        when="tiny T, VMEM-starved geometries, or as the parity oracle",
    ),
    "prefix": dict(
        serial_steps="ceil(T/tb_chunk) composed-map walk",
        scratch="composed maps (n_active·N·lanes·4 B) + entry states + "
        "(fused) unpacked chunk bits",
        when="where the backend declares it profitable — the last O(T) "
        "chain becomes O(T/C) with sublane-parallel composition/expansion",
    ),
}

# ``tb_mode="auto"`` is not an algorithm: the dispatcher resolves it to the
# backend's declared measured-fastest mode (``register_backend(
# preferred_tb_mode=...)``) BEFORE the tb_modes validation, so callers get
# the per-backend winner without knowing the benchmark table. The
# declarations encode BENCH_pr.json on the platform it was recorded:
# prefix on ``ref`` runs at 0.14-0.39× serial (XLA already fuses the
# serial scan; the associative scan pays gather-composition for nothing on
# CPU), and the Pallas kernels' interpret lowering pays similarly for the
# composition phases. A backend flips its declaration to "prefix" the
# moment a committed bench measures it profitable there (the design case:
# real-TPU runs, where the serial walk is the dependency-chain bottleneck
# the chunked composition removes).


# ---------------------------------------------------------------------------
# The ACS-radix contract (DESIGN.md §10)
# ---------------------------------------------------------------------------
# ``acs_radix`` fixes how many trellis stages one forward-ACS step collapses.
# Both radixes are bit-exact for every input (the radix-4 step emits the two
# STANDARD radix-2 survivor bit-planes, and its compare/select tree
# reproduces the two-stage comparisons exactly — by integer associativity on
# the narrow pipeline, by a staged add order in f32), so the choice is a
# pure serial-chain/bandwidth trade:
#
# * ``2`` — the paper's butterfly: one stage per step, T serial steps.
# * ``4`` — stage-fused: ceil(T/2) steps of 4-way compare-select per state
#   over the collapsed two-stage trellis (4 predecessors, combined 2-symbol
#   labels with only 2^(2R-1) distinct folded metrics per step), one
#   normalization/survivor-emission round amortized over two decoded bits;
#   the fused backend additionally double-buffers the symbol reads
#   (HBM→VMEM prefetch of the next step's tile overlaps the current
#   butterfly). Odd T runs one trailing radix-2 step. Narrow metric modes
#   re-derive the normalization cadence for the doubled per-step
#   accumulation (``quantize.norm_interval(code, mode, radix)``) and reject
#   code/mode pairs whose budget cannot absorb two unnormalized stages —
#   eagerly, before any tracing.
ACS_RADIX: dict[int, dict[str, Any]] = {
    2: dict(
        serial_steps="T butterfly stages",
        metrics_per_step="2^(R-1) folded branch metrics",
        when="the default: tiny codes (K < 3), narrow modes whose budget "
        "cannot absorb two unnormalized stages, and the measured winner on "
        "the ref/CPU backend at small batch (BENCH_pr.json acs_radix_sweep)",
    ),
    4: dict(
        serial_steps="ceil(T/2) stage-fused steps (+1 radix-2 step, odd T)",
        metrics_per_step="2^(2R-1) folded combined two-stage metrics",
        when="the ACS-bound regime (98% of decode time post-PR 4) — halves "
        "the forward serial chain and amortizes normalization/emission "
        "over two bits; fused backend overlaps symbol HBM reads via a "
        "double-buffered VMEM pipeline",
    ),
}


# ---------------------------------------------------------------------------
# The ACS-implementation contract (DESIGN.md §11)
# ---------------------------------------------------------------------------
# ``acs_impl`` fixes the *formulation* of the forward-ACS step. Both are
# bit-exact for every input — the matrix path emits the STANDARD radix-2
# survivor bit-planes per collapsed stage (recovered from its compare
# tournament), so traceback, SP layout and golden vectors are untouched —
# and the choice is a pure compute-unit/arithmetic-intensity trade:
#
# * ``"butterfly"`` — the paper's compare-select butterflies (radix 2, or
#   the PR 5 stage-fused radix 4 under ``acs_radix``), element-wise VPU
#   work throughout.
# * ``"matrix"`` — the tensor-core formulation (arXiv:2011.13579): ``acs_k``
#   consecutive stages collapse into ONE (min,+) matrix-vector product
#   ``new_pm[n'] = min_n (A[n', n] + pm[n])``, ceil(T/acs_k) steps. The
#   k-stage matrix A is assembled from only 2^(kR-1) folded combined
#   metrics (the PR 3 antipodal fold composed over the stage window) — on
#   the Pallas path as ONE dense signed one-hot matmul shaped for the MXU
#   (``ConvCode.matrix_expansion``), with the min-tournament contraction
#   (and per-stage survivor-plane recovery) on the VPU. Integer
#   accumulators take the flat contraction (exact by associativity); f32
#   accumulators lower to the staged radix-2 sequence, because IEEE float
#   addition is not associative and the contract is bit-exactness, not
#   approximate parity. ``acs_k`` is validated eagerly: 1 ≤ k ≤ v,
#   k·R ≤ MATRIX_MAX_LABEL_BITS, and narrow metric modes must absorb k
#   unnormalized stages per step (``quantize.norm_interval(code, mode,
#   stages_per_step=k)`` — config-time rejection, never a silent saturate).
#   When ``acs_impl="matrix"``, ``acs_radix`` is inert and normalized out
#   of the jit cache key (and ``acs_k`` likewise under ``"butterfly"``).
ACS_IMPL: dict[str, dict[str, Any]] = {
    "butterfly": dict(
        serial_steps="T (radix 2) or ceil(T/2) (radix 4) compare-select steps",
        metrics_per_step="2^(R-1) or 2^(2R-1) folded branch metrics",
        when="the default: VPU-bound element-wise ACS, the paper's "
        "formulation, and the measured winner under XLA CPU SIMD",
    ),
    "matrix": dict(
        serial_steps="ceil(T/acs_k) tropical matmul steps "
        "(+ T mod acs_k trailing radix-2 stages)",
        metrics_per_step="2^(acs_k·R-1) folded combined metrics, assembled "
        "by one signed one-hot (2^k·N, 2^(kR-1)) MXU matmul",
        when="MXU-rich hardware where the k-fold shorter serial chain and "
        "the matmul-shaped metric assembly beat the VPU butterflies "
        "(BENCH_pr.json acs_impl_sweep)",
    ),
}


def knob_error(backend: str, knob: str, value: Any, allowed) -> ValueError:
    """The uniform eager knob-validation error.

    Both validation layers — the dispatcher (``pbvd_decode_blocks``) and the
    config (``PBVDConfig``) — raise exactly this shape, naming the backend,
    the offending knob and the allowed values, so a bad knob fails the same
    way no matter which door it came through, always before any jit trace.
    """
    return ValueError(
        f"backend {backend!r} does not support {knob}={value!r}; "
        f"supported {knob} values: {tuple(allowed)}"
    )


@dataclasses.dataclass(frozen=True)
class FramedBlocks:
    """The framed parallel-block batch every backend consumes.

    ``y``: (T, R, B) soft symbols (float32, or int8/int16 for the exact
    quantized path), framed [truncation M | decode D | traceback L].
    ``decode_start``/``n_decode``: the decode region within the T stages.
    ``frame_counts``: when the lane axis packs several frames (independent
    streams), the number of real blocks each frame contributed, in lane
    order; ``None`` means a single frame spanning every lane. Lanes beyond
    ``sum(frame_counts)`` are padding and must be trimmed by the backend.
    """

    y: Any  # jnp.ndarray (possibly a tracer)
    decode_start: int
    n_decode: int
    frame_counts: tuple[int, ...] | None = None

    def __post_init__(self):
        if self.frame_counts is not None:
            if any(k <= 0 for k in self.frame_counts):
                raise ValueError(
                    f"frame_counts must be positive, got {self.frame_counts}"
                )
            if sum(self.frame_counts) > self.y.shape[2]:
                raise ValueError(
                    f"frame_counts {self.frame_counts} sum to "
                    f"{sum(self.frame_counts)} > lane axis {self.y.shape[2]}"
                )

    @property
    def shape(self) -> tuple[int, int, int]:
        return tuple(self.y.shape)

    @property
    def n_frames(self) -> int:
        return 1 if self.frame_counts is None else len(self.frame_counts)

    @property
    def n_real_blocks(self) -> int:
        """Real (non-pad) lanes; what every backend must return."""
        if self.frame_counts is None:
            return int(self.y.shape[2])
        return sum(self.frame_counts)

    def frame_slices(self) -> list[slice]:
        """Lane-axis slice of each packed frame, in order."""
        if self.frame_counts is None:
            return [slice(0, int(self.y.shape[2]))]
        out, lo = [], 0
        for k in self.frame_counts:
            out.append(slice(lo, lo + k))
            lo += k
        return out


class DecodeBackend(Protocol):
    def __call__(
        self,
        blocks: FramedBlocks,
        code: Any,
        *,
        start_policy: str,
        stage_chunk: int,
        interpret: bool,
        metric_mode: str,
        tb_mode: str,
        tb_chunk: int,
        acs_radix: int,
        acs_impl: str,
        acs_k: int,
    ) -> Any: ...


_BACKENDS: dict[str, DecodeBackend] = {}


def register_backend(
    name: str,
    *,
    start_policies: tuple[str, ...] = ("zero", "argmin"),
    metric_modes: tuple[str, ...] = ("f32",),
    tb_modes: tuple[str, ...] = ("serial",),
    tb_chunk_sensitive: bool = True,
    preferred_tb_mode: str = "serial",
    acs_radix: tuple[int, ...] = (2,),
    acs_impl: tuple[str, ...] = ("butterfly",),
) -> Callable[[DecodeBackend], DecodeBackend]:
    """Decorator: register a decode backend under ``name``.

    ``start_policies`` declares which traceback start policies the backend
    implements; ``metric_modes`` declares which :data:`METRIC_MODES` entries
    it implements; ``tb_modes`` declares which :data:`TB_MODES` traceback
    algorithms it implements; ``acs_radix`` declares which :data:`ACS_RADIX`
    forward-ACS radixes it implements; ``acs_impl`` declares which
    :data:`ACS_IMPL` forward-pass formulations it implements. The dispatcher
    rejects others eagerly (pre-jit). The defaults are the conservative
    ``("f32",)``/``("serial",)``/``(2,)``/``("butterfly",)`` — a backend
    must OPT INTO the narrow pipeline, the prefix traceback, the
    stage-fused ACS and the (min,+) matrix ACS explicitly, otherwise the
    eager check would wave through modes it never implemented.

    ``preferred_tb_mode`` declares the backend's measured-fastest traceback
    mode — what ``tb_mode="auto"`` resolves to (must be in ``tb_modes``).

    ``tb_chunk_sensitive=False`` declares that the backend's prefix
    traceback ignores ``tb_chunk`` (e.g. a full-depth associative scan): the
    dispatcher then normalizes the knob out of the jit cache key, and the
    benchmarks collapse the chunk sweep dimension.
    """
    unknown = set(metric_modes) - METRIC_MODES.keys()
    if unknown:
        raise ValueError(f"unknown metric modes {sorted(unknown)}")
    unknown_tb = set(tb_modes) - TB_MODES.keys()
    if unknown_tb:
        raise ValueError(f"unknown tb modes {sorted(unknown_tb)}")
    unknown_radix = set(acs_radix) - ACS_RADIX.keys()
    if unknown_radix:
        raise ValueError(f"unknown acs radixes {sorted(unknown_radix)}")
    unknown_impl = set(acs_impl) - ACS_IMPL.keys()
    if unknown_impl:
        raise ValueError(f"unknown acs impls {sorted(unknown_impl)}")
    if preferred_tb_mode not in tb_modes:
        raise ValueError(
            f"preferred_tb_mode {preferred_tb_mode!r} not in tb_modes {tb_modes}"
        )

    def deco(fn: DecodeBackend) -> DecodeBackend:
        if name in _BACKENDS:
            raise ValueError(f"backend {name!r} already registered")
        _BACKENDS[name] = fn
        fn.backend_name = name  # type: ignore[attr-defined]
        fn.start_policies = tuple(start_policies)  # type: ignore[attr-defined]
        fn.metric_modes = tuple(metric_modes)  # type: ignore[attr-defined]
        fn.tb_modes = tuple(tb_modes)  # type: ignore[attr-defined]
        fn.tb_chunk_sensitive = bool(tb_chunk_sensitive)  # type: ignore[attr-defined]
        fn.preferred_tb_mode = str(preferred_tb_mode)  # type: ignore[attr-defined]
        fn.acs_radix = tuple(acs_radix)  # type: ignore[attr-defined]
        fn.acs_impl = tuple(acs_impl)  # type: ignore[attr-defined]
        return fn

    return deco


def get_backend(name: str) -> DecodeBackend:
    try:
        return _BACKENDS[name]
    except KeyError:
        raise KeyError(
            f"unknown decode backend {name!r}; available: {available_backends()}"
        ) from None


def backend_start_policies(name: str) -> tuple[str, ...]:
    """Start policies the named backend supports."""
    return getattr(get_backend(name), "start_policies", ("zero", "argmin"))


def backend_metric_modes(name: str) -> tuple[str, ...]:
    """Metric modes the named backend supports (see :data:`METRIC_MODES`)."""
    return getattr(get_backend(name), "metric_modes", ("f32",))


def backend_tb_modes(name: str) -> tuple[str, ...]:
    """Traceback modes the named backend supports (see :data:`TB_MODES`)."""
    return getattr(get_backend(name), "tb_modes", ("serial",))


def backend_tb_chunk_sensitive(name: str) -> bool:
    """Whether the named backend's prefix traceback depends on ``tb_chunk``."""
    return getattr(get_backend(name), "tb_chunk_sensitive", True)


def backend_acs_radix(name: str) -> tuple[int, ...]:
    """Forward-ACS radixes the named backend supports (see :data:`ACS_RADIX`)."""
    return getattr(get_backend(name), "acs_radix", (2,))


def backend_acs_impl(name: str) -> tuple[str, ...]:
    """Forward-ACS formulations the named backend supports (see :data:`ACS_IMPL`)."""
    return getattr(get_backend(name), "acs_impl", ("butterfly",))


def backend_preferred_tb_mode(name: str) -> str:
    """The named backend's declared measured-fastest traceback mode."""
    return getattr(get_backend(name), "preferred_tb_mode", "serial")


def resolve_tb_mode(name: str, tb_mode: str) -> str:
    """Resolve ``"auto"`` to the backend's preferred mode; pass others through.

    Eager (pre-jit): the resolved mode is what enters the tb_modes
    validation, the jit cache key and the SessionPool group key, so an
    ``"auto"`` session coalesces with sessions that spelled the mode out.
    """
    return backend_preferred_tb_mode(name) if tb_mode == "auto" else tb_mode


def available_backends() -> list[str]:
    return sorted(_BACKENDS)
