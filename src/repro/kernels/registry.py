"""Backend registry for the PBVD decode kernels.

Every backend is a function with the common contract

    backend(blocks: FramedBlocks, code: ConvCode, *,
            start_policy, stage_chunk, interpret) -> (n_decode, B) int32 bits

registered under a name via ``@register_backend("name")``. The engine (and
the legacy ``pbvd_decode_blocks`` wrapper) dispatch through :func:`get_backend`
— adding a backend is one decorated function, not another ``if`` branch in
the decode path (DESIGN.md §1).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Protocol

__all__ = [
    "FramedBlocks",
    "DecodeBackend",
    "register_backend",
    "get_backend",
    "available_backends",
]


@dataclasses.dataclass(frozen=True)
class FramedBlocks:
    """The framed parallel-block batch every backend consumes.

    ``y``: (T, R, B) soft symbols (float32, or int8/int16 for the exact
    quantized path), framed [truncation M | decode D | traceback L].
    ``decode_start``/``n_decode``: the decode region within the T stages.
    """

    y: Any  # jnp.ndarray (possibly a tracer)
    decode_start: int
    n_decode: int

    @property
    def shape(self) -> tuple[int, int, int]:
        return tuple(self.y.shape)


class DecodeBackend(Protocol):
    def __call__(
        self,
        blocks: FramedBlocks,
        code: Any,
        *,
        start_policy: str,
        stage_chunk: int,
        interpret: bool,
    ) -> Any: ...


_BACKENDS: dict[str, DecodeBackend] = {}


def register_backend(name: str) -> Callable[[DecodeBackend], DecodeBackend]:
    """Decorator: register a decode backend under ``name``."""

    def deco(fn: DecodeBackend) -> DecodeBackend:
        if name in _BACKENDS:
            raise ValueError(f"backend {name!r} already registered")
        _BACKENDS[name] = fn
        fn.backend_name = name  # type: ignore[attr-defined]
        return fn

    return deco


def get_backend(name: str) -> DecodeBackend:
    try:
        return _BACKENDS[name]
    except KeyError:
        raise KeyError(
            f"unknown decode backend {name!r}; available: {available_backends()}"
        ) from None


def available_backends() -> list[str]:
    return sorted(_BACKENDS)
