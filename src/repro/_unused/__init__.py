"""Quarantined seed LM stack — not part of the decoder surface.

The growth seed shipped a full transformer serving/training stack
(``models/``, ``train/``, ``serve/``, the flash-attention kernel) that
nothing on the PBVD decode path imports; only the seed's LM smoke tests
and the ``launch/{train,serve,dryrun,specs}`` LM drivers exercise it.
It lives under ``_unused/`` — alongside :mod:`repro.configs._unused` —
so coverage gates, refactors, and the packaging surface track only the
decoder (ROADMAP item 4). Everything still imports and its tests still
run; the quarantine is a boundary marker, not a deletion.
"""
