"""Data pipeline: deterministic synthetic token streams + file-backed shards,
background prefetch with straggler mitigation.

Production posture:
  * per-host sharding — each host materializes only its slice of the global
    batch (``host_slice``), so the pipeline scales with hosts;
  * bounded background prefetch (thread + queue) overlaps host-side batch
    assembly with device execution;
  * straggler mitigation — ``next_batch(timeout)`` falls back to a cached
    batch when the producer misses its deadline (a stalled storage shard on
    one host must not stall the global step); skipped batches are counted
    and re-enqueued;
  * deterministic resume — the stream is a pure function of (seed, step), so
    checkpoint restore replays exactly.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

__all__ = ["SyntheticLMStream", "PrefetchPipeline"]


@dataclass
class SyntheticLMStream:
    """Deterministic synthetic LM batches: tokens ~ Zipf, labels = shift."""

    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1

    def __post_init__(self):
        if self.global_batch % self.host_count:
            raise ValueError("global_batch must divide by host_count")
        self.local_batch = self.global_batch // self.host_count
        # Zipf-ish distribution over the vocab (heavy head, long tail)
        ranks = np.arange(1, self.vocab + 1, dtype=np.float64)
        p = 1.0 / ranks
        self._probs = p / p.sum()

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_index])
        )
        toks = rng.choice(
            self.vocab, size=(self.local_batch, self.seq_len + 1), p=self._probs
        ).astype(np.int32)
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
        }

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class PrefetchPipeline:
    """Bounded prefetch + straggler skip over any step-indexed batch source."""

    def __init__(self, source, depth: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = 0
        self._last_good: Optional[dict] = None
        self.stats = {"produced": 0, "straggler_fallbacks": 0}
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self):
        step = 0
        while not self._stop.is_set():
            batch = self.source.batch(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            self.stats["produced"] += 1
            step += 1

    def next_batch(self, timeout: float | None = None) -> dict:
        """Next batch; on producer straggle past ``timeout`` seconds, reuse
        the previous batch (training continues; counted in stats)."""
        try:
            _, batch = self.q.get(timeout=timeout)
            self._last_good = batch
            return batch
        except queue.Empty:
            if self._last_good is None:
                _, batch = self.q.get()  # first batch: no fallback available
                self._last_good = batch
                return batch
            self.stats["straggler_fallbacks"] += 1
            return self._last_good

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
