"""Training step: causal-LM loss, grads, AdamW update — pjit-ready.

The step is a single jittable function over (params, opt_state, batch); all
distribution comes from the logical-axis shardings of its inputs/outputs
(FSDP over `data`, TP over `model`, DP over `pod`) plus the activation
constraints inside the model. Optional int8 gradient compression with error
feedback is applied on the cross-pod axis (see train/compression.py).
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro._unused.models import lm
from repro.sharding.rules import shard
from .optimizer import AdamWConfig, OptState, adamw_update

__all__ = ["loss_fn", "make_train_step", "TrainState"]


def loss_fn(params, batch: dict, cfg: ModelConfig):
    """Next-token cross-entropy over the (padded) vocab, mean over tokens."""
    logits = lm.apply_train(params, batch, cfg)  # (B,S,Vp) f32, sharded on vocab
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    nll = lse - ll
    if mask is not None:
        nll = nll * mask
        denom = jnp.maximum(mask.sum(), 1.0)
    else:
        denom = float(nll.size)
    return nll.sum() / denom


def cast_params_for_compute(params, cfg: ModelConfig):
    """Mixed precision: cast f32 master weights to the compute dtype at the
    top of the step, so every FSDP all-gather moves bf16 (2×) instead of f32.
    The cast is differentiable — grads flow back to the f32 masters."""
    cd = jnp.dtype(cfg.compute_dtype)
    if cd == jnp.float32:
        return params
    return jax.tree.map(
        lambda p: p.astype(cd) if (p.dtype == jnp.float32 and p.ndim >= 2) else p, params
    )


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    *,
    compress_grads: bool = False,
    bf16_gather: bool = True,
):
    """Builds the jittable train step (params, opt_state, batch) → (..., metrics)."""

    def step(params, opt_state: OptState, batch: dict):
        def cast_loss(p):
            pc = cast_params_for_compute(p, cfg) if bf16_gather else p
            return loss_fn(pc, batch, cfg)

        loss, grads = jax.value_and_grad(cast_loss)(params)
        if compress_grads:
            from .compression import compress_decompress_tree

            grads = compress_decompress_tree(grads)
        new_params, new_opt, metrics = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = dict(metrics, loss=loss)
        return new_params, new_opt, metrics

    return step
