"""AdamW + cosine schedule, pure JAX (no optax dependency).

Optimizer state mirrors the parameter pytree (m, v per leaf) and inherits
the parameter shardings — with FSDP rules the full state is sharded over
the `data` axis (ZeRO-style).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["AdamWConfig", "OptState", "adamw_init", "adamw_update", "cosine_schedule"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    state_dtype: str = "float32"


class OptState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def cosine_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def adamw_init(params, cfg: AdamWConfig) -> OptState:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree))
    )


def adamw_update(params, grads, state: OptState, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    lr = cosine_schedule(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) if cfg.grad_clip else 1.0

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v_new = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m_new / b1c
        vhat = v_new / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
