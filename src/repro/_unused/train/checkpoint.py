"""Fault-tolerant sharded checkpointing (no orbax dependency).

Design (multi-thousand-node posture):
  * atomic: writes go to ``step_N.tmp/`` and are renamed only after fsync —
    a crash mid-save never corrupts the latest checkpoint;
  * sharded: every pytree leaf is saved as its own ``.npy`` (in a real
    multi-host deployment each host writes only its addressable shards; the
    manifest records the global shape + sharding spec so restore can
    re-shard onto a different mesh — see launch/elastic.py);
  * keep-N rotation + ``latest`` pointer file;
  * async: ``save_async`` hands the host copy to a writer thread so the
    train loop only blocks for the device→host transfer.

Restore is crash-tolerant: a missing/partial tmp dir is ignored, restore
reads the newest complete manifest.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path)
        out.append((key, leaf))
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    # ---- save -----------------------------------------------------------------------
    def save(self, step: int, tree: Any, *, blocking: bool = True) -> None:
        host_tree = jax.tree.map(lambda l: np.asarray(l), tree)
        if blocking:
            self._write(step, host_tree)
        else:
            self.wait()
            self._thread = threading.Thread(target=self._write_safe, args=(step, host_tree))
            self._thread.start()

    def save_async(self, step: int, tree: Any) -> None:
        self.save(step, tree, blocking=False)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write_safe(self, step: int, host_tree) -> None:
        try:
            self._write(step, host_tree)
        except Exception as e:  # noqa: BLE001
            self._error = e

    def _write(self, step: int, host_tree) -> None:
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        leaves, _ = _flatten_with_paths(host_tree)
        manifest = {"step": step, "time": time.time(), "leaves": []}
        for i, (key, leaf) in enumerate(leaves):
            arr = np.asarray(leaf)
            fname = f"leaf_{i:05d}.npy"
            np.save(tmp / fname, arr)
            manifest["leaves"].append(
                {"key": key, "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
            )
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        (self.dir / "latest").write_text(str(step))
        self._rotate()

    def _rotate(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ---- restore ---------------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int | None, like: Any, *, shardings: Any | None = None) -> Any:
        """Restore into the structure of ``like`` (values replaced). With
        ``shardings`` the arrays are placed sharded (device_put per leaf)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        arrays = [np.load(d / leaf["file"]) for leaf in manifest["leaves"]]
        flat_like, treedef = jax.tree.flatten(like)
        if len(arrays) != len(flat_like):
            raise ValueError(
                f"checkpoint has {len(arrays)} leaves, expected {len(flat_like)}"
            )
        if shardings is not None:
            flat_sh = jax.tree.leaves(shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))
            arrays = [jax.device_put(a, s) for a, s in zip(arrays, flat_sh)]
        else:
            arrays = [jnp.asarray(a) for a in arrays]
        return jax.tree.unflatten(treedef, arrays)
