"""Gradient compression with error feedback (cross-pod DCN optimization).

int8 block-quantized gradients cut the cross-pod all-reduce volume 4×
(f32→int8). Error feedback keeps the optimizer unbiased: the quantization
residual is added back into the next step's gradient (Seide et al., 2014;
Karimireddy et al., 2019 — EF-SGD converges at the uncompressed rate).

Under pjit the quantize→dequantize pair wraps the gradient BEFORE the
implicit cross-pod psum, so XLA moves the 4×-smaller representation over
the DCN axis. ``compress_decompress_tree`` is the simulation-friendly
entry point (numerics identical to the wire version).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "compress_decompress_tree", "ErrorFeedbackState", "ef_compress"]

BLOCK = 256  # quantization block (last-dim groups share a scale)


def quantize_int8(g: jnp.ndarray):
    """Per-block symmetric int8 quantization. Returns (q, scales)."""
    flat = g.reshape(-1)
    pad = (-flat.size) % BLOCK
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    blocks = flat.reshape(-1, BLOCK).astype(jnp.float32)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, shape, dtype):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def _roundtrip(g: jnp.ndarray) -> jnp.ndarray:
    if g.size < BLOCK:  # tiny leaves (norm scales): not worth compressing
        return g
    q, s = quantize_int8(g)
    return dequantize_int8(q, s, g.shape, g.dtype)


def compress_decompress_tree(grads: Any) -> Any:
    """Quantize→dequantize every gradient leaf (wire-format simulation)."""
    return jax.tree.map(_roundtrip, grads)


class ErrorFeedbackState(NamedTuple):
    residual: Any  # same structure as grads


def ef_init(grads_like: Any) -> ErrorFeedbackState:
    return ErrorFeedbackState(
        residual=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
    )


def ef_compress(grads: Any, state: ErrorFeedbackState):
    """Error-feedback compression: g' = Q(g + r); r' = (g + r) - g'."""

    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q = _roundtrip(corrected)
        return q.astype(g.dtype), corrected - q

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(state.residual)
    pairs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = jax.tree.unflatten(treedef, [p[0] for p in pairs])
    new_r = jax.tree.unflatten(treedef, [p[1] for p in pairs])
    return new_g, ErrorFeedbackState(residual=new_r)
