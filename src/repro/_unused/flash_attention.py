"""Flash attention Pallas TPU kernel (GQA, causal, sliding-window).

The §Roofline analysis found the dominant memory term of every train/prefill
cell is the (B·H·S·S_kv) score traffic that pure-XLA streaming attention
materializes between fusions (e.g. ~80 TB/device/step for
deepseek-v2 × train_4k). This kernel keeps the running-softmax state and the
score block in VMEM — HBM traffic drops to Q/K/V/O only, O(B·S·d).

Layout: grid (batch·kv_head, q_chunks); the kernel loops KV chunks with an
online softmax carried in VMEM scratch. Causal/windowed blocks outside the
band are skipped via `pl.when` on block indices (removing the 2× causal
FLOP waste of the masked-full-scan XLA path). Group dim (q heads per kv
head) rides inside the block.

Validated bit-level against `ref_mha` (and against the model's XLA streaming
path) in interpret mode — `tests/test_flash_attention.py` sweeps shapes,
dtypes, GQA ratios, causal/window.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention", "ref_mha"]

NEG_INF = -1e30


def ref_mha(q, k, v, *, causal=True, window=None, scale=None):
    """Oracle: q (B,S,Hkv,G,dh), k/v (B,T,Hkv,dh) → (B,S,Hkv,G,dh), f32 math."""
    B, S, Hkv, G, dh = q.shape
    T = k.shape[1]
    scale = scale or 1.0 / math.sqrt(dh)
    s = jnp.einsum("bshgd,bthd->bhgst", q.astype(jnp.float32) * scale, k.astype(jnp.float32))
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgst,bthd->bshgd", p, v.astype(jnp.float32))


def _flash_kernel(
    q_ref,  # (1, Cq, G, dh)
    k_ref,  # (1, T, dh)
    v_ref,  # (1, T, dh)
    o_ref,  # (1, Cq, G, dh)
    m_scr,  # VMEM (Cq, G) f32
    l_scr,  # VMEM (Cq, G) f32
    acc_scr,  # VMEM (Cq, G, dh) f32
    *,
    kv_chunk: int,
    causal: bool,
    window: int | None,
    scale: float,
    seq_q: int,
    seq_kv: int,
):
    qi = pl.program_id(1)
    Cq, G, dh = q_ref.shape[1:]
    n_kv = seq_kv // kv_chunk

    m_scr[...] = jnp.full_like(m_scr, NEG_INF)
    l_scr[...] = jnp.zeros_like(l_scr)
    acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale  # (Cq, G, dh)
    q_start = qi * Cq

    def kv_body(ki, _):
        k_start = ki * kv_chunk
        k_blk = k_ref[0, pl.ds(k_start, kv_chunk)].astype(jnp.float32)  # (Ck, dh)
        v_blk = v_ref[0, pl.ds(k_start, kv_chunk)].astype(jnp.float32)

        s = jnp.einsum("qgd,kd->qgk", q, k_blk)  # (Cq, G, Ck)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (Cq, G, kv_chunk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (Cq, G, kv_chunk), 2)
        mask = kpos < seq_kv
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev, l_prev, acc_prev = m_scr[...], l_scr[...], acc_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(-1)
        acc_new = acc_prev * alpha[..., None] + jnp.einsum("qgk,kd->qgd", p, v_blk)
        m_scr[...] = m_new
        l_scr[...] = l_new
        acc_scr[...] = acc_new
        return ()

    if causal and window is None:
        # only blocks up to the diagonal participate (no wasted FLOPs)
        last = jax.lax.div(q_start + Cq - 1, kv_chunk) + 1
        jax.lax.fori_loop(0, jnp.minimum(last, n_kv), kv_body, ())
    elif window is not None:
        first = jnp.maximum(jax.lax.div(q_start - (window or 0), kv_chunk), 0)
        last = jax.lax.div(q_start + Cq - 1, kv_chunk) + 1 if causal else n_kv
        jax.lax.fori_loop(first, jnp.minimum(last, n_kv), kv_body, ())
    else:
        jax.lax.fori_loop(0, n_kv, kv_body, ())

    out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[..., None]
    o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "q_chunk", "kv_chunk", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,  # (B, S, Hkv, G, dh)
    k: jnp.ndarray,  # (B, T, Hkv, dh)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    q_chunk: int = 256,
    kv_chunk: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    B, S, Hkv, G, dh = q.shape
    T = k.shape[1]
    scale = scale or 1.0 / math.sqrt(dh)
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, T)
    if S % q_chunk or T % kv_chunk:
        raise ValueError(f"S={S} % {q_chunk} or T={T} % {kv_chunk} != 0")

    # fold (B, Hkv) into the grid's first axis
    qf = q.transpose(0, 2, 1, 3, 4).reshape(B * Hkv, S, G, dh)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, T, dh)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, T, dh)

    kernel = functools.partial(
        _flash_kernel,
        kv_chunk=kv_chunk,
        causal=causal,
        window=window,
        scale=scale,
        seq_q=S,
        seq_kv=T,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B * Hkv, S // q_chunk),
        in_specs=[
            pl.BlockSpec((1, q_chunk, G, dh), lambda bh, qi: (bh, qi, 0, 0)),
            pl.BlockSpec((1, T, dh), lambda bh, qi: (bh, 0, 0)),
            pl.BlockSpec((1, T, dh), lambda bh, qi: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_chunk, G, dh), lambda bh, qi: (bh, qi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hkv, S, G, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_chunk, G), jnp.float32),
            pltpu.VMEM((q_chunk, G), jnp.float32),
            pltpu.VMEM((q_chunk, G, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, Hkv, S, G, dh).transpose(0, 2, 1, 3, 4)
