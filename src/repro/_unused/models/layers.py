"""Foundational layers: params-as-pytrees, norms, embeddings, RoPE, FFNs.

No framework dependency: a "module" is an ``init_*`` function returning a
dict-of-arrays pytree plus a parallel ``axes_*`` function returning the same
structure with logical-axis tuples (consumed by ``repro.sharding.rules``).
``tests/test_models_smoke.py`` asserts the two structures stay in sync.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.sharding.rules import shard

__all__ = [
    "dense_init", "dense_axes", "dense_apply",
    "norm_init", "norm_axes", "norm_apply",
    "embed_init", "embed_axes",
    "rope_sin_cos", "apply_rope",
    "ffn_init", "ffn_axes", "ffn_apply",
    "cdtype",
]


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ---- dense / linear -----------------------------------------------------------
def dense_init(key, in_dim: int, out_dims: Sequence[int], cfg, *, bias=False, scale=None):
    out = int(np.prod(out_dims))
    if scale is None:
        scale = 1.0 / np.sqrt(in_dim)
    w = jax.random.normal(key, (in_dim, *out_dims), dtype=pdtype(cfg)) * scale
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros(tuple(out_dims), dtype=pdtype(cfg))
    return p


def dense_axes(in_axis, out_axes, *, bias=False):
    a = {"w": (in_axis, *out_axes)}
    if bias:
        a["b"] = tuple(out_axes)
    return a


def dense_apply(p, x, cfg, *, contract: str = "...d,dh->...h"):
    w = p["w"].astype(cdtype(cfg))
    y = jnp.einsum(contract, x, w)
    if "b" in p:
        y = y + p["b"].astype(cdtype(cfg))
    return y


# ---- norms ---------------------------------------------------------------------
def norm_init(dim: int, cfg):
    p = {"scale": jnp.ones((dim,), dtype=pdtype(cfg))}
    if cfg.norm_type == "layernorm":
        p["bias"] = jnp.zeros((dim,), dtype=pdtype(cfg))
    return p


def norm_axes(cfg):
    a = {"scale": ("embed",)}
    if cfg.norm_type == "layernorm":
        a["bias"] = ("embed",)
    return a


def norm_apply(p, x, cfg):
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---- embeddings ------------------------------------------------------------------
def round_vocab(vocab: int, multiple: int = 256) -> int:
    return -(-vocab // multiple) * multiple


def embed_init(key, cfg):
    v = round_vocab(cfg.vocab)
    return {"table": jax.random.normal(key, (v, cfg.d_model), dtype=pdtype(cfg)) * 0.02}


def embed_axes():
    return {"table": ("vocab", "fsdp")}


# ---- rotary position embeddings ---------------------------------------------------
def rope_sin_cos(positions: jnp.ndarray, dim: int, theta: float):
    """positions (...,) int → sin, cos (..., dim/2) f32."""
    inv = 1.0 / (theta ** (np.arange(0, dim, 2, dtype=np.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv[None, :]
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jnp.ndarray, sin: jnp.ndarray, cos: jnp.ndarray):
    """x (..., S, H, dh) with sin/cos (..., S, dh/2) — rotate-half convention."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    s, c = sin[..., None, :], cos[..., None, :]  # broadcast over heads
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


# ---- feed-forward variants ---------------------------------------------------------
def ffn_init(key, cfg: ModelConfig, d_ff: int):
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    if cfg.ffn_act == "swiglu":
        return {
            "wi": dense_init(ks[0], d, (d_ff,), cfg),
            "wg": dense_init(ks[1], d, (d_ff,), cfg),
            "wo": dense_init(ks[2], d_ff, (d,), cfg),
        }
    if cfg.ffn_act == "rwkv_cm":  # RWKV channel mix
        return {
            "mu": 0.5 * jnp.ones((2, d), dtype=pdtype(cfg)),  # token-shift mix (k, r)
            "wk": dense_init(ks[0], d, (d_ff,), cfg),
            "wv": dense_init(ks[1], d_ff, (d,), cfg),
            "wr": dense_init(ks[2], d, (d,), cfg),
        }
    # relu2 / gelu: ungated
    return {
        "wi": dense_init(ks[0], d, (d_ff,), cfg),
        "wo": dense_init(ks[2], d_ff, (d,), cfg),
    }


def ffn_axes(cfg: ModelConfig):
    if cfg.ffn_act == "swiglu":
        return {
            "wi": dense_axes("fsdp", ("mlp",)),
            "wg": dense_axes("fsdp", ("mlp",)),
            "wo": dense_axes("mlp", ("fsdp",)),
        }
    if cfg.ffn_act == "rwkv_cm":
        return {
            "mu": (None, "embed"),
            "wk": dense_axes("fsdp", ("mlp",)),
            "wv": dense_axes("mlp", ("fsdp",)),
            "wr": dense_axes("fsdp", ("embed",)),
        }
    return {"wi": dense_axes("fsdp", ("mlp",)), "wo": dense_axes("mlp", ("fsdp",))}


def ffn_apply(p, x, cfg: ModelConfig, *, x_prev=None):
    """x (B, S, d) → (B, S, d). ``x_prev`` is the token-shifted input used by
    the RWKV channel mix (ignored by other variants)."""
    if cfg.ffn_act == "swiglu":
        h = jax.nn.silu(dense_apply(p["wg"], x, cfg)) * dense_apply(p["wi"], x, cfg)
        h = shard(h, ("batch", None, "mlp"))
        return dense_apply(p["wo"], h, cfg)
    if cfg.ffn_act == "rwkv_cm":
        xp = x if x_prev is None else x_prev
        mu = p["mu"].astype(x.dtype)
        xk = x * mu[0] + xp * (1 - mu[0])
        xr = x * mu[1] + xp * (1 - mu[1])
        k = jnp.square(jax.nn.relu(dense_apply(p["wk"], xk, cfg)))
        k = shard(k, ("batch", None, "mlp"))
        v = dense_apply(p["wv"], k, cfg)
        r = jax.nn.sigmoid(dense_apply(p["wr"], xr, cfg))
        return r * v
    h = dense_apply(p["wi"], x, cfg)
    if cfg.ffn_act == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    h = shard(h, ("batch", None, "mlp"))
    return dense_apply(p["wo"], h, cfg)
