"""Mixture-of-Experts FFN with sort-based (fake-FLOP-free) dispatch.

The classic GShard dense-dispatch einsum costs O(tokens · E · capacity · d)
matmul FLOPs just to *move* tokens — for DeepSeek-V2's 160 experts that is
an order of magnitude more compute than the experts themselves. We instead
route with sort + static-capacity scatter/gather (MegaBlocks-style, adapted
to XLA's static shapes):

  1. top-k per token → (expert_id, weight) pairs, flattened to S·k entries;
  2. entries sorted by expert id (XLA row-wise sort — batch rows stay local
     to their data shard, so the sort never crosses devices);
  3. rank-in-expert = position − start-of-expert (via per-row searchsorted);
     entries with rank ≥ capacity are dropped (capacity_factor bounds skew);
  4. scatter token vectors into an (E, C, d) buffer → batched expert SwiGLU
     einsum → gather back with routing weights.

Expert weights are sharded expert-hidden over the `model` axis (always
divisible, unlike E itself) and FSDP over `data`; token buffers stay
data-sharded end to end. Shared experts (DeepSeek) run as a dense FFN.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.sharding.rules import shard
from repro.sharding.smap import shard_map as smap_shard_map
from .layers import cdtype, dense_init, pdtype

__all__ = ["moe_init", "moe_axes", "moe_apply", "moe_capacity"]


def moe_capacity(cfg: ModelConfig, seq_len: int) -> int:
    """Static per-expert capacity for one routing group (= one sequence)."""
    c = int(np.ceil(cfg.capacity_factor * seq_len * cfg.top_k / cfg.n_experts))
    return min(max(c, cfg.top_k), seq_len)


def moe_init(key, cfg: ModelConfig):
    d, E, f = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    ks = jax.random.split(key, 5)
    scale = 1.0 / np.sqrt(d)
    p = {
        "router": {"w": jax.random.normal(ks[0], (d, E), dtype=jnp.float32) * scale},
        "wi": jax.random.normal(ks[1], (E, d, f), dtype=pdtype(cfg)) * scale,
        "wg": jax.random.normal(ks[2], (E, d, f), dtype=pdtype(cfg)) * scale,
        "wo": jax.random.normal(ks[3], (E, f, d), dtype=pdtype(cfg)) * (1.0 / np.sqrt(f)),
    }
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * f
        p["shared"] = {
            "wi": dense_init(ks[4], d, (fs,), cfg),
            "wg": dense_init(ks[4], d, (fs,), cfg),
            "wo": dense_init(ks[4], fs, (d,), cfg),
        }
    return p


def moe_axes(cfg: ModelConfig):
    a = {
        "router": {"w": ("fsdp", None)},
        "wi": ("experts", "fsdp", "expert_mlp"),
        "wg": ("experts", "fsdp", "expert_mlp"),
        "wo": ("experts", "expert_mlp", "fsdp"),
    }
    if cfg.n_shared_experts:
        a["shared"] = {
            "wi": {"w": ("fsdp", "mlp")},
            "wg": {"w": ("fsdp", "mlp")},
            "wo": {"w": ("mlp", "fsdp")},
        }
    return a


def _ep_enabled(cfg: ModelConfig) -> str | None:
    """Returns the mesh axis for expert parallelism if usable, else None."""
    from repro.sharding.rules import current_rules

    r = current_rules()
    if r is None:
        return None
    ax = r.rules.get("experts")
    if isinstance(ax, tuple):
        ax = ax[0] if ax else None
    if ax is None or ax not in r.mesh.axis_names:
        return None
    if cfg.n_experts % r.mesh.shape[ax] != 0:
        return None
    return ax


def moe_apply(p, x: jnp.ndarray, cfg: ModelConfig):
    """Dispatch to the shard_map EP path when experts divide the `model`
    axis (deepseek 160, jamba 16 on a 16-way axis); otherwise the pjit
    dense path (hidden-dim TP — mixtral's 8 experts)."""
    ep_axis = _ep_enabled(cfg)
    if ep_axis is not None:
        return _moe_apply_ep(p, x, cfg, ep_axis)
    return _moe_apply_dense(p, x, cfg)


def _moe_apply_dense(p, x: jnp.ndarray, cfg: ModelConfig):
    """x (B, S, d) → (B, S, d). Routing groups = batch rows (data-local)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = moe_capacity(cfg, S)
    dtype = cdtype(cfg)

    # ---- routing -----------------------------------------------------------------
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"]["w"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, ids = jax.lax.top_k(probs, k)  # (B,S,k)
    if cfg.renorm_topk:
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # ---- sort entries by expert (per batch row) ------------------------------------
    ids_f = ids.reshape(B, S * k)
    tok_f = jnp.broadcast_to(jnp.arange(S)[None, :, None], (B, S, k)).reshape(B, S * k)
    gate_f = gate.reshape(B, S * k)
    order = jnp.argsort(ids_f, axis=-1)  # stable
    ids_s = jnp.take_along_axis(ids_f, order, axis=-1)
    tok_s = jnp.take_along_axis(tok_f, order, axis=-1)
    gate_s = jnp.take_along_axis(gate_f, order, axis=-1)

    # rank within expert = position − first-occurrence(expert)
    starts = jax.vmap(lambda row: jnp.searchsorted(row, jnp.arange(E), side="left"))(ids_s)
    rank = jnp.arange(S * k)[None, :] - jnp.take_along_axis(starts, ids_s, axis=-1)
    keep = rank < C
    dest = jnp.where(keep, ids_s * C + rank, E * C)  # drop → overflow slot

    # ---- dispatch: scatter tokens into (B, E·C+1, d) --------------------------------
    xt = jnp.take_along_axis(x, tok_s[..., None], axis=1)  # (B, S·k, d)
    buf = jnp.zeros((B, E * C + 1, d), dtype)
    buf = buf.at[jnp.arange(B)[:, None], dest].set(xt.astype(dtype), mode="drop")
    buf = buf[:, : E * C].reshape(B, E, C, d)
    buf = shard(buf, ("batch", "experts", None, None))

    # ---- expert computation (SwiGLU), hidden dim tensor-parallel --------------------
    wi, wg, wo = (p[n].astype(dtype) for n in ("wi", "wg", "wo"))
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, wg)) * jnp.einsum("becd,edf->becf", buf, wi)
    h = shard(h, ("batch", "experts", None, "expert_mlp"))
    y = jnp.einsum("becf,efd->becd", h, wo)  # (B,E,C,d)
    y = shard(y, ("batch", "experts", None, None))

    # ---- combine: gather back and weight ---------------------------------------------
    y_flat = jnp.concatenate([y.reshape(B, E * C, d), jnp.zeros((B, 1, d), dtype)], axis=1)
    out_e = y_flat[jnp.arange(B)[:, None], dest]  # (B, S·k, d); dropped → 0
    out_e = out_e * gate_s[..., None].astype(dtype)
    # scatter-add back to token positions
    out = jnp.zeros((B, S, d), dtype)
    out = out.at[jnp.arange(B)[:, None], tok_s].add(out_e)

    # ---- shared experts (dense path) ---------------------------------------------------
    out = _add_shared(p, x, out, cfg)
    return out.astype(x.dtype)


def _add_shared(p, x, out, cfg):
    if "shared" in p:
        dtype = cdtype(cfg)
        sh = p["shared"]
        hsh = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, sh["wg"]["w"].astype(dtype)))
        hsh = hsh * jnp.einsum("bsd,df->bsf", x, sh["wi"]["w"].astype(dtype))
        hsh = shard(hsh, ("batch", None, "mlp"))
        out = out + jnp.einsum("bsf,fd->bsd", hsh, sh["wo"]["w"].astype(dtype))
    return out


def _moe_apply_ep(p, x: jnp.ndarray, cfg: ModelConfig, ep_axis: str):
    """Expert-parallel MoE via shard_map (the beyond-paper §Perf optimization).

    Experts stay sharded over ``ep_axis`` for their whole life — no FSDP
    all-gather of inactive expert weights (the dominant collective cost of
    FSDP-MoE: DeepSeek-V2 would otherwise gather 236B params/pass when only
    21B are active). Activations are already replicated across `model`
    inside a data shard, so dispatch is purely local:

      each model-shard computes the routed contribution of ITS E/ep experts
      over the local tokens → one psum over `model` combines.

    Collective cost per MoE layer: one (B_loc·S·d) psum — independent of E.
    Expert weights are FSDP-sharded on d and gathered bf16 per layer
    (E/ep-th of the naive FSDP gather).
    """
    from repro.sharding.rules import current_rules

    rules = current_rules()
    mesh = rules.mesh
    fsdp_ax = rules.rules.get("fsdp")
    if isinstance(fsdp_ax, tuple):
        fsdp_ax = fsdp_ax[0] if fsdp_ax else None

    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    dtype = cdtype(cfg)
    P = jax.sharding.PartitionSpec

    x_spec = rules.spec(("batch", None, None), shape=x.shape)
    wi_spec = P(ep_axis, fsdp_ax, None)
    wo_spec = P(ep_axis, None, fsdp_ax)

    def body(xl, rw, wi, wg, wo):
        # xl (B_loc, S, d) — identical on every ep shard; w* (E_loc, ·, ·)
        E_loc = wi.shape[0]
        m_idx = jax.lax.axis_index(ep_axis)
        if fsdp_ax is not None:
            wi = jax.lax.all_gather(wi.astype(dtype), fsdp_ax, axis=1, tiled=True)
            wg = jax.lax.all_gather(wg.astype(dtype), fsdp_ax, axis=1, tiled=True)
            wo = jax.lax.all_gather(wo.astype(dtype), fsdp_ax, axis=2, tiled=True)
        else:
            wi, wg, wo = wi.astype(dtype), wg.astype(dtype), wo.astype(dtype)

        Bl, Sl, _ = xl.shape
        T = Bl * Sl
        C = int(np.ceil(cfg.capacity_factor * T * k / E))
        C = max(min(C, T), 1)

        logits = jnp.einsum("bsd,de->bse", xl.astype(jnp.float32), rw)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, ids = jax.lax.top_k(probs, k)
        if cfg.renorm_topk:
            gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

        xt = xl.reshape(T, d)
        ids_f = ids.reshape(T * k)
        tok_f = jnp.repeat(jnp.arange(T), k)
        gate_f = gate.reshape(T * k)
        order = jnp.argsort(ids_f)
        ids_s, tok_s, gate_s = ids_f[order], tok_f[order], gate_f[order]
        starts = jnp.searchsorted(ids_s, jnp.arange(E), side="left")
        rank = jnp.arange(T * k) - starts[ids_s]
        keep = rank < C
        # slots of THIS shard's experts only
        dest = ids_s * C + rank - m_idx * E_loc * C
        valid = keep & (dest >= 0) & (dest < E_loc * C)
        dest = jnp.where(valid, dest, E_loc * C)

        buf = jnp.zeros((E_loc * C + 1, d), dtype).at[dest].set(
            xt[tok_s].astype(dtype), mode="drop"
        )
        buf = buf[: E_loc * C].reshape(E_loc, C, d)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) * jnp.einsum(
            "ecd,edf->ecf", buf, wi
        )
        y = jnp.einsum("ecf,efd->ecd", h, wo).reshape(E_loc * C, d)
        y = jnp.concatenate([y, jnp.zeros((1, d), dtype)], axis=0)
        contrib = y[dest] * (gate_s * valid)[:, None].astype(dtype)
        out = jnp.zeros((T, d), dtype).at[tok_s].add(contrib)
        out = jax.lax.psum(out, ep_axis)
        return out.reshape(Bl, Sl, d)

    routed = smap_shard_map(
        body,
        mesh=mesh,
        in_specs=(x_spec, P(), wi_spec, wi_spec, wo_spec),
        out_specs=x_spec,
    )(x, p["router"]["w"], p["wi"], p["wg"], p["wo"])

    routed = _add_shared(p, x, routed, cfg)
    return routed.astype(x.dtype)
