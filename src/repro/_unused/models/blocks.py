"""Block assembly: (norm → mixer → residual → norm → FFN → residual) for every
mixer/FFN combination in the architecture pool, plus per-block decode caches.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LayerDesc, ModelConfig
from .attention import KVCache, gqa_apply, gqa_axes, gqa_init, mla_apply, mla_axes, mla_init
from .layers import ffn_apply, ffn_axes, ffn_init, norm_apply, norm_axes, norm_init
from .moe import moe_apply, moe_axes, moe_init
from .ssm import MambaCache, RwkvCache, mamba_apply, mamba_axes, mamba_init, rwkv_apply, rwkv_axes, rwkv_init

__all__ = ["block_init", "block_axes", "block_apply", "block_cache_init"]

_MIXER_INIT = {"gqa": gqa_init, "mla": mla_init, "mamba": mamba_init, "rwkv6": rwkv_init}
_MIXER_AXES = {"gqa": gqa_axes, "mla": mla_axes, "mamba": mamba_axes, "rwkv6": rwkv_axes}


def block_init(key, cfg: ModelConfig, desc: LayerDesc, *, cross: bool = False):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: dict = {"norm1": norm_init(d, cfg)}
    if desc.mixer != "none":
        p["mixer"] = _MIXER_INIT[desc.mixer](ks[0], cfg)
    if cross:
        p["norm_x"] = norm_init(d, cfg)
        p["cross"] = gqa_init(ks[2], cfg)
    if desc.ffn != "none" and not cfg.parallel_block:
        p["norm2"] = norm_init(d, cfg)
    if desc.ffn == "dense":
        p["ffn"] = ffn_init(ks[1], cfg, cfg.d_ff_dense or cfg.d_ff)
    elif desc.ffn == "moe":
        p["ffn"] = moe_init(ks[1], cfg)
    return p


def block_axes(cfg: ModelConfig, desc: LayerDesc, *, cross: bool = False):
    a: dict = {"norm1": norm_axes(cfg)}
    if desc.mixer != "none":
        a["mixer"] = _MIXER_AXES[desc.mixer](cfg)
    if cross:
        a["norm_x"] = norm_axes(cfg)
        a["cross"] = gqa_axes(cfg)
    if desc.ffn != "none" and not cfg.parallel_block:
        a["norm2"] = norm_axes(cfg)
    if desc.ffn == "dense":
        a["ffn"] = ffn_axes(cfg)
    elif desc.ffn == "moe":
        a["ffn"] = moe_axes(cfg)
    return a


def block_cache_init(cfg: ModelConfig, desc: LayerDesc, batch: int, s_max: int, dtype, *, cross_len: int = 0):
    """ShapeDtype-compatible cache pytree for one block (None where stateless)."""
    d = cfg.d_model
    caches = {}
    if desc.mixer == "gqa":
        s = min(s_max, cfg.sliding_window) if cfg.sliding_window else s_max
        kv = (batch, s, cfg.n_kv_heads, cfg.head_dim)
        caches["mixer"] = KVCache(jnp.zeros(kv, dtype), jnp.zeros(kv, dtype))
    elif desc.mixer == "mla":
        caches["mixer"] = KVCache(
            jnp.zeros((batch, s_max, cfg.kv_lora_rank), dtype),
            jnp.zeros((batch, s_max, cfg.qk_rope_head_dim), dtype),
        )
    elif desc.mixer == "mamba":
        caches["mixer"] = MambaCache(
            jnp.zeros((batch, cfg.mamba_d_conv - 1, cfg.mamba_d_inner), dtype),
            jnp.zeros((batch, cfg.mamba_d_inner, cfg.mamba_d_state), jnp.float32),
        )
    elif desc.mixer == "rwkv6":
        H = d // cfg.rwkv_head_dim
        caches["mixer"] = RwkvCache(
            jnp.zeros((batch, 1, d), dtype),
            jnp.zeros((batch, 1, d), dtype),
            jnp.zeros((batch, H, cfg.rwkv_head_dim, cfg.rwkv_head_dim), jnp.float32),
        )
    if cross_len:
        kv = (batch, cross_len, cfg.n_kv_heads, cfg.head_dim)
        caches["cross"] = KVCache(jnp.zeros(kv, dtype), jnp.zeros(kv, dtype))
    return caches


def block_cache_axes(cfg: ModelConfig, desc: LayerDesc, *, ctx_parallel: bool = False, cross: bool = False):
    """Logical axes for the cache pytree of one block (mirrors block_cache_init)."""
    seq_ax = "seq_ctx" if ctx_parallel else None
    axes = {}
    if desc.mixer == "gqa":
        kv = ("batch", seq_ax, "kv_heads", None)
        axes["mixer"] = KVCache(kv, kv)
    elif desc.mixer == "mla":
        axes["mixer"] = KVCache(("batch", seq_ax, None), ("batch", seq_ax, None))
    elif desc.mixer == "mamba":
        axes["mixer"] = MambaCache(("batch", None, "mlp"), ("batch", "mlp", None))
    elif desc.mixer == "rwkv6":
        axes["mixer"] = RwkvCache(
            ("batch", None, None), ("batch", None, None), ("batch", "heads", None, None)
        )
    if cross:
        kv = ("batch", None, "kv_heads", None)
        axes["cross"] = KVCache(kv, kv)
    return axes


def _apply_mixer(p, x, cfg, desc, *, positions, cache, cache_len, causal, ctx_parallel):
    if desc.mixer == "gqa":
        return gqa_apply(
            p, x, cfg, positions=positions, causal=causal,
            cache=cache, cache_len=cache_len, ctx_parallel=ctx_parallel,
        )
    if desc.mixer == "mla":
        return mla_apply(
            p, x, cfg, positions=positions, causal=causal,
            cache=cache, cache_len=cache_len, ctx_parallel=ctx_parallel,
        )
    if desc.mixer == "mamba":
        return mamba_apply(p, x, cfg, cache=cache)
    if desc.mixer == "rwkv6":
        return rwkv_apply(p, x, cfg, cache=cache)
    raise ValueError(desc.mixer)


def block_apply(
    p,
    x: jnp.ndarray,
    cfg: ModelConfig,
    desc: LayerDesc,
    *,
    positions: jnp.ndarray,
    cache: Optional[dict] = None,
    cache_len=None,
    xa: Optional[jnp.ndarray] = None,  # encoder context (cross-attn blocks)
    causal: bool = True,
    ctx_parallel: bool = False,
):
    """Returns (x', new_cache)."""
    new_cache: dict = {}
    mixer_cache = (cache or {}).get("mixer")

    if cfg.parallel_block:  # command-r style: shared norm, parallel attn + ffn
        xn = norm_apply(p["norm1"], x, cfg)
        attn_out, mc = _apply_mixer(
            p["mixer"], xn, cfg, desc, positions=positions, cache=mixer_cache,
            cache_len=cache_len, causal=causal, ctx_parallel=ctx_parallel,
        )
        ffn_out = ffn_apply(p["ffn"], xn, cfg) if desc.ffn == "dense" else moe_apply(p["ffn"], xn, cfg)
        if mc is not None:
            new_cache["mixer"] = mc
        return x + attn_out + ffn_out, (new_cache or None)

    h = x
    if desc.mixer != "none":
        xn = norm_apply(p["norm1"], x, cfg)
        if desc.mixer == "rwkv6" and mixer_cache is not None:
            # time-mix token shift consumes the previous *normed* input
            mixer_cache = mixer_cache._replace(x_tm=mixer_cache.x_tm)
        out, mc = _apply_mixer(
            p["mixer"], xn, cfg, desc, positions=positions, cache=mixer_cache,
            cache_len=cache_len, causal=causal, ctx_parallel=ctx_parallel,
        )
        h = x + out
        if mc is not None:
            new_cache["mixer"] = mc

    if "cross" in p:
        xn = norm_apply(p["norm_x"], h, cfg)
        cross_cache = (cache or {}).get("cross")
        if cross_cache is not None and xa is None:
            # decode: reuse precomputed encoder K/V (no update)
            from .attention import _attend_decode  # local to avoid cycle
            import math

            B, S, _ = xn.shape
            H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
            from .layers import dense_apply

            q = dense_apply(p["cross"]["wq"], xn, cfg, contract="bsd,dhe->bshe")
            qg = q.reshape(B, S, Hkv, H // Hkv, dh)
            T = cross_cache.k.shape[1]
            valid = jnp.ones((B, T), bool)
            out = _attend_decode(
                qg, cross_cache.k, cross_cache.v, scale=1.0 / math.sqrt(dh), valid=valid
            )
            out = out.reshape(B, S, H * dh).astype(x.dtype)
            out = dense_apply(p["cross"]["wo"], out, cfg)
            new_cache["cross"] = cross_cache
        else:
            out, _ = gqa_apply(p["cross"], xn, cfg, positions=positions, causal=False, xa=xa)
        h = h + out

    if desc.ffn != "none":
        xn = norm_apply(p["norm2"], h, cfg)
        if desc.ffn == "moe":
            f = moe_apply(p["ffn"], xn, cfg)
        else:
            x_prev = None
            if cfg.ffn_act == "rwkv_cm":
                if cache is not None and "mixer" in (cache or {}):
                    x_prev = cache["mixer"].x_cm.astype(xn.dtype)
                else:
                    x_prev = jnp.pad(xn, ((0, 0), (1, 0), (0, 0)))[:, : xn.shape[1]]
            f = ffn_apply(p["ffn"], xn, cfg, x_prev=x_prev)
            if cfg.ffn_act == "rwkv_cm" and "mixer" in new_cache:
                new_cache["mixer"] = new_cache["mixer"]._replace(
                    x_cm=xn.astype(new_cache["mixer"].x_cm.dtype)
                )
        h = h + f

    return h, (new_cache or None)
