"""State-space / RNN mixers: Mamba-1 (Jamba) and RWKV-6 (Finch).

Both are implemented in *chunked parallel* form so training/prefill is
matmul-parallel (TPU-friendly) while decode is O(1)-state recurrent:

* Mamba-1: selective scan ``h_t = exp(Δ_t A) h_{t-1} + Δ_t B_t x_t`` run as
  an outer ``lax.scan`` over chunks with an inner ``associative_scan``
  (first-order recurrence combine) inside each chunk.
* RWKV-6: per-head state ``S_t = diag(w_t) S_{t-1} + k_t v_tᵀ`` with
  data-dependent decay ``w_t``. Within a chunk the pairwise decay ratios
  ``exp(L_{i-1}-L_j)`` (always ≤ 1 → no overflow, no clamping) form the
  intra-chunk attention; the chunk boundary carries the dense state. This
  is the GLA/Finch chunked formulation with the numerically-safe
  difference-of-logs tensor.

Decode caches: Mamba (conv ring, h); RWKV (token-shift x, S).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.sharding.rules import shard
from .layers import cdtype, dense_apply, dense_axes, dense_init, pdtype

__all__ = [
    "mamba_init", "mamba_axes", "mamba_apply", "MambaCache",
    "rwkv_init", "rwkv_axes", "rwkv_apply", "RwkvCache",
]

MAMBA_CHUNK = 64
RWKV_CHUNK = 32


class MambaCache(NamedTuple):
    conv: jnp.ndarray  # (B, d_conv-1, di) recent pre-conv inputs
    h: jnp.ndarray  # (B, di, N) SSM state


class RwkvCache(NamedTuple):
    x_tm: jnp.ndarray  # (B, 1, d) last input seen by time-mix
    x_cm: jnp.ndarray  # (B, 1, d) last input seen by channel-mix
    s: jnp.ndarray  # (B, H, dk, dv) wkv state


# =====================================================================================
# Mamba-1
# =====================================================================================
def mamba_init(key, cfg: ModelConfig):
    d, di, N, dr, dc = (
        cfg.d_model,
        cfg.mamba_d_inner,
        cfg.mamba_d_state,
        cfg.dt_rank,
        cfg.mamba_d_conv,
    )
    ks = jax.random.split(key, 6)
    dt = jnp.exp(
        jax.random.uniform(ks[4], (di,)) * (np.log(0.1) - np.log(0.001)) + np.log(0.001)
    )
    return {
        "in_proj": dense_init(ks[0], d, (2 * di,), cfg),
        "conv_w": jax.random.normal(ks[1], (dc, di), dtype=pdtype(cfg)) / np.sqrt(dc),
        "conv_b": jnp.zeros((di,), dtype=pdtype(cfg)),
        "x_proj": dense_init(ks[2], di, (dr + 2 * N,), cfg),
        "dt_proj": dense_init(ks[3], dr, (di,), cfg, bias=False),
        "dt_bias": jnp.log(jnp.expm1(dt)).astype(pdtype(cfg)),  # softplus⁻¹(dt)
        "A_log": jnp.log(
            jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (di, N))
        ).astype(pdtype(cfg)),
        "D": jnp.ones((di,), dtype=pdtype(cfg)),
        "out_proj": dense_init(ks[5], di, (d,), cfg),
    }


def mamba_axes(cfg: ModelConfig):
    return {
        "in_proj": dense_axes("fsdp", ("mlp",)),
        "conv_w": ("conv", "mlp"),
        "conv_b": ("mlp",),
        "x_proj": dense_axes("mlp", (None,)),
        "dt_proj": dense_axes(None, ("mlp",)),
        "dt_bias": ("mlp",),
        "A_log": ("mlp", "state"),
        "D": ("mlp",),
        "out_proj": dense_axes("mlp", ("fsdp",)),
    }


def _mamba_scan_chunked(dt, A, Bc, Cc, xm, h0, chunk: int):
    """Fused selective scan: h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t,
    y_t = h_t · C_t — the (B, S, di, N) state sequence is never materialized
    beyond one chunk (decay/increment are formed inside the chunk body).

    dt/xm: (B,S,di); Bc/Cc: (B,S,N); A: (di,N); h0: (B,di,N) f32.
    Returns (y (B,S,di) f32, h_last).
    """
    B, S, di = dt.shape
    N = A.shape[-1]
    pad = (-S) % chunk
    if pad:
        z3 = ((0, 0), (0, pad), (0, 0))
        dt, xm, Bc, Cc = (jnp.pad(a, z3) for a in (dt, xm, Bc, Cc))
    nc = dt.shape[1] // chunk

    def resh(a):
        return a.reshape(B, nc, chunk, -1).transpose(1, 0, 2, 3)

    xs = (resh(dt), resh(xm), resh(Bc), resh(Cc))

    def combine(a, b):
        (a1, b1), (a2, b2) = a, b
        return a2 * a1, a2 * b1 + b2

    def chunk_body(h, x):
        dt_i, xm_i, B_i, C_i = x  # (B, chunk, ·)
        decay = jnp.exp(dt_i[..., None] * A[None, None])  # (B,chunk,di,N)
        inc = (dt_i * xm_i)[..., None] * B_i[:, :, None, :]
        Acum, Bcum = jax.lax.associative_scan(combine, (decay, inc), axis=1)
        h_seq = Acum * h[:, None] + Bcum
        y = jnp.einsum("bcdn,bcn->bcd", h_seq, C_i)
        return h_seq[:, -1], y

    h_last, y_chunks = jax.lax.scan(jax.checkpoint(chunk_body), h0, xs)
    y = y_chunks.transpose(1, 0, 2, 3).reshape(B, nc * chunk, di)
    return y[:, :S], h_last


def mamba_apply(p, x: jnp.ndarray, cfg: ModelConfig, *, cache: MambaCache | None = None):
    """x (B, S, d) → (B, S, d). With ``cache`` (decode) S must be 1."""
    B, S, d = x.shape
    di, N, dr, dc = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.dt_rank, cfg.mamba_d_conv
    dtype = cdtype(cfg)

    xz = dense_apply(p["in_proj"], x, cfg)  # (B,S,2di)
    xm, z = jnp.split(xz, 2, axis=-1)
    xm = shard(xm, ("batch", "seq", "mlp"))

    # causal depthwise conv (tap loop — dc is 4)
    conv_w = p["conv_w"].astype(dtype)
    if cache is None:
        acc = jnp.zeros_like(xm)
        for t in range(dc):
            shiftamt = dc - 1 - t
            xs = jnp.pad(xm, ((0, 0), (shiftamt, 0), (0, 0)))[:, :S]
            acc = acc + xs * conv_w[t]
        new_conv = None
    else:
        hist = jnp.concatenate([cache.conv.astype(dtype), xm], axis=1)  # (B, dc, di)
        acc = jnp.einsum("btd,td->bd", hist, conv_w)[:, None, :]
        new_conv = hist[:, 1:].astype(cache.conv.dtype)
    xm = jax.nn.silu(acc + p["conv_b"].astype(dtype))

    proj = dense_apply(p["x_proj"], xm, cfg)  # (B,S,dr+2N)
    dt_low, Bc, Cc = jnp.split(proj, [dr, dr + N], axis=-1)
    dt = jax.nn.softplus(
        dense_apply(p["dt_proj"], dt_low, cfg) + p["dt_bias"].astype(dtype)
    ).astype(jnp.float32)  # (B,S,di)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (di,N)

    if cache is None:
        h0 = jnp.zeros((B, di, N), jnp.float32)
        y, _ = _mamba_scan_chunked(
            dt, A, Bc.astype(jnp.float32), Cc.astype(jnp.float32),
            xm.astype(jnp.float32), h0, MAMBA_CHUNK,
        )
        new_h = None
    else:
        decay = jnp.exp(dt[:, 0, :, None] * A[None])  # (B,di,N)
        inc = (dt[:, 0] * xm[:, 0].astype(jnp.float32))[..., None] * Bc.astype(jnp.float32)[
            :, 0, None, :
        ]
        h = cache.h.astype(jnp.float32) * decay + inc
        new_h = h.astype(cache.h.dtype)
        y = jnp.einsum("bdn,bn->bd", h, Cc.astype(jnp.float32)[:, 0])[:, None]

    y = y.astype(dtype) + p["D"].astype(dtype) * xm
    y = y * jax.nn.silu(z)
    y = shard(y, ("batch", "seq", "mlp"))
    out = dense_apply(p["out_proj"], y, cfg)
    new_cache = MambaCache(new_conv, new_h) if cache is not None else None
    return out, new_cache


# =====================================================================================
# RWKV-6
# =====================================================================================
def rwkv_init(key, cfg: ModelConfig):
    d = cfg.d_model
    dh = cfg.rwkv_head_dim
    H = d // dh
    ks = jax.random.split(key, 10)
    p = {
        "mu": 0.5 * jnp.ones((5, d), dtype=pdtype(cfg)),  # token-shift mixes r,k,v,w,g
        "wr": dense_init(ks[0], d, (H, dh), cfg),
        "wk": dense_init(ks[1], d, (H, dh), cfg),
        "wv": dense_init(ks[2], d, (H, dh), cfg),
        "wg": dense_init(ks[3], d, (d,), cfg),
        "w0": jnp.full((d,), -6.0, dtype=pdtype(cfg)),  # base log-log decay
        "w_lora_a": dense_init(ks[4], d, (cfg.rwkv_decay_lora,), cfg),
        "w_lora_b": dense_init(ks[5], cfg.rwkv_decay_lora, (d,), cfg, scale=0.01),
        "u": jnp.zeros((H, dh), dtype=pdtype(cfg)),  # bonus
        "ln_scale": jnp.ones((H, dh), dtype=pdtype(cfg)),  # per-head groupnorm
        "ln_bias": jnp.zeros((H, dh), dtype=pdtype(cfg)),
        "wo": dense_init(ks[6], d, (d,), cfg),
    }
    return p


def rwkv_axes(cfg: ModelConfig):
    return {
        "mu": (None, "embed"),
        "wr": dense_axes("fsdp", ("heads", "head_dim")),
        "wk": dense_axes("fsdp", ("heads", "head_dim")),
        "wv": dense_axes("fsdp", ("heads", "head_dim")),
        "wg": dense_axes("fsdp", ("mlp",)),
        "w0": ("embed",),
        "w_lora_a": dense_axes("fsdp", (None,)),
        "w_lora_b": dense_axes(None, ("embed",)),
        "u": ("heads", "head_dim"),
        "ln_scale": ("heads", "head_dim"),
        "ln_bias": ("heads", "head_dim"),
        "wo": dense_axes("mlp", ("fsdp",)),
    }


def _rwkv_chunked(r, k, v, logw, u, s0, chunk: int):
    """Chunked WKV. r/k/v/logw: (B, H, S, dh); u: (H, dh); s0: (B,H,dh,dh).

    Returns (out (B,H,S,dh), s_last). All math f32.
    """
    B, H, S, dh = r.shape
    pad = (-S) % chunk
    if pad:
        z = ((0, 0), (0, 0), (0, pad), (0, 0))
        r, k, v = jnp.pad(r, z), jnp.pad(k, z), jnp.pad(v, z)
        logw = jnp.pad(logw, z)  # logw=0 → w=1 (harmless: k=0 contributes nothing)
    nc = r.shape[2] // chunk

    def resh(a):  # (B,H,nc,C,dh) → scan over nc
        return a.reshape(B, H, nc, chunk, dh).transpose(2, 0, 1, 3, 4)

    rc, kc, vc, lwc = resh(r), resh(k), resh(v), resh(logw)

    causal = np.tril(np.ones((chunk, chunk), np.float32), -1)  # strictly lower

    def body(s, xs):
        ri, ki, vi, lwi = xs  # (B,H,C,dh)
        L = jnp.cumsum(lwi, axis=2)  # inclusive log-decay products
        Lm1 = L - lwi  # L_{i-1}
        # intra-chunk: ratio_{ijd} = exp(L_{i-1,d} − L_{j,d}) (≤1 for j<i)
        ratio = jnp.exp(Lm1[:, :, :, None, :] - L[:, :, None, :, :])  # (B,H,C,C,dh)
        A = jnp.einsum("bhid,bhijd,bhjd->bhij", ri, ratio, ki)
        A = A * causal[None, None]
        diag = (ri * ki * u[None, :, None, :]).sum(-1)  # (B,H,C) bonus term
        out = jnp.einsum("bhij,bhjd->bhid", A, vi) + diag[..., None] * vi
        # inter-chunk: contribution of carried state
        out = out + jnp.einsum("bhid,bhde->bhie", ri * jnp.exp(Lm1), s)
        # state update
        kd = ki * jnp.exp(L[:, :, -1:, :] - L)  # decay from j to chunk end
        s_new = s * jnp.exp(L[:, :, -1])[..., None] + jnp.einsum("bhjd,bhje->bhde", kd, vi)
        return s_new, out

    s_last, outs = jax.lax.scan(jax.checkpoint(body), s0, (rc, kc, vc, lwc))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(B, H, nc * chunk, dh)
    return out[:, :, :S], s_last


def rwkv_apply(p, x: jnp.ndarray, cfg: ModelConfig, *, cache: RwkvCache | None = None):
    """RWKV-6 time mixing. x (B,S,d) → (B,S,d)."""
    B, S, d = x.shape
    dh = cfg.rwkv_head_dim
    H = d // dh
    dtype = cdtype(cfg)

    if cache is None:
        x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :S]
    else:
        x_prev = cache.x_tm.astype(x.dtype)
    mu = p["mu"].astype(dtype)
    xr, xk, xv, xw, xg = (x * mu[i] + x_prev * (1 - mu[i]) for i in range(5))

    r = dense_apply(p["wr"], xr, cfg, contract="bsd,dhe->bshe").transpose(0, 2, 1, 3)
    k = dense_apply(p["wk"], xk, cfg, contract="bsd,dhe->bshe").transpose(0, 2, 1, 3)
    v = dense_apply(p["wv"], xv, cfg, contract="bsd,dhe->bshe").transpose(0, 2, 1, 3)
    g = jax.nn.silu(dense_apply(p["wg"], xg, cfg))

    # data-dependent decay (the Finch feature): w = exp(-exp(w0 + lora(xw)))
    lora = dense_apply(p["w_lora_b"], jnp.tanh(dense_apply(p["w_lora_a"], xw, cfg)), cfg)
    loglog_w = p["w0"].astype(jnp.float32) + lora.astype(jnp.float32)  # (B,S,d)
    logw = -jnp.exp(loglog_w)  # log w ≤ 0
    logw = logw.reshape(B, S, H, dh).transpose(0, 2, 1, 3)

    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    u = p["u"].astype(jnp.float32)

    if cache is None:
        s0 = jnp.zeros((B, H, dh, dh), jnp.float32)
        out, _ = _rwkv_chunked(rf, kf, vf, logw, u, s0, RWKV_CHUNK)
        new_cache = None
    else:
        s = cache.s.astype(jnp.float32)
        ri, ki, vi = rf[:, :, 0], kf[:, :, 0], vf[:, :, 0]  # (B,H,dh)
        kv = jnp.einsum("bhd,bhe->bhde", ki, vi)
        out = jnp.einsum("bhd,bhde->bhe", ri, s + u[None, :, :, None] * kv)
        s_new = s * jnp.exp(logw[:, :, 0])[..., None] + kv
        out = out[:, :, None]  # (B,H,1,dh)
        new_cache = RwkvCache(x.astype(cache.x_tm.dtype), cache.x_cm, s_new.astype(cache.s.dtype))

    # per-head groupnorm, gate, output proj
    o = out.transpose(0, 2, 1, 3)  # (B,S,H,dh)
    mean = o.mean(-1, keepdims=True)
    var = ((o - mean) ** 2).mean(-1, keepdims=True)
    o = (o - mean) * jax.lax.rsqrt(var + 64e-5)
    o = o * p["ln_scale"].astype(jnp.float32) + p["ln_bias"].astype(jnp.float32)
    o = o.reshape(B, S, d).astype(dtype) * g
    o = shard(o, ("batch", "seq", "mlp"))
    return dense_apply(p["wo"], o, cfg), new_cache
