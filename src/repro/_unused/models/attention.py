"""Attention mixers: GQA (with optional bias / sliding window / cross-attn)
and MLA (DeepSeek-V2 multi-head latent attention).

Train/prefill use a chunked, numerically-stable streaming softmax (flash
style, pure XLA: scan over KV chunks with running max/denominator) so the
(S × S) score matrix is never materialized — required for `prefill_32k`.
The baseline scans ALL kv chunks under a causal mask (compact HLO, ~2×
attention-FLOP overhead for causal shapes); the §Perf hillclimb replaces it
with a diagonal-aware schedule. Decode attends a single query against the
KV cache (optionally ring-buffered for sliding-window models, or sharded
over the `model` axis for context-parallel long decode).
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.sharding.rules import shard
from .layers import apply_rope, cdtype, dense_apply, dense_axes, dense_init, norm_apply, norm_axes, norm_init, rope_sin_cos

__all__ = [
    "gqa_init", "gqa_axes", "gqa_apply", "mla_init", "mla_axes", "mla_apply",
    "KVCache", "flash_enabled",
]

NEG_INF = -1e30

# ---------------------------------------------------------------------------------
# flash-kernel switch (trace-time): on TPU the Pallas flash kernel replaces the
# XLA streaming softmax (removes the O(B·H·S·S_kv) score HBM traffic that §Perf
# identified as the dominant memory term). Backward runs through the XLA
# streaming path via custom_vjp until a bwd kernel lands. On CPU (this
# container / the dry-run) the XLA path is used — the kernel itself is
# validated in interpret mode by tests/test_flash_attention.py.
# ---------------------------------------------------------------------------------
import contextlib
import os as _os

_FLASH = {"on": _os.environ.get("REPRO_FLASH", "auto")}


@contextlib.contextmanager
def flash_enabled(mode: str = "on"):
    prev = _FLASH["on"]
    _FLASH["on"] = mode
    try:
        yield
    finally:
        _FLASH["on"] = prev


def _use_flash() -> bool:
    mode = _FLASH["on"]
    if mode == "off" or mode == "0":
        return False
    if mode in ("on", "1", "force"):
        return True
    return jax.default_backend() == "tpu"  # auto


def _flash_with_xla_bwd(q, k, v, *, causal, window, scale):
    from repro._unused.flash_attention import flash_attention

    @jax.custom_vjp
    def f(q, k, v):
        return flash_attention(q, k, v, causal=causal, window=window, scale=scale)

    def fwd(q, k, v):
        return f(q, k, v), (q, k, v)

    def bwd(res, g):
        q, k, v = res
        _, vjp = jax.vjp(
            lambda q_, k_, v_: _attend_chunked(
                q_, k_, v_, causal=causal, window=window, scale=scale
            ),
            q, k, v,
        )
        return vjp(g.astype(jnp.float32))

    f.defvjp(fwd, bwd)
    return f(q, k, v)


class KVCache(NamedTuple):
    """Decode-time cache. For GQA: k/v (B, S_max, Hkv, dh). For SWA models
    S_max = window (ring buffer). For MLA: k = latent c_kv (B, S, kv_lora),
    v = shared rope key (B, S, rope_dim)."""

    k: jnp.ndarray
    v: jnp.ndarray


def _cache_write(buf: jnp.ndarray, new: jnp.ndarray, slot: jnp.ndarray, ctx_parallel: bool):
    """Write ``new`` (B, 1, ...) at position ``slot`` of ``buf``'s seq axis.

    With a context-parallel (seq-sharded) cache a dynamic_update_slice at a
    traced offset makes GSPMD gather the whole buffer per layer (§Perf cell 2
    found 931 GB/step of exactly this). The masked iota-compare write is
    fully local on the sharded axis: each shard touches only its slice.
    """
    if not ctx_parallel:
        start = (0, slot) + (0,) * (buf.ndim - 2)
        return jax.lax.dynamic_update_slice(buf, new.astype(buf.dtype), start)
    seq = jnp.arange(buf.shape[1], dtype=jnp.int32)
    mask = (seq == slot)[None, :] if buf.ndim == 2 else (seq == slot).reshape(
        (1, -1) + (1,) * (buf.ndim - 2)
    )
    return jnp.where(mask, new.astype(buf.dtype), buf)


# =====================================================================================
# chunked streaming attention core
# =====================================================================================
def _attend_chunked(
    q: jnp.ndarray,  # (B, S, Hkv, G, dh)  — grouped query
    k: jnp.ndarray,  # (B, T, Hkv, dh)
    v: jnp.ndarray,  # (B, T, Hkv, dhv)
    *,
    causal: bool,
    window: Optional[int],
    scale: float,
    q_offset: int = 0,  # absolute position of q[0] minus that of k[0]
    kv_chunk: int = 1024,
    softcap: Optional[float] = None,
    p_dtype=jnp.bfloat16,  # probability-tensor storage across the PV fusion
):
    """Streaming-softmax attention, scanning KV chunks. Returns (B,S,Hkv,G,dhv)."""
    B, S, Hkv, G, dh = q.shape
    T = k.shape[1]
    dhv = v.shape[-1]
    n_chunks = -(-T // kv_chunk)
    Tp = n_chunks * kv_chunk
    if Tp != T:
        k = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, kv_chunk, Hkv, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, kv_chunk, Hkv, dhv).transpose(1, 0, 2, 3, 4)

    q32 = q.astype(jnp.float32) * scale
    qpos = q_offset + jnp.arange(S)  # absolute q positions (relative to k[0])

    def body(carry, xs):
        m, l, acc = carry
        ci, k_i, v_i = xs
        kpos = ci * kv_chunk + jnp.arange(kv_chunk)
        s_ij = jnp.einsum("bshgd,bthd->bhgst", q32, k_i.astype(jnp.float32))
        if softcap is not None:
            s_ij = softcap * jnp.tanh(s_ij / softcap)
        mask = kpos[None, :] <= (qpos[:, None] if causal else jnp.full((S, 1), Tp))
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        mask &= (kpos < T)[None, :]  # padding
        s_ij = jnp.where(mask[None, None, None], s_ij, NEG_INF)
        m_ij = jnp.max(s_ij, axis=-1)  # (B,H,G,S)
        m_new = jnp.maximum(m, m_ij)
        p = jnp.exp(s_ij - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(-1)
        # §Perf: p crosses an XLA fusion boundary into the PV matmul — store
        # it in the compute dtype (bf16 halves the dominant score-tensor HBM
        # traffic; f32 row max/sum above keep the softmax numerics).
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgst,bthd->bhgsd",
            p.astype(p_dtype),
            v_i.astype(p_dtype),
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, G, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, S), jnp.float32)
    acc0 = jnp.zeros((B, Hkv, G, S, dhv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body), (m0, l0, acc0), (jnp.arange(n_chunks), kc, vc)
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4)  # (B,S,Hkv,G,dhv)


def _attend_decode(
    q: jnp.ndarray,  # (B, 1, Hkv, G, dh)
    k: jnp.ndarray,  # (B, T, Hkv, dh)
    v: jnp.ndarray,  # (B, T, Hkv, dhv)
    *,
    scale: float,
    valid: jnp.ndarray,  # (B, T) bool — which cache slots participate
    softcap: Optional[float] = None,
):
    """Single-token attention against the cache (context-parallel friendly:
    when the cache's T axis is sharded over `model`, the max/sum reductions
    below become the 3-collective flash-decode combine under GSPMD)."""
    q32 = q.astype(jnp.float32) * scale
    s = jnp.einsum("bxhgd,bthd->bhgxt", q32, k.astype(jnp.float32))
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = p.sum(-1, keepdims=True)
    out = jnp.einsum("bhgxt,bthd->bhgxd", p / jnp.maximum(l, 1e-30), v.astype(jnp.float32))
    return out.transpose(0, 3, 1, 2, 4)  # (B,1,Hkv,G,dhv)


# =====================================================================================
# GQA
# =====================================================================================
def gqa_init(key, cfg: ModelConfig):
    d, H, Hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, (H, dh), cfg, bias=cfg.qkv_bias),
        "wk": dense_init(ks[1], d, (Hkv, dh), cfg, bias=cfg.qkv_bias),
        "wv": dense_init(ks[2], d, (Hkv, dh), cfg, bias=cfg.qkv_bias),
        "wo": dense_init(ks[3], H * dh, (d,), cfg),
    }


def gqa_axes(cfg: ModelConfig):
    b = cfg.qkv_bias
    return {
        "wq": dense_axes("fsdp", ("heads", "head_dim"), bias=b),
        "wk": dense_axes("fsdp", ("kv_heads", "head_dim"), bias=b),
        "wv": dense_axes("fsdp", ("kv_heads", "head_dim"), bias=b),
        "wo": dense_axes("mlp", ("fsdp",)),
    }


def gqa_apply(
    p,
    x: jnp.ndarray,  # (B, S, d)
    cfg: ModelConfig,
    *,
    positions: jnp.ndarray,  # (S,) absolute positions of x
    causal: bool = True,
    cache: Optional[KVCache] = None,
    cache_len: Optional[jnp.ndarray] = None,  # tokens already in cache
    xa: Optional[jnp.ndarray] = None,  # cross-attention context (B, Sx, d)
    ctx_parallel: bool = False,
):
    """Returns (out (B,S,d), new_cache)."""
    B, S, d = x.shape
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // Hkv
    scale = 1.0 / math.sqrt(dh)

    q = dense_apply(p["wq"], x, cfg, contract="bsd,dhe->bshe")  # (B,S,H,dh)
    kv_src = x if xa is None else xa
    k = dense_apply(p["wk"], kv_src, cfg, contract="bsd,dhe->bshe")
    v = dense_apply(p["wv"], kv_src, cfg, contract="bsd,dhe->bshe")
    q = shard(q, ("batch", "seq", "heads", "head_dim"))
    k = shard(k, ("batch", "seq", "kv_heads", "head_dim"))
    v = shard(v, ("batch", "seq", "kv_heads", "head_dim"))

    if xa is None:  # self-attention: rotary positions
        sin, cos = rope_sin_cos(positions, dh, cfg.rope_theta)
        q = apply_rope(q, sin[None], cos[None])
        kpos = positions if cache is None else positions  # same stage positions
        ksin, kcos = rope_sin_cos(kpos, dh, cfg.rope_theta)
        k = apply_rope(k, ksin[None], kcos[None])

    qg = q.reshape(B, S, Hkv, G, dh)
    new_cache = None

    if cache is not None:
        # decode: write k,v at the cache cursor, attend to the whole cache
        S_max = cache.k.shape[1]
        if cfg.sliding_window and S_max == cfg.sliding_window:
            slot = (cache_len % cfg.sliding_window).astype(jnp.int32)
        else:
            slot = cache_len.astype(jnp.int32)
        ck = _cache_write(cache.k, k, slot, ctx_parallel)
        cv = _cache_write(cache.v, v, slot, ctx_parallel)
        new_cache = KVCache(ck, cv)
        t_idx = jnp.arange(S_max)
        if cfg.sliding_window and S_max == cfg.sliding_window:
            valid = jnp.broadcast_to(t_idx[None, :] <= jnp.minimum(cache_len, S_max - 1), (B, S_max))
        else:
            valid = jnp.broadcast_to(t_idx[None, :] <= cache_len, (B, S_max))
            if cfg.sliding_window:
                valid &= t_idx[None, :] > cache_len - cfg.sliding_window
        axes = ("batch", "seq_ctx" if ctx_parallel else None, "kv_heads", "head_dim")
        ck, cv = shard(ck, axes), shard(cv, axes)
        out = _attend_decode(
            qg, ck, cv, scale=scale, valid=valid, softcap=cfg.attn_logit_softcap
        )
    else:
        is_causal = causal and xa is None
        win = cfg.sliding_window if xa is None else None
        if _use_flash() and cfg.attn_logit_softcap is None:
            out = _flash_with_xla_bwd(qg, k, v, causal=is_causal, window=win, scale=scale)
        else:
            out = _attend_chunked(
                qg, k, v, causal=is_causal, window=win, scale=scale,
                softcap=cfg.attn_logit_softcap, p_dtype=cdtype(cfg),
            )

    out = out.reshape(B, S, H * dh).astype(cdtype(cfg))
    out = shard(out, ("batch", "seq", "mlp"))
    return dense_apply(p["wo"], out, cfg), new_cache


# =====================================================================================
# MLA (DeepSeek-V2)
# =====================================================================================
def mla_init(key, cfg: ModelConfig):
    d, H = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
    ks = jax.random.split(key, 8)
    return {
        "wdq": dense_init(ks[0], d, (qr,), cfg),
        "q_norm": norm_init(qr, cfg),
        "wuq": dense_init(ks[1], qr, (H, dn + dr), cfg),
        "wdkv": dense_init(ks[2], d, (kr,), cfg),
        "kv_norm": norm_init(kr, cfg),
        "wkr": dense_init(ks[3], d, (dr,), cfg),  # shared rope key
        "wuk": dense_init(ks[4], kr, (H, dn), cfg),
        "wuv": dense_init(ks[5], kr, (H, dv), cfg),
        "wo": dense_init(ks[6], H * dv, (d,), cfg),
    }


def mla_axes(cfg: ModelConfig):
    return {
        "wdq": dense_axes("fsdp", (None,)),
        "q_norm": norm_axes(cfg),
        "wuq": dense_axes("fsdp", ("heads", "head_dim")),
        "wdkv": dense_axes("fsdp", (None,)),
        "kv_norm": norm_axes(cfg),
        "wkr": dense_axes("fsdp", (None,)),
        "wuk": dense_axes("fsdp", ("heads", "head_dim")),
        "wuv": dense_axes("fsdp", ("heads", "head_dim")),
        "wo": dense_axes("mlp", ("fsdp",)),
    }


def mla_apply(
    p,
    x: jnp.ndarray,
    cfg: ModelConfig,
    *,
    positions: jnp.ndarray,
    causal: bool = True,
    cache: Optional[KVCache] = None,
    cache_len: Optional[jnp.ndarray] = None,
    xa=None,  # unused (MLA models are decoder-only here)
    ctx_parallel: bool = False,
):
    B, S, d = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    scale = 1.0 / math.sqrt(dn + dr)

    cq = norm_apply(p["q_norm"], dense_apply(p["wdq"], x, cfg), cfg)
    q = dense_apply(p["wuq"], cq, cfg, contract="bsq,qhe->bshe")  # (B,S,H,dn+dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    sin, cos = rope_sin_cos(positions, dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, sin[None], cos[None])

    ckv = norm_apply(p["kv_norm"], dense_apply(p["wdkv"], x, cfg), cfg)  # (B,S,kr)
    k_rope = dense_apply(p["wkr"], x, cfg)[:, :, None, :]  # (B,S,1,dr)
    k_rope = apply_rope(k_rope, sin[None], cos[None])[:, :, 0]  # (B,S,dr)

    new_cache = None
    if cache is not None:
        slot = cache_len.astype(jnp.int32)
        ck = _cache_write(cache.k, ckv, slot, ctx_parallel)
        cr = _cache_write(cache.v, k_rope, slot, ctx_parallel)
        new_cache = KVCache(ck, cr)
        ckv_all, k_rope_all = ck, cr
        T = ck.shape[1]
        valid = jnp.broadcast_to(jnp.arange(T)[None, :] <= cache_len, (B, T))
    else:
        ckv_all, k_rope_all = ckv, k_rope
        T = S

    # reconstruct per-head keys/values from the latent
    k_nope = dense_apply(p["wuk"], ckv_all, cfg, contract="btq,qhe->bthe")  # (B,T,H,dn)
    vv = dense_apply(p["wuv"], ckv_all, cfg, contract="btq,qhe->bthe")  # (B,T,H,dv)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope_all[:, :, None, :], (B, T, H, dr)).astype(k_nope.dtype)],
        axis=-1,
    )
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1).reshape(B, S, H, 1, dn + dr)

    if cache is not None:
        out = _attend_decode(q_full, k_full, vv, scale=scale, valid=valid)
    else:
        out = _attend_chunked(
            q_full, k_full, vv, causal=causal, window=None, scale=scale, p_dtype=cdtype(cfg)
        )

    out = out.reshape(B, S, H * dv).astype(cdtype(cfg))
    out = shard(out, ("batch", "seq", "mlp"))
    return dense_apply(p["wo"], out, cfg), new_cache
