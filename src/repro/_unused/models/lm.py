"""Model assembly: decoder-only LMs, the enc-dec (seamless) variant, the VLM
embedding stub, scan-over-layer-groups with remat, and decode caches.

Public API:
  init_params / param_axes       — params pytree + logical-axis pytree
  apply_train(params, batch)     — full-sequence logits (train / prefill)
  init_cache / apply_decode      — KV/state-cached single-token decode
  encode / prefill_cross         — enc-dec support
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LayerDesc, ModelConfig
from repro.sharding.rules import shard
from .blocks import block_apply, block_axes, block_cache_init, block_init
from .layers import cdtype, embed_axes, embed_init, norm_apply, norm_axes, norm_init, round_vocab

__all__ = [
    "init_params",
    "param_axes",
    "apply_train",
    "init_cache",
    "apply_decode",
    "encode",
    "prefill_cross",
    "count_params",
]


# =====================================================================================
# init
# =====================================================================================
def _init_group(key, cfg: ModelConfig, pattern, repeat: int, *, cross: bool = False):
    def one(k):
        ks = jax.random.split(k, len(pattern))
        return {f"l{i}": block_init(ks[i], cfg, d, cross=cross) for i, d in enumerate(pattern)}

    return jax.vmap(one)(jax.random.split(key, repeat))


def _group_axes(cfg: ModelConfig, pattern, *, cross: bool = False):
    one = {f"l{i}": block_axes(cfg, d, cross=cross) for i, d in enumerate(pattern)}
    # prepend the stacked (scan) axis to every leaf
    return jax.tree.map(lambda a: (None, *a), one, is_leaf=lambda a: type(a) is tuple)


def init_params(key, cfg: ModelConfig):
    ks = jax.random.split(key, 8)
    params: dict[str, Any] = {
        "embed": embed_init(ks[0], cfg),
        "final_norm": norm_init(cfg.d_model, cfg),
    }
    if not cfg.tie_embeddings:
        v = round_vocab(cfg.vocab)
        params["lm_head"] = {
            "w": jax.random.normal(ks[1], (cfg.d_model, v), dtype=jnp.dtype(cfg.param_dtype))
            * (1.0 / np.sqrt(cfg.d_model))
        }
    params["groups"] = [
        _init_group(jax.random.fold_in(ks[2], gi), cfg, pattern, repeat, cross=cfg.encdec)
        for gi, (pattern, repeat) in enumerate(cfg.layer_list)
    ]
    if cfg.encdec:
        enc_pattern = (LayerDesc(mixer="gqa", ffn="dense"),)
        params["encoder"] = _init_group(ks[3], cfg, enc_pattern, cfg.n_encoder_layers)
        params["enc_norm"] = norm_init(cfg.d_model, cfg)
    return params


def param_axes(cfg: ModelConfig):
    axes: dict[str, Any] = {
        "embed": embed_axes(),
        "final_norm": norm_axes(cfg),
    }
    if not cfg.tie_embeddings:
        axes["lm_head"] = {"w": ("fsdp", "vocab")}
    axes["groups"] = [
        _group_axes(cfg, pattern, cross=cfg.encdec) for pattern, _ in cfg.layer_list
    ]
    if cfg.encdec:
        axes["encoder"] = _group_axes(cfg, (LayerDesc(mixer="gqa", ffn="dense"),))
        axes["enc_norm"] = norm_axes(cfg)
    return axes


def count_params(params) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))


# =====================================================================================
# forward (train / prefill)
# =====================================================================================
def _embed_input(params, batch, cfg: ModelConfig):
    """Token / frontend-stub embedding → (B, S, d) in compute dtype."""
    table = params["embed"]["table"].astype(cdtype(cfg))
    if cfg.frontend == "audio_frames" and cfg.encdec:
        x = batch["tokens"]
        emb = table[x]
    elif cfg.frontend == "vision_patches":
        tok_emb = table[batch["tokens"]]  # (B,S,d)
        P = cfg.n_patches
        patches = batch["patch_embeds"].astype(cdtype(cfg))  # (B,P,d)
        emb = jnp.concatenate([patches, tok_emb[:, P:]], axis=1)
    else:
        emb = table[batch["tokens"]]
    return shard(emb, ("batch", "seq", "embed"))


def _remat_policy():
    """Layer remat policy. REPRO_REMAT=dots saves matmul outputs (recompute
    only elementwise ops in the backward re-forward — trades HBM for ~25%
    less recompute FLOPs); default recomputes everything (min memory)."""
    import os

    mode = os.environ.get("REPRO_REMAT", "full")
    if mode == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return None


def _run_groups(params_groups, x, cfg, *, positions, causal=True, xa=None):
    policy = _remat_policy()
    for (pattern, repeat), p_g in zip(cfg.layer_list, params_groups):
        def body(h, p_slice, _pattern=pattern):
            for i, desc in enumerate(_pattern):
                h, _ = block_apply(
                    p_slice[f"l{i}"], h, cfg, desc, positions=positions, causal=causal, xa=xa
                )
            return h, None

        x, _ = jax.lax.scan(jax.checkpoint(body, policy=policy), x, p_g)
    return x


def _logits(params, x, cfg):
    x = norm_apply(params["final_norm"], x, cfg)
    if cfg.tie_embeddings:
        w = params["embed"]["table"].astype(cdtype(cfg)).T
    else:
        w = params["lm_head"]["w"].astype(cdtype(cfg))
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    return shard(logits.astype(jnp.float32), ("batch", "seq", "vocab"))


def encode(params, frames: jnp.ndarray, cfg: ModelConfig):
    """Encoder stack over precomputed frame embeddings (audio stub)."""
    x = shard(frames.astype(cdtype(cfg)), ("batch", "seq", "embed"))
    positions = jnp.arange(x.shape[1])
    enc_pattern = (LayerDesc(mixer="gqa", ffn="dense"),)

    def body(h, p_slice):
        h, _ = block_apply(p_slice["l0"], h, cfg, enc_pattern[0], positions=positions, causal=False)
        return h, None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["encoder"])
    return norm_apply(params["enc_norm"], x, cfg)


def apply_train(params, batch: dict, cfg: ModelConfig):
    """Full-sequence forward → logits (B, S, vocab_padded) f32."""
    if cfg.encdec:
        enc_out = encode(params, batch["frames"], cfg)
        x = _embed_input(params, batch, cfg)
        positions = jnp.arange(x.shape[1])
        x = _run_groups(params["groups"], x, cfg, positions=positions, causal=True, xa=enc_out)
    else:
        x = _embed_input(params, batch, cfg)
        positions = jnp.arange(x.shape[1])
        x = _run_groups(params["groups"], x, cfg, positions=positions, causal=True)
    return _logits(params, x, cfg)


# =====================================================================================
# decode
# =====================================================================================
def init_cache(cfg: ModelConfig, batch: int, s_max: int, dtype=jnp.bfloat16, *, cross_len: int = 0):
    groups = []
    for pattern, repeat in cfg.layer_list:
        one = {
            f"l{i}": block_cache_init(
                cfg, d, batch, s_max, dtype, cross_len=cross_len if cfg.encdec else 0
            )
            for i, d in enumerate(pattern)
        }
        groups.append(jax.tree.map(lambda l: jnp.zeros((repeat, *l.shape), l.dtype), one))
    return {"groups": groups}


def cache_axes(cfg: ModelConfig, *, ctx_parallel: bool = False, cross: bool = False):
    """Logical-axes pytree matching init_cache (leading scan axis → None)."""
    from .blocks import block_cache_axes

    groups = []
    for pattern, _repeat in cfg.layer_list:
        one = {
            f"l{i}": block_cache_axes(cfg, d, ctx_parallel=ctx_parallel, cross=cross and cfg.encdec)
            for i, d in enumerate(pattern)
        }
        groups.append(
            jax.tree.map(lambda a: (None, *a), one, is_leaf=lambda a: type(a) is tuple)
        )
    return {"groups": groups}


def prefill_cross(params, enc_out: jnp.ndarray, cfg: ModelConfig, cache):
    """Precompute cross-attention K/V from the encoder output into the cache."""
    from .layers import dense_apply

    new_groups = []
    for (pattern, repeat), p_g, c_g in zip(cfg.layer_list, params["groups"], cache["groups"]):
        def fill(p_slice, c_slice):
            out = dict(c_slice)
            for i in range(len(pattern)):
                pc = p_slice[f"l{i}"]["cross"]
                k = dense_apply(pc["wk"], enc_out, cfg, contract="bsd,dhe->bshe")
                v = dense_apply(pc["wv"], enc_out, cfg, contract="bsd,dhe->bshe")
                cc = c_slice[f"l{i}"]["cross"]
                out[f"l{i}"] = dict(c_slice[f"l{i}"])
                out[f"l{i}"]["cross"] = cc._replace(k=k.astype(cc.k.dtype), v=v.astype(cc.v.dtype))
            return out

        new_groups.append(jax.vmap(fill, in_axes=(0, 0))(p_g, c_g))
    return {"groups": new_groups}


def apply_decode(params, tokens: jnp.ndarray, cache, cache_len, cfg: ModelConfig, *, ctx_parallel=False):
    """One decode step. tokens (B, 1) → (logits (B, 1, V), new_cache)."""
    table = params["embed"]["table"].astype(cdtype(cfg))
    x = shard(table[tokens], ("batch", None, "embed"))
    positions = cache_len[None] if jnp.ndim(cache_len) == 0 else cache_len[:1]

    new_groups = []
    for (pattern, repeat), p_g, c_g in zip(cfg.layer_list, params["groups"], cache["groups"]):
        def body(h, xs, _pattern=pattern):
            p_slice, c_slice = xs
            new_c = {}
            for i, desc in enumerate(_pattern):
                h, nc = block_apply(
                    p_slice[f"l{i}"], h, cfg, desc,
                    positions=positions, cache=c_slice[f"l{i}"], cache_len=cache_len,
                    ctx_parallel=ctx_parallel,
                )
                # keep untouched cache entries (e.g. cross K/V) as-is
                merged = dict(c_slice[f"l{i}"])
                merged.update(nc or {})
                new_c[f"l{i}"] = merged
            return h, new_c

        x, c_new = jax.lax.scan(body, x, (p_g, c_g))
        new_groups.append(c_new)

    logits = _logits(params, x, cfg)
    return logits, {"groups": new_groups}
