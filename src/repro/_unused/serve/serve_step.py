"""Serving: batched single-token decode steps and the prefill that feeds them.

``decode_*`` shapes lower exactly this step: one new token against a KV/state
cache of ``seq_len`` (ring-buffered to the window for SWA models; latent for
MLA; O(1) state for Mamba/RWKV). ``long_500k`` additionally turns on context
parallelism: the cache's sequence axis is sharded over the `model` mesh axis
and the flash-decode combine runs as three small collectives (see
models/attention.py::_attend_decode).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro._unused.models import lm

__all__ = ["make_decode_step", "make_prefill_step", "greedy_generate"]

CTX_PARALLEL_THRESHOLD = 1 << 15  # 32768: shard the cache's seq axis over
# the `model` mesh axis from this length up (context-parallel decode). This
# is what keeps 32k-cache × large-batch decode inside HBM when kv_heads <
# model-axis extent (GQA kv=8 cannot TP-shard 16 ways; the seq axis always
# can), and it turns the flash-decode combine into 3 small collectives.


def make_decode_step(cfg: ModelConfig, s_max: int):
    ctx_parallel = s_max >= CTX_PARALLEL_THRESHOLD

    def step(params, tokens: jnp.ndarray, cache, cache_len: jnp.ndarray):
        # serving weights live in bf16 AT REST (see launch/specs.py) — no
        # per-step cast: converts would add their own HBM copies.
        logits, new_cache = lm.apply_decode(
            params, tokens, cache, cache_len, cfg, ctx_parallel=ctx_parallel
        )
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, new_cache

    return step


def make_prefill_step(cfg: ModelConfig):
    """Full-sequence forward producing last-position logits (prefill shapes)."""

    def step(params, batch: dict):
        logits = lm.apply_train(params, batch, cfg)
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)

    return step


def greedy_generate(params, cfg: ModelConfig, prompt: jnp.ndarray, n_new: int, s_max: int = 0):
    """Simple greedy loop (examples / tests). prompt: (B, S0) int32."""
    B, S0 = prompt.shape
    s_max = s_max or (S0 + n_new)
    cache = lm.init_cache(cfg, B, s_max)
    step = make_decode_step(cfg, s_max)
    tok = prompt[:, :1]
    out = []
    # feed the prompt token-by-token (simple; prefill path covers the fast case)
    for t in range(S0):
        nxt, cache = step(params, prompt[:, t : t + 1], cache, jnp.int32(t))
    tok = nxt[:, None]
    for t in range(n_new):
        out.append(tok)
        nxt, cache = step(params, tok, cache, jnp.int32(S0 + t))
        tok = nxt[:, None]
    return jnp.concatenate(out, axis=1)
