"""Paged session-state slabs: shared storage for millions of short streams.

A streaming decode session carries soft symbols between chunks — the
inter-block overlap tail plus whatever arrived since the last launch. With
one contiguous ndarray per session (the default
:class:`~repro.core.engine.ArraySessionStore`), a serving layer admitting
millions of short-lived streams churns an allocation per chunk per stream.
This module is the paged alternative, shaped like pie's paged-KV blocks
(ROADMAP item 2): ONE slab of fixed-size pages shared by every live
session, a LIFO free-list so a dying stream's pages are immediately reused
by the next admit, and per-session stores that are *views* onto their page
list rather than owners of memory.

* :class:`SymbolSlab` — the allocator: ``(n_pages, page_stages, R)``
  float32 backing array + free-list. Pages are zeroed on release, so a
  freshly allocated page is always all-zero (the BM-neutral erasure value
  the punctured ingest and the zero-padded tail both rely on).
* :class:`PagedSessionStore` — one session's buffered-symbol window,
  implementing the :class:`~repro.core.engine.ArraySessionStore` contract
  over a list of slab pages: ``append``/``grow``/``scatter`` fill the tail,
  ``drop_prefix`` retires committed stages and returns fully consumed pages
  to the free-list, ``read`` gathers a stage window across page boundaries.

Exhaustion is an explicit :class:`SlabExhausted` — the admission layer
(:mod:`repro.launch.serve_async`) maps it to backpressure instead of
letting the slab grow unboundedly.

See DESIGN.md §13 for the layout and the serving-layer contract.
"""

from __future__ import annotations

import numpy as np

from repro.launch.faults import CapacityError

__all__ = ["SlabExhausted", "SymbolSlab", "PagedSessionStore"]


class SlabExhausted(CapacityError):
    """No free pages left in the slab (admission should apply backpressure).

    A :class:`~repro.launch.faults.CapacityError`: the service — not the
    stream or the launch — is out of room, so waiting for a dispatch to
    retire pages (or shedding the admission) is the right response.
    """


class SymbolSlab:
    """A pool of fixed-size symbol pages with a LIFO free-list.

    Parameters
    ----------
    n_pages: total pages in the slab (the hard capacity knob).
    page_stages: full-rate stages per page. The serving layer sizes this to
        the session working set — a session holds at most ``D + L`` stages
        between steps plus whatever arrival jitter buffers on top, so
        ``D + 2L`` (one decode window) is a natural default.
    R: symbols per stage (the mother code rate denominator).
    """

    def __init__(self, n_pages: int, page_stages: int, R: int):
        if n_pages <= 0 or page_stages <= 0 or R <= 0:
            raise ValueError(
                f"slab geometry must be positive, got n_pages={n_pages}, "
                f"page_stages={page_stages}, R={R}"
            )
        self.n_pages = int(n_pages)
        self.page_stages = int(page_stages)
        self.R = int(R)
        self._data = np.zeros((n_pages, page_stages, R), np.float32)
        # flat (n_pages*page_stages, R) alias: one fancy-index gathers or
        # scatters any stage window regardless of page boundaries
        self._flat = self._data.reshape(-1, R)
        self._free: list[int] = list(range(n_pages - 1, -1, -1))  # LIFO: pop()
        self.high_water = 0  # max pages simultaneously in use (for reports)

    # ---- allocation ----------------------------------------------------------------
    @property
    def pages_free(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.n_pages - len(self._free)

    def alloc(self) -> int:
        """Take a (zeroed) page id off the free-list."""
        if not self._free:
            raise SlabExhausted(
                f"slab exhausted: all {self.n_pages} pages "
                f"({self.n_pages * self.page_stages} stages) in use"
            )
        page = self._free.pop()
        self.high_water = max(self.high_water, self.pages_in_use)
        return page

    def free(self, page: int) -> None:
        """Return a page; zero it so the next alloc sees BM-neutral zeros."""
        if not 0 <= page < self.n_pages:
            raise ValueError(f"page {page} outside slab of {self.n_pages}")
        if page in self._free:
            raise ValueError(f"double free of slab page {page}")
        self._data[page] = 0.0
        self._free.append(page)

    def open_store(self) -> "PagedSessionStore":
        """A fresh (empty) session store over this slab."""
        return PagedSessionStore(self)


class PagedSessionStore:
    """One session's symbol buffer as a window over slab pages.

    Logical stage ``i`` (0 = oldest held stage) lives at page
    ``pages[(head + i) // P]``, row ``(head + i) % P`` where ``head`` is the
    intra-page offset of stage 0 and ``P = slab.page_stages``. ``append``/
    ``grow`` extend the tail (allocating pages on demand), ``drop_prefix``
    advances ``head`` and frees pages the window has fully left — so a
    steady-state stream touches exactly ceil(working set / P) pages no
    matter how many chunks flow through it.

    Implements the :class:`~repro.core.engine.ArraySessionStore` contract;
    see that class for method semantics.
    """

    def __init__(self, slab: SymbolSlab):
        self._slab = slab
        self._pages: list[int] = []
        self._head = 0  # intra-page offset of logical stage 0
        self._n = 0  # stages held
        self._closed = False

    def __len__(self) -> int:
        return self._n

    # ---- row addressing ------------------------------------------------------------
    def _rows(self, lo: int, n: int) -> np.ndarray:
        """Flat slab row indices for logical stages [lo, lo+n)."""
        g = self._head + lo + np.arange(n)
        pages = np.asarray(self._pages, np.int64)[g // self._slab.page_stages]
        return pages * self._slab.page_stages + g % self._slab.page_stages

    def _ensure_capacity(self, n_total: int) -> None:
        """Grow the page list to hold ``n_total`` logical stages."""
        P = self._slab.page_stages
        need_pages = -(-(self._head + n_total) // P)
        while len(self._pages) < need_pages:
            self._pages.append(self._slab.alloc())

    # ---- ArraySessionStore contract ------------------------------------------------
    def append(self, rows: np.ndarray) -> None:
        self._check_open()
        rows = np.asarray(rows, np.float32)
        n = len(rows)
        if n == 0:
            return
        self._ensure_capacity(self._n + n)
        self._slab._flat[self._rows(self._n, n)] = rows
        self._n += n

    def grow(self, n: int) -> None:
        # pages arrive zeroed from the free-list and the tail past _n was
        # never written (stores only drop from the head), so growing is just
        # capacity + bookkeeping — no memset
        self._check_open()
        if n > 0:
            self._ensure_capacity(self._n + n)
            self._n += n

    def scatter(self, stage_idx, sym_idx, values) -> None:
        self._check_open()
        stage_idx = np.asarray(stage_idx)
        g = self._head + stage_idx
        P = self._slab.page_stages
        pages = np.asarray(self._pages, np.int64)[g // P]
        self._slab._flat[pages * P + g % P, sym_idx] = values

    def read(self, lo: int, n: int) -> np.ndarray:
        self._check_open()
        n = max(0, min(n, self._n - lo))
        if n <= 0:
            return np.zeros((0, self._slab.R), np.float32)
        return self._slab._flat[self._rows(lo, n)]

    def drop_prefix(self, n: int) -> None:
        self._check_open()
        n = min(n, self._n)
        if n <= 0:
            return
        self._head += n
        self._n -= n
        P = self._slab.page_stages
        while self._head >= P:
            self._slab.free(self._pages.pop(0))
            self._head -= P
        if self._n == 0 and self._head == 0 and self._pages:
            # fully drained on a page boundary: release the idle tail page too
            for p in self._pages:
                self._slab.free(p)
            self._pages.clear()

    def snapshot(self) -> dict:
        """Logical content only — a copy of the held rows, never page ids.

        Restoring allocates FRESH pages from whatever slab backs the target
        store (``_head`` restarts at 0); page boundaries shift but every
        logical stage is identical, which is all the session framing reads.
        """
        self._check_open()
        return {"rows": np.array(self.read(0, self._n), np.float32)}

    def restore(self, snap: dict) -> None:
        self._check_open()
        if self._n or self._pages:
            raise ValueError("restore() target store is not empty")
        self.append(np.asarray(snap["rows"], np.float32))

    def close(self) -> None:
        """Return every page to the slab; safe to call repeatedly."""
        if self._closed:
            return
        for p in self._pages:
            self._slab.free(p)
        self._pages.clear()
        self._head = self._n = 0
        self._closed = True

    # ---- internals -----------------------------------------------------------------
    def _check_open(self) -> None:
        if self._closed:
            raise ValueError("operation on a closed PagedSessionStore")
