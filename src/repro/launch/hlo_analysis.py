"""Post-SPMD HLO analyzer: FLOPs / bytes / collective traffic with correct
while-loop (scan) trip-count multipliers.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE regardless of
its trip count (verified empirically — a 10-iteration scanned matmul reports
1× the matmul FLOPs), which would under-count scan-over-layers models by the
layer count. This module parses the optimized HLO text instead:

* computations are split; each line is parsed into
  (result, type, opcode, operands, attrs) with a per-computation symbol
  table (operands carry no inline types in the scheduled HLO dialect);
* a multiplier is propagated from ENTRY through the call graph
  (``condition=/body=/to_apply=/calls=/branch_computations=``), multiplying
  by the trip count at every ``while`` — taken from the
  ``backend_config={"known_trip_count":{"n":...}}`` annotation (fallback:
  the loop condition's ``compare(·, constant(N)), direction=LT``);
* FLOPs: ``dot``/``convolution`` ops = 2 × output elements × contraction
  size (from lhs shape + lhs_contracting_dims) anywhere reachable;
* bytes: per top-level op (operands + output), excluding fusion-internal /
  reducer computations — an HBM-traffic model consistent with XLA's per-op
  accounting;
* collective bytes: operand sizes per collective kind.
"""

from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict

__all__ = ["analyze_hlo", "HloStats"]

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_LINE_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?[^=]*?)\s*([\w\-]+)\((.*)$"
)


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclasses.dataclass
class _Op:
    name: str
    type_str: str
    opcode: str
    rest: str  # operand list + attrs (raw tail after the opening paren)

    def operand_names(self) -> list[str]:
        # operands are %names (possibly none) before the closing paren at depth 0
        out, depth = [], 1
        token = ""
        for ch in self.rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            token += ch
        for part in token.split(","):
            part = part.strip()
            if part.startswith("%"):
                out.append(part.lstrip("%"))
            else:
                toks = part.split()
                if toks and toks[-1].startswith("%"):
                    out.append(toks[-1].lstrip("%"))
        return out


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: dict = dataclasses.field(default_factory=dict)
    collective_counts: dict = dataclasses.field(default_factory=dict)
    n_while: int = 0
    trip_counts: dict = dataclasses.field(default_factory=dict)
    bytes_by_opcode: dict = dataclasses.field(default_factory=dict)
    top_ops: list = dataclasses.field(default_factory=list)  # (bytes, opcode, name, comp)

    @property
    def total_collective_bytes(self) -> float:
        return float(sum(self.collective_bytes.values()))


def _split_computations(hlo: str):
    """name → (list[_Op], symbol table name→type)."""
    comps: dict[str, list[_Op]] = {}
    cur = None
    for raw in hlo.splitlines():
        line = re.sub(r"/\*.*?\*/", "", raw).strip()
        if not line:
            continue
        if line.endswith("{") and "->" in line and "=" not in line.split("(")[0]:
            toks = line.split()
            name = toks[1] if toks[0] == "ENTRY" else toks[0]
            cur = name.lstrip("%")
            comps[cur] = []
            continue
        if line == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _LINE_RE.match(line)
        if m:
            comps[cur].append(_Op(m.group(1), m.group(2), m.group(3), m.group(4)))
    return comps


def _entry_name(hlo: str, comps) -> str | None:
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo, re.M)
    if m:
        name = m.group(1)
        if name in comps:
            return name
    referenced = set()
    for ops in comps.values():
        for op in ops:
            for ref in re.finditer(r"(?:to_apply|calls|condition|body)=%?([\w\.\-]+)", op.rest):
                referenced.add(ref.group(1))
    for name in comps:
        if name not in referenced:
            return name
    return next(iter(comps), None)


def _while_trip(op: _Op, comps) -> int:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', op.rest)
    if m:
        return int(m.group(1))
    # fallback: scan the condition computation for compare-with-constant
    cm = re.search(r"condition=%?([\w\.\-]+)", op.rest)
    if cm and cm.group(1) in comps:
        consts = {}
        for cop in comps[cm.group(1)]:
            k = re.match(r"constant\((\d+)\)", cop.rest or "")
            if cop.opcode == "constant":
                v = re.search(r"^\s*(\d+)\s*\)", cop.rest)
                if v:
                    consts[cop.name] = int(v.group(1))
        for cop in comps[cm.group(1)]:
            if cop.opcode == "compare" and "direction=LT" in cop.rest:
                for o in cop.operand_names():
                    if o in consts:
                        return consts[o]
    return 1


def analyze_hlo(hlo: str) -> HloStats:
    comps = _split_computations(hlo)
    entry = _entry_name(hlo, comps)
    stats = HloStats(collective_bytes=defaultdict(float), collective_counts=defaultdict(int))
    if entry is None:
        return stats

    symtab = {name: {op.name: op.type_str for op in ops} for name, ops in comps.items()}

    # call graph with per-edge (multiplier, preserves-top-level?)
    edges: dict[str, list[tuple[str, float, bool]]] = defaultdict(list)
    for name, ops in comps.items():
        for op in ops:
            if op.opcode == "while":
                trip = _while_trip(op, comps)
                stats.n_while += 1
                bm = re.search(r"body=%?([\w\.\-]+)", op.rest)
                cm = re.search(r"condition=%?([\w\.\-]+)", op.rest)
                if bm:
                    edges[name].append((bm.group(1), float(trip), True))
                    stats.trip_counts[bm.group(1)] = trip
                if cm:
                    edges[name].append((cm.group(1), float(trip), False))
                continue
            top = op.opcode in ("call", "conditional", "async-start")
            for ref in re.finditer(r"(?:to_apply|calls|condition|body)=%?([\w\.\-]+)", op.rest):
                edges[name].append((ref.group(1), 1.0, top))
            bc = re.search(r"branch_computations=\{([^}]*)\}", op.rest)
            if bc:
                for x in bc.group(1).split(","):
                    edges[name].append((x.strip().lstrip("%"), 1.0, True))

    mult: dict[str, float] = defaultdict(float)
    is_top: dict[str, bool] = defaultdict(bool)
    stack = [(entry, 1.0, True)]
    visited = set()
    while stack:
        name, m, top = stack.pop()
        key = (name, round(m, 6), top)
        if key in visited or name not in comps:
            continue
        visited.add(key)
        mult[name] += m
        is_top[name] = is_top[name] or top
        for child, em, ctop in edges.get(name, []):
            stack.append((child, m * em, top and ctop))

    for name, ops in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        tab = symtab[name]
        top = is_top[name]
        for op in ops:
            if op.opcode in ("dot", "convolution"):
                out_elems = 1
                for d in _shape_dims(op.type_str):
                    out_elems *= d
                k = 1
                operands = op.operand_names()
                lhs_dims = _shape_dims(tab.get(operands[0], "")) if operands else []
                cd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
                if cd and cd.group(1):
                    for ci in cd.group(1).split(","):
                        ci = int(ci)
                        if ci < len(lhs_dims):
                            k *= lhs_dims[ci]
                elif op.opcode == "convolution" and len(operands) > 1:
                    rhs_dims = _shape_dims(tab.get(operands[1], ""))
                    k = max(int(abs(float(np_prod(rhs_dims))) // max(_shape_dims(op.type_str)[-1], 1)), 1) if rhs_dims else 1
                stats.flops += m * 2.0 * out_elems * k
            base = next((c for c in COLLECTIVES if op.opcode == c or op.opcode.startswith(c + "-")), None)
            if base and not op.opcode.endswith("-done"):
                b = sum(_shape_bytes(tab.get(o, "")) for o in op.operand_names())
                stats.collective_bytes[base] += m * b
                stats.collective_counts[base] += int(m)
            if top and op.opcode not in (
                "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
                "while", "call", "conditional", "after-all", "optimization-barrier",
            ):
                out_b = _shape_bytes(op.type_str)
                is_dus_fusion = op.opcode == "fusion" and "dynamic-update-slice" in op.name
                is_slice_fusion = op.opcode == "fusion" and (
                    "dynamic-slice" in op.name or "gather" in op.name
                ) and not is_dus_fusion
                if op.opcode in ("dynamic-slice", "slice", "gather") or is_slice_fusion:
                    # reads only the sliced region (≈ output), not the operand
                    b = 2.0 * out_b
                elif op.opcode in ("dynamic-update-slice", "scatter") or is_dus_fusion:
                    # read-modify-write of the updated region only — buffers
                    # as large as the output are aliased in place (donated
                    # scan carries) or sliced inside the fusion; only the
                    # small operands (update + indices) move. Floor at
                    # out/trips (a scan updates ~1/trips of the buffer/visit).
                    operand_bytes = [
                        _shape_bytes(tab.get(o, "")) for o in op.operand_names()
                    ]
                    if is_dus_fusion:
                        small = sum(ob for ob in operand_bytes if ob < 0.5 * out_b)
                        upd = max(small, out_b / max(m, 1.0))
                    elif len(operand_bytes) > 1:
                        upd = operand_bytes[1]
                    else:
                        upd = out_b
                    b = 2.0 * max(upd, 0.0)
                elif op.opcode in ("copy", "transpose", "reshape", "convert", "reverse",
                                   "concatenate", "broadcast", "iota", "reduce"):
                    in_b = sum(_shape_bytes(tab.get(o, "")) for o in op.operand_names())
                    b = out_b + min(in_b, 4 * out_b)  # cap pathological fan-in
                else:
                    in_b = sum(_shape_bytes(tab.get(o, "")) for o in op.operand_names())
                    b = out_b + in_b
                stats.bytes_accessed += m * b
                stats.bytes_by_opcode[op.opcode] = stats.bytes_by_opcode.get(op.opcode, 0.0) + m * b
                stats.top_ops.append((m * b, op.opcode, op.name, name))

    stats.collective_bytes = dict(stats.collective_bytes)
    stats.collective_counts = dict(stats.collective_counts)
    stats.top_ops = sorted(stats.top_ops, reverse=True)[:20]
    return stats


def np_prod(xs):
    p = 1
    for x in xs:
        p *= x
    return p
