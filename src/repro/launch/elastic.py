"""Elastic scaling: rebuild the mesh after node loss/gain and reshard state.

Strategy (single-controller JAX):
  * the `model` axis extent is fixed (TP degree is baked into layer math
    perf-wise, but **not** into the checkpoint — shards are reassembled to
    global arrays on restore, so even TP can change);
  * the (`pod` × `data`) product absorbs failures: losing a host rebuilds a
    mesh with a smaller `data` extent, restores the latest checkpoint with
    the new shardings, and rescales the data pipeline (`host_count` drops);
  * a failed step is retried from the last checkpoint — see
    launch/train.py's failure loop (tested with failure injection).

`plan_rescale` computes the largest valid mesh after losing `lost` chips;
`reshard` moves a live pytree onto a new mesh (host round-trip — the
simple, always-correct path; production would use device-to-device
resharding collectives).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np

from repro.sharding.rules import LogicalRules, tree_shardings

__all__ = [
    "plan_rescale",
    "plan_decode_rescale",
    "rescale_decode_engine",
    "reshard",
    "RescalePlan",
]


@dataclasses.dataclass(frozen=True)
class RescalePlan:
    old_shape: tuple
    new_shape: tuple
    axis_names: tuple
    dropped_chips: int

    @property
    def new_chip_count(self) -> int:
        return int(np.prod(self.new_shape))


def plan_rescale(
    mesh: jax.sharding.Mesh,
    lost_chips: int,
    *,
    shrink_axes: tuple[str, ...] | None = None,
) -> RescalePlan:
    """Largest mesh obtainable by shrinking ``shrink_axes`` after losing
    ``lost_chips`` devices.

    ``shrink_axes`` defaults to every axis except ``model`` (the train-mesh
    contract above: TP degree is baked into layer math). A decode fleet
    passes its engine's ``block_axes`` instead — the lane axis is the only
    thing a PBVD mesh shards, so those are the axes a casualty can shrink
    (see :func:`plan_decode_rescale`).

    The search maximizes the surviving chip count over ALL candidate
    shrink-axis shapes. The old implementation ``break``-ed out of a
    lexicographically descending enumeration at the first shape that fit,
    which is only the maximum when a single axis shrinks: with two 4-wide
    data-like axes and 7 chips lost it returned 4×2 = 8 chips when 3×3 = 9
    fit (the counterexample pinned in tests/test_fault_tolerance.py).
    """
    names = mesh.axis_names
    shape = dict(mesh.shape)
    total = int(np.prod(list(shape.values())))
    target = total - int(lost_chips)
    if shrink_axes is None:
        shrink_axes = tuple(n for n in names if n != "model")
    else:
        shrink_axes = tuple(shrink_axes)
        unknown = [a for a in shrink_axes if a not in shape]
        if unknown:
            raise ValueError(
                f"shrink_axes {unknown} not in mesh axes {tuple(names)}"
            )
    fixed = int(np.prod([shape[n] for n in names if n not in shrink_axes]))
    cur = [shape[n] for n in shrink_axes]
    best: tuple[int, ...] | None = None
    best_prod = 0

    def search(idx: int, acc: tuple[int, ...], prod: int) -> None:
        nonlocal best, best_prod
        # remaining axes contribute a factor >= 1 each, so prod*fixed is a
        # lower bound on the finished candidate — prune overshoots early
        if prod * fixed > target:
            return
        if idx == len(cur):
            if prod > best_prod:
                best, best_prod = acc, prod
            return
        for v in range(cur[idx], 0, -1):
            search(idx + 1, acc + (v,), prod * v)

    search(0, (), 1)
    if best is None:
        # even the all-ones shrink exceeds the survivors (fixed axes alone
        # are too big): report the degenerate minimum and let the caller
        # decide (the decode port drops to meshless dispatch)
        best = tuple(1 for _ in cur)
    new_shape = tuple(
        best[shrink_axes.index(n)] if n in shrink_axes else shape[n] for n in names
    )
    return RescalePlan(
        old_shape=tuple(shape[n] for n in names),
        new_shape=new_shape,
        axis_names=tuple(names),
        dropped_chips=total - int(np.prod(new_shape)),
    )


def plan_decode_rescale(
    mesh: jax.sharding.Mesh,
    block_axes: tuple[str, ...],
    lost_chips: int,
) -> RescalePlan | None:
    """Rescale plan for a decode-fleet mesh: only the engine's lane-carrying
    ``block_axes`` may shrink (every other axis is launch geometry the
    compiled decode depends on).

    Returns ``None`` when no valid smaller mesh exists — the survivors
    cannot host even the all-ones shrink — in which case the caller should
    drop to meshless dispatch (:func:`rescale_decode_engine` does).
    """
    plan = plan_rescale(mesh, lost_chips, shrink_axes=block_axes)
    total = int(np.prod(plan.old_shape))
    if plan.new_chip_count > total - int(lost_chips) or plan.new_chip_count < 1:
        return None
    return plan


def rescale_decode_engine(engine, lost_chips: int):
    """A replacement engine for ``engine`` after ``lost_chips`` devices died.

    Shrinks the mesh along the engine's ``block_axes`` per
    :func:`plan_decode_rescale` and rebuilds the engine on the smaller mesh;
    when no useful mesh survives (no plan, or a single-chip remnant whose
    sharding overhead buys nothing) the engine drops to meshless dispatch.
    Either way the decode is bit-exact to the original engine — the mesh
    only places lanes, it never changes what a launch computes — so a
    serving layer can swap engines under live sessions and replay their
    ready-but-undecoded blocks from session state (DESIGN.md §14).
    """
    from repro.core.engine import DecoderEngine
    from repro.launch.mesh import shrink_mesh

    if engine.mesh is None:
        return engine
    plan = plan_decode_rescale(engine.mesh, engine.block_axes, lost_chips)
    if plan is None or plan.new_chip_count < 2:
        return DecoderEngine(
            engine.cfg,
            mesh=None,
            block_axes=("data",),
            shard_dispatch=engine.shard_dispatch,
        )
    new_mesh = shrink_mesh(engine.mesh, plan.new_shape)
    return DecoderEngine(
        engine.cfg,
        mesh=new_mesh,
        block_axes=engine.block_axes,
        shard_dispatch=engine.shard_dispatch,
    )


def reshard(tree: Any, axes_tree: Any, new_mesh: jax.sharding.Mesh, rules_map=None) -> Any:
    """Move a pytree onto ``new_mesh`` with its logical axes re-resolved."""
    from repro.sharding.rules import DEFAULT_RULES, SINGLE_POD_RULES

    if rules_map is None:
        rules_map = DEFAULT_RULES if "pod" in new_mesh.axis_names else SINGLE_POD_RULES
    rules = LogicalRules(new_mesh, rules_map)
    shardings = tree_shardings(tree, axes_tree, rules)
    host = jax.tree.map(lambda l: np.asarray(l), tree)
    return jax.tree.map(jax.device_put, host, shardings)
