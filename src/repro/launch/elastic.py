"""Elastic scaling: rebuild the mesh after node loss/gain and reshard state.

Strategy (single-controller JAX):
  * the `model` axis extent is fixed (TP degree is baked into layer math
    perf-wise, but **not** into the checkpoint — shards are reassembled to
    global arrays on restore, so even TP can change);
  * the (`pod` × `data`) product absorbs failures: losing a host rebuilds a
    mesh with a smaller `data` extent, restores the latest checkpoint with
    the new shardings, and rescales the data pipeline (`host_count` drops);
  * a failed step is retried from the last checkpoint — see
    launch/train.py's failure loop (tested with failure injection).

`plan_rescale` computes the largest valid mesh after losing `lost` chips;
`reshard` moves a live pytree onto a new mesh (host round-trip — the
simple, always-correct path; production would use device-to-device
resharding collectives).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np

from repro.sharding.rules import LogicalRules, tree_shardings

__all__ = ["plan_rescale", "reshard", "RescalePlan"]


@dataclasses.dataclass(frozen=True)
class RescalePlan:
    old_shape: tuple
    new_shape: tuple
    axis_names: tuple
    dropped_chips: int

    @property
    def new_chip_count(self) -> int:
        return int(np.prod(self.new_shape))


def plan_rescale(mesh: jax.sharding.Mesh, lost_chips: int) -> RescalePlan:
    """Largest mesh obtainable by shrinking the data-ish axes after losing
    ``lost_chips`` devices (model axis preserved)."""
    names = mesh.axis_names
    shape = dict(mesh.shape)
    total = int(np.prod(list(shape.values())))
    target = total - lost_chips
    model = shape.get("model", 1)
    # shrink data (and pod if present) to the largest product that fits
    data_like = [n for n in names if n != "model"]
    best = None
    cur = [shape[n] for n in data_like]

    def candidates(idx, remaining):
        if idx == len(data_like):
            yield ()
            return
        for v in range(cur[idx], 0, -1):
            for rest in candidates(idx + 1, remaining):
                yield (v,) + rest

    for cand in candidates(0, target):
        prod = int(np.prod(cand)) * model
        if prod <= target:
            if best is None or prod > int(np.prod(best)) * model:
                best = cand
            break  # candidates are generated in decreasing order per axis
    if best is None:
        best = tuple(1 for _ in data_like)
    new_shape = tuple(
        best[data_like.index(n)] if n in data_like else model for n in names
    )
    return RescalePlan(
        old_shape=tuple(shape[n] for n in names),
        new_shape=new_shape,
        axis_names=names,
        dropped_chips=total - int(np.prod(new_shape)),
    )


def reshard(tree: Any, axes_tree: Any, new_mesh: jax.sharding.Mesh, rules_map=None) -> Any:
    """Move a pytree onto ``new_mesh`` with its logical axes re-resolved."""
    from repro.sharding.rules import DEFAULT_RULES, SINGLE_POD_RULES

    if rules_map is None:
        rules_map = DEFAULT_RULES if "pod" in new_mesh.axis_names else SINGLE_POD_RULES
    rules = LogicalRules(new_mesh, rules_map)
    shardings = tree_shardings(tree, axes_tree, rules)
    host = jax.tree.map(lambda l: np.asarray(l), tree)
    return jax.tree.map(jax.device_put, host, shardings)
