"""Generates the EXPERIMENTS.md §Perf iteration tables from report JSONs."""

from __future__ import annotations

import json
from pathlib import Path

from repro.launch.roofline import roofline_terms

ROOT = Path(__file__).resolve().parents[3] / "reports"


def _terms(path: str):
    p = ROOT / path
    if not p.exists():
        return None
    r = json.loads(p.read_text())
    if r.get("status") != "ok":
        return None
    t = roofline_terms(r)
    return t


def row(label, path, note=""):
    t = _terms(path)
    if t is None:
        return f"| {label} | — | — | — | — | — | {note} |"
    return (
        f"| {label} | {t['t_compute_s']:.3g} | {t['t_memory_s']:.3g} | "
        f"{t['t_collective_s']:.3g} | **{max(t['t_compute_s'], t['t_memory_s'], t['t_collective_s']):.3g}** "
        f"| {t.get('temp_gb','—')} / {t.get('args_gb','—')} | {note} |"
    )


HDR = "| iteration | compute s | memory s | collective s | step bound s | temp/args GB/dev | notes |\n|---|---|---|---|---|---|---|"


def main():
    print("### Cell 1 — deepseek-v2-236b × train_4k (16×16)\n")
    print(HDR)
    print(row("it0 baseline: FSDP-MoE, f32 gathers", "perf/deepseek__train_4k__baseline_fsdp_f32.json",
              "gathers all 236B params/pass"))
    print(row("it1-3 EP MoE (shard_map) + bf16 gathers + bf16 PV", "dryrun/deepseek-v2-236b__train_4k__pod16x16.json",
              "experts stay resident; 1 psum/layer"))
    print()
    print("### Cell 2 — command-r-35b × decode_32k (16×16)\n")
    print(HDR)
    print(row("it2 final, f32-at-rest weights (A/B)", "perf/commandr__decode_32k__baseline.json",
              "ctx-parallel + masked write"))
    print(row("it2 final, bf16-at-rest weights", "dryrun/command-r-35b__decode_32k__pod16x16.json",
              "weights are noise vs cache copies"))
    print()
    print("### Extra measurements\n")
    print(HDR)
    print(row("mixtral train baseline (FSDP, f32)", "perf/mixtral__train_4k__baseline_fsdp_f32.json", ""))
    print(row("mixtral train optimized", "dryrun/mixtral-8x22b__train_4k__pod16x16.json",
              "E=8<16: hidden-TP fallback (no EP)"))
    print(row("qwen train (default remat=full)", "dryrun/qwen2.5-32b__train_4k__pod16x16.json", ""))
    print(row("qwen train REPRO_REMAT=dots", "perf/qwen__train_4k__remat_dots.json",
              "−23% compute, +memory (see log)"))
    print()
    print("### Cell 3 — viterbi-ccsds × stream_16m_int8 (16×16)\n")
    print(HDR)
    print(row("two-kernel int8 (XLA artifact)", "dryrun/viterbi-ccsds__stream_16m_int8__pod16x16.json",
              "zero collectives"))


if __name__ == "__main__":
    main()
