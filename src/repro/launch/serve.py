"""Serving driver: batched greedy decoding through the production stack.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --preset tiny \
        --batch 4 --new-tokens 64

Uses the same mesh/rules machinery as training; on real hardware the mesh
comes from make_production_mesh and the KV cache shards per
serve_step.CTX_PARALLEL_THRESHOLD.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.launch.mesh import make_local_mesh
from repro._unused.models import lm
from repro._unused.serve.serve_step import make_decode_step
from repro.sharding.rules import axis_rules


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-3b")
    ap.add_argument("--preset", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.preset == "tiny":
        cfg = cfg.reduced()
    mesh = make_local_mesh()
    rng = np.random.default_rng(0)
    s_max = args.prompt_len + args.new_tokens

    with axis_rules(mesh):
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        cache = lm.init_cache(cfg, args.batch, s_max)
        step = jax.jit(make_decode_step(cfg, s_max))
        prompt = jnp.asarray(rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)

        for t in range(args.prompt_len):
            nxt, cache = step(params, prompt[:, t : t + 1], cache, jnp.int32(t))
        jax.block_until_ready(nxt)

        t0 = time.perf_counter()
        tok = nxt[:, None]
        outs = []
        for t in range(args.new_tokens):
            outs.append(tok)
            nxt, cache = step(params, tok, cache, jnp.int32(args.prompt_len + t))
            tok = nxt[:, None]
        jax.block_until_ready(tok)
        dt = time.perf_counter() - t0

    total = args.batch * args.new_tokens
    print(f"[serve] {cfg.name}: {total} tokens in {dt*1e3:.0f} ms "
          f"→ {total/dt:.0f} tok/s ({dt/args.new_tokens*1e3:.1f} ms/step)")


if __name__ == "__main__":
    main()
