"""ShapeDtypeStruct input specs for every (architecture × shape) dry-run cell.

No allocation happens here: params/caches come from ``jax.eval_shape`` over
the real init functions, inputs are literal ShapeDtypeStructs. The dry-run
lowers the exact train/prefill/decode step the runtime would execute.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ModelConfig, ShapeSpec, get_config
from repro._unused.models import lm

__all__ = ["Cell", "make_cell", "iter_cells", "SKIPS", "ENCODER_CTX", "input_specs"]

ENCODER_CTX = 4096  # enc-dec: encoder context length for decode shapes

# long_500k runs only for sub-quadratic-attention archs (DESIGN.md §7)
LONG_OK = {"mixtral-8x22b", "jamba-v0.1-52b", "rwkv6-3b"}

SKIPS: dict[tuple[str, str], str] = {}
for _a in [
    "seamless-m4t-medium", "qwen2.5-32b", "minitron-8b", "command-r-35b",
    "starcoder2-3b", "pixtral-12b", "deepseek-v2-236b",
]:
    SKIPS[(_a, "long_500k")] = "full-attention arch: 500k KV cache is the quadratic regime this shape excludes"


@dataclasses.dataclass
class Cell:
    arch: str
    shape: ShapeSpec
    kind: str  # train | prefill | decode
    cfg: ModelConfig

    def __str__(self):
        return f"{self.arch}×{self.shape.name}"


def make_cell(arch: str, shape_name: str) -> Cell:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    return Cell(arch=arch, shape=shape, kind=shape.kind, cfg=cfg)


def iter_cells(include_skips: bool = False):
    from repro.configs.base import list_archs

    for arch in list_archs():
        for shape_name in SHAPES:
            if (arch, shape_name) in SKIPS and not include_skips:
                continue
            yield make_cell(arch, shape_name)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def input_specs(cell: Cell) -> dict[str, Any]:
    """Returns {params, batch | (tokens, cache, cache_len), ...} as SDS pytrees."""
    cfg, shape = cell.cfg, cell.shape
    B, S = shape.global_batch, shape.seq_len
    specs: dict[str, Any] = {}
    specs["params"] = jax.eval_shape(lambda k: lm.init_params(k, cfg), jax.random.PRNGKey(0))
    if cell.kind in ("prefill", "decode") and os.environ.get("REPRO_SERVE_F32") != "1":
        # serving checkpoints hold bf16 weights at rest (f32 masters are a
        # training-time artifact); halves every weight read.
        specs["params"] = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
            if s.dtype == jnp.float32 and len(s.shape) >= 2
            else s,
            specs["params"],
        )

    if cell.kind in ("train", "prefill"):
        batch = {"tokens": _sds((B, S), jnp.int32)}
        if cell.kind == "train":
            batch["labels"] = _sds((B, S), jnp.int32)
        if cfg.frontend == "vision_patches":
            batch["patch_embeds"] = _sds((B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
        if cfg.encdec:
            batch["frames"] = _sds((B, S, cfg.d_model), jnp.bfloat16)
        specs["batch"] = batch
    else:  # decode: one token against a seq_len cache
        specs["tokens"] = _sds((B, 1), jnp.int32)
        cross = ENCODER_CTX if cfg.encdec else 0
        specs["cache"] = jax.eval_shape(
            lambda: lm.init_cache(cfg, B, S, dtype=jnp.bfloat16, cross_len=cross)
        )
        specs["cache_len"] = _sds((), jnp.int32)
    return specs
