"""Async decode serving: admission → paged slabs → deadline dispatch → delivery.

The kernels already turn coalesced blocks into Gb/s (decode_batch, radix-4 /
matrix ACS, mesh sharding); what they cannot do is absorb the arrival
jitter of real traffic — a synchronous serve loop either launches tiny
batches (latency-bound chunks arrive alone) or stalls streams (waiting for
a full batch). This module is the missing layer, four stages deep:

* **admission** — :meth:`AsyncStream.send` buffers a chunk into the
  stream's session state. Admission is bounded two ways: a cap on pool-wide
  ready-but-undecoded blocks (``max_pending_blocks``) and the symbol slab's
  fixed page budget (:class:`~repro.launch.slab.SymbolSlab`). Hitting
  either APPLIES BACKPRESSURE — the send awaits the next dispatch instead
  of growing a queue — or raises :class:`Backpressure` when the service is
  configured non-blocking.
* **paging** — per-stream symbol state (the overlap tail + puncture phase)
  lives in slab pages drawn from a shared free-list, so millions of
  short-lived streams reuse a constant pool of pages instead of churning
  per-session allocations (DESIGN.md §13).
* **deadline dispatch** — a :class:`DeadlineBatcher` fires
  ``SessionPool.step()`` when the pool has ``max_batch_blocks`` ready
  blocks (throughput trigger) OR the oldest undispatched chunk has waited
  ``deadline_ms`` (latency trigger), whichever comes first. The batcher is
  a pure function of an injectable clock, so trigger behaviour is testable
  under a fake clock with no sleeps.
* **delivery** — decoded bits land per stream (:meth:`AsyncStream.take` /
  the tail from :meth:`AsyncStream.finish`), and every admitted chunk's
  latency (admission → the step that decoded its last symbol) feeds the
  p50/p99 + sustained-Mb/s accounting in :meth:`AsyncDecodeService.metrics`.

Every decode goes through the same ``SessionPool`` launches as the
synchronous driver, so service output is bit-exact to per-stream one-shot
``engine.decode`` by the pool's existing invariant — the async layer only
decides WHEN ``step()`` runs, never what a launch contains.

    async with AsyncDecodeService(slab=SymbolSlab(256, 96, 2)) as svc:
        stream = svc.open(engine)
        await stream.send(chunk)           # backpressure-aware
        ...
        bits = await stream.finish(n_bits)  # take() fold + flushed tail

Failure behaviour (DESIGN.md §14): per-stream causes quarantine ONLY that
stream (its waiters get a typed :class:`~repro.launch.faults.StreamError`,
everyone else completes bit-exact); transient dispatch failures retry under
a bounded :class:`~repro.launch.faults.RetryPolicy`; device loss rebuilds a
smaller mesh (or drops to meshless) via
:func:`repro.launch.elastic.rescale_decode_engine` and replays in-flight
blocks from session state; capacity exhaustion past ``shed_deadline_ms``
sheds the admission instead of parking it forever; and an unexpected
dispatcher death propagates to every parked sender/finisher and resurfaces
from :meth:`AsyncDecodeService.aclose` — nothing hangs.
"""

from __future__ import annotations

import asyncio
import copy
import time
from collections import Counter, deque

import numpy as np

from repro.core.encoder import encoder_state
from repro.launch.faults import (
    CapacityError,
    DecodeError,
    DispatchError,
    FaultInjector,
    MeshLost,
    RetryPolicy,
    ShedError,
    StreamError,
    nonfinite_error,
)
from repro.launch.journal import ChunkJournal, IntegritySentinel
from repro.launch.serve_decoder import SessionPool
from repro.launch.slab import SlabExhausted, SymbolSlab

__all__ = [
    "Backpressure",
    "DeadlineBatcher",
    "AsyncStream",
    "AsyncDecodeService",
    "run_poisson_trace",
]


class Backpressure(CapacityError):
    """Admission refused: the service is at capacity (non-blocking mode)."""


class DeadlineBatcher:
    """The deadline-or-batch-size dispatch trigger, as a pure clocked object.

    ``note_feed()`` marks the arrival of the oldest currently-undispatched
    chunk; ``due(pending_blocks)`` answers "fire now?"; ``fired()`` resets
    the deadline arm after a dispatch. All time comes from the injected
    ``clock``, so a fake clock makes every trigger decision deterministic.

    Semantics (DESIGN.md §13): fire iff at least one block is ready AND
    (ready blocks ≥ ``max_batch_blocks`` OR the oldest undispatched chunk
    is ≥ ``deadline_s`` old). A dispatch clears the arm; chunks that were
    buffered but did not complete a block re-arm it on their stream's next
    feed.
    """

    def __init__(
        self,
        max_batch_blocks: int,
        deadline_s: float,
        *,
        clock=time.monotonic,
    ):
        if max_batch_blocks < 1:
            raise ValueError(f"max_batch_blocks must be ≥ 1, got {max_batch_blocks}")
        if deadline_s < 0:
            raise ValueError(f"deadline_s must be ≥ 0, got {deadline_s}")
        self.max_batch_blocks = max_batch_blocks
        self.deadline_s = deadline_s
        self._clock = clock
        self._oldest: float | None = None

    def note_feed(self) -> None:
        if self._oldest is None:
            self._oldest = self._clock()

    def due(self, pending_blocks: int) -> bool:
        if pending_blocks <= 0:
            return False
        if pending_blocks >= self.max_batch_blocks:
            return True
        return (
            self._oldest is not None
            and self._clock() - self._oldest >= self.deadline_s
        )

    def timeout(self) -> float | None:
        """Seconds until the deadline would fire (None = nothing armed)."""
        if self._oldest is None:
            return None
        return max(0.0, self.deadline_s - (self._clock() - self._oldest))

    def fired(self) -> None:
        self._oldest = None


class AsyncStream:
    """One stream's handle on an :class:`AsyncDecodeService`.

    Wraps a pooled session; decoded bits are drained with :meth:`take` (or
    folded into :meth:`finish`, same contract as ``PooledSession``). Tracks
    the admission time and buffered-stage watermark of every in-flight
    chunk for the service's latency accounting.
    """

    def __init__(self, service: "AsyncDecodeService", handle):
        self._service = service
        self._handle = handle
        self._inflight: deque[tuple[float, int]] = deque()  # (t_admit, watermark)
        self.finished = False
        self.failed: StreamError | None = None  # set when quarantined
        # ---- durability state (DESIGN.md §15) ----
        self.sid = -1  # journal stream id (assigned by the service's open())
        self.chunks_admitted = 0  # admitted chunks ever (resume cursor)
        self.bits_taken = 0  # client-visible bits returned by take()/finish()
        self.acked_bits = 0  # durable client watermark (journal "ack" records)
        self._retained: list[np.ndarray] = []  # taken-but-unacked (redeliverable)
        self._suppress = 0  # post-recovery bits the client already holds
        self._enc_state = 0  # encoder state after all delivered bits (sentinel)

    async def send(self, chunk) -> None:
        """Admit one chunk (backpressure-aware; see the module docstring).

        Raises this stream's :class:`StreamError` if it was quarantined, the
        service-wide failure if the dispatcher died, :class:`Backpressure` /
        :class:`ShedError` when capacity admission gives up.
        """
        await self._service._admit(self, chunk)

    def take(self, *, ack: bool = True) -> np.ndarray:
        """Drain every decoded bit delivered by dispatches so far.

        ``ack=True`` (default) also marks the bits as durably held by the
        client — the journal may forget them and recovery will not
        redeliver.  A client that persists bits itself should take with
        ``ack=False``, persist, then call :meth:`ack`: bits taken but
        unacked are retained service-side and redelivered after a crash.
        """
        if self.failed is not None:
            raise self.failed
        out = self._consume(self._handle.take())
        if ack:
            self.ack()
        elif len(out):
            self._retained.append(out)
        return out

    def ack(self) -> None:
        """Durably acknowledge every bit taken so far (journal watermark)."""
        self._retained.clear()
        if self.acked_bits != self.bits_taken:
            self.acked_bits = self.bits_taken
            self._service._journal_ack(self)

    def _consume(self, raw: np.ndarray) -> np.ndarray:
        """Client-position bookkeeping: swallow the post-recovery overlap
        (bits the client durably acked before the crash), then advance."""
        if self._suppress:
            cut = min(self._suppress, len(raw))
            raw = raw[cut:]
            self._suppress -= cut
        self.bits_taken += len(raw)
        return raw

    async def finish(self, n_bits: int | None = None) -> np.ndarray:
        """Flush the stream and release its slab pages; returns undrained
        delivery plus the tail, totalling ``n_bits`` with prior takes."""
        return await self._service._finish(self, n_bits)

    @property
    def bits_emitted(self) -> int:
        return self._handle.bits_emitted

    # ---- service internals ---------------------------------------------------------
    def _note_admitted(self, t: float) -> None:
        s = self._handle._session
        self._inflight.append((t, s._base + len(s._store)))

    def _complete_upto(self, now: float) -> None:
        """Resolve chunks whose every buffered stage is now decoded."""
        s = self._handle._session
        done_stages = s._blocks_done * s.cfg.D
        lats = self._service._latencies_s
        while self._inflight and self._inflight[0][1] <= done_stages:
            t, _ = self._inflight.popleft()
            lats.append(now - t)

    def _drain_inflight(self, now: float) -> None:
        lats = self._service._latencies_s
        while self._inflight:
            t, _ = self._inflight.popleft()
            lats.append(now - t)


class AsyncDecodeService:
    """The asyncio front-end over a :class:`SessionPool` (module docstring).

    Parameters
    ----------
    max_batch_blocks: ready blocks that trigger an immediate dispatch.
    deadline_ms: max age of the oldest undispatched chunk before a dispatch
        fires anyway (the tail-latency knob).
    max_pending_blocks: admission cap on pool-wide ready-but-undecoded
        blocks (default ``4 × max_batch_blocks``); senders beyond it wait.
    slab: shared :class:`SymbolSlab` for paged session state (None = each
        session keeps the default per-session array store).
    clock: time source for the batcher, latency accounting, retry backoff
        and the shed deadline. With a fake clock, drive dispatch
        synchronously via :meth:`poll` — the background task's waits use
        real event-loop time.
    block_on_backpressure: False turns waiting senders into
        :class:`Backpressure` raises (admission-control mode).
    retry: :class:`~repro.launch.faults.RetryPolicy` bounding dispatch
        retries; backoff is armed against ``clock`` (no real sleeping), so
        the whole retry schedule is fake-clock deterministic.
    shed_deadline_ms: load-shedding deadline — a sender whose capacity wait
        (pending-block cap or slab pages) spans this long sheds with
        :class:`~repro.launch.faults.ShedError` instead of parking forever.
        None (default) parks indefinitely, the pre-fault behaviour.
    fault_injector: a :class:`~repro.launch.faults.FaultInjector` consulted
        at the admission / slab / dispatch / mesh / open / decode_corrupt
        boundaries (chaos testing + the degraded-mode benchmark). None
        injects nothing.
    journal: a :class:`~repro.launch.journal.ChunkJournal` making the
        service crash-safe (DESIGN.md §15): admitted chunks, delivered-bit
        acks, and dispatch commits are write-ahead logged, and per-stream
        session state checkpoints every ``checkpoint_every`` dispatches.
        After a crash, :meth:`recover` rebuilds the service bit-exact. None
        (default) serves ephemerally, the pre-PR-10 behaviour.
    checkpoint_every: dispatches between periodic checkpoints (with a
        journal); each checkpoint truncates the superseded log. 0/None
        disables periodic checkpoints (the journal alone still recovers —
        replay just starts further back).
    integrity_rate: probability that a delivered block span is screened by
        the re-encode integrity sentinel (0.0 = off, the default; 1.0 =
        every delivery). A flagged stream quarantines with a typed
        :class:`~repro.launch.faults.IntegrityError` exactly like any other
        per-stream fault.
    integrity_min_agreement: the sentinel's re-encode agreement bound
        (see DESIGN.md §15 for the derivation of the 0.85 default).
    integrity_seed: seed for the sentinel's sampling rng.
    on_dispatch: optional callback ``on_dispatch(service)`` invoked after
        every completed dispatch (the crash-drill kill hook; also handy for
        external metrics scrapes).
    """

    def __init__(
        self,
        *,
        max_batch_blocks: int = 32,
        deadline_ms: float = 5.0,
        max_pending_blocks: int | None = None,
        slab: SymbolSlab | None = None,
        clock=time.monotonic,
        block_on_backpressure: bool = True,
        retry: RetryPolicy | None = None,
        shed_deadline_ms: float | None = None,
        fault_injector: FaultInjector | None = None,
        journal: ChunkJournal | None = None,
        checkpoint_every: int | None = 16,
        integrity_rate: float = 0.0,
        integrity_min_agreement: float = 0.85,
        integrity_seed: int = 0,
        on_dispatch=None,
    ):
        self._pool = SessionPool()
        self._slab = slab
        self._clock = clock
        self._batcher = DeadlineBatcher(
            max_batch_blocks, deadline_ms / 1e3, clock=clock
        )
        self.max_pending_blocks = (
            max_pending_blocks if max_pending_blocks is not None else 4 * max_batch_blocks
        )
        if self.max_pending_blocks < 1:
            raise ValueError(
                f"max_pending_blocks must be ≥ 1, got {self.max_pending_blocks}"
            )
        self.block_on_backpressure = block_on_backpressure
        self.retry = retry if retry is not None else RetryPolicy()
        self.shed_deadline_ms = shed_deadline_ms
        self._injector = fault_injector
        if fault_injector is not None:
            self._pool.fault_hook = self._fault_hook
        self._streams: list[AsyncStream] = []
        self._by_handle: dict[object, AsyncStream] = {}
        self._poisoned: set = set()  # handles marked by the stream_poison site
        self._latencies_s: list[float] = []
        self._work = asyncio.Event()  # a chunk was admitted
        self._space = asyncio.Event()  # a dispatch freed capacity/pages
        self._task: asyncio.Task | None = None
        self._closing = False
        self.dispatches = 0
        self._t_first: float | None = None
        self._t_last: float | None = None
        self._bits_delivered = 0
        # ---- failure-model state (DESIGN.md §14) ----
        self._failure: DecodeError | None = None  # service-fatal, surfaced everywhere
        self._retry_at: float | None = None  # clock time before which poll() waits
        self._attempts = 0  # consecutive failed dispatch attempts
        self._errors_by_class: Counter[str] = Counter()
        self.retries = 0
        self.shed_blocks = 0
        self.quarantined_streams = 0
        # ---- durability + integrity state (DESIGN.md §15) ----
        self._journal = journal
        self.checkpoint_every = checkpoint_every
        self._sentinel = (
            IntegritySentinel(
                rate=integrity_rate,
                min_agreement=integrity_min_agreement,
                seed=integrity_seed,
            )
            if integrity_rate > 0.0
            else None
        )
        self.on_dispatch = on_dispatch
        self._by_sid: dict[int, AsyncStream] = {}
        self._next_sid = 0
        self._recovering = False  # replay in progress: suppress re-journaling
        self.checkpoints_written = 0
        self.recovered_streams: dict[int, AsyncStream] = {}

    # ---- lifecycle -----------------------------------------------------------------
    async def __aenter__(self) -> "AsyncDecodeService":
        self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.aclose()

    def start(self) -> None:
        """Start the background dispatcher task (idempotent; must be called
        from inside a running event loop — fake-clock tests skip it and
        drive :meth:`poll` directly)."""
        if self._task is None:
            self._task = asyncio.get_running_loop().create_task(self._run())

    async def aclose(self) -> None:
        """Stop dispatching; flush nothing (streams own their finish).

        If the dispatcher died with a service-fatal error, it re-raises here
        — a crashed service never closes silently.
        """
        self._closing = True
        self._space.set()  # wake blocked senders so they observe the close
        if self._task is not None:
            self._task.cancel()
            try:
                await self._task
            except asyncio.CancelledError:
                pass
            self._task = None
        if self._failure is not None:
            raise self._failure

    def open(self, engine, *, interpret: bool | None = None) -> AsyncStream:
        """Admit a new stream; its session state pages out of the slab."""
        if self._failure is not None:
            raise self._failure
        if self._closing:
            raise RuntimeError("service is closing")
        store = self._slab.open_store() if self._slab is not None else None
        handle = self._pool.open(engine, interpret=interpret, store=store)
        stream = AsyncStream(self, handle)
        stream.sid = self._next_sid
        self._next_sid += 1
        self._streams.append(stream)
        self._by_handle[handle] = stream
        self._by_sid[stream.sid] = stream
        if self._journal is not None and not self._recovering:
            self._journal.append("open", stream.sid)
        if self._injector is not None and self._injector.fire("stream_poison"):
            # this stream's symbols will reproducibly kill any launch that
            # contains them (the bisection protocol isolates it)
            self._poisoned.add(handle)
        return stream

    # ---- dispatch ------------------------------------------------------------------
    def poll(self) -> bool:
        """Fire one coalesced dispatch if the trigger is due; returns whether
        it fired. The background task calls this; fake-clock tests drive it
        directly for deterministic trigger sequences.

        A failed dispatch arms ``_retry_at`` (retry backoff on the injected
        clock); until the clock passes it no new dispatch fires, and once it
        does the retry fires regardless of the batcher — the pending blocks
        that triggered the original dispatch are still there.
        """
        if self._failure is not None:
            return False
        if self._retry_at is not None:
            if self._clock() < self._retry_at:
                return False
            self._retry_at = None
            self._dispatch()
            return True
        if not self._batcher.due(self._pool.pending_blocks()):
            return False
        self._dispatch()
        return True

    def _dispatch(self) -> None:
        """One coalesced step under the failure model (DESIGN.md §14).

        Success resets the retry state. A transient failure arms a bounded
        exponential-backoff retry; retries exhausted (or a typed
        :class:`StreamError`) escalate to the pool's bisection protocol,
        which quarantines culprit streams while the rest deliver bit-exact.
        :class:`MeshLost` rebuilds the fleet's engines on a smaller mesh (or
        meshless) and replays the in-flight blocks on the next poll. An
        exception escaping even the isolation step is service-fatal and
        propagates (the background task turns it into ``_fail_service``).
        """
        self.dispatches += 1
        self._batcher.fired()
        checks = (
            self._sentinel_capture()
            if self._sentinel is not None and not self._recovering
            else []
        )
        before = {id(st): st._handle.bits_emitted for st in self._streams}
        qmarks = {id(st): len(st._handle._queue) for st in self._streams}
        try:
            self._pool.step()
        except MeshLost as exc:
            self._count_error(exc)
            self._handle_mesh_loss(exc)
            self.retries += 1
            self._retry_at = self._clock()  # replay on the next poll
            return
        except StreamError as exc:
            # a typed per-stream fault: retrying the same batch would fail
            # the same way, so go straight to isolation
            self._count_error(exc)
            self._pool.step(isolate=True)
            self._attempts = 0
        except Exception as exc:  # noqa: BLE001 - classify, don't mask
            self._count_error(exc)
            if self._attempts < self.retry.max_retries:
                self._attempts += 1
                self.retries += 1
                self._retry_at = self._clock() + self.retry.delay_s(self._attempts - 1)
                return
            # retries exhausted: a deterministic fault — bisect it out; if
            # even single-member launches fail, every member quarantines and
            # the pool drains rather than wedging the service
            self._attempts = 0
            self._pool.step(isolate=True)
        else:
            self._attempts = 0
        self._retry_at = None
        now = self._clock()
        delivered = sum(
            st._handle.bits_emitted - before[id(st)]
            for st in self._streams
            if id(st) in before
        )
        if delivered:
            self._bits_delivered += delivered
            self._t_last = now
        # ---- end-to-end integrity pipeline (DESIGN.md §15): the
        # decode_corrupt fault site mutates freshly delivered bits, the
        # sentinel screens them against the pre-step soft symbols, and the
        # per-stream encoder state folds forward over whatever was (really)
        # delivered — corrupted or not, the state must follow the bits the
        # client will see
        new_bits = self._collect_new_bits(qmarks)
        for st, window, code, state0 in checks:
            bits = new_bits.get(id(st))
            if st.failed is not None or bits is None:
                continue
            err = self._sentinel.check(bits, window, code, state0, stream=st._handle)
            if err is not None:
                self._count_error(err)
                self._fail_stream(st, err)
        for st in self._streams:
            bits = new_bits.get(id(st))
            if bits is not None and len(bits):
                st._enc_state = encoder_state(
                    bits, st._handle._session.spec.code, st._enc_state
                )
        for stream in self._streams:
            stream._complete_upto(now)
        for ps, err in self._pool.drain_quarantined():
            st = self._by_handle.get(ps)
            if st is not None:
                self._fail_stream(st, err)
        if self._journal is not None and not self._recovering:
            self._journal.append("commit", self.dispatches)
            if self.checkpoint_every and self.dispatches % self.checkpoint_every == 0:
                self._checkpoint()
        self._space.set()  # decoded blocks dropped pages + pending count
        if self.on_dispatch is not None and not self._recovering:
            self.on_dispatch(self)

    async def _run(self) -> None:
        try:
            while True:
                self._work.clear()
                timeout = self._next_timeout()
                if timeout is None:
                    await self._work.wait()
                else:
                    try:
                        await asyncio.wait_for(self._work.wait(), timeout)
                    except asyncio.TimeoutError:
                        pass
                self.poll()
                # yield so delivery consumers run between dispatches
                await asyncio.sleep(0)
        except asyncio.CancelledError:
            raise
        except BaseException as exc:  # noqa: BLE001 - the stranded-waiter fix
            # the dispatcher must NEVER die silently: senders parked in
            # _wait_for_space and finishers would hang forever. Record the
            # failure, wake every waiter (they re-check and raise), and let
            # aclose() re-raise it to the caller.
            self._fail_service(exc)

    def _next_timeout(self) -> float | None:
        """Sleep bound for the dispatcher: deadline arm and/or retry backoff."""
        t = self._batcher.timeout() if self._pool.pending_blocks() > 0 else None
        if self._retry_at is not None:
            r = max(0.0, self._retry_at - self._clock())
            t = r if t is None else min(t, r)
        return t

    # ---- failure handling ----------------------------------------------------------
    def _count_error(self, exc: BaseException) -> None:
        self._errors_by_class[type(exc).__name__] += 1

    def _fault_hook(self, entries, isolating: bool) -> None:
        """The pool's pre-launch injection point (``FaultInjector`` wiring).

        Transient dispatch/mesh faults are suppressed while the pool is
        bisecting — they model launch-level weather, and firing them
        mid-isolation would quarantine innocent streams. Poisoned-stream
        faults fire always: they model symbols that reproducibly kill any
        launch containing them, which is exactly what bisection isolates.
        """
        inj = self._injector
        if inj is None:
            return
        if not isolating:
            if inj.fire("mesh"):
                raise MeshLost(
                    "injected: device loss during dispatch",
                    lost_chips=inj.mesh_lost_chips,
                )
            if inj.fire("dispatch"):
                raise DispatchError("injected: transient launch failure")
        for ps, _ in entries:
            if ps in self._poisoned:
                raise StreamError(
                    "injected: poisoned stream symbols in the coalesced batch",
                    stream=ps,
                )

    def _handle_mesh_loss(self, exc: MeshLost) -> None:
        """Rebuild every meshed engine in the fleet on a post-loss mesh.

        Uses :func:`repro.launch.elastic.rescale_decode_engine` (the decode
        port of the trainer's ``plan_rescale``): shrink the engine's
        ``block_axes``, or drop to meshless dispatch when nothing useful
        survives. Sessions are repointed in place; their ready-but-undecoded
        blocks replay on the retried dispatch, bit-exact to the
        uninterrupted run (the mesh only places independent lanes).
        """
        from repro.launch.elastic import rescale_decode_engine

        engines, seen = [], set()
        for st in self._streams:
            eng = st._handle._session.engine
            if eng.mesh is not None and id(eng) not in seen:
                seen.add(id(eng))
                engines.append(eng)
        for eng in engines:
            self._pool.repoint_engine(eng, rescale_decode_engine(eng, exc.lost_chips))

    def _fail_stream(self, stream: AsyncStream, err: StreamError) -> None:
        """Quarantine one stream: typed failure to its waiters, pages freed.

        Idempotent. The slab pages are released (and zeroed, per the slab
        contract) so capacity poisoned streams held flows back to healthy
        admissions — hence the final ``_space.set()``.
        """
        if stream.failed is not None:
            return
        stream.failed = err
        stream.finished = True
        stream._inflight.clear()  # failed chunks are not latency samples
        self._pool.close(stream._handle)
        self._poisoned.discard(stream._handle)
        stream._handle._session.close()  # slab pages → free-list (zeroed)
        if stream in self._streams:
            self._streams.remove(stream)
        self._by_handle.pop(stream._handle, None)
        self._by_sid.pop(stream.sid, None)
        self.quarantined_streams += 1
        if self._journal is not None and not self._recovering:
            # replay drops the stream instead of re-feeding a known-bad one
            self._journal.append("fail", stream.sid, str(err))
        self._space.set()  # freed pages may unblock parked senders

    def _fail_service(self, exc: BaseException) -> None:
        """Mark the whole service failed; every waiter observes it."""
        if self._failure is not None:
            return
        if isinstance(exc, DecodeError):
            err = exc
        else:
            err = DispatchError(f"decode service dispatcher died: {exc!r}")
            err.__cause__ = exc
        self._failure = err
        self._count_error(err)
        self._space.set()  # parked senders wake → _check_live raises
        self._work.set()

    # ---- durability + integrity (DESIGN.md §15) --------------------------------------
    def _journal_ack(self, stream: AsyncStream) -> None:
        if self._journal is not None and not self._recovering:
            self._journal.append("ack", stream.sid, stream.acked_bits)

    def _checkpoint(self) -> None:
        """Atomically persist every live stream's session state + the
        unacked delivery tail; truncates the superseded journal log."""
        if self._journal is None:
            return
        streams = {}
        for st in self._streams:
            s = st._handle._session
            streams[st.sid] = dict(
                session=s.snapshot(),
                # the UNACKED tail: taken-but-unacked bits rejoin the queue
                # so recovery redelivers everything past the ack watermark
                queue=[np.asarray(a) for a in (*st._retained, *st._handle._queue)],
                handle_bits=st._handle.bits_emitted,
                acked=st.acked_bits,
                enc_state=st._enc_state,
                chunks_admitted=st.chunks_admitted,
            )
        self._journal.write_checkpoint(
            dict(dispatches=self.dispatches, streams=streams)
        )
        self.checkpoints_written += 1

    def _sentinel_capture(self) -> list[tuple]:
        """Pre-step capture for the re-encode sentinel: each sampled
        stream's about-to-decode soft-symbol span (the commit will drop it
        from the store) plus its encoder state at the span's first stage."""
        checks = []
        for st in self._streams:
            s = st._handle._session
            b1 = s.ready_blocks()
            if b1 <= s._blocks_done or not self._sentinel.sample():
                continue
            D = s.cfg.D
            lo = s._blocks_done * D - s._base  # = min(blocks_done·D, L) ≥ 0
            window = np.array(
                s._store.read(lo, (b1 - s._blocks_done) * D), np.float32
            )
            checks.append((st, window, s.spec.code, st._enc_state))
        return checks

    def _collect_new_bits(self, qmarks: dict) -> dict[int, np.ndarray]:
        """Bits THIS dispatch delivered per stream (delivery-queue growth
        past the pre-step mark), with the ``decode_corrupt`` fault site
        applied in place — silent corruption strikes after the kernel."""
        out = {}
        for st in list(self._streams):
            k = qmarks.get(id(st))
            if k is None:
                continue
            new = st._handle._queue[k:]
            if not new:
                continue
            if self._injector is not None and self._injector.fire("decode_corrupt"):
                # one delivered payload bit flips, silently — in the QUEUE
                # itself (the client takes the corrupt bit; only the
                # sentinel can notice), via a copy: queue arrays may be
                # read-only views of device output
                first = np.array(new[0])
                first[0] ^= 1
                new[0] = st._handle._queue[k] = first
            out[id(st)] = np.concatenate(new) if len(new) > 1 else new[0]
        return out

    @classmethod
    def recover(
        cls,
        journal: ChunkJournal,
        engine,
        *,
        interpret: bool | None = None,
        **service_kwargs,
    ) -> "AsyncDecodeService":
        """Rebuild a service from ``journal`` after a crash (DESIGN.md §15).

        Restores every checkpointed stream's session into fresh (slab)
        stores, then replays the unapplied journal records in admission
        order — re-feeding unacked chunks, re-applying ack watermarks, and
        dropping finished/quarantined streams.  Block independence makes
        the continuation bit-exact: recovered streams deliver exactly the
        bits past each client's ack watermark that the uninterrupted run
        would have delivered.

        ``engine`` is the decode engine for every recovered stream (engines
        hold meshes/compiled state and are not serializable; a restarted
        process rebuilds them the same way it did originally).  Recovered
        streams are exposed in :attr:`recovered_streams` keyed by their
        stable ``sid`` — assigned in ``open()`` order, so a driver that
        opens its streams deterministically can rebind them. Ends with a
        fresh checkpoint, so a crash during a long replay never compounds.
        """
        svc = cls(journal=journal, **service_kwargs)
        svc._recovering = True
        try:
            ckpt, records = journal.load()
            if ckpt is not None:
                svc.dispatches = int(ckpt.get("dispatches", 0))
                for sid in sorted(ckpt["streams"]):
                    svc._restore_stream(
                        int(sid), engine, ckpt["streams"][sid], interpret=interpret
                    )
            for rec in records:
                svc._replay(rec, engine, interpret)
        finally:
            svc._recovering = False
        svc.recovered_streams = dict(svc._by_sid)
        svc._checkpoint()  # collapse the replay: a re-crash replays nothing
        if svc._pool.pending_blocks() > 0:
            svc._batcher.note_feed()  # replayed blocks are ready: arm dispatch
            svc._work.set()
        return svc

    def _restore_stream(
        self, sid: int, engine, snap: dict, *, interpret: bool | None = None
    ) -> AsyncStream:
        store = self._slab.open_store() if self._slab is not None else None
        handle = self._pool.open(engine, interpret=interpret, store=store)
        handle._session.restore(snap["session"])
        handle._queue.extend(np.asarray(a) for a in snap["queue"])
        handle.bits_emitted = int(snap["handle_bits"])
        stream = AsyncStream(self, handle)
        stream.sid = sid
        # the client's position restarts at the checkpoint's ack watermark;
        # replayed ack records past it turn into suppression below
        stream.bits_taken = stream.acked_bits = int(snap["acked"])
        stream._enc_state = int(snap["enc_state"])
        stream.chunks_admitted = int(snap["chunks_admitted"])
        self._streams.append(stream)
        self._by_handle[handle] = stream
        self._by_sid[sid] = stream
        self._next_sid = max(self._next_sid, sid + 1)
        return stream

    def _replay(self, rec: tuple, engine, interpret: bool | None) -> None:
        """Apply one journal record during :meth:`recover`."""
        _seq, kind, *fields = rec
        if kind == "open":
            (sid,) = fields
            if sid in self._by_sid:
                return
            self._next_sid = max(self._next_sid, int(sid))
            st = self.open(engine, interpret=interpret)
            assert st.sid == sid, f"replayed open sid {sid} != assigned {st.sid}"
        elif kind == "admit":
            sid, chunk = fields
            st = self._by_sid.get(sid)
            if st is None or st.failed is not None or st.finished:
                return
            self._feed_replay(st, np.asarray(chunk))
        elif kind == "ack":
            sid, acked = fields
            st = self._by_sid.get(sid)
            if st is None:
                return
            gap = int(acked) - st.acked_bits
            if gap > 0:
                # the client durably holds these bits: swallow them instead
                # of redelivering (the no-duplicate-delivery invariant)
                st._suppress += gap
                st.acked_bits = st.bits_taken = int(acked)
        elif kind == "finish":
            (sid,) = fields
            st = self._by_sid.pop(sid, None)
            if st is None:
                return
            st.finished = True
            self._pool.close(st._handle)
            st._handle._session.close()
            if st in self._streams:
                self._streams.remove(st)
            self._by_handle.pop(st._handle, None)
        elif kind == "fail":
            sid, msg = fields
            st = self._by_sid.get(sid)
            if st is not None:
                self._fail_stream(st, StreamError(f"recovered quarantine: {msg}"))
        elif kind == "commit":
            (dispatches,) = fields
            self.dispatches = max(self.dispatches, int(dispatches))
        # unknown kinds are skipped: an older journal replays under a newer
        # service as long as the kinds it DID write still mean the same

    def _feed_replay(self, st: AsyncStream, chunk: np.ndarray) -> None:
        """Re-feed a journaled chunk, retiring slab pages via a dispatch on
        exhaustion exactly like live backpressure would have."""
        try:
            try:
                st._handle.feed(chunk)
            except SlabExhausted:
                if self._pool.pending_blocks() <= 0:
                    raise
                self._dispatch()  # frees committed pages, as a live wait would
                st._handle.feed(chunk)
        except StreamError as err:
            # deterministically bad symbols fail on replay exactly as they
            # did live: quarantine and move on
            self._count_error(err)
            self._fail_stream(st, err)
            return
        st.chunks_admitted += 1
        self._batcher.note_feed()

    # ---- admission -----------------------------------------------------------------
    def _check_live(self, stream: AsyncStream) -> None:
        """Raise the most specific standing failure before touching state."""
        if stream.failed is not None:
            raise stream.failed
        if self._failure is not None:
            raise self._failure
        if self._closing:
            raise RuntimeError("service is closing")

    async def _admit(self, stream: AsyncStream, chunk) -> None:
        if stream.finished and stream.failed is None:
            raise ValueError("send() on a finished stream")
        self._check_live(stream)
        if self._injector is not None and self._injector.fire("admission"):
            err = nonfinite_error("send() [injected]", 1, int(np.size(chunk)) or 1)
            self._count_error(err)
            self._fail_stream(stream, err)
            raise err
        t0 = self._clock()  # the shed deadline spans the WHOLE admission
        while True:
            self._check_live(stream)
            if self._pool.pending_blocks() >= self.max_pending_blocks:
                await self._wait_for_space("pending-block cap", t0)
                continue
            try:
                if self._injector is not None and self._injector.fire("slab"):
                    exc = SlabExhausted("injected: slab pages exhausted")
                    exc.injected = True
                    raise exc
                # session ingest is atomic w.r.t. slab exhaustion: page
                # capacity is reserved before any symbol is written, so a
                # failed admit can simply retry after the next dispatch
                stream._handle.feed(chunk)
            except SlabExhausted as exc:
                self._count_error(exc)
                if self._pool.pending_blocks() <= 0:
                    if getattr(exc, "injected", False):
                        continue  # synthetic fault, nothing to free: re-admit
                    # nothing a dispatch could free — the chunk cannot fit
                    raise
                await self._wait_for_space("slab pages", t0)
                continue
            except StreamError as err:
                # engine-boundary validation (non-finite or shape-invalid
                # symbols): per-stream poison — quarantine it, nobody else
                # is touched and the rejected chunk never entered the buffer
                self._count_error(err)
                self._fail_stream(stream, err)
                raise
            break
        # WAL the admitted chunk BEFORE admission completes (before the
        # chunk becomes dispatchable). Logging after the feed keeps shed/
        # quarantined admissions out of the journal; a crash in the gap
        # just loses an unconfirmed send() — the client's resume cursor
        # (chunks_admitted, derived from this record) re-sends it.
        if self._journal is not None and not self._recovering:
            try:
                self._journal.append("admit", stream.sid, np.asarray(chunk))
            except OSError as exc:  # durability broken → the service is dead
                self._fail_service(exc)
                raise self._failure from exc
        stream.chunks_admitted += 1
        now = self._clock()
        if self._t_first is None:
            self._t_first = now
        stream._note_admitted(now)
        self._batcher.note_feed()
        self._work.set()

    async def _wait_for_space(self, why: str, t0: float) -> None:
        if not self.block_on_backpressure:
            exc = Backpressure(f"admission refused: {why} exhausted")
            self._count_error(exc)
            raise exc
        if (
            self.shed_deadline_ms is not None
            and (self._clock() - t0) * 1e3 >= self.shed_deadline_ms
        ):
            exc = ShedError(
                f"admission shed: {why} still exhausted after "
                f"{self.shed_deadline_ms} ms"
            )
            self._count_error(exc)
            self.shed_blocks += 1
            raise exc
        self._space.clear()
        self._work.set()  # ensure the dispatcher wakes to make progress
        if self.shed_deadline_ms is None:
            await self._space.wait()
            return
        # real-time backstop so a stalled dispatcher cannot outlive the shed
        # deadline; the deterministic check above (injected clock) decides
        remaining = self.shed_deadline_ms / 1e3 - (self._clock() - t0)
        try:
            await asyncio.wait_for(self._space.wait(), max(0.0, remaining))
        except asyncio.TimeoutError:
            pass

    async def _finish(self, stream: AsyncStream, n_bits: int | None) -> np.ndarray:
        self._check_live(stream)
        if stream.finished:
            raise ValueError("finish() called twice on one stream")
        before = stream._handle.bits_emitted
        cap = None
        if self._sentinel is not None and not self._recovering:
            s = stream._handle._session
            nb, _n_blocks, prior = s._finish_plan(n_bits)
            if nb > prior and self._sentinel.sample():
                # flush-tail capture: the store may be short of the padded
                # window — check() treats missing stages as excluded zeros
                cap = (
                    np.array(s._store.read(prior - s._base, nb - prior), np.float32),
                    s.spec.code,
                    stream._enc_state,
                )
        attempt = 0
        while True:
            try:
                bits = stream._handle.finish(n_bits)  # take() fold + flush plan
                break
            except StreamError as err:
                # the stream's own flush launch is what fails: quarantine it
                self._count_error(err)
                self._fail_stream(stream, err)
                raise err from None
            except CapacityError:
                raise  # a flush never allocates; surface allocator bugs loudly
            except MeshLost as exc:
                self._count_error(exc)
                self._handle_mesh_loss(exc)
                self.retries += 1
                continue  # flush replays on the rebuilt engine, bit-exact
            except Exception as exc:  # noqa: BLE001 - transient flush failure
                self._count_error(exc)
                if attempt >= self.retry.max_retries:
                    err = StreamError(
                        f"stream flush failed after {attempt} retries ({exc!r})"
                    )
                    err.__cause__ = exc
                    self._fail_stream(stream, err)
                    raise err from exc
                await asyncio.sleep(self.retry.delay_s(attempt))
                attempt += 1
                self.retries += 1
        if cap is not None:
            tail_len = stream._handle.bits_emitted - before
            tail = bits[len(bits) - tail_len :] if tail_len else bits[:0]
            err = self._sentinel.check(
                tail, cap[0], cap[1], cap[2], stream=stream._handle
            )
            if err is not None:
                self._count_error(err)
                self._fail_stream(stream, err)
                raise err
        bits = stream._consume(bits)
        stream._retained.clear()
        if stream.acked_bits != stream.bits_taken:
            # finish() is the terminal hand-off: returning implies delivery
            stream.acked_bits = stream.bits_taken
            self._journal_ack(stream)
        if self._journal is not None and not self._recovering:
            self._journal.append("finish", stream.sid)
        now = self._clock()
        self._bits_delivered += stream._handle.bits_emitted - before
        self._t_last = now
        stream._drain_inflight(now)
        stream.finished = True
        self._pool.close(stream._handle)  # idempotent pool exit
        stream._handle._session.close()  # slab pages → free-list
        self._streams.remove(stream)  # keep the live list O(live streams)
        self._by_handle.pop(stream._handle, None)
        self._by_sid.pop(stream.sid, None)
        self._space.set()  # freed pages may unblock waiting senders
        if self._journal is not None and not self._recovering and not self._streams:
            self._checkpoint()  # everything delivered + acked: log truncates
        return bits

    # ---- accounting ----------------------------------------------------------------
    def metrics(self) -> dict:
        """Chunk-latency percentiles + sustained throughput so far.

        ``p50_ms``/``p99_ms`` are None until there are latency samples
        (guarding ``np.percentile`` on empty input); with fewer than ~20
        samples the p99 is the interpolated max and should be read as such.
        """
        lat = np.asarray(self._latencies_s, np.float64)
        span = (
            self._t_last - self._t_first
            if self._t_first is not None and self._t_last is not None
            else 0.0
        )
        return dict(
            chunks=int(lat.size),
            dispatches=self.dispatches,
            launches=self._pool.launches,
            bits_delivered=self._bits_delivered,
            span_s=span,
            sustained_mbps=(
                self._bits_delivered / span / 1e6 if span > 0 else None
            ),
            p50_ms=float(np.percentile(lat, 50) * 1e3) if lat.size else None,
            p99_ms=float(np.percentile(lat, 99) * 1e3) if lat.size else None,
            slab_pages_high_water=(
                self._slab.high_water if self._slab is not None else None
            ),
            # failure-model observability (DESIGN.md §14) — deep-copied:
            # callers mutating the snapshot must never reach live counters
            errors_by_class=copy.deepcopy(dict(self._errors_by_class)),
            faults_injected=(
                copy.deepcopy(dict(self._injector.fired))
                if self._injector is not None
                else {}
            ),
            retries=self.retries,
            shed_blocks=self.shed_blocks,
            quarantined_streams=self.quarantined_streams,
            # durability + integrity observability (DESIGN.md §15)
            checkpoints=self.checkpoints_written,
            journal_seq=(self._journal.seq if self._journal is not None else None),
            integrity_checked=(
                self._sentinel.checked if self._sentinel is not None else 0
            ),
            integrity_flagged=(
                self._sentinel.flagged if self._sentinel is not None else 0
            ),
        )


async def run_poisson_trace(
    engine,
    ys,
    n_bits_list,
    *,
    chunk_symbols: int,
    rate_chunks_per_s: float,
    seed: int = 0,
    service_kwargs: dict | None = None,
    slab: SymbolSlab | None = None,
    fault_injector: FaultInjector | None = None,
) -> tuple[list, dict]:
    """Drive ``len(ys)`` concurrent streams through the service under a
    Poisson arrival process and return (per-stream results, service metrics).

    Each stream ``i`` sends ``ys[i]`` in ``chunk_symbols``-sized chunks with
    i.i.d. exponential inter-arrival gaps at ``rate_chunks_per_s``
    (independent per stream — the aggregate arrival process at the service
    is the superposition, i.e. Poisson). Chunk CONTENT is independent of
    timing, so the decoded bits are bit-exact to per-stream one-shot
    ``engine.decode`` no matter how the trace interleaves — the property
    the serving tests pin.

    With a ``fault_injector``, a stream that the injector (or real
    validation) kills returns its typed :class:`DecodeError` in the results
    list instead of a bit array — healthy streams are unaffected and still
    deliver bit-exact arrays (the chaos acceptance criterion).
    """
    service_kwargs = dict(service_kwargs or {})
    if fault_injector is not None:
        service_kwargs.setdefault("fault_injector", fault_injector)
    async with AsyncDecodeService(slab=slab, **service_kwargs) as svc:

        async def one(i: int):
            stream = svc.open(engine)
            y = np.asarray(ys[i])
            # independent per-stream rng: the trace is reproducible no matter
            # how the event loop interleaves the stream tasks
            rng = np.random.default_rng(seed + 7919 * i)
            gaps = rng.exponential(1.0 / rate_chunks_per_s, -(-len(y) // chunk_symbols))
            outs = []
            try:
                for j, lo in enumerate(range(0, len(y), chunk_symbols)):
                    await asyncio.sleep(float(gaps[j]))
                    await stream.send(y[lo : lo + chunk_symbols])
                    outs.append(stream.take())
                outs.append(await stream.finish(n_bits_list[i]))
            except DecodeError as exc:
                # typed per-stream failure: report it as this stream's result
                # (quarantine already released its pages); service-fatal
                # failures resurface from aclose() instead
                return exc
            return np.concatenate(outs)

        bits = await asyncio.gather(*[one(i) for i in range(len(ys))])
        report = svc.metrics()
    return list(bits), report
