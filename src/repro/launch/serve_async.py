"""Async decode serving: admission → paged slabs → deadline dispatch → delivery.

The kernels already turn coalesced blocks into Gb/s (decode_batch, radix-4 /
matrix ACS, mesh sharding); what they cannot do is absorb the arrival
jitter of real traffic — a synchronous serve loop either launches tiny
batches (latency-bound chunks arrive alone) or stalls streams (waiting for
a full batch). This module is the missing layer, four stages deep:

* **admission** — :meth:`AsyncStream.send` buffers a chunk into the
  stream's session state. Admission is bounded two ways: a cap on pool-wide
  ready-but-undecoded blocks (``max_pending_blocks``) and the symbol slab's
  fixed page budget (:class:`~repro.launch.slab.SymbolSlab`). Hitting
  either APPLIES BACKPRESSURE — the send awaits the next dispatch instead
  of growing a queue — or raises :class:`Backpressure` when the service is
  configured non-blocking.
* **paging** — per-stream symbol state (the overlap tail + puncture phase)
  lives in slab pages drawn from a shared free-list, so millions of
  short-lived streams reuse a constant pool of pages instead of churning
  per-session allocations (DESIGN.md §13).
* **deadline dispatch** — a :class:`DeadlineBatcher` fires
  ``SessionPool.step()`` when the pool has ``max_batch_blocks`` ready
  blocks (throughput trigger) OR the oldest undispatched chunk has waited
  ``deadline_ms`` (latency trigger), whichever comes first. The batcher is
  a pure function of an injectable clock, so trigger behaviour is testable
  under a fake clock with no sleeps.
* **delivery** — decoded bits land per stream (:meth:`AsyncStream.take` /
  the tail from :meth:`AsyncStream.finish`), and every admitted chunk's
  latency (admission → the step that decoded its last symbol) feeds the
  p50/p99 + sustained-Mb/s accounting in :meth:`AsyncDecodeService.metrics`.

Every decode goes through the same ``SessionPool`` launches as the
synchronous driver, so service output is bit-exact to per-stream one-shot
``engine.decode`` by the pool's existing invariant — the async layer only
decides WHEN ``step()`` runs, never what a launch contains.

    async with AsyncDecodeService(slab=SymbolSlab(256, 96, 2)) as svc:
        stream = svc.open(engine)
        await stream.send(chunk)           # backpressure-aware
        ...
        bits = await stream.finish(n_bits)  # take() fold + flushed tail
"""

from __future__ import annotations

import asyncio
import time
from collections import deque

import numpy as np

from repro.launch.serve_decoder import SessionPool
from repro.launch.slab import SlabExhausted, SymbolSlab

__all__ = [
    "Backpressure",
    "DeadlineBatcher",
    "AsyncStream",
    "AsyncDecodeService",
    "run_poisson_trace",
]


class Backpressure(RuntimeError):
    """Admission refused: the service is at capacity (non-blocking mode)."""


class DeadlineBatcher:
    """The deadline-or-batch-size dispatch trigger, as a pure clocked object.

    ``note_feed()`` marks the arrival of the oldest currently-undispatched
    chunk; ``due(pending_blocks)`` answers "fire now?"; ``fired()`` resets
    the deadline arm after a dispatch. All time comes from the injected
    ``clock``, so a fake clock makes every trigger decision deterministic.

    Semantics (DESIGN.md §13): fire iff at least one block is ready AND
    (ready blocks ≥ ``max_batch_blocks`` OR the oldest undispatched chunk
    is ≥ ``deadline_s`` old). A dispatch clears the arm; chunks that were
    buffered but did not complete a block re-arm it on their stream's next
    feed.
    """

    def __init__(
        self,
        max_batch_blocks: int,
        deadline_s: float,
        *,
        clock=time.monotonic,
    ):
        if max_batch_blocks < 1:
            raise ValueError(f"max_batch_blocks must be ≥ 1, got {max_batch_blocks}")
        if deadline_s < 0:
            raise ValueError(f"deadline_s must be ≥ 0, got {deadline_s}")
        self.max_batch_blocks = max_batch_blocks
        self.deadline_s = deadline_s
        self._clock = clock
        self._oldest: float | None = None

    def note_feed(self) -> None:
        if self._oldest is None:
            self._oldest = self._clock()

    def due(self, pending_blocks: int) -> bool:
        if pending_blocks <= 0:
            return False
        if pending_blocks >= self.max_batch_blocks:
            return True
        return (
            self._oldest is not None
            and self._clock() - self._oldest >= self.deadline_s
        )

    def timeout(self) -> float | None:
        """Seconds until the deadline would fire (None = nothing armed)."""
        if self._oldest is None:
            return None
        return max(0.0, self.deadline_s - (self._clock() - self._oldest))

    def fired(self) -> None:
        self._oldest = None


class AsyncStream:
    """One stream's handle on an :class:`AsyncDecodeService`.

    Wraps a pooled session; decoded bits are drained with :meth:`take` (or
    folded into :meth:`finish`, same contract as ``PooledSession``). Tracks
    the admission time and buffered-stage watermark of every in-flight
    chunk for the service's latency accounting.
    """

    def __init__(self, service: "AsyncDecodeService", handle):
        self._service = service
        self._handle = handle
        self._inflight: deque[tuple[float, int]] = deque()  # (t_admit, watermark)
        self.finished = False

    async def send(self, chunk) -> None:
        """Admit one chunk (backpressure-aware; see the module docstring)."""
        await self._service._admit(self, chunk)

    def take(self) -> np.ndarray:
        """Drain every decoded bit delivered by dispatches so far."""
        return self._handle.take()

    async def finish(self, n_bits: int | None = None) -> np.ndarray:
        """Flush the stream and release its slab pages; returns undrained
        delivery plus the tail, totalling ``n_bits`` with prior takes."""
        return await self._service._finish(self, n_bits)

    @property
    def bits_emitted(self) -> int:
        return self._handle.bits_emitted

    # ---- service internals ---------------------------------------------------------
    def _note_admitted(self, t: float) -> None:
        s = self._handle._session
        self._inflight.append((t, s._base + len(s._store)))

    def _complete_upto(self, now: float) -> None:
        """Resolve chunks whose every buffered stage is now decoded."""
        s = self._handle._session
        done_stages = s._blocks_done * s.cfg.D
        lats = self._service._latencies_s
        while self._inflight and self._inflight[0][1] <= done_stages:
            t, _ = self._inflight.popleft()
            lats.append(now - t)

    def _drain_inflight(self, now: float) -> None:
        lats = self._service._latencies_s
        while self._inflight:
            t, _ = self._inflight.popleft()
            lats.append(now - t)


class AsyncDecodeService:
    """The asyncio front-end over a :class:`SessionPool` (module docstring).

    Parameters
    ----------
    max_batch_blocks: ready blocks that trigger an immediate dispatch.
    deadline_ms: max age of the oldest undispatched chunk before a dispatch
        fires anyway (the tail-latency knob).
    max_pending_blocks: admission cap on pool-wide ready-but-undecoded
        blocks (default ``4 × max_batch_blocks``); senders beyond it wait.
    slab: shared :class:`SymbolSlab` for paged session state (None = each
        session keeps the default per-session array store).
    clock: time source for the batcher and latency accounting. With a fake
        clock, drive dispatch synchronously via :meth:`poll` — the
        background task's waits use real event-loop time.
    block_on_backpressure: False turns waiting senders into
        :class:`Backpressure` raises (admission-control mode).
    """

    def __init__(
        self,
        *,
        max_batch_blocks: int = 32,
        deadline_ms: float = 5.0,
        max_pending_blocks: int | None = None,
        slab: SymbolSlab | None = None,
        clock=time.monotonic,
        block_on_backpressure: bool = True,
    ):
        self._pool = SessionPool()
        self._slab = slab
        self._clock = clock
        self._batcher = DeadlineBatcher(
            max_batch_blocks, deadline_ms / 1e3, clock=clock
        )
        self.max_pending_blocks = (
            max_pending_blocks if max_pending_blocks is not None else 4 * max_batch_blocks
        )
        if self.max_pending_blocks < 1:
            raise ValueError(
                f"max_pending_blocks must be ≥ 1, got {self.max_pending_blocks}"
            )
        self.block_on_backpressure = block_on_backpressure
        self._streams: list[AsyncStream] = []
        self._latencies_s: list[float] = []
        self._work = asyncio.Event()  # a chunk was admitted
        self._space = asyncio.Event()  # a dispatch freed capacity/pages
        self._task: asyncio.Task | None = None
        self._closing = False
        self.dispatches = 0
        self._t_first: float | None = None
        self._t_last: float | None = None
        self._bits_delivered = 0

    # ---- lifecycle -----------------------------------------------------------------
    async def __aenter__(self) -> "AsyncDecodeService":
        self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.aclose()

    def start(self) -> None:
        """Start the background dispatcher task (idempotent; must be called
        from inside a running event loop — fake-clock tests skip it and
        drive :meth:`poll` directly)."""
        if self._task is None:
            self._task = asyncio.get_running_loop().create_task(self._run())

    async def aclose(self) -> None:
        """Stop dispatching; flush nothing (streams own their finish)."""
        self._closing = True
        self._space.set()  # wake blocked senders so they observe the close
        if self._task is not None:
            self._task.cancel()
            try:
                await self._task
            except asyncio.CancelledError:
                pass
            self._task = None

    def open(self, engine, *, interpret: bool | None = None) -> AsyncStream:
        """Admit a new stream; its session state pages out of the slab."""
        if self._closing:
            raise RuntimeError("service is closing")
        store = self._slab.open_store() if self._slab is not None else None
        handle = self._pool.open(engine, interpret=interpret, store=store)
        stream = AsyncStream(self, handle)
        self._streams.append(stream)
        return stream

    # ---- dispatch ------------------------------------------------------------------
    def poll(self) -> bool:
        """Fire one coalesced dispatch if the trigger is due; returns whether
        it fired. The background task calls this; fake-clock tests drive it
        directly for deterministic trigger sequences."""
        if not self._batcher.due(self._pool.pending_blocks()):
            return False
        self._dispatch()
        return True

    def _dispatch(self) -> None:
        self._batcher.fired()
        before = sum(st._handle.bits_emitted for st in self._streams)
        self._pool.step()
        self.dispatches += 1
        now = self._clock()
        delivered = sum(st._handle.bits_emitted for st in self._streams) - before
        if delivered:
            self._bits_delivered += delivered
            self._t_last = now
        for stream in self._streams:
            stream._complete_upto(now)
        self._space.set()  # decoded blocks dropped pages + pending count

    async def _run(self) -> None:
        while True:
            self._work.clear()
            timeout = (
                self._batcher.timeout() if self._pool.pending_blocks() > 0 else None
            )
            if timeout is None:
                await self._work.wait()
            else:
                try:
                    await asyncio.wait_for(self._work.wait(), timeout)
                except asyncio.TimeoutError:
                    pass
            self.poll()
            # yield so delivery consumers run between dispatches
            await asyncio.sleep(0)

    # ---- admission -----------------------------------------------------------------
    async def _admit(self, stream: AsyncStream, chunk) -> None:
        if stream.finished:
            raise ValueError("send() on a finished stream")
        while True:
            if self._closing:
                raise RuntimeError("service is closing")
            if self._pool.pending_blocks() >= self.max_pending_blocks:
                await self._wait_for_space("pending-block cap")
                continue
            try:
                # session ingest is atomic w.r.t. slab exhaustion: page
                # capacity is reserved before any symbol is written, so a
                # failed admit can simply retry after the next dispatch
                stream._handle.feed(chunk)
            except SlabExhausted:
                if self._pool.pending_blocks() <= 0:
                    # nothing a dispatch could free — the chunk cannot fit
                    raise
                await self._wait_for_space("slab pages")
                continue
            break
        now = self._clock()
        if self._t_first is None:
            self._t_first = now
        stream._note_admitted(now)
        self._batcher.note_feed()
        self._work.set()

    async def _wait_for_space(self, why: str) -> None:
        if not self.block_on_backpressure:
            raise Backpressure(f"admission refused: {why} exhausted")
        self._space.clear()
        self._work.set()  # ensure the dispatcher wakes to make progress
        await self._space.wait()

    async def _finish(self, stream: AsyncStream, n_bits: int | None) -> np.ndarray:
        if stream.finished:
            raise ValueError("finish() called twice on one stream")
        before = stream._handle.bits_emitted
        bits = stream._handle.finish(n_bits)  # take() fold + shared flush plan
        now = self._clock()
        self._bits_delivered += stream._handle.bits_emitted - before
        self._t_last = now
        stream._drain_inflight(now)
        stream.finished = True
        self._pool.close(stream._handle)  # idempotent pool exit
        stream._handle._session.close()  # slab pages → free-list
        self._streams.remove(stream)  # keep the live list O(live streams)
        self._space.set()  # freed pages may unblock waiting senders
        return bits

    # ---- accounting ----------------------------------------------------------------
    def metrics(self) -> dict:
        """Chunk-latency percentiles + sustained throughput so far.

        ``p50_ms``/``p99_ms`` are None until there are latency samples
        (guarding ``np.percentile`` on empty input); with fewer than ~20
        samples the p99 is the interpolated max and should be read as such.
        """
        lat = np.asarray(self._latencies_s, np.float64)
        span = (
            self._t_last - self._t_first
            if self._t_first is not None and self._t_last is not None
            else 0.0
        )
        return dict(
            chunks=int(lat.size),
            dispatches=self.dispatches,
            launches=self._pool.launches,
            bits_delivered=self._bits_delivered,
            span_s=span,
            sustained_mbps=(
                self._bits_delivered / span / 1e6 if span > 0 else None
            ),
            p50_ms=float(np.percentile(lat, 50) * 1e3) if lat.size else None,
            p99_ms=float(np.percentile(lat, 99) * 1e3) if lat.size else None,
            slab_pages_high_water=(
                self._slab.high_water if self._slab is not None else None
            ),
        )


async def run_poisson_trace(
    engine,
    ys,
    n_bits_list,
    *,
    chunk_symbols: int,
    rate_chunks_per_s: float,
    seed: int = 0,
    service_kwargs: dict | None = None,
    slab: SymbolSlab | None = None,
) -> tuple[list[np.ndarray], dict]:
    """Drive ``len(ys)`` concurrent streams through the service under a
    Poisson arrival process and return (per-stream bits, service metrics).

    Each stream ``i`` sends ``ys[i]`` in ``chunk_symbols``-sized chunks with
    i.i.d. exponential inter-arrival gaps at ``rate_chunks_per_s``
    (independent per stream — the aggregate arrival process at the service
    is the superposition, i.e. Poisson). Chunk CONTENT is independent of
    timing, so the decoded bits are bit-exact to per-stream one-shot
    ``engine.decode`` no matter how the trace interleaves — the property
    the serving tests pin.
    """
    service_kwargs = dict(service_kwargs or {})
    async with AsyncDecodeService(slab=slab, **service_kwargs) as svc:

        async def one(i: int) -> np.ndarray:
            stream = svc.open(engine)
            y = np.asarray(ys[i])
            # independent per-stream rng: the trace is reproducible no matter
            # how the event loop interleaves the stream tasks
            rng = np.random.default_rng(seed + 7919 * i)
            gaps = rng.exponential(1.0 / rate_chunks_per_s, -(-len(y) // chunk_symbols))
            outs = []
            for j, lo in enumerate(range(0, len(y), chunk_symbols)):
                await asyncio.sleep(float(gaps[j]))
                await stream.send(y[lo : lo + chunk_symbols])
                outs.append(stream.take())
            outs.append(await stream.finish(n_bits_list[i]))
            return np.concatenate(outs)

        bits = await asyncio.gather(*[one(i) for i in range(len(ys))])
        report = svc.metrics()
    return list(bits), report
