"""Roofline analysis (assignment deliverable g): three-term roofline per
(architecture × shape × mesh) from the dry-run reports.

  compute term    = HLO_FLOPs_global / (chips × peak_FLOP/s)
  memory term     = HLO_bytes_global / (chips × HBM_bw)
  collective term = collective_bytes_global / (chips × link_bw)

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
The dry-run's HLO stats are per-device (post-SPMD partitioning), so
per-device values are divided by per-chip rates directly.

Usage: PYTHONPATH=src python -m repro.launch.roofline [--reports DIR] [--md]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
LINK_BW = 50e9  # bytes/s / link (ICI)

REPORTS = Path(__file__).resolve().parents[3] / "reports" / "dryrun"


def _useful_traffic_model(report: dict) -> dict:
    """Modeled minimal global traffic for the step (roofline 'useful work'):

      train:   optimizer state + params + grads r/w (≈ 40·N bytes) — the
               irreducible weight traffic; activations excluded (remat-able)
      prefill: one bf16 read of active weights + KV write
      decode:  one bf16 read of active weights + one full cache read

    and the minimal collective traffic (FSDP grad reduce + param gather for
    train; per-layer TP combines for inference), used to judge the dominant
    term against a useful-work bound rather than raw peak.
    """
    from repro.configs.base import SHAPES, get_config

    kind = report.get("kind", "train")
    if report["arch"] == "viterbi-ccsds":
        bits = report.get("bits_per_step", 0)
        # int8 symbols: (1+2L/D)·R bytes/bit in, 1/8 out; SP words 2×4B/stage
        return {"bytes": bits * (2.33 + 8 * 2 * 4 / 512.0), "collective": 0.0}
    cfg = get_config(report["arch"])
    shape = SHAPES.get(report["shape"])
    n_total = cfg.n_params_estimate
    n_active = cfg.n_active_params_estimate
    B = shape.global_batch if shape else 1
    S = shape.seq_len if shape else 0

    # decode-cache bytes (bf16)
    cache = 0.0
    if kind == "decode":
        per_layer = 0.0
        for pattern, repeat in cfg.layer_list:
            for d in pattern:
                if d.mixer == "gqa":
                    s_eff = min(S, cfg.sliding_window) if cfg.sliding_window else S
                    per_layer += 2 * B * s_eff * cfg.n_kv_heads * cfg.head_dim * 2
                elif d.mixer == "mla":
                    per_layer += B * S * (cfg.kv_lora_rank + cfg.qk_rope_head_dim) * 2
                elif d.mixer == "mamba":
                    per_layer += B * cfg.mamba_d_inner * (cfg.mamba_d_state + 3) * 4
                elif d.mixer == "rwkv6":
                    H = cfg.d_model // cfg.rwkv_head_dim
                    per_layer += B * H * cfg.rwkv_head_dim**2 * 4
            cache += per_layer * repeat
            per_layer = 0.0

    if kind == "train":
        bytes_ = 40.0 * n_total
        coll = 8.0 * n_total  # grad reduce-scatter (f32) + bf16 param all-gather
    elif kind == "prefill":
        bytes_ = 2.0 * n_active + B * S * cfg.d_model * 2
        coll = 2 * B * S * cfg.d_model * 2 * cfg.n_layers / 4  # TP activation combines
    else:
        bytes_ = 2.0 * n_active + cache
        coll = 2 * B * cfg.d_model * 2 * cfg.n_layers
    return {"bytes": bytes_, "collective": coll}


def roofline_terms(report: dict) -> dict | None:
    if report.get("status") != "ok" or "hlo" not in report:
        return None
    h = report["hlo"]
    chips = report.get("n_chips", 256)
    t_compute = h["flops_per_device"] / PEAK_FLOPS
    t_memory = h["bytes_per_device"] / HBM_BW
    t_coll = sum(h["collective_bytes_per_device"].values()) / LINK_BW
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    bound = max(t_compute, t_memory, t_coll)
    model_flops = report.get("model_flops_global", 0.0)
    hlo_global = h["flops_per_device"] * chips
    hlo_bytes_global = h["bytes_per_device"] * chips
    useful = _useful_traffic_model(report)

    compute_eff = (model_flops / hlo_global) if (hlo_global and model_flops) else None
    mem_eff = (useful["bytes"] / hlo_bytes_global) if hlo_bytes_global else None
    coll_global = sum(h["collective_bytes_per_device"].values()) * chips
    coll_eff = (useful["collective"] / coll_global) if coll_global else None
    frac = {"compute": compute_eff, "memory": mem_eff, "collective": coll_eff}[dominant]

    out = {
        "arch": report["arch"],
        "shape": report["shape"],
        "mesh": report["mesh"],
        "kind": report.get("kind", "?"),
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "step_lower_bound_s": bound,
        "model_flops_global": model_flops,
        "hlo_flops_global": hlo_global,
        "useful_flops_ratio": compute_eff,
        "useful_bytes_ratio": mem_eff,
        "useful_collective_ratio": coll_eff,
        # efficiency on the DOMINANT resource: useful work / compiled work.
        # 1.0 = the step is already at its useful-work roofline.
        "roofline_fraction": min(frac, 1.0) if frac is not None else None,
    }
    if "memory" in report:
        m = report["memory"]
        out["hbm_gb_per_device"] = round(
            (m.get("temp_size_in_bytes", 0) + m.get("argument_size_in_bytes", 0)) / 1e9, 2
        )
        out["temp_gb"] = round(m.get("temp_size_in_bytes", 0) / 1e9, 2)
        out["args_gb"] = round(m.get("argument_size_in_bytes", 0) / 1e9, 2)
    return out


def load_all(reports_dir: Path = REPORTS) -> list[dict]:
    rows = []
    for p in sorted(reports_dir.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("status") == "skip":
            rows.append(
                {
                    "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
                    "dominant": "skip", "reason": r.get("reason", ""),
                }
            )
            continue
        t = roofline_terms(r)
        if t:
            rows.append(t)
        else:
            rows.append(
                {
                    "arch": r["arch"], "shape": r["shape"], "mesh": r.get("mesh", "?"),
                    "dominant": r.get("status", "error"),
                }
            )
    return rows


def _fmt(x, nd=4):
    if x is None:
        return "—"
    if isinstance(x, float):
        return f"{x:.{nd}g}"
    return str(x)


def to_markdown(rows: list[dict]) -> str:
    hdr = (
        "| arch | shape | mesh | compute s | memory s | collective s | dominant | "
        "useful-FLOPs | roofline frac | HBM GB/dev |\n"
        "|---|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        lines.append(
            "| {arch} | {shape} | {mesh} | {tc} | {tm} | {tl} | **{dom}** | {uf} | {rf} | {hbm} |".format(
                arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
                tc=_fmt(r.get("t_compute_s")), tm=_fmt(r.get("t_memory_s")),
                tl=_fmt(r.get("t_collective_s")), dom=r.get("dominant", "?"),
                uf=_fmt(r.get("useful_flops_ratio"), 3),
                rf=_fmt(r.get("roofline_fraction"), 3),
                hbm=_fmt(r.get("hbm_gb_per_device")),
            )
        )
    return hdr + "\n".join(lines) + "\n"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--reports", default=str(REPORTS))
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    rows = load_all(Path(args.reports))
    if args.json:
        print(json.dumps(rows, indent=2))
    else:
        print(to_markdown(rows))


if __name__ == "__main__":
    main()
