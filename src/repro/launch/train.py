"""End-to-end training driver: data pipeline → pjit train step → checkpoints,
with crash recovery and (optional) failure injection to prove it.

Examples:
  # ~100M-param model, a few hundred steps on the local mesh
  PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b --preset 100m --steps 300

  # fault-tolerance demo: inject a failure at step 40, watch it restore
  PYTHONPATH=src python -m repro.launch.train --preset tiny --steps 60 \
      --inject-failure-at 40 --ckpt-every 20
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro._unused.models import lm
from repro.sharding.rules import axis_rules, tree_shardings
from repro.launch.mesh import make_local_mesh
from repro._unused.train.checkpoint import CheckpointManager
from repro._unused.train.data import PrefetchPipeline, SyntheticLMStream
from repro._unused.train.optimizer import AdamWConfig, OptState, adamw_init
from repro._unused.train.train_step import make_train_step

__all__ = ["TrainLoop", "main"]


def preset_config(arch: str, preset: str):
    cfg = get_config(arch)
    if preset == "full":
        return cfg
    if preset == "tiny":
        return cfg.reduced()
    if preset == "100m":  # ~100M params: a real training run that fits CPU/1 host
        return dataclasses.replace(
            cfg.reduced(),
            name=cfg.name + "-100m",
            n_layers=len(cfg.reduced().prefix) + len(cfg.reduced().pattern) * 4,
            d_model=512,
            n_heads=8,
            n_kv_heads=min(cfg.n_kv_heads, 4),
            head_dim=64,
            d_ff=2048,
            d_ff_dense=2048 if cfg.d_ff_dense else 0,
            vocab=32768,
        )
    raise ValueError(preset)


class TrainLoop:
    """Training loop with checkpoint/restore-on-failure semantics."""

    def __init__(
        self,
        cfg,
        opt_cfg: AdamWConfig,
        mesh,
        *,
        ckpt_dir: str | Path,
        global_batch: int = 8,
        seq_len: int = 128,
        ckpt_every: int = 50,
        compress_grads: bool = False,
        straggler_timeout: float | None = None,
    ):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.mesh = mesh
        self.ckpt = CheckpointManager(ckpt_dir, keep=3)
        self.ckpt_every = ckpt_every
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.stream = SyntheticLMStream(cfg.vocab, seq_len, global_batch)
        self.pipeline = PrefetchPipeline(self.stream, depth=2)
        self.straggler_timeout = straggler_timeout
        self.metrics_log: list[dict] = []

        with axis_rules(mesh) as rules:
            paxes = lm.param_axes(cfg)
            params_sds = jax.eval_shape(lambda k: lm.init_params(k, cfg), jax.random.PRNGKey(0))
            self.pshard = tree_shardings(params_sds, paxes, rules)
            step_fn = make_train_step(cfg, opt_cfg, compress_grads=compress_grads)
            self.step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
            self.params = jax.jit(
                lambda k: lm.init_params(k, cfg), out_shardings=self.pshard
            )(jax.random.PRNGKey(42))
            self.opt_state = adamw_init(self.params, opt_cfg)
        self.step = 0

    # ---- checkpoint plumbing ---------------------------------------------------------
    def _save(self, blocking: bool = False):
        self.ckpt.save(self.step, {"params": self.params, "opt": self.opt_state}, blocking=blocking)

    def _restore(self) -> bool:
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        like = {"params": self.params, "opt": self.opt_state}
        state = self.ckpt.restore(latest, like)
        self.params, self.opt_state = state["params"], state["opt"]
        self.step = latest
        return True

    # ---- main loop ---------------------------------------------------------------------
    def run(self, n_steps: int, *, inject_failure_at: int | None = None, max_restarts: int = 3):
        restarts = 0
        while self.step < n_steps:
            try:
                self._run_until(n_steps, inject_failure_at if restarts == 0 else None)
            except RuntimeError as e:
                if restarts >= max_restarts:
                    raise
                restarts += 1
                print(f"[train] failure at step {self.step}: {e}; restoring…", flush=True)
                if not self._restore():
                    print("[train] no checkpoint — restarting from init", flush=True)
                    with axis_rules(self.mesh):
                        self.params = jax.jit(
                            lambda k: lm.init_params(k, self.cfg), out_shardings=self.pshard
                        )(jax.random.PRNGKey(42))
                        self.opt_state = adamw_init(self.params, self.opt_cfg)
                    self.step = 0
        self.ckpt.wait()
        return self.metrics_log

    def _run_until(self, n_steps: int, inject_failure_at: int | None):
        with axis_rules(self.mesh):
            while self.step < n_steps:
                if inject_failure_at is not None and self.step == inject_failure_at:
                    inject_failure_at = None
                    raise RuntimeError("injected node failure")
                batch = self.pipeline.next_batch(timeout=self.straggler_timeout)
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                t0 = time.time()
                self.params, self.opt_state, metrics = self.step_fn(
                    self.params, self.opt_state, batch
                )
                self.step += 1
                if self.step % self.ckpt_every == 0:
                    self._save(blocking=False)
                if self.step % 10 == 0 or self.step == n_steps:
                    loss = float(metrics["loss"])
                    dt = time.time() - t0
                    rec = {
                        "step": self.step, "loss": round(loss, 4),
                        "grad_norm": round(float(metrics["grad_norm"]), 3),
                        "step_s": round(dt, 3),
                        "tok_s": round(self.global_batch * self.seq_len / dt, 1),
                    }
                    self.metrics_log.append(rec)
                    print(f"[train] {rec}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--inject-failure-at", type=int, default=None)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--straggler-timeout", type=float, default=None)
    args = ap.parse_args()

    cfg = preset_config(args.arch, args.preset)
    mesh = make_local_mesh()
    loop = TrainLoop(
        cfg,
        AdamWConfig(total_steps=args.steps, warmup_steps=max(args.steps // 20, 5)),
        mesh,
        ckpt_dir=args.ckpt_dir,
        global_batch=args.global_batch,
        seq_len=args.seq_len,
        ckpt_every=args.ckpt_every,
        compress_grads=args.compress_grads,
        straggler_timeout=args.straggler_timeout,
    )
    log = loop.run(args.steps, inject_failure_at=args.inject_failure_at)
    first, last = log[0], log[-1]
    print(f"[train] done: loss {first['loss']} → {last['loss']} over {args.steps} steps")
    loop.pipeline.close()


if __name__ == "__main__":
    main()
