"""Serving driver: streaming decode through the DecoderEngine + SessionPool.

    # one stream, one session (the PR-1 shape):
    PYTHONPATH=src python -m repro.launch.serve_decoder --code ccsds-3/4 \
        --chunk-bits 4096 --n-chunks 100 --ebn0 4.0 --backend ref

    # many concurrent streams coalesced into batched launches:
    PYTHONPATH=src python -m repro.launch.serve_decoder --streams 16 \
        --chunk-bits 1024 --n-chunks 50 --backend ref

Modeled on `repro.launch.serve`: a long-lived session object carries the
decoder state (the inter-block overlap tail + puncture phase) across chunks,
so an unbounded symbol stream decodes chunk-by-chunk — the serving shape of
the paper's multi-stream pipelining (§IV-D).

The :class:`SessionPool` is the multi-tenant layer on top: many concurrent
:class:`~repro.core.engine.DecoderSession`s register with the pool, chunks
are *fed* (buffered) per session, and :meth:`SessionPool.step` coalesces
every session's ready blocks — grouped by launch compatibility — into ONE
``pbvd_decode_blocks`` launch per group (DESIGN.md §3). Each session keeps
its own overlap tail and puncture phase; only the kernel launch is shared,
so per-session bits stay bit-exact to a solo session.
"""

from __future__ import annotations

import argparse
import time
from collections import defaultdict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.channel import transmit
from repro.core.codespec import available_code_specs, get_code_spec
from repro.core.encoder import encode_jax, terminate
from repro.core.engine import DecoderEngine, DecoderSession
from repro.core.pbvd import PBVDConfig
from repro.launch.faults import StreamError
from repro.kernels.ops import (
    DEFAULT_TB_CHUNK,
    available_backends,
    backend_tb_chunk_sensitive,
    resolve_tb_mode,
)

__all__ = ["SessionPool", "PooledSession", "main"]


class PooledSession:
    """One stream's handle inside a :class:`SessionPool`.

    ``feed`` buffers a chunk (no launch); decoded bits arrive on the next
    :meth:`SessionPool.step` and are drained with :meth:`take`. ``finish``
    flushes the zero-padded tail exactly like ``DecoderSession.finish``.
    """

    def __init__(self, pool: "SessionPool", session: DecoderSession):
        self._pool = pool
        self._session = session
        self._queue: list[np.ndarray] = []
        self.bits_emitted = 0

    def feed(self, chunk) -> None:
        """Buffer a chunk of received symbols (same wire formats as
        ``DecoderSession.decode``); decoding happens at ``pool.step()``."""
        self._session.ingest(chunk)

    def take(self) -> np.ndarray:
        """Drain every decoded bit delivered by pool steps so far."""
        if not self._queue:
            return np.zeros((0,), np.int32)
        out = np.concatenate(self._queue)
        self._queue.clear()
        return out

    def finish(self, n_bits: int | None = None) -> np.ndarray:
        """Flush the stream: any undrained step() output first, then the
        remaining blocks (zero-padded tail), trimmed so the session's total
        delivery is ``n_bits``.

        Undelivered step() output is FOLDED into the return value (an
        implicit :meth:`take`), so ``finish`` alone always accounts for every
        decoded bit — the old contract silently dropped queued bits when the
        caller skipped ``take()``. The flush launch itself is framed and
        trimmed by the same ``DecoderSession._finish_plan`` /
        ``_frame_ready`` / ``_pad_lanes`` path as ``DecoderSession.finish``,
        so pooled and solo tails are bit-identical by construction for every
        non-block-aligned ``n_bits``.
        """
        s = self._session
        n_bits, n_blocks, prior = s._finish_plan(n_bits)
        if n_blocks > s._blocks_done:
            # launch BEFORE draining the queue: a failed flush launch then
            # leaves the handle exactly as it was (the launch commits nothing
            # on failure), so the serving layer can retry finish() without
            # losing the undrained step() output
            tail = self._pool._launch([(self, n_blocks)])[0]
        else:
            tail = np.zeros((0,), np.int32)
        head = self.take()  # fold undrained step() output instead of losing it
        tail = tail[: max(0, n_bits - prior)]
        self.bits_emitted += len(tail)
        return np.concatenate([head, tail]) if len(head) else tail

    def _deliver(self, bits: np.ndarray) -> None:
        self._queue.append(bits)
        self.bits_emitted += len(bits)


class SessionPool:
    """Coalesce the ready blocks of many concurrent sessions into batched
    kernel launches.

    Sessions are grouped by *launch compatibility* — the key is
    ``(mother code, D, L, backend, start_policy, metric_mode, tb_mode,
    tb_chunk, window dtype, interpret, mesh identity)``: everything that
    shapes or parameterizes the kernel launch. The mesh identity is
    content-based — axis names, shape, device ids, the engine's
    ``block_axes`` and shard dispatch — never ``id(mesh)``.
    Code specs that share a mother code but differ in puncturing land in the
    same group (puncturing only affects ingest, never the launch), as do
    sessions with different payload lengths or chunk cadences.

    One :meth:`step` builds, per group, a single flattened frames × blocks
    lane axis from each member's ready window (``FramedBlocks.frame_counts``
    records the per-session block counts), pads the total to the shared
    power-of-two shape budget, launches once, and scatters the per-frame
    bits back to each session — which then advances its own overlap tail
    exactly as a solo launch would have.
    """

    def __init__(self):
        self._members: list[PooledSession] = []
        # strong refs to each pooled engine's mesh for the membership's
        # lifetime: the group key describes the mesh by CONTENT (axis names,
        # shape, device ids — never ``id()``, whose reuse after GC could
        # falsely coalesce sessions on different meshes), and pinning the
        # object here guarantees no two live members' meshes can alias.
        # Keyed by the PooledSession OBJECT (identity hash): an ``id(ps)``
        # key could alias a closed-and-GC'd member's reused id onto a new
        # member, dropping or double-releasing the wrong mesh pin
        self._mesh_refs: dict[PooledSession, object] = {}
        self.launches = 0  # batched launches issued (for reporting/tests)
        # fault-tolerance hooks (DESIGN.md §14): ``fault_hook(entries,
        # isolating)`` is consulted before every launch (the injection point
        # for repro.launch.faults.FaultInjector); quarantined members land in
        # ``quarantined`` as (session, StreamError) pairs for the serving
        # layer to drain
        self.fault_hook = None
        self.quarantined: list[tuple[PooledSession, StreamError]] = []

    # ---- membership ----------------------------------------------------------------
    def open(
        self,
        engine: DecoderEngine,
        *,
        interpret: bool | None = None,
        store=None,
    ) -> PooledSession:
        """Open a pooled streaming session on ``engine``.

        ``store`` is forwarded to :meth:`DecoderEngine.session` (slab-paged
        session state for the async serving layer). Pool state is mutated
        atomically: a partially failed open leaves neither a membership entry
        nor a mesh pin behind.
        """
        ps = PooledSession(self, engine.session(interpret=interpret, store=store))
        try:
            self._members.append(ps)
            if engine.mesh is not None:
                self._mesh_refs[ps] = engine.mesh
        except BaseException:
            if ps in self._members:
                self._members.remove(ps)
            self._mesh_refs.pop(ps, None)
            raise
        return ps

    def close(self, ps: PooledSession) -> None:
        """Remove a session from the pool (it keeps its buffered state).

        Idempotent: closing an already-closed (or never-opened) member is a
        no-op, and the member's mesh pin is released exactly once.
        """
        try:
            self._members.remove(ps)
        except ValueError:
            pass
        self._mesh_refs.pop(ps, None)

    def __len__(self) -> int:
        return len(self._members)

    # ---- scheduling ----------------------------------------------------------------
    def pending_blocks(self) -> int:
        """Blocks decodable right now across every member."""
        return sum(
            ps._session.ready_blocks() - ps._session._blocks_done
            for ps in self._members
        )

    def step(self, *, isolate: bool = False) -> int:
        """Decode every ready block in the pool; returns the block count.

        Sessions with no complete window are skipped; compatible sessions
        share one launch per group. A failed launch commits nothing —
        sessions only advance after their bits exist — so a plain ``step``
        that raises is safely retryable as-is.

        ``isolate=True`` switches to the quarantine protocol: a group whose
        launch raises is bisected until the culprit member(s) are isolated,
        each culprit is removed from the pool with a typed
        :class:`~repro.launch.faults.StreamError` recorded in
        ``self.quarantined``, and every healthy member's relaunch delivers
        bits identical to an undisturbed step (PBVD blocks are mutually
        independent, so batch composition never changes per-stream bits —
        the paper property that makes isolation cheap).
        """
        groups: dict[tuple, list[tuple[PooledSession, int]]] = defaultdict(list)
        for ps in self._members:
            s = ps._session
            b1 = s.ready_blocks()
            if b1 > s._blocks_done:
                groups[self._group_key(s)].append((ps, b1))
        total = 0
        for entries in groups.values():
            if isolate:
                delivered = self._launch_isolated(entries)
            else:
                outs = self._launch(entries)
                delivered = list(zip(entries, outs))
            for (ps, _), bits in delivered:
                ps._deliver(bits)
                total += len(bits) // ps._session.cfg.D
        return total

    # ---- internals -----------------------------------------------------------------
    @staticmethod
    def _group_key(s: DecoderSession) -> tuple:
        cfg = s.cfg
        q = cfg.effective_q  # narrow metric modes force/cap the quantizer
        if s._int_dtype is not None:
            dt = np.dtype(s._int_dtype).str
        elif q is not None:
            dt = "int8" if q <= 8 else "int16"
        else:
            dt = "float32"
        # the mesh enters the key by CONTENT plus the engine's lane-axis
        # binding: two engines on the same mesh but different block_axes (or
        # dispatch) compile DIFFERENT launches and must not coalesce, and a
        # content key — unlike the old ``id(mesh)`` — can neither split
        # equal meshes built twice nor falsely merge distinct meshes whose
        # ids collide after GC (the pool additionally pins every pooled
        # mesh in ``_mesh_refs``)
        eng = s.engine
        if eng.mesh is None:
            mesh_key = None
        else:
            mesh_key = (
                tuple(eng.mesh.axis_names),
                tuple((a, int(n)) for a, n in eng.mesh.shape.items()),
                tuple(int(d.id) for d in eng.mesh.devices.flat),
                eng.block_axes,
                eng.shard_dispatch,
            )
        # key on the RESOLVED tb mode so an "auto" session coalesces with
        # one that spelled the backend's preferred mode out explicitly
        tb_mode = resolve_tb_mode(cfg.backend, cfg.tb_mode)
        return (
            cfg.code,
            cfg.D,
            cfg.L,
            cfg.backend,
            cfg.start_policy,
            cfg.metric_mode,
            # each acs_impl's inert knob is dropped from the key (mirrors
            # the dispatcher's cache-key normalization), so e.g. matrix
            # sessions coalesce regardless of their butterfly radix
            cfg.acs_impl,
            cfg.acs_radix if cfg.acs_impl == "butterfly" else None,
            cfg.acs_k if cfg.acs_impl == "matrix" else None,
            tb_mode,
            # tb_chunk only parameterizes chunk-sensitive prefix launches
            # (the dispatcher normalizes it out otherwise); keying on it
            # elsewhere would only split coalescable groups
            cfg.tb_chunk
            if tb_mode == "prefix" and backend_tb_chunk_sensitive(cfg.backend)
            else None,
            dt,
            s._interpret,
            mesh_key,
        )

    def _launch(
        self,
        entries: list[tuple[PooledSession, int]],
        *,
        isolating: bool = False,
    ) -> list[np.ndarray]:
        """One batched launch for ``entries`` = [(session, decode-up-to-b1)].

        Returns each entry's decoded bits (whole blocks, forward order) and
        commits each session's overlap tail past the decoded blocks. An
        exception (from the hook or the kernel) commits NOTHING, so the
        identical launch can be rebuilt from session state.
        """
        if self.fault_hook is not None:
            self.fault_hook(entries, isolating)
        frames, counts = [], []
        for ps, b1 in entries:
            s = ps._session
            frames.append(s._frame_ready(b1))
            counts.append(b1 - s._blocks_done)
        packed = jnp.concatenate(frames, axis=2) if len(frames) > 1 else frames[0]
        lead = entries[0][0]._session
        # the lead engine's shard-aware budget (pow2 rounded once to the
        # mesh shard count) — identical for every member, since the group
        # key includes the full mesh identity + block_axes
        packed = lead.engine._pad_lanes(packed)
        bits = lead.engine._decode_blocks(packed, tuple(counts), lead._interpret)
        self.launches += 1
        outs, lo = [], 0
        for (ps, b1), k in zip(entries, counts):
            sub = np.asarray(
                jnp.transpose(bits[:, lo : lo + k]), dtype=np.int32
            ).reshape(-1)
            ps._session._commit(b1)
            outs.append(sub)
            lo += k
        return outs

    # ---- quarantine ----------------------------------------------------------------
    def _launch_isolated(
        self, entries: list[tuple[PooledSession, int]]
    ) -> list[tuple[tuple[PooledSession, int], np.ndarray]]:
        """Launch ``entries``, bisecting on failure to isolate culprits.

        Healthy members decode bit-exact to the full coalesced launch (block
        independence); members whose SINGLE-lane-group launch still fails are
        quarantined via :meth:`_quarantine` and excluded from the result.
        Worst case this costs O(f·log n) launches for f culprits among n
        members — each bisection level relaunches only the halves that
        contain a failure.
        """
        try:
            outs = self._launch(entries, isolating=True)
            return list(zip(entries, outs))
        except Exception as exc:  # noqa: BLE001 - classify, don't mask
            if len(entries) == 1:
                ps = entries[0][0]
                err = (
                    exc
                    if isinstance(exc, StreamError)
                    else StreamError(
                        f"stream quarantined: its lane-group reproducibly "
                        f"fails the launch ({exc!r})",
                        stream=ps,
                    )
                )
                if err.__cause__ is None and err is not exc:
                    err.__cause__ = exc
                self._quarantine(ps, err)
                return []
            mid = len(entries) // 2
            return self._launch_isolated(entries[:mid]) + self._launch_isolated(
                entries[mid:]
            )

    def _quarantine(self, ps: PooledSession, err: StreamError) -> None:
        """Remove ``ps`` from the pool and record its typed failure.

        The member's buffered session state is left intact — the serving
        layer owns the slab pages and frees them when it fails the stream's
        waiters (``AsyncDecodeService._fail_stream``).
        """
        self.close(ps)
        self.quarantined.append((ps, err))

    def drain_quarantined(self) -> list[tuple[PooledSession, StreamError]]:
        """Hand the accumulated quarantine records to the caller (and reset)."""
        out, self.quarantined = self.quarantined, []
        return out

    def repoint_engine(self, old: DecoderEngine, new: DecoderEngine) -> int:
        """Swap every member bound to engine ``old`` onto ``new`` (mesh-loss
        rescale). Members' ready-but-undecoded blocks replay on the new
        engine at the next step, bit-exact to the uninterrupted run — block
        content is host-side session state and the mesh only places lanes.
        Returns the number of members repointed.
        """
        n = 0
        for ps in self._members:
            s = ps._session
            if s.engine is old:
                s.engine = new
                if new.mesh is not None:
                    self._mesh_refs[ps] = new.mesh
                else:
                    self._mesh_refs.pop(ps, None)
                n += 1
        return n


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------
def _make_stream(spec, n_bits: int, ebn0: float, seed: int):
    rng = np.random.default_rng(seed)
    payload = rng.integers(0, 2, n_bits)
    coded = encode_jax(jnp.asarray(terminate(payload, spec.code)), spec.code)
    tx = spec.puncture_stream(coded) if spec.is_punctured else coded
    y = np.asarray(transmit(jax.random.PRNGKey(seed), tx, ebn0, spec.rate))
    return payload, y


def _latency_summary(lat_ms) -> str:
    """p50/p99 of a latency sample, guarded for tiny sample counts —
    ``np.percentile`` on an empty array raises, and a p99 quoted from a
    handful of chunks is noise dressed as a tail, so say so."""
    lat = np.asarray(lat_ms, np.float64)
    if lat.size == 0:
        return "no latency samples"
    out = f"p50={np.percentile(lat, 50):.1f} ms p99={np.percentile(lat, 99):.1f} ms"
    if lat.size < 20:  # p99 interpolated from < 20 samples ≈ the max
        out += f" (n={lat.size}: p99≈max)"
    return out


def _serve_single(engine, spec, cfg, args) -> None:
    n_bits = args.chunk_bits * args.n_chunks
    payload, y = _make_stream(spec, n_bits, args.ebn0, args.seed)
    sess = engine.session()
    bounds = np.linspace(0, len(y), args.n_chunks + 1).astype(int)
    decoded, lat_ms = [], []
    t0 = time.perf_counter()
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        t1 = time.perf_counter()
        decoded.append(sess.decode(y[lo:hi]))
        lat_ms.append((time.perf_counter() - t1) * 1e3)
    # the finish flush decodes the final (often largest) window — leaving it
    # out of lat_ms reported a p99 that omitted the worst chunk
    t1 = time.perf_counter()
    decoded.append(sess.finish(n_bits))
    lat_ms.append((time.perf_counter() - t1) * 1e3)
    dt = time.perf_counter() - t0

    bits = np.concatenate(decoded)
    ber = float(np.mean(bits != payload))
    print(
        f"[serve_decoder] {n_bits} bits in {dt*1e3:.0f} ms → {n_bits/dt/1e6:.2f} Mbps; "
        f"chunk latency {_latency_summary(lat_ms)}"
    )
    print(f"[serve_decoder] BER = {ber:.2e} ({int(ber * n_bits)} errors)")


def _serve_pooled(engine, spec, cfg, args) -> None:
    n_bits = args.chunk_bits * args.n_chunks
    streams = [
        _make_stream(spec, n_bits, args.ebn0, args.seed + i)
        for i in range(args.streams)
    ]
    pool = SessionPool()
    handles = [pool.open(engine) for _ in streams]
    bounds = np.linspace(0, len(streams[0][1]), args.n_chunks + 1).astype(int)
    outs = [[] for _ in streams]
    step_ms = []
    t0 = time.perf_counter()
    for lo, hi in zip(bounds[:-1], bounds[1:]):  # one ingest round, one step
        for (_, y), h in zip(streams, handles):
            h.feed(y[lo:hi])
        t1 = time.perf_counter()
        pool.step()
        step_ms.append((time.perf_counter() - t1) * 1e3)
        for i, h in enumerate(handles):
            outs[i].append(h.take())
    for i, h in enumerate(handles):
        outs[i].append(h.finish(n_bits))
    dt = time.perf_counter() - t0

    total_bits = n_bits * args.streams
    errors = sum(
        int(np.sum(np.concatenate(o) != p)) for o, (p, _) in zip(outs, streams)
    )
    print(
        f"[serve_decoder] {args.streams} streams × {n_bits} bits in {dt*1e3:.0f} ms "
        f"→ aggregate {total_bits/dt/1e6:.2f} Mbps; "
        f"{pool.launches} batched launches "
        f"({args.n_chunks * args.streams} chunks fed); "
        f"step latency {_latency_summary(step_ms)}"
    )
    print(
        f"[serve_decoder] BER = {errors/total_bits:.2e} ({errors} errors "
        f"over {total_bits} bits)"
    )


def _serve_async_durable(engine, spec, cfg, args) -> int:
    """Durable serving drill: journaled admissions, client-side delivered-bit
    persistence, optional mid-trace SIGKILL, and ``recover()`` restart.

    The client protocol per stream ``i``:

    * deliveries are drained with ``take(ack=False)``, appended to
      ``{journal_dir}/delivered-{i}.bits`` (one uint8 byte per bit),
      fsync'd, and only THEN acked — so the service's ack watermark never
      runs ahead of the durable file;
    * sending resumes from ``stream.chunks_admitted`` (the WAL-derived
      cursor), so a chunk lost in the crash gap between ``send()`` and its
      admit record is simply re-sent;
    * on ``--recover``, each file is truncated back to the recovered ack
      watermark — bytes persisted after the last durable ack are exactly
      the bits recovery will redeliver (the no-duplicate invariant).

    Returns a process exit code: 0 = every stream's delivered bits match
    the one-shot reference decode, 1 = mismatch, 3 = ``--kill-at`` was set
    but the trace completed without reaching the kill point.
    """
    import asyncio
    import os
    import signal

    from repro.launch.journal import ChunkJournal
    from repro.launch.serve_async import AsyncDecodeService
    from repro.launch.slab import SymbolSlab

    n_bits = args.chunk_bits * args.n_chunks
    streams = [
        _make_stream(spec, n_bits, args.ebn0, args.seed + i)
        for i in range(args.streams)
    ]
    cs = max(1, len(streams[0][1]) // args.n_chunks)
    chunk_lists = [
        [y[k * cs : (k + 1) * cs] for k in range(-(-len(y) // cs))]
        for _, y in streams
    ]
    slab = SymbolSlab(
        n_pages=args.slab_pages, page_stages=cfg.D + 2 * cfg.L, R=spec.code.R
    )
    journal = ChunkJournal(args.journal_dir)
    service_kwargs = dict(
        max_batch_blocks=args.max_batch_blocks,
        deadline_ms=args.deadline_ms,
        slab=slab,
        journal=journal,
        integrity_rate=args.integrity_rate,
    )
    if args.kill_at is not None:

        def _kill_hook(svc):
            if svc.dispatches >= args.kill_at:
                os.kill(os.getpid(), signal.SIGKILL)  # no cleanup: a real crash

        service_kwargs["on_dispatch"] = _kill_hook

    async def _client(i, stream):
        path = os.path.join(args.journal_dir, f"delivered-{i}.bits")
        if stream is None:  # finished before the crash; its file is complete
            return
        mode = "r+b" if args.recover and os.path.exists(path) else "wb"
        with open(path, mode) as f:
            if mode == "r+b":
                f.seek(0, os.SEEK_END)
                assert f.tell() >= stream.acked_bits, (
                    f"stream {i}: durable file shorter than ack watermark "
                    f"({f.tell()} < {stream.acked_bits})"
                )
                f.truncate(stream.acked_bits)  # un-acked tail gets redelivered
                f.seek(0, os.SEEK_END)

            def persist(bits):
                if len(bits):
                    f.write(np.asarray(bits, np.uint8).tobytes())
                    f.flush()
                    os.fsync(f.fileno())
                stream.ack()

            chunks = chunk_lists[i]
            # paced sends (unlike the ephemeral trace, deterministic spacing
            # not Poisson): the deadline dispatcher must actually run between
            # arrivals or the whole trace would flush inside finish() and a
            # --kill-at dispatch boundary would never be crossed
            gap_s = 1.0 / args.rate_chunks_per_s if args.rate_chunks_per_s else 0.0
            for k in range(stream.chunks_admitted, len(chunks)):
                await stream.send(chunks[k])
                await asyncio.sleep(gap_s)
                persist(stream.take(ack=False))
            persist(await stream.finish(n_bits))

    async def drive():
        if args.recover:
            kw = {k: v for k, v in service_kwargs.items() if k != "journal"}
            svc = AsyncDecodeService.recover(journal, engine, **kw)
        else:
            svc = AsyncDecodeService(**service_kwargs)
        async with svc:
            # sid == stream index by construction: streams open in index
            # order on the fresh run, and sids are stable across recovery
            if args.recover:
                handles = [svc.recovered_streams.get(i) for i in range(args.streams)]
            else:
                handles = [svc.open(engine) for _ in range(args.streams)]
            await asyncio.gather(*(_client(i, h) for i, h in enumerate(handles)))
            return svc.metrics()

    t0 = time.perf_counter()
    m = asyncio.run(drive())
    dt = time.perf_counter() - t0
    journal.close()
    if args.kill_at is not None:
        print(
            f"[serve_decoder] --kill-at {args.kill_at} never reached "
            f"({m['dispatches']} dispatches total)"
        )
        return 3

    bad = 0
    for i, (_, y) in enumerate(streams):
        path = os.path.join(args.journal_dir, f"delivered-{i}.bits")
        got = np.frombuffer(open(path, "rb").read(), np.uint8)
        sess = engine.session()
        ref = np.concatenate([sess.decode(y), sess.finish(n_bits)])
        if len(got) != n_bits or np.any(got != ref):
            bad += 1
            print(f"[serve_decoder] stream {i}: delivered bits != reference")
    print(
        f"[serve_decoder] durable: {args.streams} streams × {n_bits} bits in "
        f"{dt*1e3:.0f} ms ({m['dispatches']} dispatches, "
        f"{m['checkpoints']} checkpoints, journal seq {m['journal_seq']}, "
        f"integrity {m['integrity_flagged']}/{m['integrity_checked']} flagged); "
        f"{'all streams bit-exact vs reference' if not bad else f'{bad} stream(s) MISMATCHED'}"
    )
    return 0 if bad == 0 else 1


def _serve_async(engine, spec, cfg, args) -> None:
    """Drive the asyncio service under a Poisson arrival trace (the
    serving-layer shape: admission → paged slabs → deadline dispatch)."""
    import asyncio

    from repro.launch.serve_async import run_poisson_trace
    from repro.launch.slab import SymbolSlab

    n_bits = args.chunk_bits * args.n_chunks
    streams = [
        _make_stream(spec, n_bits, args.ebn0, args.seed + i)
        for i in range(args.streams)
    ]
    ys = [y for _, y in streams]
    chunk_symbols = max(1, len(ys[0]) // args.n_chunks)
    slab = SymbolSlab(
        n_pages=args.slab_pages,
        page_stages=cfg.D + 2 * cfg.L,
        R=spec.code.R,
    )
    t0 = time.perf_counter()
    bits, report = asyncio.run(
        run_poisson_trace(
            engine,
            ys,
            [n_bits] * len(ys),
            chunk_symbols=chunk_symbols,
            rate_chunks_per_s=args.rate_chunks_per_s,
            seed=args.seed,
            slab=slab,
            service_kwargs=dict(
                max_batch_blocks=args.max_batch_blocks,
                deadline_ms=args.deadline_ms,
            ),
        )
    )
    dt = time.perf_counter() - t0
    total_bits = n_bits * args.streams
    errors = sum(
        int(np.sum(b != p)) for b, (p, _) in zip(bits, streams)
    )
    print(
        f"[serve_decoder] async: {args.streams} streams × {n_bits} bits in "
        f"{dt*1e3:.0f} ms → sustained "
        f"{report['sustained_mbps'] if report['sustained_mbps'] is not None else float('nan'):.2f} Mbps "
        f"({report['dispatches']} dispatches, {report['launches']} launches, "
        f"slab high-water {report['slab_pages_high_water']} pages); "
        f"chunk latency p50={report['p50_ms']:.1f} ms p99={report['p99_ms']:.1f} ms"
    )
    print(
        f"[serve_decoder] BER = {errors/total_bits:.2e} ({errors} errors "
        f"over {total_bits} bits)"
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--code", default="ccsds", choices=available_code_specs())
    ap.add_argument("--backend", default="ref", choices=available_backends())
    ap.add_argument("--d", type=int, default=512, help="decode block length D")
    ap.add_argument("--l", type=int, default=42, help="traceback depth L")
    ap.add_argument("--q", type=int, default=8, help="quantization bits (0 = float32)")
    ap.add_argument(
        "--metric-mode",
        default="f32",
        choices=["f32", "i16", "i8"],
        help="path-metric pipeline (narrow modes re-cap q to the saturation budget)",
    )
    ap.add_argument(
        "--tb-mode",
        default="auto",
        choices=["auto", "serial", "prefix"],
        help="traceback algorithm (auto = the backend's measured-fastest; "
        "prefix = chunked survivor-map composition)",
    )
    ap.add_argument(
        "--tb-chunk",
        type=int,
        default=DEFAULT_TB_CHUNK,
        help="prefix traceback chunk size (stages composed per chunk map)",
    )
    ap.add_argument(
        "--acs-radix",
        type=int,
        default=2,
        choices=[2, 4],
        help="forward-ACS radix (4 = stage-fused two-stage steps, bit-exact)",
    )
    ap.add_argument(
        "--acs-impl",
        default="butterfly",
        choices=["butterfly", "matrix"],
        help="forward-pass formulation (matrix = k-stage (min,+) tropical "
        "matmul steps, bit-exact)",
    )
    ap.add_argument(
        "--acs-k",
        type=int,
        default=2,
        help="matrix-ACS fusion depth k (stages per tropical matmul step)",
    )
    ap.add_argument(
        "--mesh",
        default=None,
        metavar="AXIS=N[,AXIS=N]",
        help="shard the lane (parallel-block) axis over a device mesh, e.g. "
        "data=8 (CPU rehearsal: XLA_FLAGS=--xla_force_host_platform_"
        "device_count=8; multi-host: the JAX_COORDINATOR_ADDRESS/"
        "JAX_NUM_PROCESSES/JAX_PROCESS_ID env triplet, see repro.launch.mesh)",
    )
    ap.add_argument(
        "--shard-dispatch",
        default="constraint",
        choices=["constraint", "shard_map"],
        help="mesh dispatch path: NamedSharding placement vs explicit "
        "per-shard shard_map (bit-identical; see DESIGN.md §12)",
    )
    ap.add_argument("--chunk-bits", type=int, default=4096, help="payload bits per chunk")
    ap.add_argument("--n-chunks", type=int, default=100)
    ap.add_argument(
        "--streams",
        type=int,
        default=1,
        help="concurrent streams; >1 coalesces sessions through a SessionPool",
    )
    ap.add_argument("--ebn0", type=float, default=4.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--serve-async",
        action="store_true",
        help="drive the asyncio serving layer (repro.launch.serve_async) "
        "under a Poisson arrival trace instead of the synchronous loop",
    )
    ap.add_argument(
        "--deadline-ms",
        type=float,
        default=5.0,
        help="async dispatch deadline: max age of the oldest undispatched "
        "chunk before a coalesced step fires anyway",
    )
    ap.add_argument(
        "--max-batch-blocks",
        type=int,
        default=32,
        help="async dispatch size trigger: ready blocks that fire a step",
    )
    ap.add_argument(
        "--slab-pages",
        type=int,
        default=1024,
        help="session-state slab capacity (pages of D+2L stages each)",
    )
    ap.add_argument(
        "--rate-chunks-per-s",
        type=float,
        default=1000.0,
        help="per-stream Poisson chunk arrival rate for --serve-async",
    )
    ap.add_argument(
        "--journal-dir",
        default=None,
        help="with --serve-async: write-ahead journal admitted chunks + "
        "checkpoint session state under this directory, and persist each "
        "stream's delivered bits to delivered-<i>.bits (crash-safe serving, "
        "DESIGN.md §15)",
    )
    ap.add_argument(
        "--integrity-rate",
        type=float,
        default=0.0,
        help="fraction of deliveries screened by the re-encode integrity "
        "sentinel (0 = off; 1 = every delivery); flagged streams quarantine "
        "with IntegrityError",
    )
    ap.add_argument(
        "--kill-at",
        type=int,
        default=None,
        help="with --journal-dir: SIGKILL this process the moment the "
        "dispatch counter reaches N (crash drill; exit 3 if never reached)",
    )
    ap.add_argument(
        "--recover",
        action="store_true",
        help="with --journal-dir: rebuild the service from the journal "
        "(checkpoint + replay) instead of starting fresh, resume the trace, "
        "and verify delivered bits against the one-shot reference",
    )
    args = ap.parse_args()
    if (args.kill_at is not None or args.recover) and args.journal_dir is None:
        ap.error("--kill-at/--recover require --journal-dir")
    if args.journal_dir is not None and not args.serve_async:
        ap.error("--journal-dir requires --serve-async")

    from repro.launch.mesh import make_decode_mesh, maybe_init_distributed

    mesh = None
    if args.mesh:
        maybe_init_distributed()  # no-op unless the multi-host env triplet is set
        mesh = make_decode_mesh(args.mesh)

    spec = get_code_spec(args.code)
    cfg = PBVDConfig(
        spec=spec,
        D=args.d,
        L=args.l,
        q=args.q or None,
        backend=args.backend,
        metric_mode=args.metric_mode,
        tb_mode=args.tb_mode,
        tb_chunk=args.tb_chunk,
        acs_radix=args.acs_radix,
        acs_impl=args.acs_impl,
        acs_k=args.acs_k,
    )
    engine = DecoderEngine(
        cfg,
        mesh=mesh,
        block_axes=None if mesh is not None else ("data",),
        shard_dispatch=args.shard_dispatch,
    )
    if mesh is not None:
        print(
            f"[serve_decoder] mesh {dict(mesh.shape)} over {mesh.devices.size} "
            f"device(s); lane axis on {engine.block_axes} "
            f"({engine.n_shards} shards, dispatch={engine.shard_dispatch})"
        )
    print(
        f"[serve_decoder] {spec.name}: K={spec.code.K}, rate={spec.rate:.3f}, "
        f"D={cfg.D}, L={cfg.L}, q={cfg.effective_q}, backend={cfg.backend}, "
        f"metric_mode={cfg.metric_mode}, tb_mode={cfg.tb_mode} "
        f"(→ {resolve_tb_mode(cfg.backend, cfg.tb_mode)}), "
        f"acs_impl={cfg.acs_impl}"
        f"{f' (k={cfg.acs_k})' if cfg.acs_impl == 'matrix' else f', acs_radix={cfg.acs_radix}'}; "
        f"{args.streams} stream(s) × {args.chunk_bits * args.n_chunks} payload bits "
        f"in {args.n_chunks} chunks at Eb/N0={args.ebn0} dB"
    )
    if args.serve_async and args.journal_dir is not None:
        raise SystemExit(_serve_async_durable(engine, spec, cfg, args))
    elif args.serve_async:
        _serve_async(engine, spec, cfg, args)
    elif args.streams > 1:
        _serve_pooled(engine, spec, cfg, args)
    else:
        _serve_single(engine, spec, cfg, args)


if __name__ == "__main__":
    main()
