"""Serving driver: stateful streaming decode through the DecoderEngine.

    PYTHONPATH=src python -m repro.launch.serve_decoder --code ccsds-3/4 \
        --chunk-bits 4096 --n-chunks 100 --ebn0 4.0 --backend ref

Modeled on `repro.launch.serve`: a long-lived session object carries the
decoder state (the inter-block overlap tail + puncture phase) across chunks,
so an unbounded symbol stream decodes chunk-by-chunk — the serving shape of
the paper's multi-stream pipelining (§IV-D). Reports per-chunk latency,
aggregate throughput, and end-to-end BER against the transmitted payload.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.channel import transmit
from repro.core.codespec import available_code_specs, get_code_spec
from repro.core.encoder import encode_jax, terminate
from repro.core.engine import DecoderEngine
from repro.core.pbvd import PBVDConfig
from repro.kernels.ops import available_backends


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--code", default="ccsds", choices=available_code_specs())
    ap.add_argument("--backend", default="ref", choices=available_backends())
    ap.add_argument("--d", type=int, default=512, help="decode block length D")
    ap.add_argument("--l", type=int, default=42, help="traceback depth L")
    ap.add_argument("--q", type=int, default=8, help="quantization bits (0 = float32)")
    ap.add_argument("--chunk-bits", type=int, default=4096, help="payload bits per chunk")
    ap.add_argument("--n-chunks", type=int, default=100)
    ap.add_argument("--ebn0", type=float, default=4.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    spec = get_code_spec(args.code)
    cfg = PBVDConfig(
        spec=spec,
        D=args.d,
        L=args.l,
        q=args.q or None,
        backend=args.backend,
    )
    engine = DecoderEngine(cfg)
    n_bits = args.chunk_bits * args.n_chunks

    # ---- transmit the whole stream once (the "wire") ------------------------------
    rng = np.random.default_rng(args.seed)
    payload = rng.integers(0, 2, n_bits)
    coded = encode_jax(jnp.asarray(terminate(payload, spec.code)), spec.code)
    tx = spec.puncture_stream(coded) if spec.is_punctured else coded
    y = np.asarray(transmit(jax.random.PRNGKey(args.seed), tx, args.ebn0, spec.rate))
    print(
        f"[serve_decoder] {spec.name}: K={spec.code.K}, rate={spec.rate:.3f}, "
        f"D={cfg.D}, L={cfg.L}, q={cfg.q}, backend={cfg.backend}; "
        f"{n_bits} payload bits in {args.n_chunks} chunks at Eb/N0={args.ebn0} dB"
    )

    # ---- stream it through a session ---------------------------------------------
    sess = engine.session()
    bounds = np.linspace(0, len(y), args.n_chunks + 1).astype(int)
    decoded = []
    lat_ms = []
    t0 = time.perf_counter()
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        t1 = time.perf_counter()
        decoded.append(sess.decode(y[lo:hi]))
        lat_ms.append((time.perf_counter() - t1) * 1e3)
    decoded.append(sess.finish(n_bits))
    dt = time.perf_counter() - t0

    bits = np.concatenate(decoded)
    ber = float(np.mean(bits != payload))
    lat = np.array(lat_ms)
    print(
        f"[serve_decoder] {n_bits} bits in {dt*1e3:.0f} ms → {n_bits/dt/1e6:.2f} Mbps; "
        f"chunk latency p50={np.percentile(lat, 50):.1f} ms "
        f"p99={np.percentile(lat, 99):.1f} ms"
    )
    print(f"[serve_decoder] BER = {ber:.2e} ({int(ber * n_bits)} errors)")


if __name__ == "__main__":
    main()
