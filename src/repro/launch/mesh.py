"""Device meshes: production shapes, local test meshes, and the decode-fleet
launch recipe.

``make_production_mesh`` is a function (not a module-level constant) so that
importing this module never touches JAX device state.

Multi-process launch recipe (one process per host, à la the MaxText XPK
multi-slice scripts — SNIPPETS.md #2/#3):

    # per host i of N (same command everywhere, only PROCESS_ID varies):
    JAX_COORDINATOR_ADDRESS=host0:8476 JAX_NUM_PROCESSES=N JAX_PROCESS_ID=i \\
        python -m repro.launch.serve_decoder --mesh data=<total chips> \\
        --streams 64 --backend fused

    # single-host CI / laptop rehearsal of the SAME path on CPU, no TPU:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        python -m repro.launch.serve_decoder --mesh data=8

:func:`maybe_init_distributed` reads the ``JAX_COORDINATOR_ADDRESS`` /
``JAX_NUM_PROCESSES`` / ``JAX_PROCESS_ID`` triplet and calls
``jax.distributed.initialize`` when (and only when) all three are present,
so the same entry point serves single-process runs untouched. The decoder's
mesh path is collective-free (parallel blocks never interact), so the
multi-process fleet needs no cross-host traffic beyond the jit partitioning
handshake.
"""

from __future__ import annotations

import os

import jax
import numpy as np

__all__ = [
    "make_production_mesh",
    "make_local_mesh",
    "parse_mesh_spec",
    "make_decode_mesh",
    "shrink_mesh",
    "maybe_init_distributed",
]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples).

    Every invalid shape fails HERE with a clear ``ValueError`` — notably
    ``model`` not dividing the device count, which used to flow a zero or
    short mesh shape into ``jax.make_mesh`` (silently building a mesh over
    a device subset, or failing with an opaque downstream error).
    """
    n = len(jax.devices())
    if model < 1:
        raise ValueError(f"model axis size must be >= 1, got {model}")
    if data is None:
        if n % model:
            raise ValueError(
                f"model={model} does not divide the {n} available device(s); "
                f"pick a divisor of {n} or pass data= explicitly"
            )
        data = n // model
    if data < 1:
        raise ValueError(f"data axis size must be >= 1, got {data}")
    if data * model > n:
        raise ValueError(
            f"mesh shape ({data}, {model}) needs {data * model} devices, "
            f"only {n} available"
        )
    return jax.make_mesh((data, model), ("data", "model"))


def parse_mesh_spec(spec: str) -> tuple[tuple[str, ...], tuple[int, ...]]:
    """Parse ``"data=8"`` / ``"pod=2,data=4"`` → (axis names, axis sizes)."""
    names, sizes = [], []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        name, eq, size = part.partition("=")
        name = name.strip()
        try:
            n = int(size) if eq else -1
        except ValueError:
            n = -1
        if not name or n < 1:
            raise ValueError(
                f"bad mesh spec {spec!r}: expected AXIS=N[,AXIS=N...] with "
                f"positive integer sizes, got segment {part!r}"
            )
        if name in names:
            raise ValueError(f"bad mesh spec {spec!r}: axis {name!r} repeated")
        names.append(name)
        sizes.append(n)
    if not names:
        raise ValueError(f"bad mesh spec {spec!r}: no axes")
    return tuple(names), tuple(sizes)


def make_decode_mesh(spec: str, *, devices=None):
    """Build the decode-fleet mesh from a ``--mesh`` spec string.

    ``spec`` is ``"data=N"`` (or multi-axis ``"pod=2,data=8"``); the mesh is
    laid over the first ``prod(sizes)`` devices, so a sub-mesh of the
    available fleet is legal (the devices-sweep benchmark relies on it).
    """
    from jax.sharding import Mesh

    names, sizes = parse_mesh_spec(spec)
    devs = list(jax.devices()) if devices is None else list(devices)
    need = 1
    for s in sizes:
        need *= s
    if need > len(devs):
        raise ValueError(
            f"mesh spec {spec!r} needs {need} devices, only {len(devs)} "
            f"available (CPU rehearsal: set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need})"
        )
    return Mesh(np.asarray(devs[:need]).reshape(sizes), names)


def shrink_mesh(mesh, new_shape, *, devices=None):
    """Rebuild ``mesh`` at ``new_shape`` (same axis names) over surviving
    devices — the mesh-loss fallback of :func:`repro.launch.elastic.
    rescale_decode_engine`.

    ``devices`` lists the survivors explicitly; by default the first
    ``prod(new_shape)`` devices of the old mesh are kept (the right default
    for rehearsals and tests — a real casualty passes the live device set).
    Device choice never affects decoded bits: the decode mesh only places
    independent lanes.
    """
    from jax.sharding import Mesh

    new_shape = tuple(int(n) for n in new_shape)
    if len(new_shape) != len(mesh.axis_names):
        raise ValueError(
            f"new_shape {new_shape} has {len(new_shape)} axes, mesh has "
            f"{len(mesh.axis_names)} ({tuple(mesh.axis_names)})"
        )
    need = 1
    for n in new_shape:
        if n < 1:
            raise ValueError(f"new_shape {new_shape} has a non-positive axis")
        need *= n
    devs = list(mesh.devices.flat) if devices is None else list(devices)
    if need > len(devs):
        raise ValueError(
            f"new_shape {new_shape} needs {need} devices, only {len(devs)} survive"
        )
    return Mesh(np.asarray(devs[:need]).reshape(new_shape), tuple(mesh.axis_names))


def maybe_init_distributed() -> bool:
    """Initialize multi-process JAX from the launch env, if configured.

    Returns True when ``jax.distributed.initialize`` was called (all of
    ``JAX_COORDINATOR_ADDRESS``/``JAX_NUM_PROCESSES``/``JAX_PROCESS_ID``
    present in the environment), False for single-process runs. Call BEFORE
    any other JAX API (device queries included) — the recipe at the top of
    this module.
    """
    addr = os.environ.get("JAX_COORDINATOR_ADDRESS")
    num = os.environ.get("JAX_NUM_PROCESSES")
    pid = os.environ.get("JAX_PROCESS_ID")
    if not (addr and num and pid):
        return False
    jax.distributed.initialize(
        coordinator_address=addr, num_processes=int(num), process_id=int(pid)
    )
    return True
