"""Production meshes (assignment §MULTI-POD DRY-RUN).

``make_production_mesh`` is a function (not a module-level constant) so that
importing this module never touches JAX device state.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if data is None:
        data = n // model
    return jax.make_mesh((data, model), ("data", "model"))
