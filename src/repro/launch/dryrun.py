import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run (assignment deliverable e).

Lowers + compiles every (architecture × input shape) cell against the
production meshes — 16×16 (single pod, 256 chips) and 2×16×16 (two pods,
512 chips) — using ShapeDtypeStruct inputs only (no allocation), then
records memory analysis, cost analysis and the HLO-derived roofline terms.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] [--resume]   # batch driver
  python -m repro.launch.dryrun --viterbi                        # decoder cells

The batch driver runs every cell in a fresh subprocess (XLA state isolation
+ peak-RSS control on the 1-core CPU container) and writes one JSON report
per cell under reports/dryrun/.
"""

import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

REPORTS = Path(__file__).resolve().parents[3] / "reports" / "dryrun"


def _cell_report_path(arch: str, shape: str, multi_pod: bool) -> Path:
    mesh = "pod2x16x16" if multi_pod else "pod16x16"
    return REPORTS / f"{arch}__{shape}__{mesh}.json"


# ======================================================================================
# single-cell runner (executes inside the subprocess)
# ======================================================================================
def run_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.configs.base import SHAPES
    from repro.launch.hlo_analysis import analyze_hlo
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import ENCODER_CTX, SKIPS, input_specs, make_cell
    from repro.sharding.rules import axis_rules, tree_shardings
    from repro._unused.models import lm
    from repro._unused.serve.serve_step import make_decode_step, make_prefill_step
    from repro._unused.train.optimizer import AdamWConfig, adamw_init
    from repro._unused.train.train_step import make_train_step

    t0 = time.time()
    if (arch, shape_name) in SKIPS:
        return {
            "arch": arch, "shape": shape_name, "mesh": "2x16x16" if multi_pod else "16x16",
            "status": "skip", "reason": SKIPS[(arch, shape_name)],
        }

    cell = make_cell(arch, shape_name)
    cfg = cell.cfg
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    specs = input_specs(cell)

    # §Perf A/B knobs: REPRO_MOE_RULES=fsdp disables expert parallelism
    # (experts replicated / FSDP-gathered — the naive baseline);
    # REPRO_BF16_GATHER=0 keeps f32 FSDP gathers (paper-typical baseline).
    rules_override = None
    if os.environ.get("REPRO_MOE_RULES") == "fsdp":
        from repro.sharding.rules import DEFAULT_RULES, SINGLE_POD_RULES

        base = DEFAULT_RULES if multi_pod else SINGLE_POD_RULES
        rules_override = dict(base, experts=None)
    bf16_gather = os.environ.get("REPRO_BF16_GATHER", "1") != "0"

    with axis_rules(mesh, rules_override) as rules:
        paxes = lm.param_axes(cfg)
        pspec = tree_shardings(specs["params"], paxes, rules)

        if cell.kind == "train":
            opt_cfg = AdamWConfig()
            opt_specs = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), specs["params"])
            repl = jax.NamedSharding(mesh, jax.sharding.PartitionSpec())
            from repro._unused.train.optimizer import OptState

            oshard = OptState(step=repl, m=pspec, v=pspec)
            bshard = {
                k: jax.NamedSharding(
                    mesh, rules.spec(("batch",) + (None,) * (len(v.shape) - 1), shape=v.shape)
                )
                for k, v in specs["batch"].items()
            }
            step = make_train_step(cfg, opt_cfg, bf16_gather=bf16_gather)
            jitted = jax.jit(
                step,
                in_shardings=(pspec, oshard, bshard),
                out_shardings=(pspec, oshard, None),
                donate_argnums=(0, 1),
            )
            args = (specs["params"], opt_specs, specs["batch"])
        elif cell.kind == "prefill":
            bshard = {
                k: jax.NamedSharding(
                    mesh, rules.spec(("batch",) + (None,) * (len(v.shape) - 1), shape=v.shape)
                )
                for k, v in specs["batch"].items()
            }
            step = make_prefill_step(cfg)
            jitted = jax.jit(
                step,
                in_shardings=(pspec, bshard),
                out_shardings=jax.NamedSharding(
                    mesh, rules.spec(("batch",), shape=(cell.shape.global_batch,))
                ),
            )
            args = (specs["params"], specs["batch"])
        else:  # decode
            ctx_parallel = cell.shape.seq_len >= (1 << 15)
            caxes = lm.cache_axes(cfg, ctx_parallel=ctx_parallel, cross=cfg.encdec)
            cspec = tree_shardings(specs["cache"], caxes, rules)
            B = cell.shape.global_batch
            tshard = jax.NamedSharding(mesh, rules.spec(("batch", None), shape=(B, 1)))
            repl = jax.NamedSharding(mesh, jax.sharding.PartitionSpec())
            step = make_decode_step(cfg, cell.shape.seq_len)
            jitted = jax.jit(
                step,
                in_shardings=(pspec, tshard, cspec, repl),
                out_shardings=(
                    jax.NamedSharding(mesh, rules.spec(("batch",), shape=(B,))),
                    cspec,
                ),
                donate_argnums=(2,),
            )
            args = (specs["params"], specs["tokens"], specs["cache"], specs["cache_len"])

        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    # ---- analyses -------------------------------------------------------------------
    report = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": int(n_chips),
        "kind": cell.kind,
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
    }
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            for k in (
                "temp_size_in_bytes", "argument_size_in_bytes", "output_size_in_bytes",
                "alias_size_in_bytes", "generated_code_size_in_bytes",
            ):
                v = getattr(ma, k, None)
                if v is not None:
                    report.setdefault("memory", {})[k] = int(v)
    except Exception as e:  # noqa: BLE001
        report["memory_error"] = str(e)
    try:
        ca = compiled.cost_analysis()
        if ca:
            report["cost_analysis"] = {
                k: float(v) for k, v in ca.items()
                if k in ("flops", "bytes accessed", "transcendentals") or k.startswith("bytes accessed")
            }
    except Exception as e:  # noqa: BLE001
        report["cost_error"] = str(e)

    hlo = compiled.as_text()
    st = analyze_hlo(hlo)
    report["hlo"] = {
        "flops_per_device": st.flops,
        "bytes_per_device": st.bytes_accessed,
        "collective_bytes_per_device": st.collective_bytes,
        "collective_counts": st.collective_counts,
        "n_while": st.n_while,
        "trip_counts": st.trip_counts,
        "hlo_chars": len(hlo),
    }

    # model FLOPs (roofline §: 6·N_active·D for train, 2·N_active·D otherwise)
    n_active = cfg.n_active_params_estimate
    B, S = cell.shape.global_batch, cell.shape.seq_len
    if cell.kind == "train":
        tokens = B * S
        model_flops = 6.0 * n_active * tokens
    elif cell.kind == "prefill":
        tokens = B * S
        model_flops = 2.0 * n_active * tokens
    else:
        tokens = B  # one token per sequence
        model_flops = 2.0 * n_active * tokens
    report["model_flops_global"] = model_flops
    report["tokens_per_step"] = tokens
    report["n_active_params"] = n_active
    report["total_s"] = round(time.time() - t0, 2)
    return report


def run_viterbi_cell(variant: str, multi_pod: bool) -> dict:
    """Dry-run the PBVD decoder as a data-plane workload on the same mesh."""
    import jax
    import jax.numpy as jnp

    from repro.core.trellis import CCSDS_27
    from repro.kernels.ops import pbvd_decode_blocks
    from repro.launch.hlo_analysis import analyze_hlo
    from repro.launch.mesh import make_production_mesh
    from repro.sharding.rules import axis_rules

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    D, L = 512, 42
    T = D + 2 * L
    n_blocks = {"stream_16m_int8": 32768, "stream_4m_f32": 8192}[variant]
    dtype = jnp.int8 if variant.endswith("int8") else jnp.float32

    with axis_rules(mesh) as rules:
        bspec = jax.NamedSharding(mesh, rules.spec((None, None, "blocks")))

        def step(blocks):
            return pbvd_decode_blocks(
                blocks, CCSDS_27, decode_start=L, n_decode=D, backend="ref"
            )

        jitted = jax.jit(
            step,
            in_shardings=(bspec,),
            out_shardings=jax.NamedSharding(mesh, rules.spec((None, "blocks"))),
        )
        sds = jax.ShapeDtypeStruct((T, CCSDS_27.R, n_blocks), dtype)
        lowered = jitted.lower(sds)
        compiled = lowered.compile()

    st = analyze_hlo(compiled.as_text())
    report = {
        "arch": "viterbi-ccsds",
        "shape": variant,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": int(mesh.devices.size),
        "kind": "decode_stream",
        "status": "ok",
        "bits_per_step": D * n_blocks,
        "hlo": {
            "flops_per_device": st.flops,
            "bytes_per_device": st.bytes_accessed,
            "collective_bytes_per_device": st.collective_bytes,
            "collective_counts": st.collective_counts,
        },
        "total_s": round(time.time() - t0, 2),
    }
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            report["memory"] = {
                k: int(getattr(ma, k))
                for k in ("temp_size_in_bytes", "argument_size_in_bytes", "output_size_in_bytes")
                if getattr(ma, k, None) is not None
            }
    except Exception:  # noqa: BLE001
        pass
    return report


# ======================================================================================
# batch driver
# ======================================================================================
def _run_subprocess(arch: str, shape: str, multi_pod: bool, timeout: int = 3000) -> dict:
    out_path = _cell_report_path(arch, shape, multi_pod)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    cmd = [
        sys.executable, "-m", "repro.launch.dryrun",
        "--arch", arch, "--shape", shape, "--out", str(out_path),
    ]
    if multi_pod:
        cmd.append("--multi-pod")
    env = dict(os.environ)
    t0 = time.time()
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout, env=env)
        if out_path.exists():
            return json.loads(out_path.read_text())
        report = {
            "arch": arch, "shape": shape, "mesh": "2x16x16" if multi_pod else "16x16",
            "status": "error",
            "error": (proc.stderr or proc.stdout or "")[-2000:],
            "total_s": round(time.time() - t0, 2),
        }
    except subprocess.TimeoutExpired:
        report = {
            "arch": arch, "shape": shape, "mesh": "2x16x16" if multi_pod else "16x16",
            "status": "timeout", "total_s": round(time.time() - t0, 2),
        }
    out_path.write_text(json.dumps(report, indent=2))
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--viterbi", action="store_true")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--out")
    args = ap.parse_args()

    if args.all:
        from repro.configs.base import SHAPES, list_archs  # no jax needed

        cells = [(a, s) for a in list_archs() for s in SHAPES]
        for mp in ([False, True]):
            for arch, shape in cells:
                path = _cell_report_path(arch, shape, mp)
                if args.resume and path.exists():
                    r = json.loads(path.read_text())
                    if r.get("status") in ("ok", "skip"):
                        continue
                r = _run_subprocess(arch, shape, mp)
                print(
                    f"[{r.get('status','?'):7s}] {arch} × {shape} × {r.get('mesh')}"
                    f"  ({r.get('total_s', '?')}s)",
                    flush=True,
                )
        return

    if args.viterbi:
        for mp in (False, True):
            for variant in ("stream_16m_int8", "stream_4m_f32"):
                r = run_viterbi_cell(variant, mp)
                p = _cell_report_path("viterbi-ccsds", variant, mp)
                p.parent.mkdir(parents=True, exist_ok=True)
                p.write_text(json.dumps(r, indent=2))
                print(f"[{r['status']:7s}] viterbi × {variant} × {r['mesh']} ({r['total_s']}s)", flush=True)
        return

    if args.arch == "viterbi-ccsds":
        report = run_viterbi_cell(args.shape, args.multi_pod)
    else:
        try:
            report = run_cell(args.arch, args.shape, args.multi_pod)
        except Exception:  # noqa: BLE001
            report = {
                "arch": args.arch, "shape": args.shape,
                "mesh": "2x16x16" if args.multi_pod else "16x16",
                "status": "error", "error": traceback.format_exc()[-4000:],
            }
    text = json.dumps(report, indent=2)
    if args.out:
        Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        Path(args.out).write_text(text)
    print(text)
    sys.exit(0 if report.get("status") in ("ok", "skip") else 1)


if __name__ == "__main__":
    main()
