"""Durable chunk journal + decode integrity sentinel (DESIGN.md §15).

PR 9 made the async decode service degrade gracefully while the process
lives; this module is what survives the process dying.  Two pieces:

* :class:`ChunkJournal` — an append-only write-ahead log of everything the
  service admitted but has not yet durably handed to the client, plus
  atomic checkpoints of per-stream session state.  The paper's block
  independence (arXiv:1608.00066) is what makes replay sound: a PBVD block
  decodes identically regardless of batch composition, so re-feeding the
  journaled chunks into restored sessions reproduces the uninterrupted
  run's bits exactly — no decoder state beyond the tiny session snapshot
  (overlap tail + puncture phase + counters) needs to persist.

  Record format: ``[u32 length][u32 crc32][pickle payload]`` per record,
  payload ``(seq, kind, *fields)`` with a journal-global monotone ``seq``.
  A SIGKILL can land mid-``write()``; recovery tolerates the torn tail by
  stopping at the first incomplete or checksum-failing record — everything
  before it is intact by construction (records are flushed in order).

  Checkpoints are written tmp → fsync → ``os.replace`` (atomic on POSIX)
  and carry ``last_seq``; a crash between the checkpoint rename and the
  log truncation cannot double-apply records because recovery skips every
  record with ``seq <= last_seq``.

* :class:`IntegritySentinel` — the end-to-end screen against silent data
  corruption: re-encode each delivered block with the stream's
  convolutional code (:func:`repro.core.encoder.encode_np` from the
  tracked encoder state) and compare the re-encoded symbols against the
  sign of the received soft symbols.  The ML path's hard decisions agree
  with the channel on all but the channel-noise fraction of symbols; a
  post-decode bit flip changes ~(v+1)·R re-encoded symbols at once, so an
  agreement fraction below ``min_agreement`` flags corruption rather than
  noise (bound derivation in DESIGN.md §15).  Punctured (never-received)
  symbol slots are stored as exactly 0.0 and excluded from the comparison,
  as is the zero-padded flush tail.
"""

from __future__ import annotations

import os
import pickle
import struct
import zlib

import numpy as np

from repro.core.encoder import encode_np
from repro.launch.faults import IntegrityError

__all__ = ["ChunkJournal", "IntegritySentinel"]

_HDR = struct.Struct("<II")  # (payload length, crc32 of payload)

# journal record kinds (the full vocabulary; see DESIGN.md §15):
#   ("open",   sid)                — stream sid admitted to the service
#   ("admit",  sid, chunk)         — chunk buffered into sid's session
#   ("ack",    sid, acked_bits)    — client durably holds sid's first N bits
#   ("commit", dispatches)         — a coalesced dispatch completed
#   ("finish", sid)                — sid flushed + fully delivered
#   ("fail",   sid, message)       — sid quarantined (replay drops it)


class ChunkJournal:
    """Append-only WAL + checkpoint pair under one directory.

    Parameters
    ----------
    path: directory holding ``journal.log`` and ``checkpoint.bin`` (created
        if missing).
    fsync: fsync the log after every append.  Default False: a ``flush()``
        hands the bytes to the OS, which survives SIGKILL / process death
        (the crash model of this layer); fsync additionally survives kernel
        panics and power loss at a per-record latency cost.
    """

    def __init__(self, path: str, *, fsync: bool = False):
        self.dir = str(path)
        os.makedirs(self.dir, exist_ok=True)
        self.log_path = os.path.join(self.dir, "journal.log")
        self.ckpt_path = os.path.join(self.dir, "checkpoint.bin")
        self._fsync = bool(fsync)
        self._f = open(self.log_path, "ab")
        ckpt = self.load_checkpoint()
        recs = self.records()
        # seq continues past everything durably recorded so far, whether it
        # lives in the log or was folded into the checkpoint
        self._seq = max(
            ckpt["last_seq"] if ckpt is not None else 0,
            recs[-1][0] if recs else 0,
        )

    # ---- appending -----------------------------------------------------------------
    @property
    def seq(self) -> int:
        """Sequence number of the most recently appended record."""
        return self._seq

    def append(self, kind: str, *fields) -> int:
        """Durably append one record; returns its sequence number.

        Header + payload go down in a single ``write()`` so a torn record
        can only be a truncated tail, never an interleaving.
        """
        self._seq += 1
        payload = pickle.dumps((self._seq, kind, *fields), protocol=4)
        self._f.write(_HDR.pack(len(payload), zlib.crc32(payload)) + payload)
        self._f.flush()
        if self._fsync:
            os.fsync(self._f.fileno())
        return self._seq

    # ---- reading -------------------------------------------------------------------
    def records(self) -> list[tuple]:
        """Every intact record in the log, in append order.

        Torn-tail tolerant: scanning stops at the first incomplete,
        checksum-failing, or unpicklable record — the crash frontier.  The
        records before it were flushed earlier and are intact.
        """
        try:
            with open(self.log_path, "rb") as f:
                data = f.read()
        except FileNotFoundError:
            return []
        out, off = [], 0
        while off + _HDR.size <= len(data):
            n, crc = _HDR.unpack_from(data, off)
            lo = off + _HDR.size
            if lo + n > len(data):
                break  # torn tail: the record's bytes never fully landed
            payload = data[lo : lo + n]
            if zlib.crc32(payload) != crc:
                break  # corrupt record: nothing after it is trustworthy
            try:
                rec = pickle.loads(payload)
            except Exception:  # noqa: BLE001 - a passing crc makes this ~unreachable
                break
            out.append(rec)
            off = lo + n
        return out

    def load_checkpoint(self) -> dict | None:
        """The latest checkpoint state, or None (absent or unreadable).

        The checkpoint is fsync'd before its atomic rename, so "unreadable"
        means pre-rename garbage was never promoted — treat it as absent.
        """
        try:
            with open(self.ckpt_path, "rb") as f:
                data = f.read()
        except FileNotFoundError:
            return None
        if len(data) < _HDR.size:
            return None
        n, crc = _HDR.unpack_from(data, 0)
        payload = data[_HDR.size : _HDR.size + n]
        if len(payload) != n or zlib.crc32(payload) != crc:
            return None
        try:
            return pickle.loads(payload)
        except Exception:  # noqa: BLE001
            return None

    def load(self) -> tuple[dict | None, list[tuple]]:
        """(checkpoint state, unapplied records) — the recovery inputs.

        Records already folded into the checkpoint (``seq <= last_seq``)
        are filtered out, which is what makes the checkpoint-rename /
        log-truncate pair crash-safe in either order.
        """
        ckpt = self.load_checkpoint()
        last = ckpt["last_seq"] if ckpt is not None else 0
        return ckpt, [r for r in self.records() if r[0] > last]

    # ---- checkpointing -------------------------------------------------------------
    def write_checkpoint(self, state: dict) -> None:
        """Atomically persist ``state`` and truncate the superseded log.

        ``last_seq`` is stamped into the state; every record in the log at
        this moment is ≤ it (appends and checkpoints are issued from the
        same event loop), so the whole log is superseded and truncates.
        """
        state = dict(state)
        state["last_seq"] = self._seq
        payload = pickle.dumps(state, protocol=4)
        tmp = self.ckpt_path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(_HDR.pack(len(payload), zlib.crc32(payload)) + payload)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.ckpt_path)
        self._f.truncate(0)

    def close(self) -> None:
        self._f.close()


class IntegritySentinel:
    """Sampled re-encode screen over delivered blocks (module docstring).

    ``rate`` is the sampling knob: 1.0 checks every delivery, 0.02 checks
    ~2% of them (i.i.d. from a seeded rng, so a schedule is reproducible
    for a fixed consultation order) — the check is O(block) numpy work on
    the host, so sampling makes it cost ~0 at full load.
    """

    def __init__(
        self,
        *,
        rate: float = 1.0,
        min_agreement: float = 0.85,
        seed: int = 0,
    ):
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {rate}")
        if not 0.0 < min_agreement <= 1.0:
            raise ValueError(
                f"min_agreement must be in (0, 1], got {min_agreement}"
            )
        self.rate = float(rate)
        self.min_agreement = float(min_agreement)
        self._rng = np.random.default_rng([int(seed), len("sentinel")])
        self.checked = 0
        self.flagged = 0

    def sample(self) -> bool:
        """Should this delivery be checked? (consumes one rng draw iff 0<rate<1)"""
        if self.rate >= 1.0:
            return True
        if self.rate <= 0.0:
            return False
        return bool(self._rng.random() < self.rate)

    def check(self, bits, window, code, init_state: int, *, stream=None):
        """Screen ``bits`` (delivered payload) against ``window`` (the soft
        symbols those stages decoded from, first stage aligned with
        ``bits[0]``); returns an :class:`IntegrityError` or None.

        ``init_state`` is the encoder state at ``bits[0]`` (the last ``v``
        previously delivered bits — see :func:`repro.core.encoder
        .encoder_state`).  Symbols that are exactly 0.0 (punctured erasure
        slots, zero-padded tail stages) carry no channel evidence and are
        excluded; a window shorter than ``bits`` (flush past the buffered
        tail) is implicitly all-excluded padding.
        """
        bits = np.asarray(bits)
        self.checked += 1
        if bits.size == 0:
            return None
        w = np.asarray(window, np.float32)[: len(bits)]
        coded = encode_np(bits, code, init_state)[: len(w)]
        sgn = (1 - 2 * coded).astype(np.float32)  # bit 0 → +1 (BPSK map)
        mask = w != 0.0
        n = int(mask.sum())
        if n == 0:
            return None
        agreement = float(np.mean((w * sgn)[mask] > 0.0))
        if agreement >= self.min_agreement:
            return None
        self.flagged += 1
        return IntegrityError(
            f"integrity sentinel: re-encoded block agrees with received "
            f"hard decisions on {agreement:.3f} of {n} symbols, below the "
            f"bound {self.min_agreement} — delivered bits are suspected "
            f"corrupt (not channel noise)",
            stream=stream,
            agreement=agreement,
            bound=self.min_agreement,
        )
