"""Failure taxonomy + deterministic fault injection for the serving stack.

The source paper's block independence is what makes per-stream isolation
cheap: every PBVD block decodes from its own overlapped symbol window, so a
poisoned stream can be carved out of a coalesced launch and the survivors
relaunched bit-exact.  This module gives the serving layer the vocabulary to
do that:

* :class:`DecodeError` — root of the serving failure hierarchy.

  * :class:`StreamError` — the *stream* is at fault (non-finite soft
    symbols, shape-invalid chunks, a lane-group that reproducibly kills the
    launch).  Quarantining the stream fixes the batch.
  * :class:`DispatchError` — the *launch* is at fault (compile failure,
    runtime launch error, device loss).  Retrying — possibly on a rebuilt
    mesh — is the right response; the streams are innocent.

    * :class:`MeshLost` — a device-loss dispatch failure carrying how many
      chips died, so the service can :func:`plan a rescale
      <repro.launch.elastic.plan_rescale>`.
  * :class:`CapacityError` — the *service* is at fault (admission budget or
    slab arena exhausted).  Waiting, shedding, or resizing fixes it.
    ``Backpressure`` (serve_async) and ``SlabExhausted`` (slab) subclass it.

    * :class:`ShedError` — capacity stayed exhausted past the shed
      deadline; the admission was dropped rather than parked forever.

:class:`SymbolError` subclasses both :class:`StreamError` and
``ValueError`` so engine-boundary validation keeps its historical
``ValueError`` contract while the service can catch one class for every
per-stream cause.

:class:`FaultInjector` deterministically injects each failure class at the
admission / slab / dispatch / mesh boundaries under a seeded schedule, and
:class:`RetryPolicy` bounds the retry/backoff loop around dispatch.  Both
are pure host-side bookkeeping: no jax imports, reproducible under fake
clocks.  See DESIGN.md §14 for the full failure model.
"""

from __future__ import annotations

import dataclasses
from collections import Counter
from collections.abc import Iterable, Mapping

import numpy as np

__all__ = [
    "DecodeError",
    "StreamError",
    "SymbolError",
    "DispatchError",
    "IntegrityError",
    "MeshLost",
    "CapacityError",
    "ShedError",
    "nonfinite_error",
    "check_finite_symbols",
    "RetryPolicy",
    "FaultInjector",
    "FAULT_SITES",
]


class DecodeError(RuntimeError):
    """Root of the serving failure taxonomy (DESIGN.md §14)."""


class StreamError(DecodeError):
    """The stream is at fault; quarantining it heals the batch.

    ``stream`` (optional) names the offending stream for log lines; the
    underlying exception, when one exists, rides along as ``__cause__``.
    """

    def __init__(self, message: str, *, stream: object | None = None):
        super().__init__(message)
        self.stream = stream


class SymbolError(StreamError, ValueError):
    """Shape- or value-invalid symbols at the engine boundary.

    Also a ``ValueError`` so pre-taxonomy callers that caught the engine's
    historical validation errors keep working unchanged.
    """

    def __init__(self, message: str, *, stream: object | None = None):
        # ValueError.__init__ via StreamError's super() chain only stores
        # args; run StreamError's to also pin the stream attribute.
        StreamError.__init__(self, message, stream=stream)


class IntegrityError(StreamError):
    """Delivered bits failed the re-encode integrity screen.

    Raised by the serving layer's end-to-end sentinel
    (:class:`repro.launch.journal.IntegritySentinel`): the delivered block,
    re-encoded with the stream's convolutional code, agrees with the received
    hard decisions on fewer symbols than the path-metric-implied bound allows
    — the signature of silent data corruption between the kernel and the
    delivery queue, not of channel noise.  ``agreement`` carries the measured
    fraction and ``bound`` the threshold it fell below.
    """

    def __init__(
        self,
        message: str,
        *,
        stream: object | None = None,
        agreement: float | None = None,
        bound: float | None = None,
    ):
        super().__init__(message, stream=stream)
        self.agreement = agreement
        self.bound = bound


class DispatchError(DecodeError):
    """The launch is at fault; retry (possibly on a rebuilt mesh)."""


class MeshLost(DispatchError):
    """Device loss mid-dispatch; carries the casualty count for rescale."""

    def __init__(self, message: str, *, lost_chips: int = 1):
        super().__init__(message)
        self.lost_chips = int(lost_chips)


class CapacityError(DecodeError):
    """The service is out of room; wait, shed, or resize."""


class ShedError(CapacityError):
    """Capacity stayed exhausted past the shed deadline; admission dropped."""


def nonfinite_error(where: str, n_bad: int, n_total: int) -> SymbolError:
    """Uniform engine-boundary rejection for NaN/Inf soft symbols.

    Mirrors :func:`repro.kernels.registry.knob_error`'s shape — name the
    boundary, the offending value, and what IS supported — so every
    validation error in the repo reads the same way.
    """
    return SymbolError(
        f"{where} does not accept non-finite soft symbols: {n_bad} of "
        f"{n_total} values are NaN/Inf; supported symbol values: finite "
        f"floats (or pre-quantized integers).  A single non-finite symbol "
        f"corrupts the path metrics of every stream coalesced into the "
        f"same launch, so it is refused at the boundary."
    )


def check_finite_symbols(y, where: str) -> None:
    """Raise :func:`nonfinite_error` if a float symbol array holds NaN/Inf.

    Integer arrays (pre-quantized symbols) pass through untouched, as do
    jax tracers — validation is an eager-boundary concern and abstract
    values have no concrete entries to check.
    """
    try:  # pragma: no cover - jax is always present in this repo
        import jax

        if isinstance(y, jax.core.Tracer):
            return
    except ImportError:
        pass
    arr = np.asarray(y)
    if not np.issubdtype(arr.dtype, np.floating):
        return
    bad = ~np.isfinite(arr)
    if bad.any():
        raise nonfinite_error(where, int(bad.sum()), int(arr.size))


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry with exponential backoff for dispatch failures.

    ``delay_s(attempt)`` is a pure function of the attempt index so the
    whole retry schedule is deterministic under an injected fake clock:
    the service arms ``retry_at = clock() + delay_s(k)`` and simply refuses
    to re-dispatch until the clock passes it — no real sleeping in the
    dispatch path.
    """

    max_retries: int = 3
    backoff_s: float = 0.002
    multiplier: float = 2.0
    max_backoff_s: float = 0.25

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {self.max_retries}")
        if self.backoff_s < 0.0 or self.max_backoff_s < 0.0:
            raise ValueError("backoff_s and max_backoff_s must be >= 0")
        if self.multiplier < 1.0:
            raise ValueError(f"multiplier must be >= 1, got {self.multiplier}")

    def delay_s(self, attempt: int) -> float:
        """Backoff before retry ``attempt`` (0-indexed), in seconds."""
        if attempt < 0:
            raise ValueError(f"attempt must be >= 0, got {attempt}")
        return float(min(self.backoff_s * self.multiplier**attempt, self.max_backoff_s))


FAULT_SITES = (
    "admission",
    "slab",
    "dispatch",
    "mesh",
    "stream_poison",
    # new sites append at the END: per-site rng streams are seeded by the
    # site's index in this tuple, so reordering would silently reshuffle
    # every rate-based chaos schedule
    "decode_corrupt",
)


class FaultInjector:
    """Deterministic fault injection at the serving-stack boundaries.

    Two scheduling modes, combinable per site:

    * ``schedule={"dispatch": {2, 9}}`` — fire on exactly the 2nd and 9th
      *consultation* of the ``dispatch`` site (0-indexed).  Fully
      deterministic regardless of event-loop interleaving; what the chaos
      tests use.
    * ``rates={"slab": 0.05}`` — fire i.i.d. with probability 0.05 per
      consultation, from a per-site ``np.random.default_rng([seed, site])``
      stream.  Deterministic for a fixed consultation order; what the
      degraded-mode benchmark uses.

    Sites (``FAULT_SITES``):

    * ``"admission"``  — admission-time validation failure (shape-invalid
      symbols): the sending stream is poisoned.
    * ``"slab"``       — synthetic ``SlabExhausted`` on a page reservation.
    * ``"dispatch"``   — transient launch failure; absorbed by retry.
    * ``"mesh"``       — device loss (``MeshLost(lost_chips=...)``);
      triggers the rescale/meshless fallback.
    * ``"stream_poison"`` — the Nth ``open()``-ed stream carries symbols
      that reproducibly kill any launch containing them; isolated by
      bisection.
    * ``"decode_corrupt"`` — silent data corruption: one bit of a freshly
      delivered block is flipped AFTER the kernel ran (consulted once per
      stream-with-delivery per dispatch); caught by the re-encode
      integrity sentinel, never by launch-level validation.

    ``counts[site]`` is how often a site was consulted, ``fired[site]`` how
    often it injected — both live on the instance for test assertions.
    """

    def __init__(
        self,
        seed: int = 0,
        *,
        schedule: Mapping[str, Iterable[int]] | None = None,
        rates: Mapping[str, float] | None = None,
        mesh_lost_chips: int = 1,
    ):
        self.seed = int(seed)
        self.schedule = {k: frozenset(int(i) for i in v) for k, v in (schedule or {}).items()}
        self.rates = {k: float(v) for k, v in (rates or {}).items()}
        for site in (*self.schedule, *self.rates):
            if site not in FAULT_SITES:
                raise ValueError(
                    f"unknown fault site {site!r}; supported sites: {FAULT_SITES}"
                )
        for site, rate in self.rates.items():
            if not 0.0 <= rate <= 1.0:
                raise ValueError(f"rate for {site!r} must be in [0, 1], got {rate}")
        self.mesh_lost_chips = int(mesh_lost_chips)
        self.counts: Counter[str] = Counter()
        self.fired: Counter[str] = Counter()
        self._rngs = {
            site: np.random.default_rng([self.seed, i])
            for i, site in enumerate(FAULT_SITES)
        }

    def fire(self, site: str) -> bool:
        """Consult ``site``; True means the caller must inject the fault."""
        if site not in FAULT_SITES:
            raise ValueError(
                f"unknown fault site {site!r}; supported sites: {FAULT_SITES}"
            )
        idx = self.counts[site]
        self.counts[site] += 1
        hit = idx in self.schedule.get(site, ())
        rate = self.rates.get(site, 0.0)
        if not hit and rate > 0.0:
            hit = bool(self._rngs[site].random() < rate)
        if hit:
            self.fired[site] += 1
        return hit
