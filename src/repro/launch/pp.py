"""Optional pipeline parallelism: GPipe-style microbatch pipeline over a
`pipe` mesh axis using ``shard_map`` + ``jax.lax.ppermute``.

At the 512-chip production scale FSDP×TP suffices (and avoids bubbles), so
PP is off by default; this module exists for the >4k-chip regime where a
`pipe` axis bounds the FSDP all-gather ring. The schedule is the classic
GPipe fill-drain: with M microbatches and P stages, bubble fraction =
(P-1)/(M+P-1).

Activations hop stages with ``ppermute`` (collective-permute on the wire —
point-to-point, ICI/DCN friendly). Correctness is tested against a
sequential stage composition in tests/test_distributed.py on 4 host
devices.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.sharding.smap import shard_map

__all__ = ["pipeline_apply", "bubble_fraction"]


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)


def pipeline_apply(
    stage_fn: Callable,  # (stage_params, x_microbatch) -> x_microbatch
    stage_params,  # pytree stacked over stages (leading dim = P)
    x: jnp.ndarray,  # (M, mb, ...) microbatched input
    mesh: Mesh,
    *,
    axis: str = "pipe",
):
    """Run a P-stage pipeline over M microbatches; returns (M, mb, ...)."""
    n_stages = mesh.shape[axis]
    M = x.shape[0]
    steps = M + n_stages - 1

    def body(params, xs):
        params = jax.tree.map(lambda p: p[0], params)  # this device's stage
        stage = jax.lax.axis_index(axis)

        def step(carry, t):
            acc, inflight = carry
            mb_idx = jnp.clip(t, 0, M - 1)
            first_in = jax.lax.dynamic_index_in_dim(xs, mb_idx, axis=0, keepdims=False)
            inp = jnp.where(stage == 0, first_in, inflight)
            out = stage_fn(params, inp)
            active = jnp.logical_and(t - stage >= 0, t - stage < M)
            out = jnp.where(active, out, jnp.zeros_like(out))
            # the last stage emits microbatch t (its `active` window aligns)
            emit = jnp.logical_and(stage == n_stages - 1, active)
            out_idx = jnp.clip(t - (n_stages - 1), 0, M - 1)
            prev = jax.lax.dynamic_index_in_dim(acc, out_idx, axis=0, keepdims=False)
            acc = jax.lax.dynamic_update_index_in_dim(
                acc, jnp.where(emit, out, prev), out_idx, axis=0
            )
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            nxt = jax.lax.ppermute(out, axis, perm)
            return (acc, nxt), None

        acc0 = jnp.zeros_like(xs)
        inflight0 = jnp.zeros_like(xs[0])
        (acc, _), _ = jax.lax.scan(step, (acc0, inflight0), jnp.arange(steps))
        # only the last stage's accumulator is populated → psum broadcasts it
        acc = jnp.where(stage == n_stages - 1, acc, jnp.zeros_like(acc))
        return jax.lax.psum(acc, axis)

    nd = x.ndim
    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis), P(*([None] * nd))),
        out_specs=P(*([None] * nd)),
        check=False,
    )(stage_params, x)
