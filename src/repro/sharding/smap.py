"""shard_map compatibility shim (API moved between JAX versions), plus the
lane-axis dispatch helper the mesh-bound decode path uses."""

from __future__ import annotations

__all__ = ["shard_map", "lane_shard_map"]

try:  # jax >= 0.6: top-level, check_vma kwarg
    from jax import shard_map as _sm  # type: ignore[attr-defined]

    def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check)

except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _sm_old

    def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
        return _sm_old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check)


def lane_shard_map(f, *, mesh, axes, in_rank: int, out_rank: int):
    """shard_map ``f`` over ONLY the trailing (lane) axis of its operand.

    The PBVD decode contract shards nothing but the last axis — parallel
    blocks never interact, so ``f`` runs per-shard on its local lanes with
    zero collectives. ``axes`` is the tuple of mesh axis names carrying the
    lane axis; ``in_rank``/``out_rank`` are the operand/result ranks (the
    leading axes are replicated).
    """
    from jax.sharding import PartitionSpec as P

    in_specs = P(*([None] * (in_rank - 1) + [tuple(axes)]))
    out_specs = P(*([None] * (out_rank - 1) + [tuple(axes)]))
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
