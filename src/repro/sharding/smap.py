"""shard_map compatibility shim (API moved between JAX versions)."""

from __future__ import annotations

__all__ = ["shard_map"]

try:  # jax >= 0.6: top-level, check_vma kwarg
    from jax import shard_map as _sm  # type: ignore[attr-defined]

    def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check)

except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _sm_old

    def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
        return _sm_old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check)
