"""Logical-axis sharding rules (MaxText-style, dependency-free).

Model code annotates arrays with *logical* axis names
(``shard(x, ("batch", "seq", "embed"))``); a rule-set maps logical names to
mesh axes. Outside a rule context the annotations are no-ops, so the same
model code runs single-device smoke tests and 512-chip dry-runs unchanged.

Default production mapping (see DESIGN.md §6):

  batch   → ("pod", "data")   activations data-parallel across pods × hosts
  fsdp    → "data"            parameters fully sharded over the data axis
  heads/kv/mlp/vocab/expert_mlp → "model"   tensor parallel
  seq_ctx → "model"           context parallelism for long-sequence decode
"""

from __future__ import annotations

import contextlib
import threading
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

__all__ = [
    "LogicalRules",
    "axis_rules",
    "current_rules",
    "shard",
    "logical_to_spec",
    "named_sharding",
    "block_mesh_axes",
    "DEFAULT_RULES",
    "SINGLE_POD_RULES",
]

# logical axis name → mesh axis (or tuple of mesh axes), None → replicated
DEFAULT_RULES: dict[str, str | tuple[str, ...] | None] = {
    "batch": ("pod", "data"),
    "fsdp": "data",
    "embed": None,
    "seq": None,
    "seq_ctx": "model",  # context-parallel KV for long decode
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    # Expert parallelism with automatic fallback: `experts` is listed before
    # `expert_mlp` in every MoE axes tuple, so when n_experts divides the
    # model axis (deepseek 160, jamba 16) the experts shard (true EP) and the
    # hidden dim replicates; when it doesn't (mixtral 8 on 16), the
    # shape-divisibility fallback drops `experts` and the hidden dim takes
    # the model axis instead (TP-within-expert).
    "experts": "model",
    "expert_mlp": "model",
    "conv": None,
    "state": None,
    "blocks": ("pod", "data"),  # PBVD parallel blocks
}

SINGLE_POD_RULES = dict(DEFAULT_RULES, batch="data", blocks="data")

_local = threading.local()


class LogicalRules:
    def __init__(self, mesh: Mesh, rules: Mapping[str, str | tuple[str, ...] | None]):
        self.mesh = mesh
        self.rules = dict(rules)
        # drop mappings that reference axes the mesh doesn't have
        for k, v in list(self.rules.items()):
            axes = (v,) if isinstance(v, str) else (v or ())
            if any(a not in mesh.axis_names for a in axes):
                self.rules[k] = None

    def spec(
        self, logical_axes: Sequence[str | None], shape: Sequence[int] | None = None
    ) -> PartitionSpec:
        """Map logical axes to a PartitionSpec. With ``shape`` given, mesh
        axes that do not divide the corresponding dimension are dropped
        greedily (JAX requires exact tiling for argument shardings — e.g.
        GQA kv=8 on a 16-way model axis falls back to replicated KV)."""
        parts = []
        used: set[str] = set()
        for i, ax in enumerate(logical_axes):
            if ax is None:
                parts.append(None)
                continue
            m = self.rules.get(ax)
            if m is None:
                parts.append(None)
                continue
            maxes = (m,) if isinstance(m, str) else tuple(m)
            maxes = tuple(a for a in maxes if a not in used)
            if shape is not None:
                dim = shape[i]
                while maxes:
                    prod = 1
                    for a in maxes:
                        prod *= self.mesh.shape[a]
                    if prod and dim % prod == 0:
                        break
                    maxes = maxes[:-1]
            used.update(maxes)
            if not maxes:
                parts.append(None)
            elif len(maxes) == 1:
                parts.append(maxes[0])
            else:
                parts.append(maxes)
        return PartitionSpec(*parts)


def current_rules() -> LogicalRules | None:
    return getattr(_local, "rules", None)


def _mesh_context(mesh: Mesh):
    """Enter ``mesh`` as the ambient mesh, across JAX API generations.

    Newer JAX exposes ``jax.set_mesh`` / ``jax.sharding.use_mesh`` context
    managers; older releases (like the one pinned here) only support the mesh
    itself as a context manager. Try them in order of recency.
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    for name in ("use_mesh", "set_mesh"):
        fn = getattr(jax.sharding, name, None)
        if fn is not None:
            return fn(mesh)
    return mesh  # legacy: Mesh is itself a context manager


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: Mapping[str, str | tuple[str, ...] | None] | None = None):
    """Activate a logical→mesh rule-set (and the mesh) for the enclosed code."""
    prev = getattr(_local, "rules", None)
    if rules is None:
        rules = DEFAULT_RULES if "pod" in mesh.axis_names else SINGLE_POD_RULES
    _local.rules = LogicalRules(mesh, rules)
    try:
        with _mesh_context(mesh):
            yield _local.rules
    finally:
        _local.rules = prev


def logical_to_spec(logical_axes: Sequence[str | None]) -> PartitionSpec:
    r = current_rules()
    if r is None:
        return PartitionSpec()
    return r.spec(logical_axes)


def named_sharding(logical_axes: Sequence[str | None]) -> NamedSharding | None:
    r = current_rules()
    if r is None:
        return None
    return NamedSharding(r.mesh, r.spec(logical_axes))


def block_mesh_axes(mesh: Mesh) -> tuple[str, ...]:
    """Mesh axes the PBVD ``blocks`` logical axis maps to on ``mesh``.

    Resolves the ``"blocks"`` rule (``("pod", "data")`` multi-pod,
    ``"data"`` single-pod) and drops axes the mesh does not have — the
    engine's default ``block_axes`` when bound to a mesh without an explicit
    override (``DecoderEngine(cfg, mesh=m, block_axes=None)``).
    """
    rules = DEFAULT_RULES if "pod" in mesh.axis_names else SINGLE_POD_RULES
    m = rules["blocks"]
    axes = (m,) if isinstance(m, str) else tuple(m or ())
    resolved = tuple(a for a in axes if a in mesh.axis_names)
    if not resolved:
        raise ValueError(
            f"no 'blocks' rule axis {axes} exists on mesh axes "
            f"{tuple(mesh.axis_names)}; pass block_axes explicitly"
        )
    return resolved


def shard(x: jax.Array, logical_axes: Sequence[str | None]) -> jax.Array:
    """Annotate ``x`` with a sharding constraint; no-op outside a rule context."""
    r = current_rules()
    if r is None:
        return x
    spec = r.spec(logical_axes, shape=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(r.mesh, spec))


def tree_shardings(sds_tree, axes_tree, rules: LogicalRules):
    """Shape-aware NamedShardings for a pytree of ShapeDtypeStructs/arrays.

    ``axes_tree`` mirrors ``sds_tree`` with logical-axis tuples as leaves.
    """
    flat_sds, treedef = jax.tree.flatten(sds_tree)
    # axes leaves are PLAIN tuples of axis names; NamedTuples (KVCache etc.)
    # must still be traversed as pytrees
    flat_axes = jax.tree.leaves(axes_tree, is_leaf=lambda a: type(a) is tuple)
    if len(flat_sds) != len(flat_axes):
        raise ValueError(
            f"sds tree has {len(flat_sds)} leaves but axes tree has {len(flat_axes)}"
        )
    out = [
        NamedSharding(rules.mesh, rules.spec(a, shape=s.shape))
        for s, a in zip(flat_sds, flat_axes)
    ]
    return jax.tree.unflatten(treedef, out)
