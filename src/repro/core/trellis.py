"""Trellis description of an (R, 1, K) convolutional code.

Implements the paper's state/butterfly formalism (§II, §III-B):

* state ``d = (D_{v-1} ... D_0)_2`` with ``v = K-1`` memory cells; ``D_{v-1}``
  is the most recently shifted-in bit, ``D_0`` the oldest.
* encoder output for input bit ``x`` at state ``S_d`` (eq. 2)::

      c^{(r)} = (x · g^{(r)}_{K-1}) ⊕ (D_{K-2} · g^{(r)}_{K-2}) ⊕ ... ⊕ (D_0 · g^{(r)}_0)

* transition: ``next = (x << (v-1)) | (d >> 1)``.
* butterfly ``j`` (``j = 0 .. N/2-1``): source states ``2j, 2j+1``; target
  ``j`` for input 0 and ``j + N/2`` for input 1. Butterfly outputs (eqs. 3-6)::

      α = c(S_{2j}, 0)      β = α ⊕ g_{K-1}      γ = α ⊕ g_0      θ = α ⊕ g_{K-1} ⊕ g_0

  (XORs applied per filter r; as R-bit integers the masks are ``x_mask``
  = bits ``g^{(r)}_{K-1}`` and ``l_mask`` = bits ``g^{(r)}_0``.)

The group classification (§III-B / Table II) groups butterflies by ``α``:
at most ``2^R`` groups, hence only ``2^R`` distinct branch metrics per stage.
"""

from __future__ import annotations

import dataclasses
from functools import cached_property
from typing import Sequence, Tuple

import numpy as np

__all__ = ["ConvCode", "CCSDS_27", "parity"]


def parity(x: np.ndarray | int) -> np.ndarray | int:
    """Bitwise parity (popcount mod 2) of non-negative ints, vectorized."""
    x = np.asarray(x)
    out = np.zeros_like(x)
    while np.any(x):
        out ^= x & 1
        x = x >> 1
    return out


@dataclasses.dataclass(frozen=True)
class ConvCode:
    """An (R, 1, K) convolutional code described by generator polynomials.

    ``polys[r]`` is the r-th generator polynomial as a bit sequence
    ``[g_{K-1}, g_{K-2}, ..., g_0]`` (paper order — MSB = input tap).
    """

    polys: Tuple[Tuple[int, ...], ...]

    def __post_init__(self):
        ks = {len(p) for p in self.polys}
        if len(ks) != 1:
            raise ValueError(f"all generator polynomials must share K, got {ks}")
        if not all(b in (0, 1) for p in self.polys for b in p):
            raise ValueError("generator polynomials must be binary")

    # ---- scalar shape parameters -------------------------------------------------
    @property
    def R(self) -> int:
        return len(self.polys)

    @property
    def K(self) -> int:
        return len(self.polys[0])

    @property
    def v(self) -> int:  # number of memory cells
        return self.K - 1

    @property
    def n_states(self) -> int:
        return 1 << self.v

    @property
    def n_butterflies(self) -> int:
        return self.n_states // 2

    @property
    def rate(self) -> float:
        return 1.0 / self.R

    # ---- integer-mask views of the polynomials -----------------------------------
    @cached_property
    def poly_ints(self) -> np.ndarray:
        """polys as integers with bit i = g^{(r)}_i (so bit K-1 = input tap)."""
        out = []
        for p in self.polys:
            val = 0
            for i, bit in enumerate(p):  # p[0] = g_{K-1}
                val |= bit << (self.K - 1 - i)
            out.append(val)
        return np.array(out, dtype=np.int64)

    @cached_property
    def x_mask(self) -> int:
        """R-bit integer whose bit r (MSB-first) = g^{(r)}_{K-1} (input tap)."""
        m = 0
        for r in range(self.R):
            m = (m << 1) | ((self.poly_ints[r] >> (self.K - 1)) & 1)
        return int(m)

    @cached_property
    def l_mask(self) -> int:
        """R-bit integer whose bit r (MSB-first) = g^{(r)}_0 (oldest-bit tap)."""
        m = 0
        for r in range(self.R):
            m = (m << 1) | (self.poly_ints[r] & 1)
        return int(m)

    # ---- encoder output tables ----------------------------------------------------
    def output_bits(self, state: np.ndarray | int, x: np.ndarray | int) -> np.ndarray:
        """Per-filter output bits c^{(r)}(S_state, x): shape (..., R)."""
        state = np.asarray(state, dtype=np.int64)
        x = np.asarray(x, dtype=np.int64)
        lows = self.poly_ints & ((1 << self.v) - 1)  # memory taps
        tap_x = (self.poly_ints >> (self.K - 1)) & 1  # input tap
        mem = parity(state[..., None] & lows)  # (..., R)
        return (mem ^ (x[..., None] * tap_x)).astype(np.int64)

    def output_int(self, state: np.ndarray | int, x: np.ndarray | int) -> np.ndarray:
        """Encoder output as an R-bit integer, c^{(1)} in the MSB (paper order)."""
        bits = self.output_bits(state, x)
        val = np.zeros(bits.shape[:-1], dtype=np.int64)
        for r in range(self.R):
            val = (val << 1) | bits[..., r]
        return val

    # ---- butterfly group classification (§III-B) ----------------------------------
    @cached_property
    def alpha(self) -> np.ndarray:
        """α for each butterfly j: output int of source state 2j with input 0."""
        j = np.arange(self.n_butterflies)
        return self.output_int(2 * j, 0)

    @cached_property
    def butterfly_codewords(self) -> np.ndarray:
        """(n_butterflies, 4) int codewords [α, β, γ, θ] per butterfly."""
        a = self.alpha
        return np.stack(
            [a, a ^ self.x_mask, a ^ self.l_mask, a ^ self.x_mask ^ self.l_mask],
            axis=1,
        )

    @cached_property
    def n_groups(self) -> int:
        return len(np.unique(self.alpha))

    @cached_property
    def groups(self) -> list[dict]:
        """Paper Table II: one entry per distinct α, with member source states.

        Each dict has keys ``alpha, beta, gamma, theta`` (R-bit ints) and
        ``states`` (sorted source-state indices 2j, 2j+1 of member butterflies).
        """
        out = []
        for a in sorted(np.unique(self.alpha)):
            js = np.nonzero(self.alpha == a)[0]
            states = sorted(np.concatenate([2 * js, 2 * js + 1]).tolist())
            out.append(
                dict(
                    alpha=int(a),
                    beta=int(a ^ self.x_mask),
                    gamma=int(a ^ self.l_mask),
                    theta=int(a ^ self.x_mask ^ self.l_mask),
                    states=states,
                )
            )
        return out

    # ---- ACS constant tables (consumed by kernels/ref) ----------------------------
    @cached_property
    def acs_tables(self) -> dict:
        """Static per-butterfly codeword indices for the vectorized ACS update.

        For target state j (top half):    predecessors 2j (codeword α_j)
                                          and 2j+1 (codeword γ_j).
        For target state j+N/2 (bottom):  predecessors 2j (codeword β_j)
                                          and 2j+1 (codeword θ_j).

        Returns int32 arrays of shape (n_butterflies,):
          ``cw_top_even, cw_top_odd, cw_bot_even, cw_bot_odd``
        plus ``onehot_{...}`` float32 one-hot matrices (n_butterflies, 2^R)
        used by the Pallas kernel to expand the 2^R-entry BM table with a
        static matmul (the TPU-native form of the paper's group lookup).
        """
        cw = self.butterfly_codewords
        tabs = dict(
            cw_top_even=cw[:, 0].astype(np.int32),  # α
            cw_bot_even=cw[:, 1].astype(np.int32),  # β
            cw_top_odd=cw[:, 2].astype(np.int32),  # γ
            cw_bot_odd=cw[:, 3].astype(np.int32),  # θ
        )
        n_cw = 1 << self.R
        for key in list(tabs):
            idx = tabs[key]
            oh = np.zeros((self.n_butterflies, n_cw), dtype=np.float32)
            oh[np.arange(self.n_butterflies), idx] = 1.0
            tabs["onehot_" + key[3:]] = oh
        return tabs

    # ---- codeword ±1 sign table (for correlation branch metrics) ------------------
    @cached_property
    def codeword_signs(self) -> np.ndarray:
        """(2^R, R) float32: row c = (2·bits(c) - 1), c^{(1)} at column 0.

        Branch metric (to MINIMIZE) for received soft symbols y (BPSK map
        bit b → 1-2b, i.e. 0 → +1): BM(c) = Σ_r y_r · (2 c_r - 1).
        """
        n_cw = 1 << self.R
        rows = []
        for c in range(n_cw):
            bits = [(c >> (self.R - 1 - r)) & 1 for r in range(self.R)]
            rows.append([2.0 * b - 1.0 for b in bits])
        return np.array(rows, dtype=np.float32)

    # ---- symmetry-folded branch metrics (antipodal label structure) ----------------
    # The correlation metric is antipodal in the label: complementing every
    # output bit flips every sign row entry, so BM(~c) = -BM(c). The 2^R
    # labels therefore pair into 2^(R-1) ± pairs and only 2^(R-1) distinct
    # branch metrics exist per stage — half the paper's 2^R group metrics.
    # The canonical representative of a pair is the label whose MSB (stream
    # c^{(1)}) is 0, i.e. c < 2^(R-1); the other member is its complement.
    @property
    def n_folded(self) -> int:
        """Distinct folded branch metrics per stage: 2^(R-1)."""
        return 1 << (self.R - 1)

    @cached_property
    def fold_index(self) -> np.ndarray:
        """(2^R,) int32: folded-table row of each label (its ± representative)."""
        c = np.arange(1 << self.R)
        mask = (1 << self.R) - 1
        return np.where(c < self.n_folded, c, c ^ mask).astype(np.int32)

    @cached_property
    def fold_sign(self) -> np.ndarray:
        """(2^R,) int32 ±1: BM(c) = fold_sign[c] · BM_folded[fold_index[c]]."""
        c = np.arange(1 << self.R)
        return np.where(c < self.n_folded, 1, -1).astype(np.int32)

    @cached_property
    def folded_codeword_signs(self) -> np.ndarray:
        """(2^(R-1), R) float32 sign rows of the fold representatives.

        ``BM_folded = folded_codeword_signs @ y`` is the folded table;
        expansion to the full 2^R table is ``fold_sign · BM_folded[fold_index]``
        (exact in both IEEE float — negation and round-to-nearest are
        sign-symmetric — and integer arithmetic).
        """
        return self.codeword_signs[: self.n_folded]

    @cached_property
    def folded_acs_tables(self) -> dict:
        """Static per-butterfly folded lookups for the ACS kernels.

        For each of the four butterfly codeword rows (α top/even, γ top/odd,
        β bottom/even, θ bottom/odd — the order the kernels consume):
          ``fold_cw_*``:  (n_butterflies,) int32 folded-table row indices
          ``fold_sgn_*``: (n_butterflies,) int32 ±1 signs
        so each per-butterfly metric row is a sign-flip of one of the
        2^(R-1) folded entries — the signs are static and applied in-register.
        """
        cw = self.butterfly_codewords  # (nb, 4) as [α, β, γ, θ]
        order = dict(te=0, to=2, be=1, bo=3)  # kernel row order α, γ, β, θ
        out = {}
        for key, col in order.items():
            labels = cw[:, col]
            out["fold_cw_" + key] = self.fold_index[labels].astype(np.int32)
            out["fold_sgn_" + key] = self.fold_sign[labels].astype(np.int32)
        return out


# The paper's reference code: CCSDS (2,1,7), g1 = 1111001, g2 = 1011011.
CCSDS_27 = ConvCode(polys=((1, 1, 1, 1, 0, 0, 1), (1, 0, 1, 1, 0, 1, 1)))
