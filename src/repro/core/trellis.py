"""Trellis description of an (R, 1, K) convolutional code.

Implements the paper's state/butterfly formalism (§II, §III-B):

* state ``d = (D_{v-1} ... D_0)_2`` with ``v = K-1`` memory cells; ``D_{v-1}``
  is the most recently shifted-in bit, ``D_0`` the oldest.
* encoder output for input bit ``x`` at state ``S_d`` (eq. 2)::

      c^{(r)} = (x · g^{(r)}_{K-1}) ⊕ (D_{K-2} · g^{(r)}_{K-2}) ⊕ ... ⊕ (D_0 · g^{(r)}_0)

* transition: ``next = (x << (v-1)) | (d >> 1)``.
* butterfly ``j`` (``j = 0 .. N/2-1``): source states ``2j, 2j+1``; target
  ``j`` for input 0 and ``j + N/2`` for input 1. Butterfly outputs (eqs. 3-6)::

      α = c(S_{2j}, 0)      β = α ⊕ g_{K-1}      γ = α ⊕ g_0      θ = α ⊕ g_{K-1} ⊕ g_0

  (XORs applied per filter r; as R-bit integers the masks are ``x_mask``
  = bits ``g^{(r)}_{K-1}`` and ``l_mask`` = bits ``g^{(r)}_0``.)

The group classification (§III-B / Table II) groups butterflies by ``α``:
at most ``2^R`` groups, hence only ``2^R`` distinct branch metrics per stage.
"""

from __future__ import annotations

import dataclasses
from functools import cached_property, lru_cache
from typing import Sequence, Tuple

import numpy as np

__all__ = ["ConvCode", "CCSDS_27", "MATRIX_MAX_LABEL_BITS", "parity"]

# Cap on k·R for the k-stage (min,+) matrix ACS: the folded combined-metric
# table has 2^(kR-1) rows, each a static add/sub chain over k·R symbol rows,
# and the kernels keep the whole table resident (as matmul operand columns or
# unrolled register rows). 8 label bits → at most 128 folded metrics, the
# same ceiling as one MXU/VPU lane tile.
MATRIX_MAX_LABEL_BITS = 8


def parity(x: np.ndarray | int) -> np.ndarray | int:
    """Bitwise parity (popcount mod 2) of non-negative ints, vectorized."""
    x = np.asarray(x)
    out = np.zeros_like(x)
    while np.any(x):
        out ^= x & 1
        x = x >> 1
    return out


@dataclasses.dataclass(frozen=True)
class ConvCode:
    """An (R, 1, K) convolutional code described by generator polynomials.

    ``polys[r]`` is the r-th generator polynomial as a bit sequence
    ``[g_{K-1}, g_{K-2}, ..., g_0]`` (paper order — MSB = input tap).
    """

    polys: Tuple[Tuple[int, ...], ...]

    def __post_init__(self):
        ks = {len(p) for p in self.polys}
        if len(ks) != 1:
            raise ValueError(f"all generator polynomials must share K, got {ks}")
        if not all(b in (0, 1) for p in self.polys for b in p):
            raise ValueError("generator polynomials must be binary")

    # ---- scalar shape parameters -------------------------------------------------
    @property
    def R(self) -> int:
        return len(self.polys)

    @property
    def K(self) -> int:
        return len(self.polys[0])

    @property
    def v(self) -> int:  # number of memory cells
        return self.K - 1

    @property
    def n_states(self) -> int:
        return 1 << self.v

    @property
    def n_butterflies(self) -> int:
        return self.n_states // 2

    @property
    def rate(self) -> float:
        return 1.0 / self.R

    # ---- integer-mask views of the polynomials -----------------------------------
    @cached_property
    def poly_ints(self) -> np.ndarray:
        """polys as integers with bit i = g^{(r)}_i (so bit K-1 = input tap)."""
        out = []
        for p in self.polys:
            val = 0
            for i, bit in enumerate(p):  # p[0] = g_{K-1}
                val |= bit << (self.K - 1 - i)
            out.append(val)
        return np.array(out, dtype=np.int64)

    @cached_property
    def x_mask(self) -> int:
        """R-bit integer whose bit r (MSB-first) = g^{(r)}_{K-1} (input tap)."""
        m = 0
        for r in range(self.R):
            m = (m << 1) | ((self.poly_ints[r] >> (self.K - 1)) & 1)
        return int(m)

    @cached_property
    def l_mask(self) -> int:
        """R-bit integer whose bit r (MSB-first) = g^{(r)}_0 (oldest-bit tap)."""
        m = 0
        for r in range(self.R):
            m = (m << 1) | (self.poly_ints[r] & 1)
        return int(m)

    # ---- encoder output tables ----------------------------------------------------
    def output_bits(self, state: np.ndarray | int, x: np.ndarray | int) -> np.ndarray:
        """Per-filter output bits c^{(r)}(S_state, x): shape (..., R)."""
        state = np.asarray(state, dtype=np.int64)
        x = np.asarray(x, dtype=np.int64)
        lows = self.poly_ints & ((1 << self.v) - 1)  # memory taps
        tap_x = (self.poly_ints >> (self.K - 1)) & 1  # input tap
        mem = parity(state[..., None] & lows)  # (..., R)
        return (mem ^ (x[..., None] * tap_x)).astype(np.int64)

    def output_int(self, state: np.ndarray | int, x: np.ndarray | int) -> np.ndarray:
        """Encoder output as an R-bit integer, c^{(1)} in the MSB (paper order)."""
        bits = self.output_bits(state, x)
        val = np.zeros(bits.shape[:-1], dtype=np.int64)
        for r in range(self.R):
            val = (val << 1) | bits[..., r]
        return val

    # ---- butterfly group classification (§III-B) ----------------------------------
    @cached_property
    def alpha(self) -> np.ndarray:
        """α for each butterfly j: output int of source state 2j with input 0."""
        j = np.arange(self.n_butterflies)
        return self.output_int(2 * j, 0)

    @cached_property
    def butterfly_codewords(self) -> np.ndarray:
        """(n_butterflies, 4) int codewords [α, β, γ, θ] per butterfly."""
        a = self.alpha
        return np.stack(
            [a, a ^ self.x_mask, a ^ self.l_mask, a ^ self.x_mask ^ self.l_mask],
            axis=1,
        )

    @cached_property
    def n_groups(self) -> int:
        return len(np.unique(self.alpha))

    @cached_property
    def groups(self) -> list[dict]:
        """Paper Table II: one entry per distinct α, with member source states.

        Each dict has keys ``alpha, beta, gamma, theta`` (R-bit ints) and
        ``states`` (sorted source-state indices 2j, 2j+1 of member butterflies).
        """
        out = []
        for a in sorted(np.unique(self.alpha)):
            js = np.nonzero(self.alpha == a)[0]
            states = sorted(np.concatenate([2 * js, 2 * js + 1]).tolist())
            out.append(
                dict(
                    alpha=int(a),
                    beta=int(a ^ self.x_mask),
                    gamma=int(a ^ self.l_mask),
                    theta=int(a ^ self.x_mask ^ self.l_mask),
                    states=states,
                )
            )
        return out

    # ---- ACS constant tables (consumed by kernels/ref) ----------------------------
    @cached_property
    def acs_tables(self) -> dict:
        """Static per-butterfly codeword indices for the vectorized ACS update.

        For target state j (top half):    predecessors 2j (codeword α_j)
                                          and 2j+1 (codeword γ_j).
        For target state j+N/2 (bottom):  predecessors 2j (codeword β_j)
                                          and 2j+1 (codeword θ_j).

        Returns int32 arrays of shape (n_butterflies,):
          ``cw_top_even, cw_top_odd, cw_bot_even, cw_bot_odd``
        plus ``onehot_{...}`` float32 one-hot matrices (n_butterflies, 2^R)
        used by the Pallas kernel to expand the 2^R-entry BM table with a
        static matmul (the TPU-native form of the paper's group lookup).
        """
        cw = self.butterfly_codewords
        tabs = dict(
            cw_top_even=cw[:, 0].astype(np.int32),  # α
            cw_bot_even=cw[:, 1].astype(np.int32),  # β
            cw_top_odd=cw[:, 2].astype(np.int32),  # γ
            cw_bot_odd=cw[:, 3].astype(np.int32),  # θ
        )
        n_cw = 1 << self.R
        for key in list(tabs):
            idx = tabs[key]
            oh = np.zeros((self.n_butterflies, n_cw), dtype=np.float32)
            oh[np.arange(self.n_butterflies), idx] = 1.0
            tabs["onehot_" + key[3:]] = oh
        return tabs

    # ---- codeword ±1 sign table (for correlation branch metrics) ------------------
    @cached_property
    def codeword_signs(self) -> np.ndarray:
        """(2^R, R) float32: row c = (2·bits(c) - 1), c^{(1)} at column 0.

        Branch metric (to MINIMIZE) for received soft symbols y (BPSK map
        bit b → 1-2b, i.e. 0 → +1): BM(c) = Σ_r y_r · (2 c_r - 1).
        """
        n_cw = 1 << self.R
        rows = []
        for c in range(n_cw):
            bits = [(c >> (self.R - 1 - r)) & 1 for r in range(self.R)]
            rows.append([2.0 * b - 1.0 for b in bits])
        return np.array(rows, dtype=np.float32)

    # ---- symmetry-folded branch metrics (antipodal label structure) ----------------
    # The correlation metric is antipodal in the label: complementing every
    # output bit flips every sign row entry, so BM(~c) = -BM(c). The 2^R
    # labels therefore pair into 2^(R-1) ± pairs and only 2^(R-1) distinct
    # branch metrics exist per stage — half the paper's 2^R group metrics.
    # The canonical representative of a pair is the label whose MSB (stream
    # c^{(1)}) is 0, i.e. c < 2^(R-1); the other member is its complement.
    @property
    def n_folded(self) -> int:
        """Distinct folded branch metrics per stage: 2^(R-1)."""
        return 1 << (self.R - 1)

    @cached_property
    def fold_index(self) -> np.ndarray:
        """(2^R,) int32: folded-table row of each label (its ± representative)."""
        c = np.arange(1 << self.R)
        mask = (1 << self.R) - 1
        return np.where(c < self.n_folded, c, c ^ mask).astype(np.int32)

    @cached_property
    def fold_sign(self) -> np.ndarray:
        """(2^R,) int32 ±1: BM(c) = fold_sign[c] · BM_folded[fold_index[c]]."""
        c = np.arange(1 << self.R)
        return np.where(c < self.n_folded, 1, -1).astype(np.int32)

    @cached_property
    def folded_codeword_signs(self) -> np.ndarray:
        """(2^(R-1), R) float32 sign rows of the fold representatives.

        ``BM_folded = folded_codeword_signs @ y`` is the folded table;
        expansion to the full 2^R table is ``fold_sign · BM_folded[fold_index]``
        (exact in both IEEE float — negation and round-to-nearest are
        sign-symmetric — and integer arithmetic).
        """
        return self.codeword_signs[: self.n_folded]

    # ---- collapsed two-stage (radix-4) trellis tables ------------------------------
    # Two consecutive trellis stages collapse into one radix-4 step: every
    # state n at time t+2 has the FOUR predecessors ``4·(n mod N/4) + j``
    # (j = 2·b_m + b_p) at time t, reached through the intermediate state
    # ``m = 2·(n mod N/2) + b_m`` at time t+1. The combined 2-symbol branch
    # label is the 2R-bit concatenation ``cc = (c1 << R) | c2`` of the two
    # stage labels, and the correlation metric stays antipodal in cc
    # (BM2(~cc) = −BM2(cc)), so only 2^(2R−1) distinct combined metrics
    # exist per fused step — the PR 3 fold composed over the stage pair.
    #
    # Target states group by ``k = n >> (v-2)`` (the two MSBs of n): group k
    # covers targets ``n = k·N/4 + q``; its stage-(t+1) input bit is
    # ``x2 = k >> 1`` and its stage-t input bit is ``x1 = k & 1`` (the two
    # decoded bits the fused step emits). Groups k and k+2 share their
    # stage-t sub-problem (same x1, same intermediates), which is what lets
    # the kernels run the 4-way compare-select as a tournament whose first
    # round is computed once per x1.
    @property
    def n_folded4(self) -> int:
        """Distinct folded combined (2-stage) branch metrics: 2^(2R-1)."""
        return 1 << (2 * self.R - 1)

    @cached_property
    def fold_index4(self) -> np.ndarray:
        """(2^(2R),) int32: folded-table row of each combined label."""
        cc = np.arange(1 << (2 * self.R))
        mask = (1 << (2 * self.R)) - 1
        return np.where(cc < self.n_folded4, cc, cc ^ mask).astype(np.int32)

    @cached_property
    def fold_sign4(self) -> np.ndarray:
        """(2^(2R),) int32 ±1: BM2(cc) = fold_sign4[cc] · BM2_folded[fold_index4[cc]]."""
        cc = np.arange(1 << (2 * self.R))
        return np.where(cc < self.n_folded4, 1, -1).astype(np.int32)

    @cached_property
    def folded_radix4_codeword_signs(self) -> np.ndarray:
        """(2^(2R-1), 2R) float32 sign rows of the combined-label fold reps.

        Row cc = signs of the 2R bits of cc, stage-t label first:
        ``BM2_folded = folded_radix4_codeword_signs @ [y_t; y_{t+1}]``. Every
        representative has MSB 0 (stage-t label < 2^(R-1)), so each row is
        ``[+folded stage row i | ± full stage row j]`` — 2^(R-1)·2^(R-1)·2
        = 2^(2R-1) distinct static add/sub chains.
        """
        n = self.n_folded4
        R2 = 2 * self.R
        rows = []
        for cc in range(n):
            bits = [(cc >> (R2 - 1 - r)) & 1 for r in range(R2)]
            rows.append([2.0 * b - 1.0 for b in bits])
        return np.array(rows, dtype=np.float32)

    @cached_property
    def radix4_preds(self) -> np.ndarray:
        """(N, 4) int32: the four predecessors of each state two stages back,
        ordered by j = 2·b_m + b_p (b_m = stage-(t+1) survivor bit, b_p =
        stage-t survivor bit)."""
        if self.v < 2:
            raise ValueError(f"radix-4 tables need K >= 3 (got K={self.K})")
        n = np.arange(self.n_states)
        quarter = self.n_states // 4
        return (4 * (n[:, None] % quarter) + np.arange(4)[None, :]).astype(np.int32)

    @cached_property
    def radix4_acs_tables(self) -> dict:
        """Static per-quad label/fold tables for the radix-4 ACS kernels.

        A radix-4 "quad" q ∈ [0, N/4) is the complete bipartite unit of 4
        source states {4q+j} and 4 target states {k·N/4 + q}. Arrays (all
        int32, last axis length N/4):

          ``c1[x1, j]``  stage-t label of pred j under stage-t input x1
                         (shared by target groups k and k+2, x1 = k & 1)
          ``c2[k, bm]``  stage-(t+1) label of intermediate b_m for group k
          ``cc[k, j]``   combined 2R-bit label (c1 << R) | c2
          ``fold_c1_idx/sgn``, ``fold_c2_idx/sgn``: the per-stage fold
                         (2^(R-1) rows) of c1/c2 — the f32 staged path
          ``fold_cc_idx/sgn``: the combined fold (2^(2R-1) rows) of cc —
                         the exact integer path
        """
        if self.v < 2:
            raise ValueError(f"radix-4 tables need K >= 3 (got K={self.K})")
        N = self.n_states
        Q = N // 4
        half = N // 2
        q = np.arange(Q)
        c1 = np.zeros((2, 4, Q), dtype=np.int64)
        c2 = np.zeros((4, 2, Q), dtype=np.int64)
        cc = np.zeros((4, 4, Q), dtype=np.int64)
        for k in range(4):
            x1, x2 = k & 1, k >> 1
            n = k * Q + q
            for bm in (0, 1):
                m = 2 * (n % half) + bm
                c2[k, bm] = self.output_int(m, x2)
                for bp in (0, 1):
                    j = 2 * bm + bp
                    p = 4 * q + j
                    c1[x1, j] = self.output_int(p, x1)
                    cc[k, j] = (c1[x1, j] << self.R) | c2[k, bm]
        return dict(
            c1=c1.astype(np.int32),
            c2=c2.astype(np.int32),
            cc=cc.astype(np.int32),
            fold_c1_idx=self.fold_index[c1].astype(np.int32),
            fold_c1_sgn=self.fold_sign[c1].astype(np.int32),
            fold_c2_idx=self.fold_index[c2].astype(np.int32),
            fold_c2_sgn=self.fold_sign[c2].astype(np.int32),
            fold_cc_idx=self.fold_index4[cc].astype(np.int32),
            fold_cc_sgn=self.fold_sign4[cc].astype(np.int32),
        )

    @cached_property
    def folded_acs_tables(self) -> dict:
        """Static per-butterfly folded lookups for the ACS kernels.

        For each of the four butterfly codeword rows (α top/even, γ top/odd,
        β bottom/even, θ bottom/odd — the order the kernels consume):
          ``fold_cw_*``:  (n_butterflies,) int32 folded-table row indices
          ``fold_sgn_*``: (n_butterflies,) int32 ±1 signs
        so each per-butterfly metric row is a sign-flip of one of the
        2^(R-1) folded entries — the signs are static and applied in-register.
        """
        cw = self.butterfly_codewords  # (nb, 4) as [α, β, γ, θ]
        order = dict(te=0, to=2, be=1, bo=3)  # kernel row order α, γ, β, θ
        out = {}
        for key, col in order.items():
            labels = cw[:, col]
            out["fold_cw_" + key] = self.fold_index[labels].astype(np.int32)
            out["fold_sgn_" + key] = self.fold_sign[labels].astype(np.int32)
        return out

    # ---- k-stage (min,+) matrix trellis tables -------------------------------------
    # k consecutive trellis stages collapse into ONE transition of the
    # (min,+) semiring: new_pm[n'] = min_n (A[n', n] + pm[n]) with
    # A[n', n] = Σ_i BM_i over the unique k-stage path n → n' (+∞ when no
    # path exists). Every target n' has exactly 2^k predecessors
    # ``pred(n', j) = 2^k·u + j`` where ``u = n' mod N/2^k`` and j's bit i
    # is the survivor bit of stage t+i; the k input bits are the top k bits
    # of n', ``c = n' >> (v-k)``, with bit i of c = the stage-(t+i) input.
    # The intermediate state after i stages is
    #     s_i = ((c mod 2^i)·U + u) · 2^(k-i) + (j >> i),   U = N / 2^k,
    # and the combined label is the k·R-bit concatenation of the per-stage
    # labels, stage t in the MSBs. The correlation metric stays antipodal in
    # the combined label (complementing all k·R bits flips every sign), so
    # only 2^(kR-1) distinct combined metrics exist per collapsed step —
    # the PR 3 fold composed over the whole k-stage window. k=2 reproduces
    # ``radix4_acs_tables`` exactly (c ↔ target group, u ↔ quad).
    def validate_matrix_k(self, k: int) -> None:
        """Raise ValueError unless k is a usable matrix-ACS fusion depth."""
        if not isinstance(k, int) or k < 1:
            raise ValueError(f"acs_k must be a positive int, got {k!r}")
        if k > self.v:
            raise ValueError(
                f"acs_k={k} exceeds the trellis memory v={self.v} (K={self.K}); "
                f"a k-stage transition matrix needs 2^k <= N={self.n_states} "
                f"predecessors per state"
            )
        if k * self.R > MATRIX_MAX_LABEL_BITS:
            raise ValueError(
                f"acs_k={k} needs 2^(kR-1)={1 << (k * self.R - 1)} folded "
                f"combined metrics (k*R = {k * self.R} label bits > "
                f"{MATRIX_MAX_LABEL_BITS}); reduce acs_k"
            )

    def n_folded_matrix(self, k: int) -> int:
        """Distinct folded combined (k-stage) branch metrics: 2^(kR-1)."""
        return 1 << (k * self.R - 1)

    @lru_cache(maxsize=None)
    def fold_index_matrix(self, k: int) -> np.ndarray:
        """(2^(kR),) int32: folded-table row of each combined k-stage label."""
        cc = np.arange(1 << (k * self.R))
        mask = (1 << (k * self.R)) - 1
        return np.where(cc < self.n_folded_matrix(k), cc, cc ^ mask).astype(np.int32)

    @lru_cache(maxsize=None)
    def fold_sign_matrix(self, k: int) -> np.ndarray:
        """(2^(kR),) int32 ±1: BMk(cc) = sign[cc] · BMk_folded[index[cc]]."""
        cc = np.arange(1 << (k * self.R))
        return np.where(cc < self.n_folded_matrix(k), 1, -1).astype(np.int32)

    @lru_cache(maxsize=None)
    def folded_matrix_codeword_signs(self, k: int) -> np.ndarray:
        """(2^(kR-1), kR) float32 sign rows of the combined-label fold reps.

        ``BMk_folded = folded_matrix_codeword_signs @ [y_t; ...; y_{t+k-1}]``
        — every representative has MSB 0, so each row is a static add/sub
        chain over the k·R stacked symbol streams (stage t first).
        """
        nb = k * self.R
        rows = []
        for cc in range(self.n_folded_matrix(k)):
            bits = [(cc >> (nb - 1 - r)) & 1 for r in range(nb)]
            rows.append([2.0 * b - 1.0 for b in bits])
        return np.array(rows, dtype=np.float32)

    @lru_cache(maxsize=None)
    def matrix_acs_tables(self, k: int) -> dict:
        """Static label/fold tables of the k-stage (min,+) transition matrix.

        Arrays of shape (2^k, 2^k, U) with U = N/2^k, indexed [c, j, u]
        (c = target input-bit group = n' >> (v-k), j = predecessor survivor
        bits, u = n' mod U):

          ``cc``        combined k·R-bit label of the path
                        pred(n', j) = 2^k·u + j  →  n' = c·U + u
          ``fold_idx``  folded-table row of cc (2^(kR-1) rows)
          ``fold_sgn``  ±1 expansion sign of cc

        The finite entries of A are exactly ``BMk(cc[c, j, u])`` at
        A[c·U + u, 2^k·u + j]; everything else is +∞ (never materialized —
        the kernels contract only over the 2^k real predecessors).
        """
        self.validate_matrix_k(k)
        U = self.n_states >> k
        u = np.arange(U)
        nk = 1 << k
        cc = np.zeros((nk, nk, U), dtype=np.int64)
        for c in range(nk):
            for j in range(nk):
                lab = np.zeros(U, dtype=np.int64)
                for i in range(k):
                    s_i = ((c & ((1 << i) - 1)) * U + u) * (1 << (k - i)) + (j >> i)
                    lab = (lab << self.R) | self.output_int(s_i, (c >> i) & 1)
                cc[c, j] = lab
        return dict(
            cc=cc.astype(np.int32),
            fold_idx=self.fold_index_matrix(k)[cc].astype(np.int32),
            fold_sgn=self.fold_sign_matrix(k)[cc].astype(np.int32),
        )

    @lru_cache(maxsize=None)
    def matrix_expansion(self, k: int) -> np.ndarray:
        """(2^k·N, 2^(kR-1)) float32 signed one-hot expansion matrix E.

        Row (c, j, u) — flattened in that order — holds a single ±1 at the
        fold row of ``cc[c, j, u]``, so ``E @ BMk_folded`` assembles every
        finite entry of the k-stage transition matrix as ONE dense matmul
        (MXU-shaped: 2^(kR-1) ≤ 128 contraction columns). Exact in float:
        one nonzero per row means no accumulation, and |BMk| ≤ k·R·q_max is
        far inside f32's integer range.
        """
        t = self.matrix_acs_tables(k)
        idx = t["fold_idx"].reshape(-1)
        sgn = t["fold_sgn"].reshape(-1)
        E = np.zeros((idx.size, self.n_folded_matrix(k)), dtype=np.float32)
        E[np.arange(idx.size), idx] = sgn
        return E


# The paper's reference code: CCSDS (2,1,7), g1 = 1111001, g2 = 1011011.
CCSDS_27 = ConvCode(polys=((1, 1, 1, 1, 0, 0, 1), (1, 0, 1, 1, 0, 1, 1)))
