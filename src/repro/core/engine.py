"""The unified decode path: ``DecoderEngine`` + stateful streaming sessions.

One engine method covers what used to be three copy-pasted pipelines
(``decode_stream``, ``decode_stream_sharded`` and the per-backend branches in
``kernels/ops.py``):

* **codes** come from a :class:`~repro.core.codespec.CodeSpec` (mother code +
  optional puncturing) — punctured streams are depunctured with BM-neutral
  zeros and flow through the unchanged framing/kernels;
* **backends** are looked up in the kernel registry
  (:mod:`repro.kernels.registry`) — ``ref``/``pallas``/``fused`` all receive
  the same ``FramedBlocks`` contract;
* **sharding** is a constructor argument (``mesh`` + ``block_axes``), not a
  separate function: the parallel-block axis is sharded across the mesh with
  zero cross-device communication (the PBVD property that makes the decoder
  scale linearly in chips);
* **streaming** is :meth:`DecoderEngine.session`: a session carries the
  inter-block overlap tail (up to ``D + L`` received stages, ``2L`` of which
  overlap the neighbouring blocks) across successive ``decode()`` calls so an
  unbounded stream decodes chunk-by-chunk, bit-exact to the one-shot decode;
* **batching across streams** is :meth:`DecoderEngine.decode_batch`: the
  framed blocks of many independent streams are concatenated along the lane
  axis (a flattened frames × blocks packing, ``FramedBlocks.frame_counts``)
  and decoded in ONE kernel launch — blocks are mutually independent, so the
  per-frame bits are bit-identical to sequential ``decode()`` calls while
  short frames stop wasting the 128-lane tile.

See DESIGN.md §1/§3 for the architecture and the streaming invariants.
"""

from __future__ import annotations

import math

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.ops import check_mesh_launch, pbvd_decode_blocks
from repro.launch.faults import SymbolError, check_finite_symbols
from .codespec import CodeSpec

__all__ = ["ArraySessionStore", "DecoderEngine", "DecoderSession"]


class ArraySessionStore:
    """Default storage for a session's buffered soft symbols: one contiguous
    per-session ndarray.

    A *session store* is the seam that lets a serving layer swap the
    per-session Python buffer for shared, slab-allocated pages
    (:class:`repro.launch.slab.PagedSessionStore`) without the session
    noticing — :class:`DecoderSession` only ever touches its buffer through
    this interface. The contract (all stage indices are LOCAL, i.e. relative
    to the store's first held stage):

    * ``len(store)`` — stages currently held;
    * ``append(rows)`` — append ``(n, R)`` float-convertible symbol rows;
    * ``grow(n)`` — append ``n`` all-zero stages (punctured ingest scatters
      into them afterwards);
    * ``scatter(stage_idx, sym_idx, values)`` — elementwise write;
    * ``read(lo, n)`` — up to ``n`` rows from ``lo`` (short at the tail,
      never padded: framing owns the zero-padding);
    * ``drop_prefix(n)`` — discard the first ``n`` stages (committed blocks);
    * ``close()`` — release backing storage (idempotent);
    * ``snapshot()`` — a picklable dict of the held rows (logical content
      only — paged stores do NOT record page ids, so a snapshot restores
      into any store, slab-backed or not);
    * ``restore(snap)`` — load a snapshot into an EMPTY store.

    ``snapshot``/``restore`` are the durability seam (DESIGN.md §15): the
    serving layer's checkpoint writer snapshots every live session and the
    crash-recovery path restores them into freshly allocated stores.
    """

    def __init__(self, R: int):
        self._a = np.zeros((0, R), np.float32)

    def __len__(self) -> int:
        return len(self._a)

    def append(self, rows: np.ndarray) -> None:
        self._a = np.concatenate([self._a, rows.astype(np.float32)])

    def grow(self, n: int) -> None:
        if n > 0:
            self._a = np.concatenate(
                [self._a, np.zeros((n, self._a.shape[1]), np.float32)]
            )

    def scatter(self, stage_idx, sym_idx, values) -> None:
        self._a[stage_idx, sym_idx] = values

    def read(self, lo: int, n: int) -> np.ndarray:
        return self._a[lo : lo + n]

    def drop_prefix(self, n: int) -> None:
        if n > 0:
            self._a = self._a[n:]

    def close(self) -> None:
        self._a = np.zeros((0, self._a.shape[1]), np.float32)

    def snapshot(self) -> dict:
        return {"rows": self._a.copy()}

    def restore(self, snap: dict) -> None:
        if len(self._a):
            raise ValueError("restore() target store is not empty")
        self._a = np.asarray(snap["rows"], np.float32).copy()


def _pow2_at_least(n: int) -> int:
    """Smallest power of two ≥ n (the shared jit shape budget)."""
    return 1 << max(0, n - 1).bit_length()


class DecoderEngine:
    """Single entry point for PBVD decoding.

    Parameters
    ----------
    cfg: PBVDConfig — decode geometry (D, L), quantization, backend, code/spec.
    mesh: optional ``jax.sharding.Mesh``; when given, the parallel-block axis
        of every decode is sharded over ``block_axes`` (e.g. ``("pod","data")``
        on the production mesh). Blocks never interact, so the sharded launch
        is collective-free — fleet throughput is N chips of lane throughput.
    block_axes: mesh axes carrying the lane (flattened frames × blocks) axis.
        ``None`` resolves the ``"blocks"`` logical-axis rule of
        :mod:`repro.sharding.rules` against the mesh (``("pod", "data")``
        on a multi-pod mesh, ``("data",)`` otherwise).
    shard_dispatch: how a mesh-bound launch is driven —
        ``"constraint"`` (default) places the packed lanes with a
        ``NamedSharding`` and lets pjit partition the launch;
        ``"shard_map"`` wraps it in :func:`repro.sharding.smap.shard_map`,
        each shard decoding its local lanes explicitly. Both are bit-exact
        to the unsharded decode; validated eagerly at construction
        (:func:`repro.kernels.ops.check_mesh_launch`).
    """

    def __init__(
        self,
        cfg=None,
        *,
        mesh=None,
        block_axes: tuple[str, ...] | None = ("data",),
        shard_dispatch: str = "constraint",
    ):
        from .pbvd import PBVDConfig  # local import: pbvd re-exports the engine

        self.cfg = cfg if cfg is not None else PBVDConfig()
        self.spec: CodeSpec = self.cfg.codespec
        self.mesh = mesh
        if block_axes is None:
            if mesh is None:
                block_axes = ("data",)
            else:
                from repro.sharding.rules import block_mesh_axes

                block_axes = block_mesh_axes(mesh)
        self.block_axes = tuple(block_axes)
        self.shard_dispatch = shard_dispatch
        # eager: a bad mesh binding fails when the engine is BUILT, with a
        # clear error naming the axis/backend — never inside a pooled launch
        self.n_shards = (
            check_mesh_launch(mesh, self.block_axes, self.cfg.backend, dispatch=shard_dispatch)
            if mesh is not None
            else 1
        )

    # ------------------------------------------------------------------ one-shot
    def decode(self, y, n_bits: int | None = None, *, interpret: bool | None = None):
        """Decode a soft-symbol stream → (n_bits,) int32 bits.

        ``y`` is either a (n_stages, R) full-rate stream or, for a punctured
        spec, a 1-D stream of received (punctured) symbols, which is
        depunctured with BM-neutral zeros first. ``n_bits`` defaults to the
        number of full-rate stages in the stream.
        """
        blocks, n_blocks, n_bits = self._frame_one(y, n_bits)
        if self.mesh is not None:
            # mesh launches round lanes to the shard-aware budget once, here;
            # pad lanes are zero-symbol blocks beyond frame_counts, trimmed
            blocks = self._pad_lanes(blocks)
        bits = self._decode_blocks(blocks, (n_blocks,), interpret)  # (D, n_blocks)
        return jnp.transpose(bits).reshape(-1)[:n_bits]

    # ------------------------------------------------------------------ batched
    def decode_batch(
        self,
        ys,
        n_bits_list=None,
        *,
        interpret: bool | None = None,
    ) -> list:
        """Decode many independent streams in ONE kernel launch.

        ``ys`` is a sequence of streams, each in any form :meth:`decode`
        accepts; ``n_bits_list`` gives each stream's payload length (or
        ``None`` entries / ``None`` for the stage-count default). Every
        stream is framed exactly like :meth:`decode`, the per-frame block
        axes are concatenated into one flattened frames × blocks lane axis
        (padded to the shared power-of-two shape budget so recurring batch
        geometries reuse jit shapes), and the single launch's output is
        unpacked and trimmed per frame.

        Returns a list of (n_bits_i,) int32 arrays, bit-identical per frame
        to sequential ``decode()`` calls — parallel blocks never interact,
        and pad lanes are zero-symbol blocks the backends trim.
        """
        ys = list(ys)
        if not ys:
            return []
        if n_bits_list is None:
            n_bits_list = [None] * len(ys)
        if len(n_bits_list) != len(ys):
            raise ValueError(
                f"n_bits_list has {len(n_bits_list)} entries for {len(ys)} streams"
            )
        uniform = self._frame_uniform(ys, n_bits_list)
        if uniform is not None:
            packed, frame_counts, bit_counts = uniform
        else:
            framed = [self._frame_one(y, nb) for y, nb in zip(ys, n_bits_list)]
            frame_counts = tuple(k for _, k, _ in framed)
            bit_counts = tuple(nb for _, _, nb in framed)
            packed = jnp.concatenate([b for b, _, _ in framed], axis=2)
        packed = self._pad_lanes(packed)
        bits = self._decode_blocks(packed, frame_counts, interpret)  # (D, total)
        if uniform is not None:  # equal frames: one reshape, not S slices
            S, k, n_bits = len(ys), frame_counts[0], bit_counts[0]
            rows = jnp.transpose(bits.reshape(-1, S, k), (1, 2, 0))
            return list(rows.reshape(S, -1)[:, :n_bits])
        out, lo = [], 0
        for k, n_bits in zip(frame_counts, bit_counts):
            out.append(jnp.transpose(bits[:, lo : lo + k]).reshape(-1)[:n_bits])
            lo += k
        return out

    # ------------------------------------------------------------------ streaming
    def session(
        self, *, interpret: bool | None = None, store=None
    ) -> "DecoderSession":
        """Open a stateful streaming session (see :class:`DecoderSession`).

        ``store`` swaps the session's symbol buffer for an alternative
        :class:`ArraySessionStore`-shaped backend — e.g. a paged slab view
        (:class:`repro.launch.slab.PagedSessionStore`) so millions of
        short-lived streams share one allocation instead of churning
        per-session ndarrays.
        """
        return DecoderSession(self, interpret=interpret, store=store)

    # ------------------------------------------------------------------ internals
    def _lane_budget(self, n: int) -> int:
        """Shared jit lane-shape budget for ``n`` real lanes.

        The power-of-two budget rounded ONCE to the shard count —
        ``lcm(pow2_at_least(n), n_shards)`` — so a mesh-bound launch is both
        evenly shardable over ``block_axes`` and drawn from the same bounded
        shape set as the unsharded path (a post-hoc "pad to a multiple of
        n_shards" after the pow2 pad would mint a fresh shape per fleet size
        for any non-power-of-two shard count and recompile unboundedly under
        streaming). Without a mesh this IS ``_pow2_at_least``.
        """
        budget = _pow2_at_least(n)
        s = self.n_shards
        return budget * s // math.gcd(budget, s)

    def _pad_lanes(self, blocks):
        """Pad the lane axis to :meth:`_lane_budget` with zero-symbol blocks."""
        total = blocks.shape[2]
        budget = self._lane_budget(total)
        if budget > total:
            blocks = jnp.pad(blocks, ((0, 0), (0, 0), (0, budget - total)))
        return blocks

    def _frame_one(self, y, n_bits: int | None):
        """Depuncture, quantize and frame one stream → (blocks, n_blocks, n_bits)."""
        from .pbvd import frame_stream

        y = self._to_full_rate(y)
        # reject NaN/Inf before framing: a non-finite symbol would corrupt
        # the path metrics of every lane coalesced into the launch, and the
        # f32 metric path never passes through quantize_soft's own check
        check_finite_symbols(y, "DecoderEngine.decode")
        if n_bits is None:
            n_bits = int(y.shape[0])
        cfg = self.cfg
        n_blocks = -(-n_bits // cfg.D)
        if cfg.effective_q is not None and not jnp.issubdtype(y.dtype, jnp.integer):
            y = cfg.quantize(y)  # already-integer inputs are pre-quantized
        return frame_stream(y, cfg.D, cfg.L, n_blocks), n_blocks, n_bits

    def _frame_uniform(self, ys, n_bits_list):
        """Fast path for same-shape stream fleets (the serving common case).

        Stacks the streams, quantizes once, and vmaps the one-stream
        ``frame_stream`` over the fleet — the same framing code path as
        ``decode()``, but O(1) kernel dispatches instead of O(n_streams).
        Returns ``None`` when streams differ in shape/dtype/length (the
        general path handles those).
        """
        from .pbvd import frame_stream

        if len(ys) < 2:
            return None
        shapes = {tuple(np.shape(y)) for y in ys}
        dtypes = {np.dtype(getattr(y, "dtype", np.float64)) for y in ys}
        if len(shapes) != 1 or len(dtypes) != 1 or len(set(n_bits_list)) != 1:
            return None
        for i, y in enumerate(ys):
            check_finite_symbols(y, f"DecoderEngine.decode_batch (stream {i})")
        y0 = jnp.stack([self._to_full_rate(jnp.asarray(y)) for y in ys])  # (S, n, R)
        S, n_sym, R = y0.shape
        n_bits = n_bits_list[0] if n_bits_list[0] is not None else n_sym
        cfg = self.cfg
        k = -(-n_bits // cfg.D)
        if cfg.effective_q is not None and not jnp.issubdtype(y0.dtype, jnp.integer):
            y0 = cfg.quantize(y0)
        blocks = jax.vmap(
            lambda s: frame_stream(s, cfg.D, cfg.L, k)
        )(y0)  # (S, T, R, k)
        T = cfg.D + 2 * cfg.L
        packed = jnp.transpose(blocks, (1, 2, 0, 3)).reshape(T, R, S * k)
        return packed, (k,) * S, (n_bits,) * S

    def _to_full_rate(self, y):
        if y.ndim == 1:
            if not self.spec.is_punctured:
                raise SymbolError(
                    "1-D symbol stream given but the code spec is unpunctured; "
                    "pass (n_stages, R) soft symbols"
                )
            return self.spec.depuncture_stream(jnp.asarray(y))
        if y.shape[-1] != self.spec.code.R:
            raise SymbolError(f"stream rank {y.shape[-1]} != code R {self.spec.code.R}")
        return y

    def _decode_blocks(
        self, blocks, frame_counts: tuple[int, ...], interpret: bool | None
    ):
        """(T, R, B) framed symbols → (D, sum(frame_counts)) bits.

        ``frame_counts`` is the per-frame real-block layout along the lane
        axis (one entry for plain decodes); lanes beyond the real blocks are
        padding the backend trims. With a mesh bound, the lane axis arrives
        pre-padded to :meth:`_lane_budget` (every caller rounds once, before
        launch) and is sharded over ``block_axes`` by the configured
        dispatch — collective-free either way, since blocks never interact.
        """
        cfg = self.cfg
        launch_kwargs = dict(
            decode_start=cfg.L,
            n_decode=cfg.D,
            start_policy=cfg.start_policy,
            backend=cfg.backend,
            interpret=interpret,
            metric_mode=cfg.metric_mode,
            tb_mode=cfg.tb_mode,
            tb_chunk=cfg.tb_chunk,
            acs_radix=cfg.acs_radix,
            acs_impl=cfg.acs_impl,
            acs_k=cfg.acs_k,
        )
        if self.mesh is None:
            return pbvd_decode_blocks(
                blocks, self.spec.code, frame_counts=frame_counts, **launch_kwargs
            )

        from jax.sharding import NamedSharding, PartitionSpec as P

        B = blocks.shape[2]
        if B % self.n_shards:
            # internal invariant, not a user error: decode/decode_batch/
            # sessions/SessionPool all round lanes via _lane_budget first
            raise ValueError(
                f"lane axis {B} not divisible into {self.n_shards} shards; "
                f"callers must pad to _lane_budget before launch"
            )
        if self.shard_dispatch == "shard_map":
            from repro.sharding.smap import lane_shard_map

            # each shard decodes its B/n_shards local lanes independently;
            # per-shard outputs must be uniform in shape, so the pad-lane
            # trim happens ONCE on the stitched result (frame_counts stays a
            # host-side concept — the mapped body decodes every local lane)
            code = self.spec.code

            def _local(y_local):
                return pbvd_decode_blocks(y_local, code, **launch_kwargs)

            bits = lane_shard_map(
                _local, mesh=self.mesh, axes=self.block_axes, in_rank=3, out_rank=2
            )(blocks)
            return bits[:, : sum(frame_counts)]
        # "constraint": commit the packed lanes to the mesh placement and let
        # pjit partition the launch; the backend's n_real trim runs inside jit
        blocks = jax.lax.with_sharding_constraint(
            blocks, NamedSharding(self.mesh, P(None, None, self.block_axes))
        )
        return pbvd_decode_blocks(
            blocks, self.spec.code, frame_counts=frame_counts, **launch_kwargs
        )


class DecoderSession:
    """Chunk-by-chunk decoding of an unbounded stream.

    The session buffers received symbols (depuncturing incrementally for
    punctured specs) and decodes a parallel block as soon as its full window
    ``[bD - L, bD + D + L)`` is available — exactly the window the one-shot
    framing would build, so the concatenation of all ``decode()`` outputs plus
    ``finish()`` is bit-identical to ``engine.decode`` on the whole stream.

    The carried state between calls is the overlap tail (at most ``D + L``
    stages of soft symbols), the puncture phase, and the block counter.

    Internally the launch is split into three phases so a
    :class:`~repro.launch.serve_decoder.SessionPool` can pack the ready
    blocks of many sessions into one launch: :meth:`ready_blocks` (how far
    the stream can decode), :meth:`_frame_ready` (build the framed window,
    no launch), and :meth:`_commit` (advance the block counter, trim the
    buffer). ``decode()``/``finish()`` compose them with a solo launch.
    """

    def __init__(
        self,
        engine: DecoderEngine,
        *,
        interpret: bool | None = None,
        store=None,
    ):
        self.engine = engine
        self.cfg = engine.cfg
        self.spec = engine.spec
        self._interpret = interpret
        # the buffered-symbol storage backend (see ArraySessionStore for the
        # contract); a serving layer passes a slab-paged store instead
        self._store = store if store is not None else ArraySessionStore(self.spec.code.R)
        self._base = 0  # global stage index of the store's first held stage
        self._blocks_done = 0
        self._kept_seen = 0  # punctured symbols consumed (puncture phase)
        self._int_dtype = None  # set when chunks arrive pre-quantized (integer)
        self._started = False
        self.bits_emitted = 0

    # ---- public API ----------------------------------------------------------------
    def decode(self, chunk) -> np.ndarray:
        """Feed a chunk of received symbols; return newly decodable bits.

        ``chunk`` is (n, R) full-rate soft symbols for unpunctured specs, or
        a 1-D punctured symbol stream for punctured specs (the wire format —
        full-rate chunks would desynchronize the carried puncture phase).
        Integer chunks are treated as pre-quantized (like ``engine.decode``)
        and must not be mixed with float chunks. Returns an int32 array
        (possibly empty): ``D`` bits per parallel block whose window is now
        complete.
        """
        self.ingest(chunk)
        out = self._decode_upto(self.ready_blocks())
        self.bits_emitted += len(out)
        return out

    def finish(self, n_bits: int | None = None) -> np.ndarray:
        """Flush the stream: decode the remaining blocks (zero-padded tail).

        ``n_bits`` is the total payload length of the stream (defaults to the
        number of full-rate stages received); the returned tail makes the
        session's concatenated output equal ``engine.decode(y, n_bits)``.
        """
        n_bits, n_blocks, prior = self._finish_plan(n_bits)
        out = self._decode_upto(n_blocks)
        out = out[: max(0, n_bits - prior)]
        self.bits_emitted += len(out)
        return out

    def close(self) -> None:
        """Release the session's buffered-symbol storage (idempotent).

        Required for slab-backed stores, whose pages return to the shared
        free-list here; a no-op-ish convenience for the default store.
        """
        self._store.close()

    def ingest(self, chunk) -> None:
        """Buffer a chunk without decoding (used by pooled sessions)."""
        self._ingest(np.asarray(chunk))

    def snapshot(self) -> dict:
        """Picklable session state: the buffered-symbol window plus the
        scalars that position it in the stream (overlap base, block counter,
        puncture phase, quantization dtype).  Restoring the snapshot into a
        fresh session continues the stream bit-exact — the checkpoint half
        of the serving layer's crash-recovery contract (DESIGN.md §15)."""
        return dict(
            store=self._store.snapshot(),
            base=self._base,
            blocks_done=self._blocks_done,
            kept_seen=self._kept_seen,
            int_dtype=(
                np.dtype(self._int_dtype).str if self._int_dtype is not None else None
            ),
            started=self._started,
            bits_emitted=self.bits_emitted,
        )

    def restore(self, snap: dict) -> None:
        """Load a :meth:`snapshot` into this (freshly created) session."""
        self._store.restore(snap["store"])
        self._base = int(snap["base"])
        self._blocks_done = int(snap["blocks_done"])
        self._kept_seen = int(snap["kept_seen"])
        self._int_dtype = (
            np.dtype(snap["int_dtype"]) if snap["int_dtype"] is not None else None
        )
        self._started = bool(snap["started"])
        self.bits_emitted = int(snap["bits_emitted"])

    def ready_blocks(self) -> int:
        """Highest block index b1 such that blocks [0, b1) are decodable now."""
        D, L = self.cfg.D, self.cfg.L
        return max(self._blocks_done, (self._stages_complete() - L) // D)

    # ---- internals -----------------------------------------------------------------
    def _finish_plan(self, n_bits: int | None) -> tuple[int, int, int]:
        """The flush arithmetic shared by every finish path.

        Returns ``(n_bits, n_blocks, prior)``: the resolved payload length,
        the total block count to decode, and the bits already covered by
        committed blocks. :meth:`finish` and ``PooledSession.finish`` both
        trim their flush launch with exactly this plan, which is what keeps
        the solo and pooled tails bit-identical by construction for every
        non-block-aligned ``n_bits``.
        """
        D = self.cfg.D
        if n_bits is None:
            n_bits = self._base + len(self._store)
        return n_bits, -(-n_bits // D), self._blocks_done * D

    def _stages_complete(self) -> int:
        """Stages for which every (unpunctured) symbol has been received."""
        if not self.spec.is_punctured:
            return self._base + len(self._store)
        next_slot = int(self.spec.kept_slot_indices(self._kept_seen, 1)[0])
        return next_slot // self.spec.code.R

    def _ingest(self, chunk: np.ndarray) -> None:
        R = self.spec.code.R
        if chunk.size:
            # validate BEFORE buffering: a rejected chunk must leave the
            # session state untouched so the stream (or its quarantine) never
            # sees a half-ingested chunk
            check_finite_symbols(chunk, "session send()")
            # pre-quantized (integer) streams skip the session's quantization,
            # mirroring engine.decode; mixing dtypes would corrupt the buffer
            is_int = np.issubdtype(chunk.dtype, np.integer)
            if not self._started:
                self._int_dtype = chunk.dtype if is_int else None
                self._started = True
            elif is_int != (self._int_dtype is not None):
                raise SymbolError(
                    "cannot mix integer (pre-quantized) and float chunks "
                    "within one session"
                )
        if self.spec.is_punctured:
            if chunk.ndim != 1:
                # a punctured wire format is the 1-D kept-symbol stream; a
                # full-rate chunk would desynchronize the puncture phase
                raise SymbolError(
                    f"punctured sessions take 1-D punctured symbol chunks, "
                    f"got shape {chunk.shape}"
                )
            n = len(chunk)
            if n == 0:
                return
            slots = self.spec.kept_slot_indices(self._kept_seen, n)
            need_stages = int(slots[-1]) // R + 1
            grow = need_stages - (self._base + len(self._store))
            if grow > 0:
                self._store.grow(grow)
            local = slots - self._base * R
            self._store.scatter(local // R, local % R, chunk)
            self._kept_seen += n
        elif chunk.ndim == 2 and chunk.shape[1] == R:
            self._store.append(chunk)
        else:
            raise SymbolError(
                f"chunk shape {chunk.shape} invalid for code R={R} "
                f"(punctured={self.spec.is_punctured})"
            )

    def _frame_ready(self, b1: int) -> jnp.ndarray:
        """Frame blocks [blocks_done, b1) → (T, R, b1 - blocks_done) quantized
        symbols, zero-padding the partial last block past the buffered tail.

        Does NOT advance the session (see :meth:`_commit`). Lane-axis padding
        to the jit shape budget is the caller's job (``engine._pad_lanes``) —
        solo and pooled launches share that mechanism, so pad lanes are
        identical zero-symbol blocks on both paths.
        """
        b0 = self._blocks_done
        k = b1 - b0
        cfg = self.cfg
        D, L, R = cfg.D, cfg.L, self.spec.code.R
        T = D + 2 * L
        lo = b0 * D - L  # global first stage of the combined window
        hi_pad = (b0 + k) * D + L  # exclusive global end incl. padding
        left_pad = max(0, -lo)  # only the very first block reaches stage -L
        s0 = max(lo, 0) - self._base
        need = hi_pad - max(lo, 0)
        window = self._store.read(s0, need)
        parts = []
        if left_pad:
            parts.append(np.zeros((left_pad, R), np.float32))
        parts.append(window)
        right_pad = need - len(window)
        if right_pad > 0:
            parts.append(np.zeros((right_pad, R), np.float32))
        w = np.concatenate(parts) if len(parts) > 1 else parts[0]

        if self._int_dtype is not None:  # pre-quantized stream: exact passthrough
            y = jnp.asarray(w.astype(self._int_dtype))
        else:
            y = jnp.asarray(w)
            if cfg.effective_q is not None:
                y = cfg.quantize(y)
        idx = np.arange(T)[:, None] + np.arange(k)[None, :] * D
        return jnp.transpose(y[idx], (0, 2, 1))  # (T, R, k)

    def _commit(self, b1: int) -> None:
        """Advance past blocks [blocks_done, b1); trim the consumed buffer."""
        D, L = self.cfg.D, self.cfg.L
        self._blocks_done = b1
        new_base = max(0, b1 * D - L)
        drop = new_base - self._base
        if drop > 0:
            self._store.drop_prefix(min(drop, len(self._store)))
            self._base = new_base

    def _decode_upto(self, b1: int) -> np.ndarray:
        """Decode blocks [blocks_done, b1) in one solo launch; advance."""
        b0 = self._blocks_done
        k = b1 - b0
        if k <= 0:
            return np.zeros((0,), np.int32)
        # pad the block count to the engine's lane budget (power of two,
        # rounded once to the mesh shard count) so chunked streams hit a
        # bounded set of jit shapes; pad-lane bits are trimmed by the backend.
        # _pad_lanes is the SAME mechanism the pooled launch uses, so a solo
        # flush and a pooled flush build identical launches lane for lane
        blocks = self.engine._pad_lanes(self._frame_ready(b1))
        bits = self.engine._decode_blocks(blocks, (k,), self._interpret)  # (D, k)
        out = np.asarray(jnp.transpose(bits), dtype=np.int32).reshape(-1)
        self._commit(b1)
        return out
