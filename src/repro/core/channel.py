"""BPSK modulation + AWGN channel, matching the paper's Fig. 4 setup."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["bpsk", "awgn", "ebn0_to_sigma", "transmit"]


def bpsk(bits: jnp.ndarray) -> jnp.ndarray:
    """Map bit b ∈ {0,1} → symbol s ∈ {+1,-1} (0 → +1)."""
    return 1.0 - 2.0 * bits.astype(jnp.float32)


def ebn0_to_sigma(ebn0_db: float, rate: float) -> float:
    """Noise std for unit-energy BPSK at the given Eb/N0 (dB) and code rate.

    Es/N0 = rate * Eb/N0;  sigma^2 = 1 / (2 * Es/N0).
    """
    esn0 = rate * 10.0 ** (ebn0_db / 10.0)
    return float(np.sqrt(1.0 / (2.0 * esn0)))


def awgn(key: jax.Array, symbols: jnp.ndarray, sigma: float) -> jnp.ndarray:
    return symbols + sigma * jax.random.normal(key, symbols.shape, dtype=jnp.float32)


def transmit(key: jax.Array, coded_bits: jnp.ndarray, ebn0_db: float, rate: float) -> jnp.ndarray:
    """bits (..., T, R) → noisy soft symbols (..., T, R), float32."""
    sigma = ebn0_to_sigma(ebn0_db, rate)
    return awgn(key, bpsk(coded_bits), sigma)
