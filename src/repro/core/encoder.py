"""Convolutional encoder for (R, 1, K) codes — numpy reference + JAX version.

The JAX version is used by the data pipeline / benchmarks to generate test
streams on-device; the numpy version is the oracle for tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .trellis import ConvCode

__all__ = ["encode_np", "encode_jax", "encoder_state", "terminate"]


def terminate(bits: np.ndarray, code: ConvCode) -> np.ndarray:
    """Append K-1 zero flush bits so the encoder returns to state 0."""
    return np.concatenate([np.asarray(bits, dtype=np.int64), np.zeros(code.v, dtype=np.int64)])


def encode_np(bits: np.ndarray, code: ConvCode, init_state: int = 0) -> np.ndarray:
    """Encode a bit sequence. Returns (len(bits), R) output bits.

    Stage t consumes input bit ``bits[t]`` at state ``s_t`` and emits
    ``c(s_t, bits[t])``; ``s_{t+1} = (bits[t] << (v-1)) | (s_t >> 1)``.
    """
    bits = np.asarray(bits, dtype=np.int64)
    out = np.zeros((len(bits), code.R), dtype=np.int64)
    s = int(init_state)
    for t, x in enumerate(bits):
        out[t] = code.output_bits(s, int(x))
        s = (int(x) << (code.v - 1)) | (s >> 1)
    return out


def encoder_state(bits: np.ndarray, code: ConvCode, init_state: int = 0) -> int:
    """Encoder state after consuming ``bits`` from ``init_state``.

    The shift register holds the last ``v`` input bits, so only
    ``bits[-v:]`` can influence the result — the fold is O(v) regardless of
    stream length.  This is what lets the serving layer's integrity sentinel
    re-encode any delivered block mid-stream: tracking the last ``v``
    delivered bits per stream reproduces ``encode_np``'s state at every
    block boundary.
    """
    bits = np.asarray(bits, dtype=np.int64)
    if len(bits) > code.v:
        bits = bits[-code.v :]
    s = int(init_state)
    for x in bits:
        s = (int(x) << (code.v - 1)) | (s >> 1)
    return s


def encode_jax(bits: jnp.ndarray, code: ConvCode, init_state: int = 0) -> jnp.ndarray:
    """Vectorized JAX encoder via lax.scan. bits: (..., T) int32 → (..., T, R)."""
    lows = jnp.asarray(code.poly_ints & ((1 << code.v) - 1), dtype=jnp.int32)
    tap_x = jnp.asarray((code.poly_ints >> (code.K - 1)) & 1, dtype=jnp.int32)

    def popcount_parity(x):
        # x: int32 >= 0, values < 2^v. Parity via repeated fold.
        p = x
        for shift in (16, 8, 4, 2, 1):
            p = p ^ (p >> shift)
        return p & 1

    def step(state, x):
        mem = popcount_parity(state[..., None] & lows)
        out = mem ^ (x[..., None] * tap_x)
        nxt = (x << (code.v - 1)) | (state >> 1)
        return nxt, out

    bits = bits.astype(jnp.int32)
    batch_shape = bits.shape[:-1]
    s0 = jnp.full(batch_shape, init_state, dtype=jnp.int32)
    # scan over time (last axis)
    bits_t = jnp.moveaxis(bits, -1, 0)
    _, outs = jax.lax.scan(step, s0, bits_t)
    return jnp.moveaxis(outs, 0, -2)  # (..., T, R)
