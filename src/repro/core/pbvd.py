"""Parallel Block-based Viterbi Decoder — configuration, framing and the
paper's throughput model (§III-A / eq. 7).

The stream of received soft symbols is framed into ``N_t`` parallel blocks of
decode length ``D``, each extended by ``M = L`` truncation stages on the left
and ``L`` traceback stages on the right (biting length ``2L`` between
adjacent blocks). All blocks decode independently → block-level parallelism
maps to TPU lanes (within a chip, via the Pallas kernels) × chips (via the
``(pod, data)`` mesh axes, `shard_map`/pjit — zero collectives, verified by
the dry-run).

The decode pipelines themselves live in :mod:`repro.core.engine` — a single
:class:`~repro.core.engine.DecoderEngine` parameterized by code spec, kernel
backend and sharding. ``decode_stream``/``decode_stream_sharded`` are kept as
thin wrappers over the engine for the original call sites.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Literal

import jax
import jax.numpy as jnp

from repro.kernels.traceback import DEFAULT_TB_CHUNK

from .codespec import CodeSpec
from .quantize import (
    max_symbol_bits,
    metric_dtype_max,
    norm_interval,
    quantize_soft,
    u1_bytes,
    u2_bytes,
)
from .trellis import CCSDS_27, ConvCode

__all__ = [
    "PBVDConfig",
    "frame_stream",
    "decode_stream",
    "decode_stream_sharded",
    "throughput_model",
]


@dataclasses.dataclass(frozen=True)
class PBVDConfig:
    """Decoder configuration. Paper defaults: D=512, L=42 (≈6K), M=L.

    ``spec`` selects a :class:`~repro.core.codespec.CodeSpec` (code +
    puncturing); when given it overrides ``code`` (which is kept in sync so
    ``cfg.code`` always names the mother code the kernels run).

    ``metric_mode`` selects the path-metric pipeline (the
    :data:`~repro.kernels.registry.METRIC_MODES` contract): ``"f32"`` is the
    full-precision accumulate; ``"i16"``/``"i8"`` run the narrow normalized
    pipeline — the engine quantizes symbols to the widest width whose
    saturation budget fits the metric dtype (``effective_q``), so the narrow
    paths never saturate.

    ``tb_mode`` selects the traceback algorithm (the
    :data:`~repro.kernels.registry.TB_MODES` contract): ``"serial"`` walks
    one stage per step; ``"prefix"`` composes ``tb_chunk``-stage survivor
    maps in parallel and cuts the serial chain to ceil(T/tb_chunk) steps —
    bit-exact to serial for every chunk size. The default ``"auto"``
    resolves to the backend's declared measured-fastest mode (serial on
    ``ref``, prefix on the Pallas kernels), so picking a backend no longer
    requires knowing the benchmark table.

    ``acs_radix`` selects the forward-ACS step (the
    :data:`~repro.kernels.registry.ACS_RADIX` contract): ``2`` is the
    paper's per-stage butterfly; ``4`` collapses two trellis stages into one
    stage-fused 4-way compare-select step — bit-exact decoded bits, half the
    forward serial chain, one normalization/survivor-emission round per two
    bits, and (fused backend) a double-buffered HBM→VMEM symbol pipeline.

    ``acs_impl`` selects the forward-pass formulation (the
    :data:`~repro.kernels.registry.ACS_IMPL` contract): ``"butterfly"`` is
    the compare-select trellis at ``acs_radix``; ``"matrix"`` collapses
    ``acs_k`` stages into one (min,+) tropical matmul step — bit-exact
    decoded bits, a k-fold shorter forward serial chain, and (Pallas paths)
    the 2^(kR-1) folded combined metrics assembled by one MXU-shaped
    matmul. ``acs_k`` is validated here at config time: structural bounds
    (1 ≤ k ≤ v, k·R ≤ 8) and, for narrow metric modes, the k-stage
    saturation budget — over-deep fusion fails with a ``ValueError``, never
    a silent in-kernel saturate.
    """

    code: ConvCode = CCSDS_27
    D: int = 512  # decode block length
    L: int = 42  # traceback depth (= truncation length M)
    q: int | None = 8  # soft-symbol quantization bits; None → float32
    start_policy: Literal["zero", "argmin"] = "zero"
    backend: Literal["pallas", "ref", "fused"] = "pallas"
    spec: CodeSpec | None = None
    metric_mode: Literal["f32", "i16", "i8"] = "f32"
    tb_mode: Literal["serial", "prefix", "auto"] = "auto"
    tb_chunk: int = DEFAULT_TB_CHUNK  # prefix traceback chunk size
    acs_radix: Literal[2, 4] = 2  # forward-ACS stages fused per step (radix/2)
    acs_impl: Literal["butterfly", "matrix"] = "butterfly"
    acs_k: int = 2  # matrix-ACS fusion depth (stages per tropical matmul)

    @property
    def T(self) -> int:  # stages per parallel block
        return self.D + 2 * self.L

    @property
    def codespec(self) -> CodeSpec:
        """The effective CodeSpec (wrapping ``code`` when none was given)."""
        if self.spec is not None:
            return self.spec
        return CodeSpec(name=f"(2,1,{self.code.K})" if self.code.R == 2 else "custom",
                        code=self.code)

    @property
    def effective_q(self) -> int | None:
        """Quantizer width the engine actually applies to float symbols.

        ``f32`` keeps ``q`` as configured; the narrow metric modes quantize
        unconditionally (int PMs need int symbols) and cap the width at the
        widest q whose worst-case metric fits the mode's dtype
        (:func:`~repro.core.quantize.max_symbol_bits`).
        """
        if self.metric_mode == "f32":
            return self.q
        # cap at the width the kernels' normalization cadence assumes
        # (metric_mode_qmax) — a wider engine-side q would void the budget
        cap = max_symbol_bits(self.code, metric_dtype_max(self.metric_mode))
        return min(self.q or 8, cap)

    def quantize(self, y):
        """Quantize float soft symbols per the configured metric mode.

        ``f32``/``i16`` use the quantizer's default 4σ-ish dynamic range. The
        coarse ``i8`` quantizer (q=3 for the registered codes) maps |y| = 2
        to full scale instead — burning two of three bits on ±4 headroom
        collapses the soft information (measured: rate-3/4 BER 0.21 → 0.009
        at 4.5 dB), while full scale at ±2 keeps the classic ≈0.2 dB 3-bit
        soft-decision loss.
        """
        q = self.effective_q
        if q is None:
            return y
        scale = ((1 << (q - 1)) - 1) / 2.0 if self.metric_mode == "i8" else None
        return quantize_soft(y, q, scale)

    def __post_init__(self):
        # knob validation mirrors the dispatcher's eager checks and raises
        # the SAME uniform error shape (repro.kernels.registry.knob_error:
        # backend, knob, allowed values) — a bad knob fails identically
        # whether it enters through the config or pbvd_decode_blocks, always
        # before any jit trace
        from repro.kernels.ops import (
            backend_acs_impl,
            backend_acs_radix,
            backend_metric_modes,
            backend_tb_modes,
            knob_error,
        )

        if self.D <= 0 or self.L < 0:
            raise ValueError("D must be positive, L non-negative")
        if self.metric_mode not in backend_metric_modes(self.backend):
            raise knob_error(
                self.backend, "metric_mode", self.metric_mode,
                backend_metric_modes(self.backend),
            )
        tb_allowed = (*backend_tb_modes(self.backend), "auto")
        if self.tb_mode not in tb_allowed:
            raise knob_error(self.backend, "tb_mode", self.tb_mode, tb_allowed)
        if self.tb_chunk < 1:
            raise ValueError(f"tb_chunk must be >= 1, got {self.tb_chunk}")
        if self.acs_impl not in backend_acs_impl(self.backend):
            raise knob_error(
                self.backend, "acs_impl", self.acs_impl,
                backend_acs_impl(self.backend),
            )
        if self.acs_radix not in backend_acs_radix(self.backend):
            raise knob_error(
                self.backend, "acs_radix", self.acs_radix,
                backend_acs_radix(self.backend),
            )
        if self.spec is not None and self.spec.code is not self.code:
            # keep cfg.code authoritative for kernel callers
            object.__setattr__(self, "code", self.spec.code)
        if self.acs_impl == "matrix":
            # structural bounds on the fusion depth, then the narrow-mode
            # budget for k unnormalized stages per matrix step — fail at
            # CONFIG time, not by silent saturation in-kernel
            self.code.validate_matrix_k(self.acs_k)
            norm_interval(self.code, self.metric_mode, stages_per_step=self.acs_k)
        elif self.acs_radix == 4:
            if self.code.n_states < 4:
                raise ValueError(f"acs_radix=4 needs K >= 3 (got K={self.code.K})")
            # narrow modes: the saturation budget must absorb the fused
            # step's two unnormalized stages — fail at CONFIG time, with
            # norm_interval's ValueError, not by silent saturation in-kernel
            norm_interval(self.code, self.metric_mode, self.acs_radix)


@partial(jax.jit, static_argnames=("D", "L", "n_blocks"))
def frame_stream(y: jnp.ndarray, D: int, L: int, n_blocks: int) -> jnp.ndarray:
    """Frame a symbol stream into overlapping parallel blocks.

    y: (n_sym, R) soft symbols → (T, R, N_t) with T = D + 2L. Block b covers
    global stages [bD - L, bD + D + L); out-of-range stages are zero
    (BM-neutral).
    """
    n_sym, R = y.shape
    T = D + 2 * L
    pad_tail = n_blocks * D + L - n_sym
    yp = jnp.pad(y, ((L, max(pad_tail, 0)), (0, 0)))
    # gather block windows: index matrix (T, N_t)
    idx = jnp.arange(T)[:, None] + jnp.arange(n_blocks)[None, :] * D
    blocks = yp[idx]  # (T, N_t, R)
    return jnp.transpose(blocks, (0, 2, 1))  # (T, R, N_t)


def decode_stream(
    y: jnp.ndarray,
    n_bits: int,
    cfg: PBVDConfig = PBVDConfig(),
    *,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Decode a soft-symbol stream. y: (n_sym, R) → (n_bits,) int32 bits.

    Thin wrapper over :class:`~repro.core.engine.DecoderEngine`.
    """
    from .engine import DecoderEngine

    return DecoderEngine(cfg).decode(y, n_bits, interpret=interpret)


def decode_stream_sharded(
    y: jnp.ndarray,
    n_bits: int,
    cfg: PBVDConfig,
    mesh: jax.sharding.Mesh,
    *,
    block_axes: tuple[str, ...] | None = ("data",),
    shard_dispatch: str = "constraint",
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Distributed stream decode: thin wrapper over a mesh-bound engine.

    ``block_axes=None`` resolves the ``"blocks"`` logical-axis rule against
    the mesh; ``shard_dispatch`` picks the lane dispatch path (see
    :class:`~repro.core.engine.DecoderEngine`).
    """
    from .engine import DecoderEngine

    engine = DecoderEngine(
        cfg, mesh=mesh, block_axes=block_axes, shard_dispatch=shard_dispatch
    )
    return engine.decode(y, n_bits, interpret=interpret)


def throughput_model(
    *,
    D: int,
    L: int,
    R: int,
    q: int | None,
    packed_out: bool,
    s_kernel_mbps: float,
    n_streams: int = 3,
    bandwidth_gbps: float = 8.0,
) -> float:
    """Paper eq. (7): decoding throughput in Mbps given kernel throughput S_k.

    ``bandwidth_gbps`` is the host↔device link (PCIe 2.0 ≈ 8 GB/s in the
    paper's GTX580 setup; a TPU host-DMA link is similar in spirit).

    Derived from first principles (the paper's eq. 7 with the bandwidth
    factored consistently):

      T/P [bit/s] = N_s / ((1 + 2L/D)·U₁/B + N_s/S_k + U₂/B)

    with U in bytes/bit, B in bytes/s, S_k in bit/s.
    """
    B = bandwidth_gbps * 1e9  # bytes/s
    s_k = s_kernel_mbps * 1e6  # bit/s
    u1 = u1_bytes(R, q)
    u2 = u2_bytes(packed_out)
    denom = (1.0 + 2.0 * L / D) * u1 / B + n_streams / s_k + u2 / B
    return n_streams / denom / 1e6  # Mbps
