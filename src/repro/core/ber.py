"""BER simulation harness (paper Fig. 4 reproduction).

Monte-Carlo: random payload → convolutional encode → BPSK+AWGN →
(optional q-bit quantization) → PBVD decode → bit error rate.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from .channel import transmit
from .encoder import encode_jax
from .engine import DecoderEngine
from .pbvd import PBVDConfig

__all__ = ["simulate_ber", "uncoded_ber"]


def uncoded_ber(ebn0_db: float) -> float:
    """Theoretical uncoded BPSK BER: Q(sqrt(2 Eb/N0))."""
    ebn0 = 10.0 ** (ebn0_db / 10.0)
    return 0.5 * math.erfc(math.sqrt(ebn0))


def simulate_ber(
    key: jax.Array,
    ebn0_db: float,
    cfg: PBVDConfig,
    *,
    n_bits: int = 1 << 15,
    n_trials: int = 1,
) -> float:
    """Monte-Carlo BER of the PBVD decoder at the given Eb/N0.

    Punctured specs are exercised end-to-end: the coded stream is punctured
    before the channel (so Eb/N0 uses the effective rate) and the engine
    depunctures with BM-neutral zeros on receive.
    """
    engine = DecoderEngine(cfg)
    spec = engine.spec
    errors = 0
    total = 0
    for trial in range(n_trials):
        key, kb, kn = jax.random.split(key, 3)
        bits = jax.random.bernoulli(kb, 0.5, (n_bits,)).astype(jnp.int32)
        # flush the encoder so the stream is self-contained
        bits_t = jnp.concatenate([bits, jnp.zeros(cfg.code.v, jnp.int32)])
        coded = encode_jax(bits_t, cfg.code)  # (T, R)
        if spec.is_punctured:
            tx = spec.puncture_stream(coded)  # (n_kept,)
        else:
            tx = coded
        y = transmit(kn, tx, ebn0_db, spec.rate)
        dec = engine.decode(y, n_bits + cfg.code.v)[:n_bits]
        errors += int(jnp.sum(dec != bits))
        total += n_bits
    return errors / total
