"""Code specifications: convolutional codes + puncturing, as first-class configs.

A ``CodeSpec`` extends a mother :class:`~repro.core.trellis.ConvCode` with an
optional puncturing matrix, turning "new code / new rate" into a table entry
instead of a new decode pipeline (DESIGN.md §4).

Puncturing convention (standard DVB/3GPP form): ``puncture[r][t]`` is 1 if
output stream ``r`` of stage ``t mod period`` is transmitted. The transmitted
stream is read stage-major (for each stage, streams ``0..R-1`` in order,
skipping punctured slots). On receive, punctured positions are refilled with
**zero** soft symbols — zeros are BM-neutral for the correlation metric
``BM(c) = Σ_r y_r (2 c_r - 1)`` (they add the same constant 0 to every
codeword's metric), so depunctured streams flow through the existing framing
and kernels unchanged.

The registry at the bottom exposes named specs (``get_code_spec``), including
the paper's CCSDS (2,1,7) mother code with the standard rate-2/3, 3/4 and 5/6
punctured variants, the K=9 IS-95/NASA-style code, and the LTE-style
rate-1/3 K=7 code.
"""

from __future__ import annotations

import dataclasses
from functools import cached_property

import numpy as np

from .trellis import CCSDS_27, ConvCode

__all__ = [
    "CodeSpec",
    "PUNCTURE_PATTERNS",
    "IS95_29",
    "LTE_37",
    "register_code_spec",
    "get_code_spec",
    "available_code_specs",
]


# Standard puncturing patterns for a rate-1/2 mother code (rows = streams,
# columns = stage within period). DVB-S convention.
PUNCTURE_PATTERNS: dict[str, tuple[tuple[int, ...], ...]] = {
    "2/3": ((1, 0), (1, 1)),
    "3/4": ((1, 0, 1), (1, 1, 0)),
    "5/6": ((1, 0, 1, 0, 1), (1, 1, 0, 1, 0)),
}


@dataclasses.dataclass(frozen=True)
class CodeSpec:
    """A decodable code: mother ConvCode + optional puncturing matrix.

    Hashable/frozen so it can parameterize jit'd decode paths alongside the
    ConvCode it wraps.
    """

    name: str
    code: ConvCode
    puncture: tuple[tuple[int, ...], ...] | None = None

    def __post_init__(self):
        if self.puncture is not None:
            if len(self.puncture) != self.code.R:
                raise ValueError(
                    f"puncture matrix has {len(self.puncture)} rows, code has R={self.code.R}"
                )
            periods = {len(row) for row in self.puncture}
            if len(periods) != 1:
                raise ValueError(f"puncture rows must share a period, got {periods}")
            if not all(b in (0, 1) for row in self.puncture for b in row):
                raise ValueError("puncture matrix must be binary")
            if sum(b for row in self.puncture for b in row) == 0:
                raise ValueError("puncture matrix keeps no symbols")

    # ---- shape parameters ---------------------------------------------------------
    @property
    def is_punctured(self) -> bool:
        return self.puncture is not None

    @property
    def period(self) -> int:
        """Puncture period in stages (1 when unpunctured)."""
        return len(self.puncture[0]) if self.puncture is not None else 1

    @cached_property
    def kept_slots_period(self) -> np.ndarray:
        """Flattened slot indices (stage-major, slot = t·R + r) kept per period."""
        R = self.code.R
        if self.puncture is None:
            return np.arange(R, dtype=np.int64)
        return np.array(
            [t * R + r for t in range(self.period) for r in range(R) if self.puncture[r][t]],
            dtype=np.int64,
        )

    @property
    def kept_per_period(self) -> int:
        return len(self.kept_slots_period)

    @property
    def rate(self) -> float:
        """Effective code rate (input bits / transmitted symbols)."""
        return self.period / self.kept_per_period

    # ---- stream transforms ---------------------------------------------------------
    def kept_slot_indices(self, offset: int, n: int) -> np.ndarray:
        """Absolute full-rate slot indices of kept symbols [offset, offset+n).

        Symbol ``k`` of the punctured stream occupies slot
        ``(k // m)·p·R + kept_slots_period[k % m]`` of the full-rate stream
        flattened stage-major (p = period, m = kept per period).
        """
        m = self.kept_per_period
        slots_per_period = self.period * self.code.R
        k = np.arange(offset, offset + n, dtype=np.int64)
        return (k // m) * slots_per_period + self.kept_slots_period[k % m]

    def n_stages_for(self, n_symbols: int) -> int:
        """Full-rate stages spanned by the first ``n_symbols`` punctured symbols."""
        if n_symbols <= 0:
            return 0
        last_slot = int(self.kept_slot_indices(n_symbols - 1, 1)[0])
        return last_slot // self.code.R + 1

    def n_symbols_for(self, n_stages: int) -> int:
        """Punctured symbols transmitted for ``n_stages`` full-rate stages."""
        if self.puncture is None:
            return n_stages * self.code.R
        m = self.kept_per_period
        full, rem = divmod(n_stages, self.period)
        count = full * m
        if rem:
            count += int(np.sum(self.kept_slots_period < rem * self.code.R))
        return count

    def puncture_stream(self, coded):
        """(T, R) coded symbols → (n_kept,) transmitted stream (numpy or jax)."""
        T, R = coded.shape
        if R != self.code.R:
            raise ValueError(f"stream rank {R} != code R {self.code.R}")
        idx = self.kept_slot_indices(0, self.n_symbols_for(T))
        return coded.reshape(-1)[idx]

    def depuncture_stream(self, y, n_stages: int | None = None):
        """(n,) punctured soft symbols → (n_stages, R) with BM-neutral zeros.

        jax-traceable: the scatter indices are static numpy, the data path is
        a single ``.at[].set``.
        """
        import jax.numpy as jnp

        n = y.shape[0]
        if n_stages is None:
            n_stages = self.n_stages_for(n)
        idx = self.kept_slot_indices(0, n)
        idx = idx[idx < n_stages * self.code.R]
        flat = jnp.zeros((n_stages * self.code.R,), dtype=y.dtype)
        flat = flat.at[idx].set(y[: len(idx)])
        return flat.reshape(n_stages, self.code.R)


# ---------------------------------------------------------------------------
# First-class codes beyond the paper's CCSDS (2,1,7)
# ---------------------------------------------------------------------------
def _from_octal(K: int, *polys_octal: int) -> ConvCode:
    """Build a ConvCode from octal generator polynomials, MSB = input tap."""
    rows = []
    for g in polys_octal:
        rows.append(tuple((g >> (K - 1 - i)) & 1 for i in range(K)))
    return ConvCode(polys=tuple(rows))


# K=9 rate-1/2 code (IS-95 / NASA deep-space family): g = 753, 561 (octal).
IS95_29 = _from_octal(9, 0o753, 0o561)

# K=7 rate-1/3 LTE-style code: g = 133, 171, 165 (octal).
LTE_37 = _from_octal(7, 0o133, 0o171, 0o165)


# ---------------------------------------------------------------------------
# Named registry
# ---------------------------------------------------------------------------
_REGISTRY: dict[str, CodeSpec] = {}


def register_code_spec(spec: CodeSpec, *, overwrite: bool = False) -> CodeSpec:
    if spec.name in _REGISTRY and not overwrite:
        raise ValueError(f"code spec {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def get_code_spec(name: str) -> CodeSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown code spec {name!r}; available: {available_code_specs()}"
        ) from None


def available_code_specs() -> list[str]:
    return sorted(_REGISTRY)


def _register_family(base_name: str, code: ConvCode) -> None:
    register_code_spec(CodeSpec(name=base_name, code=code))
    if code.R == 2:  # standard punctured rates are defined from a 1/2 mother
        for rate, pattern in PUNCTURE_PATTERNS.items():
            register_code_spec(
                CodeSpec(name=f"{base_name}-{rate}", code=code, puncture=pattern)
            )


_register_family("ccsds", CCSDS_27)
_register_family("is95-k9", IS95_29)
register_code_spec(CodeSpec(name="lte-1/3", code=LTE_37))
