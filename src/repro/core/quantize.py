"""q-bit fixed-point quantization + word packing (paper §IV-C).

The paper shrinks the H2D transfer by quantizing soft symbols to q bits and
packing ``⌊32/q⌋`` of them per 32-bit word (U₁: 4R → 4R/⌊32/q⌋ bytes per
symbol), and shrinks D2H by bit-packing decoded bits (U₂ → 1/8 byte).

We implement the same transforms; the packed representations are what the
decode engine moves across the host↔HBM boundary and what the Pallas kernels
consume (int8 path) / produce (bit-packed decisions).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = [
    "quantize_soft",
    "dequantize_soft",
    "pack_words",
    "unpack_words",
    "pack_bits",
    "unpack_bits",
    "u1_bytes",
    "u2_bytes",
    "pm_spread_bound",
    "max_symbol_bits",
    "metric_dtype_max",
    "metric_mode_qmax",
    "norm_interval",
]


def quantize_soft(y: jnp.ndarray, q: int = 8, scale: float | None = None) -> jnp.ndarray:
    """Quantize soft symbols to q-bit signed fixed point, stored in int8/int16.

    ``scale`` defaults to mapping |y| = 4σ-ish dynamic range; for unit-energy
    BPSK ±1 with noise, scale = (2^(q-1)-1) / 4.0 keeps clipping negligible.

    Clipping is SYMMETRIC at ±(2^(q-1)-1): the folded branch-metric path
    negates quantized symbols in-register, and the two's-complement minimum
    (-2^(q-1)) has no negation in q bits — admitting it would silently wrap.

    Non-finite inputs are refused: ``jnp.clip(round(nan))`` quantizes NaN to
    an in-range integer, silently corrupting the path metrics of every
    stream coalesced into the same launch. Concrete inputs raise
    :func:`repro.launch.faults.nonfinite_error` here; tracers pass through
    (validation is an eager-boundary concern).
    """
    if q < 2 or q > 16:
        raise ValueError("q must be in [2, 16]")
    from repro.launch.faults import check_finite_symbols

    check_finite_symbols(y, "quantize_soft")
    qmax = (1 << (q - 1)) - 1
    if scale is None:
        scale = qmax / 4.0
    z = jnp.clip(jnp.round(y * scale), -qmax, qmax)
    dtype = jnp.int8 if q <= 8 else jnp.int16
    return z.astype(dtype)


def dequantize_soft(z: jnp.ndarray, q: int = 8, scale: float | None = None) -> jnp.ndarray:
    qmax = (1 << (q - 1)) - 1
    if scale is None:
        scale = qmax / 4.0
    return z.astype(jnp.float32) / scale


def pack_words(z: jnp.ndarray, q: int = 8) -> jnp.ndarray:
    """Pack q-bit values along the last axis into int32 words (⌊32/q⌋ per word).

    A last-dim length that is not a multiple of ⌊32/q⌋ is zero-padded into the
    final word; ``unpack_words(..., per_axis_len=n)`` trims the pad again.
    """
    per = 32 // q
    *lead, n = z.shape
    if n % per:
        widths = [(0, 0)] * (z.ndim - 1) + [(0, (-n) % per)]
        z = jnp.pad(z, widths)
        n = z.shape[-1]
    zi = z.astype(jnp.int32) & ((1 << q) - 1)
    zi = zi.reshape(*lead, n // per, per)
    shifts = jnp.arange(per, dtype=jnp.int32) * q
    # disjoint bit ranges → sum == bitwise OR (int32 add wraps, bits preserved)
    return (zi << shifts).sum(axis=-1, dtype=jnp.int32)


def unpack_words(w: jnp.ndarray, q: int = 8, per_axis_len: int | None = None) -> jnp.ndarray:
    """Inverse of pack_words; returns sign-extended int32 values."""
    per = 32 // q
    shifts = jnp.arange(per, dtype=jnp.int32) * q
    vals = (w[..., None] >> shifts) & ((1 << q) - 1)
    # sign extend
    sign_bit = 1 << (q - 1)
    vals = jnp.where(vals >= sign_bit, vals - (1 << q), vals)
    *lead, nw, per_ = vals.shape
    out = vals.reshape(*lead, nw * per_)
    if per_axis_len is not None:
        out = out[..., :per_axis_len]
    return out.astype(jnp.int32)


def pack_bits(bits: jnp.ndarray) -> jnp.ndarray:
    """Pack bits (..., T) with T % 8 == 0 into uint8 bytes (..., T/8). LSB-first."""
    *lead, t = bits.shape
    if t % 8:
        raise ValueError(f"bit length {t} not a multiple of 8")
    b = bits.astype(jnp.uint8).reshape(*lead, t // 8, 8)
    weights = (jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8))
    return (b * weights).sum(axis=-1).astype(jnp.uint8)


def unpack_bits(bytes_: jnp.ndarray, n_bits: int | None = None) -> jnp.ndarray:
    *lead, nb = bytes_.shape
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits = (bytes_[..., None] >> shifts) & jnp.uint8(1)
    out = bits.reshape(*lead, nb * 8).astype(jnp.int32)
    if n_bits is not None:
        out = out[..., :n_bits]
    return out


def u1_bytes(R: int, q: int | None) -> float:
    """Bytes per input symbol (paper's U₁). q=None → float32 unpacked."""
    if q is None:
        return 4.0 * R
    return 4.0 * R / (32 // q)


def u2_bytes(packed: bool) -> float:
    """Bytes per decoded bit (paper's U₂)."""
    return 1.0 / 8.0 if packed else 4.0


# ---------------------------------------------------------------------------
# Saturation budget for the narrow (int16/int8) path-metric pipeline
# ---------------------------------------------------------------------------
def pm_spread_bound(code, qmax: int, interval: int = 1) -> int:
    """Worst-case transient path-metric magnitude under min-subtract
    normalization applied every ``interval`` stages.

    With symbols bounded by ``|y| ≤ qmax`` the branch-metric range is
    ``2·R·qmax``. Any state's survivor path can be rerouted through the
    argmin state of ``v = K-1`` stages earlier (the trellis is fully
    connected in ``v`` steps), so the spread obeys the classical merge bound
    ``spread ≤ v · 2·R·qmax`` at ALL times; between normalizations the
    per-lane minimum can additionally drift by at most ``R·qmax`` per stage
    in either direction, for up to ``interval`` stages:

        max |PM| ≤ (2·v + interval) · R · qmax

    A metric dtype whose max dominates this bound can NEVER saturate,
    regardless of stream length — the contract the i16/i8 metric modes
    declare in :mod:`repro.kernels.registry` and that
    ``tests/test_kernels.py`` drives 10k adversarial stages against.
    """
    return (2 * code.v + interval) * code.R * qmax


def max_symbol_bits(code, pm_dtype_max: int, q_cap: int = 8) -> int:
    """Largest quantizer width q whose worst case fits the metric dtype.

    Returns the largest ``q ≤ q_cap`` with
    ``pm_spread_bound(code, 2^(q-1)-1) ≤ pm_dtype_max`` (at least 2 — a
    code so large that even 2-bit symbols overflow the dtype is rejected).
    The symbol width is chosen at the tightest cadence (``interval=1``);
    :func:`norm_interval` then spends the REMAINING headroom on amortizing
    the normalization.
    """
    for q in range(q_cap, 1, -1):
        if pm_spread_bound(code, (1 << (q - 1)) - 1) <= pm_dtype_max:
            return q
    raise ValueError(
        f"no quantizer width ≥ 2 bits fits pm dtype max {pm_dtype_max} "
        f"for K={code.K}, R={code.R}"
    )


def metric_dtype_max(metric_mode: str) -> int:
    """Path-metric dtype max of a NARROW metric mode (single source of truth)."""
    try:
        return {"i16": 32767, "i8": 127}[metric_mode]
    except KeyError:
        raise ValueError(
            f"metric_mode {metric_mode!r} has no narrow metric dtype "
            f"(expected 'i16' or 'i8')"
        ) from None


def metric_mode_qmax(code, metric_mode: str) -> int:
    """The symbol bound a narrow metric mode ASSUMES of its integer inputs.

    Pre-quantized callers must respect it (the engine's quantizer does);
    the kernels derive their static normalization cadence from it — symbols
    beyond the bound are saturated on kernel ingestion.
    """
    return (1 << (max_symbol_bits(code, metric_dtype_max(metric_mode)) - 1)) - 1


def norm_interval(
    code, metric_mode: str, acs_radix: int = 2, stages_per_step: int | None = None
) -> int:
    """Static min-subtract cadence (ACS *steps*) of a narrow metric mode.

    Per-step normalization costs a sublane reduction every step; the
    saturation budget usually has slack beyond ``interval=1``, so the
    normalization runs every k-th step with the largest k that keeps
    ``pm_spread_bound(code, qmax, k·stages_per_step) ≤ dtype_max`` —
    identical decisions (min-subtract is a uniform per-lane shift),
    identical saturation guarantee, fraction of the cost. Every backend
    derives the SAME k from the code + mode + radix, so path metrics stay
    bit-comparable across backends.

    ``acs_radix`` fixes how many trellis stages one ACS step accumulates
    before the kernel can normalize: 1 stage for the radix-2 butterfly,
    2 for the stage-fused radix-4 step (so the radix-2 cadence, in stages,
    is unchanged from the historical single-argument form). The k-stage
    (min,+) matrix path passes ``stages_per_step=k`` directly, overriding
    the radix mapping — one collapsed matrix step accumulates k stages of
    branch metric before it can min-subtract. A configuration whose budget
    cannot fit even the tightest cadence at this step width —
    ``pm_spread_bound(code, qmax, stages_per_step) > dtype_max`` — raises
    ``ValueError`` here, at config time, instead of silently saturating
    inside a jitted kernel.
    """
    if metric_mode == "f32":
        return 0  # no normalization
    origin = f"acs_k={stages_per_step}"
    if stages_per_step is None:
        if acs_radix not in (2, 4):
            raise ValueError(f"acs_radix must be 2 or 4, got {acs_radix}")
        origin = f"acs_radix={acs_radix}"
        stages_per_step = 1 if acs_radix == 2 else 2
    if not isinstance(stages_per_step, int) or stages_per_step < 1:
        raise ValueError(f"stages_per_step must be a positive int, got {stages_per_step!r}")
    dtype_max = metric_dtype_max(metric_mode)
    qmax = metric_mode_qmax(code, metric_mode)
    if pm_spread_bound(code, qmax, stages_per_step) > dtype_max:
        raise ValueError(
            f"metric_mode={metric_mode!r} cannot accumulate "
            f"{stages_per_step} unnormalized trellis stage(s) per ACS step "
            f"({origin}) for K={code.K}, R={code.R}: even the tightest "
            f"normalization cadence has worst-case path metric "
            f"{pm_spread_bound(code, qmax, stages_per_step)} "
            f"> dtype max {dtype_max}"
        )
    return max(1, (dtype_max // (code.R * qmax) - 2 * code.v) // stages_per_step)
