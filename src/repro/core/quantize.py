"""q-bit fixed-point quantization + word packing (paper §IV-C).

The paper shrinks the H2D transfer by quantizing soft symbols to q bits and
packing ``⌊32/q⌋`` of them per 32-bit word (U₁: 4R → 4R/⌊32/q⌋ bytes per
symbol), and shrinks D2H by bit-packing decoded bits (U₂ → 1/8 byte).

We implement the same transforms; the packed representations are what the
decode engine moves across the host↔HBM boundary and what the Pallas kernels
consume (int8 path) / produce (bit-packed decisions).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = [
    "quantize_soft",
    "dequantize_soft",
    "pack_words",
    "unpack_words",
    "pack_bits",
    "unpack_bits",
    "u1_bytes",
    "u2_bytes",
]


def quantize_soft(y: jnp.ndarray, q: int = 8, scale: float | None = None) -> jnp.ndarray:
    """Quantize soft symbols to q-bit signed fixed point, stored in int8/int16.

    ``scale`` defaults to mapping |y| = 4σ-ish dynamic range; for unit-energy
    BPSK ±1 with noise, scale = (2^(q-1)-1) / 4.0 keeps clipping negligible.
    """
    if q < 2 or q > 16:
        raise ValueError("q must be in [2, 16]")
    qmax = (1 << (q - 1)) - 1
    if scale is None:
        scale = qmax / 4.0
    z = jnp.clip(jnp.round(y * scale), -qmax - 1, qmax)
    dtype = jnp.int8 if q <= 8 else jnp.int16
    return z.astype(dtype)


def dequantize_soft(z: jnp.ndarray, q: int = 8, scale: float | None = None) -> jnp.ndarray:
    qmax = (1 << (q - 1)) - 1
    if scale is None:
        scale = qmax / 4.0
    return z.astype(jnp.float32) / scale


def pack_words(z: jnp.ndarray, q: int = 8) -> jnp.ndarray:
    """Pack q-bit values along the last axis into int32 words (⌊32/q⌋ per word).

    Input last-dim length must be a multiple of ⌊32/q⌋.
    """
    per = 32 // q
    *lead, n = z.shape
    if n % per:
        raise ValueError(f"last dim {n} not a multiple of {per}")
    zi = z.astype(jnp.int32) & ((1 << q) - 1)
    zi = zi.reshape(*lead, n // per, per)
    shifts = jnp.arange(per, dtype=jnp.int32) * q
    # disjoint bit ranges → sum == bitwise OR (int32 add wraps, bits preserved)
    return (zi << shifts).sum(axis=-1, dtype=jnp.int32)


def unpack_words(w: jnp.ndarray, q: int = 8, per_axis_len: int | None = None) -> jnp.ndarray:
    """Inverse of pack_words; returns sign-extended int32 values."""
    per = 32 // q
    shifts = jnp.arange(per, dtype=jnp.int32) * q
    vals = (w[..., None] >> shifts) & ((1 << q) - 1)
    # sign extend
    sign_bit = 1 << (q - 1)
    vals = jnp.where(vals >= sign_bit, vals - (1 << q), vals)
    *lead, nw, per_ = vals.shape
    out = vals.reshape(*lead, nw * per_)
    if per_axis_len is not None:
        out = out[..., :per_axis_len]
    return out.astype(jnp.int32)


def pack_bits(bits: jnp.ndarray) -> jnp.ndarray:
    """Pack bits (..., T) with T % 8 == 0 into uint8 bytes (..., T/8). LSB-first."""
    *lead, t = bits.shape
    if t % 8:
        raise ValueError(f"bit length {t} not a multiple of 8")
    b = bits.astype(jnp.uint8).reshape(*lead, t // 8, 8)
    weights = (jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8))
    return (b * weights).sum(axis=-1).astype(jnp.uint8)


def unpack_bits(bytes_: jnp.ndarray, n_bits: int | None = None) -> jnp.ndarray:
    *lead, nb = bytes_.shape
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits = (bytes_[..., None] >> shifts) & jnp.uint8(1)
    out = bits.reshape(*lead, nb * 8).astype(jnp.int32)
    if n_bits is not None:
        out = out[..., :n_bits]
    return out


def u1_bytes(R: int, q: int | None) -> float:
    """Bytes per input symbol (paper's U₁). q=None → float32 unpacked."""
    if q is None:
        return 4.0 * R
    return 4.0 * R / (32 // q)


def u2_bytes(packed: bool) -> float:
    """Bytes per decoded bit (paper's U₂)."""
    return 1.0 / 8.0 if packed else 4.0
