"""Model configuration system + architecture registry.

Every assigned architecture gets a module in this package registering its
exact published configuration plus a ``reduced`` variant for CPU smoke tests.
Shapes (the assigned input-shape set) are defined here too.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Literal, Optional

__all__ = ["ModelConfig", "LayerDesc", "ShapeSpec", "SHAPES", "register", "get_config", "list_archs"]


@dataclasses.dataclass(frozen=True)
class LayerDesc:
    """One layer of a (possibly heterogeneous) block pattern."""

    mixer: Literal["gqa", "mla", "mamba", "rwkv6", "none"] = "gqa"
    ffn: Literal["dense", "moe", "none"] = "dense"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm", "audio"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128

    # layer pattern: (pattern, repeat) groups; default = uniform decoder
    pattern: tuple[LayerDesc, ...] = (LayerDesc(),)
    # if pattern repeats don't tile n_layers exactly, a prefix group is used
    prefix: tuple[LayerDesc, ...] = ()

    # attention options
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None
    attn_logit_softcap: Optional[float] = None

    # MLA (deepseek-style) options
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # MoE options
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    renorm_topk: bool = True
    capacity_factor: float = 1.25
    d_ff_dense: int = 0  # dense FFN width when pattern mixes dense+moe

    # Mamba options
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_dt_rank: int = 0  # 0 → ceil(d_model/16)

    # RWKV options
    rwkv_head_dim: int = 64
    rwkv_decay_lora: int = 64
    rwkv_gate_lora: int = 64

    # enc-dec options
    n_encoder_layers: int = 0
    encdec: bool = False

    # modality frontend stub
    frontend: Literal["none", "audio_frames", "vision_patches"] = "none"
    n_patches: int = 0  # vision: positions replaced by patch embeddings

    # FFN activation: swiglu (3 mats), relu2/gelu (2 mats), rwkv_cm (channel mix)
    ffn_act: Literal["swiglu", "relu2", "gelu", "rwkv_cm"] = "swiglu"

    # norms / embeddings
    norm_type: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    parallel_block: bool = False  # command-r style parallel attn+ffn

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # citation / provenance
    source: str = ""

    # ---- derived ---------------------------------------------------------------
    @property
    def dt_rank(self) -> int:
        return self.mamba_dt_rank or -(-self.d_model // 16)

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def layer_list(self) -> tuple[tuple[tuple[LayerDesc, ...], int], ...]:
        """((pattern, repeat), ...) groups covering n_layers."""
        groups = []
        remaining = self.n_layers
        if self.prefix:
            groups.append((self.prefix, 1))
            remaining -= len(self.prefix)
        plen = len(self.pattern)
        if remaining % plen:
            raise ValueError(f"{self.name}: {remaining} layers not tiled by pattern {plen}")
        groups.append((self.pattern, remaining // plen))
        return tuple(groups)

    @property
    def n_params_estimate(self) -> int:
        """Analytic parameter count (used for roofline 6ND and memory checks)."""
        d = self.d_model
        total = self.vocab * d  # embed
        if not self.tie_embeddings:
            total += self.vocab * d
        for pattern, repeat in self.layer_list:
            for desc in pattern:
                p = 0
                if desc.mixer == "gqa":
                    p += d * self.n_heads * self.head_dim  # q
                    p += 2 * d * self.n_kv_heads * self.head_dim  # k, v
                    p += self.n_heads * self.head_dim * d  # o
                elif desc.mixer == "mla":
                    qr = self.q_lora_rank or d
                    p += d * self.q_lora_rank if self.q_lora_rank else 0
                    p += qr * self.n_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
                    p += d * (self.kv_lora_rank + self.qk_rope_head_dim)
                    p += self.kv_lora_rank * self.n_heads * (self.qk_nope_head_dim + self.v_head_dim)
                    p += self.n_heads * self.v_head_dim * d
                elif desc.mixer == "mamba":
                    di, ds, dr = self.mamba_d_inner, self.mamba_d_state, self.dt_rank
                    p += d * 2 * di + di * self.mamba_d_conv + di * (dr + 2 * ds) + dr * di
                    p += di * ds + 2 * di + di * d
                elif desc.mixer == "rwkv6":
                    p += 4 * d * d + d * d  # r,k,v,o + gate
                    p += self.rwkv_decay_lora * 2 * d + self.rwkv_gate_lora * 2 * d
                if desc.ffn == "dense":
                    ff = self.d_ff_dense or self.d_ff
                    if self.ffn_act == "swiglu":
                        p += 3 * d * ff
                    elif self.ffn_act == "rwkv_cm":
                        p += 2 * d * ff + d * d
                    else:  # relu2 / gelu
                        p += 2 * d * ff
                elif desc.ffn == "moe":
                    p += d * self.n_experts  # router
                    p += self.n_experts * 3 * d * self.d_ff_expert
                    p += self.n_shared_experts * 3 * d * self.d_ff_expert
                total += p * repeat
        if self.encdec:
            # encoder layers: self-attn + dense ffn; decoder adds cross-attn
            n_ffn_mats = 3 if self.ffn_act == "swiglu" else 2
            enc = self.n_encoder_layers * (
                d * self.n_heads * self.head_dim * 2
                + 2 * d * self.n_kv_heads * self.head_dim
                + n_ffn_mats * d * self.d_ff
            )
            cross = self.n_layers * (
                d * self.n_heads * self.head_dim * 2 + 2 * d * self.n_kv_heads * self.head_dim
            )
            total += enc + cross
        return total

    @property
    def n_active_params_estimate(self) -> int:
        """Active params per token (MoE: only top-k + shared experts)."""
        if self.n_experts == 0:
            return self.n_params_estimate
        total = self.n_params_estimate
        # subtract inactive routed experts in every MoE layer
        n_moe_layers = 0
        for pattern, repeat in self.layer_list:
            n_moe_layers += sum(1 for dsc in pattern if dsc.ffn == "moe") * repeat
        inactive = (self.n_experts - self.top_k) * 3 * self.d_model * self.d_ff_expert
        return total - n_moe_layers * inactive

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        plen = max(len(self.pattern), 1)
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=len(self.prefix) + plen,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            head_dim=16,
            d_ff=128,
            d_ff_dense=128 if self.d_ff_dense else 0,
            vocab=512,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            d_ff_expert=64 if self.d_ff_expert else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            q_lora_rank=32 if self.q_lora_rank else 0,
            kv_lora_rank=32 if self.kv_lora_rank else 0,
            qk_nope_head_dim=16 if self.qk_nope_head_dim else 0,
            qk_rope_head_dim=8 if self.qk_rope_head_dim else 0,
            v_head_dim=16 if self.v_head_dim else 0,
            n_encoder_layers=min(self.n_encoder_layers, 2),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else None,
            mamba_dt_rank=8 if self.family in ("hybrid", "ssm") else 0,
            rwkv_head_dim=16,
            rwkv_decay_lora=8,
            rwkv_gate_lora=8,
            n_patches=min(self.n_patches, 4) if self.n_patches else 0,
        )


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

_REGISTRY: dict[str, ModelConfig] = {}

# Seed LM architecture cards, quarantined under _unused/: nothing on the
# decoder path imports them, but get_config/list_archs still resolve them
# so the models smoke tests keep running against every registered arch.
_ARCH_MODULES = [
    "_unused.seamless_m4t_medium",
    "_unused.qwen2_5_32b",
    "_unused.minitron_8b",
    "_unused.command_r_35b",
    "_unused.starcoder2_3b",
    "_unused.pixtral_12b",
    "_unused.mixtral_8x22b",
    "_unused.deepseek_v2_236b",
    "_unused.jamba_v0_1_52b",
    "_unused.rwkv6_3b",
]


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def _load_all():
    for mod in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def list_archs() -> list[str]:
    _load_all()
    return sorted(_REGISTRY)
