"""StarCoder2-3B — code model: GQA kv=2, RoPE, sliding window 4096,
ungated GELU MLP, LayerNorm, bias terms.

[arXiv:2402.19173; hf:bigcode/starcoder2-3b; hf-verified]
30L, d_model 3072, 24 heads (GQA kv=2), d_ff 12288, vocab 49152.
"""

from ..base import LayerDesc, ModelConfig, register

STARCODER2_3B = register(
    ModelConfig(
        name="starcoder2-3b",
        family="dense",
        n_layers=30,
        d_model=3072,
        n_heads=24,
        n_kv_heads=2,
        head_dim=128,
        d_ff=12288,
        vocab=49152,
        pattern=(LayerDesc(mixer="gqa", ffn="dense"),),
        qkv_bias=True,
        rope_theta=100_000.0,
        sliding_window=4096,
        ffn_act="gelu",
        norm_type="layernorm",
        norm_eps=1e-5,
        tie_embeddings=True,
        source="arXiv:2402.19173",
    )
)
