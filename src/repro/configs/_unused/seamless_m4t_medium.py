"""SeamlessM4T-medium — encoder-decoder multimodal (speech/text) transformer.

[arXiv:2308.11596; hf:facebook/seamless-m4t-medium; hf-verified]
12L encoder + 12L decoder, d_model 1024, 16 heads (kv=16), d_ff 4096,
vocab 256206. The speech frontend (conformer feature extractor) is a STUB
per the assignment: ``input_specs()`` provides precomputed frame embeddings
for the encoder. Decode shapes run the text decoder with a precomputed
encoder context.
"""

from ..base import LayerDesc, ModelConfig, register

SEAMLESS_M4T_MEDIUM = register(
    ModelConfig(
        name="seamless-m4t-medium",
        family="audio",
        n_layers=12,  # decoder layers
        n_encoder_layers=12,
        encdec=True,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        head_dim=64,
        d_ff=4096,
        vocab=256_206,
        pattern=(LayerDesc(mixer="gqa", ffn="dense"),),
        qkv_bias=True,
        rope_theta=10_000.0,
        ffn_act="gelu",
        norm_type="layernorm",
        norm_eps=1e-5,
        frontend="audio_frames",
        source="arXiv:2308.11596",
    )
)
