"""Command-R 35B — Cohere dense decoder: parallel attn+FFN block, no bias,
tied embeddings, LayerNorm.

[hf:CohereForAI/c4ai-command-r-v01; unverified]
40L, d_model 8192, 64 heads (GQA kv=8), d_ff 22528, vocab 256000.
"""

from ..base import LayerDesc, ModelConfig, register

COMMAND_R_35B = register(
    ModelConfig(
        name="command-r-35b",
        family="dense",
        n_layers=40,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=22528,
        vocab=256_000,
        pattern=(LayerDesc(mixer="gqa", ffn="dense"),),
        qkv_bias=False,
        rope_theta=8_000_000.0,
        ffn_act="swiglu",
        norm_type="layernorm",
        norm_eps=1e-5,
        tie_embeddings=True,
        parallel_block=True,  # x + attn(ln(x)) + ffn(ln(x))
        source="hf:CohereForAI/c4ai-command-r-v01 (unverified)",
    )
)
