"""Pixtral-12B — Mistral-Nemo text backbone + Pixtral ViT frontend (STUBBED).

[hf:mistralai/Pixtral-12B-2409; unverified]
40L, d_model 5120, 32 heads (GQA kv=8, head_dim 128), d_ff 14336,
vocab 131072. The vision frontend is a stub per the assignment:
``input_specs()`` provides precomputed patch embeddings that occupy the
first ``n_patches`` positions of the sequence.
"""

from ..base import LayerDesc, ModelConfig, register

PIXTRAL_12B = register(
    ModelConfig(
        name="pixtral-12b",
        family="vlm",
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab=131072,
        pattern=(LayerDesc(mixer="gqa", ffn="dense"),),
        qkv_bias=False,
        rope_theta=1_000_000.0,
        ffn_act="swiglu",
        norm_type="rmsnorm",
        norm_eps=1e-5,
        frontend="vision_patches",
        n_patches=256,
        source="hf:mistralai/Pixtral-12B-2409 (unverified)",
    )
)
