"""Minitron-8B — width-pruned Nemotron-4 15B (squared-ReLU MLP, no gate).

[arXiv:2407.14679; hf:nvidia/Minitron-8B-Base; hf-verified]
32L, d_model 4096, 48→32 heads (GQA kv=8), d_ff 16384, vocab 256000.
"""

from ..base import LayerDesc, ModelConfig, register

MINITRON_8B = register(
    ModelConfig(
        name="minitron-8b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab=256_000,
        pattern=(LayerDesc(mixer="gqa", ffn="dense"),),
        qkv_bias=False,
        rope_theta=10_000.0,
        ffn_act="relu2",  # nemotron family uses squared ReLU, ungated
        norm_type="layernorm",
        norm_eps=1e-5,
        source="arXiv:2407.14679",
    )
)
