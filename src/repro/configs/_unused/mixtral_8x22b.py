"""Mixtral-8x22B — sparse MoE decoder: 8 experts, top-2 routing, GQA, SWA.

[arXiv:2401.04088; hf:mistralai/Mixtral-8x22B-v0.1; hf-verified]
56L, d_model 6144, 48 heads (GQA kv=8), expert d_ff 16384, vocab 32768.
Sliding-window attention per the assignment spec — this makes ``long_500k``
sub-quadratic (rolling KV cache bounded by the window).
"""

from ..base import LayerDesc, ModelConfig, register

MIXTRAL_8X22B = register(
    ModelConfig(
        name="mixtral-8x22b",
        family="moe",
        n_layers=56,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab=32768,
        pattern=(LayerDesc(mixer="gqa", ffn="moe"),),
        n_experts=8,
        top_k=2,
        d_ff_expert=16384,
        renorm_topk=True,
        sliding_window=4096,
        rope_theta=1_000_000.0,
        ffn_act="swiglu",
        norm_type="rmsnorm",
        norm_eps=1e-5,
        source="arXiv:2401.04088",
    )
)
