"""Quarantined seed model configs — not part of the decoder surface.

These LM architecture cards shipped with the growth seed and are exercised
only by the models smoke tests; nothing on the PBVD decode path imports
them. They live under ``_unused/`` so the coverage/packaging surface of
``repro.configs`` stays decoder-only while ``base.get_config``/``list_archs``
keep resolving every registered arch.
"""
