"""Jamba-v0.1 52B — hybrid Mamba/attention (1:7) with MoE every 2nd layer.

[arXiv:2403.19887; hf:ai21labs/Jamba-v0.1; hf-verified]
32L, d_model 4096, 32 heads (GQA kv=8) on the attention layers,
d_ff 14336, vocab 65536. MoE: 16 experts top-2.
HF config: attn_layer_period=8, attn_layer_offset=4;
expert_layer_period=2, expert_layer_offset=1.
Mamba: d_state 16, d_conv 4, expand 2, dt_rank 256.
``long_500k`` runs: Mamba layers carry O(1) state; the 4 attention layers
use context-parallel KV.
"""

from ..base import LayerDesc, ModelConfig, register

_PATTERN = tuple(
    LayerDesc(
        mixer="gqa" if i % 8 == 4 else "mamba",
        ffn="moe" if i % 2 == 1 else "dense",
    )
    for i in range(8)
)

JAMBA_V0_1_52B = register(
    ModelConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab=65536,
        pattern=_PATTERN,  # repeats 4× to cover 32 layers
        n_experts=16,
        top_k=2,
        d_ff_expert=14336,
        d_ff_dense=14336,
        renorm_topk=True,
        rope_theta=10_000.0,  # jamba attn layers actually use no positional
        ffn_act="swiglu",
        norm_type="rmsnorm",
        norm_eps=1e-6,
        mamba_d_state=16,
        mamba_d_conv=4,
        mamba_expand=2,
        mamba_dt_rank=256,
        source="arXiv:2403.19887",
    )
)
