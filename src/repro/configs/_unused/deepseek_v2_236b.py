"""DeepSeek-V2 236B — MLA (multi-head latent attention) + fine-grained MoE.

[arXiv:2405.04434; hf:deepseek-ai/DeepSeek-V2; hf-verified]
60L, d_model 5120, 128 heads, MLA kv_lora 512 (+64 rope), q_lora 1536,
qk_nope 128, v_head 128. MoE: 160 routed experts top-6 + 2 shared,
expert d_ff 1536; layer 0 uses a dense 12288 FFN. vocab 102400.
"""

from ..base import LayerDesc, ModelConfig, register

DEEPSEEK_V2_236B = register(
    ModelConfig(
        name="deepseek-v2-236b",
        family="moe",
        n_layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,  # MLA: per-head keys reconstructed from the latent
        head_dim=128,
        d_ff=1536,  # routed expert width (assignment lists d_ff=1536)
        vocab=102_400,
        prefix=(LayerDesc(mixer="mla", ffn="dense"),),
        pattern=(LayerDesc(mixer="mla", ffn="moe"),),
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
        n_experts=160,
        n_shared_experts=2,
        top_k=6,
        d_ff_expert=1536,
        d_ff_dense=12288,
        renorm_topk=False,  # deepseek scales by raw softmax probs
        rope_theta=10_000.0,
        ffn_act="swiglu",
        norm_type="rmsnorm",
        norm_eps=1e-6,
        source="arXiv:2405.04434",
    )
)
