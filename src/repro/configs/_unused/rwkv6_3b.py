"""RWKV-6 (Finch) 3B — attention-free RNN with data-dependent decay.

[arXiv:2404.05892; hf:RWKV/rwkv-6-world-3b; hf-verified]
32L, d_model 2560 (40 heads × 64), channel-mix d_ff 8960, vocab 65536.
``long_500k`` runs: O(1) recurrent state per layer.
"""

from ..base import LayerDesc, ModelConfig, register

RWKV6_3B = register(
    ModelConfig(
        name="rwkv6-3b",
        family="ssm",
        n_layers=32,
        d_model=2560,
        n_heads=40,  # d_model / rwkv_head_dim
        n_kv_heads=40,
        head_dim=64,
        d_ff=8960,
        vocab=65536,
        pattern=(LayerDesc(mixer="rwkv6", ffn="dense"),),
        ffn_act="rwkv_cm",  # RWKV channel mixing (relu² keyed FFN + receptance)
        norm_type="layernorm",
        norm_eps=1e-5,
        rwkv_head_dim=64,
        rwkv_decay_lora=64,
        rwkv_gate_lora=64,
        source="arXiv:2404.05892",
    )
)
