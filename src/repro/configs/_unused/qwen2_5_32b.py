"""Qwen2.5-32B — dense GQA decoder with QKV bias.

[hf:Qwen/Qwen2.5-32B; arXiv:2412.15115; hf-verified]
64L, d_model 5120, 40 heads (GQA kv=8), d_ff 27648, vocab 152064.
"""

from ..base import LayerDesc, ModelConfig, register

QWEN2_5_32B = register(
    ModelConfig(
        name="qwen2.5-32b",
        family="dense",
        n_layers=64,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=27648,
        vocab=152064,
        pattern=(LayerDesc(mixer="gqa", ffn="dense"),),
        qkv_bias=True,
        rope_theta=1_000_000.0,
        ffn_act="swiglu",
        norm_type="rmsnorm",
        norm_eps=1e-6,
        source="hf:Qwen/Qwen2.5-32B",
    )
)
