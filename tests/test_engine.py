"""DecoderEngine: backend registry parity and the stateful streaming API.

Acceptance tests for the unified decode path:
  * ref == pallas == fused bit-exact through the engine, across ≥2 codes
    and ≥2 punctured rates;
  * a 100-chunk streaming session decodes bit-exact to the one-shot decode;
  * the legacy wrappers (`decode_stream`) route through the engine unchanged.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.channel import transmit
from repro.core.codespec import get_code_spec
from repro.core.encoder import encode_jax, terminate
from repro.core.engine import DecoderEngine
from repro.core.pbvd import PBVDConfig, decode_stream
from repro.kernels.ops import (
    available_backends,
    backend_start_policies,
    get_backend,
    register_backend,
)


def _tx_stream(name, n, ebn0_db, seed):
    spec = get_code_spec(name)
    rng = np.random.default_rng(seed)
    bits = terminate(rng.integers(0, 2, n), spec.code)
    coded = encode_jax(jnp.asarray(bits), spec.code)
    tx = spec.puncture_stream(coded) if spec.is_punctured else coded
    y = transmit(jax.random.PRNGKey(seed), tx, ebn0_db, spec.rate)
    return spec, bits[:n], y


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_registry_lists_all_backends():
    assert {"ref", "pallas", "fused"} <= set(available_backends())
    with pytest.raises(KeyError):
        get_backend("no-such-backend")


def test_registry_declares_start_policies():
    """Backends advertise the traceback start policies they implement; the
    dispatcher uses this to reject unsupported combos eagerly."""
    assert set(backend_start_policies("ref")) == {"zero", "argmin"}
    assert set(backend_start_policies("pallas")) == {"zero", "argmin"}
    assert backend_start_policies("fused") == ("zero",)
    with pytest.raises(KeyError):
        backend_start_policies("no-such-backend")


def test_registry_rejects_duplicates():
    with pytest.raises(ValueError):
        register_backend("ref")(lambda *a, **k: None)


def test_unknown_backend_through_config():
    # eager: the registry lookup fails at config construction (the knob
    # validation consults the backend's declared contract), not at decode
    with pytest.raises(KeyError, match="unknown decode backend"):
        PBVDConfig(D=64, L=16, q=8, backend="nope")


# ---------------------------------------------------------------------------
# backend parity: 2 codes × (unpunctured + 2 punctured rates) × 3 backends
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("q", [8, None], ids=["int8", "f32"])
@pytest.mark.parametrize(
    "name",
    ["ccsds", "ccsds-2/3", "ccsds-5/6", "is95-k9", "is95-k9-2/3", "is95-k9-5/6"],
)
def test_backend_parity_through_engine(name, q):
    if q is None and name not in ("ccsds", "is95-k9-5/6"):
        pytest.skip("float path covered on a code+rate subsample")
    spec, bits, y = _tx_stream(name, 256, 4.5, seed=2)
    outs = {}
    for backend in ("ref", "pallas", "fused"):
        cfg = PBVDConfig(spec=spec, D=64, L=16, q=q, backend=backend)
        outs[backend] = np.asarray(DecoderEngine(cfg).decode(y, 256))
    np.testing.assert_array_equal(outs["ref"], outs["pallas"])
    np.testing.assert_array_equal(outs["ref"], outs["fused"])


def test_fused_rejects_argmin_start_eagerly():
    """The unsupported policy fails with a clear ValueError BEFORE tracing
    (never a NotImplementedError surfacing from inside jit)."""
    _, _, y = _tx_stream("ccsds", 64, 6.0, 0)
    cfg = PBVDConfig(D=64, L=16, q=8, backend="fused", start_policy="argmin")
    with pytest.raises(ValueError, match="start_policy"):
        DecoderEngine(cfg).decode(y, 64)
    # direct backend callers (bypassing the dispatcher) also fail loudly
    # rather than silently decoding from state 0
    from repro.kernels.registry import FramedBlocks
    from repro.core.trellis import CCSDS_27
    import jax.numpy as jnp

    blocks = FramedBlocks(jnp.zeros((96, 2, 4), jnp.int8), 16, 64)
    with pytest.raises(ValueError):
        get_backend("fused")(
            blocks, CCSDS_27, start_policy="argmin", stage_chunk=64, interpret=True
        )


def test_wrapper_matches_engine():
    spec, bits, y = _tx_stream("ccsds", 512, 4.0, seed=3)
    cfg = PBVDConfig(D=128, L=24, q=8, backend="ref")
    a = np.asarray(decode_stream(y, 512, cfg))
    b = np.asarray(DecoderEngine(cfg).decode(y, 512))
    np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# streaming sessions
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["ccsds", "ccsds-3/4"])
def test_streaming_100_chunks_matches_one_shot(name):
    """A 100-chunk session (random chunk sizes) is bit-exact to one-shot."""
    spec, bits, y = _tx_stream(name, 3200, 4.0, seed=4)
    cfg = PBVDConfig(spec=spec, D=64, L=16, q=8, backend="ref")
    engine = DecoderEngine(cfg)
    ref = np.asarray(engine.decode(y, 3200))

    rng = np.random.default_rng(0)
    ya = np.asarray(y)
    cuts = np.sort(rng.choice(np.arange(1, len(ya)), 99, replace=False))
    parts = np.split(ya, cuts)
    assert len(parts) == 100

    sess = engine.session()
    outs = [sess.decode(c) for c in parts]
    outs.append(sess.finish(3200))
    got = np.concatenate(outs)
    assert got.shape == ref.shape
    np.testing.assert_array_equal(got, ref)
    assert sess.bits_emitted == 3200
    # the session actually streamed: bits were emitted before the last chunk
    assert sum(len(o) for o in outs[:-1]) > 0


def test_streaming_tiny_chunks_and_empty_calls():
    spec, bits, y = _tx_stream("ccsds", 300, 5.0, seed=6)
    cfg = PBVDConfig(spec=spec, D=64, L=16, q=8, backend="ref")
    engine = DecoderEngine(cfg)
    ref = np.asarray(engine.decode(y, 300))
    sess = engine.session()
    ya = np.asarray(y)
    outs = []
    for i in range(len(ya)):  # one symbol-row at a time
        outs.append(sess.decode(ya[i : i + 1]))
    outs.append(sess.decode(ya[:0]))  # empty chunk is a no-op
    outs.append(sess.finish(300))
    np.testing.assert_array_equal(np.concatenate(outs), ref)


def test_streaming_punctured_phase_carries_across_chunks():
    """Odd chunk sizes slice puncture periods mid-stage; the carried phase
    must still reassemble the exact depunctured stream."""
    spec, bits, y = _tx_stream("ccsds-5/6", 1280, 5.0, seed=7)
    cfg = PBVDConfig(spec=spec, D=64, L=16, q=8, backend="ref")
    engine = DecoderEngine(cfg)
    ref = np.asarray(engine.decode(y, 1280))
    sess = engine.session()
    ya = np.asarray(y)
    outs, i = [], 0
    sizes = [1, 2, 3, 5, 7, 11, 13]  # deliberately misaligned with the period
    k = 0
    while i < len(ya):
        n = sizes[k % len(sizes)]
        outs.append(sess.decode(ya[i : i + n]))
        i += n
        k += 1
    outs.append(sess.finish(1280))
    np.testing.assert_array_equal(np.concatenate(outs), ref)


def test_streaming_prequantized_int_chunks_match_one_shot():
    """Integer chunks are pre-quantized: the session must not re-quantize
    them (bit-exact vs engine.decode on the same int8 stream)."""
    from repro.core.quantize import quantize_soft

    spec, bits, y = _tx_stream("ccsds", 1024, 4.0, seed=9)
    cfg = PBVDConfig(spec=spec, D=64, L=16, q=8, backend="ref")
    engine = DecoderEngine(cfg)
    yq = np.asarray(quantize_soft(y, 8))  # int8
    ref = np.asarray(engine.decode(jnp.asarray(yq), 1024))
    sess = engine.session()
    outs = [sess.decode(c) for c in np.array_split(yq, 7)]
    outs.append(sess.finish(1024))
    np.testing.assert_array_equal(np.concatenate(outs), ref)
    # mixing float chunks into an integer session is rejected
    with pytest.raises(ValueError):
        sess.decode(np.zeros((4, 2), np.float32))


def test_streaming_punctured_rejects_full_rate_chunks():
    """Punctured sessions consume the 1-D wire format only; a full-rate
    chunk would desynchronize the carried puncture phase."""
    spec = get_code_spec("ccsds-3/4")
    cfg = PBVDConfig(spec=spec, D=64, L=16, q=8, backend="ref")
    sess = DecoderEngine(cfg).session()
    with pytest.raises(ValueError):
        sess.decode(np.zeros((8, 2), np.float32))


def test_streaming_session_is_reusable_via_fresh_sessions():
    spec, bits, y = _tx_stream("ccsds", 256, 5.0, seed=8)
    cfg = PBVDConfig(spec=spec, D=64, L=16, q=8, backend="ref")
    engine = DecoderEngine(cfg)
    ref = np.asarray(engine.decode(y, 256))
    for _ in range(2):  # sessions are independent; engine is stateless
        sess = engine.session()
        out = np.concatenate([sess.decode(np.asarray(y)), sess.finish(256)])
        np.testing.assert_array_equal(out, ref)
