"""BER regression band: CCSDS ref decode at Eb/N0 = 4 dB.

Guards against silent metric/tie-break regressions that no equivalence test
can see (all backends would drift together). The seed's measured curve at
4 dB, paper geometry (D=512, L=42, q=8):

    soft-decision (8-bit)   ≈ 0          (0 errors / 32768 bits; true ~1e-5)
    hard-decision (sign)    ≈ 3.5–4.3e-3
    uncoded BPSK            = 1.25e-2

A metric regression (wrong BM sign, broken tie-break, quantizer clipping)
drags the soft curve toward the hard/uncoded levels — orders of magnitude
above the band asserted here. The fixed PRNG keys keep the run
deterministic, so the band is tight without flaking.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.core.ber import simulate_ber, uncoded_ber
from repro.core.channel import transmit
from repro.core.encoder import encode_jax
from repro.core.engine import DecoderEngine
from repro.core.pbvd import PBVDConfig

GEOMETRY = dict(D=512, L=42, backend="ref")


def _hard_decision_ber(seed: int, n_bits: int) -> float:
    """Hard-decision (sign-only) Viterbi BER at 4 dB — the upper curve."""
    cfg = PBVDConfig(q=None, **GEOMETRY)
    engine = DecoderEngine(cfg)
    key, kb, kn = jax.random.split(jax.random.PRNGKey(seed), 3)
    bits = jax.random.bernoulli(kb, 0.5, (n_bits,)).astype(jnp.int32)
    bits_t = jnp.concatenate([bits, jnp.zeros(cfg.code.v, jnp.int32)])
    y = transmit(kn, encode_jax(bits_t, cfg.code), 4.0, 0.5)
    dec = engine.decode(jnp.sign(y), n_bits + cfg.code.v)[:n_bits]
    return float(jnp.mean(dec != bits))


@pytest.mark.tier1
def test_ber_4db_smoke():
    """Tier-1 smoke: small sample, loose band (seed soft BER is 0 here)."""
    n_bits = 1 << 13
    soft = simulate_ber(jax.random.PRNGKey(0), 4.0, PBVDConfig(q=8, **GEOMETRY), n_bits=n_bits)
    assert soft <= 1.3e-3, f"soft-decision BER regressed: {soft:.2e}"
    hard = _hard_decision_ber(0, n_bits)
    assert 2e-4 <= hard <= 1.2e-2, f"hard-decision BER out of band: {hard:.2e}"
    assert soft < hard, "soft decoding must beat hard decoding at 4 dB"


@pytest.mark.slow
def test_ber_4db_full_band():
    """Full regression band at the seed's sample size (32768 bits)."""
    n_bits = 1 << 15
    cfg = PBVDConfig(q=8, **GEOMETRY)
    soft = simulate_ber(jax.random.PRNGKey(0), 4.0, cfg, n_bits=n_bits)
    # the seed measures 0 errors; 10 errors (3e-4) is far outside noise for
    # a correct decoder and far below any metric regression
    assert soft <= 3e-4, f"soft-decision BER regressed: {soft:.2e}"
    hard = _hard_decision_ber(0, n_bits)
    assert 1e-3 <= hard <= 1e-2, f"hard-decision BER out of band: {hard:.2e}"
    # the gap IS the curve shape: soft ≪ hard < uncoded
    assert soft < hard < uncoded_ber(4.0)
