"""Serving-layer suite: pool lifecycle, paged slabs, async dispatch.

Four layers, matching DESIGN.md §13:

* slab allocator + paged session store (free-list recycling, exhaustion,
  page-boundary reads, bit-exactness of a slab-backed session);
* SessionPool lifecycle (the PR's bugfix sweep): finish-before-step,
  finish folding undrained step() output, pooled-vs-solo finish
  bit-identity for every non-block-aligned tail across the golden CodeSpec
  set × all metric modes, idempotent close, mesh pins that survive id
  reuse after GC;
* deadline-or-size dispatch determinism under a fake clock (no sleeps, no
  background task — the trigger is a pure function of the injected clock);
* admission control: bounded queues block (or raise in non-blocking mode)
  instead of growing, slab exhaustion maps to backpressure, and the
  64-stream Poisson trace decodes bit-exactly vs one-shot ``decode()`` no
  matter how the event loop interleaves it.
"""

import asyncio
import gc

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.channel import transmit
from repro.core.codespec import available_code_specs, get_code_spec
from repro.core.encoder import encode_jax, terminate
from repro.core.engine import ArraySessionStore, DecoderEngine
from repro.core.pbvd import PBVDConfig
from repro.launch.serve_async import (
    AsyncDecodeService,
    Backpressure,
    DeadlineBatcher,
    run_poisson_trace,
)
from repro.launch.serve_decoder import SessionPool, _latency_summary
from repro.launch.slab import PagedSessionStore, SlabExhausted, SymbolSlab

GEOM = dict(D=64, L=16, q=8)


def _tx_stream(name: str, n_bits: int, ebn0: float, seed: int):
    spec = get_code_spec(name)
    rng = np.random.default_rng(seed)
    payload = rng.integers(0, 2, n_bits)
    coded = encode_jax(jnp.asarray(terminate(payload, spec.code)), spec.code)
    tx = spec.puncture_stream(coded) if spec.is_punctured else coded
    y = np.asarray(transmit(jax.random.PRNGKey(seed), tx, ebn0, spec.rate))
    return spec, payload, y


def _engine(spec, metric_mode="f32", **overrides):
    kw = dict(GEOM)
    kw.update(overrides)
    return DecoderEngine(
        PBVDConfig(spec=spec, backend="ref", metric_mode=metric_mode, **kw)
    )


class FakeClock:
    def __init__(self):
        self.t = 100.0

    def now(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


# ---------------------------------------------------------------------------
# SymbolSlab + PagedSessionStore
# ---------------------------------------------------------------------------
@pytest.mark.tier1
def test_slab_alloc_free_recycles_lifo_and_zeroes():
    slab = SymbolSlab(n_pages=3, page_stages=4, R=2)
    a, b = slab.alloc(), slab.alloc()
    assert slab.pages_in_use == 2 and slab.high_water == 2
    slab._data[a] = 7.0  # dirty it
    slab.free(a)
    assert slab.pages_free == 2
    c = slab.alloc()  # LIFO: the just-freed page comes back first
    assert c == a
    assert np.all(slab._data[c] == 0.0)  # zeroed on free → BM-neutral alloc
    with pytest.raises(ValueError, match="double free"):
        slab.free(b)
        slab.free(b)
    with pytest.raises(ValueError):
        SymbolSlab(0, 4, 2)


@pytest.mark.tier1
def test_slab_exhaustion_is_explicit():
    slab = SymbolSlab(n_pages=2, page_stages=8, R=2)
    store = slab.open_store()
    store.append(np.ones((16, 2)))  # fills both pages
    with pytest.raises(SlabExhausted):
        store.append(np.ones((1, 2)))
    store.drop_prefix(8)  # retire one page back to the free-list
    store.append(np.ones((8, 2)))  # recycled page absorbs the growth
    assert slab.pages_in_use == 2


@pytest.mark.tier1
def test_paged_store_matches_array_store_reference():
    """Randomized append/grow/scatter/read/drop: the paged store is
    observationally identical to the contiguous reference store."""
    rng = np.random.default_rng(3)
    slab = SymbolSlab(n_pages=64, page_stages=5, R=3)  # odd page size on purpose
    paged, ref = slab.open_store(), ArraySessionStore(3)
    for _ in range(300):
        op = rng.integers(0, 4)
        if op == 0:
            rows = rng.normal(size=(int(rng.integers(0, 12)), 3)).astype(np.float32)
            paged.append(rows)
            ref.append(rows)
        elif op == 1:
            n = int(rng.integers(0, 7))
            paged.grow(n)
            ref.grow(n)
        elif op == 2 and len(ref):
            k = int(rng.integers(1, 5))
            si = rng.integers(0, len(ref), k)
            sj = rng.integers(0, 3, k)
            v = rng.normal(size=k).astype(np.float32)
            paged.scatter(si, sj, v)
            ref.scatter(si, sj, v)
        elif op == 3 and len(ref):
            n = int(rng.integers(0, len(ref) + 1))
            paged.drop_prefix(n)
            ref.drop_prefix(n)
        assert len(paged) == len(ref)
        lo = int(rng.integers(0, len(ref) + 1))
        n = int(rng.integers(0, len(ref) - lo + 3))  # deliberately over-reads
        np.testing.assert_array_equal(paged.read(lo, n), ref.read(lo, n))
    paged.close()
    assert slab.pages_in_use == 0
    with pytest.raises(ValueError, match="closed"):
        paged.append(np.zeros((1, 3)))
    paged.close()  # idempotent


@pytest.mark.tier1
def test_slab_backed_session_bit_exact_and_releases_pages():
    spec, _, y = _tx_stream("ccsds-3/4", 512, 4.5, 9)
    eng = _engine(spec)
    ref = np.asarray(eng.decode(jnp.asarray(y), 512))
    slab = SymbolSlab(n_pages=32, page_stages=GEOM["D"] + 2 * GEOM["L"], R=spec.code.R)
    sess = eng.session(store=slab.open_store())
    rng = np.random.default_rng(0)
    out, pos = [], 0
    while pos < len(y):
        n = int(rng.integers(1, 150))
        out.append(sess.decode(y[pos : pos + n]))
        pos += n
    out.append(sess.finish(512))
    np.testing.assert_array_equal(np.concatenate(out), ref)
    assert slab.high_water > 0
    sess.close()
    assert slab.pages_in_use == 0  # every page back on the free-list


# ---------------------------------------------------------------------------
# SessionPool lifecycle: the finish paths
# ---------------------------------------------------------------------------
@pytest.mark.tier1
@pytest.mark.parametrize("name", available_code_specs())
@pytest.mark.parametrize("metric_mode", ["f32", "i16", "i8"])
def test_pooled_finish_bit_identical_to_solo_ragged_tails(name, metric_mode):
    """Acceptance: PooledSession.finish ≡ DecoderSession.finish for every
    non-block-aligned tail in the golden CodeSpec set, every metric mode."""
    spec, _, y = _tx_stream(name, 300, 4.5, 21)
    eng = _engine(spec, metric_mode=metric_mode)
    D = GEOM["D"]
    for n_bits in (300, 299, 257, 2 * D + 1, 2 * D - 1, 97):
        solo = eng.session()
        solo.ingest(y)
        a = solo.finish(n_bits)
        pool = SessionPool()
        h = pool.open(eng)
        h.feed(y)
        b = h.finish(n_bits)
        np.testing.assert_array_equal(a, b)
        assert len(a) == n_bits
        # and both equal the one-shot decode of the same stream
        np.testing.assert_array_equal(
            a, np.asarray(eng.decode(jnp.asarray(y), n_bits))
        )


@pytest.mark.tier1
def test_pooled_finish_before_step_and_interleaved_steps():
    spec, _, y = _tx_stream("ccsds", 400, 4.5, 4)
    eng = _engine(spec)
    ref = np.asarray(eng.decode(jnp.asarray(y), 400))

    # finish before any step: the flush is the only launch
    pool = SessionPool()
    h = pool.open(eng)
    h.feed(y)
    np.testing.assert_array_equal(h.finish(400), ref)
    assert h.bits_emitted == 400

    # feed/step/feed/finish with takes in between
    pool = SessionPool()
    h = pool.open(eng)
    h.feed(y[:300])
    pool.step()
    part = h.take()
    h.feed(y[300:])
    pool.step()
    out = np.concatenate([part, h.take(), h.finish(400)])
    np.testing.assert_array_equal(out, ref)


@pytest.mark.tier1
def test_pooled_finish_folds_undrained_queue():
    """finish() without a prior take() must deliver the queued step() output
    instead of silently dropping it (the old docstring caveat)."""
    spec, _, y = _tx_stream("ccsds", 400, 4.5, 5)
    eng = _engine(spec)
    ref = np.asarray(eng.decode(jnp.asarray(y), 400))
    pool = SessionPool()
    h = pool.open(eng)
    h.feed(y)
    assert pool.step() > 0  # blocks decoded and queued on the session
    out = h.finish(400)  # NO take() first — finish folds the queue
    np.testing.assert_array_equal(out, ref)
    assert len(h.take()) == 0  # nothing left behind
    assert h.bits_emitted == 400


# ---------------------------------------------------------------------------
# SessionPool lifecycle: open/close
# ---------------------------------------------------------------------------
@pytest.mark.tier1
def test_pool_close_is_idempotent():
    spec, _, y = _tx_stream("ccsds", 128, 5.0, 6)
    eng = _engine(spec)
    pool = SessionPool()
    h = pool.open(eng)
    pool.close(h)
    pool.close(h)  # second close: no ValueError, no state corruption
    assert len(pool) == 0
    h2 = pool.open(eng)
    pool.close(h2)
    pool.close(h)  # stale handle close after reuse: still a no-op
    assert len(pool) == 0 and not pool._mesh_refs


@pytest.mark.tier1
def test_pool_mesh_pin_released_once_and_survives_id_reuse():
    """The mesh pin is keyed by the member OBJECT: a closed member's GC'd
    id being reused by a new member can neither drop nor double-release a
    pin (the old ``id(ps)`` key could)."""
    from jax.sharding import Mesh

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    spec = get_code_spec("ccsds")
    eng = DecoderEngine(
        PBVDConfig(spec=spec, backend="ref", **GEOM), mesh=mesh, block_axes=("data",)
    )
    pool = SessionPool()
    h1 = pool.open(eng)
    assert len(pool._mesh_refs) == 1
    pool.close(h1)
    assert len(pool._mesh_refs) == 0
    pool.close(h1)  # double close: pin already released, exactly once
    assert len(pool._mesh_refs) == 0
    del h1
    gc.collect()
    # new members after the old id is reusable: pins track exactly the live
    # membership, keyed by the member objects themselves
    handles = [pool.open(eng) for _ in range(4)]
    assert set(pool._mesh_refs) == set(handles)
    assert all(m is mesh for m in pool._mesh_refs.values())
    for h in handles:
        pool.close(h)
    assert len(pool._mesh_refs) == 0


@pytest.mark.tier1
def test_pool_open_partial_failure_leaves_no_state():
    """A failure while registering a new member rolls the pool back to a
    clean state — no orphan member, no leaked mesh pin."""
    from jax.sharding import Mesh

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    spec = get_code_spec("ccsds")
    eng = DecoderEngine(
        PBVDConfig(spec=spec, backend="ref", **GEOM), mesh=mesh, block_axes=("data",)
    )
    pool = SessionPool()

    class ExplodingDict(dict):
        def __setitem__(self, k, v):
            raise RuntimeError("registration failed")

    pool._mesh_refs = ExplodingDict()
    with pytest.raises(RuntimeError, match="registration failed"):
        pool.open(eng)
    assert len(pool) == 0 and len(pool._mesh_refs) == 0


# ---------------------------------------------------------------------------
# Deadline-or-size dispatch: deterministic under a fake clock
# ---------------------------------------------------------------------------
@pytest.mark.tier1
def test_deadline_batcher_fake_clock_determinism():
    clk = FakeClock()
    b = DeadlineBatcher(max_batch_blocks=4, deadline_s=0.010, clock=clk.now)
    assert not b.due(0) and b.timeout() is None  # nothing pending, nothing armed
    b.note_feed()
    assert b.timeout() == pytest.approx(0.010)
    assert not b.due(1)  # below size, before deadline
    clk.advance(0.0099)
    assert not b.due(3)
    clk.advance(0.0001)
    assert b.due(1)  # exactly at the deadline
    assert b.due(4) and b.due(9)  # size trigger holds regardless
    b.fired()
    assert b.timeout() is None and not b.due(1)  # arm cleared by dispatch
    b.note_feed()
    b.note_feed()  # later feeds do not push the oldest arrival back
    assert b.timeout() == pytest.approx(0.010)
    assert b.due(4)  # size trigger is immediate even with a fresh arm
    with pytest.raises(ValueError):
        DeadlineBatcher(0, 1.0)
    with pytest.raises(ValueError):
        DeadlineBatcher(1, -1.0)


@pytest.mark.tier1
def test_service_dispatch_deadline_determinism_fake_clock():
    """Drive the service's poll() by hand under a fake clock: the dispatch
    sequence and every recorded chunk latency are exact numbers."""
    spec, _, y = _tx_stream("ccsds", 256, 4.5, 8)
    eng = _engine(spec)
    ref = np.asarray(eng.decode(jnp.asarray(y), 256))
    clk = FakeClock()

    async def scenario():
        svc = AsyncDecodeService(
            max_batch_blocks=1000,  # size trigger out of the way
            deadline_ms=10.0,
            max_pending_blocks=10_000,
            clock=clk.now,
        )  # NOT started: poll() is driven manually, no background task
        stream = svc.open(eng)
        await stream.send(y[: len(y) // 2])  # completes ≥ 1 block
        assert svc.poll() is False  # deadline not yet reached
        clk.advance(0.009)
        assert svc.poll() is False
        clk.advance(0.001)
        assert svc.poll() is True  # fires exactly at the 10 ms deadline
        assert svc.dispatches == 1
        assert svc.poll() is False  # nothing ready → no spurious dispatch
        clk.advance(5.0)
        assert svc.poll() is False  # deadline arm was cleared by the fire
        await stream.send(y[len(y) // 2 :])
        clk.advance(0.010)
        assert svc.poll() is True
        clk.advance(0.003)
        # take() was never called, so finish folds both dispatches' queued
        # bits plus the flushed tail — the whole stream comes back here
        return await stream.finish(256), svc

    out, svc = asyncio.run(scenario())
    np.testing.assert_array_equal(out, ref)
    m = svc.metrics()
    assert m["dispatches"] == 2
    assert m["chunks"] == 2
    assert m["p50_ms"] is not None and m["p99_ms"] is not None
    # latencies are exact fake-clock deltas — the accounting is
    # deterministic, not wall-clock-dependent
    lats = sorted(round(t, 6) for t in svc._latencies_s)
    assert lats[0] == pytest.approx(0.013)  # chunk 2: resolved at finish
    assert lats[1] == pytest.approx(5.020)  # chunk 1: idle gap + 2nd deadline


@pytest.mark.tier1
def test_service_metrics_guard_small_samples():
    svc = AsyncDecodeService(max_batch_blocks=1, deadline_ms=1.0)
    m = svc.metrics()
    assert m["chunks"] == 0
    assert m["p50_ms"] is None and m["p99_ms"] is None and m["sustained_mbps"] is None
    assert _latency_summary([]) == "no latency samples"
    assert "p99≈max" in _latency_summary([1.0, 2.0])
    assert "p99≈max" not in _latency_summary(list(range(50)))


# ---------------------------------------------------------------------------
# Backpressure: bounded admission
# ---------------------------------------------------------------------------
@pytest.mark.tier1
def test_backpressure_raises_in_nonblocking_mode():
    spec, _, y = _tx_stream("ccsds", 512, 4.5, 12)
    eng = _engine(spec)

    async def scenario():
        svc = AsyncDecodeService(
            max_batch_blocks=1000,
            deadline_ms=0.0,  # manual poll() is due as soon as anything is pending
            max_pending_blocks=2,
            block_on_backpressure=False,
        )
        stream = svc.open(eng)
        await stream.send(y[:300])  # ≥ 2 blocks ready → at the cap
        assert svc._pool.pending_blocks() >= 2
        with pytest.raises(Backpressure, match="pending-block cap"):
            await stream.send(y[300:])
        # a dispatch drains the pool; admission opens again
        assert svc.poll() is True
        await stream.send(y[300:])
        return np.concatenate([stream.take(), await stream.finish(512)])

    out = asyncio.run(scenario())
    np.testing.assert_array_equal(out, np.asarray(eng.decode(jnp.asarray(y), 512)))


@pytest.mark.tier1
def test_backpressure_blocks_sender_until_dispatch():
    """In blocking mode the bounded queue parks the sender instead of
    growing: the send only completes after a dispatch frees capacity."""
    spec, _, y = _tx_stream("ccsds", 512, 4.5, 13)
    eng = _engine(spec)

    async def scenario():
        svc = AsyncDecodeService(
            max_batch_blocks=1000,
            deadline_ms=0.0,  # manual poll() is due as soon as anything is pending
            max_pending_blocks=2,
        )
        stream = svc.open(eng)
        await stream.send(y[:300])
        blocked = asyncio.ensure_future(stream.send(y[300:]))
        for _ in range(5):
            await asyncio.sleep(0)
        assert not blocked.done()  # parked on the cap, not queued unboundedly
        assert svc.poll() is True  # manual dispatch (service not started)
        await asyncio.wait_for(blocked, timeout=5)
        return np.concatenate([stream.take(), await stream.finish(512)])

    out = asyncio.run(scenario())
    np.testing.assert_array_equal(out, np.asarray(eng.decode(jnp.asarray(y), 512)))


@pytest.mark.tier1
def test_slab_exhaustion_backpressure_and_hopeless_admit():
    spec, _, y = _tx_stream("ccsds", 512, 4.5, 14)
    eng = _engine(spec)
    T = GEOM["D"] + 2 * GEOM["L"]

    async def scenario():
        # 4 pages: exactly one stream's full-slab working set
        slab = SymbolSlab(n_pages=4, page_stages=T, R=spec.code.R)
        svc = AsyncDecodeService(
            max_batch_blocks=1000,
            deadline_ms=0.0,  # manual poll() is due as soon as anything is pending
            slab=slab,
            block_on_backpressure=False,
        )
        stream = svc.open(eng)
        await stream.send(y[: 4 * T])  # fills the slab exactly
        with pytest.raises(Backpressure, match="slab pages"):
            # pages can only come back via a dispatch; non-blocking mode
            # maps the allocator's exhaustion to admission refusal
            await stream.send(y[4 * T :])
        assert svc.poll() is True  # decode → commit → pages freed
        await stream.send(y[4 * T :])  # recycled pages absorb the retry
        bits = np.concatenate([stream.take(), await stream.finish(512)])
        assert slab.pages_in_use == 0  # finish released the stream's pages
        return bits

    out = asyncio.run(scenario())
    np.testing.assert_array_equal(out, np.asarray(eng.decode(jnp.asarray(y), 512)))

    async def hopeless():
        # a chunk bigger than the whole slab can never be admitted: that
        # must raise even in blocking mode rather than deadlock
        slab = SymbolSlab(n_pages=1, page_stages=8, R=spec.code.R)
        svc = AsyncDecodeService(max_batch_blocks=1000, deadline_ms=0.0, slab=slab)
        stream = svc.open(eng)
        with pytest.raises(SlabExhausted):
            await stream.send(y[:300])

    asyncio.run(hopeless())


# ---------------------------------------------------------------------------
# The acceptance trace: 64 Poisson streams, bit-exact
# ---------------------------------------------------------------------------
@pytest.mark.tier1
def test_async_service_64_stream_poisson_bit_exact():
    """64 concurrent streams under Poisson arrivals through the full stack
    (admission → slab paging → deadline dispatch → delivery) decode
    bit-exactly vs per-stream one-shot ``decode()``."""
    S, n_bits = 64, 256
    spec = get_code_spec("ccsds")
    eng = _engine(spec)
    payloads, ys = [], []
    for i in range(S):
        _, p, y = _tx_stream("ccsds", n_bits, 4.5, 40 + i)
        payloads.append(p)
        ys.append(y)
    refs = [np.asarray(eng.decode(jnp.asarray(y), n_bits)) for y in ys]
    T = GEOM["D"] + 2 * GEOM["L"]
    slab = SymbolSlab(n_pages=6 * S, page_stages=T, R=spec.code.R)
    bits, report = asyncio.run(
        run_poisson_trace(
            eng,
            ys,
            [n_bits] * S,
            chunk_symbols=100,
            rate_chunks_per_s=5000.0,
            seed=3,
            slab=slab,
            service_kwargs=dict(max_batch_blocks=64, deadline_ms=2.0),
        )
    )
    for b, r in zip(bits, refs):
        np.testing.assert_array_equal(b, r)
    assert report["chunks"] == sum(-(-len(y) // 100) for y in ys)
    assert report["bits_delivered"] == S * n_bits
    assert report["p50_ms"] is not None
    assert 0 < report["slab_pages_high_water"] <= slab.n_pages
    assert slab.pages_in_use == 0  # every stream's pages returned
    # the dispatcher coalesced: far fewer pool steps than chunks
    assert report["dispatches"] < report["chunks"]
