"""Mesh-bound engine semantics that need no multi-device runtime.

The real N-chip behavior is exercised by ``tests/test_distributed.py``
(subprocess, 8 forced host devices, ``slow``); this module keeps the mesh
code paths — shard-aware lane budgeting, both dispatch modes, eager
validation, pool grouping by mesh *content* — inside the tier-1 gate with
trivial single-device meshes (the sharding is degenerate, the code path is
not).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.channel import transmit
from repro.core.codespec import get_code_spec
from repro.core.encoder import encode_jax, terminate
from repro.core.engine import DecoderEngine, _pow2_at_least
from repro.core.pbvd import PBVDConfig, decode_stream_sharded
from repro.kernels.ops import check_mesh_launch
from repro.launch.mesh import make_decode_mesh, make_local_mesh, parse_mesh_spec
from repro.launch.serve_decoder import SessionPool
from repro.sharding.rules import block_mesh_axes


def _tx(name, n, seed, ebn0=4.5):
    spec = get_code_spec(name)
    rng = np.random.default_rng(seed)
    bits = terminate(rng.integers(0, 2, n), spec.code)
    coded = encode_jax(jnp.asarray(bits), spec.code)
    tx = spec.puncture_stream(coded) if spec.is_punctured else coded
    return transmit(jax.random.PRNGKey(seed), tx, ebn0, spec.rate)


def _mesh1(axes=("data",)):
    from jax.sharding import Mesh

    return Mesh(np.asarray(jax.devices()[:1]).reshape((1,) * len(axes)), axes)


# ---------------------------------------------------------------------------
# shard-aware lane budget
# ---------------------------------------------------------------------------
def test_lane_budget_is_pow2_without_mesh():
    eng = DecoderEngine(PBVDConfig(backend="ref"))
    for n in (1, 2, 3, 5, 8, 17, 100):
        assert eng._lane_budget(n) == _pow2_at_least(n)


def test_lane_budget_folds_shard_rounding_into_one_bounded_pad():
    """budget = lcm(pow2, n_shards): divisible by the shard count AND drawn
    from a log-bounded shape set — never pow2-then-pad-again."""
    eng = DecoderEngine(PBVDConfig(backend="ref"), mesh=_mesh1())
    eng.n_shards = 6  # non-power-of-two fleet, as if on 6 chips
    budgets = {n: eng._lane_budget(n) for n in range(1, 65)}
    assert all(b % 6 == 0 for b in budgets.values())
    assert all(b >= n for n, b in budgets.items())
    # one budget per pow2 bracket: 64 fleet sizes collapse to ~log shapes
    assert len(set(budgets.values())) <= 7
    assert budgets[5] == 24  # lcm(8, 6)
    eng.n_shards = 8
    assert eng._lane_budget(5) == 8  # pow2 shard counts change nothing


# ---------------------------------------------------------------------------
# mesh-bound decode parity (degenerate 1-device mesh, real code path)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dispatch", ["constraint", "shard_map"])
def test_mesh_engine_matches_unsharded_both_dispatches(dispatch):
    spec = get_code_spec("ccsds")
    cfg = PBVDConfig(spec=spec, D=64, L=16, q=8, backend="ref")
    base = DecoderEngine(cfg)
    eng = DecoderEngine(cfg, mesh=_mesh1(), shard_dispatch=dispatch)
    assert eng.n_shards == 1 and eng.block_axes == ("data",)
    lens = [96, 190, 96]
    ys = [_tx("ccsds", n, 40 + i) for i, n in enumerate(lens)]
    # one-shot
    np.testing.assert_array_equal(
        np.asarray(base.decode(ys[0], lens[0])),
        np.asarray(eng.decode(ys[0], lens[0])),
    )
    # batched (ragged fleet)
    for a, b in zip(base.decode_batch(ys, lens), eng.decode_batch(ys, lens)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # streaming session on the mesh engine
    sess = eng.session()
    got = np.concatenate([sess.decode(np.asarray(ys[1])), sess.finish(lens[1])])
    np.testing.assert_array_equal(got, np.asarray(base.decode(ys[1], lens[1])))


def test_decode_stream_sharded_dispatch_passthrough():
    spec = get_code_spec("ccsds")
    cfg = PBVDConfig(spec=spec, D=64, L=16, q=8, backend="ref")
    y = _tx("ccsds", 128, 3)
    ref = np.asarray(DecoderEngine(cfg).decode(y, 128))
    for dispatch in ("constraint", "shard_map"):
        out = decode_stream_sharded(
            y, 128, cfg, _mesh1(), block_axes=None, shard_dispatch=dispatch
        )
        np.testing.assert_array_equal(ref, np.asarray(out))


# ---------------------------------------------------------------------------
# eager validation + rules resolution
# ---------------------------------------------------------------------------
def test_check_mesh_launch_rejects_bad_bindings_eagerly():
    mesh = _mesh1(("data", "model"))
    assert check_mesh_launch(mesh, ("data",), "ref") == 1
    assert check_mesh_launch(mesh, ("data", "model"), "ref") == 1
    with pytest.raises(ValueError, match="not in mesh axes"):
        check_mesh_launch(mesh, ("pod",), "ref")
    with pytest.raises(ValueError, match="repeats"):
        check_mesh_launch(mesh, ("data", "data"), "ref")
    with pytest.raises(ValueError, match="at least one"):
        check_mesh_launch(mesh, (), "ref")
    with pytest.raises(ValueError, match="shard dispatch"):
        check_mesh_launch(mesh, ("data",), "ref", dispatch="pjit")
    with pytest.raises(KeyError):
        check_mesh_launch(mesh, ("data",), "no_such_backend")
    # the engine runs the same check at CONSTRUCTION, not at first decode
    with pytest.raises(ValueError, match="not in mesh axes"):
        DecoderEngine(PBVDConfig(backend="ref"), mesh=mesh, block_axes=("pod",))


def test_block_axes_resolve_from_logical_rules():
    assert block_mesh_axes(_mesh1(("data", "model"))) == ("data",)
    assert block_mesh_axes(_mesh1(("pod", "data", "model"))) == ("pod", "data")
    with pytest.raises(ValueError, match="blocks"):
        block_mesh_axes(_mesh1(("model",)))
    eng = DecoderEngine(
        PBVDConfig(backend="ref"), mesh=_mesh1(("data", "model")), block_axes=None
    )
    assert eng.block_axes == ("data",)


# ---------------------------------------------------------------------------
# launch/mesh.py helpers
# ---------------------------------------------------------------------------
def test_parse_mesh_spec():
    assert parse_mesh_spec("data=8") == (("data",), (8,))
    assert parse_mesh_spec("pod=2, data=4") == (("pod", "data"), (2, 4))
    for bad in ("", "data", "data=0", "data=x", "data=2,data=4", "=4"):
        with pytest.raises(ValueError, match="mesh spec"):
            parse_mesh_spec(bad)


def test_make_decode_mesh_single_device():
    mesh = make_decode_mesh("data=1")
    assert dict(mesh.shape) == {"data": 1}
    with pytest.raises(ValueError, match="devices"):
        make_decode_mesh(f"data={len(jax.devices()) + 1}")


def test_make_local_mesh_rejects_bad_shapes():
    n = len(jax.devices())
    with pytest.raises(ValueError, match="does not divide"):
        make_local_mesh(model=n + 1)
    with pytest.raises(ValueError, match=">= 1"):
        make_local_mesh(model=0)
    with pytest.raises(ValueError, match="devices"):
        make_local_mesh(data=n + 1, model=1)
    mesh = make_local_mesh()
    assert dict(mesh.shape) == {"data": n, "model": 1}


# ---------------------------------------------------------------------------
# SessionPool grouping on mesh content (the _group_key regression)
# ---------------------------------------------------------------------------
def test_session_pool_splits_same_mesh_different_block_axes():
    """Two sessions on the SAME mesh but different lane-axis bindings used
    to coalesce (the key ignored block_axes) and decode with the lead's
    layout; they must launch separately."""
    mesh = _mesh1(("data", "model"))
    cfg = PBVDConfig(spec=get_code_spec("ccsds"), D=64, L=16, q=8, backend="ref")
    eng_data = DecoderEngine(cfg, mesh=mesh, block_axes=("data",))
    eng_model = DecoderEngine(cfg, mesh=mesh, block_axes=("model",))
    y = np.asarray(_tx("ccsds", 256, 5))
    pool = SessionPool()
    hd, hm = pool.open(eng_data), pool.open(eng_model)
    hd.feed(y)
    hm.feed(y)
    pool.step()
    assert pool.launches == 2
    ref = np.asarray(DecoderEngine(cfg).decode(jnp.asarray(y), 256))
    for h in (hd, hm):
        np.testing.assert_array_equal(np.concatenate([h.take(), h.finish(256)]), ref)


def test_session_pool_coalesces_equal_content_meshes_and_pins_them():
    """Distinct mesh OBJECTS with identical content are one launch group
    (the old ``id(mesh)`` key split them; worse, id reuse after GC could
    merge *different* meshes). The pool pins each pooled mesh strongly."""
    cfg = PBVDConfig(spec=get_code_spec("ccsds"), D=64, L=16, q=8, backend="ref")
    eng_a = DecoderEngine(cfg, mesh=_mesh1())
    eng_b = DecoderEngine(cfg, mesh=_mesh1())  # equal content (JAX may intern)
    y = np.asarray(_tx("ccsds", 256, 6))
    pool = SessionPool()
    ha, hb = pool.open(eng_a), pool.open(eng_b)
    assert len(pool._mesh_refs) == 2  # strong refs held while pooled
    ha.feed(y)
    hb.feed(y)
    pool.step()
    assert pool.launches == 1
    # dispatch is part of the identity: a shard_map engine splits the group
    eng_c = DecoderEngine(cfg, mesh=_mesh1(), shard_dispatch="shard_map")
    ha2, hc = pool.open(eng_a), pool.open(eng_c)
    ha2.feed(y)
    hc.feed(y)
    pool.step()
    assert pool.launches == 3
    ref = np.asarray(DecoderEngine(cfg).decode(jnp.asarray(y), 256))
    for h in (ha, hb, ha2, hc):
        np.testing.assert_array_equal(np.concatenate([h.take(), h.finish(256)]), ref)
    pool.close(ha)
    pool.close(hc)
    assert len(pool._mesh_refs) == 2  # hb's and ha2's meshes still pinned
