"""Decode-vs-train consistency: incremental cached decode must reproduce the
full-sequence forward pass.

Exact (to f32 roundoff) for attention/RWKV paths; Mamba matches to ~1e-5
(scan reassociation); MoE matches when the capacity factor admits no drops
(train-time token dropping is an inherent property of capacity-bounded MoE —
documented in models/moe.py).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro._unused.models import lm


def _roundtrip(cfg, S=16, seed=1):
    params = lm.init_params(jax.random.PRNGKey(seed), cfg)
    B = 2
    toks = jnp.asarray(np.random.default_rng(seed).integers(0, cfg.vocab, (B, S)), jnp.int32)
    full = np.asarray(lm.apply_train(params, {"tokens": toks}, cfg))
    cache = lm.init_cache(cfg, B, S, dtype=jnp.float32)
    outs = []
    for t in range(S):
        lg, cache = lm.apply_decode(params, toks[:, t : t + 1], cache, jnp.int32(t), cfg)
        outs.append(np.asarray(lg[:, 0]))
    return full, np.stack(outs, 1)


def _f32(cfg, **kw):
    return dataclasses.replace(cfg, compute_dtype="float32", **kw)


@pytest.mark.parametrize(
    "arch,tol",
    [
        ("qwen2.5-32b", 1e-5),
        ("starcoder2-3b", 1e-5),  # sliding-window path
        ("command-r-35b", 1e-5),  # parallel block
        ("rwkv6-3b", 1e-4),
        ("minitron-8b", 1e-5),
    ],
)
def test_decode_matches_train_exactish(arch, tol):
    cfg = _f32(get_config(arch).reduced())
    full, dec = _roundtrip(cfg)
    np.testing.assert_allclose(dec, full, atol=tol)


@pytest.mark.parametrize(
    "arch,tol",
    [
        ("deepseek-v2-236b", 1e-4),  # MLA + MoE
        ("mixtral-8x22b", 1e-4),  # SWA + MoE
        ("jamba-v0.1-52b", 1e-4),  # Mamba + MoE
    ],
)
def test_decode_matches_train_no_drop_moe(arch, tol):
    cfg = _f32(get_config(arch).reduced(), capacity_factor=8.0)
    full, dec = _roundtrip(cfg)
    np.testing.assert_allclose(dec, full, atol=tol)


def test_sliding_window_ring_buffer():
    """Decode past the window: ring-buffer cache must equal a fresh full
    forward (the window hides everything older)."""
    cfg = _f32(get_config("starcoder2-3b").reduced(), sliding_window=8)
    params = lm.init_params(jax.random.PRNGKey(7), cfg)
    B, S = 1, 24  # 3× window
    toks = jnp.asarray(np.random.default_rng(7).integers(0, cfg.vocab, (B, S)), jnp.int32)
    full = np.asarray(lm.apply_train(params, {"tokens": toks}, cfg))
    cache = lm.init_cache(cfg, B, S, dtype=jnp.float32)  # ring: capped at window
    assert cache["groups"][0]["l0"]["mixer"].k.shape[2] == cfg.sliding_window
    outs = []
    for t in range(S):
        lg, cache = lm.apply_decode(params, toks[:, t : t + 1], cache, jnp.int32(t), cfg)
        outs.append(np.asarray(lg[:, 0]))
    dec = np.stack(outs, 1)
    np.testing.assert_allclose(dec, full, atol=1e-4)


def test_moe_capacity_drop_semantics():
    """With tight capacity the train path drops tokens (documented); the
    sort-based dispatch must still be finite and bounded."""
    cfg = dataclasses.replace(get_config("mixtral-8x22b").reduced(), capacity_factor=0.5)
    params = lm.init_params(jax.random.PRNGKey(9), cfg)
    toks = jnp.asarray(np.random.default_rng(9).integers(0, cfg.vocab, (2, 32)), jnp.int32)
    logits = lm.apply_train(params, {"tokens": toks}, cfg)
    assert bool(jnp.isfinite(logits).all())


def test_encdec_decode_with_cross_cache():
    """seamless: decode with precomputed cross K/V matches teacher forcing."""
    cfg = _f32(get_config("seamless-m4t-medium").reduced())
    params = lm.init_params(jax.random.PRNGKey(11), cfg)
    B, S, Sx = 1, 10, 12
    rng = np.random.default_rng(11)
    frames = jnp.asarray(rng.normal(size=(B, Sx, cfg.d_model)), jnp.float32)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    full = np.asarray(lm.apply_train(params, {"tokens": toks, "frames": frames}, cfg))

    enc = lm.encode(params, frames, cfg)
    cache = lm.init_cache(cfg, B, S, dtype=jnp.float32, cross_len=Sx)
    cache = lm.prefill_cross(params, enc, cfg, cache)
    outs = []
    for t in range(S):
        lg, cache = lm.apply_decode(params, toks[:, t : t + 1], cache, jnp.int32(t), cfg)
        outs.append(np.asarray(lg[:, 0]))
    dec = np.stack(outs, 1)
    np.testing.assert_allclose(dec, full, atol=1e-4)
