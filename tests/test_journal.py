"""Crash-safety suite: chunk journal, checkpoint/restore, integrity sentinel.

The DESIGN.md §15 contract end to end:

* **journal mechanics** — record roundtrip, monotone seq across reopen,
  torn-tail tolerance (a SIGKILL mid-``write()`` loses at most the torn
  record), CRC corruption stopping replay at the crash frontier, atomic
  checkpoints compacting the log;
* **snapshot/restore** — store snapshots (array + paged) and session
  snapshots restore into fresh objects and continue the stream bit-exact
  to an uninterrupted run;
* **kill-point matrix** — a journaled multi-stream trace abandoned at
  EVERY dispatch boundary (plus before the first dispatch) recovers to
  deliver exactly the reference bits: acked prefixes never redeliver
  (suppression), taken-but-unacked tails do redeliver, zero slab pages
  leak in the recovered incarnation;
* **property fuzz** — random chunk partitions × metric modes × punctured
  specs × random kill points (hypothesis, env-scaled example count);
* **integrity sentinel** — an injected post-kernel bit flip
  (``decode_corrupt``) is flagged by the re-encode screen and quarantines
  ONLY the corrupted stream; clean streams pass at the same threshold;
* **metrics** — the snapshot is a deep copy and carries injector fired
  counts.
"""

import asyncio
import os

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from repro.core.codespec import available_code_specs, get_code_spec
from repro.core.engine import ArraySessionStore
from repro.launch.faults import FaultInjector, IntegrityError
from repro.launch.journal import ChunkJournal, IntegritySentinel
from repro.launch.serve_async import AsyncDecodeService
from repro.launch.slab import SymbolSlab

from test_serve_async import GEOM, FakeClock, _engine, _tx_stream

MAX_EXAMPLES = int(os.environ.get("PROPERTY_MAX_EXAMPLES", "3"))


# ---------------------------------------------------------------------------
# ChunkJournal mechanics
# ---------------------------------------------------------------------------
@pytest.mark.tier1
def test_journal_roundtrip_and_seq_survives_reopen(tmp_path):
    j = ChunkJournal(tmp_path)
    s1 = j.append("open", 0)
    s2 = j.append("admit", 0, np.arange(6, dtype=np.float32))
    assert (s1, s2) == (1, 2) and j.seq == 2
    recs = j.records()
    assert [r[:2] for r in recs] == [(1, "open"), (2, "admit")]
    np.testing.assert_array_equal(recs[1][3], np.arange(6, dtype=np.float32))
    j.close()
    j2 = ChunkJournal(tmp_path)  # restart: seq continues, never reuses
    assert j2.append("ack", 0, 64) == 3
    j2.close()


@pytest.mark.tier1
def test_journal_torn_tail_tolerated(tmp_path):
    j = ChunkJournal(tmp_path)
    j.append("open", 0)
    j.append("ack", 0, 128)
    j.close()
    with open(j.log_path, "ab") as f:  # SIGKILL mid-write: half a record
        f.write(b"\x40\x00\x00\x00\x99\x99")
    j2 = ChunkJournal(tmp_path)
    assert [r[1] for r in j2.records()] == ["open", "ack"]
    assert j2.append("finish", 0) == 3  # appends continue past the torn tail
    j2.close()


@pytest.mark.tier1
def test_journal_crc_corruption_stops_replay_at_frontier(tmp_path):
    j = ChunkJournal(tmp_path)
    j.append("open", 0)
    mid_off = os.path.getsize(j.log_path)
    j.append("ack", 0, 64)
    j.append("finish", 0)
    j.close()
    with open(j.log_path, "r+b") as f:  # flip one payload byte mid-log
        f.seek(mid_off + 8)
        b = f.read(1)
        f.seek(mid_off + 8)
        f.write(bytes([b[0] ^ 0xFF]))
    j2 = ChunkJournal(tmp_path)
    # nothing after the corrupt record is trustworthy, even if intact
    assert [r[1] for r in j2.records()] == ["open"]
    j2.close()


@pytest.mark.tier1
def test_checkpoint_is_atomic_and_compacts_log(tmp_path):
    j = ChunkJournal(tmp_path)
    for sid in range(4):
        j.append("open", sid)
    j.write_checkpoint({"dispatches": 7, "streams": {}})
    assert os.path.getsize(j.log_path) == 0  # superseded log truncated
    j.append("open", 99)  # lands after the checkpoint
    ckpt, pending = j.load()
    assert ckpt["dispatches"] == 7 and ckpt["last_seq"] == 4
    assert [r[1:] for r in pending] == [("open", 99)]
    # a stale tmp from a crash mid-checkpoint is ignored (never promoted)
    with open(j.ckpt_path + ".tmp", "wb") as f:
        f.write(b"garbage that never got renamed")
    assert ChunkJournal(tmp_path).load_checkpoint()["dispatches"] == 7
    # a truncated checkpoint file reads as absent, not as an error
    with open(j.ckpt_path, "r+b") as f:
        f.truncate(5)
    assert ChunkJournal(tmp_path).load_checkpoint() is None
    j.close()


# ---------------------------------------------------------------------------
# Snapshot / restore
# ---------------------------------------------------------------------------
@pytest.mark.tier1
def test_store_snapshot_restore_roundtrip_array_and_paged():
    rng = np.random.default_rng(5)
    rows = rng.normal(size=(23, 2)).astype(np.float32)
    a = ArraySessionStore(2)
    a.append(rows)
    a.drop_prefix(4)
    a2 = ArraySessionStore(2)
    a2.restore(a.snapshot())
    np.testing.assert_array_equal(a2.read(0, len(a2)), rows[4:])

    slab = SymbolSlab(n_pages=16, page_stages=5, R=2)  # page-misaligned
    p = slab.open_store()
    p.append(rows)
    p.drop_prefix(4)
    p2 = slab.open_store()
    p2.restore(p.snapshot())
    np.testing.assert_array_equal(np.array(p2.read(0, len(p2))), rows[4:])
    with pytest.raises(ValueError, match="not empty"):
        p2.restore(p.snapshot())
    p.close()
    p2.close()
    assert slab.pages_in_use == 0


@pytest.mark.tier1
@pytest.mark.parametrize("name", ["ccsds", "ccsds-3/4"])
def test_session_snapshot_restores_bit_exact(name):
    """Snapshot a session mid-stream, restore into a fresh one, continue:
    the combined output equals the uninterrupted session bit for bit."""
    spec, _, y = _tx_stream(name, 700, 4.5, 31)
    eng = _engine(spec)
    cut = len(y) // 3
    ref_sess = eng.session()
    ref = np.concatenate([ref_sess.decode(y), ref_sess.finish(700)])

    s1 = eng.session()
    head = s1.decode(y[:cut])
    snap = s1.snapshot()
    s2 = eng.session()
    s2.restore(snap)
    out = np.concatenate([head, s2.decode(y[cut:]), s2.finish(700)])
    np.testing.assert_array_equal(out, ref)


# ---------------------------------------------------------------------------
# Crash → recover: the kill-point matrix
# ---------------------------------------------------------------------------
def _chunks(y, n_chunks):
    bounds = np.linspace(0, len(y), n_chunks + 1).astype(int)
    return [y[lo:hi] for lo, hi in zip(bounds[:-1], bounds[1:])]


def _slab(spec, n_streams):
    return SymbolSlab(
        n_pages=8 * n_streams, page_stages=GEOM["D"] + 2 * GEOM["L"], R=spec.code.R
    )


def _crash_recover_roundtrip(
    name, n_bits, n_chunks, n_streams, kill_at, jdir, *, metric_mode="f32", seed=40
):
    """Run a journaled manual-poll trace, abandon it after its ``kill_at``-th
    dispatch (0 = before any), recover into a fresh slab, resume, and return
    per-stream (durable_prefix + recovered_delivery, reference) pairs plus
    the recovered slab for leak assertions.

    The simulated client acks what it takes after every dispatch EXCEPT the
    last one before the crash — those taken-but-unacked bits are "lost with
    the process" and recovery must redeliver them (while never redelivering
    the acked prefix).
    """
    spec = get_code_spec(name)
    eng = _engine(spec, metric_mode=metric_mode)
    txs = [_tx_stream(name, n_bits, 4.5, seed + i) for i in range(n_streams)]
    refs = [
        np.asarray(eng.decode(jnp.asarray(y), n_bits)) for _, _, y in txs
    ]
    chunk_lists = [_chunks(y, n_chunks) for _, _, y in txs]

    async def crash_half():
        svc = AsyncDecodeService(
            max_batch_blocks=1000,
            deadline_ms=0.0,  # manual poll() is due as soon as anything is pending
            slab=_slab(spec, n_streams),
            journal=ChunkJournal(jdir),
            checkpoint_every=2,  # some acks land in the log, some fold away
        )
        streams = [svc.open(eng) for _ in txs]
        durable = [[] for _ in txs]
        fired = 0
        for k in range(n_chunks):
            if fired >= kill_at:
                return durable  # "SIGKILL": nothing closed, nothing flushed
            for st, chunks in zip(streams, chunk_lists):
                await st.send(chunks[k])
            if svc.poll():
                fired += 1
                last = fired >= kill_at
                for i, st in enumerate(streams):
                    got = st.take(ack=False)
                    if last:
                        continue  # taken but never acked: dies with the process
                    if len(got):
                        durable[i].append(got)
                    st.ack()
        return durable

    durable = asyncio.run(crash_half())

    async def recover_half():
        slab2 = _slab(spec, n_streams)
        svc = AsyncDecodeService.recover(
            ChunkJournal(jdir),
            eng,
            slab=slab2,
            max_batch_blocks=1000,
            deadline_ms=0.0,
        )
        outs = []
        for i in range(n_streams):
            st = svc.recovered_streams[i]
            assert st.acked_bits == sum(len(d) for d in durable[i])
            for k in range(st.chunks_admitted, n_chunks):
                await st.send(chunk_lists[i][k])
                svc.poll()
            tail = np.concatenate([st.take(), await st.finish(n_bits)])
            outs.append(np.concatenate([*durable[i], tail]).astype(np.int64))
        return outs, slab2

    outs, slab2 = asyncio.run(recover_half())
    return outs, refs, slab2


@pytest.mark.tier1
@pytest.mark.parametrize("kill_at", range(0, 5))
def test_kill_point_matrix_recovery_is_bit_exact(tmp_path, kill_at):
    """Crash at EVERY dispatch boundary (and before the first): the durable
    prefix plus the recovered redelivery is the reference, exactly once —
    no missing bits, no duplicates, no leaked slab pages."""
    n_bits, n_chunks, n_streams = 512, 4, 3
    outs, refs, slab2 = _crash_recover_roundtrip(
        "ccsds", n_bits, n_chunks, n_streams, kill_at, tmp_path
    )
    for got, ref in zip(outs, refs):
        assert len(got) == n_bits  # exactly-once: length alone catches dups
        np.testing.assert_array_equal(got, ref)
    assert slab2.pages_in_use == 0  # every recovered stream released its pages


@pytest.mark.tier1
def test_recover_after_clean_finish_is_empty(tmp_path):
    """A trace that finished everything leaves a journal that recovers to an
    empty service (the all-acked checkpoint truncated the log)."""
    spec, _, y = _tx_stream("ccsds", 512, 4.5, 50)
    eng = _engine(spec)

    async def scenario():
        svc = AsyncDecodeService(
            max_batch_blocks=1000,
            deadline_ms=0.0,
            slab=_slab(spec, 1),
            journal=ChunkJournal(tmp_path),
        )
        st = svc.open(eng)
        await st.send(y)
        svc.poll()
        return np.concatenate([st.take(), await st.finish(512)])

    out = asyncio.run(scenario())
    np.testing.assert_array_equal(out, np.asarray(eng.decode(jnp.asarray(y), 512)))
    j = ChunkJournal(tmp_path)
    assert j.load()[1] == []  # no unapplied records
    svc = AsyncDecodeService.recover(j, eng, slab=_slab(spec, 1))
    assert svc.recovered_streams == {} and svc._streams == []


_PUNCTURED = [n for n in available_code_specs() if get_code_spec(n).is_punctured]


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(
    st.sampled_from(["ccsds", *_PUNCTURED]),
    st.sampled_from(["f32", "i16", "i8"]),
    st.integers(2, 5),
    st.floats(0.0, 1.0),
)
def test_property_crash_recovery_bit_exact(
    tmp_path_factory, name, metric_mode, n_chunks, kill_frac
):
    """Fuzz the recovery contract: random punctured/unpunctured spec ×
    metric mode × chunk partition × kill point (including 0 = the journal
    holds only opens/admits, and points past the last dispatch)."""
    kill_at = int(round(kill_frac * n_chunks))
    jdir = tmp_path_factory.mktemp("journal")
    n_bits, n_streams = 448, 2
    outs, refs, slab2 = _crash_recover_roundtrip(
        name, n_bits, n_chunks, n_streams, kill_at, jdir,
        metric_mode=metric_mode, seed=60,
    )
    for got, ref in zip(outs, refs):
        assert len(got) == n_bits
        np.testing.assert_array_equal(got, ref)
    assert slab2.pages_in_use == 0


@pytest.mark.tier1
def test_recovery_tolerates_torn_tail_mid_trace(tmp_path):
    """Tear the journal's tail AFTER a crash (the half-written record a real
    SIGKILL leaves): recovery replays the intact prefix; the client cursor
    shrinks accordingly and re-sends, still bit-exact."""
    spec, _, y = _tx_stream("ccsds", 512, 4.5, 55)
    eng = _engine(spec)
    chunks = _chunks(y, 4)

    async def crash_half():
        svc = AsyncDecodeService(
            max_batch_blocks=1000,
            deadline_ms=0.0,
            slab=_slab(spec, 1),
            journal=ChunkJournal(tmp_path),
            checkpoint_every=None,  # keep every record in the log
        )
        st = svc.open(eng)
        for c in chunks[:3]:
            await st.send(c)
        svc.poll()

    asyncio.run(crash_half())
    with open(os.path.join(tmp_path, "journal.log"), "ab") as f:
        f.write(b"\xff" * 7)  # the torn half-record

    async def recover_half():
        slab2 = _slab(spec, 1)
        svc = AsyncDecodeService.recover(
            ChunkJournal(tmp_path), eng, slab=slab2,
            max_batch_blocks=1000, deadline_ms=0.0,
        )
        st = svc.recovered_streams[0]
        assert st.chunks_admitted == 3  # all three admits were intact
        for c in chunks[3:]:
            await st.send(c)
            svc.poll()
        return np.concatenate([st.take(), await st.finish(512)])

    out = asyncio.run(recover_half())
    np.testing.assert_array_equal(out, np.asarray(eng.decode(jnp.asarray(y), 512)))


# ---------------------------------------------------------------------------
# Integrity sentinel
# ---------------------------------------------------------------------------
@pytest.mark.tier1
def test_sentinel_unit_flags_flip_not_noise():
    """Unit-level: at high SNR a clean block passes the 0.95 bound and a
    single-bit flip (which disturbs ~(v+1)·R re-encoded symbols) fails it;
    punctured erasure slots (exact zeros) are excluded either way."""
    spec, payload, y = _tx_stream("ccsds", 64, 8.0, 70)  # one D=64 block span
    sen = IntegritySentinel(rate=1.0, min_agreement=0.95)
    window = y.reshape(-1, spec.code.R)[:64]
    assert sen.check(payload, window, spec.code, 0) is None
    bad = payload.copy()
    bad[32] ^= 1
    err = sen.check(bad, window, spec.code, 0)
    assert isinstance(err, IntegrityError)
    assert err.agreement < 0.95 == err.bound
    assert sen.checked == 2 and sen.flagged == 1
    # zero-symbol windows (all-erasure / flush padding) never flag
    assert sen.check(payload, np.zeros_like(window), spec.code, 0) is None
    with pytest.raises(ValueError, match="rate"):
        IntegritySentinel(rate=1.5)


@pytest.mark.tier1
def test_sentinel_catches_decode_corrupt_and_quarantines_one_stream():
    """An injected post-kernel bit flip on stream A is flagged by the
    re-encode sentinel and quarantines A with a typed IntegrityError;
    stream B (same dispatches, clean) delivers bit-exact — the blast
    radius is one stream."""
    n_bits = 512
    spec, _, ya = _tx_stream("ccsds", n_bits, 8.0, 80)  # high SNR: clean
    _, _, yb = _tx_stream("ccsds", n_bits, 8.0, 81)  # blocks pass 0.95 easily
    eng = _engine(spec)
    inj = FaultInjector(schedule={"decode_corrupt": {0}})  # first delivery → A

    async def scenario():
        svc = AsyncDecodeService(
            max_batch_blocks=1000,
            deadline_ms=0.0,
            slab=_slab(spec, 2),
            fault_injector=inj,
            integrity_rate=1.0,
            integrity_min_agreement=0.95,
        )
        a, b = svc.open(eng), svc.open(eng)
        # per-block deliveries: one flip in a 64-bit span drops agreement to
        # ~0.92, well under 0.95 — a whole-stream span would dilute it away
        ca, cb = _chunks(ya, 8), _chunks(yb, 8)
        for k in range(2):
            await a.send(ca[k])
            await b.send(cb[k])
        svc.poll()  # first delivery: decode_corrupt consultation 0 hits A
        with pytest.raises(IntegrityError, match="integrity sentinel"):
            a.take()
        assert isinstance(a.failed, IntegrityError)
        for c in cb[2:]:
            await b.send(c)
            svc.poll()
        out_b = np.concatenate([b.take(), await b.finish(n_bits)])
        m = svc.metrics()
        return out_b, m

    out_b, m = asyncio.run(scenario())
    np.testing.assert_array_equal(out_b, np.asarray(eng.decode(jnp.asarray(yb), n_bits)))
    assert m["integrity_flagged"] == 1 and m["integrity_checked"] >= 2
    assert m["quarantined_streams"] == 1
    assert m["errors_by_class"]["IntegrityError"] == 1
    assert m["faults_injected"]["decode_corrupt"] == 1


@pytest.mark.tier1
def test_sentinel_clean_trace_passes_at_operating_snr():
    """No injection: a full trace at the 4 dB operating point sails under
    the default 0.85 bound — the sentinel screens corruption, not noise."""
    n_bits = 512
    spec, _, y = _tx_stream("ccsds", n_bits, 4.0, 85)
    eng = _engine(spec)

    async def scenario():
        svc = AsyncDecodeService(
            max_batch_blocks=1000,
            deadline_ms=0.0,
            slab=_slab(spec, 1),
            integrity_rate=1.0,
        )
        st = svc.open(eng)
        for c in _chunks(y, 3):
            await st.send(c)
            svc.poll()
        out = np.concatenate([st.take(), await st.finish(n_bits)])
        return out, svc.metrics()

    out, m = asyncio.run(scenario())
    np.testing.assert_array_equal(out, np.asarray(eng.decode(jnp.asarray(y), n_bits)))
    assert m["integrity_checked"] >= 1 and m["integrity_flagged"] == 0


# ---------------------------------------------------------------------------
# Metrics hygiene
# ---------------------------------------------------------------------------
@pytest.mark.tier1
def test_metrics_returns_deep_copy_with_fault_counts():
    """metrics() must hand back a snapshot: mutating it cannot corrupt the
    live counters, and injector fired counts ride along."""
    spec, _, y = _tx_stream("ccsds", 256, 4.5, 90)
    eng = _engine(spec)
    clk = FakeClock()
    inj = FaultInjector(schedule={"dispatch": {0}})

    async def scenario():
        svc = AsyncDecodeService(
            max_batch_blocks=1000,
            deadline_ms=0.0,
            clock=clk.now,
            slab=_slab(spec, 1),
            fault_injector=inj,
        )
        st = svc.open(eng)
        await st.send(y)
        assert svc.poll() is True  # attempt 1: injected failure → backoff armed
        assert svc.poll() is False  # backoff gates the retry
        clk.advance(60.0)
        assert svc.poll() is True  # retry lands
        m = svc.metrics()
        m["errors_by_class"]["DispatchError"] = 999
        m["faults_injected"]["dispatch"] = 999
        m["errors_by_class"]["Phantom"] = 1
        m2 = svc.metrics()
        assert m2["errors_by_class"]["DispatchError"] == 1
        assert m2["faults_injected"]["dispatch"] == 1
        assert "Phantom" not in m2["errors_by_class"]
        assert m2["retries"] == 1
        return np.concatenate([st.take(), await st.finish(256)])

    out = asyncio.run(scenario())
    np.testing.assert_array_equal(out, np.asarray(eng.decode(jnp.asarray(y), 256)))
