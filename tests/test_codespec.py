"""CodeSpec layer: puncturing matrices, registry, and multi-rate decoding."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.channel import transmit
from repro.core.codespec import (
    IS95_29,
    LTE_37,
    CodeSpec,
    PUNCTURE_PATTERNS,
    available_code_specs,
    get_code_spec,
)
from repro.core.encoder import encode_jax, terminate
from repro.core.engine import DecoderEngine
from repro.core.pbvd import PBVDConfig
from repro.core.trellis import CCSDS_27


def test_registry_contents():
    names = available_code_specs()
    assert "ccsds" in names
    assert {"ccsds-2/3", "ccsds-3/4", "ccsds-5/6"} <= set(names)
    assert "is95-k9" in names and "lte-1/3" in names
    with pytest.raises(KeyError):
        get_code_spec("no-such-code")


def test_new_codes_shapes():
    assert (IS95_29.R, IS95_29.K, IS95_29.n_states) == (2, 9, 256)
    assert (LTE_37.R, LTE_37.K, LTE_37.n_states) == (3, 7, 64)


@pytest.mark.parametrize("rate,expect", [("2/3", 2 / 3), ("3/4", 3 / 4), ("5/6", 5 / 6)])
def test_punctured_rates(rate, expect):
    spec = get_code_spec(f"ccsds-{rate}")
    assert abs(spec.rate - expect) < 1e-12
    # symbol counting is consistent with the pattern over whole periods
    p, m = spec.period, spec.kept_per_period
    assert spec.n_symbols_for(10 * p) == 10 * m
    last_stage = int(spec.kept_slots_period[-1]) // spec.code.R
    assert spec.n_stages_for(10 * m) == 9 * p + last_stage + 1
    # round-trips for arbitrary prefixes
    for n_stages in range(1, 3 * p + 1):
        n_sym = spec.n_symbols_for(n_stages)
        assert spec.n_stages_for(n_sym) <= n_stages
        assert spec.n_symbols_for(spec.n_stages_for(n_sym)) >= n_sym


def test_puncture_depuncture_roundtrip():
    spec = get_code_spec("ccsds-3/4")
    rng = np.random.default_rng(0)
    T = 33  # not a multiple of the period
    y = jnp.asarray(rng.normal(size=(T, 2)).astype(np.float32))
    tx = spec.puncture_stream(y)
    assert tx.shape[0] == spec.n_symbols_for(T)
    back = spec.depuncture_stream(tx, n_stages=T)
    # kept slots round-trip exactly, punctured slots are zero
    kept = np.zeros(T * 2, bool)
    kept[spec.kept_slot_indices(0, tx.shape[0])] = True
    flat_y, flat_b = np.asarray(y).reshape(-1), np.asarray(back).reshape(-1)
    np.testing.assert_array_equal(flat_b[kept], flat_y[kept])
    assert np.all(flat_b[~kept] == 0.0)


def test_invalid_puncture_matrices():
    with pytest.raises(ValueError):
        CodeSpec("bad", CCSDS_27, puncture=((1, 0),))  # wrong row count
    with pytest.raises(ValueError):
        CodeSpec("bad", CCSDS_27, puncture=((1, 0), (1,)))  # ragged period
    with pytest.raises(ValueError):
        CodeSpec("bad", CCSDS_27, puncture=((0, 0), (0, 0)))  # keeps nothing


@pytest.mark.parametrize("name", ["ccsds-2/3", "ccsds-3/4", "ccsds-5/6", "is95-k9-3/4"])
def test_punctured_noiseless_roundtrip(name):
    """Depunctured-zero symbols are BM-neutral: noiseless streams decode
    error-free at every punctured rate through the engine."""
    spec = get_code_spec(name)
    rng = np.random.default_rng(3)
    n = 600
    bits = terminate(rng.integers(0, 2, n), spec.code)
    coded = encode_jax(jnp.asarray(bits), spec.code)
    y = 1.0 - 2.0 * spec.puncture_stream(coded).astype(jnp.float32)
    cfg = PBVDConfig(spec=spec, D=128, L=24, q=8, backend="ref")
    dec = np.asarray(DecoderEngine(cfg).decode(y, n))
    np.testing.assert_array_equal(dec, bits[:n])


def test_punctured_noisy_decode_beats_heavier_puncturing():
    """More puncturing → weaker code (sanity on the BM-neutral fill): at a
    fixed channel Es/N0-ish operating point 1/2 outperforms 5/6."""
    rng = np.random.default_rng(7)
    n = 4096
    errs = {}
    for name in ["ccsds", "ccsds-5/6"]:
        spec = get_code_spec(name)
        bits = terminate(rng.integers(0, 2, n), spec.code)
        coded = encode_jax(jnp.asarray(bits), spec.code)
        tx = spec.puncture_stream(coded) if spec.is_punctured else coded
        y = transmit(jax.random.PRNGKey(11), tx, 3.5, spec.rate)
        cfg = PBVDConfig(spec=spec, D=256, L=42, q=8, backend="ref")
        dec = np.asarray(DecoderEngine(cfg).decode(y, n))
        errs[name] = int((dec != bits[:n]).sum())
    assert errs["ccsds"] < errs["ccsds-5/6"]


def test_config_spec_syncs_mother_code():
    spec = get_code_spec("is95-k9-3/4")
    cfg = PBVDConfig(spec=spec)
    assert cfg.code is IS95_29
    assert cfg.codespec is spec
