"""HLO analyzer regression tests (trip counts, flops, slice accounting)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze_hlo


def _hlo(f, *sds):
    return jax.jit(f).lower(*sds).compile().as_text()


def test_scan_trip_count_multiplies_flops():
    """XLA cost_analysis counts a while body once; the analyzer must apply
    the trip count (the motivating bug — see launch/hlo_analysis.py)."""

    def f(x, w):
        def body(c, _):
            return c @ w, None

        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    sds = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    st = analyze_hlo(_hlo(f, sds, sds))
    expected = 2 * 128**3 * 10
    assert abs(st.flops - expected) / expected < 0.05
    assert st.n_while == 1
    assert list(st.trip_counts.values()) == [10]


def test_unrolled_matmul_flops():
    def f(a, b):
        return a @ b

    sds = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    sds2 = jax.ShapeDtypeStruct((256, 32), jnp.float32)
    st = analyze_hlo(_hlo(f, sds, sds2))
    assert st.flops == 2 * 64 * 256 * 32


def test_dus_in_scan_not_overcounted():
    """Scan-carried buffer updates (DUS) must count the update region, not
    the whole aliased buffer per iteration."""
    N = 1024

    def f(buf, xs):
        def body(b, i):
            return jax.lax.dynamic_update_slice(b, xs[i][None], (i, 0)), None

        out, _ = jax.lax.scan(body, buf, jnp.arange(64))
        return out

    buf = jax.ShapeDtypeStruct((64, N), jnp.float32)
    xs = jax.ShapeDtypeStruct((64, N), jnp.float32)
    st = analyze_hlo(_hlo(f, buf, xs))
    whole_buffer_per_iter = 64 * (64 * N * 4)  # the over-count we guard against
    assert st.bytes_accessed < whole_buffer_per_iter / 2, st.bytes_accessed


def test_collective_detection():
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((1,), ("model",))

    def f(a, b):
        y = a @ b
        return jax.lax.with_sharding_constraint(y, NamedSharding(mesh, P(None, None)))

    # single-device mesh → no collectives, but the pipeline must not crash
    sds = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    compiled = (
        jax.jit(f, in_shardings=(NamedSharding(mesh, P(None, "model")),) * 2)
        .lower(sds, sds)
        .compile()
    )
    st = analyze_hlo(compiled.as_text())
    assert st.flops > 0
