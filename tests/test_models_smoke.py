"""Per-architecture smoke tests (assignment requirement f).

Each assigned architecture instantiates its REDUCED config and runs one
forward (and one train-style grad) step on CPU, asserting output shapes and
finiteness. The FULL configs are exercised via eval_shape param-count checks
(no allocation) and the dry-run.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, get_config, list_archs
from repro._unused.models import lm

ARCHS = list_archs()


def _batch_for(cfg, B, S, rng):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.frontend == "vision_patches":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_patches, cfg.d_model)), jnp.float32
        )
    if cfg.frontend == "audio_frames" and cfg.encdec:
        batch["frames"] = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
    return batch


def test_all_archs_registered():
    assert len(ARCHS) == 10
    assert set(ARCHS) == {
        "seamless-m4t-medium", "qwen2.5-32b", "minitron-8b", "command-r-35b",
        "starcoder2-3b", "pixtral-12b", "mixtral-8x22b", "deepseek-v2-236b",
        "jamba-v0.1-52b", "rwkv6-3b",
    }


def test_shapes_assigned():
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["long_500k"].seq_len == 524_288


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = get_config(arch).reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    B, S = 2, 32
    logits = lm.apply_train(params, _batch_for(cfg, B, S, rng), cfg)
    from repro._unused.models.layers import round_vocab

    assert logits.shape == (B, S, round_vocab(cfg.vocab))
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    """One loss+grad step on the reduced config: finite loss, finite grads."""
    cfg = get_config(arch).reduced()
    params = lm.init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(1)
    B, S = 2, 16
    batch = _batch_for(cfg, B, S, rng)
    labels = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)

    def loss_fn(p):
        logits = lm.apply_train(p, batch, cfg)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        return (lse - ll).mean()

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    finite = jax.tree.reduce(
        lambda a, l: a and bool(jnp.isfinite(l).all()), grads, True
    )
    assert finite


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch).reduced()
    params = lm.init_params(jax.random.PRNGKey(2), cfg)
    B = 2
    cross = 8 if cfg.encdec else 0
    cache = lm.init_cache(cfg, B, 32, cross_len=cross)
    if cfg.encdec:
        enc = lm.encode(
            params, jnp.asarray(np.random.default_rng(2).normal(size=(B, 8, cfg.d_model)), jnp.float32), cfg
        )
        cache = lm.prefill_cross(params, enc, cfg, cache)
    logits, cache2 = lm.apply_decode(
        params, jnp.ones((B, 1), jnp.int32), cache, jnp.int32(0), cfg
    )
    assert logits.shape[0] == B and logits.shape[1] == 1
    assert bool(jnp.isfinite(logits).all())
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_axes_structure_matches(arch):
    """The logical-axes pytree mirrors the params pytree leaf-for-leaf, and
    every axes tuple has the same rank as its (stacked) parameter."""
    cfg = get_config(arch).reduced()
    shapes = jax.eval_shape(lambda k: lm.init_params(k, cfg), jax.random.PRNGKey(0))
    axes = lm.param_axes(cfg)
    js, ja = jax.tree.structure(shapes), jax.tree.structure(
        axes, is_leaf=lambda a: isinstance(a, tuple)
    )
    assert js == ja
    for s, a in zip(jax.tree.leaves(shapes), jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple))):
        assert len(a) == s.ndim, f"{arch}: axes {a} vs shape {s.shape}"


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_count(arch):
    """eval_shape (no allocation) param count of the FULL config matches the
    analytic estimate within 5% — catches config transcription errors."""
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda k: lm.init_params(k, cfg), jax.random.PRNGKey(0))
    actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
    est = cfg.n_params_estimate
    assert abs(actual - est) / est < 0.05, f"{arch}: actual {actual/1e9:.2f}B vs est {est/1e9:.2f}B"


def test_causality_property():
    """Changing token t must not affect logits before t (dense + ssm + moe)."""
    rng = np.random.default_rng(3)
    for arch in ["qwen2.5-32b", "rwkv6-3b", "mixtral-8x22b", "jamba-v0.1-52b"]:
        cfg = get_config(arch).reduced()
        params = lm.init_params(jax.random.PRNGKey(3), cfg)
        B, S, t = 1, 12, 7
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
        toks2 = toks.at[0, t].set((toks[0, t] + 1) % cfg.vocab)
        l1 = lm.apply_train(params, {"tokens": toks}, cfg)
        l2 = lm.apply_train(params, {"tokens": toks2}, cfg)
        np.testing.assert_allclose(
            np.asarray(l1[:, :t]), np.asarray(l2[:, :t]), atol=1e-5,
            err_msg=f"causality violated in {arch}",
        )
        assert np.abs(np.asarray(l1[:, t:]) - np.asarray(l2[:, t:])).max() > 1e-4
