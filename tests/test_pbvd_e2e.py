"""End-to-end PBVD stream-decoding tests (paper §III-A / Fig. 4 behaviour)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ber import simulate_ber, uncoded_ber
from repro.core.channel import transmit
from repro.core.encoder import encode_jax, terminate
from repro.core.pbvd import PBVDConfig, decode_stream, frame_stream, throughput_model
from repro.core.trellis import CCSDS_27


def _noisy_stream(n, ebn0_db, seed=0):
    code = CCSDS_27
    rng = np.random.default_rng(seed)
    bits = terminate(rng.integers(0, 2, n), code)
    coded = encode_jax(jnp.asarray(bits), code)
    y = transmit(jax.random.PRNGKey(seed), coded, ebn0_db, code.rate)
    return bits[:n], y


def test_frame_stream_layout():
    D, L, n_blocks = 8, 2, 3
    n_sym = 20
    y = jnp.arange(n_sym * 2, dtype=jnp.float32).reshape(n_sym, 2)
    blocks = frame_stream(y, D, L, n_blocks)
    assert blocks.shape == (D + 2 * L, 2, n_blocks)
    # block 0 head is the zero pad (stages -L..-1)
    assert np.all(np.asarray(blocks[:L, :, 0]) == 0)
    # block 0 decode region starts at the stream head
    np.testing.assert_array_equal(np.asarray(blocks[L, :, 0]), np.asarray(y[0]))
    # block 1 starts L stages before stage D
    np.testing.assert_array_equal(np.asarray(blocks[0, :, 1]), np.asarray(y[D - L]))
    # tail beyond the stream is zero-padded
    assert np.all(np.asarray(blocks[-1, :, 2]) == 0)


@pytest.mark.parametrize("q", [None, 8], ids=["f32", "int8"])
def test_stream_roundtrip_noiseless(q):
    bits, _ = _noisy_stream(2000, 100.0, seed=4)  # effectively noiseless
    code = CCSDS_27
    coded = encode_jax(jnp.asarray(terminate(bits, code)), code)
    y = 1.0 - 2.0 * coded.astype(jnp.float32)
    dec = np.asarray(decode_stream(y, 2000, PBVDConfig(q=q, backend="ref")))
    assert np.array_equal(dec, bits)


def test_stream_decode_4db_error_free():
    """At 4 dB a 64-state rate-1/2 code decodes a few kbit error-free whp."""
    bits, y = _noisy_stream(8192, 4.0, seed=5)
    dec = np.asarray(decode_stream(y, 8192, PBVDConfig(q=8, backend="ref")))
    assert np.array_equal(dec, bits)


def test_quantized_matches_float_at_moderate_snr():
    """8-bit quantization is transparent at practical SNR (paper §IV-C)."""
    bits, y = _noisy_stream(4096, 3.5, seed=6)
    d_f = np.asarray(decode_stream(y, 4096, PBVDConfig(q=None, backend="ref")))
    d_q = np.asarray(decode_stream(y, 4096, PBVDConfig(q=8, backend="ref")))
    # identical or nearly so
    assert np.mean(d_f != d_q) < 1e-3


def test_traceback_depth_improves_ber():
    """Fig. 4: larger L → better BER at fixed Eb/N0 (L=42 ≈ theory)."""
    key = jax.random.PRNGKey(8)
    cfg14 = PBVDConfig(D=512, L=14, q=None, backend="ref")
    cfg42 = PBVDConfig(D=512, L=42, q=None, backend="ref")
    ber14 = simulate_ber(key, 3.0, cfg14, n_bits=1 << 14)
    ber42 = simulate_ber(key, 3.0, cfg42, n_bits=1 << 14)
    assert ber42 <= ber14
    # and far below uncoded
    assert ber42 < uncoded_ber(3.0) / 5


def test_argmin_start_policy():
    bits, y = _noisy_stream(2048, 3.0, seed=9)
    d_zero = np.asarray(decode_stream(y, 2048, PBVDConfig(q=None, backend="ref")))
    d_arg = np.asarray(
        decode_stream(y, 2048, PBVDConfig(q=None, backend="ref", start_policy="argmin"))
    )
    # both policies decode with low error; L-stage merge makes them near-equal
    assert np.mean(d_zero != bits) < 0.01
    assert np.mean(d_arg != bits) < 0.01


def test_throughput_model_reproduces_table3():
    """Eq. (7) with the paper's measured S_k reproduces Table III's T/P(3S)
    within 5% (GTX580/PCIe-2 and GTX980/PCIe-3 peak rows)."""
    tp580 = throughput_model(
        D=512, L=42, R=2, q=8, packed_out=True, s_kernel_mbps=641.8,
        n_streams=3, bandwidth_gbps=8.0,
    )
    assert abs(tp580 - 598.3) / 598.3 < 0.05
    tp980 = throughput_model(
        D=512, L=42, R=2, q=8, packed_out=True, s_kernel_mbps=2122.7,
        n_streams=3, bandwidth_gbps=12.0,
    )
    assert abs(tp980 - 1802.5) / 1802.5 < 0.05


def test_throughput_model_packing_gain():
    """Packed I/O strictly increases modeled throughput (paper's U₁/U₂ point)."""
    kw = dict(D=512, L=42, R=2, s_kernel_mbps=2000.0, n_streams=3, bandwidth_gbps=8.0)
    unpacked = throughput_model(q=None, packed_out=False, **kw)
    packed = throughput_model(q=8, packed_out=True, **kw)
    assert packed > 1.5 * unpacked
