"""Test bootstrap: a lightweight ``hypothesis`` fallback.

The property-based tests use a small slice of the hypothesis API
(``given`` / ``settings`` / a handful of strategies). When the real package
is installed (see requirements-dev.txt) it is used as-is; otherwise this shim
provides deterministic pseudo-random sampling with the same decorator surface
so the suite collects and runs without the dependency.
"""

from __future__ import annotations

import functools
import inspect
import sys
import types
import zlib

try:  # pragma: no cover - prefer the real thing when available
    import hypothesis  # noqa: F401
except ImportError:
    import numpy as _np

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw = draw_fn

        def map(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng)))

        def filter(self, pred, _tries=1000):
            def draw(rng):
                for _ in range(_tries):
                    v = self._draw(rng)
                    if pred(v):
                        return v
                raise ValueError("filter predicate too restrictive")

            return _Strategy(draw)

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    def _booleans():
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    def _sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

    def _lists(elements, min_size=0, max_size=10):
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements._draw(rng) for _ in range(n)]

        return _Strategy(draw)

    def _tuples(*strats):
        return _Strategy(lambda rng: tuple(s._draw(rng) for s in strats))

    def _just(value):
        return _Strategy(lambda rng: value)

    def _composite(fn):
        @functools.wraps(fn)
        def builder(*args, **kwargs):
            return _Strategy(
                lambda rng: fn(lambda strat: strat._draw(rng), *args, **kwargs)
            )

        return builder

    _DEFAULT_MAX_EXAMPLES = 20

    def _given(*strategies):
        def deco(fn):
            inner = fn
            settings_kw = getattr(fn, "_hyp_settings", {})
            # strategies fill the LAST parameters (hypothesis convention);
            # bind them by name so pytest may pass fixtures/params as kwargs
            all_params = list(inspect.signature(inner).parameters)
            strat_names = all_params[len(all_params) - len(strategies):]

            @functools.wraps(inner)
            def run(*args, **kwargs):
                kw = dict(settings_kw)
                kw.update(getattr(run, "_hyp_settings", {}))
                n = kw.get("max_examples", _DEFAULT_MAX_EXAMPLES)
                seed = zlib.adler32(
                    f"{inner.__module__}.{inner.__qualname__}".encode()
                )
                rng = _np.random.default_rng(seed)
                for _ in range(n):
                    vals = {nm: s._draw(rng) for nm, s in zip(strat_names, strategies)}
                    inner(*args, **vals, **kwargs)

            # hide the strategy-filled params from pytest's fixture resolution
            run.__dict__.pop("__wrapped__", None)
            params = list(inspect.signature(inner).parameters.values())
            kept = params[: len(params) - len(strategies)]
            run.__signature__ = inspect.Signature(kept)
            run.hypothesis = types.SimpleNamespace(inner_test=inner)
            return run

        return deco

    class _settings:
        """Decorator shim: @settings(max_examples=..., deadline=...)."""

        HealthCheck = None

        def __init__(self, **kwargs):
            self.kwargs = kwargs

        def __call__(self, fn):
            # tolerate either decorator order around @given
            existing = dict(getattr(fn, "_hyp_settings", {}))
            existing.update(self.kwargs)
            fn._hyp_settings = existing
            return fn

    def _assume(condition):
        if not condition:
            raise AssertionError("assumption failed (shim treats assume as assert)")

    class _HealthCheck:
        too_slow = "too_slow"
        data_too_large = "data_too_large"
        filter_too_much = "filter_too_much"

        @classmethod
        def all(cls):
            return [cls.too_slow, cls.data_too_large, cls.filter_too_much]

    _st_mod = types.ModuleType("hypothesis.strategies")
    _st_mod.integers = _integers
    _st_mod.booleans = _booleans
    _st_mod.floats = _floats
    _st_mod.sampled_from = _sampled_from
    _st_mod.lists = _lists
    _st_mod.tuples = _tuples
    _st_mod.just = _just
    _st_mod.composite = _composite

    _hyp_mod = types.ModuleType("hypothesis")
    _hyp_mod.given = _given
    _hyp_mod.settings = _settings
    _hyp_mod.assume = _assume
    _hyp_mod.HealthCheck = _HealthCheck
    _hyp_mod.strategies = _st_mod
    _hyp_mod.__version__ = "0.0-shim"
    _hyp_mod.__is_shim__ = True

    sys.modules["hypothesis"] = _hyp_mod
    sys.modules["hypothesis.strategies"] = _st_mod


# ---------------------------------------------------------------------------
# XLA executable-map hygiene
# ---------------------------------------------------------------------------
# Every jit compilation mmaps its executable (~80-180 mappings per decoder
# config on XLA CPU) and the process-wide ``vm.max_map_count`` ceiling is
# ~65k: a full tier-1 run accumulates enough compiled configs that LLVM's
# next mmap fails mid-suite and the compiler segfaults. Dropping the jit
# caches at module boundaries keeps the map count bounded — later modules
# recompile what they actually use, which is cheap next to a compiler crash.
import gc as _gc

import jax as _jax
import pytest as _pytest


@_pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    yield
    _jax.clear_caches()
    _gc.collect()
