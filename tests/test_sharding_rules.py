"""Sharding-rule unit/property tests: divisibility fallback, axis priority."""

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.sharding.rules import DEFAULT_RULES, LogicalRules, SINGLE_POD_RULES


class FakeMesh:
    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_basic_mapping():
    r = LogicalRules(MESH, DEFAULT_RULES)
    assert r.spec(("batch", None)) == P(("pod", "data"), None)
    assert r.spec(("fsdp", "mlp")) == P("data", "model")


def test_divisibility_fallback_drops_axis():
    r = LogicalRules(MESH, DEFAULT_RULES)
    # kv_heads = 8 cannot shard over the 16-way model axis → replicated
    assert r.spec(("fsdp", "kv_heads", None), shape=(4096, 8, 128)) == P("data", None, None)
    # 40 heads likewise
    assert r.spec(("fsdp", "heads", None), shape=(5120, 40, 128)) == P("data", None, None)
    # 48 heads divide 16 → sharded
    assert r.spec(("fsdp", "heads", None), shape=(6144, 48, 128)) == P("data", "model", None)


def test_multi_axis_partial_keep():
    r = LogicalRules(MESH, DEFAULT_RULES)
    # batch 16 can't take pod×data (32) but can take pod (2)
    assert r.spec(("batch", None), shape=(16, 128)) == P(("pod", "data"), None) or True
    spec = r.spec(("batch", None), shape=(16, 128))
    # greedy prefix: pod(2) divides 16, pod×data(32) doesn't → ("pod",)
    assert spec == P("pod", None)
    # batch=1 → fully replicated
    assert r.spec(("batch", None), shape=(1, 128)) == P(None, None)


def test_ep_priority_auto_fallback():
    """experts listed before expert_mlp: EP when divisible, TP otherwise."""
    r = LogicalRules(MESH, DEFAULT_RULES)
    # deepseek: 160 experts % 16 == 0 → EP, hidden replicated
    assert r.spec(("experts", "fsdp", "expert_mlp"), shape=(160, 5120, 1536)) == P(
        "model", "data", None
    )
    # mixtral: 8 experts → fallback to hidden-TP
    assert r.spec(("experts", "fsdp", "expert_mlp"), shape=(8, 6144, 16384)) == P(
        None, "data", "model"
    )


def test_missing_axes_dropped():
    mesh1 = FakeMesh({"data": 4, "model": 2})
    r = LogicalRules(mesh1, DEFAULT_RULES)  # 'pod' missing from mesh
    assert r.spec(("batch", None)) == P(None, None)  # batch maps (pod,data) → dropped
    r2 = LogicalRules(mesh1, SINGLE_POD_RULES)
    assert r2.spec(("batch", None)) == P("data", None)


@given(
    st.integers(1, 8).map(lambda x: 2**x),
    st.sampled_from(["heads", "kv_heads", "mlp", "vocab"]),
)
@settings(max_examples=40, deadline=None)
def test_property_spec_always_divides(dim, axis):
    """Any spec produced with shape info tiles the dimension exactly."""
    r = LogicalRules(MESH, DEFAULT_RULES)
    spec = r.spec((axis,), shape=(dim,))
    part = spec[0]
    if part is None:
        return
    axes = (part,) if isinstance(part, str) else part
    prod = 1
    for a in axes:
        prod *= MESH.shape[a]
    assert dim % prod == 0
