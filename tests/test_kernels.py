"""Per-kernel allclose tests: Pallas (interpret mode) vs the pure-jnp oracle.

Sweeps shapes, dtypes and stage-chunkings per the assignment requirements.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.trellis import CCSDS_27, ConvCode
from repro.kernels.acs import acs_forward_pallas
from repro.kernels.ops import pbvd_decode_blocks
from repro.kernels.ref import (
    acs_forward_ref,
    pbvd_decode_ref,
    traceback_prefix_ref,
    traceback_ref,
    viterbi_classic_np,
)
from repro.kernels.traceback import (
    prefix_chunk_geometry,
    traceback_pallas,
    traceback_prefix_pallas,
)

CODE_25 = ConvCode(polys=((1, 0, 1, 1, 1), (1, 1, 1, 0, 1)))  # (2,1,5), N=16
CODE_37 = ConvCode(polys=((1, 1, 1, 1, 0, 0, 1), (1, 0, 1, 1, 0, 1, 1), (1, 1, 0, 1, 1, 0, 1)))


def _rand_y(rng, T, R, B, dtype):
    y = rng.normal(size=(T, R, B)).astype(np.float32)
    if dtype == np.float32:
        return jnp.asarray(y)
    scale = 31.75 if dtype == np.int8 else 8191.0
    return jnp.asarray(np.clip(np.round(y * scale), -127, 127).astype(dtype))


@pytest.mark.parametrize("code", [CCSDS_27, CODE_25, CODE_37], ids=["217", "215", "317"])
@pytest.mark.parametrize("dtype", [np.float32, np.int8, np.int16], ids=["f32", "i8", "i16"])
@pytest.mark.parametrize("T,B,chunk", [(64, 128, 32), (128, 128, 64), (96, 256, 32)])
def test_acs_pallas_matches_ref(code, dtype, T, B, chunk):
    rng = np.random.default_rng(hash((code.K, T, B)) % 2**31)
    y = _rand_y(rng, T, code.R, B, dtype)
    sp_r, pm_r = acs_forward_ref(y, code)
    sp_p, pm_p = acs_forward_pallas(y, code, stage_chunk=chunk, interpret=True)
    assert jnp.array_equal(sp_r, sp_p)
    if dtype == np.float32:
        np.testing.assert_allclose(np.asarray(pm_r), np.asarray(pm_p), rtol=1e-6)
    else:
        assert jnp.array_equal(pm_r, pm_p)  # integer path is exact


@pytest.mark.parametrize("code", [CCSDS_27, CODE_25], ids=["217", "215"])
@pytest.mark.parametrize("start_mode", ["zero", "argmin", "random"])
def test_traceback_pallas_matches_ref(code, start_mode):
    rng = np.random.default_rng(5)
    T, B, D, L = 128, 128, 64, 32
    y = _rand_y(rng, T, code.R, B, np.float32)
    sp, pm = acs_forward_ref(y, code)
    if start_mode == "zero":
        start = jnp.zeros((B,), jnp.int32)
    elif start_mode == "argmin":
        start = jnp.argmin(pm, axis=0).astype(jnp.int32)
    else:
        start = jnp.asarray(rng.integers(0, code.n_states, B), jnp.int32)
    b_r = traceback_ref(sp, code, L, D, start)
    b_p = traceback_pallas(sp, start, code, decode_start=L, n_decode=D, interpret=True)
    assert jnp.array_equal(b_r, b_p)


# ---------------------------------------------------------------------------
# parallel-prefix traceback: chunked survivor-map composition
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("code", [CCSDS_27, CODE_25], ids=["217", "215"])
@pytest.mark.parametrize("start_mode", ["zero", "argmin", "random"])
def test_traceback_prefix_ref_matches_serial(code, start_mode):
    rng = np.random.default_rng(11)
    T, B, D, L = 96, 8, 48, 24
    y = _rand_y(rng, T, code.R, B, np.float32)
    sp, pm = acs_forward_ref(y, code)
    start = {
        "zero": jnp.zeros((B,), jnp.int32),
        "argmin": jnp.argmin(pm, axis=0).astype(jnp.int32),
        "random": jnp.asarray(rng.integers(0, code.n_states, B), jnp.int32),
    }[start_mode]
    b_r = traceback_ref(sp, code, L, D, start)
    b_s = traceback_prefix_ref(sp, code, L, D, start)
    assert jnp.array_equal(b_r, b_s)


@pytest.mark.tier1
@pytest.mark.parametrize("code", [CCSDS_27, CODE_25], ids=["217", "215"])
@pytest.mark.parametrize("tb_chunk", [1, 7, 32, 64, 128, 200], ids=str)
def test_traceback_prefix_pallas_matches_ref(code, tb_chunk):
    """Bit-exact across divisor, non-divisor and >=T chunk sizes."""
    rng = np.random.default_rng(13)
    T, B, D, L = 128, 128, 64, 32  # decode region [32, 96): decode_start > 0
    y = _rand_y(rng, T, code.R, B, np.float32)
    sp, pm = acs_forward_ref(y, code)
    start = jnp.argmin(pm, axis=0).astype(jnp.int32)
    b_r = traceback_ref(sp, code, L, D, start)
    b_p = traceback_prefix_pallas(
        sp, start, code, decode_start=L, n_decode=D, tb_chunk=tb_chunk, interpret=True
    )
    assert jnp.array_equal(b_r, b_p)


def test_prefix_chunk_geometry_skips_dead_chunks():
    # T=128, decode region [32, 96), C=24 → pad 16, chunks of flat stages
    # [0,24) [24,48) … ; flat decode region [48, 112) → c_lo=2, c_hi=4
    C, P, n_chunks, c_lo, c_hi = prefix_chunk_geometry(128, 32, 64, 24)
    assert (C, P, n_chunks) == (24, 16, 6)
    assert (c_lo, c_hi) == (2, 4)
    # serial chain shrinks to the active-chunk walk
    assert n_chunks - c_lo == 4
    with pytest.raises(ValueError):
        prefix_chunk_geometry(128, 32, 64, 0)  # tb_chunk < 1
    with pytest.raises(ValueError):
        prefix_chunk_geometry(64, 40, 32, 16)  # decode region outside T


def test_tb_mode_eager_validation():
    y = jnp.zeros((16, 2, 4), jnp.float32)
    with pytest.raises(ValueError, match="tb_mode"):
        pbvd_decode_blocks(
            y, CCSDS_27, decode_start=4, n_decode=8, backend="ref", tb_mode="magic"
        )
    with pytest.raises(ValueError, match="tb_chunk"):
        pbvd_decode_blocks(
            y, CCSDS_27, decode_start=4, n_decode=8, backend="ref",
            tb_mode="prefix", tb_chunk=0,
        )


def test_composed_decode_pallas_matches_ref_aligned():
    """Full two-kernel decode: pallas == ref when T is chunk-aligned."""
    rng = np.random.default_rng(9)
    code = CCSDS_27
    D, L = 96, 16  # T = 128, aligned to chunk 64
    y = _rand_y(rng, D + 2 * L, code.R, 128, np.int8)
    ref = pbvd_decode_blocks(y, code, decode_start=L, n_decode=D, backend="ref")
    pal = pbvd_decode_blocks(y, code, decode_start=L, n_decode=D, backend="pallas", interpret=True)
    assert jnp.array_equal(ref, pal)


def test_lane_padding_path():
    """B not a multiple of 128 exercises the wrapper's lane padding."""
    rng = np.random.default_rng(11)
    code = CCSDS_27
    D, L = 64, 32
    y = _rand_y(rng, D + 2 * L, code.R, 40, np.float32)
    ref = pbvd_decode_blocks(y, code, decode_start=L, n_decode=D, backend="ref")
    pal = pbvd_decode_blocks(y, code, decode_start=L, n_decode=D, backend="pallas", interpret=True)
    assert pal.shape == (D, 40)
    assert jnp.array_equal(ref, pal)


@given(st.integers(0, 2**31 - 1), st.sampled_from([CCSDS_27, CODE_25]))
@settings(max_examples=8, deadline=None)
def test_property_noiseless_roundtrip(seed, code):
    """Property: on a noiseless channel, block decode recovers any payload."""
    from repro.core.encoder import encode_np, terminate

    rng = np.random.default_rng(seed)
    D, L = 64, 6 * code.K
    n = D
    bits = terminate(rng.integers(0, 2, n - code.v), code)
    coded = encode_np(bits, code)
    y = (1.0 - 2.0 * coded).astype(np.float32)  # noiseless BPSK
    yb = np.zeros((D + 2 * L, code.R, 1), np.float32)
    yb[L : L + n, :, 0] = y
    out = np.asarray(pbvd_decode_ref(jnp.asarray(yb), code, D, L))[:, 0]
    assert np.array_equal(out[:n], bits)


def test_block_decode_agrees_with_classic_va():
    """PBVD (windowed) agrees with the full-sequence VA at moderate SNR."""
    from repro.core.channel import transmit
    from repro.core.encoder import encode_jax, terminate

    code = CCSDS_27
    rng = np.random.default_rng(3)
    n = 1024
    bits = terminate(rng.integers(0, 2, n), code)
    coded = encode_jax(jnp.asarray(bits), code)
    y = transmit(jax.random.PRNGKey(0), coded, 4.0, code.rate)

    from repro.core.pbvd import PBVDConfig, decode_stream

    dec = np.asarray(decode_stream(y, n, PBVDConfig(q=None, backend="ref")))
    va = viterbi_classic_np(np.asarray(y), code, init_state=0, final_state=0)[:n]
    assert np.array_equal(dec, va)


def test_integer_path_exactness():
    """int8 and int16 quantizations of the same symbols give identical
    survivor paths when the quantized values are equal — the integer ACS
    path is bit-exact (no float reassociation)."""
    rng = np.random.default_rng(17)
    code = CCSDS_27
    y8 = _rand_y(rng, 64, code.R, 128, np.int8)
    y16 = y8.astype(jnp.int16)
    sp8, pm8 = acs_forward_ref(y8, code)
    sp16, pm16 = acs_forward_ref(y16, code)
    assert jnp.array_equal(sp8, sp16)
    assert jnp.array_equal(pm8, pm16)


# ---------------------------------------------------------------------------
# Symmetry-folded branch metrics (DESIGN.md §8)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "name",
    [
        "ccsds", "ccsds-2/3", "ccsds-3/4", "ccsds-5/6",
        "is95-k9", "is95-k9-2/3", "is95-k9-3/4", "is95-k9-5/6",
        "lte-1/3",
    ],
)
def test_folded_bm_equals_full_under_sign_expansion(name):
    """Per-stage folded table == full table for every CodeSpec, punctured
    rates included (erased symbols are exact zeros and stay BM-neutral)."""
    import zlib

    from repro.core.codespec import get_code_spec
    from repro.kernels.ref import (
        branch_metric_table,
        expand_folded_bm,
        folded_branch_metric_table,
    )

    rng = np.random.default_rng(zlib.adler32(name.encode()))
    spec = get_code_spec(name)
    code = spec.code
    T = 12
    y_punct = rng.normal(size=spec.n_symbols_for(T)).astype(np.float32)
    y = spec.depuncture_stream(jnp.asarray(y_punct), T)  # (T, R), zeros erased
    full = branch_metric_table(y, code)
    folded = folded_branch_metric_table(y, code)
    assert folded.shape[-1] == code.n_folded == (1 << (code.R - 1))
    assert jnp.array_equal(expand_folded_bm(folded, code), full)
    # erased (zero) symbols are BM-neutral: flipping an erased codeword bit
    # cannot change any metric
    if spec.is_punctured:
        full_np = np.asarray(full)
        y_np = np.asarray(y)
        erased = np.nonzero(y_np == 0.0)  # (t, r) erased slots
        for t, r in zip(*erased):
            bit = 1 << (code.R - 1 - r)
            for c in range(1 << code.R):
                assert full_np[t, c] == full_np[t, c ^ bit]


@pytest.mark.parametrize("code", [CCSDS_27, CODE_25, CODE_37], ids=["217", "215", "317"])
@pytest.mark.parametrize("dtype", [np.float32, np.int8], ids=["f32", "i8"])
def test_folded_acs_bit_exact_vs_full(code, dtype):
    """The folded ACS path (the hot path) is bit-exact to the full-BM path."""
    rng = np.random.default_rng(23)
    y = _rand_y(rng, 96, code.R, 128, dtype)
    sp_f, pm_f = acs_forward_ref(y, code, fold=True)
    sp_u, pm_u = acs_forward_ref(y, code, fold=False)
    assert jnp.array_equal(sp_f, sp_u)
    assert jnp.array_equal(pm_f, pm_u)  # exact even in f32: ± rounding symmetry


@pytest.mark.parametrize("start_policy", ["zero", "argmin"])
def test_folded_decode_bit_exact_vs_full_decode(start_policy):
    """Composed decode through the folded kernels == decode on the full
    table (ref fold=False ACS + shared traceback), per start policy."""
    rng = np.random.default_rng(29)
    code = CCSDS_27
    D, L = 64, 32
    y = _rand_y(rng, D + 2 * L, code.R, 96, np.float32)
    sp, pm = acs_forward_ref(y, code, fold=False)
    if start_policy == "argmin":
        start = jnp.argmin(pm, axis=0).astype(jnp.int32)
    else:
        start = jnp.zeros((y.shape[2],), jnp.int32)
    full_bits = traceback_ref(sp, code, L, D, start)
    for backend in ["ref", "pallas"] + (["fused"] if start_policy == "zero" else []):
        got = pbvd_decode_blocks(
            y, code, decode_start=L, n_decode=D, backend=backend,
            start_policy=start_policy, interpret=True,
        )
        assert jnp.array_equal(got, full_bits), backend


# ---------------------------------------------------------------------------
# Narrow metric pipeline: the saturation contract (registry.METRIC_MODES)
# ---------------------------------------------------------------------------
def _normalized_acs_max_transient(y, code, norm_every):
    """Numpy shadow of the normalized integer ACS (int64 — cannot wrap);
    returns the largest |metric| ever formed across all stages, normalizing
    at the same cadence the production kernels use."""
    T, R, B = y.shape
    signs = code.codeword_signs.astype(np.int64)
    cw = code.butterfly_codewords
    pm = np.zeros((code.n_states, B), np.int64)
    max_abs = 0
    for t in range(T):
        bm = signs @ y[t].astype(np.int64)
        pe, po = pm[0::2], pm[1::2]
        m_te, m_to = pe + bm[cw[:, 0]], po + bm[cw[:, 2]]
        m_be, m_bo = pe + bm[cw[:, 1]], po + bm[cw[:, 3]]
        max_abs = max(
            max_abs,
            int(np.abs(np.concatenate([m_te, m_to, m_be, m_bo])).max()),
        )
        pm = np.concatenate([np.minimum(m_te, m_to), np.minimum(m_be, m_bo)])
        if t % norm_every == norm_every - 1:
            pm = pm - pm.min(axis=0, keepdims=True)
    return max_abs


def _adversarial_stream(rng, T, R, B, qmax):
    """Worst-case-seeking stream: extreme ±qmax symbols (random, constant
    runs, and alternating runs — the patterns that pump the PM spread)."""
    thirds = T // 3
    a = rng.choice([-qmax, qmax], size=(thirds, R, B))
    b = np.full((thirds, R, B), qmax)
    c = np.tile(
        np.array([qmax, -qmax]).repeat(R * B).reshape(2, R, B),
        (T - 2 * thirds + 1) // 2 + 1,
    ).reshape(-1, R, B)[: T - 2 * thirds]
    return np.concatenate([a, b, c]).astype(np.int64)


@pytest.mark.parametrize(
    "metric_mode,dtype_max", [("i16", 32767), ("i8", 127)], ids=["i16", "i8"]
)
@pytest.mark.parametrize("code", [CCSDS_27, CODE_37], ids=["217", "317"])
def test_narrow_pm_never_saturates_10k_stages(code, metric_mode, dtype_max):
    """10k adversarial stages: every transient metric stays within the
    documented budget (< dtype max), and the narrow jnp path's decisions
    stay bit-exact to unbounded int32 accumulation — saturation never
    occurred."""
    from repro.core.quantize import (
        max_symbol_bits,
        metric_mode_qmax,
        norm_interval,
        pm_spread_bound,
    )

    q = max_symbol_bits(code, dtype_max)
    qmax = (1 << (q - 1)) - 1
    assert qmax == metric_mode_qmax(code, metric_mode)
    k = norm_interval(code, metric_mode)
    budget = pm_spread_bound(code, qmax, k)
    assert budget <= dtype_max  # the contract is satisfiable at this (q, k)

    rng = np.random.default_rng(41)
    T, B = 10_000, 2
    y = _adversarial_stream(rng, T, code.R, B, qmax)

    # numpy shadow tracks the true transient maximum over all 10k stages at
    # the production cadence
    max_abs = _normalized_acs_max_transient(y, code, k)
    assert max_abs <= budget, f"transient {max_abs} exceeds budget {budget}"

    # the narrow jnp pipeline agrees with unbounded int32 accumulation
    yj = jnp.asarray(y.astype(np.int8 if qmax <= 127 else np.int16))
    sp_narrow, pm_narrow = acs_forward_ref(yj, code, metric_mode=metric_mode)
    sp_wide, _ = acs_forward_ref(yj.astype(jnp.int32), code, metric_mode="f32")
    assert jnp.array_equal(sp_narrow, sp_wide)
    assert int(jnp.max(jnp.abs(pm_narrow))) <= budget


# ---------------------------------------------------------------------------
# Stage-fused radix-4 ACS (DESIGN.md §10, registry.ACS_RADIX)
# ---------------------------------------------------------------------------
def test_radix4_trellis_tables():
    """Collapsed two-stage tables vs brute-force transition enumeration, and
    the combined-label fold identity BM2(cc) = BM(c1) + BM(c2)."""
    for code in (CCSDS_27, CODE_25, CODE_37):
        N, half, Q, v = code.n_states, code.n_states // 2, code.n_states // 4, code.v
        tabs = code.radix4_acs_tables
        for n in range(N):
            k, q = n // Q, n % Q
            assert (k >> 1, k & 1) == (n >> (v - 1), (n >> (v - 2)) & 1)
            for bm in (0, 1):
                m = 2 * (n % half) + bm
                assert ((n >> (v - 1)) << (v - 1)) | (m >> 1) == n  # m → n valid
                assert tabs["c2"][k, bm, q] == code.output_int(m, n >> (v - 1))
                for bp in (0, 1):
                    p = 2 * (m % half) + bp
                    assert p == code.radix4_preds[n, 2 * bm + bp]
                    c1 = code.output_int(p, k & 1)
                    assert tabs["c1"][k & 1, 2 * bm + bp, q] == c1
                    cc = (c1 << code.R) | tabs["c2"][k, bm, q]
                    assert tabs["cc"][k, 2 * bm + bp, q] == cc
        # fold identity over random symbols
        rng = np.random.default_rng(code.K)
        y2 = rng.normal(size=2 * code.R).astype(np.float32)
        bm2f = code.folded_radix4_codeword_signs @ y2
        bm2 = code.fold_sign4 * bm2f[code.fold_index4]
        bm_t = code.codeword_signs @ y2[: code.R]
        bm_t1 = code.codeword_signs @ y2[code.R :]
        assert code.n_folded4 == 1 << (2 * code.R - 1)
        for cc in range(1 << (2 * code.R)):
            np.testing.assert_allclose(
                bm2[cc], bm_t[cc >> code.R] + bm_t1[cc & ((1 << code.R) - 1)],
                rtol=1e-6, atol=1e-6,
            )


@pytest.mark.parametrize("code", [CCSDS_27, CODE_25, CODE_37], ids=["217", "215", "317"])
@pytest.mark.parametrize("dtype,metric_mode", [(np.float32, "f32"), (np.int8, "f32"), (np.int8, "i16")], ids=["f32", "int", "i16"])
@pytest.mark.parametrize("T", [96, 77], ids=["evenT", "oddT"])
def test_acs_radix4_ref_matches_radix2(code, dtype, metric_mode, T):
    """Survivor bit-planes are bit-identical between radixes; f32 path
    metrics are bit-identical too (same IEEE op sequence); narrow-mode
    metrics differ only by a per-lane uniform shift (argmin-invariant)."""
    rng = np.random.default_rng(hash((code.K, T)) % 2**31)
    y = _rand_y(rng, T, code.R, 8, dtype)
    sp2, pm2 = acs_forward_ref(y, code, metric_mode=metric_mode, radix=2)
    sp4, pm4 = acs_forward_ref(y, code, metric_mode=metric_mode, radix=4)
    assert jnp.array_equal(sp2, sp4)
    if metric_mode == "f32":
        assert jnp.array_equal(pm2, pm4)
    else:
        shift = np.asarray(pm4 - pm2)
        assert (shift == shift[0:1]).all()  # uniform per lane
        assert (np.argmin(np.asarray(pm2), 0) == np.argmin(np.asarray(pm4), 0)).all()


@pytest.mark.parametrize("code", [CCSDS_27, CODE_37], ids=["217", "317"])
def test_acs_radix4_combined_formulation_exact(code):
    """The combined 2^(2R-1)-folded-metric form of the fused step (integer
    accumulators) is bit-identical to the staged form and to radix 2 — in
    BOTH implementations: the jnp gather idiom (ref) and the Pallas
    run-length-row idiom (radix4_stage_pair(combine=True))."""
    from repro.kernels.acs import radix4_stage_pair

    rng = np.random.default_rng(31)
    y = _rand_y(rng, 64, code.R, 8, np.int8)
    sp2, pm2 = acs_forward_ref(y, code, radix=2)
    sp4s, pm4s = acs_forward_ref(y, code, radix=4, r4_combine=False)
    sp4c, pm4c = acs_forward_ref(y, code, radix=4, r4_combine=True)
    assert jnp.array_equal(sp2, sp4s) and jnp.array_equal(sp2, sp4c)
    assert jnp.array_equal(pm2, pm4s) and jnp.array_equal(pm2, pm4c)

    # the Pallas row idiom is a pure jnp function — drive both its forms
    # step by step against the staged reference
    B = 8
    pm = jnp.zeros((code.n_states, B), jnp.int32)
    for t in range(0, 8, 2):
        y0 = y[t].astype(jnp.int32)
        y1 = y[t + 1].astype(jnp.int32)
        pm_s, d1_s, d2_s = radix4_stage_pair(pm, y0, y1, code, jnp.int32, B, combine=False)
        pm_c, d1_c, d2_c = radix4_stage_pair(pm, y0, y1, code, jnp.int32, B, combine=True)
        assert jnp.array_equal(pm_s, pm_c)
        assert jnp.array_equal(d1_s, d1_c) and jnp.array_equal(d2_s, d2_c)
        pm = pm_s


@pytest.mark.parametrize("code", [CCSDS_27, CODE_37], ids=["217", "317"])
@pytest.mark.parametrize("dtype,metric_mode", [(np.float32, "f32"), (np.int8, "i8")], ids=["f32", "i8"])
def test_acs_pallas_radix4_matches_ref(code, dtype, metric_mode):
    rng = np.random.default_rng(hash((code.K, 4)) % 2**31)
    T, B, chunk = 96, 128, 32
    y = _rand_y(rng, T, code.R, B, dtype)
    sp_r, pm_r = acs_forward_ref(y, code, metric_mode=metric_mode, radix=4)
    sp_p, pm_p = acs_forward_pallas(
        y, code, stage_chunk=chunk, interpret=True, metric_mode=metric_mode, radix=4
    )
    assert jnp.array_equal(sp_r, sp_p)
    if dtype == np.float32:
        np.testing.assert_allclose(np.asarray(pm_r), np.asarray(pm_p), rtol=1e-6)
    else:
        assert jnp.array_equal(pm_r, pm_p)  # same global step cadence → exact


def test_acs_radix4_eager_validation():
    """Unsupported radixes and geometries fail pre-jit with clear errors."""
    y = jnp.zeros((16, 2, 4), jnp.float32)
    with pytest.raises(ValueError, match="acs_radix"):
        pbvd_decode_blocks(y, CCSDS_27, decode_start=4, n_decode=8, backend="ref", acs_radix=3)
    with pytest.raises(ValueError, match="even stage_chunk"):
        acs_forward_pallas(
            jnp.zeros((66, 2, 128), jnp.float32), CCSDS_27, stage_chunk=33, radix=4,
            interpret=True,
        )
    tiny = ConvCode(polys=((1, 1), (1, 0)))  # K=2: no radix-4 trellis
    with pytest.raises(ValueError, match="K >= 3"):
        pbvd_decode_blocks(y, tiny, decode_start=4, n_decode=8, backend="ref", acs_radix=4)


def test_norm_interval_radix_budget_validation():
    """A code/mode pair whose budget cannot absorb two unnormalized stages
    is rejected at config time (norm_interval ValueError), not saturated."""
    from repro.core.quantize import norm_interval, pm_spread_bound, metric_mode_qmax
    from repro.core.pbvd import PBVDConfig

    # K=11, R=2: i8's widest q is 3 (qmax 3) and (2v+1)·R·qmax = 126 ≤ 127
    # but (2v+2)·R·qmax = 132 > 127 — radix-2 legal, radix-4 impossible
    k11 = ConvCode(polys=(tuple([1] * 11), tuple([1] + [0] * 9 + [1])))
    qmax = metric_mode_qmax(k11, "i8")
    assert pm_spread_bound(k11, qmax, 1) <= 127 < pm_spread_bound(k11, qmax, 2)
    assert norm_interval(k11, "i8") == 1  # radix-2 cadence exists
    with pytest.raises(ValueError, match="acs_radix=4"):
        norm_interval(k11, "i8", 4)
    with pytest.raises(ValueError, match="acs_radix=4"):
        PBVDConfig(code=k11, metric_mode="i8", acs_radix=4)  # config time
    with pytest.raises(ValueError, match="acs_radix=4"):
        pbvd_decode_blocks(
            jnp.zeros((16, 2, 4), jnp.int8), k11, decode_start=4, n_decode=8,
            backend="ref", metric_mode="i8", acs_radix=4,
        )
    # the same code/mode at radix 2 passes every gate
    PBVDConfig(code=k11, metric_mode="i8", acs_radix=2)


@pytest.mark.parametrize(
    "metric_mode,dtype_max", [("i16", 32767), ("i8", 127)], ids=["i16", "i8"]
)
@pytest.mark.parametrize("code", [CCSDS_27, CODE_37], ids=["217", "317"])
def test_narrow_pm_never_saturates_radix4_cadence(code, metric_mode, dtype_max):
    """10k adversarial stages at the RE-DERIVED radix-4 cadence: the doubled
    per-step accumulation stays within the documented budget, and the narrow
    radix-4 path's decisions stay bit-exact to unbounded accumulation."""
    from repro.core.quantize import metric_mode_qmax, norm_interval, pm_spread_bound

    qmax = metric_mode_qmax(code, metric_mode)
    k_steps = norm_interval(code, metric_mode, 4)  # cadence in FUSED steps
    budget = pm_spread_bound(code, qmax, 2 * k_steps)  # 2 stages per step
    assert budget <= dtype_max  # the re-derived cadence satisfies the bound

    rng = np.random.default_rng(43)
    T, B = 10_000, 2
    y = _adversarial_stream(rng, T, code.R, B, qmax)

    # numpy shadow at the radix-4 normalization points (stage cadence 2k,
    # firing after the second stage of every k-th fused step)
    max_abs = _normalized_acs_max_transient(y, code, 2 * k_steps)
    assert max_abs <= budget, f"transient {max_abs} exceeds budget {budget}"

    yj = jnp.asarray(y.astype(np.int8 if qmax <= 127 else np.int16))
    sp_narrow, pm_narrow = acs_forward_ref(yj, code, metric_mode=metric_mode, radix=4)
    sp_wide, _ = acs_forward_ref(yj.astype(jnp.int32), code, metric_mode="f32", radix=2)
    assert jnp.array_equal(sp_narrow, sp_wide)
    assert int(jnp.max(jnp.abs(pm_narrow))) <= budget


def test_tb_mode_auto_resolution():
    """tb_mode="auto" resolves to each backend's declared fastest mode, the
    resolved decode is bit-exact to spelling the mode out, and the registry
    rejects a preferred mode outside tb_modes."""
    from repro.kernels.ops import (
        backend_preferred_tb_mode,
        register_backend,
        resolve_tb_mode,
    )

    for backend in ("ref", "pallas", "fused"):
        preferred = backend_preferred_tb_mode(backend)
        assert resolve_tb_mode(backend, "auto") == preferred
        assert resolve_tb_mode(backend, "prefix") == "prefix"  # pass-through

    rng = np.random.default_rng(53)
    y = _rand_y(rng, 128, CCSDS_27.R, 40, np.float32)
    for backend in ("ref", "pallas", "fused"):
        auto = pbvd_decode_blocks(
            y, CCSDS_27, decode_start=32, n_decode=64, backend=backend,
            tb_mode="auto", interpret=True,
        )
        explicit = pbvd_decode_blocks(
            y, CCSDS_27, decode_start=32, n_decode=64, backend=backend,
            tb_mode=backend_preferred_tb_mode(backend), interpret=True,
        )
        assert jnp.array_equal(auto, explicit), backend

    with pytest.raises(ValueError, match="preferred_tb_mode"):
        register_backend("bogus-auto", tb_modes=("serial",), preferred_tb_mode="prefix")(
            lambda *a, **k: None
        )


def test_narrow_pm_rejects_float_symbols():
    """i16/i8 need pre-quantized integers; float symbols fail loudly."""
    y = jnp.zeros((8, 2, 4), jnp.float32)
    with pytest.raises(ValueError, match="pre-quantized"):
        acs_forward_ref(y, CCSDS_27, metric_mode="i16")


def test_narrow_pm_saturates_out_of_budget_symbols():
    """Pre-quantized symbols beyond the mode's budget are CLIPPED on
    ingestion, not wrapped: q=8 symbols through i8 decode like the exact
    path on the clipped (±qmax) symbols — degraded, never garbage."""
    from repro.core.quantize import metric_mode_qmax

    rng = np.random.default_rng(47)
    code = CCSDS_27
    y8 = _rand_y(rng, 64, code.R, 128, np.int8)  # |y| up to 127 ≫ budget (3)
    qm = metric_mode_qmax(code, "i8")
    sp_i8, pm_i8 = acs_forward_ref(y8, code, metric_mode="i8")
    sp_ref, _ = acs_forward_ref(jnp.clip(y8, -qm, qm), code, metric_mode="f32")
    assert jnp.array_equal(sp_i8, sp_ref)
    assert int(jnp.max(jnp.abs(pm_i8))) <= 127  # no wrap
