"""Per-kernel allclose tests: Pallas (interpret mode) vs the pure-jnp oracle.

Sweeps shapes, dtypes and stage-chunkings per the assignment requirements.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.trellis import CCSDS_27, ConvCode
from repro.kernels.acs import acs_forward_pallas
from repro.kernels.ops import pbvd_decode_blocks
from repro.kernels.ref import acs_forward_ref, pbvd_decode_ref, traceback_ref, viterbi_classic_np
from repro.kernels.traceback import traceback_pallas

CODE_25 = ConvCode(polys=((1, 0, 1, 1, 1), (1, 1, 1, 0, 1)))  # (2,1,5), N=16
CODE_37 = ConvCode(polys=((1, 1, 1, 1, 0, 0, 1), (1, 0, 1, 1, 0, 1, 1), (1, 1, 0, 1, 1, 0, 1)))


def _rand_y(rng, T, R, B, dtype):
    y = rng.normal(size=(T, R, B)).astype(np.float32)
    if dtype == np.float32:
        return jnp.asarray(y)
    scale = 31.75 if dtype == np.int8 else 8191.0
    return jnp.asarray(np.clip(np.round(y * scale), -127, 127).astype(dtype))


@pytest.mark.parametrize("code", [CCSDS_27, CODE_25, CODE_37], ids=["217", "215", "317"])
@pytest.mark.parametrize("dtype", [np.float32, np.int8, np.int16], ids=["f32", "i8", "i16"])
@pytest.mark.parametrize("T,B,chunk", [(64, 128, 32), (128, 128, 64), (96, 256, 32)])
def test_acs_pallas_matches_ref(code, dtype, T, B, chunk):
    rng = np.random.default_rng(hash((code.K, T, B)) % 2**31)
    y = _rand_y(rng, T, code.R, B, dtype)
    sp_r, pm_r = acs_forward_ref(y, code)
    sp_p, pm_p = acs_forward_pallas(y, code, stage_chunk=chunk, interpret=True)
    assert jnp.array_equal(sp_r, sp_p)
    if dtype == np.float32:
        np.testing.assert_allclose(np.asarray(pm_r), np.asarray(pm_p), rtol=1e-6)
    else:
        assert jnp.array_equal(pm_r, pm_p)  # integer path is exact


@pytest.mark.parametrize("code", [CCSDS_27, CODE_25], ids=["217", "215"])
@pytest.mark.parametrize("start_mode", ["zero", "argmin", "random"])
def test_traceback_pallas_matches_ref(code, start_mode):
    rng = np.random.default_rng(5)
    T, B, D, L = 128, 128, 64, 32
    y = _rand_y(rng, T, code.R, B, np.float32)
    sp, pm = acs_forward_ref(y, code)
    if start_mode == "zero":
        start = jnp.zeros((B,), jnp.int32)
    elif start_mode == "argmin":
        start = jnp.argmin(pm, axis=0).astype(jnp.int32)
    else:
        start = jnp.asarray(rng.integers(0, code.n_states, B), jnp.int32)
    b_r = traceback_ref(sp, code, L, D, start)
    b_p = traceback_pallas(sp, start, code, decode_start=L, n_decode=D, interpret=True)
    assert jnp.array_equal(b_r, b_p)


def test_composed_decode_pallas_matches_ref_aligned():
    """Full two-kernel decode: pallas == ref when T is chunk-aligned."""
    rng = np.random.default_rng(9)
    code = CCSDS_27
    D, L = 96, 16  # T = 128, aligned to chunk 64
    y = _rand_y(rng, D + 2 * L, code.R, 128, np.int8)
    ref = pbvd_decode_blocks(y, code, decode_start=L, n_decode=D, backend="ref")
    pal = pbvd_decode_blocks(y, code, decode_start=L, n_decode=D, backend="pallas", interpret=True)
    assert jnp.array_equal(ref, pal)


def test_lane_padding_path():
    """B not a multiple of 128 exercises the wrapper's lane padding."""
    rng = np.random.default_rng(11)
    code = CCSDS_27
    D, L = 64, 32
    y = _rand_y(rng, D + 2 * L, code.R, 40, np.float32)
    ref = pbvd_decode_blocks(y, code, decode_start=L, n_decode=D, backend="ref")
    pal = pbvd_decode_blocks(y, code, decode_start=L, n_decode=D, backend="pallas", interpret=True)
    assert pal.shape == (D, 40)
    assert jnp.array_equal(ref, pal)


@given(st.integers(0, 2**31 - 1), st.sampled_from([CCSDS_27, CODE_25]))
@settings(max_examples=8, deadline=None)
def test_property_noiseless_roundtrip(seed, code):
    """Property: on a noiseless channel, block decode recovers any payload."""
    from repro.core.encoder import encode_np, terminate

    rng = np.random.default_rng(seed)
    D, L = 64, 6 * code.K
    n = D
    bits = terminate(rng.integers(0, 2, n - code.v), code)
    coded = encode_np(bits, code)
    y = (1.0 - 2.0 * coded).astype(np.float32)  # noiseless BPSK
    yb = np.zeros((D + 2 * L, code.R, 1), np.float32)
    yb[L : L + n, :, 0] = y
    out = np.asarray(pbvd_decode_ref(jnp.asarray(yb), code, D, L))[:, 0]
    assert np.array_equal(out[:n], bits)


def test_block_decode_agrees_with_classic_va():
    """PBVD (windowed) agrees with the full-sequence VA at moderate SNR."""
    from repro.core.channel import transmit
    from repro.core.encoder import encode_jax, terminate

    code = CCSDS_27
    rng = np.random.default_rng(3)
    n = 1024
    bits = terminate(rng.integers(0, 2, n), code)
    coded = encode_jax(jnp.asarray(bits), code)
    y = transmit(jax.random.PRNGKey(0), coded, 4.0, code.rate)

    from repro.core.pbvd import PBVDConfig, decode_stream

    dec = np.asarray(decode_stream(y, n, PBVDConfig(q=None, backend="ref")))
    va = viterbi_classic_np(np.asarray(y), code, init_state=0, final_state=0)[:n]
    assert np.array_equal(dec, va)


def test_integer_path_exactness():
    """int8 and int16 quantizations of the same symbols give identical
    survivor paths when the quantized values are equal — the integer ACS
    path is bit-exact (no float reassociation)."""
    rng = np.random.default_rng(17)
    code = CCSDS_27
    y8 = _rand_y(rng, 64, code.R, 128, np.int8)
    y16 = y8.astype(jnp.int16)
    sp8, pm8 = acs_forward_ref(y8, code)
    sp16, pm16 = acs_forward_ref(y16, code)
    assert jnp.array_equal(sp8, sp16)
    assert jnp.array_equal(pm8, pm16)
