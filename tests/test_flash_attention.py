"""Flash-attention Pallas kernel vs oracle — shape/dtype/mask sweeps."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro._unused.flash_attention import flash_attention, ref_mha


def _mk(rng, B, S, T, Hkv, G, dh, dtype):
    q = jnp.asarray(rng.normal(size=(B, S, Hkv, G, dh)).astype(dtype))
    k = jnp.asarray(rng.normal(size=(B, T, Hkv, dh)).astype(dtype))
    v = jnp.asarray(rng.normal(size=(B, T, Hkv, dh)).astype(dtype))
    return q, k, v


@pytest.mark.parametrize("B,S,Hkv,G,dh", [(2, 128, 2, 1, 32), (1, 256, 1, 4, 64), (2, 64, 4, 2, 16)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_ref(B, S, Hkv, G, dh, causal):
    rng = np.random.default_rng(0)
    q, k, v = _mk(rng, B, S, S, Hkv, G, dh, np.float32)
    out = flash_attention(q, k, v, causal=causal, q_chunk=64, kv_chunk=64, interpret=True)
    ref = ref_mha(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=1e-5)


def test_flash_sliding_window():
    rng = np.random.default_rng(1)
    q, k, v = _mk(rng, 1, 128, 128, 2, 2, 32, np.float32)
    out = flash_attention(q, k, v, causal=True, window=32, q_chunk=32, kv_chunk=32, interpret=True)
    ref = ref_mha(q, k, v, causal=True, window=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=1e-5)


def test_flash_bf16():
    rng = np.random.default_rng(2)
    q, k, v = _mk(rng, 1, 128, 128, 2, 2, 64, np.float32)
    qb, kb, vb = (a.astype(jnp.bfloat16) for a in (q, k, v))
    out = flash_attention(qb, kb, vb, causal=True, q_chunk=64, kv_chunk=64, interpret=True)
    ref = ref_mha(q, k, v, causal=True)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref), atol=3e-2, rtol=3e-2
    )


def test_flash_cross_lengths():
    """T != S (cross/prefix attention, non-causal)."""
    rng = np.random.default_rng(3)
    q, k, v = _mk(rng, 1, 64, 64, 2, 2, 32, np.float32)
    k = jnp.asarray(rng.normal(size=(1, 128, 2, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 128, 2, 32)).astype(np.float32))
    out = flash_attention(q, k, v, causal=False, q_chunk=32, kv_chunk=64, interpret=True)
    ref = ref_mha(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=1e-5)


def test_flash_matches_model_streaming_path():
    """The kernel agrees with the model's XLA streaming attention (which
    stores the probability tensor in bf16 — §Perf iteration — hence the
    bf16-level tolerance)."""
    from repro._unused.models.attention import _attend_chunked

    rng = np.random.default_rng(4)
    q, k, v = _mk(rng, 2, 128, 128, 2, 2, 32, np.float32)
    out = flash_attention(q, k, v, causal=True, q_chunk=64, kv_chunk=64, interpret=True)
    ref = _attend_chunked(
        q, k, v, causal=True, window=None, scale=1.0 / math.sqrt(32), kv_chunk=64
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref, np.float32), atol=2e-2)
