"""Multi-device semantics tests.

Each test runs in a subprocess with ``--xla_force_host_platform_device_count=8``
(the main pytest process stays single-device, per the assignment's rule that
only the dry-run sees fake devices).
"""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

pytestmark = pytest.mark.slow  # subprocess-per-test; excluded from tier-1 runs

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(snippet: str, n_dev: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = SRC
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(snippet)],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-3000:]}"
    return proc.stdout


def test_sharded_decode_stream_matches_unsharded():
    """PBVD distributed decode (blocks sharded over data axis) is bit-identical
    to the single-device decode — zero-collective block parallelism."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.pbvd import PBVDConfig, decode_stream, decode_stream_sharded
        from repro.core.encoder import encode_jax, terminate
        from repro.core.channel import transmit
        from repro.core.trellis import CCSDS_27

        code = CCSDS_27
        rng = np.random.default_rng(0)
        n = 8192
        bits = terminate(rng.integers(0, 2, n), code)
        y = transmit(jax.random.PRNGKey(1), encode_jax(jnp.asarray(bits), code), 4.0, code.rate)
        cfg = PBVDConfig(q=8, backend="ref")
        ref = np.asarray(decode_stream(y, n, cfg))
        mesh = jax.make_mesh((8,), ("data",))
        out = np.asarray(decode_stream_sharded(y, n, cfg, mesh))
        assert np.array_equal(ref, out), "sharded decode diverged"
        print("ok")
    """)


def test_sharded_train_step_matches_single_device():
    """pjit train step on a 4×2 mesh reproduces single-device numerics."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs.base import get_config
        from repro._unused.models import lm
        from repro.sharding.rules import axis_rules, tree_shardings
        from repro._unused.train.optimizer import AdamWConfig, adamw_init
        from repro._unused.train.train_step import make_train_step

        cfg = dataclasses.replace(get_config("minitron-8b").reduced(), compute_dtype="float32")
        opt_cfg = AdamWConfig(warmup_steps=1, total_steps=10)
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        opt = adamw_init(params, opt_cfg)
        rng = np.random.default_rng(0)
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)), jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)), jnp.int32),
        }
        step = make_train_step(cfg, opt_cfg)
        p1, o1, m1 = jax.jit(step)(params, opt, batch)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        with axis_rules(mesh) as rules:
            paxes = lm.param_axes(cfg)
            pshard = tree_shardings(params, paxes, rules)
            params_s = jax.tree.map(jax.device_put, params, pshard)
            opt_s = adamw_init(params_s, opt_cfg)
            p2, o2, m2 = jax.jit(step)(params_s, opt_s, batch)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4, (m1["loss"], m2["loss"])
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)
        print("ok", float(m1["loss"]))
    """)


def test_pipeline_parallel_matches_sequential():
    """GPipe pipeline over 4 stages == sequential stage composition."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.pp import pipeline_apply, bubble_fraction

        mesh = jax.make_mesh((4,), ("pipe",))
        P, M, mb, d = 4, 6, 2, 16
        rng = np.random.default_rng(0)
        ws = jnp.asarray(rng.normal(size=(P, d, d)).astype(np.float32) * 0.3)
        x = jnp.asarray(rng.normal(size=(M, mb, d)).astype(np.float32))

        def stage(w, h):
            return jnp.tanh(h @ w)

        out = pipeline_apply(stage, ws, x, mesh, axis="pipe")
        ref = x
        for s in range(P):
            ref = jnp.tanh(ref @ ws[s])
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
        assert abs(bubble_fraction(4, 6) - 3/9) < 1e-9
        print("ok")
    """)


def test_dryrun_smoke_tiny_mesh():
    """The dry-run machinery itself (specs → shardings → lower → analyze)
    works on an 8-device mesh with a reduced config."""
    _run("""
        import jax, jax.numpy as jnp
        from repro.configs.base import get_config
        from repro._unused.models import lm
        from repro.sharding.rules import axis_rules, tree_shardings
        from repro.launch.hlo_analysis import analyze_hlo
        from repro._unused.train.optimizer import AdamWConfig, adamw_init, OptState
        from repro._unused.train.train_step import make_train_step

        cfg = get_config("mixtral-8x22b").reduced()
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        with axis_rules(mesh) as rules:
            params_sds = jax.eval_shape(lambda k: lm.init_params(k, cfg), jax.random.PRNGKey(0))
            pshard = tree_shardings(params_sds, lm.param_axes(cfg), rules)
            opt_cfg = AdamWConfig()
            opt_sds = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params_sds)
            repl = jax.NamedSharding(mesh, jax.sharding.PartitionSpec())
            oshard = OptState(step=repl, m=pshard, v=pshard)
            batch = {
                "tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
                "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32),
            }
            bshard = {k: jax.NamedSharding(mesh, rules.spec(("batch", None))) for k in batch}
            step = make_train_step(cfg, opt_cfg)
            compiled = jax.jit(
                step, in_shardings=(pshard, oshard, bshard),
                out_shardings=(pshard, oshard, None),
            ).lower(params_sds, opt_sds, batch).compile()
            st = analyze_hlo(compiled.as_text())
            assert st.flops > 0
            assert st.total_collective_bytes > 0, "expected collectives on a 4x2 mesh"
            ma = compiled.memory_analysis()
            assert ma is not None
        print("ok", st.flops, st.total_collective_bytes)
    """)


def test_mesh_decode_parity_matrix():
    """The acceptance matrix: on 8 host devices, ``decode`` and
    ``decode_batch`` are bit-identical with and without a ``data=8`` mesh,
    across backends × metric modes × both shard dispatches, for a ragged
    fleet whose block count does not divide the shard count."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.channel import transmit
        from repro.core.codespec import get_code_spec
        from repro.core.encoder import encode_jax, terminate
        from repro.core.engine import DecoderEngine
        from repro.core.pbvd import PBVDConfig
        from repro.launch.mesh import make_decode_mesh

        assert len(jax.devices()) == 8
        spec = get_code_spec("ccsds")

        def tx(n, seed):
            rng = np.random.default_rng(seed)
            bits = terminate(rng.integers(0, 2, n), spec.code)
            return transmit(
                jax.random.PRNGKey(seed),
                encode_jax(jnp.asarray(bits), spec.code), 4.5, spec.rate,
            )

        lens = [96, 190, 96, 250, 128]  # ragged: 10 blocks, not 8-divisible
        ys = [tx(n, 30 + i) for i, n in enumerate(lens)]
        mesh = make_decode_mesh("data=8")
        cases = [("ref", "f32"), ("ref", "i8"), ("pallas", "f32"),
                 ("pallas", "i8"), ("fused", "f32"), ("fused", "i8")]
        for backend, mm in cases:
            cfg = PBVDConfig(spec=spec, D=64, L=16, q=8,
                             backend=backend, metric_mode=mm)
            base = DecoderEngine(cfg)
            refs = [np.asarray(b) for b in base.decode_batch(ys, lens)]
            ref1 = np.asarray(base.decode(ys[1], lens[1]))
            for dispatch in ("constraint", "shard_map"):
                tag = (backend, mm, dispatch)
                eng = DecoderEngine(cfg, mesh=mesh, shard_dispatch=dispatch)
                assert eng.n_shards == 8, tag
                for r, o in zip(refs, eng.decode_batch(ys, lens)):
                    assert np.array_equal(r, np.asarray(o)), tag
                assert np.array_equal(ref1, np.asarray(eng.decode(ys[1], lens[1]))), tag
                print("ok", *tag)
    """, timeout=1800)


def test_mesh_pooled_step_parity_and_streaming():
    """Pooled sessions on a sharded engine (both dispatches, mixed with a
    meshless engine in the same pool) stream bit-identically to the solo
    unsharded decode, under a ragged chunk cadence."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.channel import transmit
        from repro.core.codespec import get_code_spec
        from repro.core.encoder import encode_jax, terminate
        from repro.core.engine import DecoderEngine
        from repro.core.pbvd import PBVDConfig
        from repro.launch.mesh import make_decode_mesh
        from repro.launch.serve_decoder import SessionPool

        spec = get_code_spec("ccsds")
        n = 512
        rng = np.random.default_rng(7)
        bits = terminate(rng.integers(0, 2, n), spec.code)
        y = np.asarray(transmit(
            jax.random.PRNGKey(7), encode_jax(jnp.asarray(bits), spec.code),
            4.5, spec.rate,
        ))
        cfg = PBVDConfig(spec=spec, D=64, L=16, q=8, backend="ref")
        ref = np.asarray(DecoderEngine(cfg).decode(jnp.asarray(y), n))

        mesh = make_decode_mesh("data=8")
        engines = [
            DecoderEngine(cfg),
            DecoderEngine(cfg, mesh=mesh),
            DecoderEngine(cfg, mesh=mesh, shard_dispatch="shard_map"),
        ]
        pool = SessionPool()
        handles = [pool.open(e) for e in engines]
        pos, outs = [0] * len(handles), [[] for _ in handles]
        crng = np.random.default_rng(1)
        while any(p < len(y) for p in pos):
            for i, h in enumerate(handles):
                if pos[i] < len(y):
                    step = int(crng.integers(40, 300))
                    h.feed(y[pos[i]:pos[i] + step])
                    pos[i] += step
            pool.step()
            for i, h in enumerate(handles):
                outs[i].append(h.take())
        for i, h in enumerate(handles):
            outs[i].append(h.finish(n))
            got = np.concatenate(outs[i])
            assert np.array_equal(got, ref), f"handle {i} diverged"
        # meshless / constraint / shard_map are three distinct launch groups
        assert len({pool._group_key(h._session) for h in handles}) == 3
        print("ok", pool.launches)
    """)


def test_mesh_nonpow2_shards_bounded_recompiles():
    """A 6-of-8 device mesh (non-pow2 shard count): sweeping many fleet
    sizes stays within a small, lcm-budgeted set of jit shapes — the old
    pad-after-budget path re-padded per size — and stays bit-exact."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.channel import transmit
        from repro.core.codespec import get_code_spec
        from repro.core.encoder import encode_jax, terminate
        from repro.core.engine import DecoderEngine
        from repro.core.pbvd import PBVDConfig
        from repro.kernels.ops import _decode_blocks_jit
        from repro.launch.mesh import make_decode_mesh

        spec = get_code_spec("ccsds")
        cfg = PBVDConfig(spec=spec, D=64, L=16, q=8, backend="ref")
        mesh = make_decode_mesh("data=6")  # submesh of the 8 host devices
        eng = DecoderEngine(cfg, mesh=mesh)
        assert eng.n_shards == 6
        # every budget divides by 6 and fleet sizes collapse to few shapes
        budgets = {k: eng._lane_budget(k) for k in range(1, 33)}
        assert all(b % 6 == 0 for b in budgets.values())
        assert len(set(budgets.values())) <= 6, sorted(set(budgets.values()))

        base = DecoderEngine(cfg)

        def tx(n, seed):
            rng = np.random.default_rng(seed)
            bits = terminate(rng.integers(0, 2, n), spec.code)
            return transmit(
                jax.random.PRNGKey(seed),
                encode_jax(jnp.asarray(bits), spec.code), 4.5, spec.rate,
            )

        fleets = ([96], [96, 190], [96, 190, 250], [96] * 5, [190] * 7)

        def sweep():
            for fleet in fleets:
                ys = [tx(n, 50 + i) for i, n in enumerate(fleet)]
                refs = base.decode_batch(ys, fleet)
                outs = eng.decode_batch(ys, fleet)
                for r, o in zip(refs, outs):
                    assert np.array_equal(np.asarray(r), np.asarray(o)), fleet

        before = _decode_blocks_jit._cache_size()
        sweep()
        grown = _decode_blocks_jit._cache_size() - before
        # one entry per engine per distinct n_real (a static arg) and no
        # more: the sharded pad never forks extra shapes per fleet
        assert grown <= 2 * len(fleets), f"jit cache grew by {grown}"
        # the sweep again, plus a permuted composition with the same total:
        # zero retraces — lcm budgeting keys purely on (shape, n_real)
        sweep()
        ys = [tx(n, 70 + i) for i, n in enumerate([190, 96])]
        eng.decode_batch(ys, [190, 96])
        assert _decode_blocks_jit._cache_size() - before == grown, "retraced"
        print("ok", grown)
    """)


def test_make_local_mesh_invalid_model_raises():
    """``make_local_mesh(model=3)`` on 8 devices used to silently build a
    6-device mesh over a device subset; it must now refuse loudly."""
    _run("""
        import jax
        from repro.launch.mesh import make_decode_mesh, make_local_mesh

        assert len(jax.devices()) == 8
        try:
            make_local_mesh(model=3)
        except ValueError as e:
            assert "does not divide" in str(e), e
        else:
            raise AssertionError("model=3 on 8 devices did not raise")
        m = make_local_mesh(model=2)
        assert dict(m.shape) == {"data": 4, "model": 2}
        m6 = make_decode_mesh("data=6")
        assert dict(m6.shape) == {"data": 6}
        print("ok")
    """)
