"""Fault-isolated serving suite (DESIGN.md §14).

Layers, matching the failure model:

* taxonomy + injector + retry-policy units (pure host-side, no engine);
* engine-boundary symbol validation: non-finite soft symbols are refused at
  ``quantize_soft`` / ``DecoderEngine.decode*`` / session ``send`` with the
  uniform ``nonfinite_error`` message, and one NaN stream cannot change any
  other stream's decoded bits (property test);
* SessionPool quarantine: bisection isolates the culprit lane-group, the
  rest of the batch delivers bit-exact, quarantined pages are reclaimed
  zeroed;
* AsyncDecodeService degradation: deterministic retry/backoff on a fake
  clock, load shedding past the deadline, mesh-loss fallback to a rescaled
  (or meshless) engine with bit-exact replay, and the stranded-waiter fix
  (a dying dispatcher propagates to every parked sender and to aclose);
* the chaos acceptance trace: 64 Poisson streams with injected
  stream-poison + dispatch + slab + mesh faults — healthy streams bit-exact,
  poisoned streams fail typed, nothing hangs, no slab page leaks.
"""

import asyncio

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core.codespec import get_code_spec
from repro.core.engine import DecoderEngine
from repro.core.pbvd import PBVDConfig
from repro.core.quantize import quantize_soft
from repro.launch.faults import (
    CapacityError,
    DecodeError,
    DispatchError,
    FaultInjector,
    MeshLost,
    RetryPolicy,
    ShedError,
    StreamError,
    SymbolError,
    check_finite_symbols,
    nonfinite_error,
)
from repro.launch.serve_async import (
    AsyncDecodeService,
    Backpressure,
    run_poisson_trace,
)
from repro.launch.serve_decoder import SessionPool
from repro.launch.slab import SlabExhausted, SymbolSlab

from test_serve_async import GEOM, FakeClock, _engine, _tx_stream

T_PAGE = GEOM["D"] + 2 * GEOM["L"]


# ---------------------------------------------------------------------------
# Taxonomy + injector + retry policy
# ---------------------------------------------------------------------------
@pytest.mark.tier1
def test_error_taxonomy_hierarchy():
    # every serving failure is a DecodeError (and a RuntimeError for
    # pre-taxonomy callers); capacity unifies Backpressure + SlabExhausted
    assert issubclass(StreamError, DecodeError)
    assert issubclass(DispatchError, DecodeError)
    assert issubclass(MeshLost, DispatchError)
    assert issubclass(CapacityError, DecodeError)
    assert issubclass(Backpressure, CapacityError)
    assert issubclass(SlabExhausted, CapacityError)
    assert issubclass(ShedError, CapacityError)
    assert issubclass(DecodeError, RuntimeError)
    # SymbolError keeps the engine's historical ValueError contract
    assert issubclass(SymbolError, StreamError)
    assert issubclass(SymbolError, ValueError)
    err = nonfinite_error("decode()", 3, 100)
    assert isinstance(err, SymbolError)
    assert "3 of 100" in str(err) and "decode()" in str(err)
    assert MeshLost("gone", lost_chips=4).lost_chips == 4


@pytest.mark.tier1
def test_check_finite_symbols():
    check_finite_symbols(np.ones((4, 2), np.float32), "t")  # finite: fine
    check_finite_symbols(np.ones((4, 2), np.int8), "t")  # ints: skipped
    bad = np.ones((4, 2), np.float32)
    bad[1, 0] = np.nan
    bad[2, 1] = np.inf
    with pytest.raises(SymbolError, match="2 of 8"):
        check_finite_symbols(bad, "t")
    # tracers pass through (eager-boundary concern only)
    jax.jit(lambda y: (check_finite_symbols(y, "t"), y * 2)[1])(jnp.ones(3))


@pytest.mark.tier1
def test_retry_policy_schedule():
    p = RetryPolicy(max_retries=4, backoff_s=0.01, multiplier=2.0, max_backoff_s=0.05)
    assert [p.delay_s(k) for k in range(5)] == [0.01, 0.02, 0.04, 0.05, 0.05]
    with pytest.raises(ValueError):
        p.delay_s(-1)
    with pytest.raises(ValueError):
        RetryPolicy(max_retries=-1)
    with pytest.raises(ValueError):
        RetryPolicy(multiplier=0.5)


@pytest.mark.tier1
def test_fault_injector_schedule_and_rates():
    inj = FaultInjector(schedule={"dispatch": {1, 3}})
    assert [inj.fire("dispatch") for _ in range(5)] == [
        False,
        True,
        False,
        True,
        False,
    ]
    assert inj.counts["dispatch"] == 5 and inj.fired["dispatch"] == 2
    assert inj.counts["slab"] == 0
    # rate mode is deterministic per (seed, site): two injectors with the
    # same seed fire on exactly the same consultations
    a = FaultInjector(seed=7, rates={"slab": 0.3})
    b = FaultInjector(seed=7, rates={"slab": 0.3})
    seq = [a.fire("slab") for _ in range(50)]
    assert seq == [b.fire("slab") for _ in range(50)]
    assert 0 < sum(seq) < 50
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultInjector(schedule={"bogus": {0}})
    with pytest.raises(ValueError, match="unknown fault site"):
        a.fire("bogus")
    with pytest.raises(ValueError):
        FaultInjector(rates={"slab": 1.5})


# ---------------------------------------------------------------------------
# Engine-boundary validation: non-finite symbols are refused, uniformly
# ---------------------------------------------------------------------------
@pytest.mark.tier1
def test_nonfinite_rejected_at_every_engine_boundary():
    spec, _, y = _tx_stream("ccsds", 256, 4.5, 21)
    eng = _engine(spec)
    bad = y.copy()
    bad[7] = np.nan

    with pytest.raises(SymbolError, match="non-finite"):
        quantize_soft(jnp.asarray([[1.0, np.inf]]))
    with pytest.raises(SymbolError, match="non-finite"):
        eng.decode(jnp.asarray(bad), 256)
    with pytest.raises(SymbolError, match="stream 1"):
        eng.decode_batch([jnp.asarray(y), jnp.asarray(bad)], [256, 256])
    sess = eng.session()
    with pytest.raises(SymbolError, match="session send"):
        sess.decode(bad[:200])
    # the rejected chunk never entered the buffer: the session still decodes
    # the clean stream bit-exactly from scratch
    out = np.concatenate([sess.decode(y), sess.finish(256)])
    np.testing.assert_array_equal(out, np.asarray(eng.decode(jnp.asarray(y), 256)))


@pytest.mark.tier1
@settings(max_examples=8, deadline=None)
@given(st.integers(0, 199), st.integers(0, 2**16))
def test_one_nan_stream_cannot_change_anothers_bits(pos, seed):
    """The isolation property behind the whole PR: stream A's decoded bits
    are identical whether its batch sibling B is healthy or poisoned —
    because a poisoned B is REJECTED (batch path) or QUARANTINED (service
    path) before its symbols can share a launch with A's."""
    spec, _, ya = _tx_stream("ccsds", 192, 4.0, seed % 1000)
    _, _, yb = _tx_stream("ccsds", 192, 4.0, seed % 1000 + 1)
    eng = _engine(spec)
    ref_a = np.asarray(eng.decode(jnp.asarray(ya), 192))
    bad_b = yb.copy()
    bad_b[pos % len(yb)] = np.nan
    # batch path: the poisoned batch refuses up front, naming the stream
    with pytest.raises(SymbolError):
        eng.decode_batch([jnp.asarray(ya), jnp.asarray(bad_b)], [192, 192])

    async def scenario():
        svc = AsyncDecodeService(max_batch_blocks=1000, deadline_ms=0.0)
        a, b = svc.open(eng), svc.open(eng)
        await a.send(ya[: len(ya) // 2])
        with pytest.raises(SymbolError):
            await b.send(bad_b)  # B quarantines at admission
        assert b.failed is not None
        await a.send(ya[len(ya) // 2 :])
        svc.poll()
        bits = np.concatenate([a.take(), await a.finish(192)])
        assert svc.metrics()["quarantined_streams"] == 1
        return bits

    np.testing.assert_array_equal(asyncio.run(scenario()), ref_a)


# ---------------------------------------------------------------------------
# SessionPool quarantine: bisection isolates culprits, the rest is bit-exact
# ---------------------------------------------------------------------------
@pytest.mark.tier1
@pytest.mark.parametrize("n_streams,culprits", [(4, {1}), (5, {0, 3}), (1, {0})])
def test_pool_bisection_quarantines_culprits_healthy_bit_exact(n_streams, culprits):
    spec = get_code_spec("ccsds")
    eng = _engine(spec)
    ys = [_tx_stream("ccsds", 256, 4.5, 60 + i)[2] for i in range(n_streams)]
    refs = [np.asarray(eng.decode(jnp.asarray(y), 256)) for y in ys]

    pool = SessionPool()
    handles = [pool.open(eng) for _ in ys]
    marked = {handles[i] for i in culprits}

    def hook(entries, isolating):
        for ps, _ in entries:
            if ps in marked:
                raise StreamError("poisoned lane-group", stream=ps)

    pool.fault_hook = hook
    for h, y in zip(handles, ys):
        h.feed(y)
    with pytest.raises(StreamError):
        pool.step()  # the plain step fails whole — nothing committed
    assert pool.pending_blocks() > 0  # retryable: sessions unchanged
    pool.step(isolate=True)
    bad = pool.drain_quarantined()
    assert {ps for ps, _ in bad} == marked
    assert all(isinstance(err, StreamError) for _, err in bad)
    assert len(pool) == n_streams - len(culprits)
    for i, (h, r) in enumerate(zip(handles, refs)):
        if i in culprits:
            continue
        # healthy members delivered from the bisected launches, bit-exact
        np.testing.assert_array_equal(
            np.concatenate([h.take(), h.finish(256)]), r
        )
    assert pool.drain_quarantined() == []  # drained exactly once


# ---------------------------------------------------------------------------
# Service degradation: retry/backoff, shedding, mesh loss, stranded waiters
# ---------------------------------------------------------------------------
@pytest.mark.tier1
def test_dispatch_retry_backoff_deterministic_on_fake_clock():
    spec, _, y = _tx_stream("ccsds", 512, 4.5, 22)
    eng = _engine(spec)
    clk = FakeClock()

    async def scenario():
        svc = AsyncDecodeService(
            max_batch_blocks=1,
            deadline_ms=0.0,
            clock=clk.now,
            retry=RetryPolicy(max_retries=3, backoff_s=0.010, multiplier=2.0),
            fault_injector=FaultInjector(schedule={"dispatch": {0, 1}}),
        )
        stream = svc.open(eng)
        await stream.send(y)
        assert svc.poll() is True  # attempt 1: injected failure → backoff armed
        assert svc._retry_at == pytest.approx(clk.now() + 0.010)
        assert svc.poll() is False  # backoff gates the retry
        clk.advance(0.010)
        assert svc.poll() is True  # attempt 2: fails again → 20 ms backoff
        assert svc._retry_at == pytest.approx(clk.now() + 0.020)
        clk.advance(0.020)
        assert svc.poll() is True  # attempt 3: schedule exhausted → success
        m = svc.metrics()
        assert m["retries"] == 2
        assert m["errors_by_class"] == {"DispatchError": 2}
        assert m["quarantined_streams"] == 0
        return np.concatenate([stream.take(), await stream.finish(512)])

    out = asyncio.run(scenario())
    np.testing.assert_array_equal(out, np.asarray(eng.decode(jnp.asarray(y), 512)))


@pytest.mark.tier1
def test_poisoned_stream_quarantined_same_step_healthy_delivers():
    """A StreamError during dispatch short-circuits retry: the same poll
    bisects, quarantines the poisoned stream, and delivers the healthy one."""
    spec, _, y0 = _tx_stream("ccsds", 512, 4.5, 23)
    _, _, y1 = _tx_stream("ccsds", 512, 4.5, 24)
    eng = _engine(spec)

    async def scenario():
        slab = SymbolSlab(n_pages=16, page_stages=T_PAGE, R=spec.code.R)
        svc = AsyncDecodeService(
            max_batch_blocks=1000,
            deadline_ms=0.0,  # manual poll() is due as soon as anything is pending
            max_pending_blocks=10_000,
            slab=slab,
            fault_injector=FaultInjector(schedule={"stream_poison": {1}}),
        )
        healthy, poisoned = svc.open(eng), svc.open(eng)
        await healthy.send(y0)
        await poisoned.send(y1)
        held = slab.pages_in_use
        assert held > 0
        assert svc.poll() is True  # one poll: bisect + quarantine + deliver
        assert poisoned.failed is not None
        with pytest.raises(StreamError):
            poisoned.take()
        with pytest.raises(StreamError):
            await poisoned.send(y1[:10])
        # the quarantined stream's pages went back to the free-list, zeroed
        assert slab.pages_in_use < held
        assert np.all(slab._data[slab._free] == 0.0)
        m = svc.metrics()
        assert m["quarantined_streams"] == 1
        assert m["errors_by_class"]["StreamError"] >= 1
        bits = np.concatenate([healthy.take(), await healthy.finish(512)])
        assert slab.pages_in_use == 0
        # a fresh stream reuses the reclaimed (zeroed) pages bit-exactly
        reuse = svc.open(eng)
        await reuse.send(y1)
        svc.poll()
        reuse_bits = np.concatenate([reuse.take(), await reuse.finish(512)])
        return bits, reuse_bits

    bits, reuse_bits = asyncio.run(scenario())
    np.testing.assert_array_equal(bits, np.asarray(eng.decode(jnp.asarray(y0), 512)))
    np.testing.assert_array_equal(
        reuse_bits, np.asarray(eng.decode(jnp.asarray(y1), 512))
    )


@pytest.mark.tier1
def test_load_shedding_past_deadline_on_fake_clock():
    spec, _, y = _tx_stream("ccsds", 512, 4.5, 25)
    eng = _engine(spec)
    clk = FakeClock()

    async def scenario():
        svc = AsyncDecodeService(
            max_batch_blocks=1000,
            deadline_ms=0.0,
            max_pending_blocks=2,
            clock=clk.now,
            shed_deadline_ms=50.0,
        )
        stream = svc.open(eng)
        await stream.send(y[:300])  # at the pending-block cap
        blocked = asyncio.ensure_future(stream.send(y[300:]))
        for _ in range(5):
            await asyncio.sleep(0)
        assert not blocked.done()  # parked within the deadline
        # a wake that frees nothing re-parks the sender (no exception) while
        # the deadline has not yet passed
        svc._space.set()
        for _ in range(5):
            await asyncio.sleep(0)
        assert not blocked.done()
        clk.advance(0.051)  # past the shed deadline (injected clock)
        svc._space.set()  # next failed wake now sheds
        with pytest.raises(ShedError, match="shed"):
            await asyncio.wait_for(blocked, timeout=5)
        m = svc.metrics()
        assert m["shed_blocks"] == 1
        assert m["errors_by_class"]["ShedError"] == 1
        # the stream itself is NOT quarantined: shedding drops the chunk, not
        # the stream — and the pool still drains normally
        assert stream.failed is None
        assert svc.poll() is True
        await stream.send(y[300:])
        svc.poll()
        return np.concatenate([stream.take(), await stream.finish(512)])

    out = asyncio.run(scenario())
    np.testing.assert_array_equal(out, np.asarray(eng.decode(jnp.asarray(y), 512)))


@pytest.mark.tier1
def test_blocked_sender_reparks_after_failed_wake():
    """A wake that frees no capacity must re-park the sender (indefinitely,
    with no shed deadline configured) — not fail it, not admit it early."""
    spec, _, y = _tx_stream("ccsds", 512, 4.5, 29)
    eng = _engine(spec)

    async def scenario():
        svc = AsyncDecodeService(
            max_batch_blocks=1000,
            deadline_ms=0.0,  # manual poll() is due as soon as anything is pending
            max_pending_blocks=2,
        )
        stream = svc.open(eng)
        await stream.send(y[:300])  # ≥ 2 blocks ready → at the cap
        blocked = asyncio.ensure_future(stream.send(y[300:]))
        for _ in range(5):
            await asyncio.sleep(0)
        assert not blocked.done()
        # spurious wake: nothing was freed, so the sender re-parks
        svc._space.set()
        for _ in range(5):
            await asyncio.sleep(0)
        assert not blocked.done()
        assert svc.poll() is True  # a real dispatch frees capacity…
        await asyncio.wait_for(blocked, timeout=5)  # …and the send completes
        svc.poll()
        return np.concatenate([stream.take(), await stream.finish(512)])

    out = asyncio.run(scenario())
    np.testing.assert_array_equal(out, np.asarray(eng.decode(jnp.asarray(y), 512)))


@pytest.mark.tier1
def test_mesh_loss_falls_back_and_replays_bit_exact():
    """Losing the mesh mid-dispatch rebuilds the engine (meshless here — a
    1-device mesh has no smaller mesh) and replays the in-flight blocks from
    session state, bit-exact to the uninterrupted run."""
    from repro.launch.mesh import make_decode_mesh

    spec, _, y = _tx_stream("ccsds", 512, 4.5, 26)
    mesh = make_decode_mesh("data=1")
    cfg = PBVDConfig(spec=spec, backend="ref", **GEOM)
    eng = DecoderEngine(cfg, mesh=mesh, block_axes=("data",))
    ref = np.asarray(DecoderEngine(cfg).decode(jnp.asarray(y), 512))
    clk = FakeClock()

    async def scenario():
        svc = AsyncDecodeService(
            max_batch_blocks=1,
            deadline_ms=0.0,
            clock=clk.now,
            fault_injector=FaultInjector(
                schedule={"mesh": {0}}, mesh_lost_chips=1
            ),
        )
        stream = svc.open(eng)
        await stream.send(y)
        assert svc.poll() is True  # MeshLost → engines rebuilt, retry armed
        sess = stream._handle._session
        assert sess.engine is not eng and sess.engine.mesh is None
        assert svc.poll() is True  # the replay dispatch (retry_at == now)
        m = svc.metrics()
        assert m["errors_by_class"] == {"MeshLost": 1}
        assert m["retries"] == 1
        return np.concatenate([stream.take(), await stream.finish(512)])

    np.testing.assert_array_equal(asyncio.run(scenario()), ref)


@pytest.mark.tier1
def test_dispatcher_death_propagates_to_waiters_and_aclose():
    """The stranded-waiter regression: a SessionPool whose step() raises
    something unhandled must fail parked senders and aclose() — before this
    PR the background task died silently and every waiter hung forever."""
    spec, _, y = _tx_stream("ccsds", 512, 4.5, 27)
    eng = _engine(spec)

    class RaisingPool(SessionPool):
        def step(self, *, isolate=False):
            raise RuntimeError("XLA launch exploded")

    async def scenario():
        svc = AsyncDecodeService(
            max_batch_blocks=1,
            deadline_ms=0.0,
            max_pending_blocks=1,
            retry=RetryPolicy(max_retries=1, backoff_s=0.0),
        )
        raising = RaisingPool()
        raising._members = svc._pool._members  # adopt the open membership
        raising._mesh_refs = svc._pool._mesh_refs
        svc._pool = raising
        svc.start()
        stream = svc.open(eng)
        await stream.send(y[:300])  # ≥ 1 pending block: the dispatcher fires
        parked = asyncio.ensure_future(stream.send(y[300:]))  # parks on the cap
        with pytest.raises(DispatchError, match="dispatcher died"):
            await asyncio.wait_for(parked, timeout=10)
        # new work is refused with the same typed failure...
        with pytest.raises(DispatchError):
            await stream.send(y[300:])
        with pytest.raises(DispatchError):
            await stream.finish(512)
        with pytest.raises(DispatchError):
            svc.open(eng)
        # ...and aclose() resurfaces it instead of closing silently
        with pytest.raises(DispatchError, match="dispatcher died"):
            await svc.aclose()
        m = svc.metrics()
        assert m["errors_by_class"]["RuntimeError"] == 2  # attempt + retry
        assert m["errors_by_class"]["DispatchError"] == 1
        assert m["retries"] == 1

    asyncio.run(scenario())


@pytest.mark.tier1
def test_capacity_errors_are_counted_in_metrics():
    spec, _, y = _tx_stream("ccsds", 512, 4.5, 28)
    eng = _engine(spec)

    async def cap_scenario():
        svc = AsyncDecodeService(
            max_batch_blocks=1000,
            deadline_ms=0.0,
            max_pending_blocks=2,
            block_on_backpressure=False,
        )
        stream = svc.open(eng)
        await stream.send(y[:300])  # ≥ 2 blocks ready → at the cap
        with pytest.raises(Backpressure, match="pending-block cap"):
            await stream.send(y[300:])
        m = svc.metrics()
        assert m["errors_by_class"] == {"Backpressure": 1}
        assert m["shed_blocks"] == 0 and m["quarantined_streams"] == 0

    async def slab_scenario():
        slab = SymbolSlab(n_pages=4, page_stages=T_PAGE, R=spec.code.R)
        svc = AsyncDecodeService(
            max_batch_blocks=1000,
            deadline_ms=0.0,
            slab=slab,
            block_on_backpressure=False,
        )
        stream = svc.open(eng)
        await stream.send(y[: 4 * T_PAGE])  # fills the slab exactly
        with pytest.raises(Backpressure, match="slab pages"):
            await stream.send(y[4 * T_PAGE :])
        m = svc.metrics()
        # the allocator's refusal AND the non-blocking mapping both count
        assert m["errors_by_class"] == {"SlabExhausted": 1, "Backpressure": 1}

    asyncio.run(cap_scenario())
    asyncio.run(slab_scenario())


# ---------------------------------------------------------------------------
# The chaos acceptance trace
# ---------------------------------------------------------------------------
@pytest.mark.tier1
def test_chaos_64_stream_trace_healthy_bit_exact_poisoned_typed():
    """The PR's acceptance criterion: a 64-stream Poisson trace with
    injected stream-poison + transient dispatch + slab-exhaustion + mesh
    faults completes with every healthy stream bit-exact to its one-shot
    reference, every poisoned stream failing with a typed StreamError, no
    hung futures (the trace returns) and no leaked slab pages."""
    S, n_bits = 64, 256
    spec = get_code_spec("ccsds")
    eng = _engine(spec)
    ys = [_tx_stream("ccsds", n_bits, 4.5, 80 + i)[2] for i in range(S)]
    refs = [np.asarray(eng.decode(jnp.asarray(y), n_bits)) for y in ys]
    slab = SymbolSlab(n_pages=6 * S, page_stages=T_PAGE, R=spec.code.R)
    poisoned = {3, 17}
    injector = FaultInjector(
        seed=5,
        schedule={
            "stream_poison": poisoned,  # the 4th and 18th open() are poison
            "dispatch": {1, 4},  # transient launch failures → retried
            "slab": {5, 30},  # synthetic page exhaustion → re-admitted
            "mesh": {2},  # device loss (meshless engine: absorbed)
            "admission": {100},  # validation failure on the 101st send
        },
    )
    results, report = asyncio.run(
        run_poisson_trace(
            eng,
            ys,
            [n_bits] * S,
            chunk_symbols=100,
            rate_chunks_per_s=5000.0,
            seed=9,
            slab=slab,
            service_kwargs=dict(max_batch_blocks=64, deadline_ms=2.0),
            fault_injector=injector,
        )
    )
    failed = {i for i, r in enumerate(results) if isinstance(r, Exception)}
    # every poisoned stream failed with its typed StreamError…
    assert poisoned <= failed
    assert all(isinstance(results[i], StreamError) for i in failed)
    # …the admission fault may land on a healthy stream (interleaving-
    # dependent) or on an already-failed one — never more than one extra
    assert len(failed) <= len(poisoned) + 1
    # every healthy stream is bit-exact to its one-shot reference
    for i in range(S):
        if i not in failed:
            np.testing.assert_array_equal(results[i], refs[i])
    # nothing leaked, nothing hung, and the degradation is observable
    assert slab.pages_in_use == 0
    assert report["quarantined_streams"] == len(failed)
    # one isolation pass may quarantine BOTH poisoned streams (a single
    # StreamError catch), so assert presence, not a per-stream count
    assert report["errors_by_class"].get("StreamError", 0) >= 1
    assert report["errors_by_class"].get("DispatchError", 0) >= 1
    assert report["errors_by_class"].get("SlabExhausted", 0) >= 1
    assert report["retries"] >= 1
    assert injector.fired["stream_poison"] == 2
    assert injector.fired["mesh"] == 1
    # healthy throughput survived: the service still coalesced dispatches
    assert report["bits_delivered"] >= (S - len(failed)) * n_bits
