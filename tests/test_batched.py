"""Batched multi-stream decoding: decode_batch, lane packing, SessionPool.

Acceptance tests for the frames × blocks packing layer:
  * ``decode_batch`` is bit-identical per frame to sequential ``decode()``
    calls for every backend (uniform and mixed-length fleets, punctured and
    pre-quantized streams);
  * a 64-stream × 1024-bit batched ref decode issues exactly ONE
    ``pbvd_decode_blocks`` launch (counting test);
  * a SessionPool coalesces the ready blocks of many concurrent sessions —
    grouped by launch compatibility — into single launches while every
    session stays bit-exact to its solo one-shot decode.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.core.engine as engine_mod
from repro.core.channel import transmit
from repro.core.codespec import get_code_spec
from repro.core.encoder import encode_jax, terminate
from repro.core.engine import DecoderEngine
from repro.core.pbvd import PBVDConfig
from repro.core.quantize import quantize_soft
from repro.kernels.registry import FramedBlocks
from repro.launch.serve_decoder import SessionPool


def _tx_stream(name, n, ebn0_db, seed):
    spec = get_code_spec(name)
    rng = np.random.default_rng(seed)
    bits = terminate(rng.integers(0, 2, n), spec.code)
    coded = encode_jax(jnp.asarray(bits), spec.code)
    tx = spec.puncture_stream(coded) if spec.is_punctured else coded
    y = transmit(jax.random.PRNGKey(seed), tx, ebn0_db, spec.rate)
    return spec, bits[:n], y


# ---------------------------------------------------------------------------
# FramedBlocks frame metadata
# ---------------------------------------------------------------------------
def test_framed_blocks_frame_metadata():
    y = jnp.zeros((8, 2, 10))
    fb = FramedBlocks(y, 2, 4, frame_counts=(3, 2, 4))
    assert fb.n_frames == 3
    assert fb.n_real_blocks == 9  # lane 9 is padding
    assert fb.frame_slices() == [slice(0, 3), slice(3, 5), slice(5, 9)]
    plain = FramedBlocks(y, 2, 4)
    assert plain.n_frames == 1 and plain.n_real_blocks == 10
    with pytest.raises(ValueError):
        FramedBlocks(y, 2, 4, frame_counts=(8, 4))  # sum > lanes
    with pytest.raises(ValueError):
        FramedBlocks(y, 2, 4, frame_counts=(3, 0))  # empty frame


# ---------------------------------------------------------------------------
# decode_batch == sequential decode, per backend
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["ref", "pallas", "fused"])
def test_decode_batch_matches_sequential_per_backend(backend):
    spec, _, _ = _tx_stream("ccsds", 64, 5.0, 0)
    cfg = PBVDConfig(spec=spec, D=64, L=16, q=8, backend=backend)
    engine = DecoderEngine(cfg)
    lengths = [96, 256, 96, 190]  # mixed → general path; repeated → same shapes
    ys = [_tx_stream("ccsds", n, 4.5, 30 + i)[2] for i, n in enumerate(lengths)]
    batch = engine.decode_batch(ys, lengths)
    assert len(batch) == len(ys)
    for y, n, b in zip(ys, lengths, batch):
        np.testing.assert_array_equal(
            np.asarray(b), np.asarray(engine.decode(y, n))
        )


@pytest.mark.parametrize("name", ["ccsds-3/4", "is95-k9-2/3"])
def test_decode_batch_uniform_punctured(name):
    """Equal-shape fleets take the stacked fast path; punctured wire
    streams depuncture per frame exactly like decode()."""
    spec, _, _ = _tx_stream(name, 128, 5.0, 0)
    cfg = PBVDConfig(spec=spec, D=64, L=16, q=8, backend="ref")
    engine = DecoderEngine(cfg)
    ys = [_tx_stream(name, 128, 4.5, 60 + i)[2] for i in range(6)]
    batch = engine.decode_batch(ys, [128] * 6)
    for y, b in zip(ys, batch):
        np.testing.assert_array_equal(
            np.asarray(b), np.asarray(engine.decode(y, 128))
        )


def test_decode_batch_prequantized_int_streams():
    spec, _, y = _tx_stream("ccsds", 256, 4.0, 1)
    cfg = PBVDConfig(spec=spec, D=64, L=16, q=8, backend="ref")
    engine = DecoderEngine(cfg)
    ys = [np.asarray(quantize_soft(_tx_stream("ccsds", 256, 4.0, 70 + i)[2], 8))
          for i in range(3)]
    batch = engine.decode_batch(ys, [256] * 3)
    for yq, b in zip(ys, batch):
        np.testing.assert_array_equal(
            np.asarray(b), np.asarray(engine.decode(jnp.asarray(yq), 256))
        )


def test_decode_batch_edge_cases():
    spec, _, y = _tx_stream("ccsds", 64, 5.0, 0)
    cfg = PBVDConfig(spec=spec, D=64, L=16, q=8, backend="ref")
    engine = DecoderEngine(cfg)
    assert engine.decode_batch([]) == []
    # single-stream batch == decode
    np.testing.assert_array_equal(
        np.asarray(engine.decode_batch([y], [64])[0]),
        np.asarray(engine.decode(y, 64)),
    )
    with pytest.raises(ValueError):
        engine.decode_batch([y, y], [64])  # length mismatch


# ---------------------------------------------------------------------------
# the acceptance geometry: 64 streams × 1024 bits, ONE launch
# ---------------------------------------------------------------------------
def test_decode_batch_64x1024_single_launch(monkeypatch):
    spec, _, _ = _tx_stream("ccsds", 64, 5.0, 0)
    cfg = PBVDConfig(spec=spec, D=512, L=42, q=8, backend="ref")
    engine = DecoderEngine(cfg)
    ys = [_tx_stream("ccsds", 1024, 4.0, 100 + i)[2] for i in range(64)]

    real = engine_mod.pbvd_decode_blocks
    calls = []

    def counting(*args, **kwargs):
        calls.append(kwargs.get("frame_counts"))
        return real(*args, **kwargs)

    monkeypatch.setattr(engine_mod, "pbvd_decode_blocks", counting)
    batch = engine.decode_batch(ys, [1024] * 64)
    assert len(calls) == 1, f"expected one launch, saw {len(calls)}"
    assert calls[0] == (2,) * 64  # 64 frames × 2 blocks of D=512
    monkeypatch.setattr(engine_mod, "pbvd_decode_blocks", real)
    for y, b in zip(ys, batch):
        np.testing.assert_array_equal(
            np.asarray(b), np.asarray(engine.decode(y, 1024))
        )


def test_frame_split_does_not_grow_jit_cache():
    """Only the TOTAL lane count keys the launch cache: different per-frame
    splits of the same padded shape must reuse one compiled entry (a pool
    with varying chunk cadences would otherwise retrace every step)."""
    from repro.kernels.ops import _decode_blocks_jit, pbvd_decode_blocks

    code = get_code_spec("ccsds").code
    y = jnp.zeros((56, 2, 4), jnp.int8)
    kw = dict(decode_start=12, n_decode=32, backend="ref")
    pbvd_decode_blocks(y, code, frame_counts=(4,), **kw)  # warm the entry
    before = _decode_blocks_jit._cache_size()
    for fc in [(1, 3), (2, 2), (3, 1), (1, 1, 2), (1, 1, 1, 1)]:
        out = pbvd_decode_blocks(y, code, frame_counts=fc, **kw)
        assert out.shape == (32, sum(fc))
    assert _decode_blocks_jit._cache_size() == before


# ---------------------------------------------------------------------------
# SessionPool
# ---------------------------------------------------------------------------
def test_session_pool_mixed_specs_bit_exact():
    """Concurrent sessions over mixed specs/rates, random chunk cadences:
    every stream decodes bit-exact to its solo one-shot decode."""
    names = ["ccsds", "ccsds-3/4", "ccsds-5/6", "is95-k9"]
    engines, ys, refs = [], [], []
    for i, name in enumerate(names):
        spec, _, y = _tx_stream(name, 512, 4.5, 20 + i)
        cfg = PBVDConfig(spec=spec, D=64, L=16, q=8, backend="ref")
        eng = DecoderEngine(cfg)
        engines.append(eng)
        ys.append(np.asarray(y))
        refs.append(np.asarray(eng.decode(y, 512)))

    pool = SessionPool()
    handles = [pool.open(e) for e in engines]
    rng = np.random.default_rng(0)
    pos = [0] * len(names)
    outs = [[] for _ in names]
    while any(p < len(y) for p, y in zip(pos, ys)):
        for i, (y, h) in enumerate(zip(ys, handles)):
            if pos[i] < len(y):
                n = int(rng.integers(1, 180))
                h.feed(y[pos[i] : pos[i] + n])
                pos[i] += n
        pool.step()
        for i, h in enumerate(handles):
            outs[i].append(h.take())
    for i, h in enumerate(handles):
        outs[i].append(h.finish(512))
    for i, name in enumerate(names):
        np.testing.assert_array_equal(np.concatenate(outs[i]), refs[i])
        assert handles[i].bits_emitted == 512


def test_session_pool_groups_compatible_sessions_into_one_launch():
    """Sessions sharing (mother code, geometry, backend, dtype) share a
    launch — including different punctured rates of one mother code."""
    cfg_a = PBVDConfig(spec=get_code_spec("ccsds"), D=64, L=16, q=8, backend="ref")
    cfg_b = PBVDConfig(spec=get_code_spec("ccsds-3/4"), D=64, L=16, q=8, backend="ref")
    eng_a, eng_b = DecoderEngine(cfg_a), DecoderEngine(cfg_b)
    _, _, ya = _tx_stream("ccsds", 256, 5.0, 1)
    _, _, yb = _tx_stream("ccsds-3/4", 256, 5.0, 2)

    pool = SessionPool()
    ha1, ha2, hb = pool.open(eng_a), pool.open(eng_a), pool.open(eng_b)
    ha1.feed(np.asarray(ya))
    ha2.feed(np.asarray(ya))
    hb.feed(np.asarray(yb))
    assert pool.pending_blocks() > 0
    n_blocks = pool.step()
    assert pool.launches == 1  # all three coalesced (same mother code + geometry)
    delivered = sum(len(h.take()) // 64 for h in (ha1, ha2, hb))
    assert n_blocks == delivered > 0
    # incompatible geometry → separate group
    cfg_c = PBVDConfig(spec=get_code_spec("ccsds"), D=128, L=16, q=8, backend="ref")
    hc = pool.open(DecoderEngine(cfg_c))
    ha1.feed(np.asarray(ya))
    hc.feed(np.asarray(ya))
    pool.step()
    assert pool.launches == 3  # one for the D=64 group, one for D=128


def test_session_pool_groups_on_resolved_tb_mode_and_radix():
    """tb_mode="auto" coalesces with sessions that spell the backend's
    preferred mode out; differing acs_radix splits the group (different
    compiled launch)."""
    from repro.kernels.ops import backend_preferred_tb_mode

    base = dict(spec=get_code_spec("ccsds"), D=64, L=16, q=8, backend="ref")
    eng_auto = DecoderEngine(PBVDConfig(**base, tb_mode="auto"))
    eng_expl = DecoderEngine(
        PBVDConfig(**base, tb_mode=backend_preferred_tb_mode("ref"))
    )
    eng_r4 = DecoderEngine(PBVDConfig(**base, tb_mode="auto", acs_radix=4))
    _, _, y = _tx_stream("ccsds", 256, 5.0, 11)
    ya = np.asarray(y)

    pool = SessionPool()
    h_auto, h_expl = pool.open(eng_auto), pool.open(eng_expl)
    h_auto.feed(ya)
    h_expl.feed(ya)
    pool.step()
    assert pool.launches == 1  # auto resolved == explicit → one group

    h_auto2, h_r4 = pool.open(eng_auto), pool.open(eng_r4)
    h_auto2.feed(ya)
    h_r4.feed(ya)
    pool.step()
    assert pool.launches == 3  # radix-4 session launched separately
    ref = np.asarray(eng_auto.decode(y, 256))
    for h in (h_auto, h_expl, h_auto2, h_r4):
        np.testing.assert_array_equal(np.concatenate([h.take(), h.finish(256)]), ref)


def test_session_pool_int_and_float_sessions_do_not_mix():
    cfg = PBVDConfig(spec=get_code_spec("ccsds"), D=64, L=16, q=8, backend="ref")
    eng = DecoderEngine(cfg)
    _, _, y = _tx_stream("ccsds", 256, 5.0, 3)
    ya = np.asarray(y)
    yq = np.asarray(quantize_soft(y, 8))
    pool = SessionPool()
    hf, hi = pool.open(eng), pool.open(eng)
    hf.feed(ya)
    hi.feed(yq)
    pool.step()
    assert pool.launches == 2  # float-fed and int-fed sessions split groups
    ref = np.asarray(eng.decode(y, 256))
    refq = np.asarray(eng.decode(jnp.asarray(yq), 256))
    np.testing.assert_array_equal(
        np.concatenate([hf.take(), hf.finish(256)]), ref
    )
    np.testing.assert_array_equal(
        np.concatenate([hi.take(), hi.finish(256)]), refq
    )


def test_session_pool_close_and_empty_step():
    cfg = PBVDConfig(spec=get_code_spec("ccsds"), D=64, L=16, q=8, backend="ref")
    eng = DecoderEngine(cfg)
    pool = SessionPool()
    h = pool.open(eng)
    assert len(pool) == 1
    assert pool.step() == 0  # nothing buffered: no launches
    assert pool.launches == 0
    pool.close(h)
    assert len(pool) == 0
