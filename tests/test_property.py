"""Property-based equivalence suite: backend parity + streaming invariance.

Hypothesis-driven (the real package when installed, else the deterministic
conftest shim) over random messages, noise realizations, geometries and
chunkings:

  * **backend-parity matrix**: for EVERY registered ``CodeSpec``, random
    transmissions decode to identical bits through ``ref``/``pallas``/
    ``fused`` × the start policies each backend supports (``argmin`` on the
    backends that implement it; the ``fused`` backend's eager ``ValueError``
    is asserted instead);
  * **streaming fuzz**: any chunk partition of a stream — empty chunks,
    1-symbol chunks, period-misaligned punctured chunks, float or
    pre-quantized int — concatenates bit-exactly to the one-shot decode;
  * **batched fuzz**: ``decode_batch`` over random mixed-length fleets is
    bit-exact per frame to sequential decodes.

``PROPERTY_MAX_EXAMPLES`` scales the example count (tools/run_property.sh
raises it in CI; the in-suite default keeps tier-1 fast).
"""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.channel import transmit
from repro.core.codespec import available_code_specs, get_code_spec
from repro.core.encoder import encode_jax, terminate
from repro.core.engine import DecoderEngine
from repro.core.pbvd import PBVDConfig
from repro.core.quantize import quantize_soft
from repro.kernels.ops import backend_start_policies

MAX_EXAMPLES = int(os.environ.get("PROPERTY_MAX_EXAMPLES", "4"))
BACKENDS = ("ref", "pallas", "fused")
_COMMON = dict(max_examples=MAX_EXAMPLES, deadline=None)
if not getattr(__import__("hypothesis"), "__is_shim__", False):
    _COMMON["derandomize"] = True  # fixed-seed CI runs (real hypothesis only)


def _tx(spec, n_bits, ebn0_db, seed):
    rng = np.random.default_rng(seed)
    bits = terminate(rng.integers(0, 2, n_bits), spec.code)
    coded = encode_jax(jnp.asarray(bits), spec.code)
    tx = spec.puncture_stream(coded) if spec.is_punctured else coded
    return transmit(jax.random.PRNGKey(seed), tx, ebn0_db, spec.rate)


# ---------------------------------------------------------------------------
# backend-parity matrix over every registered CodeSpec
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", available_code_specs())
@settings(**_COMMON)
@given(
    st.integers(24, 96),  # n_bits
    st.integers(0, 2**16 - 1),  # seed
    st.floats(3.0, 6.5),  # ebn0_db
    st.sampled_from([8, None]),  # quantization
    st.sampled_from(["zero", "argmin"]),  # start policy
    st.sampled_from(["f32", "i16", "i8"]),  # metric mode
    st.sampled_from([2, 4]),  # acs radix
    st.sampled_from(["butterfly", "matrix"]),  # acs impl
)
def test_backend_parity_matrix(
    name, n_bits, seed, ebn0_db, q, policy, metric_mode, acs_radix, acs_impl
):
    spec = get_code_spec(name)
    y = _tx(spec, n_bits, ebn0_db, seed)
    outs = {}
    for backend in BACKENDS:
        cfg = PBVDConfig(
            spec=spec, D=32, L=12, q=q, backend=backend, start_policy=policy,
            metric_mode=metric_mode, acs_radix=acs_radix, acs_impl=acs_impl,
        )
        engine = DecoderEngine(cfg)
        if policy not in backend_start_policies(backend):
            with pytest.raises(ValueError):
                engine.decode(y, n_bits)
            continue
        outs[backend] = np.asarray(engine.decode(y, n_bits))
    assert len(outs) >= 2
    for backend, bits in outs.items():
        np.testing.assert_array_equal(
            bits,
            outs["ref"],
            err_msg=f"{name}/{backend}/{policy}/{metric_mode}/r{acs_radix}"
            f"/{acs_impl} diverged",
        )


# ---------------------------------------------------------------------------
# acs-radix parity: the stage-fused radix-4 forward pass is bit-exact to
# radix-2 for every CodeSpec × backend × metric mode × tb mode — odd D makes
# T = D + 2L odd, exercising the trailing radix-2 step in every backend
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", available_code_specs())
@settings(**_COMMON)
@given(
    st.integers(24, 96),  # n_bits
    st.integers(0, 2**16 - 1),  # seed
    st.floats(3.0, 6.5),  # ebn0_db
    st.sampled_from(["f32", "i16", "i8"]),  # metric mode
    st.sampled_from([32, 31]),  # D (even/odd T)
    st.sampled_from(["serial", "prefix", "auto"]),  # tb mode
)
def test_acs_radix_parity_matrix(name, n_bits, seed, ebn0_db, metric_mode, D, tb_mode):
    spec = get_code_spec(name)
    y = _tx(spec, n_bits, ebn0_db, seed)
    for backend in BACKENDS:
        def bits(radix):
            cfg = PBVDConfig(
                spec=spec, D=D, L=12, q=8, backend=backend,
                metric_mode=metric_mode, tb_mode=tb_mode, acs_radix=radix,
            )
            return np.asarray(DecoderEngine(cfg).decode(y, n_bits))

        np.testing.assert_array_equal(
            bits(4),
            bits(2),
            err_msg=f"{name}/{backend}/{metric_mode}/D={D}/{tb_mode} "
            f"radix-4 diverged from radix-2",
        )


# ---------------------------------------------------------------------------
# acs-impl parity: the k-stage (min,+) tropical-matmul forward pass is
# bit-exact to the butterfly for every CodeSpec × backend × metric mode ×
# tb mode × fusion depth — D=31 makes T = D + 2L odd, exercising the
# trailing radix-2 stages (T mod k) in every backend; k is clamped to the
# structural bound k·R ≤ 8 (rate-1/3 codes cap at k=2)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", available_code_specs())
@settings(**_COMMON)
@given(
    st.integers(24, 96),  # n_bits
    st.integers(0, 2**16 - 1),  # seed
    st.floats(3.0, 6.5),  # ebn0_db
    st.sampled_from(["f32", "i16", "i8"]),  # metric mode
    st.sampled_from([32, 31]),  # D (even/odd T)
    st.sampled_from(["serial", "prefix", "auto"]),  # tb mode
    st.sampled_from([1, 2, 3]),  # matrix fusion depth k (pre-clamp)
)
def test_acs_impl_parity_matrix(name, n_bits, seed, ebn0_db, metric_mode, D, tb_mode, k):
    spec = get_code_spec(name)
    k = min(k, 8 // spec.code.R, spec.code.v)
    y = _tx(spec, n_bits, ebn0_db, seed)
    for backend in BACKENDS:
        def bits(impl):
            cfg = PBVDConfig(
                spec=spec, D=D, L=12, q=8, backend=backend,
                metric_mode=metric_mode, tb_mode=tb_mode,
                acs_impl=impl, acs_k=k,
            )
            return np.asarray(DecoderEngine(cfg).decode(y, n_bits))

        np.testing.assert_array_equal(
            bits("matrix"),
            bits("butterfly"),
            err_msg=f"{name}/{backend}/{metric_mode}/D={D}/{tb_mode} "
            f"matrix k={k} diverged from butterfly",
        )


# ---------------------------------------------------------------------------
# prefix-traceback parity: tb_mode="prefix" is bit-exact to "serial" for
# every CodeSpec × backend × chunk size (divisors, non-divisors, 1, >= T) —
# the decode region starts at decode_start = L > 0, so the dead-chunk
# early-exit path is always exercised
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", available_code_specs())
@settings(**_COMMON)
@given(
    st.integers(24, 96),  # n_bits
    st.integers(0, 2**16 - 1),  # seed
    st.floats(3.0, 6.5),  # ebn0_db
    st.sampled_from(["f32", "i16", "i8"]),  # metric mode
    st.sampled_from([1, 7, 32, 64, "T"]),  # tb_chunk ("T" → one whole-T chunk)
)
def test_prefix_traceback_parity_matrix(name, n_bits, seed, ebn0_db, metric_mode, tb_chunk):
    spec = get_code_spec(name)
    y = _tx(spec, n_bits, ebn0_db, seed)
    D, L = 32, 12
    chunk = D + 2 * L if tb_chunk == "T" else tb_chunk
    for backend in BACKENDS:
        serial = DecoderEngine(
            PBVDConfig(
                spec=spec, D=D, L=L, q=8, backend=backend,
                metric_mode=metric_mode, tb_mode="serial",
            )
        ).decode(y, n_bits)
        prefix = DecoderEngine(
            PBVDConfig(
                spec=spec, D=D, L=L, q=8, backend=backend,
                metric_mode=metric_mode, tb_mode="prefix", tb_chunk=chunk,
            )
        ).decode(y, n_bits)
        np.testing.assert_array_equal(
            np.asarray(prefix),
            np.asarray(serial),
            err_msg=f"{name}/{backend}/{metric_mode}/tb_chunk={chunk} "
            f"prefix diverged from serial",
        )


# ---------------------------------------------------------------------------
# metric-mode parity: f32 vs i16 exact; i8 exact on shared symbols and
# within the quantizer's documented tolerance end-to-end
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", available_code_specs())
@settings(**_COMMON)
@given(
    st.integers(48, 160),  # n_bits
    st.integers(0, 2**16 - 1),  # seed
    st.floats(4.0, 6.5),  # ebn0_db
)
def test_metric_mode_parity(name, n_bits, seed, ebn0_db):
    spec = get_code_spec(name)
    y = _tx(spec, n_bits, ebn0_db, seed)
    # an adequate truncation depth (≈6K) keeps the i8-vs-f32 comparison in
    # the quantizer-only regime — at marginal L the truncation noise itself
    # cascades and swamps the quantizer tolerance
    L = 6 * spec.code.K

    def bits_for(mode, yy):
        cfg = PBVDConfig(spec=spec, D=32, L=L, q=8, backend="ref", metric_mode=mode)
        return np.asarray(DecoderEngine(cfg).decode(yy, n_bits)), cfg

    f32, _ = bits_for("f32", y)
    i16, _ = bits_for("i16", y)
    # i16 never saturates within its budget → hard decisions are bit-exact
    np.testing.assert_array_equal(i16, f32, err_msg=f"{name}: i16 != f32")

    # i8 on the SAME coarse symbols as an f32 decode is also bit-exact: the
    # budget proves no saturation, so only the quantizer can differ...
    i8, cfg8 = bits_for("i8", y)
    y_coarse = cfg8.quantize(DecoderEngine(cfg8)._to_full_rate(y))
    f32_coarse, _ = bits_for("f32", y_coarse)
    np.testing.assert_array_equal(
        i8, f32_coarse, err_msg=f"{name}: i8 != f32 on shared coarse symbols"
    )
    # ...and end-to-end the coarse (q=3) quantizer stays within its documented
    # tolerance of the q=8 decode (≈0.2-0.3 dB — far inside a 25% bit budget)
    assert np.mean(i8 != f32) <= 0.25, f"{name}: i8 deviates beyond tolerance"


# ---------------------------------------------------------------------------
# streaming fuzz: arbitrary chunk partitions == one-shot
# ---------------------------------------------------------------------------
@settings(**_COMMON)
@given(
    st.sampled_from(["ccsds", "ccsds-3/4", "ccsds-5/6", "is95-k9-2/3"]),
    st.integers(0, 2**16 - 1),  # seed
    st.booleans(),  # pre-quantized int session?
)
def test_streaming_random_partitions_match_one_shot(name, seed, prequantized):
    spec = get_code_spec(name)
    rng = np.random.default_rng(seed)
    n_bits = int(rng.integers(150, 400))
    cfg = PBVDConfig(spec=spec, D=32, L=12, q=8, backend="ref")
    engine = DecoderEngine(cfg)
    y = np.asarray(_tx(spec, n_bits, 4.0, seed))
    if prequantized:
        y = np.asarray(quantize_soft(jnp.asarray(y), 8))
    ref = np.asarray(engine.decode(jnp.asarray(y), n_bits))

    # random cut points; duplicates produce EMPTY chunks, and the forced
    # leading cuts guarantee 1-symbol and period-misaligned chunks
    n_cuts = int(rng.integers(3, 14))
    cuts = np.sort(rng.integers(0, len(y) + 1, n_cuts))
    cuts = np.unique(np.concatenate([[0, 1, min(3, len(y))], cuts]))
    parts = np.split(y, cuts)  # np.split keeps empty leading/dup parts

    sess = engine.session()
    outs = [sess.decode(c) for c in parts]
    outs.append(sess.finish(n_bits))
    got = np.concatenate(outs)
    np.testing.assert_array_equal(got, ref)
    assert sess.bits_emitted == n_bits


# ---------------------------------------------------------------------------
# batched fuzz: decode_batch == sequential decode per frame
# ---------------------------------------------------------------------------
@settings(**_COMMON)
@given(
    st.sampled_from(["ccsds", "ccsds-5/6", "lte-1/3"]),
    st.integers(0, 2**16 - 1),  # seed
    st.lists(st.integers(20, 180), min_size=2, max_size=5),  # frame lengths
)
def test_decode_batch_random_fleets(name, seed, lengths):
    spec = get_code_spec(name)
    cfg = PBVDConfig(spec=spec, D=32, L=12, q=8, backend="ref")
    engine = DecoderEngine(cfg)
    ys = [_tx(spec, n, 4.5, seed + i) for i, n in enumerate(lengths)]
    batch = engine.decode_batch(ys, lengths)
    for y, n, b in zip(ys, lengths, batch):
        np.testing.assert_array_equal(
            np.asarray(b), np.asarray(engine.decode(y, n))
        )
