"""Encoder tests: numpy oracle vs JAX scan implementation."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.encoder import encode_jax, encode_np, terminate
from repro.core.trellis import CCSDS_27, ConvCode


@given(st.lists(st.integers(0, 1), min_size=1, max_size=256))
@settings(max_examples=30, deadline=None)
def test_encode_jax_matches_np(bits):
    bits = np.array(bits, dtype=np.int64)
    a = encode_np(bits, CCSDS_27)
    b = np.asarray(encode_jax(jnp.asarray(bits), CCSDS_27))
    assert np.array_equal(a, b)


def test_encode_batched():
    rng = np.random.default_rng(0)
    bb = rng.integers(0, 2, (4, 96))
    ref = np.stack([encode_np(r, CCSDS_27) for r in bb])
    got = np.asarray(encode_jax(jnp.asarray(bb), CCSDS_27))
    assert np.array_equal(ref, got)


def test_terminate_returns_to_zero():
    code = CCSDS_27
    rng = np.random.default_rng(1)
    bits = terminate(rng.integers(0, 2, 50), code)
    s = 0
    for x in bits:
        s = (int(x) << (code.v - 1)) | (s >> 1)
    assert s == 0


def test_encoder_other_code():
    """(2,1,5) code sanity — encoder works for any (R,1,K)."""
    code = ConvCode(polys=((1, 0, 1, 1, 1), (1, 1, 1, 0, 1)))
    rng = np.random.default_rng(2)
    bits = rng.integers(0, 2, 64)
    a = encode_np(bits, code)
    b = np.asarray(encode_jax(jnp.asarray(bits), code))
    assert np.array_equal(a, b)
    assert a.shape == (64, 2)
