"""Fused single-kernel PBVD (ACS + in-VMEM traceback) vs the two-kernel path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantize import unpack_bits
from repro.core.trellis import CCSDS_27, ConvCode
from repro.kernels.fused import pbvd_fused_pallas
from repro.kernels.ref import acs_forward_ref, traceback_ref

CODE_25 = ConvCode(polys=((1, 0, 1, 1, 1), (1, 1, 1, 0, 1)))


def _unpack_words_bits(packed: np.ndarray, n_bits: int) -> np.ndarray:
    """(n_words, B) int32 → (n_bits, B) bits (LSB-first per word)."""
    n_words, B = packed.shape
    shifts = np.arange(32)
    bits = (packed[:, None, :] >> shifts[None, :, None]) & 1
    return bits.reshape(n_words * 32, B)[:n_bits]


@pytest.mark.parametrize("code", [CCSDS_27, CODE_25], ids=["217", "215"])
@pytest.mark.parametrize("dtype", [np.float32, np.int8], ids=["f32", "i8"])
def test_fused_matches_two_kernel(code, dtype):
    rng = np.random.default_rng(0)
    D, L = 64, 32
    T = D + 2 * L
    B = 128
    y = rng.normal(size=(T, code.R, B)).astype(np.float32)
    if dtype == np.int8:
        y = np.clip(np.round(y * 31.75), -127, 127).astype(np.int8)
    y = jnp.asarray(y)

    sp, pm = acs_forward_ref(y, code)
    start = jnp.zeros((B,), jnp.int32)
    ref_bits = np.asarray(traceback_ref(sp, code, L, D, start))

    packed = pbvd_fused_pallas(y, code, decode_start=L, n_decode=D, interpret=True)
    got = _unpack_words_bits(np.asarray(packed), D)
    np.testing.assert_array_equal(got, ref_bits)


@pytest.mark.parametrize("code", [CCSDS_27, CODE_25], ids=["217", "215"])
@pytest.mark.parametrize("tb_mode", ["serial", "prefix"])
@pytest.mark.parametrize("T_shape", ["even", "odd"])
def test_fused_radix4_dbuf_matches_ref(code, tb_mode, T_shape):
    """The radix-4 fused kernel (double-buffered HBM→VMEM symbol pipeline,
    in-kernel widen/clip, odd-T trailing radix-2 step) is bit-exact to the
    radix-2 jnp oracle under both traceback modes."""
    rng = np.random.default_rng(7)
    D, L = 64, (32 if T_shape == "even" else 29)
    T = D + 2 * L
    if T_shape == "odd":
        T += 1  # 123 stages: exercises the trailing radix-2 step
    B = 128
    y = np.clip(rng.normal(size=(T, code.R, B)) * 2.5, -3, 3)
    y = jnp.asarray(np.round(y).astype(np.int8))

    # i16: the narrow path (in-kernel widen/clip + re-derived cadence) that
    # every registered code supports at radix 4 (K=5's i8 budget cannot
    # absorb two unnormalized stages — that rejection has its own test)
    sp, _ = acs_forward_ref(y, code, metric_mode="i16")
    ref_bits = np.asarray(
        traceback_ref(sp, code, T - D - L, D, jnp.zeros((B,), jnp.int32))
    )
    packed = pbvd_fused_pallas(
        y, code, decode_start=T - D - L, n_decode=D, interpret=True,
        metric_mode="i16", tb_mode=tb_mode, acs_radix=4, sym_chunk=32,
    )
    got = _unpack_words_bits(np.asarray(packed), D)
    np.testing.assert_array_equal(got, ref_bits)


def test_fused_end_to_end_noiseless():
    from repro.core.channel import transmit
    from repro.core.encoder import encode_jax, terminate
    from repro.core.pbvd import frame_stream
    from repro.core.quantize import quantize_soft

    code = CCSDS_27
    rng = np.random.default_rng(1)
    D, L = 128, 42
    n = 256
    bits = terminate(rng.integers(0, 2, n), code)
    coded = encode_jax(jnp.asarray(bits), code)
    y = transmit(jax.random.PRNGKey(0), coded, 5.0, code.rate)
    yq = quantize_soft(y, 8)
    blocks = frame_stream(yq, D, L, 2)  # (T, R, 2)
    blocks = jnp.pad(blocks, ((0, 0), (0, 0), (0, 126)))  # lane pad
    packed = pbvd_fused_pallas(blocks, code, decode_start=L, n_decode=D, interpret=True)
    got = _unpack_words_bits(np.asarray(packed), D)
    decoded = np.concatenate([got[:, 0], got[:, 1]])[:n]
    assert np.array_equal(decoded, bits[:n])


def test_fused_vmem_budget():
    """The fused kernel's VMEM working set fits the documented budget."""
    code = CCSDS_27
    D, L = 512, 42
    T = D + 2 * L
    sp_bytes = T * 2 * 4 * 128  # scratch SP
    y_bytes = T * code.R * 4 * 128
    pm_bytes = code.n_states * 4 * 128
    total = sp_bytes + y_bytes + pm_bytes
    assert total < 64 * 1024 * 1024  # well under a v5e core's VMEM
