"""Quantization / packing tests (paper §IV-C storage schemes)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.quantize import (
    dequantize_soft,
    pack_bits,
    pack_words,
    quantize_soft,
    u1_bytes,
    u2_bytes,
    unpack_bits,
    unpack_words,
)


@given(st.integers(0, 2**31 - 1), st.sampled_from([4, 8, 16]))
@settings(max_examples=25, deadline=None)
def test_word_pack_roundtrip(seed, q):
    rng = np.random.default_rng(seed)
    per = 32 // q
    n = per * rng.integers(1, 16)
    qmax = (1 << (q - 1)) - 1
    vals = rng.integers(-qmax - 1, qmax + 1, n).astype(np.int32)
    w = pack_words(jnp.asarray(vals), q)
    back = np.asarray(unpack_words(w, q))
    assert np.array_equal(back, vals)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_bit_pack_roundtrip(seed):
    rng = np.random.default_rng(seed)
    n = 8 * rng.integers(1, 64)
    bits = rng.integers(0, 2, n).astype(np.int32)
    packed = pack_bits(jnp.asarray(bits))
    assert packed.dtype == jnp.uint8 and packed.shape == (n // 8,)
    back = np.asarray(unpack_bits(packed, n))
    assert np.array_equal(back, bits)


def test_quantize_dequantize_error_bound():
    rng = np.random.default_rng(0)
    y = jnp.asarray(rng.normal(size=1024).astype(np.float32))
    z = quantize_soft(y, 8)
    yd = dequantize_soft(z, 8)
    # quantization step = 4/127; clipped tail beyond ±4σ is negligible
    inside = np.abs(np.asarray(y)) < 4.0
    err = np.abs(np.asarray(yd) - np.asarray(y))[inside]
    assert err.max() <= (4.0 / 127) / 2 + 1e-6


def test_paper_u1_u2_values():
    """§IV-C: U₁ drops 4R → 4R/⌊32/q⌋; U₂ drops 4 → 1/8."""
    assert u1_bytes(2, None) == 8.0  # f32, R=2
    assert u1_bytes(2, 8) == 2.0  # 8-bit packed, 4 per word
    assert u1_bytes(2, 4) == 1.0
    assert u2_bytes(False) == 4.0
    assert u2_bytes(True) == 0.125


def test_quantize_saturates():
    y = jnp.asarray([1e9, -1e9], dtype=jnp.float32)
    z = np.asarray(quantize_soft(y, 8))
    # clipping is SYMMETRIC: -2^(q-1) is excluded so in-register negation of
    # a quantized symbol (the folded BM path) can never wrap
    assert z[0] == 127 and z[1] == -127


@given(st.integers(0, 2**31 - 1), st.sampled_from([2, 3, 4, 8, 12, 16]))
@settings(max_examples=25, deadline=None)
def test_quantize_symmetric_clip_bounds(seed, q):
    """|quantize_soft| ≤ 2^(q-1)-1 for any input, any q — and the bound is hit."""
    rng = np.random.default_rng(seed)
    qmax = (1 << (q - 1)) - 1
    y = np.concatenate(
        [rng.normal(scale=100.0, size=256), [1e30, -1e30, 0.0]]
    ).astype(np.float32)
    z = np.asarray(quantize_soft(jnp.asarray(y), q), dtype=np.int64)
    assert z.max() == qmax and z.min() == -qmax
    assert np.all(np.abs(z) <= qmax)
    # negation of every representable value stays representable (fold safety)
    assert np.all(np.abs(-z) <= qmax)


@given(st.integers(0, 2**31 - 1), st.sampled_from([4, 8, 16]))
@settings(max_examples=25, deadline=None)
def test_word_pack_roundtrip_non_multiple(seed, q):
    """pack_words zero-pads a ragged last dim; unpack(per_axis_len) trims it."""
    rng = np.random.default_rng(seed)
    per = 32 // q
    n = int(rng.integers(1, 16) * per + rng.integers(1, per))  # NOT a multiple
    assert n % per != 0
    qmax = (1 << (q - 1)) - 1
    vals = rng.integers(-qmax, qmax + 1, n).astype(np.int32)
    w = pack_words(jnp.asarray(vals), q)
    assert w.shape == (-(-n // per),)
    back = np.asarray(unpack_words(w, q, per_axis_len=n))
    assert np.array_equal(back, vals)
    # the pad region decodes as zeros (unpack without trimming)
    full = np.asarray(unpack_words(w, q))
    assert np.all(full[n:] == 0)

def test_norm_interval_radix_semantics():
    """The radix argument converts the cadence to fused-step units without
    changing the radix-2 stage cadence, and the total inter-normalization
    stage gap always fits the budget."""
    from repro.core.quantize import (
        metric_dtype_max,
        metric_mode_qmax,
        norm_interval,
        pm_spread_bound,
    )
    from repro.core.trellis import CCSDS_27

    code = CCSDS_27
    for mode in ("i16", "i8"):
        k2 = norm_interval(code, mode)  # historical single-argument form
        assert k2 == norm_interval(code, mode, 2)  # radix 2 is the default
        k4 = norm_interval(code, mode, 4)
        assert 1 <= k4 <= max(1, k2 // 2)  # two stages accumulate per step
        qmax = metric_mode_qmax(code, mode)
        for stages in (k2, 2 * k4):  # worst gap per radix, in stages
            assert pm_spread_bound(code, qmax, stages) <= metric_dtype_max(mode)
    assert norm_interval(code, "f32") == 0
    assert norm_interval(code, "f32", 4) == 0
