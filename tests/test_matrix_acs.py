"""Unit suite for the k-stage (min,+) matrix-ACS layer (tier-1).

Covers the trellis-side construction (PR 6) from first principles:

  * the combined k·R-bit labels of ``matrix_acs_tables(k)`` equal a
    brute-force walk of the canonical transition ``s' = (x << (v-1)) | (s >> 1)``
    along every k-stage path, i.e. the (min,+) matrix entries are exactly the
    summed per-stage butterfly branch metrics;
  * k=2 reproduces ``radix4_acs_tables`` (the matrix layer generalizes the
    PR 5 radix-4 tables);
  * the antipodal fold round-trips: sign·folded == direct correlation, and
    the signed one-hot expansion matrix ``E @ BMk_folded`` assembles the
    same values as the (index, sign) gather;
  * ``acs_forward_ref(impl="matrix")`` is bit-exact to the butterfly on the
    survivor planes (pm differs by a uniform per-lane shift only);
  * the config-time guard rails: structural ``acs_k`` bounds, the
    narrow-mode saturation budget counterexample, and the uniform
    ``knob_error`` shape raised by BOTH ``PBVDConfig`` and
    ``pbvd_decode_blocks`` before any jit trace.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.codespec import LTE_37, _from_octal
from repro.core.pbvd import PBVDConfig
from repro.core.quantize import norm_interval
from repro.core.trellis import CCSDS_27, ConvCode
from repro.kernels.ops import pbvd_decode_blocks
from repro.kernels.ref import (
    acs_forward_ref,
    expand_folded_matrix_bm,
    folded_matrix_bm_table,
)

CODES = {"ccsds": CCSDS_27, "lte13": LTE_37, "k3": _from_octal(3, 0o7, 0o5)}


def _valid_ks(code, ks=(1, 2, 3)):
    return [k for k in ks if k <= code.v and k * code.R <= 8]


def _bm_of_label(y_stages, lab, k, R):
    """Direct correlation metric of a k·R-bit combined label (stage t = MSBs)."""
    bm = np.zeros(y_stages.shape[-1])
    for r in range(k * R):
        bit = (lab >> (k * R - 1 - r)) & 1
        bm = bm + y_stages[r // R, r % R] * (2.0 * bit - 1.0)
    return bm


# ---------------------------------------------------------------------------
# table construction
# ---------------------------------------------------------------------------
@pytest.mark.tier1
@pytest.mark.parametrize("name", sorted(CODES))
def test_matrix_tables_match_transition_walk(name):
    code = CODES[name]
    v, R, N = code.v, code.R, code.n_states
    for k in _valid_ks(code):
        U = N >> k
        tabs = code.matrix_acs_tables(k)
        for n_prime in range(N):
            c = n_prime >> (v - k)
            u = n_prime % U
            for j in range(1 << k):
                s = (1 << k) * u + j  # pred(n', j)
                lab = 0
                for i in range(k):
                    x = (c >> i) & 1
                    lab = (lab << R) | int(code.output_int(s, x))
                    s = (x << (v - 1)) | (s >> 1)  # canonical transition
                assert s == n_prime, f"{name} k={k}: path does not land on n'"
                assert tabs["cc"][c, j, u] == lab, (name, k, n_prime, j)


@pytest.mark.tier1
def test_matrix_k2_reproduces_radix4_tables():
    for name, code in CODES.items():
        if code.v < 2:
            continue
        np.testing.assert_array_equal(
            code.matrix_acs_tables(2)["cc"],
            code.radix4_acs_tables["cc"],
            err_msg=f"{name}: matrix k=2 labels != radix-4 labels",
        )


@pytest.mark.tier1
@pytest.mark.parametrize("name", sorted(CODES))
def test_matrix_fold_and_expansion_are_exact(name):
    code = CODES[name]
    R = code.R
    rng = np.random.default_rng(7)
    for k in _valid_ks(code):
        y = rng.integers(-31, 32, size=(k, R, 5)).astype(np.int32)
        # fold: sign[cc]·folded[idx[cc]] == direct correlation, every label
        yk = jnp.asarray(np.moveaxis(y.reshape(k * R, 5), 0, -1))  # (5, kR)
        folded = np.asarray(folded_matrix_bm_table(yk, code, k))  # (5, 2^(kR-1))
        full = np.asarray(expand_folded_matrix_bm(jnp.asarray(folded), code, k))
        for lab in range(1 << (k * R)):
            np.testing.assert_array_equal(
                full[:, lab], _bm_of_label(y, lab, k, R).astype(np.int32),
                err_msg=f"{name} k={k} label {lab}: fold expansion diverged",
            )
        # expansion operand: E @ folded == the (index, sign) gather
        tabs = code.matrix_acs_tables(k)
        e = code.matrix_expansion(k)
        assembled = (e @ folded.T.astype(np.float32)).astype(np.int64)
        gathered = (
            tabs["fold_sgn"].reshape(-1)[:, None]
            * folded.T[tabs["fold_idx"].reshape(-1)]
        )
        np.testing.assert_array_equal(
            assembled, gathered, err_msg=f"{name} k={k}: E-matmul diverged"
        )


# ---------------------------------------------------------------------------
# forward pass
# ---------------------------------------------------------------------------
@pytest.mark.tier1
@pytest.mark.parametrize("name", sorted(CODES))
@pytest.mark.parametrize("metric_mode", ["f32", "i16"])
def test_ref_matrix_forward_bit_exact(name, metric_mode):
    code = CODES[name]
    rng = np.random.default_rng(11)
    T, B = 29, 4  # T mod k != 0 for every k: trailing radix-2 stages run
    y = jnp.asarray(
        np.clip(np.round(rng.normal(size=(T, code.R, B)) * 15), -127, 127)
        .astype(np.int16)
    )
    sp_b, pm_b = acs_forward_ref(y, code, metric_mode=metric_mode)
    for k in _valid_ks(code):
        sp_m, pm_m = acs_forward_ref(
            y, code, metric_mode=metric_mode, impl="matrix", matrix_k=k
        )
        np.testing.assert_array_equal(
            np.asarray(sp_m), np.asarray(sp_b),
            err_msg=f"{name}/{metric_mode}/k={k}: survivor planes diverged",
        )
        # pm may differ from the butterfly only by a uniform per-lane shift
        # (the matrix cadence normalizes per k-stage step)
        d = np.asarray(pm_m, np.int64) - np.asarray(pm_b, np.int64)
        assert np.all(d == d[0:1]), f"{name}/{metric_mode}/k={k}: pm not a shift"


# ---------------------------------------------------------------------------
# guard rails
# ---------------------------------------------------------------------------
@pytest.mark.tier1
def test_structural_k_bounds_rejected():
    code = CCSDS_27  # v=6, R=2
    with pytest.raises(ValueError, match="positive int"):
        code.validate_matrix_k(0)
    with pytest.raises(ValueError, match="exceeds the trellis memory"):
        code.validate_matrix_k(7)
    with pytest.raises(ValueError, match="label bits"):
        code.validate_matrix_k(5)  # k·R = 10 > 8
    with pytest.raises(ValueError, match="label bits"):
        LTE_37.validate_matrix_k(3)  # k·R = 9 > 8
    for cfg_kwargs in (dict(acs_k=0), dict(acs_k=7), dict(acs_k=5)):
        with pytest.raises(ValueError):
            PBVDConfig(backend="ref", acs_impl="matrix", **cfg_kwargs)


@pytest.mark.tier1
def test_matrix_k_budget_counterexample_rejected():
    """A deep-memory code where a VALID structural k blows the i8 budget.

    K=31, R=2 → v=30: the i8 budget forces qmax=1 and
    pm_spread_bound = (2·30 + k)·2·1, so k ≤ 3 fits 127 but k=4 gives
    128 > 127. The rejection must fire at CONFIG time from both entry
    points — cheap, because the check runs before any 2^30-state table
    materializes.
    """
    code = ConvCode(polys=((1,) + (0,) * 29 + (1,), (1,) * 31))
    code.validate_matrix_k(4)  # structurally fine: 4 ≤ v, k·R = 8
    assert norm_interval(code, "i8", stages_per_step=3) >= 1
    with pytest.raises(ValueError, match="cannot accumulate 4 unnormalized"):
        norm_interval(code, "i8", stages_per_step=4)
    with pytest.raises(ValueError, match="cannot accumulate 4 unnormalized"):
        PBVDConfig(code=code, backend="ref", metric_mode="i8",
                   acs_impl="matrix", acs_k=4)
    with pytest.raises(ValueError, match="cannot accumulate 4 unnormalized"):
        pbvd_decode_blocks(
            jnp.zeros((8, 2, 1), jnp.int8), code, decode_start=0, n_decode=4,
            backend="ref", metric_mode="i8", acs_impl="matrix", acs_k=4,
        )


@pytest.mark.tier1
@pytest.mark.parametrize(
    "knob,value",
    [
        ("acs_impl", "systolic"),
        ("acs_radix", 3),
        ("tb_mode", "zigzag"),
        ("metric_mode", "i4"),
    ],
)
def test_uniform_knob_errors_pre_jit(knob, value):
    """Bad knobs fail identically — backend, knob name, allowed values in the
    message — whether they enter through PBVDConfig or pbvd_decode_blocks,
    always eagerly (no jit trace, no kernel-internal error)."""
    for entry in ("config", "dispatch"):
        if entry == "config":
            ctx = pytest.raises(ValueError, match=rf"backend 'ref'.*{knob}")
            with ctx as ei:
                PBVDConfig(backend="ref", **{knob: value})
        else:
            ctx = pytest.raises(ValueError, match=rf"backend 'ref'.*{knob}")
            with ctx as ei:
                pbvd_decode_blocks(
                    jnp.zeros((8, 2, 1), jnp.float32), CCSDS_27,
                    decode_start=0, n_decode=4, backend="ref", **{knob: value},
                )
        msg = str(ei.value)
        assert "supported" in msg and repr(value) in msg, msg
