"""Fault-tolerance substrate tests: checkpointing, data pipeline stragglers,
gradient compression, elastic rescale planning, failure-recovery training."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro._unused.train.checkpoint import CheckpointManager
from repro._unused.train.compression import (
    compress_decompress_tree,
    dequantize_int8,
    ef_compress,
    ef_init,
    quantize_int8,
)
from repro._unused.train.data import PrefetchPipeline, SyntheticLMStream


# ---- checkpoint -----------------------------------------------------------------------
def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32)),
        "nested": {"b": jnp.asarray(rng.integers(0, 100, (4,)), jnp.int32)},
        "scalar": jnp.float32(3.5),
    }


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    t = _tree()
    mgr.save(7, t)
    out = mgr.restore(None, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_rotation(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_atomicity_partial_tmp(tmp_path):
    """A stale .tmp dir (simulated crash mid-save) must not break restore."""
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(1, _tree(1))
    # simulate crash: a half-written tmp for step 2
    bad = tmp_path / "step_00000002.tmp"
    bad.mkdir()
    (bad / "leaf_00000.npy").write_bytes(b"garbage")
    assert mgr.latest_step() == 1
    out = mgr.restore(None, _tree())
    assert out is not None


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    mgr.save_async(5, _tree(5))
    mgr.wait()
    assert mgr.latest_step() == 5


def test_checkpoint_restore_detects_mismatch(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _tree())
    with pytest.raises(ValueError):
        mgr.restore(1, {"only_one_leaf": jnp.zeros(3)})


# ---- data pipeline -----------------------------------------------------------------------
def test_synthetic_stream_deterministic():
    s1 = SyntheticLMStream(vocab=128, seq_len=16, global_batch=4, seed=9)
    s2 = SyntheticLMStream(vocab=128, seq_len=16, global_batch=4, seed=9)
    b1, b2 = s1.batch(13), s2.batch(13)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are the shifted tokens
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_stream_host_sharding():
    full = SyntheticLMStream(vocab=64, seq_len=8, global_batch=8)
    h0 = SyntheticLMStream(vocab=64, seq_len=8, global_batch=8, host_index=0, host_count=2)
    assert h0.local_batch == 4
    with pytest.raises(ValueError):
        SyntheticLMStream(vocab=64, seq_len=8, global_batch=7, host_count=2)


def test_prefetch_pipeline_and_straggler_fallback():
    class SlowStream:
        def __init__(self):
            self.calls = 0

        def batch(self, step):
            self.calls += 1
            if step >= 2:
                time.sleep(0.5)  # straggling shard
            return {"x": np.full((2,), step)}

    p = PrefetchPipeline(SlowStream(), depth=1)
    try:
        b0 = p.next_batch(timeout=2.0)
        b1 = p.next_batch(timeout=2.0)
        # producer now straggles; a tight deadline falls back to cached batch
        b2 = p.next_batch(timeout=0.01)
        assert p.stats["straggler_fallbacks"] >= 1
        np.testing.assert_array_equal(b2["x"], b1["x"])
    finally:
        p.close()


# ---- gradient compression ------------------------------------------------------------------
@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_int8_quantization_error_bound(seed):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(1024,)).astype(np.float32))
    q, s = quantize_int8(g)
    back = dequantize_int8(q, s, g.shape, g.dtype)
    # error bounded by half a quantization step per block
    step = np.asarray(s).repeat(256)[: g.size]
    assert np.all(np.abs(np.asarray(back) - np.asarray(g)) <= step / 2 + 1e-7)


def test_error_feedback_accumulates_residual():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(512,)).astype(np.float32))}
    state = ef_init(g)
    g1, state = ef_compress(g, state)
    # residual = exactly what compression lost
    np.testing.assert_allclose(
        np.asarray(state.residual["w"]),
        np.asarray(g["w"]) - np.asarray(g1["w"]),
        atol=1e-6,
    )
    # over many steps the average compressed gradient → the true gradient
    total = np.zeros(512, np.float32)
    for _ in range(64):
        gc, state = ef_compress(g, state)
        total += np.asarray(gc["w"])
    np.testing.assert_allclose(total / 64, np.asarray(g["w"]), atol=2e-2)


def test_compress_tree_skips_tiny_leaves():
    g = {"scale": jnp.ones((4,)), "w": jnp.ones((512,))}
    out = compress_decompress_tree(g)
    np.testing.assert_array_equal(np.asarray(out["scale"]), np.asarray(g["scale"]))


# ---- elastic rescale ------------------------------------------------------------------------
def test_plan_rescale():
    from repro.launch.elastic import plan_rescale

    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    plan = plan_rescale(FakeMesh(), lost_chips=16)
    assert plan.new_chip_count <= 512 - 16
    assert plan.new_shape[plan.axis_names.index("model")] == 16
    # losing one host of 16 chips should drop exactly one data slice
    assert plan.new_chip_count == 496 or plan.new_chip_count == 480


def test_plan_rescale_maximizes_across_multiple_axes():
    """The counterexample behind the early-``break`` fix: with TWO 4-wide
    data-like axes and 7 chips lost, the lexicographic first-fit accepted
    4×2 = 8 chips; the exhaustive max-product search finds 3×3 = 9."""
    from repro.launch.elastic import plan_rescale

    class TwoAxis:
        axis_names = ("a", "b")
        shape = {"a": 4, "b": 4}

    plan = plan_rescale(TwoAxis(), lost_chips=7)
    assert plan.new_chip_count == 9
    assert sorted(plan.new_shape) == [3, 3]
    assert plan.dropped_chips == 7


def test_plan_rescale_shrink_axes_port():
    from repro.launch.elastic import plan_rescale

    class DecodeMesh:
        axis_names = ("pod", "data")
        shape = {"pod": 2, "data": 8}

    # the decode port: only the engine's block_axes may shrink — pod is
    # launch geometry here and must stay fixed at 2
    plan = plan_rescale(DecodeMesh(), lost_chips=4, shrink_axes=("data",))
    assert plan.new_shape == (2, 6) and plan.new_chip_count == 12
    with pytest.raises(ValueError, match="shrink_axes"):
        plan_rescale(DecodeMesh(), lost_chips=1, shrink_axes=("bogus",))


def test_plan_decode_rescale_none_when_nothing_survives():
    from repro.launch.elastic import plan_decode_rescale

    class OneChip:
        axis_names = ("data",)
        shape = {"data": 1}

    # a 1-chip mesh losing its only chip has no valid smaller mesh
    assert plan_decode_rescale(OneChip(), ("data",), lost_chips=1) is None

    class Fixed:
        axis_names = ("pod", "data")
        shape = {"pod": 4, "data": 2}

    # fixed axes alone (pod=4) exceed the 3 survivors: the all-ones shrink
    # still needs 4 chips, so there is no plan
    assert plan_decode_rescale(Fixed(), ("data",), lost_chips=5) is None


def test_rescale_decode_engine_drops_to_meshless_bit_exact():
    from repro.core.codespec import get_code_spec
    from repro.core.engine import DecoderEngine
    from repro.core.pbvd import PBVDConfig
    from repro.launch.elastic import rescale_decode_engine
    from repro.launch.mesh import make_decode_mesh

    spec = get_code_spec("ccsds")
    cfg = PBVDConfig(spec=spec, backend="ref", D=64, L=16, q=8)
    eng = DecoderEngine(cfg, mesh=make_decode_mesh("data=1"), block_axes=("data",))
    new = rescale_decode_engine(eng, lost_chips=1)
    assert new.mesh is None and new.block_axes == ("data",)
    # meshless engines pass through unchanged (nothing to rescale)
    assert rescale_decode_engine(new, lost_chips=1) is new

    rng = np.random.default_rng(0)
    y = jnp.asarray(rng.normal(size=(512, spec.code.R)).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(new.decode(y, 192)), np.asarray(eng.decode(y, 192))
    )


def test_reshard_roundtrip_local():
    from repro.launch.elastic import reshard

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    axes = {"w": ("fsdp", "mlp")}
    out = reshard(tree, axes, mesh)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))


# ---- end-to-end failure recovery --------------------------------------------------------------
def test_train_loop_failure_recovery(tmp_path):
    from repro.configs.base import get_config
    from repro.launch.mesh import make_local_mesh
    from repro.launch.train import TrainLoop
    from repro._unused.train.optimizer import AdamWConfig

    cfg = get_config("starcoder2-3b").reduced()
    loop = TrainLoop(
        cfg,
        AdamWConfig(total_steps=24, warmup_steps=2),
        make_local_mesh(),
        ckpt_dir=tmp_path,
        global_batch=2,
        seq_len=32,
        ckpt_every=8,
    )
    try:
        log = loop.run(24, inject_failure_at=12)
        assert loop.step == 24
        assert log[-1]["step"] == 24
        # a checkpoint exists at/after the last ckpt_every boundary
        assert loop.ckpt.latest_step() >= 16
    finally:
        loop.pipeline.close()
