"""Trellis / group-classification tests, including the paper's Table II."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.trellis import CCSDS_27, ConvCode, parity


# Table II of the paper, verbatim: (α, β, γ, θ, states) per group.
TABLE_II = [
    (0b00, 0b11, 0b11, 0b00, [0, 1, 4, 5, 24, 25, 28, 29, 42, 43, 46, 47, 50, 51, 54, 55]),
    (0b01, 0b10, 0b10, 0b01, [2, 3, 6, 7, 26, 27, 30, 31, 40, 41, 44, 45, 48, 49, 52, 53]),
    (0b11, 0b00, 0b00, 0b11, [8, 9, 12, 13, 16, 17, 20, 21, 34, 35, 38, 39, 58, 59, 62, 63]),
    (0b10, 0b01, 0b01, 0b10, [10, 11, 14, 15, 18, 19, 22, 23, 32, 33, 36, 37, 56, 57, 60, 61]),
]


def test_table2_exact():
    """The group classification reproduces the paper's Table II exactly."""
    groups = {g["alpha"]: g for g in CCSDS_27.groups}
    assert len(groups) == 4 == CCSDS_27.n_groups
    for alpha, beta, gamma, theta, states in TABLE_II:
        g = groups[alpha]
        assert g["beta"] == beta
        assert g["gamma"] == gamma
        assert g["theta"] == theta
        assert g["states"] == sorted(states)


def test_ccsds_shape_params():
    c = CCSDS_27
    assert (c.R, c.K, c.v, c.n_states, c.n_butterflies) == (2, 7, 6, 64, 32)
    assert c.rate == 0.5


def test_butterfly_codeword_relations():
    """Eqs. (4)-(6): β = α⊕g_{K-1}, γ = α⊕g_0, θ = α⊕g_{K-1}⊕g_0."""
    c = CCSDS_27
    cw = c.butterfly_codewords
    assert np.array_equal(cw[:, 1], cw[:, 0] ^ c.x_mask)  # β
    assert np.array_equal(cw[:, 2], cw[:, 0] ^ c.l_mask)  # γ
    assert np.array_equal(cw[:, 3], cw[:, 0] ^ c.x_mask ^ c.l_mask)  # θ


def test_codewords_match_direct_encoding():
    """α/β/γ/θ equal direct eq.(2) evaluation on the butterfly sources."""
    c = CCSDS_27
    j = np.arange(c.n_butterflies)
    assert np.array_equal(c.butterfly_codewords[:, 0], c.output_int(2 * j, 0))
    assert np.array_equal(c.butterfly_codewords[:, 1], c.output_int(2 * j, 1))
    assert np.array_equal(c.butterfly_codewords[:, 2], c.output_int(2 * j + 1, 0))
    assert np.array_equal(c.butterfly_codewords[:, 3], c.output_int(2 * j + 1, 1))


@st.composite
def random_code(draw):
    R = draw(st.integers(2, 3))
    K = draw(st.integers(3, 8))
    polys = []
    for _ in range(R):
        # ensure a non-degenerate poly (input tap or memory tap set)
        bits = draw(st.lists(st.integers(0, 1), min_size=K, max_size=K))
        if sum(bits) == 0:
            bits[0] = 1
        polys.append(tuple(bits))
    return ConvCode(polys=tuple(polys))


@given(random_code())
@settings(max_examples=50, deadline=None)
def test_group_count_bound(code):
    """§III-B: butterflies classify into at most 2^R groups."""
    assert code.n_groups <= 1 << code.R
    # every butterfly's 4 codewords are fully determined by α and the masks
    cw = code.butterfly_codewords
    assert np.array_equal(cw[:, 1], cw[:, 0] ^ code.x_mask)
    assert np.array_equal(cw[:, 2], cw[:, 0] ^ code.l_mask)


@given(random_code(), st.integers(0, 1))
@settings(max_examples=30, deadline=None)
def test_output_bits_parity_identity(code, x):
    """output_bits equals the per-tap XOR of eq. (2) for every state."""
    for d in range(code.n_states):
        expect = []
        for r in range(code.R):
            g = code.polys[r]  # [g_{K-1}, ..., g_0]
            acc = x * g[0]
            for i in range(1, code.K):  # g[i] multiplies D_{K-1-i}
                acc ^= ((d >> (code.K - 1 - i)) & 1) * g[i]
            expect.append(acc)
        got = code.output_bits(d, x).tolist()
        assert got == expect


def test_parity_vectorized():
    xs = np.arange(1024)
    expect = np.array([bin(x).count("1") & 1 for x in xs])
    assert np.array_equal(parity(xs), expect)


def test_bm_reduction_claim():
    """Paper claim: total BM computation per stage is 2^{R+2} < 2^K values
    for the common codes (R=2, K=5/7/9; R=3, K=7/9)."""
    for R, K in [(2, 5), (2, 7), (2, 9), (3, 7), (3, 9)]:
        assert 1 << (R + 2) < 1 << K
