"""Golden known-answer suite (tier-1).

Fixed-seed noisy symbols → expected decoded bits, committed as
``tests/golden/*.npz`` (one per registered CodeSpec) by
``tools/regen_golden.py``. The symbols are read from disk, NOT re-derived
through the encoder/channel at test time, so a JAX/XLA version bump that
moves any stage of the decode path — framing, depuncturing, quantization,
folded branch metrics, ACS, traceback — fails here against a byte-stable
reference instead of drifting silently.

Every registered CodeSpec × backend × metric mode × traceback mode × ACS
formulation is replayed: ``bits_f32`` must be reproduced exactly by metric
modes "f32" AND "i16" (the i16 contract is bit-exact hard decisions),
``bits_i8`` by "i8" — and the prefix traceback, the stage-fused radix-4
forward pass AND the k-stage (min,+) matrix forward pass must reproduce the
same vectors as the serial walk / radix-2 butterfly (the TB_MODES,
ACS_RADIX and ACS_IMPL contracts are bit-exactness, so the goldens need no
new files). Matrix k=3 is skipped where the structural bound k·R ≤ 8
forbids it (rate-1/3 codes).
"""

import json
from pathlib import Path

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.codespec import available_code_specs, get_code_spec
from repro.core.engine import DecoderEngine
from repro.core.pbvd import PBVDConfig
from repro.kernels.ops import available_backends

GOLDEN_DIR = Path(__file__).parent / "golden"


def _load(name):
    path = GOLDEN_DIR / (name.replace("/", "_") + ".npz")
    if not path.exists():
        pytest.fail(
            f"missing golden vector {path.name} — run "
            f"PYTHONPATH=src python tools/regen_golden.py"
        )
    with np.load(path, allow_pickle=False) as z:
        data = {k: z[k] for k in z.files}
    data["meta"] = json.loads(str(data["meta"]))
    return data


@pytest.mark.tier1
def test_golden_covers_every_registered_spec():
    missing = [
        name
        for name in available_code_specs()
        if not (GOLDEN_DIR / (name.replace("/", "_") + ".npz")).exists()
    ]
    assert not missing, f"no golden vectors for {missing}; run tools/regen_golden.py"


@pytest.mark.tier1
@pytest.mark.parametrize("backend", available_backends())
@pytest.mark.parametrize("name", available_code_specs())
@pytest.mark.parametrize("metric_mode", ["f32", "i16", "i8"])
@pytest.mark.parametrize("tb_mode", ["serial", "prefix"])
@pytest.mark.parametrize(
    "acs",  # (acs_impl, acs_radix-or-k)
    [("butterfly", 2), ("butterfly", 4), ("matrix", 2), ("matrix", 3)],
    ids=["bfly-r2", "bfly-r4", "mat-k2", "mat-k3"],
)
def test_golden_decode(name, backend, metric_mode, tb_mode, acs):
    g = _load(name)
    meta = g["meta"]
    spec = get_code_spec(name)
    acs_impl, depth = acs
    if acs_impl == "matrix" and depth * spec.code.R > 8:
        pytest.skip(f"k·R = {depth * spec.code.R} > 8 (structural bound)")
    cfg = PBVDConfig(
        spec=spec,
        D=meta["D"],
        L=meta["L"],
        q=meta["q"],
        backend=backend,
        metric_mode=metric_mode,
        tb_mode=tb_mode,
        tb_chunk=24,  # non-divisor of T at the golden geometry
        acs_radix=depth if acs_impl == "butterfly" else 2,
        acs_impl=acs_impl,
        acs_k=depth if acs_impl == "matrix" else 2,
    )
    bits = np.asarray(
        DecoderEngine(cfg).decode(jnp.asarray(g["y"]), meta["n_bits"])
    )
    expected = g["bits_i8"] if metric_mode == "i8" else g["bits_f32"]
    np.testing.assert_array_equal(
        bits,
        expected,
        err_msg=f"{name}/{backend}/{metric_mode}/{tb_mode}/{acs_impl}-{depth} "
        f"drifted from the golden vector",
    )
