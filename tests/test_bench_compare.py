"""tools/bench_compare.py: the BENCH_*.json regression gate (tier-1).

The gate is CI-critical (a wrong exit code silently ungates perf), so the
row-matching and threshold semantics are pinned here: rows match on their
identity (non-measurement) fields only, only ``*_mbps`` fields gate, and
"no matching rows" is a pass unless ``--min-matches`` demands otherwise.
"""

import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).parent.parent / "tools"))
import bench_compare  # noqa: E402


def _doc(rows):
    return {"benchmark": "x", "rows": rows}


ROW = dict(kind="traceback_sweep", backend="ref", tb_chunk=64, n_blocks=8)


def _write(tmp_path, name, rows):
    p = tmp_path / name
    p.write_text(json.dumps(_doc(rows)))
    return str(p)


@pytest.mark.tier1
def test_identity_ignores_measurements():
    a = dict(ROW, serial_mbps=3.0, prefix_mbps=1.0, acs_ms=5.0)
    b = dict(ROW, serial_mbps=9.0, prefix_mbps=9.0, acs_ms=9.0)
    assert bench_compare.row_identity(a) == bench_compare.row_identity(b)
    assert bench_compare.row_identity(a) != bench_compare.row_identity(
        dict(ROW, tb_chunk=32, serial_mbps=3.0)
    )


@pytest.mark.tier1
def test_identity_ignores_derived_walk_steps(tmp_path):
    # a PR that shortens the traceback walk must STILL gate its throughput
    # against the baseline row (walk length is a derived stat, not identity)
    old = _write(
        tmp_path, "old.json", [dict(ROW, serial_walk_steps=596, prefix_mbps=3.0)]
    )
    new = _write(
        tmp_path, "new.json", [dict(ROW, serial_walk_steps=554, prefix_mbps=1.0)]
    )
    assert bench_compare.main([old, new, "--min-matches", "1"]) == 1


@pytest.mark.tier1
def test_pass_within_threshold(tmp_path, capsys):
    old = _write(tmp_path, "old.json", [dict(ROW, serial_mbps=3.0)])
    new = _write(tmp_path, "new.json", [dict(ROW, serial_mbps=2.6)])  # -13%
    assert bench_compare.main([old, new, "--threshold", "0.15"]) == 0


@pytest.mark.tier1
def test_fail_beyond_threshold(tmp_path):
    old = _write(tmp_path, "old.json", [dict(ROW, serial_mbps=3.0)])
    new = _write(tmp_path, "new.json", [dict(ROW, serial_mbps=2.4)])  # -20%
    assert bench_compare.main([old, new, "--threshold", "0.15"]) == 1


@pytest.mark.tier1
def test_latency_fields_report_but_never_gate(tmp_path):
    old = _write(tmp_path, "old.json", [dict(ROW, serial_mbps=3.0, acs_ms=1.0)])
    new = _write(tmp_path, "new.json", [dict(ROW, serial_mbps=3.0, acs_ms=99.0)])
    assert bench_compare.main([old, new]) == 0


@pytest.mark.tier1
def test_unmatched_rows_pass_unless_min_matches(tmp_path):
    old = _write(tmp_path, "old.json", [dict(ROW, n_blocks=512, serial_mbps=3.0)])
    new = _write(tmp_path, "new.json", [dict(ROW, n_blocks=8, serial_mbps=0.1)])
    assert bench_compare.main([old, new]) == 0  # geometry change, not regression
    assert bench_compare.main([old, new, "--min-matches", "1"]) == 2


@pytest.mark.tier1
def test_io_error_is_usage_exit(tmp_path):
    new = _write(tmp_path, "new.json", [dict(ROW, serial_mbps=1.0)])
    assert bench_compare.main([str(tmp_path / "missing.json"), new]) == 2
