"""Shared plumbing for the benchmark sweeps: timing, geometry, row merging.

BENCH_*.json is a CUMULATIVE artifact: each sweep owns a set of row
``kind``s and refreshing one sweep must replace exactly its own rows while
preserving every other sweep's. Environment fields (jax version/backend,
machine) describe the most recent write. The timing helper and the Table
III geometry live here too, so every sweep measures the same way —
cross-sweep comparability is the artifact's whole point.
"""

from __future__ import annotations

import json
import platform
import time
from pathlib import Path

import jax
import numpy as np

# Paper Table III geometry (CCSDS (2,1,7) — 64 states, D=512, L=42, q=8).
TABLE3 = dict(D=512, L=42, q=8)


def time_median(fn, reps: int) -> float:
    """Median of per-call wall times — robust to machine-load spikes that a
    mean over one timed loop folds into every row."""
    jax.block_until_ready(fn())  # warmup: trace + compile
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def merge_rows(
    path: str,
    rows: list[dict],
    replace_kinds: tuple[str, ...],
    *,
    geometry: dict,
) -> None:
    """Merge ``rows`` into ``path``, replacing only rows of ``replace_kinds``."""
    p = Path(path)
    if p.exists():
        doc = json.loads(p.read_text())
        doc["rows"] = [
            r for r in doc.get("rows", []) if r.get("kind") not in replace_kinds
        ]
    else:
        doc = dict(geometry=geometry, rows=[])
    doc["benchmark"] = "pbvd_bench"
    doc["jax_version"] = jax.__version__
    doc["jax_backend"] = jax.default_backend()
    doc["machine"] = platform.machine()
    doc["rows"] = doc["rows"] + rows
    p.write_text(json.dumps(doc, indent=2) + "\n")
