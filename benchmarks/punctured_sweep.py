"""Punctured multi-rate sweep: BER and throughput across the rate family.

For each rate of the CCSDS mother code (1/2, 2/3, 3/4, 5/6) the same engine
decodes the same payload — puncturing is a CodeSpec table entry, not a new
pipeline — and we report:

  * BER at a few Eb/N0 points (higher rate → less redundancy → worse BER),
  * decode throughput in payload Mbps (higher rate → fewer received symbols
    per payload bit → cheaper H2D, same trellis work per stage).

    PYTHONPATH=src python benchmarks/punctured_sweep.py [--bits 65536]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ber import simulate_ber
from repro.core.channel import transmit
from repro.core.codespec import get_code_spec
from repro.core.encoder import encode_jax, terminate
from repro.core.engine import DecoderEngine
from repro.core.pbvd import PBVDConfig

RATES = ["ccsds", "ccsds-2/3", "ccsds-3/4", "ccsds-5/6"]


def run(n_bits: int = 1 << 16, ebn0_points=(3.0, 4.0, 5.0), backend="ref") -> list[dict]:
    rows = []
    rng = np.random.default_rng(0)
    payload = rng.integers(0, 2, n_bits)
    for name in RATES:
        spec = get_code_spec(name)
        cfg = PBVDConfig(spec=spec, D=512, L=42, q=8, backend=backend)
        engine = DecoderEngine(cfg)

        # --- throughput on a fixed payload at 4 dB --------------------------------
        coded = encode_jax(jnp.asarray(terminate(payload, spec.code)), spec.code)
        tx = spec.puncture_stream(coded) if spec.is_punctured else coded
        y = transmit(jax.random.PRNGKey(1), tx, 4.0, spec.rate)
        f = jax.jit(lambda yy: engine.decode(yy, n_bits))
        jax.block_until_ready(f(y))
        t0 = time.perf_counter()
        reps = 3
        for _ in range(reps):
            out = f(y)
            jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) / reps
        ber4 = float(np.mean(np.asarray(out) != payload))

        # --- BER sweep -------------------------------------------------------------
        bers = {}
        key = jax.random.PRNGKey(2)
        for ebn0 in ebn0_points:
            key, k = jax.random.split(key)
            bers[ebn0] = simulate_ber(k, ebn0, cfg, n_bits=min(n_bits, 1 << 15))

        rows.append(
            dict(
                spec=name,
                rate=round(spec.rate, 4),
                n_symbols=int(tx.shape[0] if spec.is_punctured else tx.shape[0] * spec.code.R),
                mbps=round(n_bits / dt / 1e6, 2),
                ber_at_4db=ber4,
                **{f"ber_{e}db": v for e, v in bers.items()},
            )
        )
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--bits", type=int, default=1 << 16)
    # programmatic callers (benchmarks/run.py) get the defaults, not sys.argv
    args = ap.parse_args(argv if argv is not None else [])
    rows = run(args.bits)
    for r in rows:
        extra = ",".join(f"{k}={v}" for k, v in r.items() if k != "spec")
        print(f"punctured_{r['spec'].replace('/', '_')},{extra}")
    print("\nhigher rate → more payload Mbps through the same kernels, at a BER cost "
          "— the multi-rate family is one engine + four table entries.")


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
