"""Serving latency under a Poisson arrival trace: sustained Mb/s + p50/p99.

The kernel sweeps measure what a saturated launch can do; this sweep
measures what the serving layer DELIVERS when traffic arrives with jitter —
the piece that turns kernel throughput into servable traffic (ROADMAP item
2). ``n_streams`` concurrent streams push chunks through
:class:`repro.launch.serve_async.AsyncDecodeService` (paged session slabs,
deadline-or-size dispatch, bounded admission) with i.i.d. exponential
inter-arrival gaps; every stream's decoded bits are asserted bit-exact to
its one-shot ``engine.decode`` before any number is reported.

Rows land in BENCH_*.json as ``kind="serve_latency"``:

* ``sustained_mbps`` — delivered payload bits over the admit→last-delivery
  span (GATED by tools/bench_compare.py like every ``*_mbps`` field);
* ``p50_ms`` / ``p99_ms`` — per-chunk latency, admission to the dispatch
  that decoded the chunk's last symbol (REPORTED, not gated: they overlap
  the mbps signal and tail percentiles are noisy at smoke sample counts);
* ``dispatch_steps`` — coalesced pool steps the trace needed (reported).

Per the repo-wide sweep policy the trace runs ``reps`` times after a
warm-up pass (compile time is not serving latency) and each field is the
median across runs.

    PYTHONPATH=src python benchmarks/serve_latency.py \
        [--streams 64] [--backend ref] [--reps 5] [--out BENCH_pr.json]

``--smoke`` shrinks to CI geometry (16 streams, short payloads, tiny
blocks) but keeps every code path — admission, slab paging, deadline
dispatch, backpressure accounting — identical.

``--fault-rate R`` adds a SECOND row measuring degraded mode: transient
dispatch/slab faults injected i.i.d. at rate R (fixed seed) and absorbed by
the retry/backpressure machinery of DESIGN.md §14 — every stream still
asserts bit-exact; the row carries a ``fault_rate`` identity field so
tools/bench_compare.py never matches it against a clean baseline
(degradation is reported, not gated) plus ``retry_steps`` for context.

``--kill-at N`` adds a ``kind="serve_recovery"`` row measuring crash
recovery (DESIGN.md §15): a journaled trace is abandoned mid-flight after
its N-th dispatch (simulating SIGKILL at a dispatch boundary), the journal
is reopened and ``AsyncDecodeService.recover`` rebuilds the service —
``recovery_ms`` is that rebuild (checkpoint restore + WAL replay), and the
resumed trace must still deliver every stream bit-exact. The ``_ms``
suffix makes the row report-only under tools/bench_compare.py: recovery
latency is context, never a gate.
"""

from __future__ import annotations

import argparse
import asyncio
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

try:
    from . import bench_json  # package mode (python -m benchmarks.…)
except ImportError:
    import bench_json  # script mode (benchmarks/ on sys.path)

from repro.core.channel import transmit
from repro.core.codespec import get_code_spec
from repro.core.encoder import encode_jax, terminate
from repro.core.engine import DecoderEngine
from repro.core.pbvd import PBVDConfig
from repro.launch.faults import FaultInjector
from repro.launch.journal import ChunkJournal
from repro.launch.serve_async import AsyncDecodeService, run_poisson_trace
from repro.launch.slab import SymbolSlab

TABLE3 = bench_json.TABLE3


def _streams(spec, n_streams: int, payload_bits: int, ebn0: float, seed: int):
    payloads, ys = [], []
    for i in range(n_streams):
        rng = np.random.default_rng(seed + i)
        payload = rng.integers(0, 2, payload_bits)
        coded = encode_jax(jnp.asarray(terminate(payload, spec.code)), spec.code)
        tx = spec.puncture_stream(coded) if spec.is_punctured else coded
        y = transmit(jax.random.PRNGKey(seed + i), tx, ebn0, spec.rate)
        payloads.append(payload)
        ys.append(np.asarray(y))
    return payloads, ys


def run(
    *,
    code: str = "ccsds",
    backend: str = "ref",
    n_streams: int = 64,
    payload_bits: int = 16384,
    chunk_bits: int = 2048,
    deadline_ms: float = 5.0,
    max_batch_blocks: int = 64,
    rate_chunks_per_s: float = 2000.0,
    reps: int = 5,
    ebn0: float = 4.0,
    smoke: bool = False,
    fault_rate: float = 0.0,
) -> list[dict]:
    spec = get_code_spec(code)
    geom = dict(D=64, L=16, q=8) if smoke else TABLE3
    cfg = PBVDConfig(spec=spec, backend=backend, **geom)
    engine = DecoderEngine(cfg)
    payloads, ys = _streams(spec, n_streams, payload_bits, ebn0, seed=7)
    n_bits = [payload_bits] * n_streams
    # received symbols per payload chunk (1-D wire symbols for punctured
    # specs, full-rate stages otherwise)
    chunk_symbols = max(1, int(round(len(ys[0]) * chunk_bits / payload_bits)))
    # slab sized to the worst case — every stream holding a full decode
    # window plus one chunk of arrival jitter — so the trace measures
    # dispatch behaviour, not allocator starvation
    page_stages = geom["D"] + 2 * geom["L"]
    pages_per_stream = 2 + -(-chunk_symbols // page_stages) * 2
    refs = [np.asarray(engine.decode(jnp.asarray(y), payload_bits)) for y in ys]

    def trace():
        slab = SymbolSlab(
            n_pages=pages_per_stream * n_streams,
            page_stages=page_stages,
            R=spec.code.R,
        )
        # degraded mode (--fault-rate): TRANSIENT dispatch/slab faults only,
        # injected i.i.d. at the given rate from a fixed seed — the retry/
        # backpressure machinery absorbs every one, so streams still finish
        # bit-exact; what degrades (and what the row reports) is throughput
        # and tail latency
        injector = (
            FaultInjector(seed=13, rates={"dispatch": fault_rate, "slab": fault_rate})
            if fault_rate > 0.0
            else None
        )
        bits, report = asyncio.run(
            run_poisson_trace(
                engine,
                ys,
                n_bits,
                chunk_symbols=chunk_symbols,
                rate_chunks_per_s=rate_chunks_per_s,
                seed=11,
                slab=slab,
                service_kwargs=dict(
                    max_batch_blocks=max_batch_blocks,
                    deadline_ms=deadline_ms,
                ),
                fault_injector=injector,
            )
        )
        return bits, report

    # warm-up pass compiles every launch shape the trace will hit (step
    # coalescing shapes + per-stream flush shapes); compile time must not
    # masquerade as serving latency
    bits, _ = trace()
    for b, r in zip(bits, refs):
        np.testing.assert_array_equal(np.asarray(b), r)

    reports = []
    for _ in range(max(1, reps)):
        bits, report = trace()
        for b, r in zip(bits, refs):
            np.testing.assert_array_equal(np.asarray(b), r)
        reports.append(report)

    med = lambda k: float(np.median([r[k] for r in reports]))
    row = dict(
        kind="serve_latency",
        code=code,
        backend=backend,
        n_streams=n_streams,
        payload_bits=payload_bits,
        chunk_bits=chunk_bits,
        deadline_cfg_us=int(deadline_ms * 1e3),  # identity (not a *_ms metric)
        max_batch_blocks=max_batch_blocks,
        sustained_mbps=round(med("sustained_mbps"), 3),
        p50_ms=round(med("p50_ms"), 2),
        p99_ms=round(med("p99_ms"), 2),
        dispatch_steps=int(med("dispatches")),
    )
    if fault_rate > 0.0:
        # the extra identity field keeps degraded rows from ever matching a
        # clean baseline row in tools/bench_compare.py — degraded numbers
        # are REPORTED, never gated (the clean row still gates as before)
        row["fault_rate"] = fault_rate
        row["retry_steps"] = int(med("retries"))
    return [row]


def run_recovery(
    *,
    code: str = "ccsds",
    backend: str = "ref",
    n_streams: int = 16,
    payload_bits: int = 2048,
    chunk_bits: int = 512,
    max_batch_blocks: int = 32,
    kill_at: int = 2,
    reps: int = 3,
    ebn0: float = 4.0,
    smoke: bool = False,
) -> list[dict]:
    """Measure ``recover()`` latency at a dispatch-boundary crash point.

    The first incarnation drives the service manually (no background task)
    with a journal attached, abandons it the moment its ``kill_at``-th
    dispatch commits — nothing is closed, exactly like a SIGKILL — and the
    second incarnation rebuilds from the journal (fresh slab: a new process
    would not inherit the old allocator) and finishes the trace. Every
    stream must come out bit-exact to the one-shot reference or the row is
    not reported at all.
    """
    spec = get_code_spec(code)
    geom = dict(D=64, L=16, q=8) if smoke else TABLE3
    cfg = PBVDConfig(spec=spec, backend=backend, **geom)
    engine = DecoderEngine(cfg)
    payloads, ys = _streams(spec, n_streams, payload_bits, ebn0, seed=7)
    chunk_symbols = max(1, int(round(len(ys[0]) * chunk_bits / payload_bits)))
    page_stages = geom["D"] + 2 * geom["L"]
    pages_per_stream = 2 + -(-chunk_symbols // page_stages) * 2
    refs = [np.asarray(engine.decode(jnp.asarray(y), payload_bits)) for y in ys]
    chunk_lists = [
        [y[k * chunk_symbols : (k + 1) * chunk_symbols] for k in range(-(-len(y) // chunk_symbols))]
        for y in ys
    ]

    def slab():
        return SymbolSlab(
            n_pages=pages_per_stream * n_streams, page_stages=page_stages, R=spec.code.R
        )

    def one_rep():
        jdir = tempfile.mkdtemp(prefix="serve_recovery_")
        kwargs = dict(max_batch_blocks=max_batch_blocks, deadline_ms=0.0)

        async def crash_half():
            # incarnation 1: journaled, manually polled, abandoned mid-trace
            svc = AsyncDecodeService(slab=slab(), journal=ChunkJournal(jdir), **kwargs)
            streams = [svc.open(engine) for _ in range(n_streams)]
            for k in range(len(chunk_lists[0])):
                for st, chunks in zip(streams, chunk_lists):
                    if k < len(chunks):
                        await st.send(chunks[k])
                svc.poll()
                if svc.dispatches >= kill_at:
                    return True  # "SIGKILL": drop everything unclosed
            return False

        async def recover_half():
            t0 = time.perf_counter()
            svc = AsyncDecodeService.recover(
                ChunkJournal(jdir), engine, slab=slab(), **kwargs
            )
            ms = (time.perf_counter() - t0) * 1e3
            replayed = sum(
                st.chunks_admitted for st in svc.recovered_streams.values()
            )
            for i in range(n_streams):
                st = svc.recovered_streams[i]
                for k in range(st.chunks_admitted, len(chunk_lists[i])):
                    await st.send(chunk_lists[i][k])
                    svc.poll()
                got = np.concatenate([st.take(), await st.finish(payload_bits)])
                np.testing.assert_array_equal(got, refs[i])
            return ms, replayed

        if not asyncio.run(crash_half()):
            raise RuntimeError(
                f"trace completed before dispatch {kill_at}: the recovery row "
                f"would measure an empty journal — shrink max_batch_blocks"
            )
        return asyncio.run(recover_half())

    one_rep()  # warm-up: compile the launch shapes out of the measurement
    results = [one_rep() for _ in range(max(1, reps))]
    return [
        dict(
            kind="serve_recovery",
            code=code,
            backend=backend,
            n_streams=n_streams,
            payload_bits=payload_bits,
            chunk_bits=chunk_bits,
            max_batch_blocks=max_batch_blocks,
            kill_at_dispatch=kill_at,
            chunks_replayed=int(np.median([r[1] for r in results])),
            recovery_ms=round(float(np.median([r[0] for r in results])), 2),
        )
    ]


def merge_bench_json(rows: list[dict], path: str) -> None:
    # own "serve_recovery" only when actually merging such a row — a plain
    # latency run must not wipe recovery rows merged earlier
    kinds = ("serve_latency",)
    if any(r.get("kind") == "serve_recovery" for r in rows):
        kinds = ("serve_latency", "serve_recovery")
    bench_json.merge_rows(path, rows, kinds, geometry=TABLE3)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--code", default="ccsds")
    ap.add_argument("--backend", default="ref")
    ap.add_argument("--streams", type=int, default=64)
    ap.add_argument("--payload-bits", type=int, default=16384)
    ap.add_argument("--chunk-bits", type=int, default=2048)
    ap.add_argument("--deadline-ms", type=float, default=5.0)
    ap.add_argument("--max-batch-blocks", type=int, default=64)
    ap.add_argument("--rate", type=float, default=2000.0, metavar="CHUNKS_PER_S")
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument(
        "--fault-rate",
        type=float,
        default=0.0,
        help="ALSO measure a degraded-mode row: transient dispatch/slab "
        "faults injected i.i.d. at this rate (seeded), absorbed by "
        "retry/backpressure — streams stay bit-exact, the row reports the "
        "throughput/latency cost and is never gated",
    )
    ap.add_argument(
        "--kill-at",
        type=int,
        default=None,
        metavar="N",
        help="ALSO measure a crash-recovery row: abandon a journaled trace "
        "after its N-th dispatch, rebuild with AsyncDecodeService.recover, "
        "report recovery_ms (never gated) — resumed streams must still "
        "deliver bit-exact",
    )
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="tiny CI geometry: 16 streams × 2048-bit payloads, D=64 blocks",
    )
    ap.add_argument("--out", default=None, help="merge rows into this BENCH_*.json")
    args = ap.parse_args(argv if argv is not None else [])

    kw = dict(
        code=args.code,
        backend=args.backend,
        n_streams=args.streams,
        payload_bits=args.payload_bits,
        chunk_bits=args.chunk_bits,
        deadline_ms=args.deadline_ms,
        max_batch_blocks=args.max_batch_blocks,
        rate_chunks_per_s=args.rate,
        reps=args.reps,
        smoke=args.smoke,
    )
    if args.smoke:
        kw.update(
            n_streams=min(args.streams, 16),
            payload_bits=min(args.payload_bits, 2048),
            chunk_bits=min(args.chunk_bits, 512),
            max_batch_blocks=min(args.max_batch_blocks, 32),
            rate_chunks_per_s=max(args.rate, 4000.0),
            reps=min(args.reps, 3),
        )
    rows = run(**kw)
    if args.fault_rate > 0.0:
        # the degraded row rides NEXT TO the clean one: same trace, faults on
        rows += run(**kw, fault_rate=args.fault_rate)
    if args.kill_at is not None:
        rkw = {k: kw[k] for k in (
            "code", "backend", "n_streams", "payload_bits", "chunk_bits",
            "max_batch_blocks", "reps", "smoke",
        )}
        if args.smoke:
            # recovery_ms is report-only; a small fleet measures it just as
            # well and keeps the CI job from doubling its runtime
            rkw.update(n_streams=4, payload_bits=1024, chunk_bits=256, reps=1)
        rows += run_recovery(**rkw, kill_at=args.kill_at)
    for r in rows:
        print("serve_latency," + ",".join(f"{k}={v}" for k, v in r.items()))
    if args.out:
        merge_bench_json(rows, args.out)
        print(f"# merged into {args.out}")
    print(
        "\nevery stream asserted bit-exact to one-shot decode before "
        "reporting; sustained_mbps is gated by tools/bench_compare.py, "
        "latency is reported but not gated."
    )


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
