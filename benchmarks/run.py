"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines per the repo convention.

  table3_throughput — paper Table III (orig vs optimized decoder, T/P model)
  kernel_scaling    — paper Table III S_k column (K1/K2 split vs N_t)
  fig4_ber          — paper Fig. 4 (BER vs Eb/N0 for L ∈ {14,28,42})
  table4_comparison — paper Table IV (cross-work TNDC normalization)
  punctured_sweep   — beyond-paper: BER/throughput across punctured rates
  batched_throughput — beyond-paper: multi-stream aggregate Mb/s
                       (sequential vs decode_batch vs SessionPool)
  metric_sweep      — beyond-paper: folded-vs-full BM + f32/i16/i8
                       metric-mode decoded-bits/s (writes BENCH_*.json)
  traceback_sweep   — beyond-paper: serial vs parallel-prefix traceback
                       decoded-bits/s per tb_chunk + the ACS-vs-traceback
                       phase timing split (merges into BENCH_*.json)
  acs_radix_sweep   — beyond-paper: stage-fused radix-4 vs radix-2 ACS
                       decoded-bits/s per backend + the per-radix ACS
                       phase split (merges into BENCH_*.json)
  acs_matrix_sweep  — beyond-paper: k-stage (min,+) matrix ACS vs the
                       butterfly decoded-bits/s per backend × fusion depth
                       + the per-impl phase split (merges into BENCH_*.json)

``--metric-mode`` runs ONLY the metric sweep (the folded/quantized
hot-path numbers); ``--tb-mode serial prefix`` runs ONLY the traceback
sweep (``--tb-chunk`` sizes the prefix chunks); ``--acs-radix`` runs ONLY
the radix sweep; ``--acs-impl`` runs ONLY the matrix-vs-butterfly sweep
(``--acs-k`` sets the fusion depths). The CI benchmark-smoke job runs all
four into one artifact, then gates it with tools/bench_compare.py:

    python benchmarks/run.py --metric-mode --out BENCH_pr.json --smoke
    python benchmarks/run.py --tb-mode serial prefix --out BENCH_pr.json --smoke
    python benchmarks/run.py --acs-radix --out BENCH_pr.json --smoke
    python benchmarks/run.py --acs-impl --out BENCH_pr.json --smoke

Roofline tables (assignment §Roofline) are produced by
``python -m repro.launch.roofline`` from the dry-run reports.
"""

from __future__ import annotations

import argparse
import importlib
import sys
import time
from pathlib import Path


def _sibling(name: str):
    """Import a sibling benchmark module whether run as a script or -m."""
    if __package__:
        return importlib.import_module(f".{name}", __package__)
    sys.path.insert(0, str(Path(__file__).resolve().parent))
    return importlib.import_module(name)


def _run_all() -> None:
    for mod in (
        _sibling("table3_throughput"),
        _sibling("kernel_scaling"),
        _sibling("fig4_ber"),
        _sibling("table4_comparison"),
        _sibling("punctured_sweep"),
        _sibling("batched_throughput"),
        _sibling("metric_sweep"),
        _sibling("traceback_sweep"),
        _sibling("acs_radix_sweep"),
        _sibling("acs_matrix_sweep"),
    ):
        t0 = time.perf_counter()
        mod.main()
        print(
            f"# {mod.__name__.split('.')[-1]} finished in {time.perf_counter()-t0:.1f}s",
            file=sys.stderr,
        )


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--metric-mode",
        action="store_true",
        help="run only the metric-pipeline sweep (folded BM + f32/i16/i8)",
    )
    ap.add_argument(
        "--tb-mode",
        nargs="+",
        choices=("serial", "prefix"),
        default=None,
        metavar="MODE",
        help="run only the traceback sweep with these tb modes (reports the "
        "serial-vs-prefix decoded-bits/s and the ACS-vs-traceback phase split)",
    )
    ap.add_argument(
        "--tb-chunk",
        type=int,
        nargs="+",
        default=None,
        metavar="C",
        help="prefix chunk sizes for the traceback sweep (default: 32 64 128)",
    )
    ap.add_argument(
        "--acs-radix",
        action="store_true",
        help="run only the ACS-radix sweep (stage-fused radix-4 vs radix-2)",
    )
    ap.add_argument(
        "--acs-impl",
        action="store_true",
        help="run only the ACS-impl sweep (k-stage matrix vs butterfly)",
    )
    ap.add_argument(
        "--acs-k",
        type=int,
        nargs="+",
        default=None,
        metavar="K",
        help="matrix fusion depths for the ACS-impl sweep (default: 2 3)",
    )
    ap.add_argument(
        "--out", default=None, help="write/merge BENCH_*.json (sweep modes only)"
    )
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="tiny geometry for CI: fewer blocks/reps, same code paths",
    )
    args = ap.parse_args(argv)

    selected = args.metric_mode or args.tb_mode or args.acs_radix or args.acs_impl
    if (args.out or args.smoke) and not selected:
        ap.error(
            "--out/--smoke only apply to the sweeps; add "
            "--metric-mode/--tb-mode/--acs-radix/--acs-impl"
        )
    if args.tb_chunk and not args.tb_mode:
        ap.error("--tb-chunk only applies to the traceback sweep; add --tb-mode")
    if args.acs_k and not args.acs_impl:
        ap.error("--acs-k only applies to the ACS-impl sweep; add --acs-impl")
    # ALL sweep runs (smoke and full) use reps>=5 medians: the smoke rows
    # feed the CI regression gate — one noisy sample on a shared runner must
    # not trip the 15% threshold — and the committed full-geometry artifact
    # records the perf trajectory, which single-sample timings would smear
    reps = 5
    if args.metric_mode:
        metric_sweep = _sibling("metric_sweep")

        n_blocks = (8,) if args.smoke else (64, 512)
        rows = metric_sweep.run(n_blocks, reps=reps)
        for r in rows:
            print("metric_sweep," + ",".join(f"{k}={v}" for k, v in r.items()))
        if args.out:
            metric_sweep.write_bench_json(rows, args.out)
            print(f"# wrote {args.out}", file=sys.stderr)
    if args.tb_mode:
        traceback_sweep = _sibling("traceback_sweep")

        n_blocks = (8,) if args.smoke else (64, 512)
        tb_chunks = tuple(args.tb_chunk) if args.tb_chunk else (32, 64, 128)
        rows = traceback_sweep.run(
            n_blocks,
            tb_chunks=tb_chunks,
            tb_modes=tuple(args.tb_mode),
            reps=reps,
        )
        for r in rows:
            print("traceback_sweep," + ",".join(f"{k}={v}" for k, v in r.items()))
        if args.out:
            traceback_sweep.merge_bench_json(rows, args.out)
            print(f"# merged into {args.out}", file=sys.stderr)
    if args.acs_radix:
        acs_radix_sweep = _sibling("acs_radix_sweep")

        n_blocks = (8,) if args.smoke else (64, 256)
        rows = acs_radix_sweep.run(n_blocks, reps=reps)
        for r in rows:
            print("acs_radix_sweep," + ",".join(f"{k}={v}" for k, v in r.items()))
        if args.out:
            acs_radix_sweep.merge_bench_json(rows, args.out)
            print(f"# merged into {args.out}", file=sys.stderr)
    if args.acs_impl:
        acs_matrix_sweep = _sibling("acs_matrix_sweep")

        n_blocks = (8,) if args.smoke else (64, 256)
        ks = tuple(args.acs_k) if args.acs_k else (2, 3)
        rows = acs_matrix_sweep.run(n_blocks, ks=ks, reps=reps)
        for r in rows:
            print("acs_matrix_sweep," + ",".join(f"{k}={v}" for k, v in r.items()))
        if args.out:
            acs_matrix_sweep.merge_bench_json(rows, args.out)
            print(f"# merged into {args.out}", file=sys.stderr)
    if not selected:
        _run_all()


if __name__ == "__main__":
    main()
