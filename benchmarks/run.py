"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines per the repo convention.

  table3_throughput — paper Table III (orig vs optimized decoder, T/P model)
  kernel_scaling    — paper Table III S_k column (K1/K2 split vs N_t)
  fig4_ber          — paper Fig. 4 (BER vs Eb/N0 for L ∈ {14,28,42})
  table4_comparison — paper Table IV (cross-work TNDC normalization)
  punctured_sweep   — beyond-paper: BER/throughput across punctured rates
  batched_throughput — beyond-paper: multi-stream aggregate Mb/s
                       (sequential vs decode_batch vs SessionPool)

Roofline tables (assignment §Roofline) are produced by
``python -m repro.launch.roofline`` from the dry-run reports.
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    from . import (
        batched_throughput,
        fig4_ber,
        kernel_scaling,
        punctured_sweep,
        table3_throughput,
        table4_comparison,
    )

    for mod in (
        table3_throughput,
        kernel_scaling,
        fig4_ber,
        table4_comparison,
        punctured_sweep,
        batched_throughput,
    ):
        t0 = time.perf_counter()
        mod.main()
        print(
            f"# {mod.__name__.split('.')[-1]} finished in {time.perf_counter()-t0:.1f}s",
            file=sys.stderr,
        )


if __name__ == "__main__":
    main()
