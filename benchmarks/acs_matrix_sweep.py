"""ACS-impl sweep: k-stage (min,+) matrix ACS vs the butterfly, + phase split.

The matrix formulation collapses ``acs_k`` trellis stages into one batched
tropical-matmul step: the 2^(kR-1) folded combined metrics assemble the
(2^k, 2^k, N/2^k) transition matrix (on the Pallas paths via ONE dense MXU
matmul against the signed one-hot expansion operand), a suffix-min
tournament reduces the 2^k candidates per target, and every step still
emits k standard radix-2 survivor bit-planes — bit-exact to the butterfly,
with a k-fold shorter forward serial chain. This sweep runs at the paper's
64-state Table III geometry (CCSDS (2,1,7), D=512, L=42, 8-bit symbols)
and reports:

  * ``acs_impl_sweep`` rows — end-to-end ``DecoderEngine.decode``
    decoded-bits/s for butterfly radix-2/radix-4 vs matrix k=2/k=3 per
    backend;
  * ``acs_impl_phase_split`` rows — forward-pass wall time per formulation
    on the jnp kernels vs the serial traceback, extending the PR 5 radix
    split with the matrix dimension.

``--out BENCH_pr.json`` MERGES the rows into an existing benchmark artifact
(other benchmarks' rows are kept; stale acs-impl rows are replaced):

    PYTHONPATH=src python benchmarks/acs_matrix_sweep.py \
        [--n-blocks 64 256] [--backends ref pallas fused] [--ks 2 3] \
        [--reps 5] [--out BENCH_pr.json]
"""

from __future__ import annotations

import argparse

import jax.numpy as jnp
import numpy as np

try:
    from . import bench_json  # package mode (python -m benchmarks.…)
except ImportError:
    import bench_json  # script mode (benchmarks/ on sys.path)

from repro.core.codespec import get_code_spec
from repro.core.engine import DecoderEngine
from repro.core.pbvd import PBVDConfig
from repro.kernels.ref import acs_forward_ref, traceback_ref

TABLE3 = bench_json.TABLE3  # paper Table III geometry
MATRIX_KINDS = ("acs_impl_sweep", "acs_impl_phase_split")
_time = bench_json.time_median


def _phase_split_row(
    code, code_name: str, n_blocks: int, ks: tuple[int, ...], reps: int, seed: int
) -> dict:
    """Forward-pass wall time per ACS formulation vs the serial traceback.

    Integer symbols (the exact path): float inputs would lower the matrix
    impl to the butterfly, timing the wrong formulation.
    """
    D, L = TABLE3["D"], TABLE3["L"]
    T = D + 2 * L
    rng = np.random.default_rng(seed)
    y = jnp.asarray(
        np.clip(np.round(rng.normal(size=(T, code.R, n_blocks)) * 31.75), -127, 127)
        .astype(np.int8)
    )
    sp, _ = acs_forward_ref(y, code)
    start = jnp.zeros((n_blocks,), jnp.int32)
    row = dict(
        kind="acs_impl_phase_split",
        code=code_name,  # row identity for the bench_compare gate
        backend="ref",  # the split always measures the jnp (ref) kernels
        n_blocks=n_blocks,
        bfly_r2_ms=round(_time(lambda: acs_forward_ref(y, code, radix=2), reps) * 1e3, 2),
        bfly_r4_ms=round(_time(lambda: acs_forward_ref(y, code, radix=4), reps) * 1e3, 2),
        tb_serial_ms=round(_time(lambda: traceback_ref(sp, code, L, D, start), reps) * 1e3, 2),
    )
    for k in ks:
        ms = _time(
            lambda: acs_forward_ref(y, code, impl="matrix", matrix_k=k), reps
        ) * 1e3
        row[f"mat_k{k}_ms"] = round(ms, 2)
        # derived stat — outside bench_compare's identity
        row[f"mat_k{k}_vs_r2"] = round(row["bfly_r2_ms"] / ms, 3)
    return row


def run(
    n_blocks=(64, 256),
    *,
    code: str = "ccsds",
    backends=("ref", "pallas", "fused"),
    ks=(2, 3),
    reps: int = 5,
    seed: int = 7,
) -> list[dict]:
    spec = get_code_spec(code)
    ks = tuple(k for k in ks if k * spec.code.R <= 8 and k <= spec.code.v)
    D = TABLE3["D"]
    rows = [_phase_split_row(spec.code, code, max(n_blocks), ks, reps, seed)]
    for backend in backends:
        for nb in n_blocks:
            n_bits = D * nb
            rng = np.random.default_rng(seed)
            y = jnp.asarray(rng.normal(size=(n_bits, spec.code.R)).astype(np.float32))

            def mbps(**knobs) -> float:
                # i8 metric mode keeps the engine on integer symbols, so the
                # matrix impl runs its real (non-lowered) kernel end-to-end
                cfg = PBVDConfig(
                    spec=spec, backend=backend, metric_mode="i8", **knobs, **TABLE3
                )
                engine = DecoderEngine(cfg)
                return n_bits / _time(lambda: engine.decode(y, n_bits), reps) / 1e6

            row = dict(
                kind="acs_impl_sweep",
                code=code,
                backend=backend,
                n_blocks=nb,
                n_bits=n_bits,
                bfly_r2_mbps=round(mbps(acs_radix=2), 2),
                bfly_r4_mbps=round(mbps(acs_radix=4), 2),
            )
            for k in ks:
                m = mbps(acs_impl="matrix", acs_k=k)
                row[f"mat_k{k}_mbps"] = round(m, 2)
                row[f"mat_k{k}_vs_bfly_r2"] = round(m / row["bfly_r2_mbps"], 3)
            rows.append(row)
    return rows


def merge_bench_json(rows: list[dict], path: str, *, code: str = "ccsds") -> None:
    """Merge the acs-impl rows into ``path`` (other sweeps' rows preserved)."""
    bench_json.merge_rows(path, rows, MATRIX_KINDS, geometry=dict(code=code, **TABLE3))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-blocks", type=int, nargs="+", default=[64, 256])
    ap.add_argument("--backends", nargs="+", default=["ref", "pallas", "fused"])
    ap.add_argument("--ks", type=int, nargs="+", default=[2, 3])
    ap.add_argument("--code", default="ccsds")
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--out", default=None, help="merge rows into this BENCH_*.json")
    args = ap.parse_args(argv if argv is not None else [])
    rows = run(
        tuple(args.n_blocks),
        code=args.code,
        backends=tuple(args.backends),
        ks=tuple(args.ks),
        reps=args.reps,
    )
    for r in rows:
        print("acs_matrix_sweep," + ",".join(f"{k}={v}" for k, v in r.items()))
    if args.out:
        merge_bench_json(rows, args.out, code=args.code)
        print(f"# merged into {args.out}")
    print(
        "\nmatrix ACS collapses k trellis stages into one (min,+) tropical "
        "matmul step: the forward serial chain shrinks k-fold, the 2^(kR-1) "
        "folded combined metrics assemble via one MXU-shaped matmul on the "
        "Pallas paths, and every step still emits the standard radix-2 "
        "survivor bit-planes — decoded bits stay bit-exact to the butterfly."
    )


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
