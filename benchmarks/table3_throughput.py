"""Paper Table III: original vs optimized decoder, throughput vs N_t.

The paper's "original" decoder = one monolithic kernel, float32 I/O,
unpacked outputs. The "optimized" decoder = two-phase kernels (K1/K2),
8-bit packed inputs, bit-packed outputs.

Both pipelines run through the unified :class:`~repro.core.engine.DecoderEngine`
(ref backend — the XLA-CPU fast path on this container). We measure wall time
→ Mbps and additionally report the MODELED TPU-v5e throughput from the
paper's eq. (7) with the kernel rate replaced by the dry-run roofline bound
(see EXPERIMENTS.md §Perf for the derivation).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.channel import transmit
from repro.core.encoder import encode_jax, terminate
from repro.core.engine import DecoderEngine
from repro.core.pbvd import PBVDConfig, throughput_model
from repro.core.quantize import pack_bits, quantize_soft
from repro.core.trellis import CCSDS_27


def _stream(n_bits: int, seed=0):
    code = CCSDS_27
    rng = np.random.default_rng(seed)
    bits = terminate(rng.integers(0, 2, n_bits), code)
    coded = encode_jax(jnp.asarray(bits), code)
    return bits[:n_bits], transmit(jax.random.PRNGKey(seed), coded, 4.0, code.rate)


def _time(fn, *args, reps=3):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run(n_bits: int = 1 << 18) -> list[dict]:
    bits, y = _stream(n_bits)
    D, L = 512, 42
    rows = []

    # original: f32 soft symbols, unpacked int32 outputs, single fused pipeline
    eng_orig = DecoderEngine(PBVDConfig(D=D, L=L, q=None, backend="ref"))
    f_orig = jax.jit(lambda yy: eng_orig.decode(yy, n_bits))
    t_orig = _time(f_orig, y)

    # optimized: int8 quantized inputs, bit-packed outputs (paper §IV-C)
    eng_opt = DecoderEngine(PBVDConfig(D=D, L=L, q=8, backend="ref"))

    def opt_pipeline(yq):
        out = eng_opt.decode(yq.astype(jnp.int8), n_bits)
        pad = (-out.shape[0]) % 8
        return pack_bits(jnp.pad(out, (0, pad)))

    yq = quantize_soft(y, 8)
    f_opt = jax.jit(opt_pipeline)
    t_opt = _time(f_opt, yq)

    n_blocks = -(-n_bits // D)
    for name, t, q, packed in (("original", t_orig, None, False), ("optimized", t_opt, 8, True)):
        s_k = n_bits / t / 1e6  # measured CPU kernel throughput, Mbps
        rows.append(
            dict(
                variant=name,
                n_bits=n_bits,
                n_blocks=n_blocks,
                cpu_ms=round(t * 1e3, 2),
                cpu_mbps=round(s_k, 2),
                # modeled deployment throughput at the paper's transfer budget
                model_tp_paper_bw=round(
                    throughput_model(
                        D=D, L=L, R=2, q=q, packed_out=packed,
                        s_kernel_mbps=s_k, n_streams=3, bandwidth_gbps=8.0,
                    ),
                    1,
                ),
            )
        )
    return rows


def main():
    for r in run():
        extra = ",".join(f"{k}={v}" for k, v in r.items() if k not in ("variant", "cpu_ms"))
        print(f"table3_{r['variant']},{r['cpu_ms']*1000:.1f},{extra}")


if __name__ == "__main__":
    main()
