"""Kernel-level scaling benchmark (paper Table III's S_k column analogue):
decoder throughput vs number of parallel blocks N_t, plus the per-phase
split (K1 forward ACS vs K2 traceback) the paper reports as T_k1/T_k2.

The end-to-end number runs the framed blocks through the backend registry
(the same ``FramedBlocks`` contract the engine dispatches on); the per-phase
split instruments the ref kernels directly.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.trellis import CCSDS_27
from repro.kernels.ops import FramedBlocks, get_backend
from repro.kernels.ref import acs_forward_ref, traceback_ref


def _time(fn, *args, reps=3):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def run(d=512, l=42, backend="ref") -> list[dict]:
    code = CCSDS_27
    T = d + 2 * l
    rows = []
    rng = np.random.default_rng(0)
    decode = get_backend(backend)
    k1 = jax.jit(lambda y: acs_forward_ref(y, code))
    e2e = jax.jit(
        lambda y: decode(
            FramedBlocks(y, l, d), code, start_policy="zero", stage_chunk=64,
            interpret=jax.default_backend() != "tpu",
        )
    )
    for n_t in (64, 256, 1024, 4096):
        y = jnp.asarray(
            np.clip(rng.normal(size=(T, code.R, n_t)) * 32, -127, 127).astype(np.int8)
        )
        sp, pm = k1(y)
        t_k1 = _time(k1, y)
        k2 = jax.jit(
            lambda s: traceback_ref(s, code, l, d, jnp.zeros((s.shape[-1],), jnp.int32))
        )
        t_k2 = _time(k2, sp)
        t_e2e = _time(e2e, y)
        bits = d * n_t
        rows.append(
            dict(
                n_t=n_t,
                backend=backend,
                t_k1_ms=round(t_k1 * 1e3, 2),
                t_k2_ms=round(t_k2 * 1e3, 2),
                s_k_mbps=round(bits / (t_k1 + t_k2) / 1e6, 2),
                e2e_mbps=round(bits / t_e2e / 1e6, 2),
            )
        )
    return rows


def main():
    for r in run():
        print(
            f"kernel_scaling_nt{r['n_t']},{(r['t_k1_ms']+r['t_k2_ms'])*1000:.0f},"
            f"t_k1_ms={r['t_k1_ms']},t_k2_ms={r['t_k2_ms']},s_k_mbps={r['s_k_mbps']},"
            f"e2e_mbps={r['e2e_mbps']}"
        )


if __name__ == "__main__":
    main()
