"""Batched multi-stream throughput: aggregate Mb/s vs streams × frame length.

The paper saturates the GPU with the parallel blocks of ONE stream (Table
III geometry: D=512, L=42, 8-bit symbols). The serving workload is the
opposite shape — many short independent frames — and a sequential
per-stream loop leaves most of the 128-lane tile idle while paying a full
launch per frame. This sweep measures, for each (n_streams, frame_bits)
cell:

  * ``sequential``: one ``engine.decode`` launch per stream (the PR-1 path),
  * ``batched``: one ``engine.decode_batch`` launch for the whole fleet
    (flattened frames × blocks lane packing),
  * ``pooled``: a :class:`~repro.launch.serve_decoder.SessionPool` fed each
    stream in chunks, stepping once per ingest round,

and reports aggregate payload Mb/s plus the batched/sequential speedup.

    PYTHONPATH=src python benchmarks/batched_throughput.py \
        [--streams 1 4 16 64] [--frame-bits 256 1024 4096] [--reps 5]

``--devices 1 2 4 8`` runs the weak-scaling sweep instead: the stream
fleet grows proportionally to the device count and each cell decodes on a
``data=N`` sub-mesh of the visible devices (CPU rehearsal:
``XLA_FLAGS=--xla_force_host_platform_device_count=8``). Rows land in
BENCH_*.json as ``kind="batched_devices"`` — ``agg_mbps`` is gated by
tools/bench_compare.py, ``weak_eff_share`` (mbps ÷ devices × 1-device
mbps) is reported alongside:

    PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python benchmarks/batched_throughput.py --devices 1 2 4 8 \
        --smoke --out BENCH_pr.json
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

try:
    from . import bench_json  # package mode (python -m benchmarks.…)
except ImportError:
    import bench_json  # script mode (benchmarks/ on sys.path)

from repro.core.channel import transmit
from repro.core.codespec import get_code_spec
from repro.core.encoder import encode_jax, terminate
from repro.core.engine import DecoderEngine
from repro.core.pbvd import PBVDConfig
from repro.launch.serve_decoder import SessionPool

# Paper Table III geometry (CCSDS (2,1,7), D=512, L=42, 8-bit symbols).
TABLE3 = bench_json.TABLE3


def _streams(spec, n_streams: int, frame_bits: int, ebn0: float, seed: int):
    outs = []
    for i in range(n_streams):
        rng = np.random.default_rng(seed + i)
        payload = rng.integers(0, 2, frame_bits)
        coded = encode_jax(jnp.asarray(terminate(payload, spec.code)), spec.code)
        tx = spec.puncture_stream(coded) if spec.is_punctured else coded
        y = transmit(jax.random.PRNGKey(seed + i), tx, ebn0, spec.rate)
        outs.append((payload, jnp.asarray(y)))
    return outs


# reps>=5 MEDIAN of per-call times (the repo-wide sweep policy from
# bench_json) — the old mean-of-one-timed-loop folded any machine-load
# spike into every row
_time = bench_json.time_median


def run(
    streams=(1, 4, 16, 64),
    frame_bits=(256, 1024, 4096),
    *,
    code: str = "ccsds",
    backend: str = "ref",
    reps: int = 5,
    ebn0: float = 4.0,
    with_pool: bool = True,
    metric_mode: str = "f32",
) -> list[dict]:
    spec = get_code_spec(code)
    cfg = PBVDConfig(spec=spec, backend=backend, metric_mode=metric_mode, **TABLE3)
    engine = DecoderEngine(cfg)
    rows = []
    for fb in frame_bits:
        for ns in streams:
            data = _streams(spec, ns, fb, ebn0, seed=7)
            ys = [y for _, y in data]
            n_bits = [fb] * ns
            total = fb * ns

            dt_seq = _time(lambda: [engine.decode(y, fb) for y in ys], reps)
            dt_bat = _time(lambda: engine.decode_batch(ys, n_bits), reps)

            # sanity: the batched bits are the sequential bits, per frame
            seq = [np.asarray(engine.decode(y, fb)) for y in ys]
            bat = [np.asarray(b) for b in engine.decode_batch(ys, n_bits)]
            for a, b in zip(seq, bat):
                np.testing.assert_array_equal(a, b)

            row = dict(
                backend=backend,
                metric_mode=metric_mode,
                n_streams=ns,
                frame_bits=fb,
                seq_mbps=round(total / dt_seq / 1e6, 2),
                batch_mbps=round(total / dt_bat / 1e6, 2),
                speedup=round(dt_seq / dt_bat, 2),
            )
            if with_pool:
                ya = [np.asarray(y) for y in ys]

                def pooled():
                    pool = SessionPool()
                    hs = [pool.open(engine) for _ in ya]
                    outs = []
                    for y, h in zip(ya, hs):
                        h.feed(y)
                    pool.step()
                    for h in hs:
                        outs.append(np.concatenate([h.take(), h.finish(fb)]))
                    return outs

                dt_pool = _time(pooled, reps)
                row["pool_mbps"] = round(total / dt_pool / 1e6, 2)
            rows.append(row)
    return rows


def run_devices(
    devices=(1, 2, 4, 8),
    *,
    code: str = "ccsds",
    backend: str = "ref",
    frame_bits: int = 1024,
    streams_per_device: int = 2,
    reps: int = 5,
    ebn0: float = 4.0,
    smoke: bool = False,
) -> list[dict]:
    """Weak-scaling sweep: fleet grows with the device count, each cell one
    ``decode_batch`` launch on a ``data=N`` sub-mesh. Perfect scaling keeps
    ``agg_mbps / devices`` flat (``weak_eff_share`` = 1.0); the decode is
    collective-free, so efficiency measures pure partitioning overhead."""
    import jax

    from repro.launch.mesh import make_decode_mesh

    spec = get_code_spec(code)
    geom = dict(D=64, L=16, q=8) if smoke else TABLE3
    cfg = PBVDConfig(spec=spec, backend=backend, **geom)
    n_dev = len(jax.devices())
    rows = []
    base_mbps = None
    for d in devices:
        if d > n_dev:
            print(f"# skipping devices={d}: only {n_dev} device(s) visible")
            continue
        mesh = make_decode_mesh(f"data={d}")
        engine = DecoderEngine(cfg, mesh=mesh)
        ns = streams_per_device * d
        data = _streams(spec, ns, frame_bits, ebn0, seed=7)
        ys = [y for _, y in data]
        n_bits = [frame_bits] * ns
        dt = _time(lambda: engine.decode_batch(ys, n_bits), reps)
        mbps = frame_bits * ns / dt / 1e6
        if base_mbps is None:
            base_mbps = mbps / d  # normalize even if the sweep skips d=1
        rows.append(
            dict(
                kind="batched_devices",
                backend=backend,
                devices=d,
                n_streams=ns,
                frame_bits=frame_bits,
                agg_mbps=round(mbps, 2),
                weak_eff_share=round(mbps / (d * base_mbps), 3),
            )
        )
    return rows


def merge_bench_json(rows: list[dict], path: str) -> None:
    bench_json.merge_rows(path, rows, ("batched_devices",), geometry=TABLE3)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, nargs="+", default=[1, 4, 16, 64])
    ap.add_argument("--frame-bits", type=int, nargs="+", default=[256, 1024, 4096])
    ap.add_argument("--backend", default="ref")
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument(
        "--metric-mode", default="f32", choices=["f32", "i16", "i8"],
        help="path-metric pipeline for every launch in the sweep",
    )
    ap.add_argument(
        "--devices", type=int, nargs="+", default=None, metavar="N",
        help="run the weak-scaling devices sweep instead (data=N sub-meshes)",
    )
    ap.add_argument(
        "--out", default=None,
        help="merge devices rows into this BENCH_*.json (devices sweep only)",
    )
    ap.add_argument(
        "--smoke", action="store_true",
        help="tiny geometry for CI: short blocks, same code paths",
    )
    args = ap.parse_args(argv if argv is not None else [])
    if args.out and not args.devices:
        ap.error("--out only applies to the devices sweep; add --devices")
    if args.devices:
        fb = args.frame_bits[0] if args.frame_bits else 1024
        if args.smoke:
            fb = min(fb, 512)
        rows = run_devices(
            tuple(args.devices),
            backend=args.backend,
            frame_bits=fb,
            reps=args.reps,
            smoke=args.smoke,
        )
        for r in rows:
            print("batched_devices," + ",".join(f"{k}={v}" for k, v in r.items()))
        if args.out:
            merge_bench_json(rows, args.out)
            print(f"# merged into {args.out}")
        return
    rows = run(
        tuple(args.streams),
        tuple(args.frame_bits),
        backend=args.backend,
        reps=args.reps,
        metric_mode=args.metric_mode,
    )
    for r in rows:
        extra = ",".join(f"{k}={v}" for k, v in r.items())
        print(f"batched_throughput,{extra}")
    print(
        "\none decode_batch launch packs every frame's blocks onto the lane "
        "axis (Table III geometry) — short frames stop paying a launch each "
        "and the tile stays saturated."
    )


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
