"""Metric-pipeline sweep: folded-vs-full BM and f32/i16/i8 decoded-bits/s.

Runs at the paper's 64-state Table III geometry (CCSDS (2,1,7), D=512,
L=42, 8-bit symbols) and reports, per cell:

  * ``acs_fold`` / ``acs_full``: forward-ACS wall time with the
    symmetry-folded 2^(R-1) BM table vs the full 2^R table (the folded path
    is bit-exact to the full one — asserted here before timing);
  * ``f32`` / ``i16`` / ``i8``: end-to-end ``DecoderEngine.decode``
    decoded-bits/s per metric mode (the narrow modes run the amortized
    min-subtract pipeline, see ``repro.kernels.registry.METRIC_MODES``).

``--out BENCH_pr.json`` writes the rows as a benchmark artifact:

    PYTHONPATH=src python benchmarks/metric_sweep.py \
        [--n-blocks 64 512] [--reps 5] [--backend ref] [--out BENCH_pr.json]
"""

from __future__ import annotations

import argparse

import jax.numpy as jnp
import numpy as np

try:
    from . import bench_json  # package mode (python -m benchmarks.…)
except ImportError:
    import bench_json  # script mode (benchmarks/ on sys.path)

from repro.core.codespec import get_code_spec
from repro.core.engine import DecoderEngine
from repro.core.pbvd import PBVDConfig
from repro.kernels.ref import acs_forward_ref

TABLE3 = bench_json.TABLE3  # paper Table III geometry
MODES = ("f32", "i16", "i8")
METRIC_KINDS = ("acs_fold_vs_full", "metric_mode_mbps")
_time = bench_json.time_median


def _fold_row(code, code_name: str, n_blocks: int, reps: int, seed: int) -> dict:
    """Forward-ACS folded vs full timing (quantized int8 symbols)."""
    T = TABLE3["D"] + 2 * TABLE3["L"]
    rng = np.random.default_rng(seed)
    y = jnp.asarray(
        np.clip(np.round(rng.normal(size=(T, code.R, n_blocks)) * 31.75), -127, 127)
        .astype(np.int8)
    )
    sp_f, pm_f = acs_forward_ref(y, code, fold=True)
    sp_u, pm_u = acs_forward_ref(y, code, fold=False)
    assert jnp.array_equal(sp_f, sp_u) and jnp.array_equal(pm_f, pm_u)
    dt_fold = _time(lambda: acs_forward_ref(y, code, fold=True), reps)
    dt_full = _time(lambda: acs_forward_ref(y, code, fold=False), reps)
    return dict(
        kind="acs_fold_vs_full",
        code=code_name,  # row identity for the bench_compare gate
        n_blocks=n_blocks,
        fold_ms=round(dt_fold * 1e3, 2),
        full_ms=round(dt_full * 1e3, 2),
        fold_speedup=round(dt_full / dt_fold, 3),
    )


def run(
    n_blocks=(64, 512),
    *,
    code: str = "ccsds",
    backend: str = "ref",
    reps: int = 5,
    seed: int = 7,
) -> list[dict]:
    spec = get_code_spec(code)
    # fold micro-bench at the largest (saturating) fleet: the folded table
    # halves per-stage metric ops, which only shows once lanes fill SIMD
    rows = [_fold_row(spec.code, code, max(n_blocks), reps, seed)]
    for nb in n_blocks:
        n_bits = TABLE3["D"] * nb
        rng = np.random.default_rng(seed)
        y = jnp.asarray(rng.normal(size=(n_bits, spec.code.R)).astype(np.float32))
        row = dict(
            kind="metric_mode_mbps", code=code, backend=backend, n_blocks=nb, n_bits=n_bits
        )
        for mode in MODES:
            cfg = PBVDConfig(spec=spec, backend=backend, metric_mode=mode, **TABLE3)
            engine = DecoderEngine(cfg)
            dt = _time(lambda: engine.decode(y, n_bits), reps)
            row[f"{mode}_mbps"] = round(n_bits / dt / 1e6, 2)
        row["i8_vs_f32"] = round(row["i8_mbps"] / row["f32_mbps"], 2)
        row["i16_vs_f32"] = round(row["i16_mbps"] / row["f32_mbps"], 2)
        rows.append(row)
    return rows


def write_bench_json(rows: list[dict], path: str, *, code: str = "ccsds") -> None:
    """Merge the metric rows into ``path`` (other sweeps' rows preserved)."""
    bench_json.merge_rows(path, rows, METRIC_KINDS, geometry=dict(code=code, **TABLE3))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-blocks", type=int, nargs="+", default=[64, 512])
    ap.add_argument("--code", default="ccsds")
    ap.add_argument("--backend", default="ref")
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--out", default=None, help="write rows to this BENCH_*.json")
    args = ap.parse_args(argv if argv is not None else [])
    rows = run(tuple(args.n_blocks), code=args.code, backend=args.backend, reps=args.reps)
    for r in rows:
        print("metric_sweep," + ",".join(f"{k}={v}" for k, v in r.items()))
    if args.out:
        write_bench_json(rows, args.out, code=args.code)
        print(f"# wrote {args.out}")
    print(
        "\nfolded BM halves the per-stage metric table; the i8 pipeline "
        "(coarse symbols + amortized min-subtract int8 metrics) trades "
        "~0.2-0.3 dB of quantizer loss for the narrow-dtype throughput."
    )


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
