"""Traceback sweep: serial-vs-prefix K2 timings + ACS/traceback phase split.

Runs at the paper's 64-state Table III geometry (CCSDS (2,1,7), D=512,
L=42, 8-bit symbols) and reports:

  * ``traceback_sweep`` rows — end-to-end ``DecoderEngine.decode``
    decoded-bits/s with ``tb_mode="serial"`` vs ``tb_mode="prefix"`` per
    ``tb_chunk``, plus the serial step counts (T - decode_start for the
    serial walk, the active-chunk count for the prefix walk — the paper's
    O(T) chain becomes O(T/C));
  * ``traceback_phase_split`` rows — forward-ACS wall time vs
    traceback-only wall time per tb mode (the K1/K2 balance the paper
    reports in Table III), measured on the jnp kernels directly.

``--out BENCH_pr.json`` MERGES the rows into an existing benchmark artifact
(other benchmarks' rows are kept; stale traceback rows are replaced):

    PYTHONPATH=src python benchmarks/traceback_sweep.py \
        [--n-blocks 64 512] [--tb-chunks 32 64 128] [--reps 5] \
        [--backend ref] [--out BENCH_pr.json]
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

try:
    from . import bench_json  # package mode (python -m benchmarks.…)
except ImportError:
    import bench_json  # script mode (benchmarks/ on sys.path)

from repro.core.codespec import get_code_spec
from repro.core.engine import DecoderEngine
from repro.core.pbvd import PBVDConfig
from repro.kernels.ops import backend_tb_chunk_sensitive
from repro.kernels.ref import acs_forward_ref, traceback_prefix_ref, traceback_ref
from repro.kernels.traceback import prefix_chunk_geometry

TABLE3 = bench_json.TABLE3  # paper Table III geometry
TB_KINDS = ("traceback_sweep", "traceback_phase_split")
_time = bench_json.time_median


def _phase_split_row(code, code_name: str, n_blocks: int, reps: int, seed: int) -> dict:
    """K1 (ACS) vs K2 (traceback) wall time on the jnp kernels."""
    D, L = TABLE3["D"], TABLE3["L"]
    T = D + 2 * L
    rng = np.random.default_rng(seed)
    y = jnp.asarray(
        np.clip(np.round(rng.normal(size=(T, code.R, n_blocks)) * 31.75), -127, 127)
        .astype(np.int8)
    )
    sp, _ = acs_forward_ref(y, code)
    sp = jax.block_until_ready(sp)
    start = jnp.zeros((n_blocks,), jnp.int32)
    acs_ms = _time(lambda: acs_forward_ref(y, code), reps) * 1e3
    tb_serial_ms = _time(lambda: traceback_ref(sp, code, L, D, start), reps) * 1e3
    tb_prefix_ms = (
        _time(lambda: traceback_prefix_ref(sp, code, L, D, start), reps) * 1e3
    )
    return dict(
        kind="traceback_phase_split",
        code=code_name,  # row identity for the bench_compare gate
        backend="ref",  # the split always measures the jnp (ref) kernels
        n_blocks=n_blocks,
        acs_ms=round(acs_ms, 2),
        tb_serial_ms=round(tb_serial_ms, 2),
        tb_prefix_ms=round(tb_prefix_ms, 2),
        tb_serial_share=round(tb_serial_ms / (acs_ms + tb_serial_ms), 3),
        tb_prefix_share=round(tb_prefix_ms / (acs_ms + tb_prefix_ms), 3),
    )


def run(
    n_blocks=(64, 512),
    *,
    code: str = "ccsds",
    backend: str = "ref",
    tb_chunks=(32, 64, 128),
    tb_modes=("serial", "prefix"),
    reps: int = 5,
    seed: int = 7,
) -> list[dict]:
    spec = get_code_spec(code)
    D, L = TABLE3["D"], TABLE3["L"]
    T = D + 2 * L
    if not backend_tb_chunk_sensitive(backend):
        # chunk-free prefix implementation (e.g. ref's full-depth scan):
        # per-chunk timings would be the identical launch re-measured —
        # noise presented as a chunk-size effect. Keep one representative
        # chunk row (its *_walk_steps still document the chunked kernels'
        # serial-chain reduction at that C).
        tb_chunks = tb_chunks[:1]
    rows = [_phase_split_row(spec.code, code, max(n_blocks), reps, seed)]
    for nb in n_blocks:
        n_bits = D * nb
        rng = np.random.default_rng(seed)
        y = jnp.asarray(rng.normal(size=(n_bits, spec.code.R)).astype(np.float32))

        def mbps(tb_mode: str, tb_chunk: int) -> float:
            cfg = PBVDConfig(
                spec=spec, backend=backend, tb_mode=tb_mode, tb_chunk=tb_chunk,
                **TABLE3,
            )
            engine = DecoderEngine(cfg)
            return n_bits / _time(lambda: engine.decode(y, n_bits), reps) / 1e6

        serial_mbps = mbps("serial", tb_chunks[0]) if "serial" in tb_modes else None
        for C in tb_chunks:
            _, _, n_chunks, c_lo, _ = prefix_chunk_geometry(T, L, D, C)
            row = dict(
                kind="traceback_sweep",
                code=code,
                backend=backend,
                n_blocks=nb,
                n_bits=n_bits,
                tb_chunk=C,
                # walk lengths are derived stats (the *_steps suffix keeps
                # them OUT of bench_compare's row identity — a PR that
                # shortens the walk must still gate against the old row)
                serial_walk_steps=T - L,  # early-exit serial walk length
                prefix_walk_steps=n_chunks - c_lo,  # composed-map walk length
            )
            if serial_mbps is not None:
                row["serial_mbps"] = round(serial_mbps, 2)
            if "prefix" in tb_modes:
                row["prefix_mbps"] = round(mbps("prefix", C), 2)
            if serial_mbps is not None and "prefix" in tb_modes:
                row["prefix_vs_serial"] = round(row["prefix_mbps"] / serial_mbps, 2)
            rows.append(row)
    return rows


def merge_bench_json(rows: list[dict], path: str, *, code: str = "ccsds") -> None:
    """Merge the traceback rows into ``path`` (other sweeps' rows preserved)."""
    bench_json.merge_rows(path, rows, TB_KINDS, geometry=dict(code=code, **TABLE3))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-blocks", type=int, nargs="+", default=[64, 512])
    ap.add_argument("--tb-chunks", type=int, nargs="+", default=[32, 64, 128])
    ap.add_argument("--code", default="ccsds")
    ap.add_argument("--backend", default="ref")
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--out", default=None, help="merge rows into this BENCH_*.json")
    args = ap.parse_args(argv if argv is not None else [])
    rows = run(
        tuple(args.n_blocks),
        code=args.code,
        backend=args.backend,
        tb_chunks=tuple(args.tb_chunks),
        reps=args.reps,
    )
    for r in rows:
        print("traceback_sweep," + ",".join(f"{k}={v}" for k, v in r.items()))
    if args.out:
        merge_bench_json(rows, args.out, code=args.code)
        print(f"# merged into {args.out}")
    print(
        "\nthe prefix traceback composes tb_chunk-stage survivor maps in "
        "parallel and walks ceil(T/C) composed maps instead of T stages — "
        "the last serial O(T) chain in the decoder becomes O(T/C)."
    )


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
