"""Paper Table IV: cross-work throughput comparison under normalized cost.

We reproduce the table's normalization logic (TNDC — throughput under
normalized decoding cost) against the paper's published numbers and append
this work's measured-CPU and modeled-TPU rows. No GPU numbers are
re-measured (no GPU in this container); the paper rows are cited verbatim.
"""

from __future__ import annotations

# (work, device, T/P Mbps, TNDC) — verbatim from the paper's Table IV
PAPER_ROWS = [
    ("[6]", "GTX275", 28.7, 0.085),
    ("[7]", "8800GTX", 29.4, 0.170),
    ("[8]", "GTX580", 67.1, 0.085),
    ("[9]", "9800GTX", 90.8, 0.420),
    ("[11]", "HD7970", 391.5, 0.207),
    ("[10]", "Tesla C2050", 240.9, 0.468),
    ("[10]", "GTX580", 404.7, 0.512),
    ("paper", "GTX580", 598.3, 0.757),
    ("paper", "GTX980", 1802.5, 0.782),
]


def tpu_v5e_decoder_model(D=512, L=42, R=2, fused=True, vpu_ops=3.85e12, hbm=819e9):
    """Per-chip decoder throughput model (see EXPERIMENTS.md §Perf cell 3).

    memory ceiling: bytes/bit = (1+2L/D)·R (int8 in) + SP traffic + out
      two-kernel: SP written+read through HBM (2 × 8 B × (1+2L/D))
      fused:      SP lives in VMEM → only symbols in + packed bits out
    compute ceiling: ≈ 900 VPU ops per decoded bit (ACS 64 states + group
      BM expansion + packing), VPU ≈ 3.85e12 op/s on v5e.
    """
    overhead = 1.0 + 2.0 * L / D
    bytes_per_bit = overhead * R + 0.125 + (0.0 if fused else 2 * 8 * overhead)
    mem_gbps = hbm / bytes_per_bit / 1e9
    ops_per_bit = 900.0 * overhead  # ~770 VPU ops/stage, (1+2L/D) stages per bit
    compute_gbps = vpu_ops / ops_per_bit / 1e9
    return dict(
        mem_ceiling_gbps=round(mem_gbps, 1),
        compute_ceiling_gbps=round(compute_gbps, 1),
        bound=round(min(mem_gbps, compute_gbps), 1),
    )


def run() -> list[dict]:
    rows = [
        dict(work=w, device=d, tp_mbps=tp, tndc=tndc, speedup=round(0.782 / tndc, 2))
        for w, d, tp, tndc in PAPER_ROWS
    ]
    # this work, measured on CPU (XLA) — see table3 benchmark for the numbers
    from .table3_throughput import run as t3

    ours = t3(1 << 18)
    opt = next(r for r in ours if r["variant"] == "optimized")
    rows.append(
        dict(
            work="this-repro", device="CPU(XLA, 1 core)", tp_mbps=opt["cpu_mbps"],
            tndc=None, speedup=None,
        )
    )
    two_kernel = tpu_v5e_decoder_model(fused=False)
    fused = tpu_v5e_decoder_model(fused=True)
    rows.append(
        dict(
            work="this-repro(2-kernel,modeled)", device="TPUv5e-chip",
            tp_mbps=two_kernel["bound"] * 1e3, tndc=None, speedup=None,
            note=f"mem {two_kernel['mem_ceiling_gbps']} / compute {two_kernel['compute_ceiling_gbps']} Gb/s",
        )
    )
    rows.append(
        dict(
            work="this-repro(fused,modeled)", device="TPUv5e-chip",
            tp_mbps=fused["bound"] * 1e3, tndc=None, speedup=None,
            note=f"mem {fused['mem_ceiling_gbps']} / compute {fused['compute_ceiling_gbps']} Gb/s; pod aggregate ×256",
        )
    )
    return rows


def main():
    for r in run():
        print(
            f"table4_{r['work']}_{r['device'].replace(' ', '')},0,"
            + ",".join(f"{k}={v}" for k, v in r.items())
        )


if __name__ == "__main__":
    main()
