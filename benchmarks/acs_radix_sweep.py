"""ACS-radix sweep: stage-fused radix-4 vs radix-2 decoded-bits/s + phase split.

PR 4's phase split put the forward ACS pass at ~98% of decode time once the
traceback parallelized; the radix-4 knob attacks exactly that phase by
collapsing two trellis stages into one fused step (half the serial chain,
one normalization/survivor-emission round per two bits, double-buffered
symbol prefetch on the fused backend). This sweep runs at the paper's
64-state Table III geometry (CCSDS (2,1,7), D=512, L=42, 8-bit symbols)
and reports:

  * ``acs_radix_sweep`` rows — end-to-end ``DecoderEngine.decode``
    decoded-bits/s with ``acs_radix=2`` vs ``acs_radix=4`` per backend;
  * ``acs_radix_phase_split`` rows — forward-ACS wall time per radix on the
    jnp kernels (including the combined-folded-metric formulation of the
    fused step, kept as the measured alternative) vs the serial traceback,
    updating the PR 4 ACS-vs-traceback split with the radix dimension.

``--out BENCH_pr.json`` MERGES the rows into an existing benchmark artifact
(other benchmarks' rows are kept; stale acs-radix rows are replaced):

    PYTHONPATH=src python benchmarks/acs_radix_sweep.py \
        [--n-blocks 64 256] [--backends ref pallas fused] [--reps 5] \
        [--out BENCH_pr.json]
"""

from __future__ import annotations

import argparse

import jax.numpy as jnp
import numpy as np

try:
    from . import bench_json  # package mode (python -m benchmarks.…)
except ImportError:
    import bench_json  # script mode (benchmarks/ on sys.path)

from repro.core.codespec import get_code_spec
from repro.core.engine import DecoderEngine
from repro.core.pbvd import PBVDConfig
from repro.kernels.ref import acs_forward_ref, traceback_ref

TABLE3 = bench_json.TABLE3  # paper Table III geometry
RADIX_KINDS = ("acs_radix_sweep", "acs_radix_phase_split")
_time = bench_json.time_median


def _phase_split_row(code, code_name: str, n_blocks: int, reps: int, seed: int) -> dict:
    """Forward-ACS wall time per radix vs the serial traceback (jnp kernels).

    ``acs_r4_ms`` times the staged fused step (the production form);
    ``acs_r4_combined_ms`` times the combined 2^(2R-1)-folded-metric
    formulation — both bit-exact, committed so the formulation choice stays
    a measured one.
    """
    D, L = TABLE3["D"], TABLE3["L"]
    T = D + 2 * L
    rng = np.random.default_rng(seed)
    y = jnp.asarray(
        np.clip(np.round(rng.normal(size=(T, code.R, n_blocks)) * 31.75), -127, 127)
        .astype(np.int8)
    )
    sp, _ = acs_forward_ref(y, code)
    start = jnp.zeros((n_blocks,), jnp.int32)
    acs_r2_ms = _time(lambda: acs_forward_ref(y, code, radix=2), reps) * 1e3
    acs_r4_ms = _time(lambda: acs_forward_ref(y, code, radix=4), reps) * 1e3
    acs_r4c_ms = (
        _time(lambda: acs_forward_ref(y, code, radix=4, r4_combine=True), reps) * 1e3
    )
    tb_ms = _time(lambda: traceback_ref(sp, code, L, D, start), reps) * 1e3
    return dict(
        kind="acs_radix_phase_split",
        code=code_name,  # row identity for the bench_compare gate
        backend="ref",  # the split always measures the jnp (ref) kernels
        n_blocks=n_blocks,
        acs_r2_ms=round(acs_r2_ms, 2),
        acs_r4_ms=round(acs_r4_ms, 2),
        acs_r4_combined_ms=round(acs_r4c_ms, 2),
        tb_serial_ms=round(tb_ms, 2),
        # *_share/_vs_* are derived stats — outside bench_compare's identity
        acs_r2_share=round(acs_r2_ms / (acs_r2_ms + tb_ms), 3),
        acs_r4_share=round(acs_r4_ms / (acs_r4_ms + tb_ms), 3),
        acs_r4_vs_r2=round(acs_r2_ms / acs_r4_ms, 3),
    )


def run(
    n_blocks=(64, 256),
    *,
    code: str = "ccsds",
    backends=("ref", "pallas", "fused"),
    reps: int = 5,
    seed: int = 7,
) -> list[dict]:
    spec = get_code_spec(code)
    D = TABLE3["D"]
    rows = [_phase_split_row(spec.code, code, max(n_blocks), reps, seed)]
    for backend in backends:
        for nb in n_blocks:
            n_bits = D * nb
            rng = np.random.default_rng(seed)
            y = jnp.asarray(rng.normal(size=(n_bits, spec.code.R)).astype(np.float32))

            def mbps(radix: int) -> float:
                cfg = PBVDConfig(spec=spec, backend=backend, acs_radix=radix, **TABLE3)
                engine = DecoderEngine(cfg)
                return n_bits / _time(lambda: engine.decode(y, n_bits), reps) / 1e6

            r2, r4 = mbps(2), mbps(4)
            rows.append(
                dict(
                    kind="acs_radix_sweep",
                    code=code,
                    backend=backend,
                    n_blocks=nb,
                    n_bits=n_bits,
                    radix2_mbps=round(r2, 2),
                    radix4_mbps=round(r4, 2),
                    radix4_vs_radix2=round(r4 / r2, 3),
                )
            )
    return rows


def merge_bench_json(rows: list[dict], path: str, *, code: str = "ccsds") -> None:
    """Merge the acs-radix rows into ``path`` (other sweeps' rows preserved)."""
    bench_json.merge_rows(path, rows, RADIX_KINDS, geometry=dict(code=code, **TABLE3))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-blocks", type=int, nargs="+", default=[64, 256])
    ap.add_argument("--backends", nargs="+", default=["ref", "pallas", "fused"])
    ap.add_argument("--code", default="ccsds")
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--out", default=None, help="merge rows into this BENCH_*.json")
    args = ap.parse_args(argv if argv is not None else [])
    rows = run(
        tuple(args.n_blocks),
        code=args.code,
        backends=tuple(args.backends),
        reps=args.reps,
    )
    for r in rows:
        print("acs_radix_sweep," + ",".join(f"{k}={v}" for k, v in r.items()))
    if args.out:
        merge_bench_json(rows, args.out, code=args.code)
        print(f"# merged into {args.out}")
    print(
        "\nradix-4 fuses two trellis stages into one 4-way compare-select "
        "step: the ACS serial chain (98% of decode time post-PR 4) halves, "
        "normalization/survivor emission amortize over two bits, and the "
        "fused backend overlaps the symbol HBM reads with a double-buffered "
        "VMEM pipeline."
    )


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
