"""Paper Fig. 4: BER of the (2,1,7) CCSDS code vs Eb/N0 for several
traceback depths L (D = 512, 8-bit quantization).

Reproduces the paper's finding: L = 42 (≈6K) is indistinguishable from
full-depth Viterbi; shallow L degrades error floors.
"""

from __future__ import annotations

import time

import jax

from repro.core.ber import simulate_ber, uncoded_ber
from repro.core.pbvd import PBVDConfig


def run(n_bits: int = 1 << 15, ebn0_grid=(2.0, 3.0, 4.0), depths=(14, 28, 42)) -> list[dict]:
    rows = []
    key = jax.random.PRNGKey(0)
    for ebn0 in ebn0_grid:
        row = {"ebn0_db": ebn0, "uncoded": uncoded_ber(ebn0)}
        for L in depths:
            cfg = PBVDConfig(D=512, L=L, q=8, backend="ref")
            key, k = jax.random.split(key)
            row[f"L{L}"] = simulate_ber(k, ebn0, cfg, n_bits=n_bits)
        rows.append(row)
    return rows


def main():
    t0 = time.perf_counter()
    rows = run()
    dt_us = (time.perf_counter() - t0) * 1e6
    for row in rows:
        derived = ",".join(
            f"{k}={v:.2e}" if isinstance(v, float) else f"{k}={v}" for k, v in row.items()
        )
        print(f"fig4_ber_ebn0_{row['ebn0_db']},{dt_us/len(rows):.0f},{derived}")


if __name__ == "__main__":
    main()
